//! Offline stub of the `xla` PJRT bindings.
//!
//! The serving/simulation stack (engine, schedulers, cluster, gateway,
//! experiments) has no XLA dependency at runtime — only the real-model
//! backend ([`PjRtClient`] and friends) does. This stub provides the
//! exact API surface `backend/pjrt.rs` and `runtime/engine.rs` compile
//! against, so the whole crate builds and tests in environments without
//! the XLA toolchain. Creating a client fails with a clear error at
//! runtime; the TCP-server integration test already skips gracefully
//! when the model artifacts are absent.
//!
//! To serve the real compiled tiny-OPT model, repoint the workspace
//! dependency `xla = { path = "third_party/xla" }` at the actual PJRT
//! bindings crate (same API).

use std::fmt;
use std::path::Path;

pub type Result<T> = std::result::Result<T, Error>;

/// Error type mirroring the real bindings' (Display + std::error::Error,
/// convertible into `anyhow::Error` via `?`).
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: PJRT is unavailable in this build (offline `xla` stub); \
             point the workspace `xla` dependency at the real bindings"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// Element types accepted by [`Literal::vec1`].
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}

/// Host-side literal (tensor). The stub only carries shape-free
/// placeholder state — every data-bearing operation errors.
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::to_tuple"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

/// Device buffer returned by an executable.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// HLO module proto parsed from a text file.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// A computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_surfaces_clear_errors() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("stub"));
        assert!(Literal::vec1(&[1.0f32, 2.0]).reshape(&[2, 1]).is_ok());
        assert!(Literal.to_vec::<f32>().is_err());
        assert!(HloModuleProto::from_text_file("nope.hlo.txt").is_err());
    }
}
