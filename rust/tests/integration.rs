//! Integration & property tests across the coordinator, backends, and
//! server (DESIGN.md §6).

use andes::backend::sim::SimBackend;
use andes::backend::VirtualClock;
use andes::coordinator::engine::{Engine, EngineConfig};
use andes::coordinator::kv::KvCacheManager;
use andes::coordinator::sched::andes::{AndesConfig, AndesScheduler};
use andes::coordinator::sched::dp::solve_exact_knapsack;
use andes::coordinator::sched::fcfs::FcfsScheduler;
use andes::coordinator::sched::round_robin::RoundRobinScheduler;
use andes::coordinator::sched::Scheduler;
use andes::experiments::runner::{SchedKind, SimRun};
use andes::model::gpu::a100_4x;
use andes::model::latency::LatencyModel;
use andes::model::llm::opt_66b;
use andes::util::rng::Rng;
use andes::util::testing::{check_prop, gen_vec};
use andes::workload::{ArrivalProcess, Dataset, QoeTrace, Workload};

fn small_engine(sched: Box<dyn Scheduler>, kv_tokens: usize) -> Engine<SimBackend, VirtualClock> {
    let latency = LatencyModel::for_deployment(&opt_66b(), &a100_4x());
    let cfg = EngineConfig {
        kv_capacity_tokens: kv_tokens,
        swap_capacity_tokens: kv_tokens,
        ..EngineConfig::default()
    };
    Engine::new(cfg, SimBackend::new(latency.clone()), VirtualClock::default(), sched, latency)
}

// ---------------------------------------------------------------- engine

#[test]
fn token_conservation_across_schedulers_and_pressure() {
    // Every request must receive exactly its ground-truth token count,
    // in monotone time order, regardless of scheduler and memory size.
    check_prop("token conservation", 12, |rng| {
        let kv_tokens = rng.range(1500, 8000);
        let sched: Box<dyn Scheduler> = match rng.below(3) {
            0 => Box::new(FcfsScheduler::new()),
            1 => Box::new(RoundRobinScheduler::new(rng.range(5, 60) as u64)),
            _ => Box::new(AndesScheduler::with_defaults()),
        };
        let mut e = small_engine(sched, kv_tokens);
        let wl = Workload {
            dataset: Dataset::ShareGpt,
            arrivals: ArrivalProcess::Poisson { rate: 1.0 + rng.f64() * 5.0 },
            qoe_trace: QoeTrace::TextReading,
            num_requests: 25,
            seed: rng.next_u64(),
        };
        let trace = wl.generate();
        let expect: Vec<usize> = trace.iter().map(|r| r.output_tokens).collect();
        e.load_trace(trace);
        let m = e.run_to_completion().unwrap();
        assert_eq!(m.requests.len(), 25, "lost requests");
        for r in &m.requests {
            assert_eq!(r.token_times.len(), expect[r.id].min(2048), "req {}", r.id);
            assert!(
                r.token_times.windows(2).all(|w| w[1] >= w[0] - 1e-12),
                "non-monotone delivery"
            );
            assert!((0.0..=1.0).contains(&r.final_qoe), "qoe out of range");
        }
        // All KV released.
        assert_eq!(e.kv().num_allocations(), 0);
    });
}

#[test]
fn simulation_is_deterministic() {
    let run = |seed| {
        SimRun {
            llm: opt_66b(),
            gpu: a100_4x(),
            sched: SchedKind::andes_default(),
            dataset: Dataset::ShareGpt,
            arrivals: ArrivalProcess::Poisson { rate: 4.0 },
            qoe_trace: QoeTrace::TextReading,
            num_requests: 120,
            seed,
        }
        .execute()
    };
    let a = run(9);
    let b = run(9);
    assert_eq!(a.avg_qoe(), b.avg_qoe());
    assert_eq!(a.total_tokens, b.total_tokens);
    assert_eq!(a.total_preemptions, b.total_preemptions);
    // A different seed draws different workloads (QoE can coincide at
    // 1.0 under light load, so compare token totals).
    let c = run(10);
    assert_ne!(a.total_tokens, c.total_tokens);
}

#[test]
fn andes_beats_fcfs_under_overload() {
    // The headline claim, as a regression test: at ~1.7× estimated
    // capacity, Andes's average QoE must clearly exceed FCFS's.
    let rate =
        andes::experiments::runner::eval_rate(&opt_66b(), &a100_4x(), Dataset::ShareGpt);
    let run = |sched| {
        SimRun {
            llm: opt_66b(),
            gpu: a100_4x(),
            sched,
            dataset: Dataset::ShareGpt,
            arrivals: ArrivalProcess::Poisson { rate },
            qoe_trace: QoeTrace::TextReading,
            num_requests: 800,
            seed: 42,
        }
        .execute()
    };
    let fcfs = run(SchedKind::Fcfs);
    let andes = run(SchedKind::andes_default());
    assert!(
        andes.avg_qoe() > fcfs.avg_qoe() * 1.1,
        "andes {:.3} vs fcfs {:.3}",
        andes.avg_qoe(),
        fcfs.avg_qoe()
    );
    // And the preemption cap holds.
    assert!(andes.preemption_frequency() <= 1.1);
}

#[test]
fn preemption_cap_zero_means_no_scheduler_preemptions() {
    let mut e = small_engine(
        Box::new(AndesScheduler::new(AndesConfig {
            preemption_cap: 0.0,
            ..AndesConfig::default()
        })),
        3000,
    );
    let wl = Workload {
        dataset: Dataset::ShareGpt,
        arrivals: ArrivalProcess::Poisson { rate: 6.0 },
        qoe_trace: QoeTrace::TextReading,
        num_requests: 80,
        seed: 3,
    };
    e.load_trace(wl.generate());
    let m = e.run_to_completion().unwrap();
    // Only the engine's OOM safety net may preempt with P = 0.
    assert_eq!(
        m.total_preemptions, m.oom_preemptions,
        "scheduler preempted {} times with P=0",
        m.total_preemptions - m.oom_preemptions
    );
}

// ------------------------------------------------------------------- kv

#[test]
fn kv_manager_invariants_under_random_ops() {
    // Random alloc/extend/swap/free plus the prefix-park lifecycle
    // (park → claim/drop, with LRU eviction under host pressure): the
    // pool invariants must hold after every op, and releasing every
    // allocation and parked prefix must return both pools to zero.
    const PARK_KEYS: u64 = 6;
    check_prop("kv invariants", 200, |rng| {
        let block = 1 << rng.range(2, 5); // 4..16
        let device = block * rng.range(4, 40);
        let host = block * rng.range(0, 20);
        let mut kv = KvCacheManager::new(device, host, block);
        let mut live: Vec<usize> = Vec::new();
        let mut next_id = 0usize;
        let ops = gen_vec(rng, 120, |r| r.below(8));
        for op in ops {
            match op {
                0 => {
                    let tokens = rng.range(1, device.max(2));
                    if kv.allocate(next_id, tokens).is_ok() {
                        live.push(next_id);
                    }
                    next_id += 1;
                }
                1 => {
                    if !live.is_empty() {
                        let id = *rng.choose(&live);
                        let _ = kv.extend(id, rng.range(1, 40));
                    }
                }
                2 => {
                    if !live.is_empty() {
                        let id = *rng.choose(&live);
                        let _ = kv.swap_out(id);
                    }
                }
                3 => {
                    if !live.is_empty() {
                        let id = *rng.choose(&live);
                        let _ = kv.swap_in(id);
                    }
                }
                4 => {
                    // Park a live allocation under a random session key;
                    // on success the allocation is consumed.
                    if !live.is_empty() {
                        let idx = rng.range(0, live.len() - 1);
                        let id = live[idx];
                        if kv.park(rng.below(PARK_KEYS), id).is_ok() {
                            live.swap_remove(idx);
                        }
                    }
                }
                5 => {
                    let _ = kv.claim_parked(rng.below(PARK_KEYS));
                }
                6 => {
                    let _ = kv.drop_parked(rng.below(PARK_KEYS));
                }
                _ => {
                    if !live.is_empty() {
                        let idx = rng.range(0, live.len() - 1);
                        let id = live.swap_remove(idx);
                        kv.free(id).unwrap();
                    }
                }
            }
            // Invariants after every op.
            assert!(kv.device_free_blocks() <= device / block);
            assert!(kv.host_free_blocks() <= host / block);
            assert!(kv.device_utilization() <= 1.0 + 1e-12);
            assert!(
                kv.parked_blocks() <= host / block - kv.host_free_blocks(),
                "parked blocks must be accounted inside host usage"
            );
            assert!(kv.parked_count() as u64 <= PARK_KEYS);
        }
        // Release everything: allocations, then parked prefixes.
        for id in live {
            kv.free(id).unwrap();
        }
        for key in 0..PARK_KEYS {
            kv.drop_parked(key);
        }
        assert_eq!(kv.num_allocations(), 0);
        assert_eq!(kv.parked_count(), 0);
        assert_eq!(kv.parked_blocks(), 0);
        assert_eq!(kv.device_free_tokens(), (device / block) * block);
        assert_eq!(kv.host_free_blocks(), host / block, "host pool must drain to zero");
    });
}

// ------------------------------------------------------------- knapsack

#[test]
fn greedy_never_beats_dp_value() {
    // DP is exact for the (≤B, ≤capacity) relaxation it solves; greedy
    // by value/weight must never exceed it on identical instances.
    check_prop("greedy ≤ dp", 150, |rng| {
        let n = rng.range(1, 14);
        let weights: Vec<usize> = (0..n).map(|_| rng.range(1, 12)).collect();
        let values: Vec<f64> = (0..n).map(|_| rng.f64() * 3.0).collect();
        let b = rng.range(1, n);
        let cap = rng.range(4, 50);
        let (_, dp_val) = solve_exact_knapsack(&weights, &values, b, cap);
        // Simple greedy replica of Algorithm 1.
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&i, &j| {
            (values[j] / weights[j] as f64).total_cmp(&(values[i] / weights[i] as f64))
        });
        let mut used = 0usize;
        let mut cnt = 0usize;
        let mut greedy_val = 0.0;
        for i in order {
            if cnt < b && used + weights[i] <= cap {
                used += weights[i];
                cnt += 1;
                greedy_val += values[i];
            }
        }
        assert!(
            greedy_val <= dp_val + 1e-9,
            "greedy {greedy_val} > dp {dp_val} (w={weights:?} v={values:?} b={b} cap={cap})"
        );
    });
}

// -------------------------------------------------------------- workload

#[test]
fn workload_respects_context_budget() {
    check_prop("workload bounds", 40, |rng| {
        let wl = Workload {
            dataset: if rng.chance(0.5) { Dataset::ShareGpt } else { Dataset::MultiRoundShareGpt },
            arrivals: ArrivalProcess::Gamma { rate: 0.5 + rng.f64() * 5.0, cv: 1.0 + rng.f64() * 3.0 },
            qoe_trace: QoeTrace::TextReading,
            num_requests: 200,
            seed: rng.next_u64(),
        };
        for r in wl.generate() {
            assert!(r.prompt_tokens + r.output_tokens <= 1024);
            assert!(r.qoe.tds > 0.0 && r.qoe.ttft >= 0.0);
        }
    });
}

// --------------------------------------------------------------- gateway

#[test]
fn gateway_full_stack_conserves_requests() {
    // Every arrival must come back exactly once: served (with a QoE in
    // range) or rejected (with a structured reason) — across loads and
    // both arrival processes.
    use andes::cluster::{Cluster, RoutingPolicy};
    use andes::config::SchedulerConfig;
    use andes::gateway::{Gateway, GatewayConfig};

    let latency = LatencyModel::for_deployment(&opt_66b(), &a100_4x());
    for (rate, cv) in [(2.0, 1.0), (8.0, 3.0)] {
        let cfg = EngineConfig {
            kv_capacity_tokens: 6000,
            swap_capacity_tokens: 12_000,
            ..EngineConfig::default()
        };
        let cluster = Cluster::new(
            2,
            cfg,
            latency.clone(),
            &SchedulerConfig::Fcfs,
            RoutingPolicy::QoeAware,
        );
        let mut gcfg = GatewayConfig::default();
        gcfg.surge.baseline_rate = 2.0;
        let mut gw = Gateway::new(cluster, gcfg);
        let trace = Workload {
            dataset: Dataset::ShareGpt,
            arrivals: if cv == 1.0 {
                ArrivalProcess::Poisson { rate }
            } else {
                ArrivalProcess::Gamma { rate, cv }
            },
            qoe_trace: QoeTrace::TextReading,
            num_requests: 80,
            seed: 13,
        }
        .generate();
        let res = gw.run_trace(trace).unwrap();
        assert_eq!(
            res.served.len() + res.rejections.len(),
            80,
            "rate {rate} cv {cv}: request conservation"
        );
        for s in &res.served {
            assert!((0.0..=1.0).contains(&s.paced_qoe), "qoe out of range");
            assert!(s.paced_early_tokens <= s.output_tokens);
        }
        for r in &res.rejections {
            assert!(!r.reason.label().is_empty());
        }
        assert_eq!(res.stats.admitted, res.served.len());
        assert_eq!(res.stats.rejected, res.rejections.len());
    }
}

#[test]
fn gateway_conserves_requests_across_random_traces() {
    // Property: for random traces (one-shot or multi-turn sessions),
    // loads, and gateway shapes — plain, autoscaling, spilling, prefix
    // parking, session affinity — every arrival is accounted for
    // exactly once: admitted+spilled+rejected == arrivals at the stats
    // layer, and served+spilled+rejections == arrivals at the result
    // layer.
    use andes::cluster::{Cluster, RoutingPolicy};
    use andes::config::SchedulerConfig;
    use andes::gateway::{AutoscaleConfig, Gateway, GatewayConfig, SpillConfig};
    use andes::workload::SessionWorkload;

    let latency = LatencyModel::for_deployment(&opt_66b(), &a100_4x());
    check_prop("gateway request conservation", 10, |rng| {
        let n = rng.range(10, 45);
        let rate = 0.5 + rng.f64() * 9.5;
        let cv = if rng.chance(0.5) { 1.0 } else { 3.0 };
        let sessions = rng.chance(0.5);
        let park = sessions && rng.chance(0.7);
        let affinity = park && rng.chance(0.5);
        let ecfg = EngineConfig {
            kv_capacity_tokens: rng.range(2500, 9000),
            swap_capacity_tokens: 18_000,
            park_prefixes: park,
            ..EngineConfig::default()
        };
        let mut cluster = Cluster::new(
            rng.range(1, 3),
            ecfg.clone(),
            latency.clone(),
            &SchedulerConfig::Fcfs,
            RoutingPolicy::QoeAware,
        );
        cluster.set_session_affinity(affinity);
        let mut gcfg = GatewayConfig::default();
        gcfg.pacing_enabled = rng.chance(0.5);
        gcfg.surge.baseline_rate = 0.5 + rng.f64() * 3.0;
        gcfg.admission.max_defer_wait = 1.0 + rng.f64() * 9.0;
        if rng.chance(0.5) {
            gcfg.autoscale = AutoscaleConfig {
                enabled: true,
                min_replicas: 1,
                max_replicas: 4,
                replica_capacity: 0.5 + rng.f64() * 2.0,
                target_utilization: 0.8,
                cold_start_secs: rng.f64() * 5.0,
                scale_in_hold_secs: 5.0 + rng.f64() * 20.0,
                kv_high_watermark: 0.9,
                eval_interval_secs: 0.5,
            };
        }
        let arrivals = if cv == 1.0 {
            ArrivalProcess::Poisson { rate }
        } else {
            ArrivalProcess::Gamma { rate, cv }
        };
        let trace = if sessions {
            SessionWorkload {
                num_sessions: n.div_ceil(3),
                arrivals,
                qoe_trace: QoeTrace::TextReading,
                min_turns: 2,
                max_turns: 4,
                think_time_mean: rng.f64() * 6.0,
                seed: rng.next_u64(),
            }
            .generate()
        } else {
            Workload {
                dataset: Dataset::ShareGpt,
                arrivals,
                qoe_trace: QoeTrace::TextReading,
                num_requests: n,
                seed: rng.next_u64(),
            }
            .generate()
        };
        let n = trace.len();
        let mut gw = if rng.chance(0.5) {
            let spill = SpillConfig { enabled: true, replicas: 1, kv_fraction: 0.5 }
                .build_cluster(&ecfg, &latency, &SchedulerConfig::Fcfs);
            Gateway::with_spill(cluster, gcfg, spill)
        } else {
            Gateway::new(cluster, gcfg)
        };
        let res = gw.run_trace(trace).unwrap();
        assert_eq!(res.stats.arrivals, n, "arrival count");
        assert_eq!(
            res.stats.admitted + res.stats.spilled + res.stats.rejected,
            n,
            "stats conservation (admitted {} spilled {} rejected {})",
            res.stats.admitted,
            res.stats.spilled,
            res.stats.rejected
        );
        assert_eq!(
            res.served.len() + res.spilled.len() + res.rejections.len(),
            n,
            "result conservation (served {} spilled {} rejected {})",
            res.served.len(),
            res.spilled.len(),
            res.rejections.len()
        );
        assert_eq!(res.stats.admitted, res.served.len());
        assert_eq!(res.stats.spilled, res.spilled.len());
        assert_eq!(res.stats.rejected, res.rejections.len());
        assert!(res.replica_seconds >= 0.0);
    });
}

#[test]
fn sessions_disabled_reproduce_one_shot_serving_bit_identically() {
    // Flag-off parity: with parking and affinity off, session metadata
    // must be inert — a session-annotated trace through the full
    // gateway+cluster stack produces bit-identical results to the same
    // trace with the annotations stripped.
    use andes::cluster::{Cluster, RoutingPolicy};
    use andes::config::SchedulerConfig;
    use andes::gateway::{Gateway, GatewayConfig};
    use andes::workload::SessionWorkload;

    let latency = LatencyModel::for_deployment(&opt_66b(), &a100_4x());
    let trace = SessionWorkload {
        num_sessions: 30,
        arrivals: ArrivalProcess::Poisson { rate: 1.5 },
        qoe_trace: QoeTrace::TextReading,
        min_turns: 2,
        max_turns: 4,
        think_time_mean: 3.0,
        seed: 99,
    }
    .generate();
    let run = |trace: Vec<andes::workload::RequestSpec>| {
        let ecfg = EngineConfig {
            kv_capacity_tokens: 6000,
            swap_capacity_tokens: 12_000,
            ..EngineConfig::default() // park_prefixes: false
        };
        let cluster = Cluster::new(
            2,
            ecfg,
            latency.clone(),
            &SchedulerConfig::Fcfs,
            RoutingPolicy::QoeAware,
        );
        let mut gcfg = GatewayConfig::default();
        gcfg.surge.baseline_rate = 2.0;
        let mut gw = Gateway::new(cluster, gcfg);
        gw.run_trace(trace).unwrap()
    };
    let with = run(trace.clone());
    let stripped = trace
        .into_iter()
        .map(|mut s| {
            s.session = None;
            s
        })
        .collect();
    let without = run(stripped);
    assert_eq!(with.served.len(), without.served.len());
    assert_eq!(with.rejections.len(), without.rejections.len());
    for (a, b) in with.served.iter().zip(&without.served) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.raw_qoe, b.raw_qoe, "request {} diverged", a.id);
        assert_eq!(a.paced_qoe, b.paced_qoe);
        assert_eq!(a.output_tokens, b.output_tokens);
    }
    for (a, b) in with.rejections.iter().zip(&without.rejections) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.time, b.time);
    }
    assert_eq!(
        with.per_replica.iter().map(|m| m.prefix_hits).sum::<u64>(),
        0,
        "nothing may hit with parking disabled"
    );
}

#[test]
fn raising_a_tier_weight_never_lowers_its_admitted_fraction() {
    // Property (open loop, fixed load): feed two admission controllers
    // the identical replica-state and request sequence, differing only
    // in ONE tier's weight. The hysteresis latch is driven by the
    // unweighted score, so both controllers latch identically; the
    // weighted per-request shed test is monotone in the weight, so the
    // raised tier's admitted fraction can only go up. This is the
    // contract that makes `--tier-weights` safe to tune upward.
    use andes::gateway::{
        AdmissionConfig, AdmissionController, AdmissionDecision, LoadMode, ReplicaState,
        TierWeights,
    };
    use andes::qoe::spec::QoeSpec;

    let tier_specs = [
        QoeSpec::new(0.5, 6.5), // premium
        QoeSpec::new(1.0, 4.8), // standard
        QoeSpec::new(2.0, 2.5), // economy
    ];
    check_prop("tier weight monotonicity", 40, |rng| {
        let base = TierWeights {
            premium: 0.25 + rng.f64() * 3.0,
            standard: 0.25 + rng.f64() * 3.0,
            economy: 0.25 + rng.f64() * 3.0,
        };
        let raised_tier = rng.below(3) as usize;
        let mut raised = base;
        let bump = 0.1 + rng.f64() * 3.0;
        match raised_tier {
            0 => raised.premium += bump,
            1 => raised.standard += bump,
            _ => raised.economy += bump,
        }
        let mk = |w: TierWeights| {
            AdmissionController::new(AdmissionConfig {
                tier_weights: w,
                ..AdmissionConfig::default()
            })
        };
        let (mut lo, mut hi) = (mk(base), mk(raised));
        let (mut lo_admits, mut hi_admits, mut raised_arrivals) = (0usize, 0usize, 0usize);
        for _ in 0..200 {
            // A shared random load trajectory (the "fixed load").
            let states = [ReplicaState {
                active_requests: rng.range(0, 400),
                kv_free_tokens: rng.range(100, 60_000),
                kv_capacity_tokens: 70_000,
                est_request_tds: 0.2 + rng.f64() * 12.0,
            }];
            let mode =
                if rng.chance(0.5) { LoadMode::Surge } else { LoadMode::Normal };
            let prompt = rng.range(50, 1500);
            let depth = rng.range(0, 8);
            let tier = rng.below(3) as usize;
            let spec = tier_specs[tier];
            let a = lo.decide(prompt, &spec, &states, mode, depth);
            let b = hi.decide(prompt, &spec, &states, mode, depth);
            if tier == raised_tier {
                raised_arrivals += 1;
                if a == AdmissionDecision::Admit {
                    lo_admits += 1;
                }
                if b == AdmissionDecision::Admit {
                    hi_admits += 1;
                }
                // Pointwise: an admit under the lower weight must stay
                // an admit under the higher one.
                if a == AdmissionDecision::Admit {
                    assert_eq!(b, AdmissionDecision::Admit, "raised weight demoted an admit");
                }
            }
        }
        if raised_arrivals > 0 {
            assert!(
                hi_admits >= lo_admits,
                "raised tier admitted fraction dropped: {hi_admits}/{raised_arrivals} \
                 < {lo_admits}/{raised_arrivals}"
            );
        }
    });
}

#[test]
fn federation_conserves_requests_across_random_traces() {
    // Property: for random traces, gateway counts, sync intervals, and
    // tier weights, no request is lost or double-admitted across the
    // federated front doors: admitted + rejected == arrivals at the
    // stats layer, served + rejections == arrivals at the result layer.
    use andes::cluster::{Cluster, RoutingPolicy};
    use andes::config::SchedulerConfig;
    use andes::gateway::{FederatedGateway, FederationConfig, GatewayConfig, TierWeights};

    let latency = LatencyModel::for_deployment(&opt_66b(), &a100_4x());
    check_prop("federation request conservation", 10, |rng| {
        let n = rng.range(10, 45);
        let rate = 0.5 + rng.f64() * 9.5;
        let ecfg = EngineConfig {
            kv_capacity_tokens: rng.range(2500, 9000),
            swap_capacity_tokens: 18_000,
            ..EngineConfig::default()
        };
        let cluster = Cluster::new(
            rng.range(1, 3),
            ecfg,
            latency.clone(),
            &SchedulerConfig::Fcfs,
            RoutingPolicy::QoeAware,
        );
        let mut gcfg = GatewayConfig::default();
        gcfg.pacing_enabled = rng.chance(0.5);
        gcfg.surge.baseline_rate = 0.5 + rng.f64() * 3.0;
        gcfg.admission.max_defer_wait = 1.0 + rng.f64() * 9.0;
        if rng.chance(0.5) {
            gcfg.admission.tier_weights = TierWeights {
                premium: 0.5 + rng.f64() * 2.5,
                standard: 1.0,
                economy: 0.25 + rng.f64() * 1.5,
            };
        }
        let fed = FederationConfig {
            gateways: rng.range(1, 4),
            sync_interval_secs: 0.05 + rng.f64() * 5.0,
            staleness_bound_secs: rng.f64() * 20.0,
        };
        let trace = Workload {
            dataset: Dataset::ShareGpt,
            arrivals: ArrivalProcess::Poisson { rate },
            qoe_trace: if rng.chance(0.5) {
                QoeTrace::Tiered
            } else {
                QoeTrace::TextReading
            },
            num_requests: n,
            seed: rng.next_u64(),
        }
        .generate();
        let mut gw = FederatedGateway::new(cluster, gcfg, fed);
        let res = gw.run_trace(trace).unwrap();
        assert_eq!(res.stats.arrivals, n, "arrival count");
        assert_eq!(
            res.stats.admitted + res.stats.rejected,
            n,
            "stats conservation (admitted {} rejected {})",
            res.stats.admitted,
            res.stats.rejected
        );
        assert_eq!(
            res.served.len() + res.rejections.len(),
            n,
            "result conservation (served {} rejected {})",
            res.served.len(),
            res.rejections.len()
        );
        assert_eq!(res.stats.admitted, res.served.len(), "no double-admission");
        assert_eq!(res.stats.rejected, res.rejections.len());
        assert!(res.replica_seconds >= 0.0);
    });
}

// ---------------------------------------------------------------- server

#[test]
fn tcp_server_streams_tokens_end_to_end() {
    use std::io::{BufRead, BufReader, Write};
    // Requires artifacts; skip gracefully otherwise.
    let dir = andes::runtime::engine::ModelRuntime::default_dir();
    if !dir.join("meta.json").exists() {
        eprintln!("skipping server test: artifacts not built");
        return;
    }
    let (ready_tx, ready_rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let cfg = andes::server::ServerConfig {
            addr: "127.0.0.1:0".into(),
            ..andes::server::ServerConfig::default()
        };
        let _ = andes::server::serve(cfg, Some(ready_tx));
    });
    let addr = ready_rx.recv_timeout(std::time::Duration::from_secs(120)).unwrap();
    let mut stream = std::net::TcpStream::connect(&addr).unwrap();
    writeln!(stream, r#"{{"prompt":"hello scheduler","max_tokens":8,"ttft":1.0,"tds":4.8}}"#)
        .unwrap();
    let reader = BufReader::new(stream.try_clone().unwrap());
    let mut tokens = 0;
    let mut done = false;
    for line in reader.lines() {
        let line = line.unwrap();
        let ev = andes::util::json::Json::parse(&line).unwrap();
        match ev.get("event").as_str() {
            Some("token") => tokens += 1,
            Some("done") => {
                done = true;
                assert!(ev.get("qoe").as_f64().unwrap() >= 0.0);
                break;
            }
            other => panic!("unexpected event {other:?} in {line}"),
        }
    }
    assert!(done, "no done event");
    assert!(tokens >= 1 && tokens <= 8, "streamed {tokens} tokens");
}

// ---------------------------------------------------------- rng streams

#[test]
fn rng_statistical_sanity() {
    let mut rng = Rng::new(0xDEAD);
    let n = 20_000;
    let mean: f64 = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
    assert!((mean - 0.5).abs() < 0.01, "uniform mean {mean}");
}

// ------------------------------------------------------- fault injection

/// A backend that fails after a configurable number of decode calls —
/// verifies the engine surfaces backend errors instead of corrupting
/// state or spinning.
struct FaultyBackend {
    inner: SimBackend,
    decodes_until_failure: usize,
}

impl andes::backend::ExecutionBackend for FaultyBackend {
    fn register(&mut self, req: andes::backend::BackendRequest) -> anyhow::Result<()> {
        self.inner.register(req)
    }
    fn prefill(
        &mut self,
        jobs: &[andes::backend::PrefillJob],
    ) -> anyhow::Result<andes::backend::StepOutcome> {
        self.inner.prefill(jobs)
    }
    fn decode(
        &mut self,
        batch: &[usize],
        total_ctx: usize,
    ) -> anyhow::Result<andes::backend::StepOutcome> {
        if self.decodes_until_failure == 0 {
            anyhow::bail!("injected device failure");
        }
        self.decodes_until_failure -= 1;
        self.inner.decode(batch, total_ctx)
    }
    fn swap_cost(&mut self, tokens: usize) -> f64 {
        self.inner.swap_cost(tokens)
    }
    fn drop_kv(&mut self, id: usize) {
        self.inner.drop_kv(id)
    }
    fn release(&mut self, id: usize) {
        self.inner.release(id)
    }
}

#[test]
fn engine_surfaces_backend_failures() {
    let latency = LatencyModel::for_deployment(&opt_66b(), &a100_4x());
    let backend = FaultyBackend {
        inner: SimBackend::new(latency.clone()),
        decodes_until_failure: 5,
    };
    let cfg = EngineConfig::default();
    let mut e = Engine::new(
        cfg,
        backend,
        VirtualClock::default(),
        Box::new(FcfsScheduler::new()) as Box<dyn Scheduler>,
        latency,
    );
    let wl = Workload {
        dataset: Dataset::ShareGpt,
        arrivals: ArrivalProcess::Poisson { rate: 2.0 },
        qoe_trace: QoeTrace::TextReading,
        num_requests: 10,
        seed: 1,
    };
    e.load_trace(wl.generate());
    let mut failed = false;
    for _ in 0..10_000 {
        match e.tick() {
            Ok(true) => continue,
            Ok(false) => break,
            Err(e) => {
                failed = true;
                assert!(e.to_string().contains("injected device failure"), "{e:#}");
                break;
            }
        }
    }
    assert!(failed, "the injected failure must propagate out of tick()");
}

#[test]
fn config_roundtrip_drives_engine() {
    // A config-file deployment must produce a working engine.
    let d = andes::config::AndesDeployment::from_json_str(
        r#"{"model":"opt-66b","gpu":"a100-4x",
            "scheduler":{"kind":"andes","preemption_cap":0.4},
            "engine":{"kv_capacity_tokens":4000,"swap_capacity_tokens":8000}}"#,
    )
    .unwrap();
    let latency = LatencyModel::for_deployment(&d.llm, &d.gpu);
    let mut e = Engine::new(
        d.engine.clone(),
        SimBackend::new(latency.clone()),
        VirtualClock::default(),
        d.scheduler.build(),
        latency,
    );
    let wl = Workload {
        dataset: Dataset::ShareGpt,
        arrivals: ArrivalProcess::Poisson { rate: 3.0 },
        qoe_trace: QoeTrace::TextReading,
        num_requests: 40,
        seed: 2,
    };
    e.load_trace(wl.generate());
    let m = e.run_to_completion().unwrap();
    assert_eq!(m.requests.len(), 40);
    // The configured cap bounds *scheduler-initiated* preemptions; the
    // engine's OOM safety net is exempt (it must always be able to run).
    let scheduler_preempts = m.total_preemptions - m.oom_preemptions;
    assert!(
        scheduler_preempts as f64 / m.requests.len() as f64 <= 0.4 + 0.05,
        "scheduler preempts {} over {} requests",
        scheduler_preempts,
        m.requests.len()
    );
}
