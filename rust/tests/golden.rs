//! Golden-trace regression suite: seeded, reduced-size `ext-gateway`
//! and `ext-sessions` scenarios pinned against JSON snapshots committed
//! under `rust/tests/golden/`, with per-metric relative tolerances
//! (counts exact, floats to 1e-6) — plus a byte-for-byte pin of the
//! Prometheus text exposition the instrumented gateway cell emits.
//!
//! Regeneration after an intentional behavior change:
//!
//! ```text
//! GOLDEN_BLESS=1 cargo test --test golden
//! git diff rust/tests/golden/   # review, then commit
//! ```
//!
//! A missing snapshot is blessed on first run (see
//! `andes::util::golden`), which is how a new scenario bootstraps.

use std::path::PathBuf;

use andes::cluster::{Cluster, RoutingPolicy};
use andes::config::SchedulerConfig;
use andes::coordinator::engine::EngineConfig;
use andes::coordinator::sched::andes::AndesConfig;
use andes::experiments::runner::estimate_capacity;
use andes::gateway::{Gateway, GatewayConfig};
use andes::model::gpu::a100_4x;
use andes::model::latency::LatencyModel;
use andes::model::llm::opt_66b;
use andes::telemetry::{validate_exposition, Telemetry, TelemetryConfig};
use andes::util::golden::{check_or_bless, check_or_bless_text, metric};
use andes::util::stats::{mean, percentile};
use andes::workload::{ArrivalProcess, Dataset, QoeTrace, SessionWorkload, Workload};

fn golden_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/golden")
        .join(name)
}

/// Count exactly.
const EXACT: f64 = 0.0;
/// Absorb platform-libm noise in float metrics while catching any real
/// behavior change.
const FLOAT: f64 = 1e-6;

#[test]
fn golden_ext_gateway_cell() {
    // A reduced `ext-gateway` stress cell: the full gateway (admission +
    // pacing) fronting a 2-replica Andes cluster under gamma-burst
    // arrivals at 2× estimated aggregate capacity, seed 42.
    let llm = opt_66b();
    let gpu = a100_4x();
    let latency = LatencyModel::for_deployment(&llm, &gpu);
    let replicas = 2usize;
    let capacity = estimate_capacity(&llm, &gpu, Dataset::ShareGpt) * replicas as f64;
    let engine_cfg = EngineConfig {
        kv_capacity_tokens: llm.kv_capacity_tokens(&gpu),
        swap_capacity_tokens: llm.swap_capacity_tokens(&gpu),
        ..EngineConfig::default()
    };
    let sched = SchedulerConfig::Andes(AndesConfig::default());
    let cluster = Cluster::new(
        replicas,
        engine_cfg,
        latency,
        &sched,
        RoutingPolicy::QoeAware,
    );
    let mut gcfg = GatewayConfig::default();
    gcfg.surge.baseline_rate = capacity;
    let trace = Workload {
        dataset: Dataset::ShareGpt,
        arrivals: ArrivalProcess::Gamma { rate: capacity * 2.0, cv: 3.0 },
        qoe_trace: QoeTrace::TextReading,
        num_requests: 150,
        seed: 42,
    }
    .generate();
    let mut gw = Gateway::new(cluster, gcfg);
    let res = gw.run_trace(trace).unwrap();

    let served: Vec<f64> = res.served.iter().map(|s| s.paced_qoe).collect();
    let (early_raw, early_shaped) = res.early_token_fractions();
    check_or_bless(
        &golden_path("ext_gateway.json"),
        &[
            metric("served", res.served.len() as f64, EXACT),
            metric("rejected", res.rejections.len() as f64, EXACT),
            metric("deferred", res.stats.deferred as f64, EXACT),
            metric("surge_transitions", res.stats.surge_transitions as f64, EXACT),
            metric("mean_served_qoe", res.mean_served_qoe(), FLOAT),
            metric("p10_served_qoe", percentile(&served, 10.0), FLOAT),
            metric("mean_qoe_incl_rejects", res.mean_qoe_incl_rejects(), FLOAT),
            metric("early_frac_unshaped", early_raw, FLOAT),
            metric("early_frac_delivered", early_shaped, FLOAT),
            metric("replica_seconds", res.replica_seconds, FLOAT),
        ],
    )
    .unwrap();
}

#[test]
fn golden_ext_gateway_prometheus_exposition() {
    // The same seeded cell as `golden_ext_gateway_cell`, but pinning the
    // *entire* Prometheus text exposition byte-for-byte: family order
    // (declaration order), label order (alphabetical, `le` last),
    // bucket layout, and every counter/gauge value. Stable label
    // ordering is part of the contract scrapers rely on.
    let llm = opt_66b();
    let gpu = a100_4x();
    let latency = LatencyModel::for_deployment(&llm, &gpu);
    let replicas = 2usize;
    let capacity = estimate_capacity(&llm, &gpu, Dataset::ShareGpt) * replicas as f64;
    let engine_cfg = EngineConfig {
        kv_capacity_tokens: llm.kv_capacity_tokens(&gpu),
        swap_capacity_tokens: llm.swap_capacity_tokens(&gpu),
        ..EngineConfig::default()
    };
    let sched = SchedulerConfig::Andes(AndesConfig::default());
    let mut cluster = Cluster::new(
        replicas,
        engine_cfg,
        latency,
        &sched,
        RoutingPolicy::QoeAware,
    );
    let mut gcfg = GatewayConfig::default();
    gcfg.surge.baseline_rate = capacity;
    let telemetry =
        Telemetry::new(&TelemetryConfig { enabled: true, ..TelemetryConfig::default() });
    telemetry.set_time_domain("sim");
    cluster.set_telemetry(telemetry.clone());
    let trace = Workload {
        dataset: Dataset::ShareGpt,
        arrivals: ArrivalProcess::Gamma { rate: capacity * 2.0, cv: 3.0 },
        qoe_trace: QoeTrace::TextReading,
        num_requests: 150,
        seed: 42,
    }
    .generate();
    let mut gw = Gateway::new(cluster, gcfg);
    gw.set_telemetry(telemetry.clone());
    gw.run_trace(trace).unwrap();

    let text = telemetry.render_prometheus();
    // Hard guarantees first: the exposition parses and carries the core
    // families — so a drift failure below is about *values*, not shape.
    let samples = validate_exposition(&text).unwrap();
    assert!(samples > 0, "seeded run produced an empty exposition");
    for family in [
        "andes_requests_total",
        "andes_ttft_seconds",
        "andes_tpot_seconds",
        "andes_qoe",
        "andes_tokens_total",
        "andes_batch_size",
        "andes_kv_used_fraction",
        "andes_defer_queue_depth",
    ] {
        assert!(text.contains(family), "exposition lost family {family}:\n{text}");
    }
    check_or_bless_text(&golden_path("ext_gateway_prometheus.txt"), &text).unwrap();
}

#[test]
fn golden_ext_slack_cell() {
    // A reduced `ext-slack` cell: the slack-aware arm (estimator fed to
    // the Andes scheduler, DESIGN.md §15) under gamma-burst arrivals at
    // 2× estimated aggregate capacity, pacing + fiber delivery on, seed
    // 42. Pins the estimator's effect on scheduling end to end; the
    // slack-off arm is already pinned by `golden_ext_gateway_cell`
    // (EngineConfig::default() keeps `slack: None`).
    let llm = opt_66b();
    let gpu = a100_4x();
    let latency = LatencyModel::for_deployment(&llm, &gpu);
    let replicas = 2usize;
    let capacity = estimate_capacity(&llm, &gpu, Dataset::ShareGpt) * replicas as f64;
    let mut gcfg = GatewayConfig::default();
    gcfg.network.enabled = true; // default fiber mix
    gcfg.surge.baseline_rate = capacity;
    let engine_cfg = EngineConfig {
        kv_capacity_tokens: llm.kv_capacity_tokens(&gpu),
        swap_capacity_tokens: llm.swap_capacity_tokens(&gpu),
        slack: Some(gcfg.slack_config()),
        ..EngineConfig::default()
    };
    let sched = SchedulerConfig::Andes(AndesConfig::default());
    let cluster = Cluster::new(
        replicas,
        engine_cfg,
        latency,
        &sched,
        RoutingPolicy::QoeAware,
    );
    let trace = Workload {
        dataset: Dataset::ShareGpt,
        arrivals: ArrivalProcess::Gamma { rate: capacity * 2.0, cv: 3.0 },
        qoe_trace: QoeTrace::TextReading,
        num_requests: 150,
        seed: 42,
    }
    .generate();
    let mut gw = Gateway::new(cluster, gcfg);
    let res = gw.run_trace(trace).unwrap();

    let client: Vec<f64> = res.served.iter().map(|s| s.client_qoe).collect();
    let preemptions: u64 = res.per_replica.iter().map(|m| m.total_preemptions).sum();
    let deep: u64 =
        res.per_replica.iter().map(|m| m.deep_buffer_preemptions).sum();
    check_or_bless(
        &golden_path("ext_slack.json"),
        &[
            metric("served", res.served.len() as f64, EXACT),
            metric("rejected", res.rejections.len() as f64, EXACT),
            metric("preemptions", preemptions as f64, EXACT),
            metric("deep_buffer_preemptions", deep as f64, EXACT),
            metric("stalls", res.total_stalls() as f64, EXACT),
            metric("stall_time_total", res.total_stall_time(), FLOAT),
            metric("mean_client_qoe", mean(&client), FLOAT),
            metric("p10_client_qoe", percentile(&client, 10.0), FLOAT),
            metric("mean_served_qoe", res.mean_served_qoe(), FLOAT),
        ],
    )
    .unwrap();
}

#[test]
fn golden_ext_sessions_cell() {
    // A reduced `ext-sessions` park+affinity cell: 40 multi-turn
    // sessions through the gateway over a 2-replica parking cluster
    // with affinity routing, seed 42, pacing off (as in the experiment).
    let llm = opt_66b();
    let gpu = a100_4x();
    let latency = LatencyModel::for_deployment(&llm, &gpu);
    let replicas = 2usize;
    let capacity = estimate_capacity(&llm, &gpu, Dataset::ShareGpt) * replicas as f64;
    let engine_cfg = EngineConfig {
        kv_capacity_tokens: llm.kv_capacity_tokens(&gpu),
        swap_capacity_tokens: llm.swap_capacity_tokens(&gpu),
        park_prefixes: true,
        ..EngineConfig::default()
    };
    let sched = SchedulerConfig::Andes(AndesConfig::default());
    let mut cluster = Cluster::new(
        replicas,
        engine_cfg,
        latency,
        &sched,
        RoutingPolicy::QoeAware,
    );
    cluster.set_session_affinity(true);
    let mut gcfg = GatewayConfig::default();
    gcfg.pacing_enabled = false;
    gcfg.surge.baseline_rate = capacity;
    let trace = SessionWorkload {
        num_sessions: 40,
        arrivals: ArrivalProcess::Poisson { rate: capacity * 1.3 / 3.0 },
        qoe_trace: QoeTrace::TextReading,
        min_turns: 2,
        max_turns: 4,
        think_time_mean: 4.0,
        seed: 42,
    }
    .generate();
    let requests = trace.len();
    let mut gw = Gateway::new(cluster, gcfg);
    let res = gw.run_trace(trace).unwrap();

    let mut returning_ttfts: Vec<f64> = Vec::new();
    let mut returning_served = 0usize;
    let mut hits = 0u64;
    let mut qoes: Vec<f64> = Vec::new();
    for m in &res.per_replica {
        for r in &m.requests {
            qoes.push(r.final_qoe);
            if r.session.is_some_and(|s| s.is_returning()) {
                returning_served += 1;
                if r.ttft.is_finite() {
                    returning_ttfts.push(r.ttft);
                }
                if r.prefix_hit_tokens > 0 {
                    hits += 1;
                }
            }
        }
    }
    let parked: u64 = res.per_replica.iter().map(|m| m.prefixes_parked).sum();
    let evictions: u64 = res.per_replica.iter().map(|m| m.park_evictions).sum();
    let hit_rate = if returning_served == 0 {
        0.0
    } else {
        hits as f64 / returning_served as f64
    };
    // Guard the mean like hit_rate: a config tweak that leaves no served
    // returning turns must not pin NaN (check_or_bless rejects it).
    let ttft_returning =
        if returning_ttfts.is_empty() { 0.0 } else { mean(&returning_ttfts) };
    check_or_bless(
        &golden_path("ext_sessions.json"),
        &[
            metric("requests", requests as f64, EXACT),
            metric("served", res.served.len() as f64, EXACT),
            metric("rejected", res.rejections.len() as f64, EXACT),
            metric("prefix_hits", hits as f64, EXACT),
            metric("prefixes_parked", parked as f64, EXACT),
            metric("park_evictions", evictions as f64, EXACT),
            metric("prefix_hit_rate", hit_rate, FLOAT),
            metric("mean_qoe_served", mean(&qoes), FLOAT),
            metric("mean_ttft_returning", ttft_returning, FLOAT),
            metric("mean_qoe_incl_rejects", res.mean_qoe_incl_rejects(), FLOAT),
        ],
    )
    .unwrap();
}
