//! Telemetry integration suite (DESIGN.md §12):
//!
//! - **Parity**: attaching a telemetry handle — disabled *or* enabled —
//!   to the seeded gateway cell changes no per-request result bit.
//! - **Tracer ring**: property test that bounded-memory eviction never
//!   drops an open span, across randomized open/event/close schedules.
//! - **Trace export**: the gateway cell's JSONL validates against the
//!   event schema and every served request's span joins arrival→finish
//!   on one key (the spec-id span key, not the engine-local record id).
//! - **Live surface**: `serve --backend sim` answers a streaming request
//!   plus `/metrics` (valid Prometheus exposition with the core
//!   families) and `/health` (JSON readiness) on the same port.

use std::collections::{BTreeMap, HashSet};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::mpsc::channel;
use std::time::Duration;

use andes::cluster::{Cluster, RoutingPolicy};
use andes::config::SchedulerConfig;
use andes::coordinator::engine::EngineConfig;
use andes::coordinator::sched::andes::AndesConfig;
use andes::experiments::runner::estimate_capacity;
use andes::gateway::{Gateway, GatewayConfig, GatewayRunResult};
use andes::model::gpu::a100_4x;
use andes::model::latency::LatencyModel;
use andes::model::llm::opt_66b;
use andes::server::{serve, ServeBackend, ServerConfig};
use andes::telemetry::{
    validate_exposition, validate_jsonl, Telemetry, TelemetryConfig, Tracer,
};
use andes::util::json::Json;
use andes::util::testing::check_prop;
use andes::workload::{ArrivalProcess, Dataset, QoeTrace, Workload};

/// Per-request fingerprint: bit-exact floats via `to_bits`.
type Fingerprint = Vec<(usize, u64, u64, usize)>;

/// Run the seeded gateway stress cell, optionally instrumented, and
/// return (result, bit-exact served fingerprint).
fn run_cell(telemetry: Option<Telemetry>) -> (GatewayRunResult, Fingerprint) {
    let llm = opt_66b();
    let gpu = a100_4x();
    let latency = LatencyModel::for_deployment(&llm, &gpu);
    let replicas = 2usize;
    let capacity = estimate_capacity(&llm, &gpu, Dataset::ShareGpt) * replicas as f64;
    let engine_cfg = EngineConfig {
        kv_capacity_tokens: llm.kv_capacity_tokens(&gpu),
        swap_capacity_tokens: llm.swap_capacity_tokens(&gpu),
        ..EngineConfig::default()
    };
    let sched = SchedulerConfig::Andes(AndesConfig::default());
    let mut cluster = Cluster::new(
        replicas,
        engine_cfg,
        latency,
        &sched,
        RoutingPolicy::QoeAware,
    );
    let mut gcfg = GatewayConfig::default();
    gcfg.surge.baseline_rate = capacity;
    if let Some(tel) = &telemetry {
        cluster.set_telemetry(tel.clone());
    }
    let trace = Workload {
        dataset: Dataset::ShareGpt,
        arrivals: ArrivalProcess::Gamma { rate: capacity * 2.0, cv: 3.0 },
        qoe_trace: QoeTrace::TextReading,
        num_requests: 80,
        seed: 42,
    }
    .generate();
    let mut gw = Gateway::new(cluster, gcfg);
    if let Some(tel) = telemetry {
        gw.set_telemetry(tel);
    }
    let res = gw.run_trace(trace).unwrap();
    let fp: Fingerprint = res
        .served
        .iter()
        .map(|s| (s.id, s.paced_qoe.to_bits(), s.client_qoe.to_bits(), s.output_tokens))
        .collect();
    (res, fp)
}

fn enabled_telemetry() -> Telemetry {
    let tel =
        Telemetry::new(&TelemetryConfig { enabled: true, ..TelemetryConfig::default() });
    tel.set_time_domain("sim");
    tel
}

#[test]
fn telemetry_handles_do_not_perturb_results() {
    // Baseline: no handle attached at all (pre-telemetry construction).
    let (base_res, base) = run_cell(None);
    // An explicitly disabled handle must be bit-identical — this is the
    // `telemetry: off` parity contract.
    let (off_res, off) = run_cell(Some(Telemetry::disabled()));
    assert_eq!(base, off, "disabled telemetry perturbed per-request results");
    assert_eq!(base_res.rejections.len(), off_res.rejections.len());
    // Stronger: a *recording* handle must also observe without
    // perturbing (instrumentation only reads engine state).
    let tel = enabled_telemetry();
    let (on_res, on) = run_cell(Some(tel.clone()));
    assert_eq!(base, on, "enabled telemetry perturbed per-request results");
    assert_eq!(base_res.rejections.len(), on_res.rejections.len());
    // And it actually recorded the run.
    assert!(
        tel.value("andes_requests_total", &[("outcome", "admitted"), ("tier", "standard")])
            > 0.0
    );
    assert!(!tel.render_prometheus().is_empty());
}

#[test]
fn tracer_ring_eviction_never_drops_open_spans() {
    check_prop("open spans survive ring eviction", 150, |rng| {
        let capacity = (rng.below(48) + 1) as usize;
        let mut t = Tracer::new(capacity);
        // BTreeMap: the invariant loop iterates this map, and its panic
        // messages should name spans in a stable order across runs.
        let mut open_counts: BTreeMap<u64, usize> = BTreeMap::new();
        let mut closed: HashSet<u64> = HashSet::new();
        let mut live: Vec<u64> = Vec::new();
        let mut next_id = 0u64;
        let ops = rng.below(250) + 20;
        for i in 0..ops {
            let action = rng.below(10);
            if live.is_empty() || action < 3 {
                let id = next_id;
                next_id += 1;
                t.record(id, "arrival", i as f64, &[]);
                live.push(id);
                open_counts.insert(id, 1);
            } else if action < 8 {
                let id = live[rng.below(live.len() as u64) as usize];
                t.record(id, "pacer_release", i as f64, &[("tokens", 1u64.into())]);
                *open_counts.get_mut(&id).unwrap() += 1;
            } else {
                let id = live.swap_remove(rng.below(live.len() as u64) as usize);
                t.record(id, "finish", i as f64, &[]);
                open_counts.remove(&id);
                closed.insert(id);
            }
            // Invariant 1: every open span keeps every one of its events.
            for (id, n) in &open_counts {
                let evs = t
                    .events_for(*id)
                    .unwrap_or_else(|| panic!("open span {id} was evicted"));
                assert_eq!(evs.len(), *n, "open span {id} lost events");
            }
            // Invariant 2: the buffer respects capacity except when only
            // open spans remain (they are never evicted).
            let open_events: usize = open_counts.values().sum();
            assert!(
                t.buffered_events() <= capacity || t.buffered_events() == open_events,
                "buffer over capacity ({} > {capacity}) with closed spans retained",
                t.buffered_events()
            );
        }
        assert_eq!(t.open_spans(), open_counts.len());
        // Anything evicted was a span we closed.
        assert!(t.dropped_spans() <= closed.len() as u64);
        // The export of whatever survived is schema-valid.
        validate_jsonl(&t.export_jsonl()).unwrap();
    });
}

#[test]
fn gateway_trace_export_validates_and_spans_join() {
    let tel = enabled_telemetry();
    let (res, _) = run_cell(Some(tel.clone()));
    let jsonl = tel.trace_jsonl();
    let n = validate_jsonl(&jsonl).unwrap();
    assert!(n > 0, "instrumented run exported no events");
    // Group events by span key: every served request's span must join
    // arrival → finish on ONE key. (Regression guard: the gateway keys
    // spans by spec id; using the engine-local record id would split
    // every span in two once routing reorders submissions.)
    // BTreeMap: the span grouping below is iterated for the join check.
    let mut by_req: BTreeMap<u64, Vec<String>> = BTreeMap::new();
    for line in jsonl.lines() {
        let j = Json::parse(line).unwrap();
        by_req
            .entry(j.get("request").as_u64().unwrap())
            .or_default()
            .push(j.get("event").as_str().unwrap().to_string());
    }
    let joined = by_req
        .values()
        .filter(|evs| {
            evs.iter().any(|e| e == "arrival") && evs.iter().any(|e| e == "finish")
        })
        .count();
    assert!(
        joined >= res.served.len(),
        "only {joined} of {} served spans join arrival→finish",
        res.served.len()
    );
    // No eviction at default capacity on this small run.
    assert_eq!(tel.trace_stats().2, 0, "default capacity evicted spans on 80 requests");
}

fn http_get(addr: &str, path: &str) -> (String, String) {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    write!(s, "GET {path} HTTP/1.1\r\nHost: test\r\nAccept: */*\r\n\r\n").unwrap();
    let mut buf = String::new();
    s.read_to_string(&mut buf).expect("read http response");
    let (head, body) = buf.split_once("\r\n\r\n").expect("malformed http response");
    (head.lines().next().unwrap_or("").to_string(), body.to_string())
}

#[test]
fn live_serve_sim_backend_metrics_and_health() {
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        backend: ServeBackend::Sim,
        max_output_tokens: 16,
        ..ServerConfig::default()
    };
    let (ready_tx, ready_rx) = channel();
    std::thread::spawn(move || {
        let _ = serve(cfg, Some(ready_tx));
    });
    let addr = ready_rx.recv_timeout(Duration::from_secs(10)).expect("server ready");

    // One streaming request end-to-end (placeholder glyph tokens; a
    // fast digestion speed keeps the pacer from stretching the test).
    let mut s = TcpStream::connect(&addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    writeln!(s, r#"{{"prompt": "hello telemetry", "max_tokens": 4, "ttft": 1.0, "tds": 40.0}}"#)
        .unwrap();
    let mut reader = BufReader::new(s.try_clone().unwrap());
    let mut line = String::new();
    let mut done = false;
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) | Err(_) => break,
            Ok(_) => {
                if line.contains(r#""event":"done""#) {
                    done = true;
                    break;
                }
                if line.contains(r#""event":"rejected""#) {
                    break;
                }
            }
        }
    }
    assert!(done, "streaming request did not complete: {line}");
    drop(reader);

    // /metrics on the same port: a valid Prometheus exposition carrying
    // the core request/latency/QoE families with tier labels.
    let (status, body) = http_get(&addr, "/metrics");
    assert!(status.contains("200"), "{status}");
    let samples = validate_exposition(&body).expect("exposition must parse");
    assert!(samples > 0, "empty exposition");
    for family in [
        "andes_requests_total",
        "andes_ttft_seconds",
        "andes_qoe",
        "andes_tokens_total",
        "andes_time_domain_wall",
    ] {
        assert!(body.contains(family), "missing family {family} in:\n{body}");
    }
    assert!(body.contains("tier="), "per-tier labels missing:\n{body}");

    // /health: JSON readiness document; poll briefly for the served
    // count (the engine thread updates it at the end of its iteration).
    let mut healthy = false;
    for _ in 0..100 {
        let (status, body) = http_get(&addr, "/health");
        assert!(status.contains("200"), "{status}");
        let j = Json::parse(body.trim()).expect("health must be valid JSON");
        if j.get("status").as_str() == Some("ok")
            && j.get("served_requests").as_u64().unwrap_or(0) >= 1
        {
            assert_eq!(j.get("backend").as_str(), Some("sim"));
            assert_eq!(j.get("replicas").as_u64(), Some(1));
            healthy = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    assert!(healthy, "/health never reported ok with a served request");

    // Unknown paths 404 instead of hanging the connection.
    let (status, _) = http_get(&addr, "/nope");
    assert!(status.contains("404"), "{status}");
}
