//! Step-vs-calendar parity suite (DESIGN.md §14).
//!
//! The event calendar replaced every hand-rolled next-event scan in the
//! simulation stack — engine arrival peeks, gateway defer-deadline
//! sweeps, autoscaler ticks, federation sync timers, delivery ack
//! drains. Each port kept the legacy path behind a `legacy_stepping`
//! toggle; this suite drives the golden experiment cells through both
//! paths and demands *bit-identical* results: per-request QoE, event
//! traces, rejection streams, and summary metrics.
//!
//! Alongside parity: property tests for the calendar's ordering and
//! cancellation invariants, shard-determinism for the grid runner, and
//! a regression test for the defer-sweep clock drift the port fixed.

use andes::backend::sim::SimBackend;
use andes::backend::VirtualClock;
use andes::cluster::{Cluster, RoutingPolicy};
use andes::config::SchedulerConfig;
use andes::coordinator::calendar::{EventCalendar, EventKind, WakeupToken};
use andes::coordinator::engine::{Engine, EngineConfig};
use andes::coordinator::metrics::Metrics;
use andes::coordinator::sched::andes::AndesConfig;
use andes::delivery::NetworkProfile;
use andes::experiments::runner::{estimate_capacity, SchedKind};
use andes::experiments::shard::run_grid;
use andes::gateway::{
    AutoscaleConfig, FederatedGateway, FederationConfig, Gateway, GatewayConfig,
    GatewayRunResult, RejectReason, Rejection, ServedRequest,
};
use andes::model::gpu::a100_4x;
use andes::model::latency::LatencyModel;
use andes::model::llm::opt_66b;
use andes::util::testing::check_prop;
use andes::workload::{ArrivalProcess, Dataset, QoeTrace, SessionWorkload, Workload};

// ---------------------------------------------------------- fingerprints

/// Bit-exact rendering of one served request (floats as hex bit
/// patterns, so two fingerprints agree iff every f64 agrees bitwise).
fn fp_served(s: &ServedRequest) -> String {
    format!(
        "{}:{:x}:{:x}:{:x}:{}:{:x}:{}:{}:{}:{}:{}:{:x}",
        s.id,
        s.raw_qoe.to_bits(),
        s.paced_qoe.to_bits(),
        s.client_qoe.to_bits(),
        s.stall_count,
        s.stall_time.to_bits(),
        s.retransmits,
        s.disconnects,
        s.raw_early_tokens,
        s.paced_early_tokens,
        s.output_tokens,
        s.expected_tds.to_bits(),
    )
}

fn fp_rejection(r: &Rejection) -> String {
    format!("rej {}:{:x}:{:?}", r.id, r.time.to_bits(), r.reason)
}

/// Per-request engine records including the full token-delivery event
/// trace (every token timestamp, bitwise).
fn fp_metrics(m: &Metrics) -> String {
    let mut out = String::new();
    for r in &m.requests {
        out.push_str(&format!(
            "req {}:{}:{:x}:{}:{}:{:x}:{:x}:{:x}:{}:{:x}:{:?}:{} tt",
            r.id,
            r.spec_id,
            r.arrival.to_bits(),
            r.prompt_tokens,
            r.output_tokens,
            r.ttft.to_bits(),
            r.final_qoe.to_bits(),
            r.normalized_latency.to_bits(),
            r.preemptions,
            r.finished_at.to_bits(),
            r.session,
            r.prefix_hit_tokens,
        ));
        for t in &r.token_times {
            out.push_str(&format!(" {:x}", t.to_bits()));
        }
        out.push('\n');
    }
    out.push_str(&format!(
        "sum {}:{}:{}:{}:{}:{}:{}:{}:{}:{:x}:{:x}\n",
        m.total_tokens,
        m.total_preemptions,
        m.swap_preemptions,
        m.recompute_preemptions,
        m.oom_preemptions,
        m.prefixes_parked,
        m.prefix_hits,
        m.prefix_hit_tokens,
        m.park_evictions,
        m.started_at.to_bits(),
        m.ended_at.to_bits(),
    ));
    out
}

/// Full-run fingerprint: served stream, rejection stream, summary
/// counters, replica-seconds, and every per-replica request record.
fn fp_gateway(res: &GatewayRunResult) -> String {
    let mut out = String::new();
    for s in &res.served {
        out.push_str(&fp_served(s));
        out.push('\n');
    }
    for s in &res.spilled {
        out.push_str("spill ");
        out.push_str(&fp_served(s));
        out.push('\n');
    }
    for r in &res.rejections {
        out.push_str(&fp_rejection(r));
        out.push('\n');
    }
    out.push_str(&format!(
        "stats {:?}\nrs {:x} {:x}\n",
        res.stats,
        res.replica_seconds.to_bits(),
        res.spill_replica_seconds.to_bits(),
    ));
    for m in &res.per_replica {
        out.push_str(&fp_metrics(m));
    }
    out
}

// ------------------------------------------------------- parity: engine

fn engine_trace_fp(trace: Vec<andes::workload::RequestSpec>, legacy: bool) -> String {
    let llm = opt_66b();
    let gpu = a100_4x();
    let latency = LatencyModel::for_deployment(&llm, &gpu);
    let cfg = EngineConfig {
        kv_capacity_tokens: llm.kv_capacity_tokens(&gpu),
        swap_capacity_tokens: llm.swap_capacity_tokens(&gpu),
        legacy_stepping: legacy,
        ..EngineConfig::default()
    };
    let mut e = Engine::new(
        cfg,
        SimBackend::new(latency.clone()),
        VirtualClock::default(),
        SchedKind::andes_default().build(),
        latency,
    );
    e.load_trace(trace);
    fp_metrics(e.run_to_completion().unwrap())
}

#[test]
fn engine_arrival_stream_parity() {
    // The engine's pending-arrival peeks vs the calendar's Arrival /
    // SessionReturn wakeups: identical per-request records and token
    // traces on both a one-shot and a session trace.
    let one_shot = Workload {
        dataset: Dataset::ShareGpt,
        arrivals: ArrivalProcess::Gamma { rate: 3.0, cv: 3.0 },
        qoe_trace: QoeTrace::TextReading,
        num_requests: 80,
        seed: 42,
    }
    .generate();
    let sessions = SessionWorkload {
        num_sessions: 20,
        arrivals: ArrivalProcess::Poisson { rate: 1.5 },
        qoe_trace: QoeTrace::TextReading,
        min_turns: 2,
        max_turns: 4,
        think_time_mean: 4.0,
        seed: 42,
    }
    .generate();
    for trace in [one_shot, sessions] {
        let stepped = engine_trace_fp(trace.clone(), true);
        let calendar = engine_trace_fp(trace, false);
        assert_eq!(stepped, calendar, "engine step-vs-calendar parity broke");
    }
}

// ------------------------------------------------ parity: golden cells

fn golden_cluster(latency: &LatencyModel, park: bool, legacy: bool) -> Cluster {
    let llm = opt_66b();
    let gpu = a100_4x();
    let engine_cfg = EngineConfig {
        kv_capacity_tokens: llm.kv_capacity_tokens(&gpu),
        swap_capacity_tokens: llm.swap_capacity_tokens(&gpu),
        park_prefixes: park,
        legacy_stepping: legacy,
        ..EngineConfig::default()
    };
    let sched = SchedulerConfig::Andes(AndesConfig::default());
    Cluster::new(2, engine_cfg, latency.clone(), &sched, RoutingPolicy::QoeAware)
}

#[test]
fn gateway_stress_cell_parity() {
    // The `ext-gateway` golden cell: gamma-burst (cv 3) at 2× capacity
    // through the full gateway. Defer deadlines, autoscale queries, and
    // pacing all exercise the calendar.
    let llm = opt_66b();
    let gpu = a100_4x();
    let latency = LatencyModel::for_deployment(&llm, &gpu);
    let capacity = estimate_capacity(&llm, &gpu, Dataset::ShareGpt) * 2.0;
    let trace = Workload {
        dataset: Dataset::ShareGpt,
        arrivals: ArrivalProcess::Gamma { rate: capacity * 2.0, cv: 3.0 },
        qoe_trace: QoeTrace::TextReading,
        num_requests: 150,
        seed: 42,
    }
    .generate();
    let run = |legacy: bool| -> String {
        let mut gcfg = GatewayConfig::default();
        gcfg.surge.baseline_rate = capacity;
        gcfg.legacy_stepping = legacy;
        let mut gw = Gateway::new(golden_cluster(&latency, false, legacy), gcfg);
        fp_gateway(&gw.run_trace(trace.clone()).unwrap())
    };
    assert_eq!(run(true), run(false), "gateway step-vs-calendar parity broke");
}

#[test]
fn sessions_cell_parity() {
    // The `ext-sessions` golden cell: 40 multi-turn sessions, prefix
    // parking + affinity routing, pacing off. Think-time returns ride
    // SessionReturn wakeups on the calendar path.
    let llm = opt_66b();
    let gpu = a100_4x();
    let latency = LatencyModel::for_deployment(&llm, &gpu);
    let capacity = estimate_capacity(&llm, &gpu, Dataset::ShareGpt) * 2.0;
    let trace = SessionWorkload {
        num_sessions: 40,
        arrivals: ArrivalProcess::Poisson { rate: capacity * 1.3 / 3.0 },
        qoe_trace: QoeTrace::TextReading,
        min_turns: 2,
        max_turns: 4,
        think_time_mean: 4.0,
        seed: 42,
    }
    .generate();
    let run = |legacy: bool| -> String {
        let mut cluster = golden_cluster(&latency, true, legacy);
        cluster.set_session_affinity(true);
        let mut gcfg = GatewayConfig::default();
        gcfg.pacing_enabled = false;
        gcfg.surge.baseline_rate = capacity;
        gcfg.legacy_stepping = legacy;
        let mut gw = Gateway::new(cluster, gcfg);
        fp_gateway(&gw.run_trace(trace.clone()).unwrap())
    };
    assert_eq!(run(true), run(false), "sessions step-vs-calendar parity broke");
}

#[test]
fn network_cell_parity() {
    // The `ext-network` lte cell: session workload over a jittery
    // last-mile link with the adaptive pacer lead. The delivery ack
    // drain rides DeliveryAck wakeups on the calendar path.
    let latency = LatencyModel::for_deployment(&opt_66b(), &a100_4x());
    let trace = SessionWorkload {
        num_sessions: 15,
        arrivals: ArrivalProcess::Poisson { rate: 1.0 },
        qoe_trace: QoeTrace::TextReading,
        min_turns: 2,
        max_turns: 4,
        think_time_mean: 3.0,
        seed: 7,
    }
    .generate();
    let run = |legacy: bool| -> String {
        let ecfg = EngineConfig {
            kv_capacity_tokens: 6000,
            swap_capacity_tokens: 12_000,
            legacy_stepping: legacy,
            ..EngineConfig::default()
        };
        let cluster =
            Cluster::new(2, ecfg, latency.clone(), &SchedulerConfig::Fcfs, RoutingPolicy::QoeAware);
        let mut gcfg = GatewayConfig::default();
        gcfg.surge.baseline_rate = 2.0;
        gcfg.legacy_stepping = legacy;
        gcfg.network.enabled = true;
        gcfg.network.adaptive_lead = true;
        gcfg.network.legacy_stepping = legacy;
        gcfg.network = gcfg.network.clone().with_mix(vec![(NetworkProfile::lte(), 1.0)]);
        let mut gw = Gateway::new(cluster, gcfg);
        fp_gateway(&gw.run_trace(trace.clone()).unwrap())
    };
    assert_eq!(run(true), run(false), "network step-vs-calendar parity broke");
}

#[test]
fn federation_parity() {
    // Two federated gateways over the stress-cell cluster: sync timers
    // ride FederationSync wakeups, per-node defer deadlines ride
    // DeferDeadline wakeups.
    let llm = opt_66b();
    let gpu = a100_4x();
    let latency = LatencyModel::for_deployment(&llm, &gpu);
    let capacity = estimate_capacity(&llm, &gpu, Dataset::ShareGpt) * 2.0;
    let trace = Workload {
        dataset: Dataset::ShareGpt,
        arrivals: ArrivalProcess::Gamma { rate: capacity * 2.0, cv: 3.0 },
        qoe_trace: QoeTrace::TextReading,
        num_requests: 120,
        seed: 42,
    }
    .generate();
    let run = |legacy: bool| -> String {
        let mut gcfg = GatewayConfig::default();
        gcfg.surge.baseline_rate = capacity;
        gcfg.legacy_stepping = legacy;
        let fed = FederationConfig {
            gateways: 2,
            sync_interval_secs: 0.25,
            ..FederationConfig::default()
        };
        let mut gw = FederatedGateway::new(golden_cluster(&latency, false, legacy), gcfg, fed);
        let res = gw.run_trace(trace.clone()).unwrap();
        let mut out = String::new();
        for s in &res.served {
            out.push_str(&fp_served(s));
            out.push('\n');
        }
        for r in &res.rejections {
            out.push_str(&fp_rejection(r));
            out.push('\n');
        }
        out.push_str(&format!(
            "stats {:?}\nrs {:x}\n",
            res.stats,
            res.replica_seconds.to_bits()
        ));
        for m in &res.per_replica {
            out.push_str(&fp_metrics(m));
        }
        out
    };
    assert_eq!(run(true), run(false), "federation step-vs-calendar parity broke");
}

// ------------------------------------------------- calendar invariants

#[test]
fn calendar_invariants_under_random_interleaving() {
    // Random register/cancel/fire schedules against a brute-force
    // model: fire order is exactly (time, seq), fire times are monotone
    // non-decreasing, cancelled wakeups never fire, nothing is lost,
    // nothing fires twice.
    #[derive(Clone, Copy, PartialEq)]
    enum State {
        Live,
        Cancelled,
        Fired,
    }
    check_prop("calendar invariants", 300, |rng| {
        let mut cal = EventCalendar::new();
        // Model entry: (time, token, state); index == payload == seq order.
        let mut model: Vec<(f64, WakeupToken, State)> = Vec::new();
        let mut fired_count = 0u64;
        let ops = 1 + rng.below(120);
        for _ in 0..ops {
            match rng.below(10) {
                // Register (weighted): time >= last fired instant, with
                // deliberate ties to exercise the seq tie-break.
                0..=5 => {
                    let base = cal.last_fired().unwrap_or(0.0);
                    let time = base + (rng.below(8) as f64) * 0.25;
                    let kinds = [
                        EventKind::Arrival,
                        EventKind::DeferDeadline,
                        EventKind::AutoscaleTick,
                        EventKind::DeliveryAck,
                    ];
                    let kind = kinds[rng.below(kinds.len() as u64) as usize];
                    let token = cal.register(time, kind, model.len() as u64);
                    model.push((time, token, State::Live));
                }
                // Cancel a random live wakeup (double-cancel is inert).
                6..=7 => {
                    let live: Vec<usize> = (0..model.len())
                        .filter(|&i| model[i].2 == State::Live)
                        .collect();
                    if let Some(&i) = live.get(rng.below(live.len().max(1) as u64) as usize) {
                        assert!(cal.cancel(model[i].1), "live token must cancel");
                        assert!(!cal.cancel(model[i].1), "double-cancel must be inert");
                        model[i].2 = State::Cancelled;
                    }
                }
                // Fire the earliest live wakeup and check it against the
                // model's brute-force minimum.
                _ => {
                    let expected = (0..model.len())
                        .filter(|&i| model[i].2 == State::Live)
                        .min_by(|&a, &b| model[a].0.total_cmp(&model[b].0).then(a.cmp(&b)));
                    let before = cal.last_fired();
                    match (cal.pop(), expected) {
                        (Some(w), Some(i)) => {
                            assert_eq!(w.payload as usize, i, "fired out of (time, seq) order");
                            assert_eq!(w.time.to_bits(), model[i].0.to_bits());
                            assert!(
                                before.is_none_or(|last| w.time >= last),
                                "fire times must be monotone non-decreasing"
                            );
                            model[i].2 = State::Fired;
                            fired_count += 1;
                        }
                        (None, None) => {}
                        (got, want) => panic!(
                            "pop() disagreed with the model: got {:?}, wanted index {:?}",
                            got.map(|w| w.payload),
                            want
                        ),
                    }
                }
            }
            let live_in_model = model.iter().filter(|e| e.2 == State::Live).count();
            assert_eq!(cal.len(), live_in_model, "len() must count exactly the live wakeups");
        }
        // Drain: every remaining live wakeup fires exactly once, in
        // (time, seq) order; cancelled ones never surface.
        let mut remaining: Vec<usize> =
            (0..model.len()).filter(|&i| model[i].2 == State::Live).collect();
        remaining.sort_by(|&a, &b| model[a].0.total_cmp(&model[b].0).then(a.cmp(&b)));
        for &i in &remaining {
            let w = cal.pop().expect("a live wakeup was lost");
            assert_eq!(w.payload as usize, i, "drain fired out of order");
            model[i].2 = State::Fired;
            fired_count += 1;
        }
        assert!(cal.pop().is_none(), "a cancelled or fired wakeup surfaced twice");
        assert_eq!(cal.fired(), fired_count);
        assert_eq!(
            fired_count as usize,
            model.iter().filter(|e| e.2 == State::Fired).count()
        );
    });
}

#[test]
fn next_time_of_matches_filtered_model() {
    // The &self kind-filtered query must agree with a brute-force scan
    // regardless of heap layout, registration order, or cancellations.
    check_prop("next_time_of", 200, |rng| {
        let mut cal = EventCalendar::new();
        let mut entries: Vec<(f64, EventKind, WakeupToken, bool)> = Vec::new();
        let kinds = [
            EventKind::DeferDeadline,
            EventKind::AutoscaleTick,
            EventKind::FederationSync,
        ];
        for _ in 0..rng.below(60) {
            let time = (rng.below(20) as f64) * 0.5;
            let kind = kinds[rng.below(3) as usize];
            let token = cal.register(time, kind, 0);
            let cancel = rng.below(4) == 0;
            if cancel {
                cal.cancel(token);
            }
            entries.push((time, kind, token, cancel));
        }
        for kind in kinds {
            let want = entries
                .iter()
                .filter(|(_, k, _, cancelled)| *k == kind && !cancelled)
                .map(|(t, ..)| *t)
                .min_by(f64::total_cmp);
            assert_eq!(
                cal.next_time_of(kind).map(f64::to_bits),
                want.map(f64::to_bits),
                "kind-filtered minimum diverged from the model"
            );
        }
    });
}

// ------------------------------------------------- shard determinism

#[test]
fn shard_counts_are_byte_identical() {
    // Six reduced gateway cells producing (JSONL trace, summary CSV)
    // pairs: the concatenated artifacts must be byte-identical between
    // shards=1 and shards=4, across repeated runs.
    let llm = opt_66b();
    let gpu = a100_4x();
    let latency = LatencyModel::for_deployment(&llm, &gpu);
    let capacity = estimate_capacity(&llm, &gpu, Dataset::ShareGpt) * 2.0;
    let cells: Vec<(f64, bool)> = vec![
        (1.0, false),
        (1.0, true),
        (2.0, false),
        (2.0, true),
        (4.0, false),
        (4.0, true),
    ];
    let run_cells = |shards: usize| -> (String, String) {
        let outs = run_grid(&cells, shards, |i, &(load, pacing)| {
            let trace = Workload {
                dataset: Dataset::ShareGpt,
                arrivals: ArrivalProcess::Gamma { rate: capacity * load, cv: 3.0 },
                qoe_trace: QoeTrace::TextReading,
                num_requests: 60,
                seed: 42 + i as u64,
            }
            .generate();
            let mut gcfg = GatewayConfig::default();
            gcfg.pacing_enabled = pacing;
            gcfg.surge.baseline_rate = capacity;
            let mut gw = Gateway::new(golden_cluster(&latency, false, false), gcfg);
            let res = gw.run_trace(trace).unwrap();
            let mut jsonl = String::new();
            for s in &res.served {
                jsonl.push_str(&format!(
                    "{{\"cell\":{i},\"id\":{},\"qoe\":\"{:x}\"}}\n",
                    s.id,
                    s.paced_qoe.to_bits()
                ));
            }
            let csv = format!(
                "{i},{load},{pacing},{},{},{:x}\n",
                res.served.len(),
                res.rejections.len(),
                res.mean_served_qoe().to_bits()
            );
            (jsonl, csv)
        });
        let mut jsonl = String::new();
        let mut csv = String::from("cell,load,pacing,served,rejected,mean_qoe_bits\n");
        for (j, c) in outs {
            jsonl.push_str(&j);
            csv.push_str(&c);
        }
        (jsonl, csv)
    };
    let base = run_cells(1);
    for _ in 0..2 {
        assert_eq!(run_cells(4), base, "sharded run diverged from the inline baseline");
        assert_eq!(run_cells(1), base, "repeated inline run diverged");
    }
}

// ------------------------------------------- defer-sweep drift fix

#[test]
fn defer_expiry_lands_on_deadline_with_autoscale_ticking() {
    // Regression for the defer-sweep clock drift: during `finish()` the
    // engine can step past a defer deadline, and the catch-up sweep at
    // the deadline used to hand the autoscaler a *smaller* t than its
    // previous evaluation — backwards time. With the calendar clock and
    // the monotonicity clamp: the planner never observes a regression,
    // and every defer-timeout rejection lands on its exact deadline.
    let llm = opt_66b();
    let gpu = a100_4x();
    let latency = LatencyModel::for_deployment(&llm, &gpu);
    let per_replica = estimate_capacity(&llm, &gpu, Dataset::ShareGpt);
    let trace = Workload {
        dataset: Dataset::ShareGpt,
        arrivals: ArrivalProcess::Gamma { rate: per_replica * 6.0, cv: 3.0 },
        qoe_trace: QoeTrace::TextReading,
        num_requests: 120,
        seed: 42,
    }
    .generate();
    let arrivals: Vec<(usize, f64)> = trace.iter().map(|s| (s.id, s.arrival)).collect();
    let run = |legacy: bool| -> (String, u64, usize) {
        let llm = opt_66b();
        let gpu = a100_4x();
        let engine_cfg = EngineConfig {
            kv_capacity_tokens: llm.kv_capacity_tokens(&gpu),
            swap_capacity_tokens: llm.swap_capacity_tokens(&gpu),
            legacy_stepping: legacy,
            ..EngineConfig::default()
        };
        let sched = SchedulerConfig::Andes(AndesConfig::default());
        let cluster =
            Cluster::new(1, engine_cfg, latency.clone(), &sched, RoutingPolicy::QoeAware);
        let mut gcfg = GatewayConfig::default();
        gcfg.admission_enabled = true;
        gcfg.legacy_stepping = legacy;
        gcfg.surge.baseline_rate = per_replica * 3.0;
        gcfg.autoscale = AutoscaleConfig {
            enabled: true,
            min_replicas: 1,
            max_replicas: 3,
            replica_capacity: per_replica,
            ..AutoscaleConfig::default()
        };
        let mut gw = Gateway::new(cluster, gcfg.clone());
        let res = gw.run_trace(trace.clone()).unwrap();
        let mut timeouts = 0usize;
        for r in &res.rejections {
            if let RejectReason::DeferTimeout { .. } = r.reason {
                timeouts += 1;
                let arrival = arrivals
                    .iter()
                    .find(|(id, _)| *id == r.id)
                    .map(|(_, a)| *a)
                    .expect("rejected id must come from the trace");
                let deadline = arrival + gcfg.admission.max_defer_wait;
                assert!(
                    (r.time - deadline).abs() <= 1e-9,
                    "defer expiry drifted off its deadline: id {} expired at {} vs {}",
                    r.id,
                    r.time,
                    deadline
                );
            }
        }
        (fp_gateway(&res), gw.autoscaler().time_regressions(), timeouts)
    };
    let (calendar_fp, calendar_regressions, calendar_timeouts) = run(false);
    let (legacy_fp, legacy_regressions, _) = run(true);
    assert!(calendar_timeouts > 0, "scenario must produce defer timeouts to be meaningful");
    assert_eq!(calendar_regressions, 0, "autoscaler observed backwards time (calendar path)");
    assert_eq!(legacy_regressions, 0, "autoscaler observed backwards time (legacy path)");
    assert_eq!(legacy_fp, calendar_fp, "autoscale step-vs-calendar parity broke");
}

// --------------------------------------------------- calendar vs clear

#[test]
fn engine_reload_reanchors_the_calendar() {
    // Back-to-back load_trace calls on one engine: the second trace's
    // arrivals all lie *before* the times the first run fired, which
    // only works because load_trace clears the calendar and clear()
    // re-anchors the monotone-firing guard (while keeping seqs fresh so
    // stale tokens from the first schedule stay inert). Pre-clear, the
    // debug assertion in pop() would trip on the first re-fired wakeup.
    let trace = Workload {
        dataset: Dataset::ShareGpt,
        arrivals: ArrivalProcess::Poisson { rate: 2.0 },
        qoe_trace: QoeTrace::TextReading,
        num_requests: 30,
        seed: 11,
    }
    .generate();
    let llm = opt_66b();
    let gpu = a100_4x();
    let latency = LatencyModel::for_deployment(&llm, &gpu);
    let cfg = EngineConfig {
        kv_capacity_tokens: llm.kv_capacity_tokens(&gpu),
        swap_capacity_tokens: llm.swap_capacity_tokens(&gpu),
        ..EngineConfig::default()
    };
    let mut e = Engine::new(
        cfg,
        SimBackend::new(latency.clone()),
        VirtualClock::default(),
        SchedKind::andes_default().build(),
        latency,
    );
    e.load_trace(trace.clone());
    let served = e.run_to_completion().unwrap().requests.len();
    assert_eq!(served, trace.len());
    e.load_trace(trace.clone());
    let total = e.run_to_completion().unwrap().requests.len();
    assert_eq!(
        total,
        2 * trace.len(),
        "the reloaded trace must be served in full on the reused calendar"
    );
}
