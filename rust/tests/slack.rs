//! Property and parity tests for the server-side client-buffer slack
//! estimator (DESIGN.md §15).
//!
//! Three contracts are pinned here:
//! - structural bounds: estimated occupancy is never negative and never
//!   exceeds what the modeled pacer has released, on arbitrary seeded
//!   generation traces and estimator configs;
//! - ground truth: with the pacer parameters mirrored exactly, the
//!   estimate reproduces the real client buffer — both against the
//!   batch pacer schedule plus a constant transit, and against the full
//!   delivery layer on the ideal (identity) link;
//! - passivity: constructing the estimator changes nothing unless a
//!   scheduler reads it — an FCFS engine with `slack: Some(..)` is
//!   bit-identical to `slack: None`.

use andes::backend::sim::SimBackend;
use andes::backend::VirtualClock;
use andes::coordinator::engine::{Engine, EngineConfig};
use andes::coordinator::sched::fcfs::FcfsScheduler;
use andes::coordinator::sched::Scheduler;
use andes::coordinator::{SlackConfig, SlackEstimator};
use andes::delivery::{deliver_request, NetworkConfig, NetworkProfile};
use andes::gateway::{pace_times, PacingConfig};
use andes::model::gpu::a100_4x;
use andes::model::latency::LatencyModel;
use andes::model::llm::opt_66b;
use andes::qoe::metric::DigestState;
use andes::qoe::spec::QoeSpec;
use andes::util::rng::Rng;
use andes::util::testing::check_prop;
use andes::workload::{ArrivalProcess, Dataset, QoeTrace, Workload};

/// A non-decreasing request-relative generation trace with same-instant
/// bursts mixed in (the overfast-generation shape the pacer exists for).
fn gen_trace(rng: &mut Rng, n: usize) -> Vec<f64> {
    let mut t = rng.f64() * 0.5;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        if rng.below(3) != 0 {
            t += rng.f64() * 0.5;
        }
        out.push(t);
    }
    out
}

#[test]
fn occupancy_bounded_on_seeded_traces() {
    check_prop("slack occupancy bounds", 40, |rng| {
        let cfg = SlackConfig {
            paced: rng.below(4) != 0,
            rate_factor: 1.0 + rng.f64(),
            lead_tokens: rng.below(6) as usize,
            transit: rng.f64() * 0.05,
        };
        let spec = QoeSpec::new(0.5 + rng.f64(), 1.0 + rng.f64() * 6.0);
        let mut est = SlackEstimator::new(cfg);
        let n = rng.range(5, 60);
        let trace = gen_trace(rng, n);
        for (i, &t) in trace.iter().enumerate() {
            est.on_token(3, &spec, t);
            let released = est.released(3).unwrap();
            assert_eq!(released, i + 1);
            // Probes at "now" and into the future, as the scheduler
            // would issue them between generation events.
            for probe in [t, t + rng.f64() * 2.0, t + 30.0] {
                let d = est.estimate(3, probe).unwrap();
                let occ = d.buffered();
                assert!(occ >= -1e-12, "occupancy {occ} negative at {probe}");
                assert!(
                    d.delivered() <= released as f64 + 1e-9,
                    "delivered {} exceeds released {released}",
                    d.delivered()
                );
                assert!(
                    occ <= d.delivered() + 1e-9,
                    "buffered {occ} exceeds delivered {}",
                    d.delivered()
                );
            }
        }
    });
}

#[test]
fn estimator_replays_the_pacer_schedule_exactly() {
    // With the pacer parameters mirrored and a constant transit, the
    // estimate must equal a digest fed by `pace_times(..) + transit` —
    // the same release rule the gateway applies.
    check_prop("slack pacer replay", 30, |rng| {
        let pacing = PacingConfig {
            rate_factor: 1.0 + rng.f64() * 0.5,
            lead_tokens: rng.below(6) as usize,
        };
        let transit = rng.f64() * 0.03;
        let cfg = SlackConfig {
            paced: true,
            rate_factor: pacing.rate_factor,
            lead_tokens: pacing.lead_tokens,
            transit,
        };
        let spec = QoeSpec::new(1.0, 2.0 + rng.f64() * 4.0);
        let trace = gen_trace(rng, rng.range(5, 50));
        let mut est = SlackEstimator::new(cfg);
        for &t in &trace {
            est.on_token(9, &spec, t);
        }
        let releases = pace_times(&spec, &pacing, &trace);
        let last = *trace.last().unwrap();
        for probe in [last, last + 0.7, last + 5.0, last + 50.0] {
            let mut truth = DigestState::new(&spec);
            for &r in &releases {
                if r + transit <= probe {
                    truth.deliver(r + transit);
                }
            }
            truth.advance_to(probe);
            let d = est.estimate(9, probe).unwrap();
            assert!(
                (d.buffered() - truth.buffered()).abs() < 1e-9,
                "buffered {} vs ground truth {} at {probe}",
                d.buffered(),
                truth.buffered()
            );
            assert!(
                (d.delivered() - truth.delivered()).abs() < 1e-9,
                "delivered {} vs ground truth {} at {probe}",
                d.delivered(),
                truth.delivered()
            );
        }
    });
}

#[test]
fn estimator_agrees_with_the_delivery_layer_on_the_ideal_link() {
    // End-to-end ground truth: run the same generation trace through
    // the real delivery layer (pacer → network → client buffer) on the
    // identity link and compare client-buffer occupancy.
    check_prop("slack vs delivery ground truth", 20, |rng| {
        let pacing = PacingConfig {
            rate_factor: 1.0 + rng.f64() * 0.5,
            lead_tokens: rng.below(6) as usize,
        };
        let netcfg = NetworkConfig { enabled: true, ..NetworkConfig::default() }
            .with_mix(vec![(NetworkProfile::ideal(), 1.0)]);
        let spec = QoeSpec::new(1.0, 2.0 + rng.f64() * 4.0);
        let trace = gen_trace(rng, rng.range(5, 40));
        let out = deliver_request(
            &spec,
            true,
            &pacing,
            &netcfg,
            rng.below(1000) as usize,
            &trace,
        );
        assert_eq!(out.client_arrivals.len(), trace.len());
        let cfg = SlackConfig {
            paced: true,
            rate_factor: pacing.rate_factor,
            lead_tokens: pacing.lead_tokens,
            transit: 0.0, // the ideal link is the identity
        };
        let mut est = SlackEstimator::new(cfg);
        for &t in &trace {
            est.on_token(0, &spec, t);
        }
        let last = *trace.last().unwrap();
        for probe in [last, last + 1.0, last + 10.0] {
            let mut truth = DigestState::new(&spec);
            for &a in &out.client_arrivals {
                if a <= probe {
                    truth.deliver(a);
                }
            }
            truth.advance_to(probe);
            let occ = est.occupancy(0, probe).unwrap();
            assert!(
                (occ - truth.buffered()).abs() < 1e-9,
                "estimated {occ} vs delivery ground truth {} at {probe}",
                truth.buffered()
            );
        }
    });
}

#[test]
fn slack_estimator_is_passive_under_a_slack_blind_scheduler() {
    // FCFS never reads `SchedView::slack`, so enabling the estimator
    // must leave every token time and QoE bit-identical — the estimator
    // observes, it never steers.
    let run = |slack: Option<SlackConfig>| {
        let latency = LatencyModel::for_deployment(&opt_66b(), &a100_4x());
        let cfg = EngineConfig {
            kv_capacity_tokens: 3000,
            swap_capacity_tokens: 3000,
            slack,
            ..EngineConfig::default()
        };
        let sched: Box<dyn Scheduler> = Box::new(FcfsScheduler::new());
        let mut e = Engine::new(
            cfg,
            SimBackend::new(latency.clone()),
            VirtualClock::default(),
            sched,
            latency,
        );
        let trace = Workload {
            dataset: Dataset::ShareGpt,
            arrivals: ArrivalProcess::Poisson { rate: 3.0 },
            qoe_trace: QoeTrace::TextReading,
            num_requests: 60,
            seed: 7,
        }
        .generate();
        e.load_trace(trace);
        e.run_to_completion().unwrap()
    };
    let off = run(None);
    let on = run(Some(SlackConfig::default()));
    assert_eq!(off.total_preemptions, on.total_preemptions);
    assert_eq!(off.deep_buffer_preemptions, on.deep_buffer_preemptions);
    assert_eq!(off.requests.len(), on.requests.len());
    for (a, b) in off.requests.iter().zip(on.requests.iter()) {
        assert_eq!(a.token_times, b.token_times, "req {}", a.id);
        assert_eq!(a.final_qoe.to_bits(), b.final_qoe.to_bits(), "req {}", a.id);
    }
}
