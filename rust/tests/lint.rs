//! Integration tests for the in-tree determinism lint (`andes lint`).
//!
//! Two jobs: (1) the repository itself must lint clean — every finding
//! is either fixed or carries a reasoned inline waiver, so the committed
//! baseline stays empty; (2) the rule engine must keep firing on the
//! known-bad fixture corpus under `rust/tests/lint_fixtures/` and stay
//! quiet on the known-good counterparts.

use std::path::Path;

use andes::analysis::baseline::Baseline;
use andes::analysis::lexer::strip_source;
use andes::analysis::{lint_repo, lint_sources, LintOptions, LintOutcome};
use andes::util::testing::check_prop;

/// Read a fixture file from the corpus (skipped by the repo walker).
fn fixture(name: &str) -> String {
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/lint_fixtures")
        .join(name);
    std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("fixture {} unreadable: {e}", path.display()))
}

/// Lint one fixture under a synthetic repo-relative path (the path picks
/// the per-rule scopes: D2 wall domain, D5 library code, D6 sim paths).
fn lint_one(rel: &str, text: &str) -> LintOutcome {
    lint_sources(&[(rel.to_string(), text.to_string())], &LintOptions::default())
}

fn rules_of(outcome: &LintOutcome) -> Vec<&str> {
    outcome.findings.iter().map(|f| f.rule).collect()
}

#[test]
fn repository_lints_clean_with_empty_baseline() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let opts = LintOptions::default(); // empty baseline: nothing grandfathered
    let out = lint_repo(root, &opts).expect("lint walk failed");
    assert!(
        out.findings.is_empty(),
        "repository must lint clean; fresh findings:\n{}",
        out.findings
            .iter()
            .map(|f| format!("  {}:{}: [{}] {}", f.file, f.line, f.rule, f.excerpt))
            .collect::<Vec<_>>()
            .join("\n")
    );
    assert!(out.files_scanned > 40, "walker found too few files: {}", out.files_scanned);
    // X1 sanity: the metric taxonomy is present and reconciles.
    assert!(out.declared > 0, "declare_base_families not found");
    assert_eq!(out.declared, out.emitted, "metric families must reconcile");
}

#[test]
fn committed_baseline_is_empty_and_parses() {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("lint-baseline.json");
    let text = std::fs::read_to_string(&path).expect("lint-baseline.json missing");
    let base = Baseline::parse(&text).expect("lint-baseline.json malformed");
    assert_eq!(base.total(), 0, "baseline must stay empty; fix or waive instead");
}

#[test]
fn d1_fixtures() {
    let bad = lint_one("rust/src/coordinator/fx.rs", &fixture("d1_bad.rs"));
    assert_eq!(rules_of(&bad), vec!["D1", "D1"], "{:?}", bad.findings);
    let good = lint_one("rust/src/coordinator/fx.rs", &fixture("d1_good.rs"));
    assert!(good.findings.is_empty(), "{:?}", good.findings);
}

#[test]
fn d2_fixtures() {
    let bad = lint_one("rust/src/coordinator/fx.rs", &fixture("d2_bad.rs"));
    assert_eq!(rules_of(&bad), vec!["D2", "D2"], "{:?}", bad.findings);
    // The same file inside the wall domain is fine.
    let allowed = lint_one("rust/src/server/fx.rs", &fixture("d2_bad.rs"));
    assert!(allowed.findings.is_empty(), "{:?}", allowed.findings);
    let good = lint_one("rust/src/coordinator/fx.rs", &fixture("d2_good.rs"));
    assert!(good.findings.is_empty(), "{:?}", good.findings);
}

#[test]
fn d3_fixtures() {
    let bad = lint_one("rust/src/util/fx.rs", &fixture("d3_bad.rs"));
    assert_eq!(rules_of(&bad), vec!["D3", "D3"], "{:?}", bad.findings);
    let good = lint_one("rust/src/util/fx.rs", &fixture("d3_good.rs"));
    assert!(good.findings.is_empty(), "{:?}", good.findings);
}

#[test]
fn d4_fixtures() {
    let bad = lint_one("rust/src/workload/fx.rs", &fixture("d4_bad.rs"));
    assert_eq!(rules_of(&bad), vec!["D4", "D4"], "{:?}", bad.findings);
    let good = lint_one("rust/src/workload/fx.rs", &fixture("d4_good.rs"));
    assert!(good.findings.is_empty(), "{:?}", good.findings);
}

#[test]
fn d5_fixtures() {
    let bad = lint_one("rust/src/qoe/fx.rs", &fixture("d5_bad.rs"));
    assert_eq!(rules_of(&bad), vec!["D5", "D5"], "{:?}", bad.findings);
    // The same text under rust/tests/ is out of D5 scope.
    let test_side = lint_one("rust/tests/fx.rs", &fixture("d5_bad.rs"));
    assert!(test_side.findings.is_empty(), "{:?}", test_side.findings);
    let good = lint_one("rust/src/qoe/fx.rs", &fixture("d5_good.rs"));
    assert!(good.findings.is_empty(), "{:?}", good.findings);
}

#[test]
fn d6_fixtures() {
    let bad = lint_one("rust/src/qoe/fx.rs", &fixture("d6_bad.rs"));
    assert_eq!(rules_of(&bad), vec!["D6", "D6"], "{:?}", bad.findings);
    // Outside the sim scope the same unwraps are accepted.
    let cli_side = lint_one("rust/src/experiments/fx.rs", &fixture("d6_bad.rs"));
    assert!(cli_side.findings.is_empty(), "{:?}", cli_side.findings);
    let good = lint_one("rust/src/qoe/fx.rs", &fixture("d6_good.rs"));
    assert!(good.findings.is_empty(), "{:?}", good.findings);
}

#[test]
fn d2_thread_fixtures() {
    // Worker threads in the shard runner are still simulation code: a
    // wall-clock read inside a spawned closure (or in the post-merge
    // assembly) fires like any other.
    let bad = lint_one("rust/src/experiments/shard.rs", &fixture("d2_threads_bad.rs"));
    assert_eq!(rules_of(&bad), vec!["D2", "D2"], "{:?}", bad.findings);
    let good = lint_one("rust/src/experiments/shard.rs", &fixture("d2_threads_good.rs"));
    assert!(good.findings.is_empty(), "{:?}", good.findings);
}

#[test]
fn d6_covers_calendar_and_shard_runner() {
    // The event calendar rides the coordinator/ prefix and the shard
    // runner is listed explicitly: unwraps fire on both, while the rest
    // of experiments/ stays CLI-side plumbing (see d6_fixtures).
    let cal = lint_one("rust/src/coordinator/calendar.rs", &fixture("d6_bad.rs"));
    assert_eq!(rules_of(&cal), vec!["D6", "D6"], "{:?}", cal.findings);
    let shard = lint_one("rust/src/experiments/shard.rs", &fixture("d6_bad.rs"));
    assert_eq!(rules_of(&shard), vec!["D6", "D6"], "{:?}", shard.findings);
}

#[test]
fn x1_fixtures() {
    let bad = lint_one("rust/src/telemetry_fx.rs", &fixture("x1_bad.rs"));
    assert_eq!(rules_of(&bad), vec!["X1", "X1"], "{:?}", bad.findings);
    let excerpts: Vec<&str> = bad.findings.iter().map(|f| f.excerpt.as_str()).collect();
    assert!(
        excerpts.contains(&"andes_declared_only_total")
            && excerpts.contains(&"andes_ghost_total"),
        "{excerpts:?}"
    );
    let good = lint_one("rust/src/telemetry_fx.rs", &fixture("x1_good.rs"));
    assert!(good.findings.is_empty(), "{:?}", good.findings);
    assert_eq!(good.declared, 2);
    assert_eq!(good.emitted, 2);
}

#[test]
fn suppression_fixture_lints_clean_with_counted_waivers() {
    let out = lint_one("rust/src/qoe/fx.rs", &fixture("suppressed.rs"));
    assert!(out.findings.is_empty(), "{:?}", out.findings);
    // D2 + D3 + D6 (sort line) + D6 (head line) all consumed a waiver.
    assert_eq!(out.suppressed, 4);
}

#[test]
fn strings_and_comments_never_produce_findings() {
    // Scanned under the strictest scope (D6 active, outside wall domain):
    // every forbidden token sits in a comment or literal, so the lexer
    // must blank them all.
    let out = lint_one("rust/src/coordinator/fx.rs", &fixture("strings_comments.rs"));
    assert!(out.findings.is_empty(), "{:?}", out.findings);
}

#[test]
fn baseline_ratchets_only_new_findings() {
    let rel = "rust/src/coordinator/fx.rs";
    let text = fixture("d2_bad.rs");
    let all = lint_one(rel, &text);
    assert_eq!(all.findings.len(), 2);
    // Grandfather today's findings: a re-run reports nothing fresh.
    let opts = LintOptions {
        rule: None,
        baseline: Baseline::from_findings(&all.findings),
    };
    let again = lint_sources(&[(rel.to_string(), text.clone())], &opts);
    assert!(again.findings.is_empty(), "{:?}", again.findings);
    assert_eq!(again.baselined, 2);
    // A newly introduced violation surfaces despite the baseline.
    let grown = format!("{text}\npub fn extra() -> u64 {{ SystemTime::now_stub() }}\n");
    let regressed = lint_sources(&[(rel.to_string(), grown)], &opts);
    assert_eq!(regressed.findings.len(), 1, "{:?}", regressed.findings);
    assert_eq!(regressed.findings[0].rule, "D2");
    assert!(regressed.findings[0].excerpt.contains("extra"));
}

#[test]
fn rule_filter_restricts_fixture_report() {
    let files = vec![
        ("rust/src/coordinator/a.rs".to_string(), fixture("d2_bad.rs")),
        ("rust/src/util/b.rs".to_string(), fixture("d3_bad.rs")),
    ];
    let opts = LintOptions { rule: Some("D3".to_string()), ..Default::default() };
    let out = lint_sources(&files, &opts);
    assert_eq!(rules_of(&out), vec!["D3", "D3"], "{:?}", out.findings);
}

#[test]
fn strip_pass_preserves_line_numbers() {
    // Property: whatever mix of comments, strings, raw strings, char
    // literals, and unterminated constructs the lexer sees, the stripped
    // views keep exactly one entry per input line — findings and
    // suppressions would otherwise drift off their source lines.
    let frags = [
        "let x = 1;",
        "/* open",
        "still inside */ let y = 2;",
        "let s = \"literal with // and /* inside\";",
        "let r = r#\"raw \" quote\"#;",
        "// line comment with \" quote",
        "let c = '\"';",
        "let multi = \"spans",
        "two lines\";",
        "let b = b\"bytes\";",
        "let lt: &'static str = \"x\";",
        "/* nested /* depth */ two */",
        "}",
        "{",
        "",
    ];
    check_prop("strip preserves line count", 300, |rng| {
        let n = rng.range(1, 40);
        let mut src = String::new();
        for i in 0..n {
            if i > 0 {
                src.push('\n');
            }
            src.push_str(frags[rng.below(frags.len() as u64) as usize]);
        }
        let lines = src.split('\n').count();
        let stripped = strip_source(&src);
        assert_eq!(stripped.code.len(), lines, "code lines drifted for:\n{src}");
        assert_eq!(stripped.comments.len(), lines, "comment lines drifted for:\n{src}");
        for lit in &stripped.strings {
            assert!(lit.line < lines, "literal anchored past EOF in:\n{src}");
        }
    });
}
