//! Integration tests for the in-tree determinism lint (`andes lint`).
//!
//! Four jobs: (1) the repository itself must lint clean — every finding
//! is either fixed or carries a reasoned inline waiver, so the committed
//! baseline stays empty; (2) the rule engine must keep firing on the
//! known-bad fixture corpus under `rust/tests/lint_fixtures/` and stay
//! quiet on the known-good counterparts; (3) the cross-artifact rules
//! (X2–X5) must be provably *live* — desyncing an in-memory copy of the
//! real paired artifact makes the finding appear; (4) the token-tree
//! parser must tile sources byte-for-byte and agree with the legacy
//! strip pass over the whole tree.

use std::path::Path;

use andes::analysis::artifacts::{load_artifacts, Artifacts};
use andes::analysis::baseline::Baseline;
use andes::analysis::lexer::strip_source;
use andes::analysis::parse::{to_stripped, ParsedFile};
use andes::analysis::report::{render_human, render_json};
use andes::analysis::rules::{known_rule, RULE_TABLE};
use andes::analysis::{
    collect_sources, lint_repo, lint_sources, lint_sources_with, LintOptions, LintOutcome,
};
use andes::util::golden::check_or_bless_text;
use andes::util::json::Json;
use andes::util::testing::check_prop;

/// Read a fixture file from the corpus (skipped by the repo walker).
fn fixture(name: &str) -> String {
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/lint_fixtures")
        .join(name);
    std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("fixture {} unreadable: {e}", path.display()))
}

/// Lint one fixture under a synthetic repo-relative path (the path picks
/// the per-rule scopes: D2 wall domain, D5 library code, D6 sim paths).
fn lint_one(rel: &str, text: &str) -> LintOutcome {
    lint_sources(&[(rel.to_string(), text.to_string())], &LintOptions::default())
}

fn rules_of(outcome: &LintOutcome) -> Vec<&str> {
    outcome.findings.iter().map(|f| f.rule).collect()
}

#[test]
fn repository_lints_clean_with_empty_baseline() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let opts = LintOptions::default(); // empty baseline: nothing grandfathered
    let out = lint_repo(root, &opts).expect("lint walk failed");
    assert!(
        out.findings.is_empty(),
        "repository must lint clean; fresh findings:\n{}",
        out.findings
            .iter()
            .map(|f| format!("  {}:{}: [{}] {}", f.file, f.line, f.rule, f.excerpt))
            .collect::<Vec<_>>()
            .join("\n")
    );
    assert!(out.files_scanned > 40, "walker found too few files: {}", out.files_scanned);
    // X1 sanity: the metric taxonomy is present and reconciles.
    assert!(out.declared > 0, "declare_base_families not found");
    assert_eq!(out.declared, out.emitted, "metric families must reconcile");
}

#[test]
fn committed_baseline_is_empty_and_parses() {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("lint-baseline.json");
    let text = std::fs::read_to_string(&path).expect("lint-baseline.json missing");
    let base = Baseline::parse(&text).expect("lint-baseline.json malformed");
    assert_eq!(base.total(), 0, "baseline must stay empty; fix or waive instead");
}

#[test]
fn d1_fixtures() {
    let bad = lint_one("rust/src/coordinator/fx.rs", &fixture("d1_bad.rs"));
    assert_eq!(rules_of(&bad), vec!["D1", "D1"], "{:?}", bad.findings);
    let good = lint_one("rust/src/coordinator/fx.rs", &fixture("d1_good.rs"));
    assert!(good.findings.is_empty(), "{:?}", good.findings);
}

#[test]
fn d2_fixtures() {
    let bad = lint_one("rust/src/coordinator/fx.rs", &fixture("d2_bad.rs"));
    assert_eq!(rules_of(&bad), vec!["D2", "D2"], "{:?}", bad.findings);
    // The same file inside the wall domain is fine.
    let allowed = lint_one("rust/src/server/fx.rs", &fixture("d2_bad.rs"));
    assert!(allowed.findings.is_empty(), "{:?}", allowed.findings);
    let good = lint_one("rust/src/coordinator/fx.rs", &fixture("d2_good.rs"));
    assert!(good.findings.is_empty(), "{:?}", good.findings);
}

#[test]
fn d2_env_fixtures() {
    let bad = lint_one("rust/src/coordinator/fx.rs", &fixture("d2_env_bad.rs"));
    assert_eq!(rules_of(&bad), vec!["D2", "D2"], "{:?}", bad.findings);
    // Outside the sim scope (util helpers, benches) env reads are allowed —
    // that's where the golden/bench bless knobs live.
    let allowed = lint_one("rust/src/util/fx.rs", &fixture("d2_env_bad.rs"));
    assert!(allowed.findings.is_empty(), "{:?}", allowed.findings);
    let good = lint_one("rust/src/coordinator/fx.rs", &fixture("d2_env_good.rs"));
    assert!(good.findings.is_empty(), "{:?}", good.findings);
}

#[test]
fn d3_fixtures() {
    let bad = lint_one("rust/src/util/fx.rs", &fixture("d3_bad.rs"));
    assert_eq!(rules_of(&bad), vec!["D3", "D3"], "{:?}", bad.findings);
    let good = lint_one("rust/src/util/fx.rs", &fixture("d3_good.rs"));
    assert!(good.findings.is_empty(), "{:?}", good.findings);
}

#[test]
fn d4_fixtures() {
    let bad = lint_one("rust/src/workload/fx.rs", &fixture("d4_bad.rs"));
    assert_eq!(rules_of(&bad), vec!["D4", "D4"], "{:?}", bad.findings);
    let good = lint_one("rust/src/workload/fx.rs", &fixture("d4_good.rs"));
    assert!(good.findings.is_empty(), "{:?}", good.findings);
}

#[test]
fn d5_fixtures() {
    let bad = lint_one("rust/src/qoe/fx.rs", &fixture("d5_bad.rs"));
    assert_eq!(rules_of(&bad), vec!["D5", "D5"], "{:?}", bad.findings);
    // The same text under rust/tests/ is out of D5 scope.
    let test_side = lint_one("rust/tests/fx.rs", &fixture("d5_bad.rs"));
    assert!(test_side.findings.is_empty(), "{:?}", test_side.findings);
    let good = lint_one("rust/src/qoe/fx.rs", &fixture("d5_good.rs"));
    assert!(good.findings.is_empty(), "{:?}", good.findings);
}

#[test]
fn d6_fixtures() {
    let bad = lint_one("rust/src/qoe/fx.rs", &fixture("d6_bad.rs"));
    assert_eq!(rules_of(&bad), vec!["D6", "D6"], "{:?}", bad.findings);
    // Outside the sim scope the same unwraps are accepted.
    let cli_side = lint_one("rust/src/experiments/fx.rs", &fixture("d6_bad.rs"));
    assert!(cli_side.findings.is_empty(), "{:?}", cli_side.findings);
    let good = lint_one("rust/src/qoe/fx.rs", &fixture("d6_good.rs"));
    assert!(good.findings.is_empty(), "{:?}", good.findings);
}

#[test]
fn d2_thread_fixtures() {
    // Worker threads in the shard runner are still simulation code: a
    // wall-clock read inside a spawned closure (or in the post-merge
    // assembly) fires like any other.
    let bad = lint_one("rust/src/experiments/shard.rs", &fixture("d2_threads_bad.rs"));
    assert_eq!(rules_of(&bad), vec!["D2", "D2"], "{:?}", bad.findings);
    let good = lint_one("rust/src/experiments/shard.rs", &fixture("d2_threads_good.rs"));
    assert!(good.findings.is_empty(), "{:?}", good.findings);
}

#[test]
fn d6_covers_calendar_and_shard_runner() {
    // The event calendar rides the coordinator/ prefix and the shard
    // runner is listed explicitly: unwraps fire on both, while the rest
    // of experiments/ stays CLI-side plumbing (see d6_fixtures).
    let cal = lint_one("rust/src/coordinator/calendar.rs", &fixture("d6_bad.rs"));
    assert_eq!(rules_of(&cal), vec!["D6", "D6"], "{:?}", cal.findings);
    let shard = lint_one("rust/src/experiments/shard.rs", &fixture("d6_bad.rs"));
    assert_eq!(rules_of(&shard), vec!["D6", "D6"], "{:?}", shard.findings);
}

#[test]
fn x1_fixtures() {
    let bad = lint_one("rust/src/telemetry_fx.rs", &fixture("x1_bad.rs"));
    assert_eq!(rules_of(&bad), vec!["X1", "X1"], "{:?}", bad.findings);
    let excerpts: Vec<&str> = bad.findings.iter().map(|f| f.excerpt.as_str()).collect();
    assert!(
        excerpts.contains(&"andes_declared_only_total")
            && excerpts.contains(&"andes_ghost_total"),
        "{excerpts:?}"
    );
    let good = lint_one("rust/src/telemetry_fx.rs", &fixture("x1_good.rs"));
    assert!(good.findings.is_empty(), "{:?}", good.findings);
    assert_eq!(good.declared, 2);
    assert_eq!(good.emitted, 2);
}

#[test]
fn suppression_fixture_lints_clean_with_counted_waivers() {
    let out = lint_one("rust/src/qoe/fx.rs", &fixture("suppressed.rs"));
    assert!(out.findings.is_empty(), "{:?}", out.findings);
    // D2 + D3 + D6 (sort line) + D6 (head line) all consumed a waiver.
    assert_eq!(out.suppressed, 4);
}

#[test]
fn strings_and_comments_never_produce_findings() {
    // Scanned under the strictest scope (D6 active, outside wall domain):
    // every forbidden token sits in a comment or literal, so the lexer
    // must blank them all.
    let out = lint_one("rust/src/coordinator/fx.rs", &fixture("strings_comments.rs"));
    assert!(out.findings.is_empty(), "{:?}", out.findings);
}

#[test]
fn baseline_ratchets_only_new_findings() {
    let rel = "rust/src/coordinator/fx.rs";
    let text = fixture("d2_bad.rs");
    let all = lint_one(rel, &text);
    assert_eq!(all.findings.len(), 2);
    // Grandfather today's findings: a re-run reports nothing fresh.
    let opts = LintOptions {
        rule: None,
        baseline: Baseline::from_findings(&all.findings),
    };
    let again = lint_sources(&[(rel.to_string(), text.clone())], &opts);
    assert!(again.findings.is_empty(), "{:?}", again.findings);
    assert_eq!(again.baselined, 2);
    // A newly introduced violation surfaces despite the baseline.
    let grown = format!("{text}\npub fn extra() -> u64 {{ SystemTime::now_stub() }}\n");
    let regressed = lint_sources(&[(rel.to_string(), grown)], &opts);
    assert_eq!(regressed.findings.len(), 1, "{:?}", regressed.findings);
    assert_eq!(regressed.findings[0].rule, "D2");
    assert!(regressed.findings[0].excerpt.contains("extra"));
}

#[test]
fn rule_filter_restricts_fixture_report() {
    let files = vec![
        ("rust/src/coordinator/a.rs".to_string(), fixture("d2_bad.rs")),
        ("rust/src/util/b.rs".to_string(), fixture("d3_bad.rs")),
    ];
    let opts = LintOptions { rule: Some("D3".to_string()), ..Default::default() };
    let out = lint_sources(&files, &opts);
    assert_eq!(rules_of(&out), vec!["D3", "D3"], "{:?}", out.findings);
}

#[test]
fn strip_pass_preserves_line_numbers() {
    // Property: whatever mix of comments, strings, raw strings, char
    // literals, and unterminated constructs the lexer sees, the stripped
    // views keep exactly one entry per input line — findings and
    // suppressions would otherwise drift off their source lines.
    let frags = [
        "let x = 1;",
        "/* open",
        "still inside */ let y = 2;",
        "let s = \"literal with // and /* inside\";",
        "let r = r#\"raw \" quote\"#;",
        "// line comment with \" quote",
        "let c = '\"';",
        "let multi = \"spans",
        "two lines\";",
        "let b = b\"bytes\";",
        "let lt: &'static str = \"x\";",
        "/* nested /* depth */ two */",
        "}",
        "{",
        "",
    ];
    check_prop("strip preserves line count", 300, |rng| {
        let n = rng.range(1, 40);
        let mut src = String::new();
        for i in 0..n {
            if i > 0 {
                src.push('\n');
            }
            src.push_str(frags[rng.below(frags.len() as u64) as usize]);
        }
        let lines = src.split('\n').count();
        let stripped = strip_source(&src);
        assert_eq!(stripped.code.len(), lines, "code lines drifted for:\n{src}");
        assert_eq!(stripped.comments.len(), lines, "comment lines drifted for:\n{src}");
        for lit in &stripped.strings {
            assert!(lit.line < lines, "literal anchored past EOF in:\n{src}");
        }
    });
}

// ---------------------------------------------------------------------------
// Token-tree parser: span fidelity + agreement with the legacy strip pass.
// ---------------------------------------------------------------------------

#[test]
fn token_spans_tile_the_source_byte_for_byte() {
    // Property: lexing partitions the input — concatenating every token's
    // span reconstructs the file exactly, whatever mix of comments,
    // strings, raw strings, and unterminated constructs it hits. Rules
    // that reason over token windows rely on this tiling.
    let frags = [
        "let x = 1;",
        "/* open",
        "still inside */ let y = 2;",
        "let s = \"literal with // and /* inside\";",
        "let r = r#\"raw \" quote\"#;",
        "// line comment with \" quote",
        "let c = '\"';",
        "let multi = \"spans",
        "two lines\";",
        "let b = b\"bytes\";",
        "let lt: &'static str = \"x\";",
        "/* nested /* depth */ two */",
        "fn f(t: Instant) -> f64 { t.elapsed().as_secs_f64() }",
        "}",
        "{",
        "",
    ];
    check_prop("token spans tile the source", 300, |rng| {
        let n = rng.range(1, 40);
        let mut src = String::new();
        for i in 0..n {
            if i > 0 {
                src.push('\n');
            }
            src.push_str(frags[rng.below(frags.len() as u64) as usize]);
        }
        let pf = ParsedFile::parse(&src);
        let mut rebuilt = String::with_capacity(src.len());
        for t in &pf.tokens {
            rebuilt.push_str(t.text(&pf.src));
        }
        assert_eq!(rebuilt, src, "token spans do not tile:\n{src}");
    });
}

#[test]
fn token_projection_agrees_with_legacy_strip_pass_tree_wide() {
    // The legacy per-line blanking pass stays in-tree as an oracle: over
    // every real source file and the whole fixture corpus, projecting the
    // token stream down to (code, comments, strings) must agree with it
    // exactly. This pins the parser swap as behavior-preserving.
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let mut files = collect_sources(root).expect("lint walk failed");
    let dir = root.join("rust/tests/lint_fixtures");
    let mut names: Vec<String> = std::fs::read_dir(&dir)
        .expect("fixture corpus dir unreadable")
        .flatten()
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .collect();
    names.sort();
    for name in &names {
        files.push((format!("rust/tests/lint_fixtures/{name}"), fixture(name)));
    }
    assert!(files.len() > 50, "sweep covers too few files: {}", files.len());
    for (rel, text) in &files {
        let pf = ParsedFile::parse(text);
        let proj = to_stripped(&pf.src, &pf.tokens);
        let legacy = strip_source(text);
        assert_eq!(proj.code, legacy.code, "code projection drifted in {rel}");
        assert_eq!(proj.comments, legacy.comments, "comment projection drifted in {rel}");
        assert_eq!(proj.strings, legacy.strings, "string literals drifted in {rel}");
    }
}

// ---------------------------------------------------------------------------
// New rule families: D7 clock-domain flow, C1/C2 calendar misuse, W1.
// ---------------------------------------------------------------------------

#[test]
fn d7_fixtures() {
    // Linted under the wall domain on purpose: D2 permits the Instant
    // reads there, so the two findings isolate the flow rule itself —
    // line 8 mixes a wall duration into sim-time arithmetic (sink B),
    // line 9 passes the tainted result to a calendar sink (sink A).
    let bad = lint_one("rust/src/server/fx.rs", &fixture("d7_bad.rs"));
    assert_eq!(rules_of(&bad), vec!["D7", "D7"], "{:?}", bad.findings);
    assert_eq!(bad.findings[0].line, 8, "{:?}", bad.findings);
    assert_eq!(bad.findings[1].line, 9, "{:?}", bad.findings);
    let good = lint_one("rust/src/server/fx.rs", &fixture("d7_good.rs"));
    assert!(good.findings.is_empty(), "{:?}", good.findings);
}

#[test]
fn c1_fixtures() {
    // Registered with to_bits, popped as a raw integer cast: exactly one
    // C1 at the decode site, reconciled across the register/match pair.
    let bad = lint_one("rust/src/coordinator/fx.rs", &fixture("c1_bad.rs"));
    assert_eq!(rules_of(&bad), vec!["C1"], "{:?}", bad.findings);
    assert_eq!(bad.findings[0].excerpt, "EventKind::DeferDeadline");
    let good = lint_one("rust/src/coordinator/fx.rs", &fixture("c1_good.rs"));
    assert!(good.findings.is_empty(), "{:?}", good.findings);
}

#[test]
fn c2_fixtures() {
    let bad = lint_one("rust/src/gateway/fx.rs", &fixture("c2_bad.rs"));
    assert_eq!(rules_of(&bad), vec!["C2", "C2"], "{:?}", bad.findings);
    assert_eq!(bad.findings[0].line, 10, "{:?}", bad.findings);
    assert_eq!(bad.findings[1].line, 14, "{:?}", bad.findings);
    // coordinator/ owns the simulation clock: the same text is fine there.
    let owner = lint_one("rust/src/coordinator/fx.rs", &fixture("c2_bad.rs"));
    assert!(owner.findings.is_empty(), "{:?}", owner.findings);
    let good = lint_one("rust/src/gateway/fx.rs", &fixture("c2_good.rs"));
    assert!(good.findings.is_empty(), "{:?}", good.findings);
}

#[test]
fn w1_fixtures() {
    let bad = lint_one("rust/src/qoe/fx.rs", &fixture("w1_bad.rs"));
    assert_eq!(rules_of(&bad), vec!["W1"], "{:?}", bad.findings);
    assert_eq!(bad.findings[0].line, 5, "{:?}", bad.findings);
    assert!(bad.findings[0].message.contains("lint:allow(D6)"), "{}", bad.findings[0].message);
    // Stale waivers must surface in both renderings.
    assert!(render_human(&bad).contains("[W1]"));
    let doc = Json::parse(&render_json(&bad)).expect("render_json must emit valid JSON");
    let rows = doc.get("findings").as_arr().expect("findings array");
    assert_eq!(rows[0].get("rule").as_str(), Some("W1"));
    // A consumed waiver is counted, not reported.
    let good = lint_one("rust/src/qoe/fx.rs", &fixture("w1_good.rs"));
    assert!(good.findings.is_empty(), "{:?}", good.findings);
    assert_eq!(good.suppressed, 1);
}

// ---------------------------------------------------------------------------
// Cross-artifact rules against synthetic artifact pairs.
// ---------------------------------------------------------------------------

#[test]
fn x2_fixtures() {
    let art = Artifacts {
        design: Some("The `model` section picks the LLM.".to_string()),
        ..Default::default()
    };
    let main = ("rust/src/main.rs".to_string(), "// --model picks the LLM".to_string());
    let bad = lint_sources_with(
        &[("rust/src/config.rs".to_string(), fixture("x2_bad.rs")), main.clone()],
        &art,
        &LintOptions::default(),
    );
    assert_eq!(rules_of(&bad), vec!["X2"], "{:?}", bad.findings);
    assert!(bad.findings[0].message.contains("`ghost_knob`"), "{}", bad.findings[0].message);
    let good = lint_sources_with(
        &[("rust/src/config.rs".to_string(), fixture("x2_good.rs")), main],
        &art,
        &LintOptions::default(),
    );
    assert!(good.findings.is_empty(), "{:?}", good.findings);
}

#[test]
fn x3_fixtures() {
    let art = Artifacts {
        roadmap: Some("andes exp ext-alpha\n".to_string()),
        ci: Some("run: andes exp ext-alpha --quick\n".to_string()),
        ..Default::default()
    };
    let bad = lint_sources_with(
        &[("rust/src/experiments/mod.rs".to_string(), fixture("x3_bad.rs"))],
        &art,
        &LintOptions::default(),
    );
    assert_eq!(rules_of(&bad), vec!["X3"], "{:?}", bad.findings);
    assert!(bad.findings[0].message.contains("`ext-ghost`"), "{}", bad.findings[0].message);
    let good = lint_sources_with(
        &[("rust/src/experiments/mod.rs".to_string(), fixture("x3_good.rs"))],
        &art,
        &LintOptions::default(),
    );
    assert!(good.findings.is_empty(), "{:?}", good.findings);
}

#[test]
fn x4_fixtures() {
    let art = Artifacts {
        design: Some("| D1 | hash iteration |".to_string()),
        fixtures: Some(vec!["d1_bad.rs".to_string(), "d1_good.rs".to_string()]),
        ..Default::default()
    };
    let bad = lint_sources_with(
        &[("rust/src/analysis/fx.rs".to_string(), fixture("x4_bad.rs"))],
        &art,
        &LintOptions::default(),
    );
    assert_eq!(rules_of(&bad), vec!["X4"], "{:?}", bad.findings);
    assert!(bad.findings[0].message.contains("z9_bad.rs"), "{}", bad.findings[0].message);
    let good = lint_sources_with(
        &[("rust/src/analysis/fx.rs".to_string(), fixture("x4_good.rs"))],
        &art,
        &LintOptions::default(),
    );
    assert!(good.findings.is_empty(), "{:?}", good.findings);
}

#[test]
fn x5_fixtures() {
    let base = "{\"benchmarks\": [\n  {\"name\": \"fixture-case/one\"},\n  \
                {\"name\": \"fixture-case/two\"}\n]}";
    let art = Artifacts {
        bench_baselines: vec![("BENCH_fx.json".to_string(), base.to_string())],
        ..Default::default()
    };
    let bad = lint_sources_with(
        &[("benches/fx.rs".to_string(), fixture("x5_bad.rs"))],
        &art,
        &LintOptions::default(),
    );
    assert_eq!(rules_of(&bad), vec!["X5"], "{:?}", bad.findings);
    assert_eq!(bad.findings[0].file, "BENCH_fx.json");
    assert!(bad.findings[0].message.contains("fixture-case/two"), "{}", bad.findings[0].message);
    let good = lint_sources_with(
        &[("benches/fx.rs".to_string(), fixture("x5_good.rs"))],
        &art,
        &LintOptions::default(),
    );
    assert!(good.findings.is_empty(), "{:?}", good.findings);
}

// ---------------------------------------------------------------------------
// Cross-artifact rules proven live against the real tree: desyncing an
// in-memory copy of the paired artifact must make the finding appear.
// ---------------------------------------------------------------------------

#[test]
fn x2_desynced_main_fires_on_the_real_tree() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let mut files = collect_sources(root).expect("lint walk failed");
    let art = load_artifacts(root);
    let main = files
        .iter_mut()
        .find(|(rel, _)| rel.as_str() == "rust/src/main.rs")
        .expect("main.rs scanned");
    assert!(main.1.contains("tiers"), "main.rs lost its `tiers` mention");
    main.1 = main.1.replace("tiers", "t_ers");
    let opts = LintOptions { rule: Some("X2".to_string()), ..Default::default() };
    let out = lint_sources_with(&files, &art, &opts);
    assert!(
        out.findings.iter().any(|f| f.rule == "X2" && f.message.contains("`tiers`")),
        "{:?}",
        out.findings
    );
}

#[test]
fn x3_desynced_ci_fires_on_the_real_tree() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let files = collect_sources(root).expect("lint walk failed");
    let mut art = load_artifacts(root);
    let ci = art.ci.take().expect("ci.yml present");
    assert!(ci.contains("ext-tiers"), "ci.yml lost its ext-tiers smoke step");
    art.ci = Some(ci.replace("ext-tiers", "ext-t_ers"));
    let opts = LintOptions { rule: Some("X3".to_string()), ..Default::default() };
    let out = lint_sources_with(&files, &art, &opts);
    assert!(
        out.findings.iter().any(|f| f.rule == "X3" && f.message.contains("`ext-tiers`")),
        "{:?}",
        out.findings
    );
}

#[test]
fn x4_desynced_fixture_corpus_fires_on_the_real_tree() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let files = collect_sources(root).expect("lint walk failed");
    let mut art = load_artifacts(root);
    let listed = art.fixtures.as_ref().is_some_and(|v| v.iter().any(|n| n == "d7_bad.rs"));
    assert!(listed, "fixture corpus lost d7_bad.rs");
    art.fixtures = art.fixtures.map(|v| v.into_iter().filter(|n| n != "d7_bad.rs").collect());
    let opts = LintOptions { rule: Some("X4".to_string()), ..Default::default() };
    let out = lint_sources_with(&files, &art, &opts);
    assert!(
        out.findings.iter().any(|f| f.rule == "X4" && f.message.contains("d7_bad.rs")),
        "{:?}",
        out.findings
    );
}

#[test]
fn x5_desynced_baseline_fires_on_the_real_tree() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let files = collect_sources(root).expect("lint walk failed");
    let mut art = load_artifacts(root);
    art.bench_baselines.push((
        "BENCH_ghost.json".to_string(),
        "{\"benchmarks\": [{\"name\": \"ghost-case/never\"}]}".to_string(),
    ));
    let opts = LintOptions { rule: Some("X5".to_string()), ..Default::default() };
    let out = lint_sources_with(&files, &art, &opts);
    assert!(
        out.findings.iter().any(|f| f.rule == "X5" && f.message.contains("ghost-case/never")),
        "{:?}",
        out.findings
    );
}

// ---------------------------------------------------------------------------
// Ratchet, --json schema, and the DESIGN.md §13 golden pin.
// ---------------------------------------------------------------------------

#[test]
fn baseline_ratchet_reports_deltas_and_refuses_growth() {
    let rel = "rust/src/coordinator/fx.rs";
    let text = fixture("d2_bad.rs");
    let committed = Baseline::from_findings(&lint_one(rel, &text).findings);

    // Shrink: the committed debt is paid down to zero, absorbed deltas
    // are reported, and the update is allowed.
    let shrink = committed.ratchet(&Baseline::from_findings(&[]));
    assert!(!shrink.grew);
    assert_eq!(shrink.rows, vec![("D2".to_string(), rel.to_string(), 2, 0)]);
    assert!(shrink.render().contains("D2 rust/src/coordinator/fx.rs: 2 -> 0"));

    // Growth: a third finding in the same (rule, file) bucket trips the
    // ratchet, which is what makes `--update-baseline` exit nonzero.
    let grown = format!("{text}\npub fn extra() -> u64 {{ SystemTime::now_stub() }}\n");
    let fresh = Baseline::from_findings(&lint_one(rel, &grown).findings);
    let grow = committed.ratchet(&fresh);
    assert!(grow.grew);
    assert!(grow.render().contains("2 -> 3"), "{}", grow.render());

    // Steady state: identical debt produces no delta rows.
    let same = committed.ratchet(&Baseline::from_findings(&lint_one(rel, &text).findings));
    assert!(!same.grew);
    assert!(same.rows.is_empty(), "{:?}", same.rows);
}

#[test]
fn lint_json_schema_is_stable() {
    // CI pipes a captured `andes lint --json` report through this test
    // via LINT_JSON; local runs regenerate the report in-process so the
    // check never silently skips.
    let text = match std::env::var("LINT_JSON") {
        Ok(path) => std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("LINT_JSON={path} unreadable: {e}")),
        Err(_) => {
            let root = Path::new(env!("CARGO_MANIFEST_DIR"));
            render_json(&lint_repo(root, &LintOptions::default()).expect("lint walk failed"))
        }
    };
    let doc = Json::parse(&text).expect("lint --json must emit valid JSON");
    let findings = doc.get("findings").as_arr().expect("findings: array");
    for f in findings {
        let rule = f.get("rule").as_str().expect("finding.rule: string");
        assert!(known_rule(rule), "finding.rule unknown: {rule}");
        assert!(f.get("file").as_str().is_some(), "finding.file: string");
        assert!(f.get("line").as_u64().is_some(), "finding.line: integer");
        assert!(f.get("excerpt").as_str().is_some(), "finding.excerpt: string");
        assert!(f.get("message").as_str().is_some(), "finding.message: string");
    }
    for row in doc.get("by_rule").as_arr().expect("by_rule: array") {
        let rule = row.get("rule").as_str().expect("by_rule.rule: string");
        assert!(known_rule(rule), "by_rule.rule unknown: {rule}");
        assert!(row.get("count").as_u64().unwrap_or(0) > 0, "by_rule rows omit zero counts");
    }
    let counters =
        ["files_scanned", "suppressed", "baselined", "declared_families", "emitted_families"];
    for key in counters {
        assert!(doc.get(key).as_u64().is_some(), "{key}: integer");
    }
}

#[test]
fn design_section_13_matches_its_golden_pin() {
    // §13 documents the rule table, the parser architecture, and the
    // --json schema; it is pinned byte-for-byte so a rules.rs change
    // cannot silently outrun its documentation. Re-bless deliberately
    // with GOLDEN_BLESS=1 after editing the section.
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let text = std::fs::read_to_string(root.join("DESIGN.md")).expect("DESIGN.md unreadable");
    let start = text.find("## §13").expect("DESIGN.md lost its §13 heading");
    let rest = &text[start..];
    let end = rest.find("\n## ").map(|p| p + 1).unwrap_or(rest.len());
    let section = &rest[..end];
    for (rule, _) in RULE_TABLE {
        assert!(section.contains(&format!("| {rule} |")), "§13 lost its {rule} table row");
    }
    check_or_bless_text(&root.join("rust/tests/golden/design_s13.golden"), section)
        .expect("DESIGN.md §13 drifted from its golden pin");
}
