//! Delivery-layer integration & property tests (DESIGN.md §11): parity
//! with the pacer-only path, client-buffer invariants, token
//! conservation on the wire, run determinism, and client-side QoE edge
//! cases.

use andes::cluster::{Cluster, RoutingPolicy};
use andes::config::SchedulerConfig;
use andes::coordinator::engine::EngineConfig;
use andes::delivery::{
    deliver_request, ClientBuffer, NetworkConfig, NetworkModel, NetworkProfile,
};
use andes::gateway::{Gateway, GatewayConfig, GatewayRunResult};
use andes::model::gpu::a100_4x;
use andes::model::latency::LatencyModel;
use andes::model::llm::opt_66b;
use andes::qoe::metric::qoe_with_ttft_penalty;
use andes::qoe::spec::QoeSpec;
use andes::util::rng::Rng;
use andes::util::testing::check_prop;
use andes::workload::{ArrivalProcess, Dataset, QoeTrace, SessionWorkload, Workload};

fn small_cluster(latency: &LatencyModel) -> Cluster {
    let ecfg = EngineConfig {
        kv_capacity_tokens: 6000,
        swap_capacity_tokens: 12_000,
        ..EngineConfig::default()
    };
    Cluster::new(2, ecfg, latency.clone(), &SchedulerConfig::Fcfs, RoutingPolicy::QoeAware)
}

// ------------------------------------------------------------- parity

#[test]
fn zero_profile_delivery_is_bit_identical_to_pacer_only_path() {
    // Satellite: with the network section absent — and with an explicit
    // zero-latency/zero-jitter profile — per-request QoE, stats, and
    // the rejection stream are bit-identical to the pacer-only path,
    // across random traces, with and without pacing/adaptive-lead.
    let latency = LatencyModel::for_deployment(&opt_66b(), &a100_4x());
    check_prop("delivery zero-profile parity", 6, |rng| {
        let n = rng.range(15, 40);
        let rate = 0.5 + rng.f64() * 6.0;
        let pacing_enabled = rng.chance(0.7);
        let adaptive = rng.chance(0.5);
        let trace = Workload {
            dataset: Dataset::ShareGpt,
            arrivals: ArrivalProcess::Poisson { rate },
            qoe_trace: QoeTrace::TextReading,
            num_requests: n,
            seed: rng.next_u64(),
        }
        .generate();
        let mut run = |network: Option<NetworkConfig>| -> GatewayRunResult {
            let mut gcfg = GatewayConfig::default();
            gcfg.pacing_enabled = pacing_enabled;
            gcfg.surge.baseline_rate = 2.0;
            if let Some(net) = network {
                gcfg.network = net;
            }
            let mut gw = Gateway::new(small_cluster(&latency), gcfg);
            gw.run_trace(trace.clone()).unwrap()
        };
        let plain = run(None);
        let zero = NetworkConfig {
            enabled: true,
            adaptive_lead: adaptive,
            ..NetworkConfig::default()
        }
        .with_mix(vec![(NetworkProfile::ideal(), 1.0)]);
        let ideal = run(Some(zero));

        assert_eq!(plain.served.len(), ideal.served.len());
        for (a, b) in plain.served.iter().zip(&ideal.served) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.raw_qoe.to_bits(), b.raw_qoe.to_bits(), "raw qoe {}", a.id);
            assert_eq!(a.paced_qoe.to_bits(), b.paced_qoe.to_bits(), "paced qoe {}", a.id);
            assert_eq!(a.raw_early_tokens, b.raw_early_tokens);
            assert_eq!(a.paced_early_tokens, b.paced_early_tokens);
            assert_eq!(a.output_tokens, b.output_tokens);
            // The zero link adds nothing on top of the server schedule.
            assert_eq!(
                b.client_qoe.to_bits(),
                b.paced_qoe.to_bits(),
                "ideal-link client qoe must equal server qoe on {}",
                a.id
            );
            // Stalls are an end-to-end playback metric: even the ideal
            // link reports underruns caused by generation gaps, so they
            // are not asserted zero here — only the link's own effects.
            assert_eq!(b.retransmits, 0);
            assert_eq!(b.disconnects, 0);
        }
        assert_eq!(plain.rejections.len(), ideal.rejections.len());
        for (a, b) in plain.rejections.iter().zip(&ideal.rejections) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.time.to_bits(), b.time.to_bits());
            assert_eq!(a.reason.label(), b.reason.label());
        }
        let (s, t) = (&plain.stats, &ideal.stats);
        assert_eq!(s.arrivals, t.arrivals);
        assert_eq!(s.admitted, t.admitted);
        assert_eq!(s.deferred, t.deferred);
        assert_eq!(s.rejected, t.rejected);
        assert_eq!(s.surge_transitions, t.surge_transitions);
        // Aggregates collapse to the pacer-only numbers.
        assert_eq!(
            ideal.mean_client_qoe().to_bits(),
            ideal.mean_served_qoe().to_bits()
        );
        assert_eq!(ideal.client_qoe_gap(), 0.0);
        assert_eq!(ideal.total_retransmits(), 0);
    });
}

// ------------------------------------------- client-buffer invariants

#[test]
fn client_buffer_invariants_under_random_links() {
    // Satellite: tokens replay in order exactly once, nothing digests
    // before its client arrival, stall time is zero whenever delivery
    // stays ahead of the digestion curve, and the wire conserves tokens
    // (sent == delivered + in-flight + lost-pending-retransmit) at
    // every probe instant — across random jitter/loss/disconnect links.
    check_prop("client buffer invariants", 60, |rng| {
        let profile = NetworkProfile {
            name: "random",
            base_latency: rng.f64() * 0.1,
            jitter_mean: rng.f64() * 0.4,
            loss_prob: rng.f64() * 0.1,
            retransmit_delay: 0.05 + rng.f64() * 0.3,
            disconnect_rate: if rng.chance(0.5) { rng.f64() * 0.2 } else { 0.0 },
            disconnect_mean: 0.2 + rng.f64() * 2.0,
        };
        let spec = QoeSpec::new(rng.f64() * 2.0, 1.0 + rng.f64() * 8.0);
        let n = rng.range(1, 120);
        let mut releases = Vec::with_capacity(n);
        let mut t = 0.0;
        for _ in 0..n {
            t += rng.f64() * 0.5;
            releases.push(t);
        }
        let mut net = NetworkModel::new(profile, Rng::new(rng.next_u64()));
        let mut buf = ClientBuffer::new(&spec);
        let mut prev = f64::NEG_INFINITY;
        for &r in &releases {
            let tr = net.send(r);
            assert!(tr.arrived_at >= r, "token arrived before its release");
            assert!(tr.arrived_at >= prev, "reordered delivery");
            prev = tr.arrived_at;
            buf.receive(tr.arrived_at);
            // In order, exactly once: the digest curve has seen every
            // received token and nothing else.
            assert_eq!(buf.digest().delivered(), buf.received() as f64);
            assert!(
                buf.digest().digested() <= buf.digest().delivered() + 1e-9,
                "digestion ran ahead of delivery"
            );
        }
        assert_eq!(buf.received(), n, "exactly-once replay");
        // Conservation partition at random probe instants.
        for _ in 0..20 {
            let probe = rng.f64() * (prev + 1.0);
            let sent_by = releases.iter().filter(|&&s| s <= probe).count();
            let (d, f, l) = net.census_at(probe);
            assert_eq!(d + f + l, sent_by, "wire conservation at t={probe}");
        }
        let (d, _, _) = net.census_at(f64::INFINITY);
        assert_eq!(d, n, "every token eventually delivers");
        // Stall-free whenever delivery stays (strictly) ahead of the
        // digestion ramp anchored at the first arrival.
        let arrivals: Vec<f64> = net.transits().iter().map(|tr| tr.arrived_at).collect();
        let a0 = arrivals[0];
        let strictly_ahead = arrivals
            .iter()
            .enumerate()
            .all(|(i, &a)| i == 0 || a <= a0 + i as f64 / spec.tds - 1e-9);
        if strictly_ahead {
            assert_eq!(buf.stall_count(), 0, "delivery ahead of digestion yet stalled");
            assert_eq!(buf.stall_time(), 0.0);
        }
    });
}

#[test]
fn burst_delivery_never_stalls() {
    // Constructive anchor for the stall invariant: everything arrives
    // at once, so delivery is always ahead and playback never waits.
    let spec = QoeSpec::new(1.0, 4.0);
    let mut buf = ClientBuffer::new(&spec);
    for _ in 0..50 {
        buf.receive(2.0);
    }
    assert_eq!(buf.stall_count(), 0);
    assert_eq!(buf.stall_time(), 0.0);
}

// -------------------------------------------------------- determinism

#[test]
fn ext_network_summary_is_byte_identical_across_runs() {
    // Satellite: same seed ⇒ byte-identical ext-network summary across
    // two in-process runs (all grid randomness flows from fixed seeds).
    let a = andes::experiments::network::run_grid(40, None).unwrap();
    let b = andes::experiments::network::run_grid(40, None).unwrap();
    assert_eq!(a, b, "ext-network grid must be deterministic");
    assert!(a.contains("shape checks"), "summary must include the verdicts");
}

#[test]
fn session_workload_with_network_is_deterministic() {
    // Pins the whole RNG plumbing: SessionWorkload → arrivals → network
    // draws. Two in-process runs must agree to the last bit.
    let latency = LatencyModel::for_deployment(&opt_66b(), &a100_4x());
    let run = || -> String {
        let trace = SessionWorkload {
            num_sessions: 15,
            arrivals: ArrivalProcess::Poisson { rate: 1.0 },
            qoe_trace: QoeTrace::TextReading,
            min_turns: 2,
            max_turns: 4,
            think_time_mean: 3.0,
            seed: 7,
        }
        .generate();
        let mut gcfg = GatewayConfig::default();
        gcfg.surge.baseline_rate = 2.0;
        gcfg.network.enabled = true;
        gcfg.network.adaptive_lead = true;
        gcfg.network =
            gcfg.network.clone().with_mix(vec![(NetworkProfile::lte(), 1.0)]);
        let mut gw = Gateway::new(small_cluster(&latency), gcfg);
        let res = gw.run_trace(trace).unwrap();
        let mut out = String::new();
        for s in &res.served {
            out.push_str(&format!(
                "{}:{:x}:{:x}:{}:{}:{}\n",
                s.id,
                s.client_qoe.to_bits(),
                s.stall_time.to_bits(),
                s.stall_count,
                s.retransmits,
                s.disconnects,
            ));
        }
        out
    };
    assert_eq!(run(), run());
}

// -------------------------------------------------- client-side edges

#[test]
fn disconnect_spanning_expected_ttft_boundary() {
    // Satellite: a disconnect episode that straddles the expected-TTFT
    // instant pushes the *client's* first token past the deadline even
    // though the server released it on time — the TTFT penalty must
    // bite on the client timeline and stay inert on the server one.
    let spec = QoeSpec::new(1.0, 4.0);
    let profile = NetworkProfile {
        disconnect_rate: 2.0,
        disconnect_mean: 3.0,
        jitter_mean: 0.0,
        loss_prob: 0.0,
        base_latency: 0.0,
        ..NetworkProfile::lte()
    };
    // Find a seed whose first episode covers the release at t=0.9 and
    // ends past the expected TTFT of 1.0 (deterministic thereafter).
    let mut found = None;
    for seed in 0..200u64 {
        let mut net = NetworkModel::new(profile, Rng::new(seed));
        let tr = net.send(0.9);
        if tr.disconnect_wait > 0.0 && tr.arrived_at > spec.ttft + 0.5 {
            found = Some((seed, tr));
            break;
        }
    }
    let (seed, first) = found.expect("an episode straddling t=0.9 must exist in 200 seeds");
    // Replay the full stream on that seed through the client buffer.
    let mut net = NetworkModel::new(profile, Rng::new(seed));
    let mut buf = ClientBuffer::new(&spec);
    let releases: Vec<f64> = (0..12).map(|i| 0.9 + i as f64 * 0.25).collect();
    let mut first_arrival = None;
    for &r in &releases {
        let tr = net.send(r);
        if first_arrival.is_none() {
            first_arrival = Some(tr.arrived_at);
        }
        buf.receive(tr.arrived_at);
    }
    let client_ttft = first_arrival.unwrap();
    assert_eq!(client_ttft, first.arrived_at);
    assert!(client_ttft > spec.ttft, "the disconnect must push TTFT past expected");
    let horizon = buf.digest().digest_end().max(client_ttft + 1.0);
    let cap = Some(releases.len() as f64);
    let base = qoe_with_ttft_penalty(&spec, buf.digest(), horizon, cap, 1.0, Some(client_ttft));
    let penalized =
        qoe_with_ttft_penalty(&spec, buf.digest(), horizon, cap, 0.5, Some(client_ttft));
    let lateness = client_ttft - spec.ttft;
    let expect = 0.5f64.powf(lateness) * base;
    assert!(
        (penalized - expect).abs() < 1e-9,
        "penalty must follow the client-side lateness: {penalized} vs {expect}"
    );
    // A server-side observer (on-time release at 0.9) sees no penalty.
    let server =
        qoe_with_ttft_penalty(&spec, buf.digest(), horizon, cap, 0.5, Some(releases[0]));
    assert_eq!(server, base, "server-side TTFT was on time");
}

#[test]
fn zero_length_response_is_perfect_on_any_link() {
    // Satellite edge: an empty stream has nothing to deliver — QoE 1,
    // no stalls, regardless of link quality or adaptive mode.
    for adaptive in [false, true] {
        let cfg = NetworkConfig {
            enabled: true,
            adaptive_lead: adaptive,
            ..NetworkConfig::default()
        }
        .with_mix(vec![(NetworkProfile::lte(), 1.0)]);
        let out = deliver_request(
            &QoeSpec::new(1.0, 4.8),
            true,
            &andes::gateway::PacingConfig::default(),
            &cfg,
            3,
            &[],
        );
        assert_eq!(out.client_qoe, 1.0);
        assert_eq!(out.stall_count, 0);
        assert_eq!(out.retransmits, 0);
    }
}

// ------------------------------------------------- adaptive-lead story

#[test]
fn adaptive_lead_cuts_stalls_on_jittery_links() {
    // The tentpole's control-law claim, as a direct test: across many
    // seeded lte links, the adaptive lead must strictly reduce total
    // stall time versus the static lead, and never lose client QoE on
    // aggregate.
    let spec = QoeSpec::new(1.0, 4.8);
    let pacing = andes::gateway::PacingConfig { rate_factor: 1.0, lead_tokens: 4 };
    let gen: Vec<f64> = vec![0.5; 250]; // a long overfast stream
    let mk = |adaptive: bool| {
        NetworkConfig { enabled: true, adaptive_lead: adaptive, ..NetworkConfig::default() }
            .with_mix(vec![(NetworkProfile::lte(), 1.0)])
    };
    let (mut stall_static, mut stall_adaptive) = (0.0f64, 0.0f64);
    let (mut qoe_static, mut qoe_adaptive) = (0.0f64, 0.0f64);
    for id in 0..40 {
        let s = deliver_request(&spec, true, &pacing, &mk(false), id, &gen);
        let a = deliver_request(&spec, true, &pacing, &mk(true), id, &gen);
        stall_static += s.stall_time;
        stall_adaptive += a.stall_time;
        qoe_static += s.client_qoe;
        qoe_adaptive += a.client_qoe;
        assert!(a.final_lead >= pacing.lead_tokens);
    }
    assert!(stall_static > 0.0, "the static lead must stall on lte jitter");
    assert!(
        stall_adaptive < stall_static,
        "adaptive lead must strictly cut stall time ({stall_adaptive:.2}s vs \
         {stall_static:.2}s)"
    );
    // The two modes consume the per-link RNG streams differently (the
    // episode timeline is probed at different instants), so compare on
    // aggregate with a small tolerance rather than pointwise.
    assert!(
        qoe_adaptive >= qoe_static - 1e-3,
        "adaptive lead must not lose client QoE ({qoe_adaptive:.4} vs {qoe_static:.4})"
    );
}
