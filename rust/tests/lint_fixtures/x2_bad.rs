//! Config parsing with a key (`ghost_knob`) that neither the CLI nor
//! DESIGN.md mentions — X2 fires when this file is linted as
//! `rust/src/config.rs` against an artifact set lacking the key.

pub fn parse(j: &Json) -> Config {
    let mut c = Config::default();
    if let Some(v) = j.get("model").as_str() {
        c.model = v.to_string();
    }
    if let Some(v) = j.get("ghost_knob").as_f64() {
        c.ghost_knob = v;
    }
    c
}
