//! Known-good D3 fixture: total_cmp for ordering; a partial_cmp whose
//! None case is handled explicitly is fine.
use std::cmp::Ordering;

pub fn rank(mut xs: Vec<f64>) -> Vec<f64> {
    xs.sort_by(|a, b| a.total_cmp(b));
    xs
}

pub fn strictly_less(a: f64, b: f64) -> bool {
    matches!(a.partial_cmp(&b), Some(Ordering::Less))
}
