//! Known-good D1 fixture: point lookups on a hash map are fine, ordered
//! iteration goes through a BTreeMap, and a foreign receiver that merely
//! shares a declared field's name must not fire.
use std::collections::{BTreeMap, HashMap};

pub struct Index {
    counts: HashMap<String, usize>,
    ordered: BTreeMap<String, usize>,
}

impl Index {
    pub fn get(&self, k: &str) -> Option<usize> {
        self.counts.get(k).copied()
    }

    pub fn put(&mut self, k: String, v: usize) {
        self.counts.insert(k.clone(), v);
        self.ordered.insert(k, v);
    }

    pub fn dump(&self) -> Vec<String> {
        self.ordered.iter().map(|(k, v)| format!("{k}={v}")).collect()
    }
}

pub struct View {
    pub counts: Vec<usize>,
}

pub fn scan(view: &View) -> usize {
    view.counts.iter().sum()
}
