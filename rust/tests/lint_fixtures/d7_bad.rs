//! Wall-clock readings flowing into simulated time: a wall `Instant`
//! is bound, converted, and mixed into sim-clock arithmetic (D7 sink B),
//! then passed into a calendar registration (D7 sink A).

pub fn schedule_retry(sim_now: f64, cal: &mut EventCalendar) -> f64 {
    let t0 = std::time::Instant::now();
    let dt = t0.elapsed();
    let due = sim_now + dt.as_secs_f64();
    cal.register(due, EventKind::DeferDeadline, 0);
    due
}
