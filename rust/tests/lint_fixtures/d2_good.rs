//! Known-good D2 fixture: time flows through the injected sim clock.

pub trait Clock {
    fn now(&self) -> f64;
}

pub fn stamp(clock: &dyn Clock) -> f64 {
    clock.now()
}
