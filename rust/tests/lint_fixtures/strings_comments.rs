//! False-positive corpus: every forbidden token below is inert because
//! it sits inside a comment or a string literal. A lexer that fails to
//! strip any of these produces findings and fails the fixture test.

// Instant::now() thread_rng() println!("x") partial_cmp(a).unwrap()
/* block comment: for k in counts.keys() { SystemTime::now(); }
   nested /* still a comment: xs.sort_by(|a, b| a.partial_cmp(b).unwrap()) */
   tail */

pub fn docs() -> &'static str {
    "Instant::now SystemTime thread_rng println! .unwrap() counts.iter()"
}

pub fn raw() -> &'static str {
    r#"sort_by(|a, b| a.partial_cmp(b).unwrap()) and "quoted" eprintln!"#
}

pub fn bytes() -> &'static [u8] {
    b"from_entropy() dbg!(x) .expect(msg)"
}

pub fn tricky_char() -> char {
    '"'
}
