//! Known-bad D5 fixture: direct prints in library code.

pub fn report(value: f64) {
    println!("value = {value}");
    eprintln!("warning: value observed");
}
