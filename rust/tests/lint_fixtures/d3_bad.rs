//! Known-bad D3 fixture: partial_cmp feeding sorts and unwraps,
//! including the rustfmt-wrapped form where the unwrap lands on the
//! next line.

pub fn rank(mut xs: Vec<f64>) -> Vec<f64> {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs
}

pub fn worst(xs: &[f64]) -> Option<&f64> {
    xs.iter().max_by(|a, b| {
        a.partial_cmp(b)
            .unwrap()
    })
}
