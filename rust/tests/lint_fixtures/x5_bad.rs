//! A bench source that has dropped a case (`fixture-case/two`) still
//! recorded in its committed baseline — X5 fires on the stale baseline
//! entry when the two are checked together.

fn main() {
    let mut b = Bencher::new();
    b.bench("fixture-case/one", || 1);
}
