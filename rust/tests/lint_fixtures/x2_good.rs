//! Config parsing whose every top-level key is mentioned by both the
//! CLI and DESIGN.md — X2 stays silent.

pub fn parse(j: &Json) -> Config {
    let mut c = Config::default();
    if let Some(v) = j.get("model").as_str() {
        c.model = v.to_string();
    }
    c
}
