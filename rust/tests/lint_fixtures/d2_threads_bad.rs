//! Known-bad D2 fixture: wall-clock reads inside shard worker threads.
//! A spawned worker closure is still simulation code — a timestamp
//! taken on a worker depends on thread scheduling and breaks replay.

pub fn run_grid(cells: &[u64]) -> Vec<(u64, f64)> {
    let mut out = Vec::new();
    std::thread::scope(|scope| {
        let (tx, rx) = std::sync::mpsc::channel();
        for &cell in cells {
            let tx = tx.clone();
            scope.spawn(move || {
                let t0 = std::time::Instant::now();
                let _ = tx.send((cell, t0.elapsed().as_secs_f64()));
            });
        }
        drop(tx);
        for pair in rx {
            out.push(pair);
        }
    });
    out.sort_by(|a, b| a.0.cmp(&b.0));
    out
}

pub fn merge_stamp() -> u64 {
    std::time::SystemTime::now().elapsed().map(|d| d.as_secs()).unwrap_or(0)
}
