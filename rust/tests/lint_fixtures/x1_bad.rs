//! Known-bad X1 fixture: one family declared but never emitted, one
//! emitted but never declared.

pub fn declare_base_families(reg: &mut Registry) {
    reg.declare_counter("andes_declared_only_total", "never emitted anywhere");
    reg.declare_counter("andes_used_total", "declared and emitted");
}

pub fn tick(reg: &mut Registry) {
    reg.inc("andes_used_total", &[]);
    reg.inc("andes_ghost_total", &[]);
}
