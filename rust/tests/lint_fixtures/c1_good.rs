//! Calendar payload round-trip: the deadline is registered through
//! `f64::to_bits` and decoded with `f64::from_bits` at the pop site.

pub fn arm(cal: &mut EventCalendar, deadline: f64) {
    cal.register(deadline, EventKind::DeferDeadline, deadline.to_bits());
}

pub fn fire(cal: &mut EventCalendar) -> f64 {
    match cal.pop() {
        Some(w) => match w.kind {
            EventKind::DeferDeadline => f64::from_bits(w.payload),
            _ => 0.0,
        },
        None => 0.0,
    }
}
