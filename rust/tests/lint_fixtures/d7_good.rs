//! Wall-clock used only for operator-facing profiling: the reading is
//! converted and accumulated into a wall-side metric, never mixed with
//! sim-time values or passed to a sim-path call.

pub fn profile_step(metrics: &mut StepMetrics) {
    let t0 = std::time::Instant::now();
    run_scheduler_once();
    metrics.sched_seconds += t0.elapsed().as_secs_f64();
}
