//! Known-bad D2 fixture: wall-clock reads outside the wall domain.

pub fn stamp() -> f64 {
    let t0 = std::time::Instant::now();
    t0.elapsed().as_secs_f64()
}

pub fn epoch() -> u64 {
    std::time::SystemTime::now().elapsed().map(|d| d.as_secs()).unwrap_or(0)
}
