//! Known-good D2 fixture: shard workers report *simulated* time; any
//! wall-clock measurement stays with the caller in the wall domain.

pub fn run_grid(cells: &[u64], sim_now: f64) -> Vec<(u64, f64)> {
    let mut out = Vec::new();
    std::thread::scope(|scope| {
        let (tx, rx) = std::sync::mpsc::channel();
        for &cell in cells {
            let tx = tx.clone();
            scope.spawn(move || {
                let finished_at = sim_now + cell as f64 * 0.5;
                let _ = tx.send((cell, finished_at));
            });
        }
        drop(tx);
        for pair in rx {
            out.push(pair);
        }
    });
    out.sort_by(|a, b| a.0.cmp(&b.0));
    out
}
