//! Known-good D4 fixture: explicit seeds only.
use crate::util::rng::Rng;

pub fn roll(seed: u64) -> u64 {
    let mut rng = Rng::new(seed);
    rng.next_u64()
}
