//! Known-bad D1 fixture: hash-order iteration feeding output.
use std::collections::HashMap;

pub struct Index {
    counts: HashMap<String, usize>,
}

impl Index {
    pub fn dump(&self) -> Vec<String> {
        let mut out = Vec::new();
        for (k, v) in self.counts.iter() {
            out.push(format!("{k}={v}"));
        }
        out
    }

    pub fn total(&self) -> usize {
        self.counts.values().sum()
    }
}
