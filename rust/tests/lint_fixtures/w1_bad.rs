//! A stale waiver: the directive below no longer matches any finding on
//! the line it covers, so the linter reports it as W1.

pub fn safe_head(xs: &[u64]) -> u64 {
    // lint:allow(D6, kept after the unwrap below was replaced)
    xs.first().copied().unwrap_or(0)
}
