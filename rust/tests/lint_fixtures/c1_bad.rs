//! Calendar payload mismatch: the deadline is registered through
//! `f64::to_bits`, but the pop site reads the payload raw instead of
//! decoding it with `from_bits`.

pub fn arm(cal: &mut EventCalendar, deadline: f64) {
    cal.register(deadline, EventKind::DeferDeadline, deadline.to_bits());
}

pub fn fire(cal: &mut EventCalendar) -> f64 {
    match cal.pop() {
        Some(w) => match w.kind {
            EventKind::DeferDeadline => w.payload as f64,
            _ => 0.0,
        },
        None => 0.0,
    }
}
