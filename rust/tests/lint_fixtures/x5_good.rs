//! A bench source still defining every case its committed baseline
//! records — X5 stays silent.

fn main() {
    let mut b = Bencher::new();
    b.bench("fixture-case/one", || 1);
    b.bench("fixture-case/two", || 2);
}
