//! Direct sim-clock mutation outside `coordinator/`: the pacer advances
//! its own copy of `now` instead of going through the engine clock.

pub struct Pacer {
    pub now: f64,
}

impl Pacer {
    pub fn tick(&mut self, dt: f64) {
        self.now += dt;
    }

    pub fn reset(&mut self) {
        self.now = 0.0;
    }
}
