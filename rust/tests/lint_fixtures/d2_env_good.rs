//! Known-good D2 fixture (env-var case): the trace toggle is gated on the
//! logger instead of re-reading the process environment on the hot path.

pub fn trace_enabled() -> bool {
    log::log_enabled!(log::Level::Debug)
}
