//! An experiment registry whose every `ext-*` id has a CI smoke step
//! and a ROADMAP quickstart line — X3 stays silent.

pub fn registry() -> Vec<Exp> {
    vec![
        Exp { id: "ext-alpha", title: "covered everywhere" },
    ]
}
