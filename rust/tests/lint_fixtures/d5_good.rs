//! Known-good D5 fixture: library code logs through `log::`; a print
//! inside a #[cfg(test)] module is test-only output and out of scope.

pub fn report(value: f64) {
    log::info!("value = {value}");
}

#[cfg(test)]
mod tests {
    #[test]
    fn prints_are_fine_in_tests() {
        println!("test diagnostics are allowed");
    }
}
