//! Known-bad D4 fixture: entropy-seeded randomness is unreproducible.

pub fn roll() -> u64 {
    let mut rng = rand::thread_rng();
    rng.gen()
}

pub fn seed_rng() -> SmallRng {
    SmallRng::from_entropy()
}
