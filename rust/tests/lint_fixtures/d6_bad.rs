//! Known-bad D6 fixture: bare unwrap/expect in a simulation path.

pub fn pick(xs: &[f64]) -> f64 {
    let first = xs.first().unwrap();
    let last = xs.last().expect("non-empty");
    first + last
}
