//! A rule table declaring a ghost rule id (`Z9`) that has no fixture
//! pair and no DESIGN.md row — X4 fires on the declaration line.

pub const RULE_TABLE: &[(&str, &str)] = &[
    ("D1", "hash-map iteration in metric lookups"),
    ("Z9", "ghost rule with no fixtures and no docs row"),
];
