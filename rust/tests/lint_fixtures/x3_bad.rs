//! An experiment registry with an `ext-*` id that has no CI smoke step
//! and no ROADMAP quickstart line — X3 fires when this file is linted
//! as `rust/src/experiments/mod.rs`.

pub fn registry() -> Vec<Exp> {
    vec![
        Exp { id: "ext-alpha", title: "covered everywhere" },
        Exp { id: "ext-ghost", title: "absent from ci.yml and ROADMAP" },
    ]
}
