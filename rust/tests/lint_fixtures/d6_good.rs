//! Known-good D6 fixture: fallible paths surface errors instead of
//! panicking mid-experiment.

pub fn pick(xs: &[f64]) -> Option<f64> {
    let first = xs.first()?;
    let last = xs.last()?;
    Some(first + last)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unwrap_is_fine_in_tests() {
        assert_eq!(pick(&[1.0, 2.0]).unwrap(), 3.0);
    }
}
