//! Suppression fixture: each violation below carries a reasoned inline
//! waiver, so the file lints clean. A directive on its own comment line
//! covers the next line; a trailing comment covers its own line; one
//! comment may carry several directives.

pub fn profile() -> f64 {
    // lint:allow(D2, this fixture models a wall-domain profiling helper)
    let t0 = std::time::Instant::now();
    t0.elapsed().as_secs_f64()
}

pub fn rank(mut xs: Vec<f64>) -> Vec<f64> {
    // lint:allow(D3, callers pre-filter NaN) lint:allow(D6, same contract)
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs
}

pub fn head(xs: &[f64]) -> f64 {
    *xs.first().unwrap() // lint:allow(D6, callers guarantee a non-empty slice)
}
