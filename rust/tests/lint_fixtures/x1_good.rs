//! Known-good X1 fixture: the declared and emitted family sets match.

pub fn declare_base_families(reg: &mut Registry) {
    reg.declare_counter("andes_used_total", "declared and emitted");
    reg.declare_gauge("andes_depth", "declared and emitted");
}

pub fn tick(reg: &mut Registry) {
    reg.inc("andes_used_total", &[]);
    reg.set_gauge("andes_depth", &[], 1.0);
}
