//! A live waiver: the directive is consumed by the D6 finding on the
//! next line, so the finding is suppressed and no W1 is reported.

pub fn head(xs: &[u64]) -> u64 {
    // lint:allow(D6, demo fixture: callers guarantee a non-empty slice)
    *xs.first().unwrap()
}
