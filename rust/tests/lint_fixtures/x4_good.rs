//! A rule table whose only id has its fixture pair and its DESIGN.md
//! row — X4 stays silent.

pub const RULE_TABLE: &[(&str, &str)] = &[
    ("D1", "hash-map iteration in metric lookups"),
];
