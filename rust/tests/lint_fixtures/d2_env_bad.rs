//! Known-bad D2 fixture (env-var case): environment reads on a sim path.

pub fn trace_enabled() -> bool {
    std::env::var("ANDES_TRACE_CAP").is_ok()
}

pub fn trace_dir() -> Option<std::ffi::OsString> {
    std::env::var_os("ANDES_TRACE_DIR")
}
