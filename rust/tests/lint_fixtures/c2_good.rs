//! Sim-time reads that must not trip C2: comparisons, field reads,
//! `let` bindings, and parameter names all mention `now` without
//! mutating a clock.

pub struct Pacer {
    pub now: f64,
}

impl Pacer {
    pub fn due(&self, now: f64, deadline: f64) -> bool {
        now >= deadline && self.now <= now
    }

    pub fn shifted(&self, dt: f64) -> f64 {
        let now = self.now + dt;
        now
    }
}
