//! Text-streaming service frontend.
//!
//! A std-net TCP server speaking newline-delimited JSON (no tokio in the
//! offline environment; threads + channels instead):
//!
//! ```text
//! → {"prompt": "...", "max_tokens": 64, "ttft": 1.0, "tds": 4.8}
//! ← {"event":"token","text":"...","index":0}           (streamed)
//! ← {"event":"done","tokens":42,"ttft":0.18,"qoe":1.0}
//! ```
//!
//! Architecture: one engine thread owns the PJRT model (the xla client
//! is not Send) and runs the continuous-batching loop; connection
//! threads submit requests through an mpsc channel and receive token
//! events through per-request channels. The client-side token buffer
//! (paper §5) lives in [`crate::qoe::buffer`] and is exercised by the
//! example clients.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::backend::pjrt::PjrtBackend;
use crate::backend::WallClock;
use crate::coordinator::engine::{Engine, EngineConfig};
use crate::coordinator::request::RequestId;
use crate::coordinator::sched::andes::AndesScheduler;
use crate::model::gpu::a100_1x;
use crate::model::latency::LatencyModel;
use crate::model::llm::tiny_opt;
use crate::qoe::spec::QoeSpec;
use crate::runtime::engine::ModelRuntime;
use crate::runtime::tokenizer::ByteTokenizer;
use crate::runtime::Sampling;
use crate::util::json::Json;
use crate::workload::RequestSpec;

/// A request submitted by a connection thread.
struct Submission {
    prompt: Vec<u32>,
    max_tokens: usize,
    qoe: QoeSpec,
    /// Channel for token events back to the connection.
    events: Sender<Event>,
}

/// Streamed event.
#[derive(Debug, Clone)]
pub enum Event {
    Token { index: usize, token: u32 },
    Done { tokens: usize, ttft: f64, qoe: f64 },
}

/// Server configuration.
pub struct ServerConfig {
    pub addr: String,
    pub kv_capacity_tokens: usize,
    pub max_output_tokens: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7878".into(),
            kv_capacity_tokens: 2048,
            max_output_tokens: 128,
        }
    }
}

/// Engine thread: owns the model, pulls submissions, streams events.
fn engine_loop(cfg: ServerConfig, rx: Receiver<Submission>) -> Result<()> {
    let runtime = ModelRuntime::load(&ModelRuntime::default_dir())
        .context("loading artifacts (run `make artifacts`)")?;
    let backend = PjrtBackend::new(runtime, Sampling::TopK { k: 40, temperature: 1.0 }, 1234);
    let engine_cfg = EngineConfig {
        kv_capacity_tokens: cfg.kv_capacity_tokens,
        swap_capacity_tokens: cfg.kv_capacity_tokens * 4,
        max_output_tokens: cfg.max_output_tokens,
        ..EngineConfig::default()
    };
    let latency = LatencyModel::for_deployment(&tiny_opt(), &a100_1x());
    let mut engine = Engine::new(
        engine_cfg,
        backend,
        WallClock::new(),
        Box::new(AndesScheduler::with_defaults()),
        latency,
    );

    let mut sinks: HashMap<RequestId, Sender<Event>> = HashMap::new();
    let mut delivered: HashMap<RequestId, usize> = HashMap::new();
    let mut reported = 0usize; // finished requests already notified
    loop {
        // Drain new submissions (block briefly when idle).
        let first = if engine.has_work() {
            rx.try_recv().ok()
        } else {
            match rx.recv_timeout(std::time::Duration::from_millis(50)) {
                Ok(s) => Some(s),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => None,
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => return Ok(()),
            }
        };
        let mut incoming = Vec::new();
        if let Some(s) = first {
            incoming.push(s);
        }
        while let Ok(s) = rx.try_recv() {
            incoming.push(s);
        }
        for sub in incoming {
            let spec = RequestSpec {
                id: 0, // engine assigns
                arrival: 0.0,
                prompt_tokens: sub.prompt.len(),
                output_tokens: sub.max_tokens,
                qoe: sub.qoe,
            };
            match engine.submit_with_prompt(spec, sub.prompt) {
                Ok(id) => {
                    sinks.insert(id, sub.events);
                    delivered.insert(id, 0);
                }
                Err(e) => {
                    let _ = sub.events.send(Event::Done { tokens: 0, ttft: f64::NAN, qoe: 0.0 });
                    log::warn!("rejected request: {e:#}");
                }
            }
        }

        if engine.has_work() {
            engine.tick()?;
            // Push newly generated tokens to their sinks.
            let ids: Vec<RequestId> = sinks.keys().copied().collect();
            for id in ids {
                let req = &engine.requests()[id];
                let have = req.generated;
                let sent = delivered.get_mut(&id).unwrap();
                if have > *sent {
                    if let Some(tokens) = engine.backend().generated(id) {
                        for (idx, &tok) in tokens.iter().enumerate().take(have).skip(*sent) {
                            let _ = sinks[&id].send(Event::Token { index: idx, token: tok });
                        }
                    }
                    *sent = have;
                }
            }
            // Report finishes.
            let metrics = engine.metrics();
            while reported < metrics.requests.len() {
                let r = &metrics.requests[reported];
                if let Some(sink) = sinks.remove(&r.id) {
                    let _ = sink.send(Event::Done {
                        tokens: r.output_tokens,
                        ttft: r.ttft,
                        qoe: r.final_qoe,
                    });
                }
                delivered.remove(&r.id);
                reported += 1;
            }
        }
    }
}

fn handle_conn(stream: TcpStream, tx: Sender<Submission>) {
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
    let tokenizer = ByteTokenizer::new();
    let reader = BufReader::new(stream.try_clone().expect("clone stream"));
    let mut writer = stream;
    for line in reader.lines() {
        let line = match line {
            Ok(l) if !l.trim().is_empty() => l,
            Ok(_) => continue,
            Err(_) => break,
        };
        let req = match Json::parse(&line) {
            Ok(j) => j,
            Err(e) => {
                let _ = writeln!(writer, r#"{{"event":"error","message":"bad json: {e}"}}"#);
                continue;
            }
        };
        let prompt_text = req.get("prompt").as_str().unwrap_or("").to_string();
        if prompt_text.is_empty() {
            let _ = writeln!(writer, r#"{{"event":"error","message":"missing prompt"}}"#);
            continue;
        }
        let max_tokens = req.get("max_tokens").as_u64().unwrap_or(64) as usize;
        let ttft = req.get("ttft").as_f64().unwrap_or(1.0);
        let tds = req.get("tds").as_f64().unwrap_or(4.8);
        let (etx, erx) = channel();
        if tx
            .send(Submission {
                prompt: tokenizer.encode(&prompt_text),
                max_tokens,
                qoe: QoeSpec::new(ttft.max(0.0), tds.max(0.1)),
                events: etx,
            })
            .is_err()
        {
            let _ = writeln!(writer, r#"{{"event":"error","message":"engine gone"}}"#);
            break;
        }
        // Stream events for this request until Done.
        for ev in erx {
            let out = match ev {
                Event::Token { index, token } => {
                    let text = tokenizer.decode_one(token);
                    Json::obj(vec![
                        ("event", "token".into()),
                        ("index", (index as u64).into()),
                        ("text", text.into()),
                    ])
                }
                Event::Done { tokens, ttft, qoe } => {
                    let j = Json::obj(vec![
                        ("event", "done".into()),
                        ("tokens", (tokens as u64).into()),
                        ("ttft", ttft.into()),
                        ("qoe", qoe.into()),
                    ]);
                    let _ = writeln!(writer, "{j}");
                    break;
                }
            };
            if writeln!(writer, "{out}").is_err() {
                break;
            }
        }
    }
    log::info!("connection {peer} closed");
}

/// Run the server (blocks). `ready` is signalled with the bound address
/// once listening — used by tests and examples.
pub fn serve(cfg: ServerConfig, ready: Option<Sender<String>>) -> Result<()> {
    let listener = TcpListener::bind(&cfg.addr)
        .with_context(|| format!("binding {}", cfg.addr))?;
    let local = listener.local_addr()?.to_string();
    log::info!("andes serving on {local}");
    if let Some(r) = ready {
        let _ = r.send(local);
    }
    let (tx, rx) = channel::<Submission>();
    let engine_handle = std::thread::spawn(move || {
        if let Err(e) = engine_loop(cfg, rx) {
            eprintln!("engine thread error: {e:#}");
        }
    });
    let tx = Arc::new(tx);
    for stream in listener.incoming() {
        match stream {
            Ok(s) => {
                let tx = Sender::clone(&tx);
                std::thread::spawn(move || handle_conn(s, tx));
            }
            Err(e) => log::warn!("accept error: {e}"),
        }
    }
    drop(tx);
    let _ = engine_handle.join();
    Ok(())
}
