//! Text-streaming service frontend.
//!
//! A std-net TCP server speaking newline-delimited JSON (no tokio in the
//! offline environment; threads + channels instead):
//!
//! ```text
//! → {"prompt": "...", "max_tokens": 64, "ttft": 1.0, "tds": 4.8}
//! → {"prompt": "...", "session": 7, "turn": 1}     (multi-turn client)
//! ← {"event":"token","text":"...","index":0}           (streamed, paced)
//! ← {"event":"done","tokens":42,"ttft":0.18,"qoe":1.0}
//! ← {"event":"rejected","reason":"surge-shed","detail":"..."}
//! ```
//!
//! Clients resuming a conversation send `session` (a stable numeric
//! session key) and `turn` (0-based); the tags flow into the request
//! records. KV prefix retention itself (DESIGN.md §10) is a
//! simulation-tier feature — the PJRT backend has no prefix cache, so
//! `--park-prefixes` is advisory here (see `engine_loop`).
//!
//! Architecture: one engine thread owns the execution backend (the
//! PJRT xla client is not Send) and runs the continuous-batching loop;
//! connection threads submit requests through an mpsc channel and
//! receive token events through per-request channels. The engine
//! thread fronts the model with the gateway components
//! ([`crate::gateway`]): an admission controller + surge detector
//! decide admit/defer/reject per request, and a per-request
//! [`TokenPacer`] releases tokens at the client's digestion speed
//! instead of the raw generation speed. The model, GPU profile, and
//! scheduler are configured through [`ServerConfig`] (reusing
//! [`crate::config::SchedulerConfig`]), so the server and the gateway
//! experiments share one config path.
//!
//! The same port also answers plain HTTP (DESIGN.md §12): a first line
//! starting with `GET` switches the connection to the observability
//! surface — `/metrics` serves the Prometheus text exposition of the
//! server's [`Telemetry`] registry, `/health` a JSON readiness document
//! (backend, replica count, active requests, defer depth). With
//! `--backend sim` the engine runs the calibrated simulator on the wall
//! clock (no compiled artifacts needed; token payloads are placeholder
//! glyphs, while admission, pacing, and QoE accounting are real) — the
//! configuration CI smokes against.

use std::collections::{BTreeMap, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::backend::pjrt::PjrtBackend;
use crate::backend::sim::SimBackend;
use crate::backend::{ExecutionBackend, WallClock};
use crate::config::SchedulerConfig;
use crate::coordinator::engine::{Engine, EngineConfig};
use crate::coordinator::request::RequestId;
use crate::gateway::{
    engine_state, AdmissionController, AdmissionDecision, GatewayConfig, LoadMode,
    RejectReason, SpillConfig, SurgeDetector, TokenPacer,
};
use crate::model::gpu::{a100_1x, GpuProfile};
use crate::model::latency::LatencyModel;
use crate::model::llm::{tiny_opt, LlmProfile};
use crate::qoe::spec::QoeSpec;
use crate::runtime::engine::ModelRuntime;
use crate::runtime::tokenizer::ByteTokenizer;
use crate::runtime::Sampling;
use crate::telemetry::{Telemetry, TelemetryConfig};
use crate::util::json::Json;
use crate::workload::qoe_trace::QoeTrace;
use crate::workload::{RequestSpec, SessionInfo};

/// A request submitted by a connection thread.
struct Submission {
    prompt: Vec<u32>,
    max_tokens: usize,
    qoe: QoeSpec,
    /// Conversational-session membership from the client (None =
    /// one-shot request).
    session: Option<SessionInfo>,
    /// Channel for token events back to the connection.
    events: Sender<Event>,
}

/// Streamed event.
#[derive(Debug, Clone)]
pub enum Event {
    Token { index: usize, token: u32 },
    Done { tokens: usize, ttft: f64, qoe: f64 },
    Rejected { reason: RejectReason },
}

/// Which execution backend the live server fronts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeBackend {
    /// The compiled tiny-OPT model via PJRT (requires `make artifacts`).
    Pjrt,
    /// The calibrated simulator on the wall clock — no artifacts
    /// needed. Token payloads are placeholder glyphs; admission,
    /// pacing, and QoE accounting are real.
    Sim,
}

impl ServeBackend {
    pub fn label(&self) -> &'static str {
        match self {
            ServeBackend::Pjrt => "pjrt",
            ServeBackend::Sim => "sim",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "pjrt" | "real" => Some(ServeBackend::Pjrt),
            "sim" | "simulator" => Some(ServeBackend::Sim),
            _ => None,
        }
    }
}

/// Live readiness state shared between the engine thread and the
/// `/health` endpoint.
#[derive(Debug, Clone, Default)]
pub struct HealthState {
    /// Set once the engine thread is serving.
    pub ready: bool,
    pub backend: String,
    pub replicas: usize,
    pub active_requests: usize,
    pub defer_depth: usize,
    pub served_requests: usize,
}

/// Server configuration.
pub struct ServerConfig {
    pub addr: String,
    pub kv_capacity_tokens: usize,
    pub max_output_tokens: usize,
    /// Execution backend (`--backend pjrt|sim`).
    pub backend: ServeBackend,
    /// Telemetry section: registry + tracer behind `/metrics`.
    pub telemetry: TelemetryConfig,
    /// Model profile driving the latency model the scheduler sees. The
    /// generated tokens always come from the compiled tiny-OPT runtime.
    pub llm: LlmProfile,
    pub gpu: GpuProfile,
    pub scheduler: SchedulerConfig,
    pub gateway: GatewayConfig,
    /// Spill-tier section from the deployment config. The live server
    /// fronts a single engine, so this is advisory (see `engine_loop`);
    /// the simulated cluster paths consume it for real.
    pub spill: SpillConfig,
    /// Sessions section from the deployment config / `--park-prefixes`.
    /// Advisory on the live server (see `engine_loop`): the PJRT
    /// backend has no prefix cache, so prefix retention is a
    /// simulation-tier feature; session/turn request tags are accepted
    /// and recorded regardless.
    pub park_prefixes: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7878".into(),
            kv_capacity_tokens: 2048,
            max_output_tokens: 128,
            backend: ServeBackend::Pjrt,
            // The live surface defaults to observable: /metrics and
            // /health answer out of the box (simulation paths default
            // to telemetry off for bit-identical parity instead).
            telemetry: TelemetryConfig { enabled: true, ..TelemetryConfig::default() },
            llm: tiny_opt(),
            gpu: a100_1x(),
            scheduler: SchedulerConfig::Andes(Default::default()),
            gateway: GatewayConfig::default(),
            spill: SpillConfig::default(),
            park_prefixes: false,
        }
    }
}

/// Per-request serving state on the engine thread.
struct Stream {
    events: Sender<Event>,
    pacer: TokenPacer,
    /// Token values pulled from the backend as they are generated.
    tokens: Vec<u32>,
    /// Tokens released to the connection so far.
    sent: usize,
    /// Set when the engine finished the request; the Done event is held
    /// until the pacer drains.
    done: Option<(usize, f64, f64)>,
}

/// Engine thread: owns the model, pulls submissions, streams events
/// through the gateway's admission controller and per-request pacers.
/// Generic over the execution backend: PJRT for real serving, the
/// calibrated simulator for artifact-free smokes.
fn engine_loop<B: ExecutionBackend>(
    cfg: ServerConfig,
    rx: Receiver<Submission>,
    backend: B,
    telemetry: Telemetry,
    health: Arc<Mutex<HealthState>>,
) -> Result<()> {
    let engine_cfg = EngineConfig {
        kv_capacity_tokens: cfg.kv_capacity_tokens,
        swap_capacity_tokens: cfg.kv_capacity_tokens * 4,
        max_output_tokens: cfg.max_output_tokens,
        // Parking is NOT enabled on the real engine (see below): the
        // PJRT backend has no prefix cache, so parked KV would consume
        // host-pool headroom and relieve admission scores without ever
        // delivering the prefill saving.
        ..EngineConfig::default()
    };
    let latency = LatencyModel::for_deployment(&cfg.llm, &cfg.gpu);
    let mut engine = Engine::new(
        engine_cfg,
        backend,
        WallClock::new(),
        cfg.scheduler.build(),
        latency,
    );
    telemetry.set_time_domain("wall");
    engine.set_telemetry(telemetry.clone(), 0);
    if let Ok(mut h) = health.lock() {
        h.ready = true;
        h.backend = cfg.backend.label().to_string();
        h.replicas = 1;
    }

    if cfg.gateway.autoscale.enabled {
        // The live server fronts a single real-model engine; elastic
        // replica scaling applies to the simulated cluster tier
        // (`andes exp ext-autoscale`, `andes simulate --autoscale`).
        log::info!(
            "autoscale config present ({}..{} replicas) — advisory only for the \
             single-engine live server",
            cfg.gateway.autoscale.min_replicas,
            cfg.gateway.autoscale.max_replicas
        );
    }
    if cfg.spill.enabled {
        log::info!(
            "spill config present ({} replicas) — advisory only for the \
             single-engine live server (use `andes simulate --spill-replicas` \
             or `andes exp ext-autoscale`)",
            cfg.spill.replicas
        );
    }
    if cfg.gateway.network.enabled {
        // The live server's tokens ride a real TCP link; the simulated
        // delivery model (and its client-vs-server QoE split) is a
        // simulation-tier feature.
        log::info!(
            "network delivery model configured — advisory only for the live \
             server (its clients sit on a real network); exercised by \
             `andes simulate --network` and `andes exp ext-network`"
        );
    }
    if cfg.park_prefixes {
        // Session/turn tags are accepted and recorded either way; the
        // prefix-aware admission path below stays inert until a real
        // prefix cache exists (nothing is ever parked).
        log::info!(
            "park_prefixes requested — advisory only for the live server: the \
             PJRT backend has no prefix cache, so retention is exercised by \
             `andes simulate --park` and `andes exp ext-sessions`"
        );
    }
    let mut admission = AdmissionController::new(cfg.gateway.admission.clone());
    let mut surge = SurgeDetector::new(cfg.gateway.surge.clone());
    // BTreeMap: the tick loop iterates streams to emit tokens, so the
    // emission order across requests must not depend on hash order.
    let mut streams: BTreeMap<RequestId, Stream> = BTreeMap::new();
    let mut deferred: VecDeque<(Submission, f64, usize)> = VecDeque::new();
    let mut reported = 0usize; // finished requests already examined
    let mut next_req = 0usize; // arrival ordinal → spec id / trace span key

    // Parked-prefix tokens usable by a submission (0 for one-shot
    // requests, opening turns, and missing/evicted prefixes).
    fn usable_prefix<B: ExecutionBackend>(
        engine: &Engine<B, WallClock>,
        session: Option<SessionInfo>,
    ) -> usize {
        session
            .map(|s| s.usable_prefix(engine.parked_prefix_tokens(s.session_id)))
            .unwrap_or(0)
    }

    // `arrival` is the request's original arrival time: admit time for
    // fresh submissions, enqueue time for deferred ones — so defer-queue
    // wait is charged to TTFT/QoE exactly as in the simulated gateway.
    // `arrival_id` is the server-level arrival ordinal; it becomes the
    // spec id, which keys the telemetry trace span across defer/admit.
    fn admit<B: ExecutionBackend>(
        sub: Submission,
        arrival: f64,
        arrival_id: usize,
        engine: &mut Engine<B, WallClock>,
        streams: &mut BTreeMap<RequestId, Stream>,
        cfg: &ServerConfig,
    ) {
        let Submission { prompt, max_tokens, qoe, session, events } = sub;
        let spec = RequestSpec {
            id: arrival_id,
            arrival,
            prompt_tokens: prompt.len(),
            output_tokens: max_tokens,
            qoe,
            session,
        };
        match engine.submit_with_prompt(spec, prompt) {
            Ok(id) => {
                let pacer = if cfg.gateway.pacing_enabled {
                    TokenPacer::new(&qoe, &cfg.gateway.pacing)
                } else {
                    TokenPacer::passthrough()
                };
                streams.insert(
                    id,
                    Stream { events, pacer, tokens: Vec::new(), sent: 0, done: None },
                );
            }
            Err(e) => {
                let _ = events.send(Event::Done { tokens: 0, ttft: 0.0, qoe: 0.0 });
                log::warn!("failed to submit request: {e:#}");
            }
        }
    }

    loop {
        let pacing_busy =
            streams.values().any(|s| s.pacer.pending() > 0 || s.done.is_some());
        let busy = engine.has_work() || pacing_busy || !deferred.is_empty();

        // Drain new submissions (block briefly when fully idle).
        let first = if busy {
            rx.try_recv().ok()
        } else {
            match rx.recv_timeout(std::time::Duration::from_millis(50)) {
                Ok(s) => Some(s),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => None,
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => return Ok(()),
            }
        };
        let mut incoming = Vec::new();
        if let Some(s) = first {
            incoming.push(s);
        }
        while let Ok(s) = rx.try_recv() {
            incoming.push(s);
        }

        // Retry deferred submissions: admit, keep waiting, or time out.
        let now = engine.now();
        for _ in 0..deferred.len() {
            let (sub, t0, rid) = deferred.pop_front().unwrap();
            let waited = now - t0;
            if waited > cfg.gateway.admission.max_defer_wait {
                let reason = RejectReason::DeferTimeout { waited };
                if telemetry.is_enabled() {
                    let tier = QoeTrace::tier_of(&sub.qoe);
                    telemetry.inc(
                        "andes_requests_total",
                        &[("outcome", "rejected"), ("tier", tier)],
                        1.0,
                    );
                    telemetry.inc("andes_rejects_total", &[("cause", reason.label())], 1.0);
                    telemetry.event(
                        rid as u64,
                        "reject",
                        now,
                        &[("cause", reason.label().into()), ("waited", waited.into())],
                    );
                }
                let _ = sub.events.send(Event::Rejected { reason });
                continue;
            }
            let state = [engine_state(&engine)];
            let prefix = usable_prefix(&engine, sub.session);
            match admission.decide_with_prefix(
                sub.prompt.len(),
                prefix,
                &sub.qoe,
                &state,
                surge.mode(),
                deferred.len(),
            ) {
                AdmissionDecision::Admit => {
                    if telemetry.is_enabled() {
                        let tier = QoeTrace::tier_of(&sub.qoe);
                        telemetry.inc(
                            "andes_requests_total",
                            &[("outcome", "admitted"), ("tier", tier)],
                            1.0,
                        );
                        telemetry.event(
                            rid as u64,
                            "admit",
                            now,
                            &[("waited", waited.into())],
                        );
                    }
                    admit(sub, t0, rid, &mut engine, &mut streams, &cfg)
                }
                _ => {
                    deferred.push_front((sub, t0, rid));
                    break; // FIFO: the head blocks the rest
                }
            }
        }

        // Gateway admission for newcomers.
        for sub in incoming {
            let t = engine.now();
            surge.observe(t);
            let rid = next_req;
            next_req += 1;
            let tier = QoeTrace::tier_of(&sub.qoe);
            if telemetry.is_enabled() {
                telemetry.event(
                    rid as u64,
                    "arrival",
                    t,
                    &[("tier", tier.into()), ("prompt_tokens", sub.prompt.len().into())],
                );
                telemetry.set_gauge(
                    "andes_surge_mode",
                    &[],
                    if surge.mode() == LoadMode::Surge { 1.0 } else { 0.0 },
                );
            }
            if !cfg.gateway.admission_enabled {
                if telemetry.is_enabled() {
                    telemetry.inc(
                        "andes_requests_total",
                        &[("outcome", "admitted"), ("tier", tier)],
                        1.0,
                    );
                    telemetry.event(rid as u64, "admit", t, &[]);
                }
                admit(sub, t, rid, &mut engine, &mut streams, &cfg);
                continue;
            }
            let state = [engine_state(&engine)];
            let prefix = usable_prefix(&engine, sub.session);
            match admission.decide_with_prefix(
                sub.prompt.len(),
                prefix,
                &sub.qoe,
                &state,
                surge.mode(),
                deferred.len(),
            ) {
                AdmissionDecision::Admit => {
                    if telemetry.is_enabled() {
                        telemetry.inc(
                            "andes_requests_total",
                            &[("outcome", "admitted"), ("tier", tier)],
                            1.0,
                        );
                        telemetry.event(rid as u64, "admit", t, &[]);
                    }
                    admit(sub, t, rid, &mut engine, &mut streams, &cfg)
                }
                AdmissionDecision::Defer => {
                    if telemetry.is_enabled() {
                        telemetry.inc(
                            "andes_requests_total",
                            &[("outcome", "deferred"), ("tier", tier)],
                            1.0,
                        );
                        telemetry.event(
                            rid as u64,
                            "defer",
                            t,
                            &[("depth", (deferred.len() + 1).into())],
                        );
                    }
                    deferred.push_back((sub, t, rid));
                }
                AdmissionDecision::Reject(reason) => {
                    if telemetry.is_enabled() {
                        telemetry.inc(
                            "andes_requests_total",
                            &[("outcome", "rejected"), ("tier", tier)],
                            1.0,
                        );
                        telemetry.inc(
                            "andes_rejects_total",
                            &[("cause", reason.label())],
                            1.0,
                        );
                        telemetry.event(
                            rid as u64,
                            "reject",
                            t,
                            &[("cause", reason.label().into())],
                        );
                    }
                    let _ = sub.events.send(Event::Rejected { reason });
                }
            }
        }

        if engine.has_work() {
            engine.tick()?;
        } else if pacing_busy || !deferred.is_empty() {
            // Only pacers or the defer queue left: let wall time pass at
            // a fine grain instead of busy-spinning on try_recv.
            std::thread::sleep(std::time::Duration::from_millis(2));
        }

        // Pull newly generated tokens into their pacers, release what is
        // due, and hold Done until each pacer drains. Backends that
        // retain no token values (the simulator) stream a placeholder
        // glyph per generated token — cadence is what matters here.
        let now = engine.now();
        let ids: Vec<RequestId> = streams.keys().copied().collect();
        for id in ids {
            let have = engine.requests().get(id).map_or(0, |r| r.generated);
            let s = streams.get_mut(&id).unwrap();
            if have > s.tokens.len() {
                match engine.backend().generated_tokens(id) {
                    Some(toks) => {
                        for &tok in
                            toks.iter().take(have.min(toks.len())).skip(s.tokens.len())
                        {
                            s.pacer.push(now);
                            s.tokens.push(tok);
                        }
                    }
                    None => {
                        while s.tokens.len() < have {
                            s.pacer.push(now);
                            s.tokens.push(u32::from(b'.'));
                        }
                    }
                }
            }
            let due = s.pacer.release_due(now);
            for k in 0..due {
                let idx = s.sent + k;
                let _ = s.events.send(Event::Token { index: idx, token: s.tokens[idx] });
            }
            s.sent += due;
        }

        // Record newly finished requests (Done is sent once paced out).
        {
            let metrics = engine.metrics();
            while reported < metrics.requests.len() {
                let r = &metrics.requests[reported];
                if let Some(s) = streams.get_mut(&r.id) {
                    s.done = Some((r.output_tokens, r.ttft, r.final_qoe));
                }
                if telemetry.is_enabled() {
                    let spec =
                        QoeSpec::new(r.expected_ttft.max(0.0), r.expected_tds.max(0.1));
                    let tier = QoeTrace::tier_of(&spec);
                    let labels = [("tier", tier)];
                    let sid = r.spec_id as u64;
                    if r.ttft.is_finite() && r.ttft >= 0.0 {
                        telemetry.observe_latency("andes_ttft_seconds", &labels, r.ttft);
                        telemetry.event(
                            sid,
                            "first_token",
                            r.arrival + r.ttft,
                            &[("ttft", r.ttft.into())],
                        );
                    }
                    if r.avg_tds.is_finite() && r.avg_tds > 0.0 {
                        telemetry.observe_tpot("andes_tpot_seconds", &labels, 1.0 / r.avg_tds);
                    }
                    if r.final_qoe.is_finite() {
                        telemetry.observe_unit(
                            "andes_qoe",
                            &labels,
                            r.final_qoe.clamp(0.0, 1.0),
                        );
                    }
                    telemetry.inc("andes_tokens_total", &labels, r.output_tokens as f64);
                    telemetry.event(
                        sid,
                        "finish",
                        now,
                        &[
                            ("tokens", r.output_tokens.into()),
                            ("qoe", r.final_qoe.into()),
                            ("tier", tier.into()),
                        ],
                    );
                }
                reported += 1;
            }
        }
        let mut finished: Vec<RequestId> = Vec::new();
        for (&id, s) in streams.iter() {
            if s.done.is_some() && s.pacer.pending() == 0 {
                finished.push(id);
            }
        }
        for id in finished {
            if let Some(s) = streams.remove(&id) {
                let (tokens, ttft, qoe) = s.done.unwrap();
                let _ = s.events.send(Event::Done { tokens, ttft, qoe });
            }
            engine.backend_mut().forget(id);
        }

        // Observability heartbeat: queue-depth gauge, periodic metric
        // snapshots, and the /health readiness document.
        if telemetry.is_enabled() {
            telemetry.set_gauge("andes_defer_queue_depth", &[], deferred.len() as f64);
            telemetry.maybe_snapshot(engine.now());
        }
        if let Ok(mut h) = health.lock() {
            h.active_requests = streams.len();
            h.defer_depth = deferred.len();
            h.served_requests = reported;
        }
    }
}

/// Answer one plain-HTTP request on a connection whose first line was a
/// `GET`. Headers are drained and ignored; the response closes the
/// connection (curl-friendly, no keep-alive state to manage).
fn serve_http(
    request_line: &str,
    reader: &mut BufReader<TcpStream>,
    writer: &mut TcpStream,
    telemetry: &Telemetry,
    health: &Arc<Mutex<HealthState>>,
) {
    // Drain headers up to the blank line.
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => break,
            Ok(_) if line.trim().is_empty() => break,
            Ok(_) => continue,
            Err(_) => break,
        }
    }
    let path = request_line.split_whitespace().nth(1).unwrap_or("/");
    let (status, content_type, body) = match path {
        "/metrics" => {
            let text = telemetry.render_prometheus();
            if text.is_empty() {
                (
                    "503 Service Unavailable",
                    "text/plain; charset=utf-8",
                    "telemetry disabled\n".to_string(),
                )
            } else {
                ("200 OK", "text/plain; version=0.0.4; charset=utf-8", text)
            }
        }
        "/health" => {
            let h = health.lock().map(|h| h.clone()).unwrap_or_default();
            let j = Json::obj(vec![
                ("status", if h.ready { "ok" } else { "starting" }.into()),
                ("backend", h.backend.as_str().into()),
                ("replicas", (h.replicas as u64).into()),
                ("active_requests", (h.active_requests as u64).into()),
                ("defer_depth", (h.defer_depth as u64).into()),
                ("served_requests", (h.served_requests as u64).into()),
            ]);
            ("200 OK", "application/json", format!("{j}\n"))
        }
        _ => (
            "404 Not Found",
            "text/plain; charset=utf-8",
            "not found (try /metrics or /health)\n".to_string(),
        ),
    };
    let _ = write!(
        writer,
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    let _ = writer.flush();
}

fn handle_conn(
    stream: TcpStream,
    tx: Sender<Submission>,
    telemetry: Telemetry,
    health: Arc<Mutex<HealthState>>,
) {
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
    let tokenizer = ByteTokenizer::new();
    let mut reader = BufReader::new(stream.try_clone().expect("clone stream"));
    let mut writer = stream;

    // Peek the first line: `GET …` switches the connection to the HTTP
    // observability surface; anything else is the JSONL protocol.
    let mut first = String::new();
    match reader.read_line(&mut first) {
        Ok(0) | Err(_) => return,
        Ok(_) => {}
    }
    if first.starts_with("GET ") || first.starts_with("HEAD ") {
        serve_http(&first, &mut reader, &mut writer, &telemetry, &health);
        log::debug!("http {peer} {}", first.trim());
        return;
    }

    for line in std::iter::once(Ok::<String, std::io::Error>(first)).chain(reader.lines()) {
        let line = match line {
            Ok(l) if !l.trim().is_empty() => l,
            Ok(_) => continue,
            Err(_) => break,
        };
        let req = match Json::parse(&line) {
            Ok(j) => j,
            Err(e) => {
                let _ = writeln!(writer, r#"{{"event":"error","message":"bad json: {e}"}}"#);
                continue;
            }
        };
        let prompt_text = req.get("prompt").as_str().unwrap_or("").to_string();
        if prompt_text.is_empty() {
            let _ = writeln!(writer, r#"{{"event":"error","message":"missing prompt"}}"#);
            continue;
        }
        let max_tokens = req.get("max_tokens").as_u64().unwrap_or(64) as usize;
        let ttft = req.get("ttft").as_f64().unwrap_or(1.0);
        let tds = req.get("tds").as_f64().unwrap_or(4.8);
        let prompt = tokenizer.encode(&prompt_text);
        // Multi-turn clients tag requests with a session key + turn
        // index; the prompt carries the whole history, so the shareable
        // prefix is bounded by the prompt itself (the engine further
        // caps it at what is actually parked).
        let session = req.get("session").as_u64().map(|sid| SessionInfo {
            session_id: sid,
            turn: req.get("turn").as_u64().unwrap_or(0) as usize,
            turns_total: usize::MAX, // unknown: the client may always return
            prefix_tokens: prompt.len(),
        });
        let (etx, erx) = channel();
        if tx
            .send(Submission {
                prompt,
                max_tokens,
                qoe: QoeSpec::new(ttft.max(0.0), tds.max(0.1)),
                session,
                events: etx,
            })
            .is_err()
        {
            let _ = writeln!(writer, r#"{{"event":"error","message":"engine gone"}}"#);
            break;
        }
        // Stream events for this request until Done or Rejected.
        for ev in erx {
            let out = match ev {
                Event::Token { index, token } => {
                    let text = tokenizer.decode_one(token);
                    Json::obj(vec![
                        ("event", "token".into()),
                        ("index", (index as u64).into()),
                        ("text", text.into()),
                    ])
                }
                Event::Done { tokens, ttft, qoe } => {
                    // Non-finite values would serialize as invalid JSON.
                    let ttft = if ttft.is_finite() { ttft } else { 0.0 };
                    let qoe = if qoe.is_finite() { qoe } else { 0.0 };
                    let j = Json::obj(vec![
                        ("event", "done".into()),
                        ("tokens", (tokens as u64).into()),
                        ("ttft", ttft.into()),
                        ("qoe", qoe.into()),
                    ]);
                    let _ = writeln!(writer, "{j}");
                    break;
                }
                Event::Rejected { reason } => {
                    let j = Json::obj(vec![
                        ("event", "rejected".into()),
                        ("reason", reason.label().into()),
                        ("detail", reason.detail().as_str().into()),
                    ]);
                    let _ = writeln!(writer, "{j}");
                    break;
                }
            };
            if writeln!(writer, "{out}").is_err() {
                break;
            }
        }
    }
    log::info!("connection {peer} closed");
}

/// Run the server (blocks). `ready` is signalled with the bound address
/// once listening — used by tests and examples.
pub fn serve(cfg: ServerConfig, ready: Option<Sender<String>>) -> Result<()> {
    let listener = TcpListener::bind(&cfg.addr)
        .with_context(|| format!("binding {}", cfg.addr))?;
    let local = listener.local_addr()?.to_string();
    log::info!("andes serving on {local} (backend={})", cfg.backend.label());
    if let Some(r) = ready {
        let _ = r.send(local);
    }
    let telemetry = Telemetry::new(&cfg.telemetry);
    let health = Arc::new(Mutex::new(HealthState {
        backend: cfg.backend.label().to_string(),
        ..HealthState::default()
    }));
    let (tx, rx) = channel::<Submission>();
    // Backends are constructed inside the engine thread: the PJRT xla
    // client is not Send, and the simulator needs no sharing either.
    let backend_kind = cfg.backend;
    let engine_tel = telemetry.clone();
    let engine_health = Arc::clone(&health);
    let engine_handle = std::thread::spawn(move || {
        let run = || -> Result<()> {
            match backend_kind {
                ServeBackend::Sim => {
                    let latency = LatencyModel::for_deployment(&cfg.llm, &cfg.gpu);
                    let backend = SimBackend::new(latency);
                    engine_loop(cfg, rx, backend, engine_tel, engine_health)
                }
                ServeBackend::Pjrt => {
                    let runtime = ModelRuntime::load(&ModelRuntime::default_dir())
                        .context("loading artifacts (run `make artifacts`)")?;
                    let backend = PjrtBackend::new(
                        runtime,
                        Sampling::TopK { k: 40, temperature: 1.0 },
                        1234,
                    );
                    engine_loop(cfg, rx, backend, engine_tel, engine_health)
                }
            }
        };
        if let Err(e) = run() {
            log::error!("engine thread error: {e:#}");
        }
    });
    let tx = Arc::new(tx);
    for stream in listener.incoming() {
        match stream {
            Ok(s) => {
                let tx = Sender::clone(&tx);
                let tel = telemetry.clone();
                let h = Arc::clone(&health);
                std::thread::spawn(move || handle_conn(s, tx, tel, h));
            }
            Err(e) => log::warn!("accept error: {e}"),
        }
    }
    drop(tx);
    let _ = engine_handle.join();
    Ok(())
}
