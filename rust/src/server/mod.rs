//! Text-streaming service frontend.
//!
//! A std-net TCP server speaking newline-delimited JSON (no tokio in the
//! offline environment; threads + channels instead):
//!
//! ```text
//! → {"prompt": "...", "max_tokens": 64, "ttft": 1.0, "tds": 4.8}
//! → {"prompt": "...", "session": 7, "turn": 1}     (multi-turn client)
//! ← {"event":"token","text":"...","index":0}           (streamed, paced)
//! ← {"event":"done","tokens":42,"ttft":0.18,"qoe":1.0}
//! ← {"event":"rejected","reason":"surge-shed","detail":"..."}
//! ```
//!
//! Clients resuming a conversation send `session` (a stable numeric
//! session key) and `turn` (0-based); the tags flow into the request
//! records. KV prefix retention itself (DESIGN.md §10) is a
//! simulation-tier feature — the PJRT backend has no prefix cache, so
//! `--park-prefixes` is advisory here (see `engine_loop`).
//!
//! Architecture: one engine thread owns the PJRT model (the xla client
//! is not Send) and runs the continuous-batching loop; connection
//! threads submit requests through an mpsc channel and receive token
//! events through per-request channels. The engine thread fronts the
//! model with the gateway components ([`crate::gateway`]): an admission
//! controller + surge detector decide admit/defer/reject per request,
//! and a per-request [`TokenPacer`] releases tokens at the client's
//! digestion speed instead of the raw generation speed. The model, GPU
//! profile, and scheduler are configured through [`ServerConfig`]
//! (reusing [`crate::config::SchedulerConfig`]), so the server and the
//! gateway experiments share one config path.

use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::backend::pjrt::PjrtBackend;
use crate::backend::WallClock;
use crate::config::SchedulerConfig;
use crate::coordinator::engine::{Engine, EngineConfig};
use crate::coordinator::request::RequestId;
use crate::gateway::{
    engine_state, AdmissionController, AdmissionDecision, GatewayConfig, RejectReason,
    SpillConfig, SurgeDetector, TokenPacer,
};
use crate::model::gpu::{a100_1x, GpuProfile};
use crate::model::latency::LatencyModel;
use crate::model::llm::{tiny_opt, LlmProfile};
use crate::qoe::spec::QoeSpec;
use crate::runtime::engine::ModelRuntime;
use crate::runtime::tokenizer::ByteTokenizer;
use crate::runtime::Sampling;
use crate::util::json::Json;
use crate::workload::{RequestSpec, SessionInfo};

/// A request submitted by a connection thread.
struct Submission {
    prompt: Vec<u32>,
    max_tokens: usize,
    qoe: QoeSpec,
    /// Conversational-session membership from the client (None =
    /// one-shot request).
    session: Option<SessionInfo>,
    /// Channel for token events back to the connection.
    events: Sender<Event>,
}

/// Streamed event.
#[derive(Debug, Clone)]
pub enum Event {
    Token { index: usize, token: u32 },
    Done { tokens: usize, ttft: f64, qoe: f64 },
    Rejected { reason: RejectReason },
}

/// Server configuration.
pub struct ServerConfig {
    pub addr: String,
    pub kv_capacity_tokens: usize,
    pub max_output_tokens: usize,
    /// Model profile driving the latency model the scheduler sees. The
    /// generated tokens always come from the compiled tiny-OPT runtime.
    pub llm: LlmProfile,
    pub gpu: GpuProfile,
    pub scheduler: SchedulerConfig,
    pub gateway: GatewayConfig,
    /// Spill-tier section from the deployment config. The live server
    /// fronts a single engine, so this is advisory (see `engine_loop`);
    /// the simulated cluster paths consume it for real.
    pub spill: SpillConfig,
    /// Sessions section from the deployment config / `--park-prefixes`.
    /// Advisory on the live server (see `engine_loop`): the PJRT
    /// backend has no prefix cache, so prefix retention is a
    /// simulation-tier feature; session/turn request tags are accepted
    /// and recorded regardless.
    pub park_prefixes: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7878".into(),
            kv_capacity_tokens: 2048,
            max_output_tokens: 128,
            llm: tiny_opt(),
            gpu: a100_1x(),
            scheduler: SchedulerConfig::Andes(Default::default()),
            gateway: GatewayConfig::default(),
            spill: SpillConfig::default(),
            park_prefixes: false,
        }
    }
}

/// Per-request serving state on the engine thread.
struct Stream {
    events: Sender<Event>,
    pacer: TokenPacer,
    /// Token values pulled from the backend as they are generated.
    tokens: Vec<u32>,
    /// Tokens released to the connection so far.
    sent: usize,
    /// Set when the engine finished the request; the Done event is held
    /// until the pacer drains.
    done: Option<(usize, f64, f64)>,
}

/// Engine thread: owns the model, pulls submissions, streams events
/// through the gateway's admission controller and per-request pacers.
fn engine_loop(cfg: ServerConfig, rx: Receiver<Submission>) -> Result<()> {
    let runtime = ModelRuntime::load(&ModelRuntime::default_dir())
        .context("loading artifacts (run `make artifacts`)")?;
    let backend = PjrtBackend::new(runtime, Sampling::TopK { k: 40, temperature: 1.0 }, 1234);
    let engine_cfg = EngineConfig {
        kv_capacity_tokens: cfg.kv_capacity_tokens,
        swap_capacity_tokens: cfg.kv_capacity_tokens * 4,
        max_output_tokens: cfg.max_output_tokens,
        // Parking is NOT enabled on the real engine (see below): the
        // PJRT backend has no prefix cache, so parked KV would consume
        // host-pool headroom and relieve admission scores without ever
        // delivering the prefill saving.
        ..EngineConfig::default()
    };
    let latency = LatencyModel::for_deployment(&cfg.llm, &cfg.gpu);
    let mut engine = Engine::new(
        engine_cfg,
        backend,
        WallClock::new(),
        cfg.scheduler.build(),
        latency,
    );

    if cfg.gateway.autoscale.enabled {
        // The live server fronts a single real-model engine; elastic
        // replica scaling applies to the simulated cluster tier
        // (`andes exp ext-autoscale`, `andes simulate --autoscale`).
        log::info!(
            "autoscale config present ({}..{} replicas) — advisory only for the \
             single-engine live server",
            cfg.gateway.autoscale.min_replicas,
            cfg.gateway.autoscale.max_replicas
        );
    }
    if cfg.spill.enabled {
        log::info!(
            "spill config present ({} replicas) — advisory only for the \
             single-engine live server (use `andes simulate --spill-replicas` \
             or `andes exp ext-autoscale`)",
            cfg.spill.replicas
        );
    }
    if cfg.gateway.network.enabled {
        // The live server's tokens ride a real TCP link; the simulated
        // delivery model (and its client-vs-server QoE split) is a
        // simulation-tier feature.
        log::info!(
            "network delivery model configured — advisory only for the live \
             server (its clients sit on a real network); exercised by \
             `andes simulate --network` and `andes exp ext-network`"
        );
    }
    if cfg.park_prefixes {
        // Session/turn tags are accepted and recorded either way; the
        // prefix-aware admission path below stays inert until a real
        // prefix cache exists (nothing is ever parked).
        log::info!(
            "park_prefixes requested — advisory only for the live server: the \
             PJRT backend has no prefix cache, so retention is exercised by \
             `andes simulate --park` and `andes exp ext-sessions`"
        );
    }
    let mut admission = AdmissionController::new(cfg.gateway.admission.clone());
    let mut surge = SurgeDetector::new(cfg.gateway.surge.clone());
    let mut streams: HashMap<RequestId, Stream> = HashMap::new();
    let mut deferred: VecDeque<(Submission, f64)> = VecDeque::new();
    let mut reported = 0usize; // finished requests already examined

    // Parked-prefix tokens usable by a submission (0 for one-shot
    // requests, opening turns, and missing/evicted prefixes).
    fn usable_prefix(
        engine: &Engine<PjrtBackend, WallClock>,
        session: Option<SessionInfo>,
    ) -> usize {
        session
            .map(|s| s.usable_prefix(engine.parked_prefix_tokens(s.session_id)))
            .unwrap_or(0)
    }

    // `arrival` is the request's original arrival time: admit time for
    // fresh submissions, enqueue time for deferred ones — so defer-queue
    // wait is charged to TTFT/QoE exactly as in the simulated gateway.
    fn admit(
        sub: Submission,
        arrival: f64,
        engine: &mut Engine<PjrtBackend, WallClock>,
        streams: &mut HashMap<RequestId, Stream>,
        cfg: &ServerConfig,
    ) {
        let Submission { prompt, max_tokens, qoe, session, events } = sub;
        let spec = RequestSpec {
            id: 0, // engine assigns
            arrival,
            prompt_tokens: prompt.len(),
            output_tokens: max_tokens,
            qoe,
            session,
        };
        match engine.submit_with_prompt(spec, prompt) {
            Ok(id) => {
                let pacer = if cfg.gateway.pacing_enabled {
                    TokenPacer::new(&qoe, &cfg.gateway.pacing)
                } else {
                    TokenPacer::passthrough()
                };
                streams.insert(
                    id,
                    Stream { events, pacer, tokens: Vec::new(), sent: 0, done: None },
                );
            }
            Err(e) => {
                let _ = events.send(Event::Done { tokens: 0, ttft: 0.0, qoe: 0.0 });
                log::warn!("failed to submit request: {e:#}");
            }
        }
    }

    loop {
        let pacing_busy =
            streams.values().any(|s| s.pacer.pending() > 0 || s.done.is_some());
        let busy = engine.has_work() || pacing_busy || !deferred.is_empty();

        // Drain new submissions (block briefly when fully idle).
        let first = if busy {
            rx.try_recv().ok()
        } else {
            match rx.recv_timeout(std::time::Duration::from_millis(50)) {
                Ok(s) => Some(s),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => None,
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => return Ok(()),
            }
        };
        let mut incoming = Vec::new();
        if let Some(s) = first {
            incoming.push(s);
        }
        while let Ok(s) = rx.try_recv() {
            incoming.push(s);
        }

        // Retry deferred submissions: admit, keep waiting, or time out.
        let now = engine.now();
        for _ in 0..deferred.len() {
            let (sub, t0) = deferred.pop_front().unwrap();
            let waited = now - t0;
            if waited > cfg.gateway.admission.max_defer_wait {
                let _ = sub
                    .events
                    .send(Event::Rejected { reason: RejectReason::DeferTimeout { waited } });
                continue;
            }
            let state = [engine_state(&engine)];
            let prefix = usable_prefix(&engine, sub.session);
            match admission.decide_with_prefix(
                sub.prompt.len(),
                prefix,
                &sub.qoe,
                &state,
                surge.mode(),
                deferred.len(),
            ) {
                AdmissionDecision::Admit => admit(sub, t0, &mut engine, &mut streams, &cfg),
                _ => {
                    deferred.push_front((sub, t0));
                    break; // FIFO: the head blocks the rest
                }
            }
        }

        // Gateway admission for newcomers.
        for sub in incoming {
            let t = engine.now();
            surge.observe(t);
            if !cfg.gateway.admission_enabled {
                admit(sub, t, &mut engine, &mut streams, &cfg);
                continue;
            }
            let state = [engine_state(&engine)];
            let prefix = usable_prefix(&engine, sub.session);
            match admission.decide_with_prefix(
                sub.prompt.len(),
                prefix,
                &sub.qoe,
                &state,
                surge.mode(),
                deferred.len(),
            ) {
                AdmissionDecision::Admit => admit(sub, t, &mut engine, &mut streams, &cfg),
                AdmissionDecision::Defer => deferred.push_back((sub, t)),
                AdmissionDecision::Reject(reason) => {
                    let _ = sub.events.send(Event::Rejected { reason });
                }
            }
        }

        if engine.has_work() {
            engine.tick()?;
        } else if pacing_busy || !deferred.is_empty() {
            // Only pacers or the defer queue left: let wall time pass at
            // a fine grain instead of busy-spinning on try_recv.
            std::thread::sleep(std::time::Duration::from_millis(2));
        }

        // Pull newly generated tokens into their pacers, release what is
        // due, and hold Done until each pacer drains.
        let now = engine.now();
        let ids: Vec<RequestId> = streams.keys().copied().collect();
        for id in ids {
            let have = engine.requests().get(id).map_or(0, |r| r.generated);
            let s = streams.get_mut(&id).unwrap();
            if have > s.tokens.len() {
                if let Some(toks) = engine.backend().generated(id) {
                    for &tok in toks.iter().take(have.min(toks.len())).skip(s.tokens.len()) {
                        s.pacer.push(now);
                        s.tokens.push(tok);
                    }
                }
            }
            let due = s.pacer.release_due(now);
            for k in 0..due {
                let idx = s.sent + k;
                let _ = s.events.send(Event::Token { index: idx, token: s.tokens[idx] });
            }
            s.sent += due;
        }

        // Record newly finished requests (Done is sent once paced out).
        {
            let metrics = engine.metrics();
            while reported < metrics.requests.len() {
                let r = &metrics.requests[reported];
                if let Some(s) = streams.get_mut(&r.id) {
                    s.done = Some((r.output_tokens, r.ttft, r.final_qoe));
                }
                reported += 1;
            }
        }
        let mut finished: Vec<RequestId> = Vec::new();
        for (&id, s) in streams.iter() {
            if s.done.is_some() && s.pacer.pending() == 0 {
                finished.push(id);
            }
        }
        for id in finished {
            if let Some(s) = streams.remove(&id) {
                let (tokens, ttft, qoe) = s.done.unwrap();
                let _ = s.events.send(Event::Done { tokens, ttft, qoe });
            }
            engine.backend_mut().forget(id);
        }
    }
}

fn handle_conn(stream: TcpStream, tx: Sender<Submission>) {
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
    let tokenizer = ByteTokenizer::new();
    let reader = BufReader::new(stream.try_clone().expect("clone stream"));
    let mut writer = stream;
    for line in reader.lines() {
        let line = match line {
            Ok(l) if !l.trim().is_empty() => l,
            Ok(_) => continue,
            Err(_) => break,
        };
        let req = match Json::parse(&line) {
            Ok(j) => j,
            Err(e) => {
                let _ = writeln!(writer, r#"{{"event":"error","message":"bad json: {e}"}}"#);
                continue;
            }
        };
        let prompt_text = req.get("prompt").as_str().unwrap_or("").to_string();
        if prompt_text.is_empty() {
            let _ = writeln!(writer, r#"{{"event":"error","message":"missing prompt"}}"#);
            continue;
        }
        let max_tokens = req.get("max_tokens").as_u64().unwrap_or(64) as usize;
        let ttft = req.get("ttft").as_f64().unwrap_or(1.0);
        let tds = req.get("tds").as_f64().unwrap_or(4.8);
        let prompt = tokenizer.encode(&prompt_text);
        // Multi-turn clients tag requests with a session key + turn
        // index; the prompt carries the whole history, so the shareable
        // prefix is bounded by the prompt itself (the engine further
        // caps it at what is actually parked).
        let session = req.get("session").as_u64().map(|sid| SessionInfo {
            session_id: sid,
            turn: req.get("turn").as_u64().unwrap_or(0) as usize,
            turns_total: usize::MAX, // unknown: the client may always return
            prefix_tokens: prompt.len(),
        });
        let (etx, erx) = channel();
        if tx
            .send(Submission {
                prompt,
                max_tokens,
                qoe: QoeSpec::new(ttft.max(0.0), tds.max(0.1)),
                session,
                events: etx,
            })
            .is_err()
        {
            let _ = writeln!(writer, r#"{{"event":"error","message":"engine gone"}}"#);
            break;
        }
        // Stream events for this request until Done or Rejected.
        for ev in erx {
            let out = match ev {
                Event::Token { index, token } => {
                    let text = tokenizer.decode_one(token);
                    Json::obj(vec![
                        ("event", "token".into()),
                        ("index", (index as u64).into()),
                        ("text", text.into()),
                    ])
                }
                Event::Done { tokens, ttft, qoe } => {
                    // Non-finite values would serialize as invalid JSON.
                    let ttft = if ttft.is_finite() { ttft } else { 0.0 };
                    let qoe = if qoe.is_finite() { qoe } else { 0.0 };
                    let j = Json::obj(vec![
                        ("event", "done".into()),
                        ("tokens", (tokens as u64).into()),
                        ("ttft", ttft.into()),
                        ("qoe", qoe.into()),
                    ]);
                    let _ = writeln!(writer, "{j}");
                    break;
                }
                Event::Rejected { reason } => {
                    let j = Json::obj(vec![
                        ("event", "rejected".into()),
                        ("reason", reason.label().into()),
                        ("detail", reason.detail().as_str().into()),
                    ]);
                    let _ = writeln!(writer, "{j}");
                    break;
                }
            };
            if writeln!(writer, "{out}").is_err() {
                break;
            }
        }
    }
    log::info!("connection {peer} closed");
}

/// Run the server (blocks). `ready` is signalled with the bound address
/// once listening — used by tests and examples.
pub fn serve(cfg: ServerConfig, ready: Option<Sender<String>>) -> Result<()> {
    let listener = TcpListener::bind(&cfg.addr)
        .with_context(|| format!("binding {}", cfg.addr))?;
    let local = listener.local_addr()?.to_string();
    log::info!("andes serving on {local}");
    if let Some(r) = ready {
        let _ = r.send(local);
    }
    let (tx, rx) = channel::<Submission>();
    let engine_handle = std::thread::spawn(move || {
        if let Err(e) = engine_loop(cfg, rx) {
            eprintln!("engine thread error: {e:#}");
        }
    });
    let tx = Arc::new(tx);
    for stream in listener.incoming() {
        match stream {
            Ok(s) => {
                let tx = Sender::clone(&tx);
                std::thread::spawn(move || handle_conn(s, tx));
            }
            Err(e) => log::warn!("accept error: {e}"),
        }
    }
    drop(tx);
    let _ = engine_handle.join();
    Ok(())
}
