//! `andes` — QoE-aware LLM text-streaming serving (paper reproduction).
//!
//! Subcommands:
//!   serve           run the TCP streaming server (tiny-OPT or simulator)
//!   exp             regenerate paper tables/figures (CSV + ASCII)
//!   workload        generate a workload trace as CSV
//!   simulate        one simulated serving run, printing summary metrics
//!   trace-validate  schema-check a telemetry trace JSONL file
//!   lint            determinism static analysis over the repo's own sources
//!
//! Global flags (any position): `--log-level <off|error|warn|info|debug|trace>`
//! and `--quiet` (alias for `--log-level error`) control the leveled
//! stderr logger every subcommand shares.

use std::path::PathBuf;

use andes::experiments::{self, ExpCtx};
use andes::model::gpu::{a100_4x, gpu_by_name};
use andes::model::llm::{llm_by_name, opt_66b};
use andes::util::cli::{usage, Args, CliError, OptSpec};
use andes::workload::{ArrivalProcess, Dataset, QoeTrace, SessionWorkload, Workload};

/// Extract the global logging flags from anywhere in the argv and
/// initialise the leveled stderr logger; returns the remaining args.
fn init_logging(argv: Vec<String>) -> Vec<String> {
    let mut level = log::LevelFilter::Info;
    let mut rest = Vec::with_capacity(argv.len());
    let mut it = argv.into_iter();
    while let Some(a) = it.next() {
        if a == "--quiet" || a == "-q" {
            level = log::LevelFilter::Error;
        } else if a == "--log-level" {
            match it.next().as_deref().and_then(andes::telemetry::parse_level) {
                Some(l) => level = l,
                None => {
                    eprintln!("--log-level expects off|error|warn|info|debug|trace");
                    std::process::exit(2);
                }
            }
        } else if let Some(v) = a.strip_prefix("--log-level=") {
            match andes::telemetry::parse_level(v) {
                Some(l) => level = l,
                None => {
                    eprintln!("unknown log level '{v}'");
                    std::process::exit(2);
                }
            }
        } else {
            rest.push(a);
        }
    }
    andes::telemetry::init_logging(level);
    rest
}

fn main() {
    let argv = init_logging(std::env::args().skip(1).collect());
    let (cmd, rest) = match argv.split_first() {
        Some((c, r)) => (c.as_str(), r.to_vec()),
        None => {
            eprintln!("{}", top_usage());
            std::process::exit(2);
        }
    };
    let code = match cmd {
        "exp" => cmd_exp(&rest),
        "serve" => cmd_serve(&rest),
        "workload" => cmd_workload(&rest),
        "simulate" => cmd_simulate(&rest),
        "trace-validate" => cmd_trace_validate(&rest),
        "lint" => cmd_lint(&rest),
        "--help" | "-h" | "help" => {
            println!("{}", top_usage());
            0
        }
        other => {
            eprintln!("unknown command '{other}'\n{}", top_usage());
            2
        }
    };
    std::process::exit(code);
}

fn top_usage() -> String {
    "andes — QoE-aware LLM text-streaming serving\n\n\
     Usage: andes [--log-level L|--quiet] <command> [options]\n\n\
     Commands:\n\
       exp <id|all>           regenerate paper tables/figures (see DESIGN.md §5)\n\
       serve                  TCP streaming server (tiny-OPT or --backend sim)\n\
       workload               generate a workload trace CSV\n\
       simulate               one simulated serving run with summary metrics\n\
       trace-validate <path>  schema-check a telemetry trace JSONL file\n\
       lint                   determinism lint over the repo's own sources\n\n\
     Run `andes <command> --help` for options."
        .to_string()
}

fn cmd_trace_validate(argv: &[String]) -> i32 {
    let path = match argv.first() {
        Some(p) if p != "--help" && p != "-h" => p,
        _ => {
            println!(
                "Usage: andes trace-validate <trace.jsonl>\n\n\
                 Validates a telemetry trace export (DESIGN.md §12): every line\n\
                 must be a JSON object with finite non-negative time, integer\n\
                 request id, a known event kind, and scalar-only fields."
            );
            return if argv.first().is_some() { 0 } else { 2 };
        }
    };
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("reading {path}: {e}");
            return 1;
        }
    };
    match andes::telemetry::validate_jsonl(&text) {
        Ok(n) => {
            println!("{path}: {n} events ok");
            0
        }
        Err(e) => {
            eprintln!("{path}: invalid trace: {e:#}");
            1
        }
    }
}

fn cmd_lint(argv: &[String]) -> i32 {
    use andes::analysis::{self, baseline::Baseline, report, rules, LintOptions};
    let specs = [
        OptSpec::flag("deny", "exit non-zero when any new finding remains"),
        OptSpec::flag("json", "machine-readable report on stdout"),
        OptSpec::value("rule", None, "restrict the report to one rule id (D1..D7, C1, C2, W1, X1..X5)"),
        OptSpec::flag(
            "update-baseline",
            "re-bless current findings into the baseline (ratchet-only: refuses if any \
             (rule,file) count would grow)",
        ),
        OptSpec::value("root", Some("."), "repository root to scan"),
        OptSpec::value("baseline", Some("lint-baseline.json"), "baseline file, relative to root"),
    ];
    let about = "Determinism lint over the repo's own Rust sources (DESIGN.md §13)";
    let args = match Args::parse(argv, &specs) {
        Ok(a) => a,
        Err(e) => return die_on_cli("lint", about, &specs, e),
    };
    let rule = args.get("rule").map(str::to_string);
    if let Some(r) = &rule {
        if !rules::known_rule(r) {
            let known: Vec<&str> = rules::RULE_TABLE.iter().map(|&(id, _)| id).collect();
            eprintln!("unknown rule '{r}' (known: {})", known.join(" "));
            return 2;
        }
    }
    let update = args.has_flag("update-baseline");
    if update && rule.is_some() {
        eprintln!("--update-baseline blesses the full rule set; drop --rule");
        return 2;
    }
    let root = PathBuf::from(args.get("root").unwrap());
    let baseline_path = root.join(args.get("baseline").unwrap());
    let committed = if baseline_path.is_file() {
        let text = match std::fs::read_to_string(&baseline_path) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("reading {}: {e}", baseline_path.display());
                return 1;
            }
        };
        match Baseline::parse(&text) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("{}: {e}", baseline_path.display());
                return 1;
            }
        }
    } else {
        Baseline::empty()
    };
    // When re-blessing, scan against an empty baseline so every current
    // finding is visible for the ratchet comparison.
    let baseline = if update { Baseline::empty() } else { committed.clone() };
    let opts = LintOptions { rule, baseline };
    let outcome = match analysis::lint_repo(&root, &opts) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("lint: {e}");
            return 1;
        }
    };
    if args.has_flag("json") {
        print!("{}", report::render_json(&outcome));
    } else {
        print!("{}", report::render_human(&outcome));
    }
    if update {
        // Ratchet: the baseline may shrink as debt is fixed, never grow.
        // New findings must be fixed or waived inline, not grandfathered.
        let blessed = Baseline::from_findings(&outcome.findings);
        let delta = committed.ratchet(&blessed);
        if delta.grew {
            eprintln!(
                "refusing to update {}: baseline would grow\n{}",
                baseline_path.display(),
                delta.render()
            );
            return 1;
        }
        if let Err(e) = std::fs::write(&baseline_path, blessed.render()) {
            eprintln!("writing {}: {e}", baseline_path.display());
            return 1;
        }
        if delta.rows.is_empty() {
            eprintln!(
                "baseline unchanged ({} finding(s)) at {}",
                blessed.total(),
                baseline_path.display()
            );
        } else {
            eprintln!(
                "blessed {} finding(s) into {}; absorbed delta:\n{}",
                blessed.total(),
                baseline_path.display(),
                delta.render()
            );
        }
        return 0;
    }
    if args.has_flag("deny") && !outcome.findings.is_empty() {
        return 1;
    }
    0
}

fn die_on_cli(cmd: &str, about: &str, specs: &[OptSpec], e: CliError) -> i32 {
    match e {
        CliError::Help => {
            println!("{}", usage(cmd, about, specs));
            0
        }
        e => {
            eprintln!("error: {e}\n{}", usage(cmd, about, specs));
            2
        }
    }
}

fn cmd_exp(argv: &[String]) -> i32 {
    let specs = [
        OptSpec::value("out", Some("results"), "output directory for CSVs"),
        OptSpec::flag("quick", "reduced request counts (smoke run)"),
        OptSpec::value(
            "trace-out",
            None,
            "export telemetry traces from instrumented experiments (JSONL; \
             currently ext-gateway) plus metric snapshots beside it",
        ),
        OptSpec::value(
            "shards",
            Some("1"),
            "worker threads for grid-sharded experiments (outputs are \
             byte-identical at any value)",
        ),
    ];
    let about = "Regenerate paper tables and figures";
    let args = match Args::parse(argv, &specs) {
        Ok(a) => a,
        Err(e) => return die_on_cli("exp", about, &specs, e),
    };
    let id = args.positional().first().cloned().unwrap_or_else(|| "all".into());
    let shards: usize = match args.get("shards").unwrap().parse() {
        Ok(s) if s >= 1 => s,
        _ => {
            eprintln!("error: --shards must be a positive integer");
            return 2;
        }
    };
    let ctx = ExpCtx {
        out_dir: PathBuf::from(args.get("out").unwrap()),
        quick: args.has_flag("quick"),
        trace_out: args.get("trace-out").map(PathBuf::from),
        shards,
    };
    match experiments::run(&id, &ctx) {
        Ok(report) => {
            println!("{report}");
            println!("CSV outputs under {}", ctx.out_dir.display());
            0
        }
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

fn cmd_serve(argv: &[String]) -> i32 {
    let specs = [
        OptSpec::value("addr", Some("127.0.0.1:7878"), "listen address"),
        OptSpec::value(
            "backend",
            Some("pjrt"),
            "pjrt (compiled tiny-OPT, needs `make artifacts`) | sim (calibrated \
             simulator on the wall clock; placeholder token glyphs)",
        ),
        OptSpec::flag(
            "no-telemetry",
            "disable the metric registry and tracer (/metrics answers 503)",
        ),
        OptSpec::value("kv-tokens", None, "device KV capacity (tokens) [default: 2048 or config]"),
        OptSpec::value("max-output", None, "max generated tokens per request [default: 128 or config]"),
        OptSpec::value("model", Some("tiny-opt"), "latency-model profile (tiny-opt|opt-13b|...)"),
        OptSpec::value("gpu", Some("a100-1x"), "gpu profile (a100-1x|a100-4x|a40)"),
        OptSpec::value("sched", Some("andes"), "fcfs | rr | andes"),
        OptSpec::value("config", None, "JSON deployment config (overrides model/gpu/sched/engine/gateway)"),
        OptSpec::flag("no-gateway", "disable gateway admission control and token pacing"),
        OptSpec::flag(
            "park-prefixes",
            "accept session KV retention config (advisory: the real backend \
             has no prefix cache; see `simulate --park` / `exp ext-sessions`)",
        ),
        OptSpec::value(
            "lead",
            None,
            "pacer lead tokens (default from config: 4; 0 disables the lead)",
        ),
        OptSpec::value(
            "tier-weights",
            None,
            "per-tier admission weights premium:standard:economy (e.g. 2:1:0.5); \
             same knob as the `tiers` config section",
        ),
        OptSpec::value(
            "gateways",
            None,
            "federated gateway instances (the live server supports 1; \
             use `andes simulate --gateways N` for federation)",
        ),
        OptSpec::value(
            "network",
            None,
            "client-side delivery model mix, e.g. lte or fiber:0.6,lte:0.4 \
             (advisory: the live server streams over a real network; the \
             model is exercised by `andes simulate --network` and \
             `andes exp ext-network`)",
        ),
    ];
    let about = "Serve the streaming model over TCP (JSON lines + HTTP /metrics, /health)";
    let args = match Args::parse(argv, &specs) {
        Ok(a) => a,
        Err(e) => return die_on_cli("serve", about, &specs, e),
    };
    // Precedence: explicit CLI flag > config file > built-in default.
    let mut cfg = andes::server::ServerConfig {
        addr: args.get("addr").unwrap().to_string(),
        ..andes::server::ServerConfig::default()
    };
    match andes::server::ServeBackend::parse(args.get("backend").unwrap()) {
        Some(b) => cfg.backend = b,
        None => {
            eprintln!("unknown backend '{}' (pjrt|sim)", args.get("backend").unwrap());
            return 2;
        }
    }
    if let Some(path) = args.get("config") {
        match andes::config::AndesDeployment::from_file(std::path::Path::new(path)) {
            Ok(d) => {
                if d.federation.gateways > 1 {
                    eprintln!(
                        "note: config requests {g} federated gateways; the live server \
                         fronts a single engine, so the federation section is ignored \
                         (run `andes simulate --gateways {g}` to exercise federation)",
                        g = d.federation.gateways
                    );
                }
                cfg.llm = d.llm;
                cfg.gpu = d.gpu;
                cfg.scheduler = d.scheduler;
                cfg.gateway = d.gateway;
                cfg.spill = d.spill;
                cfg.kv_capacity_tokens = d.engine.kv_capacity_tokens;
                cfg.max_output_tokens = d.engine.max_output_tokens;
                cfg.park_prefixes = d.engine.park_prefixes;
                // The live surface defaults telemetry on; a config file
                // takes over only when it has an explicit section.
                if let Some(t) = d.telemetry {
                    cfg.telemetry = t;
                }
            }
            Err(e) => {
                eprintln!("error: {e:#}");
                return 2;
            }
        }
    } else {
        if let Some(llm) = llm_by_name(args.get("model").unwrap()) {
            cfg.llm = llm;
        } else {
            eprintln!("unknown model '{}'", args.get("model").unwrap());
            return 2;
        }
        if let Some(gpu) = gpu_by_name(args.get("gpu").unwrap()) {
            cfg.gpu = gpu;
        } else {
            eprintln!("unknown gpu '{}'", args.get("gpu").unwrap());
            return 2;
        }
        cfg.scheduler = match args.get("sched").unwrap() {
            "fcfs" => andes::config::SchedulerConfig::Fcfs,
            "rr" => andes::config::SchedulerConfig::RoundRobin { quantum: 50 },
            "andes" => andes::config::SchedulerConfig::Andes(Default::default()),
            other => {
                eprintln!("unknown scheduler '{other}'");
                return 2;
            }
        };
    }
    if args.has_flag("no-gateway") {
        cfg.gateway.admission_enabled = false;
        cfg.gateway.pacing_enabled = false;
    }
    if args.has_flag("no-telemetry") {
        cfg.telemetry.enabled = false;
    }
    if args.has_flag("park-prefixes") {
        cfg.park_prefixes = true;
    }
    match args.get_usize("kv-tokens") {
        Ok(Some(kv)) => cfg.kv_capacity_tokens = kv.max(1),
        Ok(None) => {}
        Err(e) => return die_on_cli("serve", about, &specs, e),
    }
    match args.get_usize("max-output") {
        Ok(Some(m)) => cfg.max_output_tokens = m.max(1),
        Ok(None) => {}
        Err(e) => return die_on_cli("serve", about, &specs, e),
    }
    match args.get_usize("lead") {
        Ok(Some(lead)) => cfg.gateway.pacing.lead_tokens = lead,
        Ok(None) => {}
        Err(e) => return die_on_cli("serve", about, &specs, e),
    }
    if let Some(s) = args.get("tier-weights") {
        match andes::gateway::TierWeights::parse(s) {
            Ok(w) => cfg.gateway.admission.tier_weights = w,
            Err(e) => {
                eprintln!("error: {e:#}");
                return 2;
            }
        }
    }
    match args.get_usize("gateways") {
        Ok(Some(g)) if g > 1 => {
            eprintln!(
                "the live server fronts a single real-model engine; multi-gateway \
                 federation is simulation-only (try `andes simulate --gateways {g}`)"
            );
            return 2;
        }
        Ok(_) => {}
        Err(e) => return die_on_cli("serve", about, &specs, e),
    }
    if let Some(s) = args.get("network") {
        match andes::delivery::NetworkConfig::parse_mix(s) {
            Ok(mix) => {
                cfg.gateway.network.enabled = true;
                cfg.gateway.network.mix = mix;
            }
            Err(e) => {
                eprintln!("error: {e:#}");
                return 2;
            }
        }
    }
    match andes::server::serve(cfg, None) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

fn cmd_workload(argv: &[String]) -> i32 {
    let specs = [
        OptSpec::value("dataset", Some("sharegpt"), "sharegpt | multiround"),
        OptSpec::value("rate", Some("2.0"), "arrival rate (req/s)"),
        OptSpec::value("cv", Some("1.0"), "arrival CV (1 = Poisson)"),
        OptSpec::value("qoe", Some("text"), "text | voice"),
        OptSpec::value("n", Some("1000"), "number of requests"),
        OptSpec::value("seed", Some("42"), "PRNG seed"),
        OptSpec::value("out", None, "output CSV path (default stdout)"),
    ];
    let about = "Generate a workload trace";
    let args = match Args::parse(argv, &specs) {
        Ok(a) => a,
        Err(e) => return die_on_cli("workload", about, &specs, e),
    };
    let dataset = match Dataset::by_name(args.get("dataset").unwrap()) {
        Some(d) => d,
        None => {
            eprintln!("unknown dataset");
            return 2;
        }
    };
    let rate = args.get_f64("rate").unwrap().unwrap();
    let cv = args.get_f64("cv").unwrap().unwrap();
    let arrivals = if (cv - 1.0).abs() < 1e-9 {
        ArrivalProcess::Poisson { rate }
    } else {
        ArrivalProcess::Gamma { rate, cv }
    };
    let qoe_trace = QoeTrace::by_name(args.get("qoe").unwrap()).unwrap_or(QoeTrace::TextReading);
    let wl = Workload {
        dataset,
        arrivals,
        qoe_trace,
        num_requests: args.get_usize("n").unwrap().unwrap(),
        seed: args.get_u64("seed").unwrap().unwrap(),
    };
    let mut csv = andes::util::csv::Csv::new(&[
        "id", "arrival", "prompt_tokens", "output_tokens", "ttft_expected", "tds_expected",
    ]);
    for r in wl.generate() {
        csv.row_f64(&[
            r.id as f64,
            r.arrival,
            r.prompt_tokens as f64,
            r.output_tokens as f64,
            r.qoe.ttft,
            r.qoe.tds,
        ]);
    }
    match args.get("out") {
        Some(path) => {
            if let Err(e) = csv.write(std::path::Path::new(path)) {
                eprintln!("write failed: {e}");
                return 1;
            }
            eprintln!("wrote {path}");
        }
        None => print!("{}", csv.to_string()),
    }
    0
}

fn cmd_simulate(argv: &[String]) -> i32 {
    let specs = [
        OptSpec::value("model", Some("opt-66b"), "opt-13b|opt-30b|opt-66b|opt-175b"),
        OptSpec::value("gpu", Some("a100-4x"), "a100-1x|a100-4x|a40"),
        OptSpec::value("sched", Some("andes"), "fcfs | rr | andes"),
        OptSpec::value("dataset", Some("sharegpt"), "sharegpt | multiround"),
        OptSpec::value("rate", Some("2.0"), "arrival rate (req/s)"),
        OptSpec::value("n", Some("1000"), "number of requests"),
        OptSpec::value("seed", Some("42"), "PRNG seed"),
        OptSpec::value("trace", None, "replay a workload CSV instead of generating"),
        OptSpec::value("replicas", Some("1"), "cluster replicas (>1 runs via the gateway)"),
        OptSpec::flag("gateway", "front the run with the QoE-aware gateway"),
        OptSpec::value(
            "autoscale",
            None,
            "elastic replicas as min:max (enables the gateway + autoscaler)",
        ),
        OptSpec::value(
            "spill-replicas",
            Some("0"),
            "spill-tier replicas replaying rejects (0 = no spill tier)",
        ),
        OptSpec::value(
            "gateways",
            Some("1"),
            "federated gateway instances fronting the cluster (>1 enables the gateway)",
        ),
        OptSpec::value(
            "sync-interval",
            Some("0.25"),
            "federation snapshot-exchange period (s)",
        ),
        OptSpec::value(
            "tier-weights",
            None,
            "per-tier admission weights premium:standard:economy (e.g. 2:1:0.5); \
             enables the gateway and the tiered QoE trace",
        ),
        OptSpec::value(
            "sessions",
            None,
            "multi-turn session workload: N sessions of 2-4 turns (enables the \
             gateway; --rate becomes session openings/s and --n is ignored)",
        ),
        OptSpec::flag("park", "park finished turns' KV for the session's next turn"),
        OptSpec::flag(
            "affinity",
            "route returning turns to the replica holding their parked prefix \
             (requires --park)",
        ),
        OptSpec::value("think", Some("4.0"), "mean think time between session turns (s)"),
        OptSpec::value(
            "network",
            None,
            "client-side delivery model: a profile (ideal|fiber|wifi|lte) or a \
             weighted mix like fiber:0.6,wifi:0.3,lte:0.1 (enables the gateway)",
        ),
        OptSpec::flag(
            "adaptive-lead",
            "grow the pacer lead from observed ack jitter instead of the static \
             lead (requires --network)",
        ),
        OptSpec::flag(
            "slack",
            "estimate client-buffer slack server-side and feed it to the \
             scheduler (enables the gateway; off = bit-identical baseline)",
        ),
        OptSpec::value(
            "trace-out",
            None,
            "write the per-request telemetry event trace as JSONL (enables the \
             gateway + telemetry; validate with `andes trace-validate`)",
        ),
        OptSpec::value(
            "metrics-out",
            None,
            "write periodic metric snapshots as CSV (enables the gateway + \
             telemetry; see DESIGN.md §12)",
        ),
        OptSpec::value(
            "snapshot-interval",
            Some("1.0"),
            "sim-seconds between metric snapshots for --metrics-out",
        ),
        OptSpec::value(
            "shards",
            Some("1"),
            "run this many seed replications (seed, seed+1, ...) across worker \
             threads, reported in seed order (plain engine runs only)",
        ),
    ];
    let about = "One simulated serving run";
    let args = match Args::parse(argv, &specs) {
        Ok(a) => a,
        Err(e) => return die_on_cli("simulate", about, &specs, e),
    };
    let llm = llm_by_name(args.get("model").unwrap()).unwrap_or_else(opt_66b);
    let gpu = gpu_by_name(args.get("gpu").unwrap()).unwrap_or_else(a100_4x);
    let sched = match args.get("sched").unwrap() {
        "fcfs" => experiments::runner::SchedKind::Fcfs,
        "rr" => experiments::runner::SchedKind::RoundRobin { quantum: 50 },
        _ => experiments::runner::SchedKind::andes_default(),
    };
    let dataset = Dataset::by_name(args.get("dataset").unwrap()).unwrap_or(Dataset::ShareGpt);

    // Cluster/gateway flags: --replicas > 1, --gateway, --autoscale, or
    // --spill-replicas route the trace through the serving gateway.
    let replicas = match args.get_usize("replicas") {
        Ok(Some(r)) => r.max(1),
        Ok(None) => 1,
        Err(e) => return die_on_cli("simulate", about, &specs, e),
    };
    let spill_replicas = match args.get_usize("spill-replicas") {
        Ok(Some(r)) => r,
        Ok(None) => 0,
        Err(e) => return die_on_cli("simulate", about, &specs, e),
    };
    let autoscale_arg = args.get("autoscale").map(str::to_string);
    let gateways = match args.get_usize("gateways") {
        Ok(Some(0)) => {
            eprintln!("--gateways must be >= 1");
            return 2;
        }
        Ok(Some(g)) => g,
        Ok(None) => 1,
        Err(e) => return die_on_cli("simulate", about, &specs, e),
    };
    let sync_interval = match args.get_f64("sync-interval") {
        Ok(Some(s)) if s > 0.0 => s,
        Ok(Some(_)) => {
            eprintln!("--sync-interval must be > 0");
            return 2;
        }
        Ok(None) => 0.25,
        Err(e) => return die_on_cli("simulate", about, &specs, e),
    };
    let tier_weights = match args.get("tier-weights") {
        Some(s) => match andes::gateway::TierWeights::parse(s) {
            Ok(w) => Some(w),
            Err(e) => {
                eprintln!("error: {e:#}");
                return 2;
            }
        },
        None => None,
    };
    let sessions = match args.get_usize("sessions") {
        Ok(s) => s,
        Err(e) => return die_on_cli("simulate", about, &specs, e),
    };
    let park = args.has_flag("park");
    let affinity = args.has_flag("affinity");
    if affinity && !park {
        eprintln!("--affinity requires --park (nothing is parked to route back to)");
        return 2;
    }
    let think = match args.get_f64("think") {
        Ok(Some(t)) if t >= 0.0 => t,
        Ok(_) => {
            eprintln!("--think must be >= 0");
            return 2;
        }
        Err(e) => return die_on_cli("simulate", about, &specs, e),
    };
    let network_mix = match args.get("network") {
        Some(s) => match andes::delivery::NetworkConfig::parse_mix(s) {
            Ok(mix) => Some(mix),
            Err(e) => {
                eprintln!("error: {e:#}");
                return 2;
            }
        },
        None => None,
    };
    let adaptive_lead = args.has_flag("adaptive-lead");
    if adaptive_lead && network_mix.is_none() {
        eprintln!("--adaptive-lead requires --network (nothing to observe jitter on)");
        return 2;
    }
    let slack = args.has_flag("slack");
    let trace_out = args.get("trace-out").map(PathBuf::from);
    let metrics_out = args.get("metrics-out").map(PathBuf::from);
    let snapshot_interval = match args.get_f64("snapshot-interval") {
        Ok(Some(s)) if s > 0.0 => s,
        Ok(Some(_)) => {
            eprintln!("--snapshot-interval must be > 0");
            return 2;
        }
        Ok(None) => 1.0,
        Err(e) => return die_on_cli("simulate", about, &specs, e),
    };
    let shards = match args.get_usize("shards") {
        Ok(Some(0)) => {
            eprintln!("--shards must be >= 1");
            return 2;
        }
        Ok(Some(s)) => s,
        Ok(None) => 1,
        Err(e) => return die_on_cli("simulate", about, &specs, e),
    };
    let telemetry_on = trace_out.is_some() || metrics_out.is_some();
    let use_gateway = args.has_flag("gateway")
        || autoscale_arg.is_some()
        || spill_replicas > 0
        || replicas > 1
        || gateways > 1
        || tier_weights.is_some()
        || sessions.is_some()
        || park
        || network_mix.is_some()
        || slack
        || telemetry_on;
    if telemetry_on && gateways > 1 {
        eprintln!(
            "--trace-out/--metrics-out instrument the single-gateway path; they \
             cannot be combined with --gateways > 1"
        );
        return 2;
    }
    if gateways > 1 && (autoscale_arg.is_some() || spill_replicas > 0) {
        eprintln!(
            "--gateways > 1 fronts a static cluster; it cannot be combined with \
             --autoscale or --spill-replicas (those are single-gateway features)"
        );
        return 2;
    }
    if gateways > 1 && (sessions.is_some() || park) {
        eprintln!(
            "--gateways > 1 cannot be combined with --sessions/--park: prefix \
             parking and affinity are single-gateway features"
        );
        return 2;
    }
    if shards > 1 && (use_gateway || args.get("trace").is_some()) {
        eprintln!(
            "--shards > 1 fans seed replications of the plain engine run across \
             threads; it cannot be combined with gateway modes or --trace"
        );
        return 2;
    }

    // Trace replay path: run the exact recorded workload.
    if let Some(path) = args.get("trace") {
        if use_gateway {
            eprintln!(
                "--trace replays a recorded workload on a single static engine; \
                 it cannot be combined with --gateway/--replicas/--autoscale/\
                 --spill-replicas/--gateways/--tier-weights/--sessions/--park/\
                 --network/--slack"
            );
            return 2;
        }
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("reading {path}: {e}");
                return 1;
            }
        };
        let trace = match andes::workload::parse_trace_csv(&text) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("parsing {path}: {e:#}");
                return 1;
            }
        };
        use andes::backend::sim::SimBackend;
        use andes::backend::VirtualClock;
        use andes::coordinator::engine::{Engine, EngineConfig};
        let latency = andes::model::latency::LatencyModel::for_deployment(&llm, &gpu);
        let cfg = EngineConfig {
            kv_capacity_tokens: llm.kv_capacity_tokens(&gpu),
            swap_capacity_tokens: llm.swap_capacity_tokens(&gpu),
            ..EngineConfig::default()
        };
        let mut e = Engine::new(
            cfg,
            SimBackend::new(latency.clone()),
            VirtualClock::default(),
            sched.build(),
            latency,
        );
        e.load_trace(trace);
        match e.run_to_completion() {
            Ok(m) => {
                println!("{}", m.summary());
                return 0;
            }
            Err(err) => {
                eprintln!("error: {err:#}");
                return 1;
            }
        }
    }

    // Gateway path: reports replica-seconds alongside QoE.
    if use_gateway {
        use andes::cluster::{Cluster, RoutingPolicy};
        use andes::coordinator::engine::EngineConfig;
        use andes::gateway::{
            AutoscaleConfig, FederatedGateway, FederationConfig, Gateway, GatewayConfig,
            SpillConfig,
        };

        let sched_cfg = match args.get("sched").unwrap() {
            "fcfs" => andes::config::SchedulerConfig::Fcfs,
            "rr" => andes::config::SchedulerConfig::RoundRobin { quantum: 50 },
            _ => andes::config::SchedulerConfig::Andes(Default::default()),
        };
        let latency = andes::model::latency::LatencyModel::for_deployment(&llm, &gpu);
        let mut engine_cfg = EngineConfig {
            kv_capacity_tokens: llm.kv_capacity_tokens(&gpu),
            swap_capacity_tokens: llm.swap_capacity_tokens(&gpu),
            park_prefixes: park,
            ..EngineConfig::default()
        };
        let per_replica = experiments::runner::estimate_capacity(&llm, &gpu, dataset);
        let mut gcfg = GatewayConfig::default();
        if let Some(spec) = autoscale_arg.as_deref() {
            let parsed: Option<(usize, usize)> = spec.split_once(':').and_then(|(lo, hi)| {
                let lo = lo.trim().parse().ok()?;
                let hi = hi.trim().parse().ok()?;
                Some((lo, hi))
            });
            let (min_r, max_r) = match parsed {
                Some((lo, hi)) if lo >= 1 && lo <= hi => (lo, hi),
                _ => {
                    eprintln!("--autoscale expects min:max with 1 <= min <= max");
                    return 2;
                }
            };
            gcfg.autoscale = AutoscaleConfig {
                enabled: true,
                min_replicas: min_r,
                max_replicas: max_r,
                replica_capacity: per_replica,
                ..AutoscaleConfig::default()
            };
        }
        // Surge baseline reflects the tier's reachable capacity: for an
        // elastic tier that is the autoscale ceiling, not the starting
        // replica count — otherwise the detector sheds during the very
        // cold starts the autoscaler exists to cover.
        let cap_replicas = if gcfg.autoscale.enabled {
            gcfg.autoscale.max_replicas.max(replicas)
        } else {
            replicas
        };
        gcfg.surge.baseline_rate = (per_replica * cap_replicas as f64).max(0.1);
        // With autoscale, start at least at the floor of the range.
        let start_replicas = if gcfg.autoscale.enabled {
            replicas.max(gcfg.autoscale.min_replicas)
        } else {
            replicas
        };
        if let Some(w) = tier_weights {
            gcfg.admission.tier_weights = w;
        }
        if let Some(mix) = network_mix.clone() {
            gcfg.network.enabled = true;
            gcfg.network.mix = mix;
            gcfg.network.adaptive_lead = adaptive_lead;
        }
        // After the pacing/network knobs are final: the slack estimator
        // mirrors the gateway's release schedule and expected transit.
        if slack {
            engine_cfg.slack = Some(gcfg.slack_config());
        }
        let mut cluster = Cluster::new(
            start_replicas,
            engine_cfg.clone(),
            latency.clone(),
            &sched_cfg,
            RoutingPolicy::QoeAware,
        );
        cluster.set_session_affinity(affinity);
        // Telemetry rides the sim clock here; snapshots only tick when
        // a CSV sink was requested.
        let telemetry = if telemetry_on {
            andes::telemetry::Telemetry::new(&andes::telemetry::TelemetryConfig {
                enabled: true,
                snapshot_interval: if metrics_out.is_some() { snapshot_interval } else { 0.0 },
                ..Default::default()
            })
        } else {
            andes::telemetry::Telemetry::disabled()
        };
        telemetry.set_time_domain("sim");
        cluster.set_telemetry(telemetry.clone());
        // Tier weights only bite on a tiered workload.
        let qoe_trace = if tier_weights.is_some() {
            QoeTrace::Tiered
        } else {
            QoeTrace::TextReading
        };
        let rate = args.get_f64("rate").unwrap().unwrap();
        let seed = args.get_u64("seed").unwrap().unwrap();
        let trace = match sessions {
            Some(num_sessions) => SessionWorkload {
                num_sessions,
                arrivals: ArrivalProcess::Poisson { rate },
                qoe_trace,
                min_turns: 2,
                max_turns: 4,
                think_time_mean: think,
                seed,
            }
            .generate(),
            None => Workload {
                dataset,
                arrivals: ArrivalProcess::Poisson { rate },
                qoe_trace,
                num_requests: args.get_usize("n").unwrap().unwrap(),
                seed,
            }
            .generate(),
        };

        // Federated front door: N gateway instances over the cluster.
        if gateways > 1 {
            let fed = FederationConfig {
                gateways,
                sync_interval_secs: sync_interval,
                ..FederationConfig::default()
            };
            let mut gw = FederatedGateway::new(cluster, gcfg, fed);
            return match gw.run_trace(trace) {
                Ok(res) => {
                    println!(
                        "federation: gateways={} arrivals={} served={} rejected={} \
                         deferred={} mean_qoe={:.3} incl_rejects={:.3} \
                         disagreement_rate={:.3} syncs={} forced_refreshes={} \
                         replica_seconds={:.1}",
                        gateways,
                        res.stats.arrivals,
                        res.served.len(),
                        res.rejections.len(),
                        res.stats.deferred,
                        res.mean_served_qoe(),
                        res.mean_qoe_incl_rejects(),
                        res.stats.disagreement_rate(),
                        res.stats.syncs,
                        res.stats.forced_refreshes,
                        res.replica_seconds,
                    );
                    if network_mix.is_some() && !res.served.is_empty() {
                        let n = res.served.len() as f64;
                        let client: f64 =
                            res.served.iter().map(|s| s.client_qoe).sum::<f64>() / n;
                        let stalls: usize =
                            res.served.iter().map(|s| s.stall_count).sum();
                        let stall_time: f64 =
                            res.served.iter().map(|s| s.stall_time).sum();
                        let rtx: usize =
                            res.served.iter().map(|s| s.retransmits).sum();
                        println!(
                            "delivery: client_qoe={client:.3} qoe_gap={:.3} \
                             stalls={stalls} stall_time={stall_time:.1}s \
                             retransmits={rtx} adaptive_lead={adaptive_lead}",
                            res.mean_served_qoe() - client,
                        );
                    }
                    0
                }
                Err(e) => {
                    eprintln!("error: {e:#}");
                    1
                }
            };
        }

        let mut gw = if spill_replicas > 0 {
            let spill =
                SpillConfig { enabled: true, replicas: spill_replicas, kv_fraction: 0.5 }
                    .build_cluster(&engine_cfg, &latency, &sched_cfg);
            Gateway::with_spill(cluster, gcfg, spill)
        } else {
            Gateway::new(cluster, gcfg)
        };
        gw.set_telemetry(telemetry.clone());
        return match gw.run_trace(trace) {
            Ok(res) => {
                println!(
                    "gateway: arrivals={} served={} spilled={} rejected={} deferred={} \
                     mean_qoe={:.3} incl_rejects={:.3} replica_seconds={:.1} (spill {:.1}) \
                     scale_outs={} scale_ins={}",
                    res.stats.arrivals,
                    res.served.len(),
                    res.spilled.len(),
                    res.rejections.len(),
                    res.stats.deferred,
                    res.mean_served_qoe(),
                    res.mean_qoe_incl_rejects(),
                    res.replica_seconds,
                    res.spill_replica_seconds,
                    res.stats.scale_out_requests,
                    res.stats.scale_ins,
                );
                if network_mix.is_some() {
                    println!(
                        "delivery: client_qoe={:.3} qoe_gap={:.3} stalls={} \
                         stall_time={:.1}s retransmits={} disconnects={} \
                         adaptive_lead={}",
                        res.mean_client_qoe(),
                        res.client_qoe_gap(),
                        res.total_stalls(),
                        res.total_stall_time(),
                        res.total_retransmits(),
                        res.total_disconnects(),
                        adaptive_lead,
                    );
                }
                if slack {
                    let deep: u64 = res
                        .per_replica
                        .iter()
                        .map(|m| m.deep_buffer_preemptions)
                        .sum();
                    println!("slack: deep_buffer_preemptions={deep}");
                }
                if sessions.is_some() || park {
                    let hits: u64 = res.per_replica.iter().map(|m| m.prefix_hits).sum();
                    let parked: u64 =
                        res.per_replica.iter().map(|m| m.prefixes_parked).sum();
                    let evicted: u64 =
                        res.per_replica.iter().map(|m| m.park_evictions).sum();
                    println!(
                        "sessions: prefixes_parked={parked} prefix_hits={hits} \
                         park_evictions={evicted} affinity={affinity}"
                    );
                }
                if let Some(p) = &trace_out {
                    if let Err(e) = std::fs::write(p, gw.telemetry().trace_jsonl()) {
                        eprintln!("writing {}: {e}", p.display());
                        return 1;
                    }
                    let (buffered, open, dropped) = gw.telemetry().trace_stats();
                    eprintln!(
                        "wrote {} ({buffered} events, {open} open spans, \
                         {dropped} evicted spans)",
                        p.display()
                    );
                }
                if let Some(p) = &metrics_out {
                    if let Err(e) = std::fs::write(p, gw.telemetry().snapshot_csv()) {
                        eprintln!("writing {}: {e}", p.display());
                        return 1;
                    }
                    eprintln!(
                        "wrote {} ({} snapshot rows)",
                        p.display(),
                        gw.telemetry().snapshot_rows_len()
                    );
                }
                0
            }
            Err(e) => {
                eprintln!("error: {e:#}");
                1
            }
        };
    }

    let run = experiments::runner::SimRun {
        llm,
        gpu,
        sched,
        dataset,
        arrivals: ArrivalProcess::Poisson { rate: args.get_f64("rate").unwrap().unwrap() },
        qoe_trace: QoeTrace::TextReading,
        num_requests: args.get_usize("n").unwrap().unwrap(),
        seed: args.get_u64("seed").unwrap().unwrap(),
    };
    if shards > 1 {
        // Seed replications sharded across threads; summaries print in
        // seed order regardless of which worker finished first.
        let seeds: Vec<u64> = (0..shards as u64).map(|i| run.seed + i).collect();
        let summaries = experiments::shard::run_grid(&seeds, shards, |_, &seed| {
            experiments::runner::SimRun { seed, ..run.clone() }.execute().summary()
        });
        for (seed, summary) in seeds.iter().zip(&summaries) {
            println!("--- seed {seed} ---\n{summary}");
        }
        return 0;
    }
    let m = run.execute();
    println!("{}", m.summary());
    0
}
