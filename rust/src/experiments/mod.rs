//! Experiment harness: one entry per table/figure in the paper
//! (DESIGN.md §5 maps each to its module). `andes exp <id|all>` runs
//! them, writing CSVs + ASCII renderings under the output directory and
//! printing a shape-check verdict per artifact.

pub mod autoscale;
pub mod breakdown;
pub mod endtoend;
pub mod extensions;
pub mod federation;
pub mod gateway;
pub mod micro;
pub mod motivation;
pub mod network;
pub mod robustness;
pub mod runner;
pub mod sensitivity;
pub mod sessions;
pub mod shard;
pub mod slack;

use std::path::PathBuf;

use anyhow::Result;

use crate::workload::Dataset;

/// Execution context shared by all experiments.
pub struct ExpCtx {
    pub out_dir: PathBuf,
    /// Reduced request counts / grids for smoke runs.
    pub quick: bool,
    /// When set, instrumented experiments (currently `ext-gateway`)
    /// export their telemetry event trace as JSONL here, plus periodic
    /// metric snapshots next to it (`<stem>.metrics.csv`).
    pub trace_out: Option<PathBuf>,
    /// Worker threads for grid-sharded experiments ([`shard::run_grid`]);
    /// 1 runs every cell inline. Outputs are identical at any value.
    pub shards: usize,
}

/// One registered experiment.
pub struct Experiment {
    pub id: &'static str,
    pub paper_ref: &'static str,
    pub title: &'static str,
    pub run: fn(&ExpCtx) -> Result<String>,
}

/// The full registry, in paper order.
pub fn registry() -> Vec<Experiment> {
    vec![
        Experiment {
            id: "fig2",
            paper_ref: "Fig. 2",
            title: "QoE intuition: four delivery timelines",
            run: motivation::fig2,
        },
        Experiment {
            id: "fig3",
            paper_ref: "Fig. 3",
            title: "Motivation: TTFT explosion & overfast generation (FCFS)",
            run: motivation::fig3,
        },
        Experiment {
            id: "fig4",
            paper_ref: "Fig. 4",
            title: "Toy example: FCFS vs RR vs QoE-aware",
            run: motivation::fig4,
        },
        Experiment {
            id: "fig5",
            paper_ref: "Fig. 5",
            title: "QoE metric worked example",
            run: motivation::fig5,
        },
        Experiment {
            id: "fig7",
            paper_ref: "Fig. 7",
            title: "Q_serve as a function of batch size",
            run: motivation::fig7,
        },
        Experiment {
            id: "fig9",
            paper_ref: "Fig. 9",
            title: "Dataset length distributions",
            run: motivation::fig9,
        },
        Experiment {
            id: "fig10",
            paper_ref: "Fig. 10",
            title: "Avg QoE vs rate, ShareGPT, 4 models",
            run: |ctx| endtoend::fig10_11(ctx, Dataset::ShareGpt),
        },
        Experiment {
            id: "fig11",
            paper_ref: "Fig. 11",
            title: "Avg QoE vs rate, Multi-Round ShareGPT",
            run: |ctx| endtoend::fig10_11(ctx, Dataset::MultiRoundShareGpt),
        },
        Experiment {
            id: "fig12",
            paper_ref: "Figs. 12–13",
            title: "Throughput & preemption frequency (OPT-66B)",
            run: endtoend::fig12_13,
        },
        Experiment {
            id: "tab4",
            paper_ref: "Table 4",
            title: "QoE / TTFT / TDS percentile breakdown",
            run: breakdown::tab4,
        },
        Experiment {
            id: "fig14",
            paper_ref: "Fig. 14",
            title: "QoE vs total length scatter",
            run: breakdown::fig14,
        },
        Experiment {
            id: "fig15a",
            paper_ref: "Fig. 15a",
            title: "Robustness: A40 hardware",
            run: robustness::fig15a,
        },
        Experiment {
            id: "fig15b",
            paper_ref: "Fig. 15b",
            title: "Robustness: bursty Gamma arrivals",
            run: robustness::fig15b,
        },
        Experiment {
            id: "fig15c",
            paper_ref: "Fig. 15c",
            title: "Robustness: voice-chat QoE trace",
            run: robustness::fig15c,
        },
        Experiment {
            id: "fig16",
            paper_ref: "Fig. 16",
            title: "Sensitivity: preemption cap P",
            run: sensitivity::fig16,
        },
        Experiment {
            id: "fig17",
            paper_ref: "Fig. 17",
            title: "Sensitivity: prediction timeframe Δt",
            run: sensitivity::fig17,
        },
        Experiment {
            id: "fig18",
            paper_ref: "Fig. 18",
            title: "Sensitivity: greedy vs DP knapsack",
            run: sensitivity::fig18,
        },
        Experiment {
            id: "fig19",
            paper_ref: "Fig. 19 / App. B",
            title: "Batch size vs total context correlation",
            run: breakdown::fig19,
        },
        Experiment {
            id: "fig20",
            paper_ref: "Fig. 20 / App. D",
            title: "Swap vs recomputation overhead",
            run: micro::fig20,
        },
        Experiment {
            id: "fig21",
            paper_ref: "Fig. 21 / App. E",
            title: "Normalized latency",
            run: endtoend::fig21,
        },
        Experiment {
            id: "fig22",
            paper_ref: "Fig. 22 / App. F",
            title: "Token delivery timeline visualization",
            run: breakdown::fig22,
        },
        Experiment {
            id: "appA",
            paper_ref: "Appendix A",
            title: "Alternative scheduling objectives",
            run: sensitivity::app_a,
        },
        Experiment {
            id: "ext-tiers",
            paper_ref: "§6.1 (extension)",
            title: "API price tiers: per-tier QoE contracts",
            run: extensions::ext_tiers,
        },
        Experiment {
            id: "ext-cluster",
            paper_ref: "§5 (extension)",
            title: "Cluster routing policies × per-replica scheduling",
            run: extensions::ext_cluster,
        },
        Experiment {
            id: "ext-gateway",
            paper_ref: "§5 (extension)",
            title: "QoE-aware gateway: admission, pacing, surge routing",
            run: gateway::ext_gateway,
        },
        Experiment {
            id: "ext-autoscale",
            paper_ref: "§7.4 (extension)",
            title: "Predictive autoscaling + spill tier: QoE vs replica-seconds",
            run: autoscale::ext_autoscale,
        },
        Experiment {
            id: "ext-federation",
            paper_ref: "§6.1 (extension)",
            title: "Multi-gateway federation × per-tier admission weights",
            run: federation::ext_federation,
        },
        Experiment {
            id: "ext-sessions",
            paper_ref: "§2 (extension)",
            title: "Multi-turn sessions: KV prefix retention × affinity routing",
            run: sessions::ext_sessions,
        },
        Experiment {
            id: "ext-network",
            paper_ref: "§2.2 (extension)",
            title: "Client-side delivery: network jitter × adaptive pacer lead",
            run: network::ext_network,
        },
        Experiment {
            id: "ext-slack",
            paper_ref: "§2.3 (extension)",
            title: "Buffer-slack-aware scheduling: slack-aware vs slack-blind Andes",
            run: slack::ext_slack,
        },
        Experiment {
            id: "e2e",
            paper_ref: "—",
            title: "End-to-end real model over PJRT",
            run: micro::e2e_real,
        },
    ]
}

/// Run one experiment by id (or "all"). Returns the combined report.
pub fn run(id: &str, ctx: &ExpCtx) -> Result<String> {
    std::fs::create_dir_all(&ctx.out_dir)?;
    let registry = registry();
    let mut report = String::new();
    let mut matched = false;
    for exp in &registry {
        if id == "all" || id == exp.id {
            matched = true;
            // lint:allow(D2, operator-facing wall-time per experiment, not a sim input)
            let t0 = std::time::Instant::now();
            report.push_str(&format!(
                "\n================ {} [{}] {} ================\n",
                exp.id, exp.paper_ref, exp.title
            ));
            match (exp.run)(ctx) {
                Ok(r) => report.push_str(&r),
                Err(e) => report.push_str(&format!("ERROR: {e:#}\n")),
            }
            report.push_str(&format!("({:.1}s)\n", t0.elapsed().as_secs_f64()));
        }
    }
    if !matched {
        anyhow::bail!(
            "unknown experiment '{id}'; available: all, {}",
            registry.iter().map(|e| e.id).collect::<Vec<_>>().join(", ")
        );
    }
    Ok(report)
}
