//! ext-autoscale: the QoE-vs-resource tradeoff at cluster scale.
//!
//! The paper's efficiency headline — equal QoE at far fewer GPUs —
//! requires the serving tier to be elastic rather than provisioned for
//! the peak. This experiment sweeps four provisioning strategies over
//! Poisson and Gamma-burst (cv = 3) arrivals at a rate that needs ~2–3
//! replicas on average but bursts past a single replica's capacity:
//!
//! - **static-min** — 1 replica, the cheapest fixed tier;
//! - **static-max** — 4 replicas, peak provisioning (the QoE ceiling);
//! - **autoscale** — elastic 1..4 replicas driven by the gateway's
//!   predictive autoscaler (cold-start lead, scale-in hysteresis);
//! - **autoscale+spill** — elastic primary plus a half-size overflow
//!   replica that replays shed/saturated/timed-out requests.
//!
//! Reported per cell: mean QoE counting rejects as zero, rejected
//! fraction, and **replica-seconds** (primary, spill, and cost-weighted
//! total where a spill replica is charged at its `kv_fraction`). The
//! shape checks assert the paper's tradeoff: autoscale+spill holds mean
//! QoE within 5% of static-max while consuming measurably fewer
//! replica-seconds.

use anyhow::Result;

use crate::cluster::{Cluster, RoutingPolicy};
use crate::config::SchedulerConfig;
use crate::coordinator::engine::EngineConfig;
use crate::coordinator::sched::andes::AndesConfig;
use crate::gateway::{AutoscaleConfig, Gateway, GatewayConfig, SpillConfig};
use crate::model::gpu::a100_4x;
use crate::model::latency::LatencyModel;
use crate::model::llm::opt_66b;
use crate::util::csv::Csv;
use crate::workload::{ArrivalProcess, Dataset, QoeTrace, Workload};

use super::runner::estimate_capacity;
use super::ExpCtx;

const SPILL_COST_WEIGHT: f64 = 0.5; // == kv_fraction of the spill tier

struct Cell {
    arrivals: &'static str,
    variant: &'static str,
    mean_qoe: f64,
    reject_frac: f64,
    /// Cost-weighted replica-seconds (primary + weight × spill).
    cost: f64,
}

pub fn ext_autoscale(ctx: &ExpCtx) -> Result<String> {
    let llm = opt_66b();
    let gpu = a100_4x();
    let latency = LatencyModel::for_deployment(&llm, &gpu);
    let per_replica = estimate_capacity(&llm, &gpu, Dataset::ShareGpt);
    let (min_r, max_r) = (1usize, 4usize);
    let n = if ctx.quick { 240 } else { 600 };
    // Mean load plans out to ~2 replicas; Gamma bursts transiently need
    // more, and a single static replica runs near its empirical knee.
    let rate = per_replica * 1.5;
    let engine_cfg = EngineConfig {
        kv_capacity_tokens: llm.kv_capacity_tokens(&gpu),
        swap_capacity_tokens: llm.swap_capacity_tokens(&gpu),
        ..EngineConfig::default()
    };
    let sched = SchedulerConfig::Andes(AndesConfig::default());
    let autoscale_cfg = AutoscaleConfig {
        enabled: true,
        min_replicas: min_r,
        max_replicas: max_r,
        replica_capacity: per_replica,
        // The analytic estimate is ~1.6× conservative vs the empirical
        // knee, so planning at 0.8 of it still leaves ~2× real headroom
        // (1.5× load / 0.8 → a steady-state target of 2 replicas).
        target_utilization: 0.8,
        cold_start_secs: 5.0,
        scale_in_hold_secs: 20.0,
        kv_high_watermark: 0.85,
        eval_interval_secs: 0.5,
    };
    let spill_cfg = SpillConfig {
        enabled: true,
        replicas: 1,
        kv_fraction: SPILL_COST_WEIGHT,
    };
    let variants: [(&'static str, bool, bool, usize); 4] = [
        ("static-min", false, false, min_r),
        ("static-max", false, false, max_r),
        ("autoscale", true, false, min_r),
        ("autoscale+spill", true, true, min_r),
    ];
    let mut csv = Csv::new(&[
        "arrivals",
        "variant",
        "served",
        "spilled",
        "rejected",
        "reject_frac",
        "mean_served_qoe",
        "mean_qoe_incl_rejects",
        "replica_seconds",
        "spill_replica_seconds",
        "cost_weighted_replica_seconds",
        "scale_out_requests",
        "scale_ins",
    ]);
    let mut report = format!(
        "ext-autoscale — elastic {min_r}..{max_r} replicas, \
         per-replica capacity ≈ {per_replica:.2} req/s, rate {rate:.2} req/s\n"
    );
    let mut cells: Vec<Cell> = Vec::new();

    for (alabel, cv) in [("poisson", 1.0), ("gamma-cv3", 3.0)] {
        let trace = Workload {
            dataset: Dataset::ShareGpt,
            arrivals: if cv == 1.0 {
                ArrivalProcess::Poisson { rate }
            } else {
                ArrivalProcess::Gamma { rate, cv }
            },
            qoe_trace: QoeTrace::TextReading,
            num_requests: n,
            seed: 42,
        }
        .generate();
        for &(vname, elastic, spill, start_replicas) in &variants {
            let cluster = Cluster::new(
                start_replicas,
                engine_cfg.clone(),
                latency.clone(),
                &sched,
                RoutingPolicy::QoeAware,
            );
            let mut gcfg = GatewayConfig::default();
            gcfg.pacing_enabled = false;
            // Baseline = the mean provisioning level: Surge only for
            // genuine bursts beyond it.
            gcfg.surge.baseline_rate = rate;
            if elastic {
                gcfg.autoscale = autoscale_cfg.clone();
            }
            let mut gw = if spill {
                let overflow = spill_cfg.build_cluster(&engine_cfg, &latency, &sched);
                Gateway::with_spill(cluster, gcfg, overflow)
            } else {
                Gateway::new(cluster, gcfg)
            };
            let res = gw.run_trace(trace.clone())?;
            let cost = res.replica_seconds + SPILL_COST_WEIGHT * res.spill_replica_seconds;
            let cell = Cell {
                arrivals: alabel,
                variant: vname,
                mean_qoe: res.mean_qoe_incl_rejects(),
                reject_frac: res.rejected_fraction(),
                cost,
            };
            csv.row(&[
                alabel.to_string(),
                vname.to_string(),
                format!("{}", res.served.len()),
                format!("{}", res.spilled.len()),
                format!("{}", res.rejections.len()),
                format!("{:.4}", cell.reject_frac),
                format!("{:.4}", res.mean_served_qoe()),
                format!("{:.4}", cell.mean_qoe),
                format!("{:.1}", res.replica_seconds),
                format!("{:.1}", res.spill_replica_seconds),
                format!("{cost:.1}"),
                format!("{}", res.stats.scale_out_requests),
                format!("{}", res.stats.scale_ins),
            ]);
            report.push_str(&format!(
                "  {alabel:<10} {vname:<16} served {:<4} spilled {:<4} rejected {:<4} \
                 QoE {:.3} (incl-rej) cost {:.0} rs (primary {:.0} + spill {:.0})\n",
                res.served.len(),
                res.spilled.len(),
                res.rejections.len(),
                cell.mean_qoe,
                cost,
                res.replica_seconds,
                res.spill_replica_seconds,
            ));
            cells.push(cell);
        }
    }
    csv.write(&ctx.out_dir.join("ext_autoscale.csv"))?;

    // Shape checks: the QoE-vs-resource tradeoff, per arrival process.
    for alabel in ["poisson", "gamma-cv3"] {
        let smin = find(&cells, "static-min", alabel);
        let smax = find(&cells, "static-max", alabel);
        let auto = find(&cells, "autoscale", alabel);
        let spill = find(&cells, "autoscale+spill", alabel);
        let c1 = spill.mean_qoe >= 0.95 * smax.mean_qoe;
        let c2 = spill.cost < 0.9 * smax.cost;
        let c3 = smin.mean_qoe < auto.mean_qoe;
        let c4 = spill.reject_frac <= auto.reject_frac;
        report.push_str(&format!(
            "shape checks @{alabel}:\n\
             \x20 autoscale+spill QoE within 5% of static-max ({:.3} vs {:.3}): {}\n\
             \x20 autoscale+spill cost < 90% of static-max ({:.0} vs {:.0} rs): {}\n\
             \x20 static-min QoE below autoscale ({:.3} vs {:.3}): {}\n\
             \x20 spill does not increase rejected fraction ({:.3} vs {:.3}): {}\n",
            spill.mean_qoe,
            smax.mean_qoe,
            verdict(c1),
            spill.cost,
            smax.cost,
            verdict(c2),
            smin.mean_qoe,
            auto.mean_qoe,
            verdict(c3),
            spill.reject_frac,
            auto.reject_frac,
            verdict(c4),
        ));
    }
    Ok(report)
}

fn verdict(ok: bool) -> &'static str {
    if ok {
        "HOLDS"
    } else {
        "VIOLATED"
    }
}

fn find<'a>(cells: &'a [Cell], variant: &str, arrivals: &str) -> &'a Cell {
    cells
        .iter()
        .find(|c| c.variant == variant && c.arrivals == arrivals)
        .expect("cell missing")
}
