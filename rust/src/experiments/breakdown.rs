//! Breakdown analyses: Table 4 (percentiles), Fig. 14 (QoE vs length
//! scatter), Fig. 19 (batch/context correlation), Fig. 22 (TDT
//! visualization).

use anyhow::Result;

use crate::model::gpu::a100_4x;
use crate::model::llm::opt_66b;
use crate::util::csv::Csv;
use crate::util::stats::percentile;
use crate::workload::{ArrivalProcess, Dataset, QoeTrace};

use super::runner::{SchedKind, SimRun};
use super::ExpCtx;

fn run_at_eval_rate(ctx: &ExpCtx, sched: SchedKind) -> crate::coordinator::metrics::Metrics {
    let llm = opt_66b();
    let gpu = a100_4x();
    // The paper's breakdown uses OPT-66B at 3.3 req/s where Andes scored
    // 0.92 — i.e. just past FCFS's capacity. Mirror that: 1.15× capacity.
    let rate = super::runner::eval_rate(&llm, &gpu, Dataset::ShareGpt);
    SimRun {
        llm,
        gpu,
        sched,
        dataset: Dataset::ShareGpt,
        arrivals: ArrivalProcess::Poisson { rate },
        qoe_trace: QoeTrace::TextReading,
        num_requests: if ctx.quick { 600 } else { 1500 },
        seed: 42,
    }
    .execute()
}

/// Table 4: QoE / TTFT / TDS percentiles, vLLM vs Andes.
pub fn tab4(ctx: &ExpCtx) -> Result<String> {
    let fcfs = run_at_eval_rate(ctx, SchedKind::Fcfs);
    let andes = run_at_eval_rate(ctx, SchedKind::andes_default());

    let mut csv = Csv::new(&["metric", "percentile", "vLLM", "Andes"]);
    let mut report = String::from(
        "Table 4 — percentile breakdown (OPT-66B, ShareGPT, 1.15× capacity)\n\
         metric        pct    vLLM      Andes\n",
    );
    let sections: Vec<(&str, Vec<f64>, Vec<f64>, Vec<f64>)> = vec![
        ("QoE", vec![10.0, 50.0, 90.0], fcfs.qoes(), andes.qoes()),
        ("TTFT (s)", vec![10.0, 50.0, 90.0], fcfs.ttfts(), andes.ttfts()),
        ("TDS (tok/s)", vec![10.0, 50.0, 90.0], fcfs.tds_values(), andes.tds_values()),
    ];
    for (metric, pcts, f, a) in &sections {
        for &p in pcts {
            let vf = percentile(f, p);
            let va = percentile(a, p);
            csv.row(&[
                metric.to_string(),
                format!("p{p:.0}"),
                format!("{vf:.2}"),
                format!("{va:.2}"),
            ]);
            report.push_str(&format!("{metric:<13} p{p:<4.0} {vf:>8.2} {va:>9.2}\n"));
        }
    }
    csv.write(&ctx.out_dir.join("tab4_breakdown.csv"))?;
    let ttft_gain = percentile(&fcfs.ttfts(), 50.0) / percentile(&andes.ttfts(), 50.0).max(1e-9);
    let qoe_p10_better =
        percentile(&andes.qoes(), 10.0) > percentile(&fcfs.qoes(), 10.0);
    let tds_ok = percentile(&andes.tds_values(), 50.0) >= 3.3;
    report.push_str(&format!(
        "shape check: median TTFT improvement {ttft_gain:.0}×, p10 QoE better: {}, median TDS ≥ speaking speed: {}\n",
        if qoe_p10_better { "HOLDS" } else { "VIOLATED" },
        if tds_ok { "HOLDS" } else { "VIOLATED" },
    ));
    Ok(report)
}

/// Fig. 14: final QoE vs total (prompt+output) length scatter.
pub fn fig14(ctx: &ExpCtx) -> Result<String> {
    let fcfs = run_at_eval_rate(ctx, SchedKind::Fcfs);
    let andes = run_at_eval_rate(ctx, SchedKind::andes_default());
    let mut csv = Csv::new(&["scheduler", "total_len", "qoe"]);
    for (label, m) in [("vLLM-FCFS", &fcfs), ("Andes", &andes)] {
        for r in &m.requests {
            csv.row(&[
                label.to_string(),
                format!("{}", r.total_len()),
                format!("{:.4}", r.final_qoe),
            ]);
        }
    }
    csv.write(&ctx.out_dir.join("fig14_qoe_vs_length.csv"))?;

    // Starvation profile: QoE of short vs long requests.
    let split = |m: &crate::coordinator::metrics::Metrics| {
        let mut short = Vec::new();
        let mut long = Vec::new();
        for r in &m.requests {
            if r.total_len() < 400 {
                short.push(r.final_qoe);
            } else {
                long.push(r.final_qoe);
            }
        }
        (crate::util::stats::mean(&short), crate::util::stats::mean(&long))
    };
    let (fs, fl) = split(&fcfs);
    let (as_, al) = split(&andes);
    let report = format!(
        "Fig. 14 — QoE vs total length\n  vLLM-FCFS: short-req avg QoE {fs:.3}, long-req {fl:.3}\n  Andes:     short-req avg QoE {as_:.3}, long-req {al:.3}\n  shape check (FCFS hurts short requests more than Andes does): {}\n",
        if as_ > fs { "HOLDS" } else { "VIOLATED" }
    );
    Ok(report)
}

/// Fig. 19 (Appendix B): batch size vs total context length correlation
/// over decode iterations of an FCFS run.
pub fn fig19(ctx: &ExpCtx) -> Result<String> {
    let m = run_at_eval_rate(ctx, SchedKind::Fcfs);
    let mut csv = Csv::new(&["batch_size", "total_ctx"]);
    for s in m.iterations.iter().filter(|s| !s.is_prefill) {
        csv.row_f64(&[s.batch_size as f64, s.total_ctx as f64]);
    }
    csv.write(&ctx.out_dir.join("fig19_batch_ctx.csv"))?;
    let r = m.batch_ctx_correlation();
    Ok(format!(
        "Fig. 19 — Pearson r(batch size, total context) = {r:.4} over {} decode iterations\n  shape check (r ≈ 0.99, paper: 0.997): {}\n",
        m.iterations.len(),
        if r > 0.95 { "HOLDS" } else { "VIOLATED" }
    ))
}

/// Fig. 22 (Appendix F): accumulated-token timelines of sampled
/// requests, FCFS vs Andes, against the expected TDT.
pub fn fig22(ctx: &ExpCtx) -> Result<String> {
    let fcfs = run_at_eval_rate(ctx, SchedKind::Fcfs);
    let andes = run_at_eval_rate(ctx, SchedKind::andes_default());
    let mut csv = Csv::new(&["scheduler", "request", "t_rel", "tokens"]);
    let mut report = String::from("Fig. 22 — token delivery timelines (sampled)\n");
    for (label, m) in [("vLLM-FCFS", &fcfs), ("Andes", &andes)] {
        // Sample ~3% of requests with the modal QoE spec.
        let sampled: Vec<_> = m.requests.iter().filter(|r| r.id % 33 == 0).collect();
        let mut on_time = 0usize;
        for r in &sampled {
            for (i, &t) in r.token_times.iter().enumerate() {
                csv.row(&[
                    label.to_string(),
                    format!("{}", r.id),
                    format!("{:.3}", t - r.arrival),
                    format!("{}", i + 1),
                ]);
            }
            if r.final_qoe > 0.95 {
                on_time += 1;
            }
        }
        report.push_str(&format!(
            "  {label:<12} {}/{} sampled requests track the expected TDT (QoE > 0.95)\n",
            on_time,
            sampled.len()
        ));
    }
    csv.write(&ctx.out_dir.join("fig22_tdt.csv"))?;
    Ok(report)
}
