//! Extension experiments beyond the paper's evaluation:
//!
//! - `ext-tiers`: API price tiering (the use case §6.1 sketches) — can
//!   Andes uphold per-tier QoE contracts under load where tier-blind
//!   FCFS cannot?
//! - `ext-cluster`: the cluster layer the paper leaves to future work —
//!   how much does the routing policy matter across replicas once
//!   per-replica scheduling is QoE-aware?

use anyhow::Result;

use crate::cluster::{merged_qoes, Cluster, RoutingPolicy};
use crate::config::SchedulerConfig;
use crate::coordinator::engine::EngineConfig;
use crate::coordinator::sched::andes::AndesConfig;
use crate::model::gpu::a100_4x;
use crate::model::latency::LatencyModel;
use crate::model::llm::opt_66b;
use crate::util::csv::Csv;
use crate::util::stats::{mean, percentile};
use crate::workload::qoe_trace::QoeTrace;
use crate::workload::{ArrivalProcess, Dataset, Workload};

use super::runner::{SchedKind, SimRun};
use super::ExpCtx;

/// ext-tiers: per-tier QoE under a tiered workload at overload.
pub fn ext_tiers(ctx: &ExpCtx) -> Result<String> {
    let llm = opt_66b();
    let gpu = a100_4x();
    let rate = super::runner::eval_rate(&llm, &gpu, Dataset::ShareGpt);
    let mut csv = Csv::new(&["scheduler", "tier", "n", "avg_qoe", "p10_qoe"]);
    let mut report =
        String::from("ext-tiers — API price tiers (premium 6.5 tok/s / standard / economy)\n");
    let mut andes_premium = 0.0;
    let mut fcfs_premium = 0.0;
    let mut overall_andes = 0.0;
    let mut overall_fcfs = 0.0;
    for sched in [SchedKind::Fcfs, SchedKind::andes_default()] {
        let m = SimRun {
            llm: llm.clone(),
            gpu: gpu.clone(),
            sched: sched.clone(),
            dataset: Dataset::ShareGpt,
            arrivals: ArrivalProcess::Poisson { rate },
            qoe_trace: QoeTrace::Tiered,
            num_requests: if ctx.quick { 600 } else { 1500 },
            seed: 42,
        }
        .execute();
        match sched {
            SchedKind::Fcfs => overall_fcfs = m.avg_qoe(),
            _ => overall_andes = m.avg_qoe(),
        }
        // Re-derive tiers from the workload (same seed ⇒ same specs).
        let wl = Workload {
            dataset: Dataset::ShareGpt,
            arrivals: ArrivalProcess::Poisson { rate },
            qoe_trace: QoeTrace::Tiered,
            num_requests: if ctx.quick { 600 } else { 1500 },
            seed: 42,
        }
        .generate();
        for tier in ["premium", "standard", "economy"] {
            let qoes: Vec<f64> = m
                .requests
                .iter()
                .filter(|r| QoeTrace::tier_of(&wl[r.id].qoe) == tier)
                .map(|r| r.final_qoe)
                .collect();
            let avg = mean(&qoes);
            csv.row(&[
                sched.label().to_string(),
                tier.to_string(),
                format!("{}", qoes.len()),
                format!("{avg:.4}"),
                format!("{:.4}", percentile(&qoes, 10.0)),
            ]);
            report.push_str(&format!(
                "  {:<10} {tier:<9} n={:<4} avg QoE {avg:.3} p10 {:.3}\n",
                sched.label(),
                qoes.len(),
                percentile(&qoes, 10.0)
            ));
            if tier == "premium" {
                match sched {
                    SchedKind::Fcfs => fcfs_premium = avg,
                    _ => andes_premium = avg,
                }
            }
        }
    }
    csv.write(&ctx.out_dir.join("ext_tiers.csv"))?;
    // At 1.7× capacity nobody can deliver the premium 6.5 tok/s stream
    // (saturated per-request speed < 6.5): both schedulers miss it, and
    // the unweighted avg-QoE objective correctly spends capacity where
    // it pays. The finding this extension documents: per-tier contracts
    // need *weighted* objectives — the breakdown makes the infeasible
    // tier visible, and Andes dominates on every feasible tier.
    report.push_str(&format!(
        "note: premium ({:.3} vs {:.3}) is capacity-infeasible at this rate for any scheduler\n\
         shape check (Andes dominates on feasible tiers and overall): {}\n",
        andes_premium,
        fcfs_premium,
        if overall_andes > overall_fcfs { "HOLDS" } else { "VIOLATED" }
    ));
    Ok(report)
}

/// ext-cluster: 4 replicas at aggregate overload; routing × scheduling.
pub fn ext_cluster(ctx: &ExpCtx) -> Result<String> {
    let llm = opt_66b();
    let gpu = a100_4x();
    let latency = LatencyModel::for_deployment(&llm, &gpu);
    let replicas = 4usize;
    // Per-replica capacity ~ eval_rate; aggregate slightly past the knee.
    let agg_rate = super::runner::eval_rate(&llm, &gpu, Dataset::ShareGpt)
        * replicas as f64
        * 0.95;
    let n = if ctx.quick { 1200 } else { 3000 };
    let cfg = EngineConfig {
        kv_capacity_tokens: llm.kv_capacity_tokens(&gpu),
        swap_capacity_tokens: llm.swap_capacity_tokens(&gpu),
        ..EngineConfig::default()
    };
    let mut csv = Csv::new(&["routing", "scheduler", "avg_qoe", "p10_qoe"]);
    let mut report = format!(
        "ext-cluster — {replicas} replicas, aggregate rate {agg_rate:.1} req/s\n"
    );
    let mut best: Option<(String, f64)> = None;
    let mut rr_fcfs = 0.0;
    for policy in
        [RoutingPolicy::RoundRobin, RoutingPolicy::LeastLoaded, RoutingPolicy::QoeAware]
    {
        for (sname, sched) in [
            ("fcfs", SchedulerConfig::Fcfs),
            ("andes", SchedulerConfig::Andes(AndesConfig::default())),
        ] {
            let mut cluster = Cluster::new(replicas, cfg.clone(), latency.clone(), &sched, policy);
            let trace = Workload {
                dataset: Dataset::ShareGpt,
                arrivals: ArrivalProcess::Poisson { rate: agg_rate },
                qoe_trace: QoeTrace::TextReading,
                num_requests: n,
                seed: 42,
            }
            .generate();
            let all = cluster.run_trace(trace)?;
            let qoes = merged_qoes(&all);
            let avg = mean(&qoes);
            let p10 = percentile(&qoes, 10.0);
            csv.row(&[
                policy.label().to_string(),
                sname.to_string(),
                format!("{avg:.4}"),
                format!("{p10:.4}"),
            ]);
            report.push_str(&format!(
                "  {:<13} + {:<6} avg QoE {avg:.3}  p10 {p10:.3}\n",
                policy.label(),
                sname
            ));
            let key = format!("{}+{}", policy.label(), sname);
            if key == "round-robin+fcfs" {
                rr_fcfs = avg;
            }
            if best.as_ref().map_or(true, |(_, b)| avg > *b) {
                best = Some((key, avg));
            }
        }
    }
    csv.write(&ctx.out_dir.join("ext_cluster.csv"))?;
    let (best_key, best_avg) = best.unwrap();
    report.push_str(&format!(
        "best combination: {best_key} ({best_avg:.3}); shape check (beats rr+fcfs {rr_fcfs:.3}): {}\n",
        if best_avg > rr_fcfs { "HOLDS" } else { "VIOLATED" }
    ));
    Ok(report)
}
