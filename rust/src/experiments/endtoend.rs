//! End-to-end evaluation sweeps: Figs. 10, 11, 12, 13, 21 — average
//! QoE, system capacity, throughput, preemption frequency, and
//! normalized latency across request rates, models, and datasets.

use anyhow::Result;

use crate::model::gpu::{a100_1x, a100_4x, GpuProfile};
use crate::model::llm::{opt_13b, opt_175b, opt_30b, opt_66b, LlmProfile};
use crate::util::csv::Csv;
use crate::util::plot::{line_plot, Series};
use crate::util::stats::percentile;
use crate::workload::{ArrivalProcess, Dataset, QoeTrace};

use super::runner::{capacity_at_threshold, estimate_capacity, rate_grid, SchedKind, SimRun};
use super::ExpCtx;

/// The paper's four deployments (Table 3).
pub fn deployments() -> Vec<(LlmProfile, GpuProfile)> {
    vec![
        (opt_13b(), a100_1x()),
        (opt_30b(), a100_4x()),
        (opt_66b(), a100_4x()),
        (opt_175b(), a100_4x()),
    ]
}

/// Shared sweep: average QoE vs rate for every scheduler on one
/// deployment. Returns (per-scheduler series, csv rows).
#[allow(clippy::type_complexity)]
fn qoe_sweep(
    llm: &LlmProfile,
    gpu: &GpuProfile,
    dataset: Dataset,
    qoe_trace: QoeTrace,
    arrivals: fn(f64) -> ArrivalProcess,
    ctx: &ExpCtx,
) -> (Vec<(String, Vec<(f64, f64)>)>, Vec<(String, f64, f64, f64, f64)>) {
    let capacity = estimate_capacity(llm, gpu, dataset);
    let rates = rate_grid(capacity, ctx.quick);
    let n = if ctx.quick { 600 } else { 1500 };
    let mut series = Vec::new();
    let mut rows = Vec::new();
    for sched in SchedKind::paper_three() {
        let mut pts = Vec::new();
        for &rate in &rates {
            let m = SimRun {
                llm: llm.clone(),
                gpu: gpu.clone(),
                sched: sched.clone(),
                dataset,
                arrivals: arrivals(rate),
                qoe_trace,
                num_requests: n,
                seed: 42,
            }
            .execute();
            pts.push((rate, m.avg_qoe()));
            rows.push((
                sched.label().to_string(),
                rate,
                m.avg_qoe(),
                m.throughput(),
                m.preemption_frequency(),
            ));
        }
        series.push((sched.label().to_string(), pts));
    }
    (series, rows)
}

fn render_sweep(
    title: &str,
    series: &[(String, Vec<(f64, f64)>)],
) -> (String, f64, f64, f64) {
    let plot_series: Vec<Series> =
        series.iter().map(|(n, p)| Series::new(n, p.clone())).collect();
    let mut report = line_plot(title, "request rate (req/s)", "avg QoE", &plot_series);
    let cap = |name: &str| {
        capacity_at_threshold(
            &series.iter().find(|(n, _)| n == name).unwrap().1,
            0.9,
        )
    };
    let (c_fcfs, c_rr, c_andes) = (cap("vLLM-FCFS"), cap("Round-Robin"), cap("Andes"));
    // Max QoE ratio at any common rate.
    let fcfs = &series.iter().find(|(n, _)| n == "vLLM-FCFS").unwrap().1;
    let andes = &series.iter().find(|(n, _)| n == "Andes").unwrap().1;
    let max_ratio = fcfs
        .iter()
        .zip(andes)
        .map(|(&(_, qf), &(_, qa))| if qf > 1e-6 { qa / qf } else { 1.0 })
        .fold(0.0f64, f64::max);
    report.push_str(&format!(
        "  capacity@QoE≥0.9: fcfs={c_fcfs:.2}, rr={c_rr:.2}, andes={c_andes:.2} (gain {:.2}×); max QoE gain {max_ratio:.2}×\n",
        if c_fcfs > 0.0 { c_andes / c_fcfs } else { f64::NAN },
    ));
    (report, c_fcfs, c_andes, max_ratio)
}

/// Figs. 10 (ShareGPT) / 11 (Multi-Round): avg QoE vs rate × 4 models.
pub fn fig10_11(ctx: &ExpCtx, dataset: Dataset) -> Result<String> {
    let fig = if dataset == Dataset::ShareGpt { "fig10" } else { "fig11" };
    let mut csv = Csv::new(&["model", "scheduler", "rate", "avg_qoe", "throughput", "preempt_per_req"]);
    let mut report = format!("{} — average QoE vs request rate ({})\n", fig, dataset.name());
    let mut all_hold = true;
    let deps = if ctx.quick {
        vec![(opt_66b(), a100_4x())]
    } else {
        deployments()
    };
    for (llm, gpu) in deps {
        let (series, rows) =
            qoe_sweep(&llm, &gpu, dataset, QoeTrace::TextReading, |r| {
                ArrivalProcess::Poisson { rate: r }
            }, ctx);
        for (sched, rate, qoe, tput, pf) in rows {
            csv.row(&[
                llm.name.to_string(),
                sched,
                format!("{rate}"),
                format!("{qoe:.4}"),
                format!("{tput:.1}"),
                format!("{pf:.3}"),
            ]);
        }
        let (r, c_fcfs, c_andes, ratio) =
            render_sweep(&format!("{} — {} avg QoE", fig, llm.name), &series);
        report.push_str(&r);
        // Allow 10% interpolation noise on the sparse rate grid; the
        // QoE-ratio claim is checked separately by the sweep plots.
        if c_fcfs > 0.0 && c_andes < c_fcfs * 0.9 {
            all_hold = false;
        }
        let _ = ratio;
    }
    csv.write(&ctx.out_dir.join(format!("{fig}_avg_qoe.csv")))?;
    report.push_str(&format!(
        "shape check (Andes capacity ≥ FCFS on every model): {}\n",
        if all_hold { "HOLDS" } else { "VIOLATED" }
    ));
    Ok(report)
}

/// Fig. 12 (throughput) + Fig. 13 (preemption frequency) on OPT-66B.
pub fn fig12_13(ctx: &ExpCtx) -> Result<String> {
    let llm = opt_66b();
    let gpu = a100_4x();
    let mut csv = Csv::new(&["dataset", "scheduler", "rate", "throughput", "preempt_per_req"]);
    let mut report = String::new();
    let mut ok_tput = true;
    let mut ok_preempt = true;
    for dataset in [Dataset::ShareGpt, Dataset::MultiRoundShareGpt] {
        let capacity = estimate_capacity(&llm, &gpu, dataset);
        let rates = rate_grid(capacity, ctx.quick);
        let n = if ctx.quick { 600 } else { 1500 };
        let mut tput_series = Vec::new();
        let mut pf_series = Vec::new();
        for sched in SchedKind::paper_three() {
            let mut tputs = Vec::new();
            let mut pfs = Vec::new();
            for &rate in &rates {
                let m = SimRun {
                    llm: llm.clone(),
                    gpu: gpu.clone(),
                    sched: sched.clone(),
                    dataset,
                    arrivals: ArrivalProcess::Poisson { rate },
                    qoe_trace: QoeTrace::TextReading,
                    num_requests: n,
                    seed: 42,
                }
                .execute();
                csv.row(&[
                    dataset.name().to_string(),
                    sched.label().to_string(),
                    format!("{rate}"),
                    format!("{:.1}", m.throughput()),
                    format!("{:.3}", m.preemption_frequency()),
                ]);
                tputs.push((rate, m.throughput()));
                pfs.push((rate, m.preemption_frequency()));
            }
            tput_series.push((sched.label().to_string(), tputs));
            pf_series.push((sched.label().to_string(), pfs));
        }
        report.push_str(&line_plot(
            &format!("Fig. 12 — throughput ({})", dataset.name()),
            "req/s",
            "tokens/s",
            &tput_series.iter().map(|(n, p)| Series::new(n, p.clone())).collect::<Vec<_>>(),
        ));
        report.push_str(&line_plot(
            &format!("Fig. 13 — preemption frequency ({})", dataset.name()),
            "req/s",
            "preempts/request",
            &pf_series.iter().map(|(n, p)| Series::new(n, p.clone())).collect::<Vec<_>>(),
        ));
        // Shape: Andes throughput within ~12% of FCFS at sub-capacity
        // rates (paper: ≤10% drop overall); preempt/req bounded by ~1.
        let fcfs = &tput_series.iter().find(|(n, _)| n == "vLLM-FCFS").unwrap().1;
        let andes = &tput_series.iter().find(|(n, _)| n == "Andes").unwrap().1;
        for ((r, tf), (_, ta)) in fcfs.iter().zip(andes) {
            if *r <= capacity && *ta < tf * 0.85 {
                ok_tput = false;
            }
        }
        let apf = &pf_series.iter().find(|(n, _)| n == "Andes").unwrap().1;
        if apf.iter().any(|&(_, p)| p > 1.1) {
            ok_preempt = false;
        }
    }
    csv.write(&ctx.out_dir.join("fig12_13_throughput_preemption.csv"))?;
    report.push_str(&format!(
        "shape checks: sub-capacity throughput within 15% of FCFS: {}; preempt/req ≤ ~1: {}\n",
        if ok_tput { "HOLDS" } else { "VIOLATED" },
        if ok_preempt { "HOLDS" } else { "VIOLATED" },
    ));
    Ok(report)
}

/// Fig. 21 (Appendix E): normalized latency vs request rate, both
/// datasets, OPT-66B.
pub fn fig21(ctx: &ExpCtx) -> Result<String> {
    let llm = opt_66b();
    let gpu = a100_4x();
    let mut csv = Csv::new(&["dataset", "scheduler", "rate", "p50_norm_latency_s_per_tok"]);
    let mut report = String::new();
    for dataset in [Dataset::ShareGpt, Dataset::MultiRoundShareGpt] {
        let capacity = estimate_capacity(&llm, &gpu, dataset);
        let rates = rate_grid(capacity, ctx.quick);
        let n = if ctx.quick { 600 } else { 1500 };
        let mut all_series = Vec::new();
        for sched in SchedKind::paper_three() {
            let mut pts = Vec::new();
            for &rate in &rates {
                let m = SimRun {
                    llm: llm.clone(),
                    gpu: gpu.clone(),
                    sched: sched.clone(),
                    dataset,
                    arrivals: ArrivalProcess::Poisson { rate },
                    qoe_trace: QoeTrace::TextReading,
                    num_requests: n,
                    seed: 42,
                }
                .execute();
                let p50 = percentile(&m.normalized_latencies(), 50.0);
                csv.row(&[
                    dataset.name().to_string(),
                    sched.label().to_string(),
                    format!("{rate}"),
                    format!("{p50:.4}"),
                ]);
                pts.push((rate, p50));
            }
            all_series.push(Series::new(sched.label(), pts));
        }
        report.push_str(&line_plot(
            &format!("Fig. 21 — normalized latency ({})", dataset.name()),
            "req/s",
            "s/token (p50)",
            &all_series,
        ));
    }
    csv.write(&ctx.out_dir.join("fig21_normalized_latency.csv"))?;
    Ok(report)
}
