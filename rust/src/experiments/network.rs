//! ext-network: client-side delivery under jittery last-mile links
//! (DESIGN.md §11).
//!
//! Runs one seeded workload through the full gateway (admission +
//! pacing) on a 2-replica Andes cluster, then carries every served
//! request's token timeline across {ideal, wifi, lte-jitter} links with
//! {static-lead, adaptive-lead} pacing. Because the delivery layer is
//! strictly post-generation (it never changes admission or scheduling),
//! all six cells share one engine run — the grid re-evaluates delivery,
//! which keeps the experiment ~7× cheaper and makes the ideal-link
//! parity check exact rather than statistical.
//!
//! Reported per cell: mean and p10 **client** QoE, the client-vs-server
//! QoE gap, playback stall count/time, retransmissions, disconnect
//! holds, and the mean final pacer lead. Shape checks assert the
//! delivery story: the ideal link reproduces the no-network baseline
//! bit-exactly, the QoE gap widens from ideal → wifi → lte, and under
//! lte-jitter the adaptive lead strictly reduces stall time without
//! losing client QoE.

use std::path::Path;

use anyhow::Result;

use crate::cluster::{Cluster, RoutingPolicy};
use crate::config::SchedulerConfig;
use crate::coordinator::engine::EngineConfig;
use crate::coordinator::metrics::RequestRecord;
use crate::coordinator::sched::andes::AndesConfig;
use crate::delivery::{deliver_request, NetworkConfig, NetworkProfile};
use crate::gateway::{Gateway, GatewayConfig, PacingConfig};
use crate::model::gpu::a100_4x;
use crate::model::latency::LatencyModel;
use crate::model::llm::opt_66b;
use crate::qoe::metric::{qoe_finished, DigestState};
use crate::qoe::spec::QoeSpec;
use crate::util::csv::Csv;
use crate::util::stats::{mean, percentile};
use crate::workload::{ArrivalProcess, Dataset, QoeTrace, Workload};

use super::runner::estimate_capacity;
use super::ExpCtx;

/// One cell's aggregates, kept for the shape checks.
struct Cell {
    profile: &'static str,
    lead: &'static str,
    mean_client: f64,
    p10_client: f64,
    mean_server: f64,
    stall_time: f64,
    stalls: usize,
}

impl Cell {
    fn gap(&self) -> f64 {
        self.mean_server - self.mean_client
    }
}

pub fn ext_network(ctx: &ExpCtx) -> Result<String> {
    let n = if ctx.quick { 200 } else { 600 };
    run_grid(n, Some(&ctx.out_dir))
}

/// The grid itself, parameterized so the determinism test can run a
/// small instance twice in-process and compare reports byte-for-byte.
pub fn run_grid(n: usize, out_dir: Option<&Path>) -> Result<String> {
    let llm = opt_66b();
    let gpu = a100_4x();
    let latency = LatencyModel::for_deployment(&llm, &gpu);
    let replicas = 2usize;
    let capacity = estimate_capacity(&llm, &gpu, Dataset::ShareGpt) * replicas as f64;
    let engine_cfg = EngineConfig {
        kv_capacity_tokens: llm.kv_capacity_tokens(&gpu),
        swap_capacity_tokens: llm.swap_capacity_tokens(&gpu),
        ..EngineConfig::default()
    };
    let sched = SchedulerConfig::Andes(AndesConfig::default());
    // rate_factor 1.0: release exactly at digestion speed so the client
    // buffer holds ~lead tokens throughout — the Eloquent setting where
    // the lead is the only jitter absorber (the default 1.25 would
    // slowly build a masking surplus).
    let pacing = PacingConfig { rate_factor: 1.0, lead_tokens: 4 };

    let trace = Workload {
        dataset: Dataset::ShareGpt,
        arrivals: ArrivalProcess::Poisson { rate: capacity },
        qoe_trace: QoeTrace::TextReading,
        num_requests: n,
        seed: 42,
    }
    .generate();

    // One engine run, network disabled: the no-network baseline.
    let cluster = Cluster::new(
        replicas,
        engine_cfg,
        latency,
        &sched,
        RoutingPolicy::QoeAware,
    );
    let mut gcfg = GatewayConfig::default();
    gcfg.pacing = pacing.clone();
    gcfg.surge.baseline_rate = capacity;
    let mut gw = Gateway::new(cluster, gcfg);
    let base = gw.run_trace(trace)?;
    let baseline_qoe = base.mean_served_qoe();
    let records: Vec<&RequestRecord> =
        base.per_replica.iter().flat_map(|m| m.requests.iter()).collect();

    let profiles: [(&'static str, NetworkProfile); 3] = [
        ("ideal", NetworkProfile::ideal()),
        ("wifi", NetworkProfile::wifi()),
        ("lte-jitter", NetworkProfile::lte()),
    ];
    let leads: [(&'static str, bool); 2] = [("static-lead", false), ("adaptive-lead", true)];

    let mut csv = Csv::new(&[
        "profile",
        "lead_mode",
        "served",
        "mean_client_qoe",
        "p10_client_qoe",
        "mean_server_qoe",
        "qoe_gap",
        "stalls",
        "stall_time_total",
        "stall_time_per_req",
        "retransmits",
        "disconnects",
        "mean_final_lead",
    ]);
    let mut report = format!(
        "ext-network — {replicas}-replica Andes cluster at 1x capacity \
         ({capacity:.1} req/s), {n} requests, {} served; \
         no-network baseline QoE {baseline_qoe:.4}\n",
        records.len(),
    );
    let mut cells: Vec<Cell> = Vec::new();

    for &(plabel, profile) in &profiles {
        for &(llabel, adaptive) in &leads {
            let netcfg = NetworkConfig {
                enabled: true,
                adaptive_lead: adaptive,
                ..NetworkConfig::default()
            }
            .with_mix(vec![(profile, 1.0)]);
            let mut client_qoes = Vec::with_capacity(records.len());
            let mut server_qoes = Vec::with_capacity(records.len());
            let mut stalls = 0usize;
            let mut stall_time = 0.0f64;
            let mut retransmits = 0usize;
            let mut disconnects = 0usize;
            let mut leads_sum = 0usize;
            for rec in &records {
                let spec =
                    QoeSpec::new(rec.expected_ttft.max(0.0), rec.expected_tds.max(0.1));
                let rel: Vec<f64> =
                    rec.token_times.iter().map(|t| (t - rec.arrival).max(0.0)).collect();
                let out = deliver_request(&spec, true, &pacing, &netcfg, rec.id, &rel);
                let mut st = DigestState::new(&spec);
                for &t in &out.release_times {
                    st.deliver(t);
                }
                server_qoes.push(qoe_finished(&spec, &st, out.release_times.len()));
                client_qoes.push(out.client_qoe);
                stalls += out.stall_count;
                stall_time += out.stall_time;
                retransmits += out.retransmits;
                disconnects += out.disconnects;
                leads_sum += out.final_lead;
            }
            let served = records.len().max(1);
            let cell = Cell {
                profile: plabel,
                lead: llabel,
                mean_client: mean(&client_qoes),
                p10_client: percentile(&client_qoes, 10.0),
                mean_server: mean(&server_qoes),
                stall_time,
                stalls,
            };
            csv.row(&[
                plabel.to_string(),
                llabel.to_string(),
                format!("{}", records.len()),
                format!("{:.4}", cell.mean_client),
                format!("{:.4}", cell.p10_client),
                format!("{:.4}", cell.mean_server),
                format!("{:.4}", cell.gap()),
                format!("{stalls}"),
                format!("{stall_time:.2}"),
                format!("{:.4}", stall_time / served as f64),
                format!("{retransmits}"),
                format!("{disconnects}"),
                format!("{:.2}", leads_sum as f64 / served as f64),
            ]);
            report.push_str(&format!(
                "  {plabel:<10} {llabel:<13} QoE {:.3} (p10 {:.3}) gap {:.3} \
                 stalls {stalls:<5} ({stall_time:.1}s) rtx {retransmits:<5} \
                 lead {:.1}\n",
                cell.mean_client,
                cell.p10_client,
                cell.gap(),
                leads_sum as f64 / served as f64,
            ));
            cells.push(cell);
        }
    }
    if let Some(dir) = out_dir {
        csv.write(&dir.join("ext_network.csv"))?;
    }

    let find = |profile: &str, lead: &str| {
        cells
            .iter()
            .find(|c| c.profile == profile && c.lead == lead)
            .expect("cell missing")
    };
    let ideal_s = find("ideal", "static-lead");
    let ideal_a = find("ideal", "adaptive-lead");
    let wifi_s = find("wifi", "static-lead");
    let lte_s = find("lte-jitter", "static-lead");
    let lte_a = find("lte-jitter", "adaptive-lead");
    let c1 = lte_a.stall_time < lte_s.stall_time;
    // Stalls are end-to-end: generation gaps under-run playback even on
    // the ideal link, so the parity check pins QoE (exact), not stalls —
    // the lte cells must stall strictly more than that baseline though.
    let c2 = (ideal_s.mean_client - baseline_qoe).abs() < 1e-9
        && (ideal_a.mean_client - baseline_qoe).abs() < 1e-9;
    let c3 = lte_s.gap() >= wifi_s.gap() - 1e-9 && wifi_s.gap() >= ideal_s.gap() - 1e-9;
    let c4 = lte_a.mean_client >= lte_s.mean_client - 1e-6;
    let c5 = lte_s.stall_time > ideal_s.stall_time;
    report.push_str(&format!(
        "shape checks:\n\
         \x20 adaptive lead strictly cuts lte stall time ({:.1}s < {:.1}s): {}\n\
         \x20 ideal link reproduces the no-network baseline ({:.4} == {:.4}): {}\n\
         \x20 client-vs-server QoE gap widens with link quality loss \
         ({:.4} >= {:.4} >= {:.4}): {}\n\
         \x20 adaptive lead does not lose lte client QoE ({:.4} vs {:.4}): {}\n\
         \x20 lte jitter stalls beyond the generation-gap baseline \
         ({:.1}s > {:.1}s): {}\n",
        lte_a.stall_time,
        lte_s.stall_time,
        verdict(c1),
        ideal_s.mean_client,
        baseline_qoe,
        verdict(c2),
        lte_s.gap(),
        wifi_s.gap(),
        ideal_s.gap(),
        verdict(c3),
        lte_a.mean_client,
        lte_s.mean_client,
        verdict(c4),
        lte_s.stall_time,
        ideal_s.stall_time,
        verdict(c5),
    ));
    Ok(report)
}

fn verdict(ok: bool) -> &'static str {
    if ok {
        "HOLDS"
    } else {
        "VIOLATED"
    }
}
