//! Motivation & definition experiments: Figs. 2, 3, 4, 5, 7 and the
//! dataset distributions of Fig. 9.

use anyhow::Result;

use crate::coordinator::sched::andes::AndesConfig;
use crate::model::gpu::a100_4x;
use crate::model::latency::LatencyModel;
use crate::model::llm::opt_66b;
use crate::qoe::metric::{project, qoe_at, qoe_finished, DigestState};
use crate::qoe::spec::QoeSpec;
use crate::util::csv::Csv;
use crate::util::plot::{bar_chart, line_plot, Series};
use crate::util::rng::Rng;
use crate::util::stats::{mean, percentile, Histogram};
use crate::workload::{ArrivalProcess, Dataset, QoeTrace};

use super::runner::{SchedKind, SimRun};
use super::ExpCtx;

/// Fig. 2: four hand-crafted token delivery timelines; QoE must order
/// them 1 = 2 > 3 > 4.
pub fn fig2(ctx: &ExpCtx) -> Result<String> {
    let sp = QoeSpec::new(1.0, 1.0);
    let l = 8usize;

    let mut r1 = DigestState::new(&sp); // exactly on schedule
    for i in 0..l {
        r1.deliver(1.0 + i as f64);
    }
    let mut r2 = DigestState::new(&sp); // burst, then ahead
    r2.deliver_n(0.5, 4);
    for i in 4..l {
        r2.deliver(0.5 + (i - 3) as f64);
    }
    let mut r3 = DigestState::new(&sp); // half-speed TDS
    for i in 0..l {
        r3.deliver(1.0 + 2.0 * i as f64);
    }
    let mut r4 = DigestState::new(&sp); // same TTFT/TTLT, back-loaded
    r4.deliver(1.0);
    r4.deliver_n(1.0 + 2.0 * (l - 1) as f64, l - 1);

    let qoes = [
        ("request-1 (on schedule)", qoe_finished(&sp, &r1, l)),
        ("request-2 (early burst)", qoe_finished(&sp, &r2, l)),
        ("request-3 (slow TDS)", qoe_finished(&sp, &r3, l)),
        ("request-4 (back-loaded)", qoe_finished(&sp, &r4, l)),
    ];
    let mut csv = Csv::new(&["request", "qoe"]);
    for (name, q) in &qoes {
        csv.row(&[name.to_string(), format!("{q:.4}")]);
    }
    csv.write(&ctx.out_dir.join("fig2_qoe_intuition.csv"))?;

    let mut report = bar_chart(
        "Fig. 2 — QoE of four delivery timelines",
        &qoes.iter().map(|(n, q)| (n.to_string(), *q)).collect::<Vec<_>>(),
    );
    let ok = qoes[0].1 > 0.99
        && qoes[1].1 > 0.99
        && qoes[2].1 < 0.95
        && qoes[3].1 < qoes[2].1;
    report.push_str(&format!(
        "shape check (1=2>3>4): {}\n",
        if ok { "HOLDS" } else { "VIOLATED" }
    ));
    Ok(report)
}

/// Fig. 3: FCFS under increasing request rate — p90 TTFT explodes past
/// capacity while server-side generation speed stays well above the
/// user-expected 4.8 / 3.3 tok/s.
pub fn fig3(ctx: &ExpCtx) -> Result<String> {
    let llm = opt_66b();
    let gpu = a100_4x();
    let capacity = super::runner::estimate_capacity(&llm, &gpu, Dataset::ShareGpt);
    let rates = super::runner::rate_grid(capacity, ctx.quick);
    let n = if ctx.quick { 600 } else { 1500 };

    let mut csv = Csv::new(&["rate", "p90_ttft_s", "p50_gen_speed", "p10_gen_speed"]);
    let mut ttft_series = Vec::new();
    let mut speed_series = Vec::new();
    for &rate in &rates {
        let m = SimRun {
            llm: llm.clone(),
            gpu: gpu.clone(),
            sched: SchedKind::Fcfs,
            dataset: Dataset::ShareGpt,
            arrivals: ArrivalProcess::Poisson { rate },
            qoe_trace: QoeTrace::TextReading,
            num_requests: n,
            seed: 42,
        }
        .execute();
        let p90_ttft = percentile(&m.ttfts(), 90.0);
        // Server-side per-request generation speed: tokens / service time
        // (excluding queueing): use avg TDS of delivered tokens.
        let speeds = m.tds_values();
        let p50 = percentile(&speeds, 50.0);
        let p10 = percentile(&speeds, 10.0);
        csv.row_f64(&[rate, p90_ttft, p50, p10]);
        ttft_series.push((rate, p90_ttft));
        speed_series.push((rate, p50));
    }
    csv.write(&ctx.out_dir.join("fig3_motivation.csv"))?;

    let mut report = line_plot(
        "Fig. 3a — p90 TTFT vs request rate (FCFS, OPT-66B)",
        "req/s",
        "p90 TTFT (s)",
        &[Series::new("fcfs", ttft_series.clone())],
    );
    report.push_str(&line_plot(
        "Fig. 3b — p50 token generation speed vs request rate",
        "req/s",
        "tokens/s",
        &[
            Series::new("fcfs", speed_series.clone()),
            Series::new("reading-4.8", rates.iter().map(|&r| (r, 4.8)).collect()),
            Series::new("speaking-3.3", rates.iter().map(|&r| (r, 3.3)).collect()),
        ],
    ));
    let explodes = ttft_series.last().unwrap().1 > 10.0 * ttft_series[0].1.max(0.5);
    // The "generation outpaces reading" observation applies below the
    // empirical capacity knee (~1.5× the analytic estimate).
    let fast = speed_series
        .iter()
        .filter(|&&(r, _)| r <= capacity * 1.2)
        .all(|&(_, s)| s > 4.8);
    report.push_str(&format!(
        "shape check: TTFT explodes past capacity: {}; early-load gen speed > reading speed: {}\n",
        if explodes { "HOLDS" } else { "VIOLATED" },
        if fast { "HOLDS" } else { "VIOLATED" },
    ));
    Ok(report)
}

/// Fig. 4: the paper's toy example. Server fits 200 tokens; four
/// requests with different lengths/QoE arrive at t=0. FCFS starves the
/// last; RR misses late deadlines; Andes satisfies all.
pub fn fig4(ctx: &ExpCtx) -> Result<String> {
    use crate::backend::sim::SimBackend;
    use crate::backend::VirtualClock;
    use crate::coordinator::engine::{Engine, EngineConfig};
    use crate::qoe::spec::QoeSpec;
    use crate::workload::RequestSpec;

    // Four requests: (prompt, output, ttft_exp, tds_exp) — modeled on
    // the paper's toy: mixed lengths, one stringent-TTFT short request.
    let reqs = [
        (40usize, 40usize, 1.0, 2.0),
        (40, 40, 1.0, 2.0),
        (20, 25, 1.0, 4.0), // small + stringent TDS
        (45, 40, 1.0, 2.0),
    ];
    let mut report = String::from("Fig. 4 — toy example, M = 200 tokens\n");
    let mut csv = Csv::new(&["scheduler", "request", "qoe", "ttft"]);
    let mut per_sched_min = Vec::new();
    for sched in SchedKind::paper_three() {
        // A tiny deployment whose decode speed ≈ 10 tok/s/request at
        // B=4, mirroring the illustration's timescale.
        let latency = LatencyModel {
            decode_base: 0.05,
            decode_per_seq: 0.01,
            decode_per_ctx_token: 1e-5,
            prefill_base: 0.05,
            prefill_per_token: 5e-4,
            swap_fixed: 0.01,
            pcie_bytes_s: 25.0 * crate::model::llm::GIB,
            kv_bytes_per_token: 2.4e6,
        };
        let cfg = EngineConfig {
            kv_capacity_tokens: 200,
            swap_capacity_tokens: 400,
            block_size: 4,
            ..EngineConfig::default()
        };
        let mut engine = Engine::new(
            cfg,
            SimBackend::new(latency.clone()),
            VirtualClock::default(),
            sched.build(),
            latency,
        );
        let trace: Vec<RequestSpec> = reqs
            .iter()
            .enumerate()
            .map(|(i, &(p, o, ttft, tds))| RequestSpec {
                id: i,
                arrival: 0.0,
                prompt_tokens: p,
                output_tokens: o,
                qoe: QoeSpec::new(ttft, tds),
                session: None,
            })
            .collect();
        engine.load_trace(trace);
        engine.run_to_completion()?;
        let m = engine.metrics();
        let mut min_qoe = 1.0f64;
        for r in &m.requests {
            csv.row(&[
                sched.label().to_string(),
                format!("req{}", r.id),
                format!("{:.3}", r.final_qoe),
                format!("{:.2}", r.ttft),
            ]);
            min_qoe = min_qoe.min(r.final_qoe);
        }
        report.push_str(&format!(
            "  {:<12} min QoE = {:.3}, avg = {:.3}\n",
            sched.label(),
            min_qoe,
            m.avg_qoe()
        ));
        per_sched_min.push((sched.label(), min_qoe));
    }
    csv.write(&ctx.out_dir.join("fig4_toy.csv"))?;
    let andes_min = per_sched_min.iter().find(|x| x.0 == "Andes").unwrap().1;
    let fcfs_min = per_sched_min.iter().find(|x| x.0 == "vLLM-FCFS").unwrap().1;
    report.push_str(&format!(
        "shape check (Andes min ≥ others): {}\n",
        if andes_min >= fcfs_min - 1e-9 { "HOLDS" } else { "VIOLATED" }
    ));
    Ok(report)
}

/// Fig. 5: worked QoE computation for one request — expected vs actual
/// areas and the resulting ratio.
pub fn fig5(ctx: &ExpCtx) -> Result<String> {
    let sp = QoeSpec::new(1.0, 2.0);
    let mut st = DigestState::new(&sp);
    // A bursty-but-late delivery: first token at 2s, burst at 4s, tail.
    st.deliver(2.0);
    st.deliver_n(4.0, 6);
    st.deliver(6.0);
    st.deliver(7.5);
    let l = 9usize;
    let t_end = st.digest_end();
    let mut probe = st.clone();
    probe.advance_to(t_end);
    let actual = probe.area_at(t_end);
    let expected = sp.expected_area(t_end, Some(l as f64));
    let qoe = qoe_finished(&sp, &st, l);

    let mut csv = Csv::new(&["t", "expected_tokens", "actual_digested"]);
    let steps = 60;
    for k in 0..=steps {
        let t = t_end * k as f64 / steps as f64;
        let mut s = st.clone();
        s.advance_to(t.max(1e-9));
        csv.row_f64(&[t, sp.expected_tokens_at(t, Some(l as f64)), s.digested()]);
    }
    csv.write(&ctx.out_dir.join("fig5_qoe_example.csv"))?;

    Ok(format!(
        "Fig. 5 — QoE worked example\n  S_actual = {actual:.2} token·s, S_expected = {expected:.2} token·s\n  QoE = {qoe:.3} (ratio {:.3} clamped to [0,1])\n",
        actual / expected
    ))
}

/// Fig. 7: Q_serve(B) for one request at different batch sizes, vs the
/// constant Q_wait.
pub fn fig7(ctx: &ExpCtx) -> Result<String> {
    let llm = opt_66b();
    let gpu = a100_4x();
    let latency = LatencyModel::for_deployment(&llm, &gpu);
    let sp = QoeSpec::new(1.0, 4.8);
    // A request mid-flight: 40 tokens delivered on schedule so far.
    let mut st = DigestState::new(&sp);
    for i in 0..40 {
        st.deliver(1.0 + i as f64 / 4.8);
    }
    let now = st.last_t();
    let horizon = 30.0;
    let avg_ctx = 500usize;

    let mut csv = Csv::new(&["batch_size", "q_serve", "q_wait"]);
    let mut series = Vec::new();
    let waited = project(&st, 0.0, 0.0, now + horizon);
    let q_wait = qoe_at(&sp, &waited, now + horizon, None);
    for b in (10..=400).step_by(10) {
        let rate = 1.0 / latency.decode(b, b * avg_ctx);
        let served = project(&st, rate, 0.0, now + horizon);
        let q_serve = qoe_at(&sp, &served, now + horizon, None);
        csv.row_f64(&[b as f64, q_serve, q_wait]);
        series.push((b as f64, q_serve));
    }
    csv.write(&ctx.out_dir.join("fig7_qserve_vs_batch.csv"))?;

    let q10 = series[0].1;
    let q_small = series.iter().take(5).map(|x| x.1).fold(1.0f64, f64::min);
    let q_large = series.last().unwrap().1;
    let mut report = line_plot(
        "Fig. 7 — Q_serve(B) vs batch size (Q_wait constant)",
        "batch size B",
        "QoE after Δt",
        &[
            Series::new("Q_serve(B)", series),
            Series::new("Q_wait", (10..=200).step_by(10).map(|b| (b as f64, q_wait)).collect()),
        ],
    );
    report.push_str(&format!(
        "shape check: small-B perfect ({q10:.3} ≈ 1), large-B degraded ({q_large:.3} < {q_small:.3}): {}\n",
        if q10 > 0.99 && q_large < q_small { "HOLDS" } else { "VIOLATED" }
    ));
    Ok(report)
}

/// Fig. 9: input/output token length distributions of the two datasets.
pub fn fig9(ctx: &ExpCtx) -> Result<String> {
    let n = 20_000;
    let mut report = String::from("Fig. 9 — dataset length distributions\n");
    let mut csv = Csv::new(&["dataset", "kind", "bin_center", "density"]);
    let mut means = Vec::new();
    for ds in [Dataset::ShareGpt, Dataset::MultiRoundShareGpt] {
        let mut rng = Rng::new(9);
        let samples = ds.sample_many(&mut rng, n);
        let inputs: Vec<f64> = samples.iter().map(|s| s.prompt_tokens as f64).collect();
        let outputs: Vec<f64> = samples.iter().map(|s| s.output_tokens as f64).collect();
        for (kind, xs) in [("input", &inputs), ("output", &outputs)] {
            let mut h = Histogram::new(0.0, 1024.0, 32);
            for &x in xs.iter() {
                h.add(x);
            }
            for (center, dens) in h.density() {
                csv.row(&[
                    ds.name().to_string(),
                    kind.to_string(),
                    format!("{center:.0}"),
                    format!("{dens:.6}"),
                ]);
            }
        }
        report.push_str(&format!(
            "  {:<22} mean input = {:.0}, mean output = {:.0}\n",
            ds.name(),
            mean(&inputs),
            mean(&outputs)
        ));
        means.push((mean(&inputs), mean(&outputs)));
    }
    csv.write(&ctx.out_dir.join("fig9_datasets.csv"))?;
    let ratio = means[1].0 / means[0].0;
    report.push_str(&format!(
        "shape check: MR input ≈ 3× ShareGPT (got {ratio:.1}×), outputs similar: {}\n",
        if (2.0..4.5).contains(&ratio) && (means[1].1 / means[0].1 - 1.0).abs() < 0.25 {
            "HOLDS"
        } else {
            "VIOLATED"
        }
    ));
    Ok(report)
}

/// Helper for sensitivity experiments: default Andes config.
pub fn andes_cfg() -> AndesConfig {
    AndesConfig::default()
}
