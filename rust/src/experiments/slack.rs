//! ext-slack: buffer-slack-aware scheduling (TokenFlow × Andes;
//! DESIGN.md §15).
//!
//! Slack-blind Andes reads the *server-side* digestion state, which
//! counts a token as delivered the instant it is generated — but the
//! gateway pacer and the last-mile link hold tokens back, so a runner
//! that raced ahead looks deep-buffered ("coasting", gain ≈ 0) while
//! the real client sits near the pacer lead. At overload the scheduler
//! serially evicts exactly those runners, and the client stalls the
//! moment its thin buffer drains.
//!
//! This experiment runs the same seeded workload through the full
//! gateway (pacing + fiber delivery) on a 2-replica Andes cluster,
//! slack-aware vs slack-blind, on equal GPU: {poisson, gamma-cv3}
//! arrivals × {1x, 2x, 4x} of estimated capacity. Reported per cell:
//! mean and p10 **client** QoE, playback stall count/time, total
//! preemptions, and preemptions of deep-buffer runners (server-side
//! window ≥ one swap round trip — counted identically in both arms).
//! The headline shape check: at 2x overload the slack-aware arm must
//! match or beat slack-blind mean client QoE while preempting strictly
//! fewer deep-buffer runners.

use std::path::Path;

use anyhow::Result;

use crate::cluster::{Cluster, RoutingPolicy};
use crate::config::SchedulerConfig;
use crate::coordinator::engine::EngineConfig;
use crate::coordinator::sched::andes::AndesConfig;
use crate::gateway::{Gateway, GatewayConfig, PacingConfig};
use crate::model::gpu::a100_4x;
use crate::model::latency::LatencyModel;
use crate::model::llm::opt_66b;
use crate::util::csv::Csv;
use crate::util::stats::{mean, percentile};
use crate::workload::{ArrivalProcess, Dataset, QoeTrace, Workload};

use super::runner::estimate_capacity;
use super::ExpCtx;

/// One cell's aggregates, kept for the shape checks.
struct Cell {
    arrivals: &'static str,
    load: &'static str,
    aware: bool,
    mean_client: f64,
    p10_client: f64,
    stalls: usize,
    stall_time: f64,
    preemptions: u64,
    deep_preemptions: u64,
}

pub fn ext_slack(ctx: &ExpCtx) -> Result<String> {
    let n = if ctx.quick { 120 } else { 400 };
    run_grid(n, Some(&ctx.out_dir))
}

/// The grid itself, parameterized so the determinism test can run a
/// small instance twice in-process and compare reports byte-for-byte.
pub fn run_grid(n: usize, out_dir: Option<&Path>) -> Result<String> {
    let llm = opt_66b();
    let gpu = a100_4x();
    let replicas = 2usize;
    let capacity = estimate_capacity(&llm, &gpu, Dataset::ShareGpt) * replicas as f64;
    let sched = SchedulerConfig::Andes(AndesConfig::default());
    // rate_factor 1.0: release exactly at digestion speed, so the real
    // client holds ~lead tokens throughout. The server-side digest still
    // inflates with every generation burst — the widest server/client
    // gap, i.e. the regime the estimator exists for.
    let pacing = PacingConfig { rate_factor: 1.0, lead_tokens: 4 };

    let arrival_kinds: [&'static str; 2] = ["poisson", "gamma-cv3"];
    let loads: [(&'static str, f64); 3] = [("1x", 1.0), ("2x", 2.0), ("4x", 4.0)];

    let mut csv = Csv::new(&[
        "arrivals",
        "load",
        "slack",
        "served",
        "mean_client_qoe",
        "p10_client_qoe",
        "stalls",
        "stall_time_total",
        "preemptions",
        "deep_buffer_preemptions",
    ]);
    let mut report = format!(
        "ext-slack — {replicas}-replica Andes cluster, capacity {capacity:.1} req/s, \
         {n} requests per cell, slack-aware vs slack-blind on equal GPU\n",
    );
    let mut cells: Vec<Cell> = Vec::new();

    for &akind in &arrival_kinds {
        for &(llabel, mult) in &loads {
            let rate = capacity * mult;
            for aware in [false, true] {
                let arrivals = match akind {
                    "poisson" => ArrivalProcess::Poisson { rate },
                    _ => ArrivalProcess::Gamma { rate, cv: 3.0 },
                };
                let trace = Workload {
                    dataset: Dataset::ShareGpt,
                    arrivals,
                    qoe_trace: QoeTrace::TextReading,
                    num_requests: n,
                    seed: 42,
                }
                .generate();
                let latency = LatencyModel::for_deployment(&llm, &gpu);
                let mut gcfg = GatewayConfig::default();
                gcfg.pacing = pacing.clone();
                gcfg.network.enabled = true; // default fiber mix
                gcfg.surge.baseline_rate = capacity;
                let mut engine_cfg = EngineConfig {
                    kv_capacity_tokens: llm.kv_capacity_tokens(&gpu),
                    swap_capacity_tokens: llm.swap_capacity_tokens(&gpu),
                    ..EngineConfig::default()
                };
                if aware {
                    engine_cfg.slack = Some(gcfg.slack_config());
                }
                let cluster = Cluster::new(
                    replicas,
                    engine_cfg,
                    latency,
                    &sched,
                    RoutingPolicy::QoeAware,
                );
                let mut gw = Gateway::new(cluster, gcfg);
                let res = gw.run_trace(trace)?;
                let client_qoes: Vec<f64> =
                    res.served.iter().map(|s| s.client_qoe).collect();
                let preemptions: u64 =
                    res.per_replica.iter().map(|m| m.total_preemptions).sum();
                let deep: u64 = res
                    .per_replica
                    .iter()
                    .map(|m| m.deep_buffer_preemptions)
                    .sum();
                let cell = Cell {
                    arrivals: akind,
                    load: llabel,
                    aware,
                    mean_client: mean(&client_qoes),
                    p10_client: percentile(&client_qoes, 10.0),
                    stalls: res.total_stalls(),
                    stall_time: res.total_stall_time(),
                    preemptions,
                    deep_preemptions: deep,
                };
                let slabel = if aware { "aware" } else { "blind" };
                csv.row(&[
                    akind.to_string(),
                    llabel.to_string(),
                    slabel.to_string(),
                    format!("{}", res.served.len()),
                    format!("{:.4}", cell.mean_client),
                    format!("{:.4}", cell.p10_client),
                    format!("{}", cell.stalls),
                    format!("{:.2}", cell.stall_time),
                    format!("{preemptions}"),
                    format!("{deep}"),
                ]);
                report.push_str(&format!(
                    "  {akind:<10} {llabel:<3} {slabel:<5} client QoE {:.3} \
                     (p10 {:.3}) stalls {:<5} ({:.1}s) preempt {:<5} \
                     deep {deep}\n",
                    cell.mean_client,
                    cell.p10_client,
                    cell.stalls,
                    cell.stall_time,
                    cell.preemptions,
                ));
                cells.push(cell);
            }
        }
    }
    if let Some(dir) = out_dir {
        csv.write(&dir.join("ext_slack.csv"))?;
    }

    let find = |arrivals: &str, load: &str, aware: bool| {
        cells
            .iter()
            .find(|c| c.arrivals == arrivals && c.load == load && c.aware == aware)
            .expect("cell missing")
    };
    let p2_blind = find("poisson", "2x", false);
    let p2_aware = find("poisson", "2x", true);
    let g2_blind = find("gamma-cv3", "2x", false);
    let g2_aware = find("gamma-cv3", "2x", true);
    // The headline acceptance shape: equal-or-better client QoE with
    // strictly fewer deep-buffer-runner preemptions at 2x overload.
    let c1 = p2_aware.mean_client >= p2_blind.mean_client - 1e-9
        && p2_aware.deep_preemptions < p2_blind.deep_preemptions;
    // The problem must exist for the strict inequality to mean anything.
    let c2 = p2_blind.deep_preemptions > 0;
    let c3 = g2_aware.mean_client >= g2_blind.mean_client - 1e-9;
    report.push_str(&format!(
        "shape checks:\n\
         \x20 poisson 2x: slack-aware holds client QoE ({:.4} >= {:.4}) with \
         strictly fewer deep-buffer preemptions ({} < {}): {}\n\
         \x20 poisson 2x: slack-blind Andes does preempt deep-buffer runners \
         ({} > 0): {}\n\
         \x20 gamma-cv3 2x: slack-aware does not lose client QoE \
         ({:.4} vs {:.4}): {}\n",
        p2_aware.mean_client,
        p2_blind.mean_client,
        p2_aware.deep_preemptions,
        p2_blind.deep_preemptions,
        verdict(c1),
        p2_blind.deep_preemptions,
        verdict(c2),
        g2_aware.mean_client,
        g2_blind.mean_client,
        verdict(c3),
    ));
    Ok(report)
}

fn verdict(ok: bool) -> &'static str {
    if ok {
        "HOLDS"
    } else {
        "VIOLATED"
    }
}
