//! Sensitivity analyses (§6.5): preemption cap P (Fig. 16), prediction
//! timeframe Δt (Fig. 17), greedy vs DP knapsack solver (Fig. 18), and
//! the alternative scheduling objectives of Appendix A.

use anyhow::Result;

use crate::coordinator::sched::andes::{AndesConfig, KnapsackSolver};
use crate::coordinator::sched::objective::Objective;
use crate::model::gpu::a100_4x;
use crate::model::llm::opt_66b;
use crate::util::csv::Csv;
use crate::util::plot::{line_plot, Series};
use crate::util::stats::percentile;
use crate::workload::{ArrivalProcess, Dataset, QoeTrace};

use super::runner::{SchedKind, SimRun};
use super::ExpCtx;

fn eval_rate(ctx: &ExpCtx) -> f64 {
    let _ = ctx;
    super::runner::eval_rate(&opt_66b(), &a100_4x(), Dataset::ShareGpt)
}

fn run_andes(ctx: &ExpCtx, cfg: AndesConfig, rate: f64) -> crate::coordinator::metrics::Metrics {
    SimRun {
        llm: opt_66b(),
        gpu: a100_4x(),
        sched: SchedKind::Andes(cfg),
        dataset: Dataset::ShareGpt,
        arrivals: ArrivalProcess::Poisson { rate },
        qoe_trace: QoeTrace::TextReading,
        num_requests: if ctx.quick { 600 } else { 1500 },
        seed: 42,
    }
    .execute()
}

/// Fig. 16: preemption cap P sweep — QoE rises then plateaus; throughput
/// mildly decreases.
pub fn fig16(ctx: &ExpCtx) -> Result<String> {
    let rate = eval_rate(ctx);
    let caps = if ctx.quick {
        vec![0.0, 0.4, 1.0]
    } else {
        vec![0.0, 0.1, 0.2, 0.4, 0.7, 1.0, 2.0, 4.0]
    };
    let mut csv = Csv::new(&["P", "avg_qoe", "throughput", "preempt_per_req"]);
    let mut qoe_pts = Vec::new();
    let mut tput_pts = Vec::new();
    for &p in &caps {
        let m = run_andes(ctx, AndesConfig { preemption_cap: p, ..AndesConfig::default() }, rate);
        csv.row_f64(&[p, m.avg_qoe(), m.throughput(), m.preemption_frequency()]);
        qoe_pts.push((p, m.avg_qoe()));
        tput_pts.push((p, m.throughput()));
    }
    csv.write(&ctx.out_dir.join("fig16_preemption_cap.csv"))?;
    let mut report = line_plot(
        "Fig. 16a — avg QoE vs preemption cap P",
        "P (preempts/request)",
        "avg QoE",
        &[Series::new("andes", qoe_pts.clone())],
    );
    report.push_str(&line_plot(
        "Fig. 16b — throughput vs preemption cap P",
        "P",
        "tokens/s",
        &[Series::new("andes", tput_pts.clone())],
    ));
    let q0 = qoe_pts[0].1;
    let qmax = qoe_pts.iter().map(|x| x.1).fold(0.0f64, f64::max);
    let plateau = {
        // Values at P ≥ 0.4 within 5% of each other.
        let tail: Vec<f64> =
            qoe_pts.iter().filter(|&&(p, _)| p >= 0.4).map(|x| x.1).collect();
        let lo = tail.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = tail.iter().cloned().fold(0.0f64, f64::max);
        hi - lo < 0.08
    };
    report.push_str(&format!(
        "shape check: QoE improves with P (P=0: {q0:.3} → max {qmax:.3}) then plateaus: {}\n",
        if qmax > q0 && plateau { "HOLDS" } else { "VIOLATED" }
    ));
    Ok(report)
}

/// Fig. 17: Δt sweep — average QoE roughly flat for Δt ≥ 50, above
/// baselines.
pub fn fig17(ctx: &ExpCtx) -> Result<String> {
    let rate = eval_rate(ctx);
    let dts = if ctx.quick {
        vec![25.0, 100.0]
    } else {
        vec![10.0, 25.0, 50.0, 100.0, 200.0, 400.0]
    };
    let mut csv = Csv::new(&["delta_t", "avg_qoe"]);
    let mut pts = Vec::new();
    for &dt in &dts {
        let m = run_andes(
            ctx,
            AndesConfig { delta_t_override: Some(dt), ..AndesConfig::default() },
            rate,
        );
        csv.row_f64(&[dt, m.avg_qoe()]);
        pts.push((dt, m.avg_qoe()));
    }
    // Baseline for comparison.
    let fcfs = SimRun {
        llm: opt_66b(),
        gpu: a100_4x(),
        sched: SchedKind::Fcfs,
        dataset: Dataset::ShareGpt,
        arrivals: ArrivalProcess::Poisson { rate },
        qoe_trace: QoeTrace::TextReading,
        num_requests: if ctx.quick { 600 } else { 1500 },
        seed: 42,
    }
    .execute();
    csv.write(&ctx.out_dir.join("fig17_delta_t.csv"))?;
    let mut report = line_plot(
        "Fig. 17 — avg QoE vs Δt",
        "Δt (s)",
        "avg QoE",
        &[
            Series::new("andes", pts.clone()),
            Series::new("fcfs", dts.iter().map(|&d| (d, fcfs.avg_qoe())).collect()),
        ],
    );
    let tail: Vec<f64> = pts.iter().filter(|&&(d, _)| d >= 50.0).map(|x| x.1).collect();
    let flat = tail.iter().cloned().fold(0.0f64, f64::max)
        - tail.iter().cloned().fold(f64::INFINITY, f64::min)
        < 0.08;
    let beats = tail.iter().all(|&q| q > fcfs.avg_qoe());
    report.push_str(&format!(
        "shape check: flat for Δt ≥ 50 and above FCFS: {}\n",
        if flat && beats { "HOLDS" } else { "VIOLATED" }
    ));
    Ok(report)
}

/// Fig. 18: greedy (Algorithm 1) vs exact DP (Algorithm 2) end to end.
/// The DP's higher solve cost makes it *worse* online (the paper's
/// finding); we also report raw solver wall-time.
///
/// Run on a scaled-down deployment (M = 8k tokens, ~35 concurrent
/// requests): at full 66B scale the pseudo-polynomial DP needs hours per
/// run — precisely the intractability the paper cites (Appendix C); the
/// scaled instance preserves the contention pattern while keeping the
/// DP measurable.
pub fn fig18(ctx: &ExpCtx) -> Result<String> {
    use crate::backend::sim::SimBackend;
    use crate::backend::VirtualClock;
    use crate::coordinator::engine::{Engine, EngineConfig};
    use crate::model::latency::LatencyModel;
    use crate::coordinator::sched::andes::AndesScheduler;

    let llm = opt_66b();
    let gpu = a100_4x();
    let latency = LatencyModel::for_deployment(&llm, &gpu);
    // Tiny memory slice of the 66B node → ~17-request batches.
    let small = EngineConfig {
        kv_capacity_tokens: 8_000,
        swap_capacity_tokens: 16_000,
        ..EngineConfig::default()
    };
    let rate = 2.0; // ≈1.8× this slice's capacity
    let n = if ctx.quick { 200 } else { 500 };

    let run_small = |solver: KnapsackSolver| {
        let sched = AndesScheduler::new(AndesConfig {
            solver,
            b_grid: 3,
            ..AndesConfig::default()
        });
        let mut e = Engine::new(
            small.clone(),
            SimBackend::new(latency.clone()),
            VirtualClock::default(),
            Box::new(sched),
            latency.clone(),
        );
        e.load_trace(
            crate::workload::Workload {
                dataset: Dataset::ShareGpt,
                arrivals: ArrivalProcess::Poisson { rate },
                qoe_trace: QoeTrace::TextReading,
                num_requests: n,
                seed: 42,
            }
            .generate(),
        );
        e.run_to_completion().unwrap();
        std::mem::take(e.metrics_mut())
    };

    let mut csv = Csv::new(&["solver", "avg_qoe", "scheduler_time_s", "p50_ttft"]);
    let mut report = String::from(
        "Fig. 18 — knapsack solver comparison (scaled deployment, M = 8k tokens)\n",
    );
    let mut rows = Vec::new();
    for (name, solver) in [("greedy", KnapsackSolver::Greedy), ("dp", KnapsackSolver::Dp)] {
        let m = run_small(solver);
        csv.row(&[
            name.to_string(),
            format!("{:.4}", m.avg_qoe()),
            format!("{:.2}", m.scheduler_time),
            format!("{:.2}", percentile(&m.ttfts(), 50.0)),
        ]);
        report.push_str(&format!(
            "  {name:<7} avg QoE {:.3}, cumulative solver time {:.2}s\n",
            m.avg_qoe(),
            m.scheduler_time
        ));
        rows.push((name, m.avg_qoe(), m.scheduler_time));
    }
    csv.write(&ctx.out_dir.join("fig18_solver.csv"))?;
    let greedy = rows.iter().find(|r| r.0 == "greedy").unwrap();
    let dp = rows.iter().find(|r| r.0 == "dp").unwrap();
    report.push_str(&format!(
        "shape check: greedy QoE ≥ DP QoE − ε AND greedy solver ≫ cheaper: {}\n",
        if greedy.1 >= dp.1 - 0.05 && greedy.2 < dp.2 { "HOLDS" } else { "VIOLATED" }
    ));
    Ok(report)
}

/// Appendix A: alternative scheduling objectives. Max-min lifts the QoE
/// floor; PerfectCount maximizes the number of QoE = 1 requests.
pub fn app_a(ctx: &ExpCtx) -> Result<String> {
    // Milder overload than the breakdown point: with the floor already
    // at 0 (deep overload), Eq. 6's max-min gain degenerates — there is
    // no floor left to lift.
    let rate = eval_rate(ctx) * 0.75;
    let mut csv = Csv::new(&["objective", "avg_qoe", "p10_qoe", "min_qoe", "perfect_frac"]);
    let mut report = String::from("Appendix A — scheduling objectives\n  objective      avg    p10    min    %perfect\n");
    let mut rows = Vec::new();
    for (name, obj) in [
        ("avg-qoe", Objective::AvgQoe),
        ("max-min", Objective::MaxMin),
        ("perfect-count", Objective::PerfectCount),
    ] {
        let m = run_andes(ctx, AndesConfig { objective: obj, ..AndesConfig::default() }, rate);
        let qoes = m.qoes();
        let min = qoes.iter().cloned().fold(f64::INFINITY, f64::min);
        let p10 = percentile(&qoes, 10.0);
        let perfect =
            qoes.iter().filter(|&&q| q > 0.999).count() as f64 / qoes.len() as f64;
        csv.row(&[
            name.to_string(),
            format!("{:.4}", m.avg_qoe()),
            format!("{p10:.4}"),
            format!("{min:.4}"),
            format!("{perfect:.3}"),
        ]);
        report.push_str(&format!(
            "  {name:<14} {:.3}  {p10:.3}  {min:.3}  {:.1}%\n",
            m.avg_qoe(),
            perfect * 100.0
        ));
        rows.push((name, m.avg_qoe(), p10, min, perfect));
    }
    csv.write(&ctx.out_dir.join("appA_objectives.csv"))?;
    let avg = rows.iter().find(|r| r.0 == "avg-qoe").unwrap();
    let maxmin = rows.iter().find(|r| r.0 == "max-min").unwrap();
    report.push_str(&format!(
        "shape check: max-min p10 ≥ avg-qoe p10 − ε: {}\n",
        if maxmin.2 >= avg.2 - 0.05 { "HOLDS" } else { "VIOLATED" }
    ));
    Ok(report)
}
