//! ext-federation: multi-gateway federation under tiered overload.
//!
//! Sweeps {1, 2, 4 gateways} × {fresh, stale snapshot sync} ×
//! {tier-blind, tier-weighted admission} in front of a 2-replica Andes
//! cluster at 2× aggregate capacity on the tiered QoE trace (paper
//! §6.1's price tiers). Reported per cell: per-tier arrivals / served /
//! rejected counts, mean and p10 QoE counting rejects as zero, and the
//! **cross-gateway admission disagreement rate** (on each arrival,
//! every node is asked what it would decide on its own — possibly
//! stale — view; see `gateway/federation.rs`).
//!
//! Shape checks assert the federation story: scaling the front door to
//! 4 gateways at fresh sync costs ≤ 5% mean QoE vs. a single gateway,
//! stale sync disagrees at least as often as fresh, and premium weight
//! 2 strictly improves premium p10 QoE over tier-blind admission at
//! this overload.

use anyhow::Result;

use crate::cluster::{Cluster, RoutingPolicy};
use crate::config::SchedulerConfig;
use crate::coordinator::engine::EngineConfig;
use crate::coordinator::sched::andes::AndesConfig;
use crate::gateway::{FederatedGateway, FederationConfig, GatewayConfig, TierWeights};
use crate::model::gpu::a100_4x;
use crate::model::latency::LatencyModel;
use crate::model::llm::opt_66b;
use crate::qoe::spec::QoeSpec;
use crate::util::csv::Csv;
use crate::util::stats::{mean, percentile};
use crate::workload::qoe_trace::QoeTrace;
use crate::workload::{ArrivalProcess, Dataset, Workload};

use super::runner::estimate_capacity;
use super::ExpCtx;

const TIERS: [&str; 3] = ["premium", "standard", "economy"];

struct Cell {
    gateways: usize,
    sync: &'static str,
    weights: &'static str,
    mean_qoe: f64,
    disagreement: f64,
    /// Per-tier p10 QoE counting rejects as zero, in TIERS order.
    tier_p10: [f64; 3],
}

fn tier_of_tds(tds: f64) -> &'static str {
    QoeTrace::tier_of(&QoeSpec::new(1.0, tds))
}

pub fn ext_federation(ctx: &ExpCtx) -> Result<String> {
    let llm = opt_66b();
    let gpu = a100_4x();
    let latency = LatencyModel::for_deployment(&llm, &gpu);
    let replicas = 2usize;
    let capacity = estimate_capacity(&llm, &gpu, Dataset::ShareGpt) * replicas as f64;
    let rate = capacity * 2.0; // the acceptance point: 2× overload
    let n = if ctx.quick { 320 } else { 800 };
    let engine_cfg = EngineConfig {
        kv_capacity_tokens: llm.kv_capacity_tokens(&gpu),
        swap_capacity_tokens: llm.swap_capacity_tokens(&gpu),
        ..EngineConfig::default()
    };
    let sched = SchedulerConfig::Andes(AndesConfig::default());
    let trace = Workload {
        dataset: Dataset::ShareGpt,
        arrivals: ArrivalProcess::Poisson { rate },
        qoe_trace: QoeTrace::Tiered,
        num_requests: n,
        seed: 42,
    }
    .generate();

    let syncs: [(&'static str, f64, f64); 2] =
        [("fresh", 0.25, 2.0), ("stale", 10.0, 60.0)];
    let weight_variants: [(&'static str, TierWeights); 2] = [
        ("blind", TierWeights::default()),
        ("weighted", TierWeights { premium: 2.0, standard: 1.0, economy: 0.5 }),
    ];

    let mut csv = Csv::new(&[
        "gateways",
        "sync",
        "weights",
        "tier",
        "arrivals",
        "served",
        "rejected",
        "mean_qoe_incl_rejects",
        "p10_qoe_incl_rejects",
        "disagreement_rate",
    ]);
    let mut report = format!(
        "ext-federation — {replicas}-replica Andes cluster at 2x overload \
         ({rate:.1} req/s), tiered workload, {n} requests\n"
    );
    let mut cells: Vec<Cell> = Vec::new();

    for gateways in [1usize, 2, 4] {
        for &(slabel, sync_interval, staleness) in &syncs {
            for &(wlabel, weights) in &weight_variants {
                let cluster = Cluster::new(
                    replicas,
                    engine_cfg.clone(),
                    latency.clone(),
                    &sched,
                    RoutingPolicy::QoeAware,
                );
                let mut gcfg = GatewayConfig::default();
                gcfg.pacing_enabled = false;
                gcfg.surge.baseline_rate = capacity;
                gcfg.admission.tier_weights = weights;
                let fed = FederationConfig {
                    gateways,
                    sync_interval_secs: sync_interval,
                    staleness_bound_secs: staleness,
                };
                let mut gw = FederatedGateway::new(cluster, gcfg, fed);
                let res = gw.run_trace(trace.clone())?;

                // Per-tier QoE: served requests classified by their
                // preserved QoE spec (engine ids follow admission order,
                // not trace order), rejects by the workload spec.
                let mut tier_qoes: [Vec<f64>; 3] = Default::default();
                let mut tier_arrivals = [0usize; 3];
                let mut tier_rejected = [0usize; 3];
                for spec in &trace {
                    let k = tier_index(QoeTrace::tier_of(&spec.qoe));
                    tier_arrivals[k] += 1;
                }
                for s in &res.served {
                    tier_qoes[tier_index(tier_of_tds(s.expected_tds))].push(s.paced_qoe);
                }
                for r in &res.rejections {
                    let k = tier_index(QoeTrace::tier_of(&trace[r.id].qoe));
                    tier_qoes[k].push(0.0);
                    tier_rejected[k] += 1;
                }

                let disagreement = res.stats.disagreement_rate();
                let mut tier_p10 = [0.0f64; 3];
                for (k, tier) in TIERS.iter().enumerate() {
                    let qoes = &tier_qoes[k];
                    tier_p10[k] = percentile(qoes, 10.0);
                    csv.row(&[
                        format!("{gateways}"),
                        slabel.to_string(),
                        wlabel.to_string(),
                        tier.to_string(),
                        format!("{}", tier_arrivals[k]),
                        format!("{}", qoes.len() - tier_rejected[k]),
                        format!("{}", tier_rejected[k]),
                        format!("{:.4}", mean(qoes)),
                        format!("{:.4}", tier_p10[k]),
                        format!("{disagreement:.4}"),
                    ]);
                }
                let cell = Cell {
                    gateways,
                    sync: slabel,
                    weights: wlabel,
                    mean_qoe: res.mean_qoe_incl_rejects(),
                    disagreement,
                    tier_p10,
                };
                csv.row(&[
                    format!("{gateways}"),
                    slabel.to_string(),
                    wlabel.to_string(),
                    "all".to_string(),
                    format!("{}", res.stats.arrivals),
                    format!("{}", res.served.len()),
                    format!("{}", res.rejections.len()),
                    format!("{:.4}", cell.mean_qoe),
                    format!("{:.4}", percentile_incl(&res)),
                    format!("{disagreement:.4}"),
                ]);
                report.push_str(&format!(
                    "  g={gateways} {slabel:<6} {wlabel:<9} served {:<4} rejected {:<4} \
                     QoE {:.3} (incl-rej) disagreement {:.3} premium-p10 {:.3}\n",
                    res.served.len(),
                    res.rejections.len(),
                    cell.mean_qoe,
                    disagreement,
                    cell.tier_p10[0],
                ));
                cells.push(cell);
            }
        }
    }
    csv.write(&ctx.out_dir.join("ext_federation.csv"))?;

    // Shape checks.
    let single = find(&cells, 1, "fresh", "blind");
    let fed4 = find(&cells, 4, "fresh", "blind");
    let fed4_stale = find(&cells, 4, "stale", "blind");
    let weighted4 = find(&cells, 4, "fresh", "weighted");
    let weighted1 = find(&cells, 1, "fresh", "weighted");
    let c1 = fed4.mean_qoe >= 0.95 * single.mean_qoe;
    let c2 = fed4_stale.disagreement >= fed4.disagreement;
    let c3 = weighted4.tier_p10[0] > fed4.tier_p10[0];
    let c4 = weighted1.tier_p10[0] > single.tier_p10[0];
    report.push_str(&format!(
        "shape checks @2x overload:\n\
         \x20 4 fresh-sync gateways within 5% of a single gateway \
         ({:.3} vs {:.3}): {}\n\
         \x20 stale sync disagrees at least as often as fresh \
         ({:.3} vs {:.3}): {}\n\
         \x20 tier weights strictly improve premium p10, 4 gateways \
         ({:.3} vs {:.3}): {}\n\
         \x20 tier weights strictly improve premium p10, 1 gateway \
         ({:.3} vs {:.3}): {}\n",
        fed4.mean_qoe,
        single.mean_qoe,
        verdict(c1),
        fed4_stale.disagreement,
        fed4.disagreement,
        verdict(c2),
        weighted4.tier_p10[0],
        fed4.tier_p10[0],
        verdict(c3),
        weighted1.tier_p10[0],
        single.tier_p10[0],
        verdict(c4),
    ));
    Ok(report)
}

/// Overall p10 QoE counting rejects as zero.
fn percentile_incl(res: &crate::gateway::FederationRunResult) -> f64 {
    let mut qoes: Vec<f64> = res.served.iter().map(|s| s.paced_qoe).collect();
    qoes.resize(qoes.len() + res.rejections.len(), 0.0);
    percentile(&qoes, 10.0)
}

fn tier_index(tier: &str) -> usize {
    TIERS.iter().position(|t| *t == tier).expect("known tier")
}

fn verdict(ok: bool) -> &'static str {
    if ok {
        "HOLDS"
    } else {
        "VIOLATED"
    }
}

fn find<'a>(cells: &'a [Cell], gateways: usize, sync: &str, weights: &str) -> &'a Cell {
    cells
        .iter()
        .find(|c| c.gateways == gateways && c.sync == sync && c.weights == weights)
        .expect("cell missing")
}
