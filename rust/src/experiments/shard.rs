//! Sharded grid runner: split whole-simulation grids across
//! `std::thread` workers with a deterministic merge (DESIGN.md §14).
//!
//! Each cell of an experiment grid is an *entire* simulation — its own
//! trace, cluster, and gateway — so cells share no mutable state and can
//! run on any thread. Worker `w` of `n` takes cell indices `w, w + n,
//! w + 2n, …`; results travel back over a channel tagged with their cell
//! index and are merged into cell order before anything downstream (CSV
//! rows, report lines, telemetry) is assembled. The output is therefore
//! a pure function of the cell list — byte-identical for every shard
//! count, which `rust/tests/calendar.rs` locks in.

use std::sync::mpsc;

/// Run `run(i, &cells[i])` for every cell, fanned out across `shards`
/// worker threads, and return the outputs in cell order.
///
/// `shards <= 1` (or a grid of at most one cell) runs inline on the
/// caller's thread — the zero-thread baseline the sharded path must
/// match byte for byte.
///
/// # Panics
///
/// A panic in any worker aborts the run and propagates to the caller
/// (via [`std::thread::scope`]); no partial result is returned.
///
/// ```
/// use andes::experiments::shard::run_grid;
/// let cells: Vec<u64> = (0..10).collect();
/// let one = run_grid(&cells, 1, |i, c| i as u64 * 100 + c * c);
/// let four = run_grid(&cells, 4, |i, c| i as u64 * 100 + c * c);
/// assert_eq!(one, four);
/// ```
pub fn run_grid<C, T, F>(cells: &[C], shards: usize, run: F) -> Vec<T>
where
    C: Sync,
    T: Send,
    F: Fn(usize, &C) -> T + Sync,
{
    if shards <= 1 || cells.len() <= 1 {
        return cells.iter().enumerate().map(|(i, c)| run(i, c)).collect();
    }
    let workers = shards.min(cells.len());
    let mut slots: Vec<Option<T>> = Vec::with_capacity(cells.len());
    slots.resize_with(cells.len(), || None);
    let run = &run;
    std::thread::scope(|scope| {
        let (tx, rx) = mpsc::channel::<(usize, T)>();
        for w in 0..workers {
            let tx = tx.clone();
            scope.spawn(move || {
                for i in (w..cells.len()).step_by(workers) {
                    // A failed send means the receiver is gone, i.e. the
                    // collector below already panicked; nothing to do.
                    let _ = tx.send((i, run(i, &cells[i])));
                }
            });
        }
        drop(tx);
        for (i, out) in rx {
            slots[i] = Some(out);
        }
    });
    slots
        .into_iter()
        .map(|s| {
            // lint:allow(D6, an empty slot means a worker panicked, which scope propagated)
            s.expect("every cell index is covered by exactly one worker")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_order_is_cell_order_for_any_shard_count() {
        let cells: Vec<usize> = (0..23).collect();
        let baseline = run_grid(&cells, 1, |i, c| format!("{i}:{c}"));
        for shards in [2, 3, 4, 8, 23, 64] {
            assert_eq!(
                run_grid(&cells, shards, |i, c| format!("{i}:{c}")),
                baseline,
                "shards={shards} must merge identically"
            );
        }
    }

    #[test]
    fn empty_and_singleton_grids() {
        let none: Vec<u8> = vec![];
        assert!(run_grid(&none, 4, |_, c| *c).is_empty());
        assert_eq!(run_grid(&[7u8], 4, |i, c| (i, *c)), vec![(0, 7)]);
    }

    #[test]
    fn index_matches_cell() {
        let cells: Vec<usize> = (100..140).collect();
        let out = run_grid(&cells, 5, |i, c| (i, *c));
        for (i, (idx, c)) in out.iter().enumerate() {
            assert_eq!(*idx, i);
            assert_eq!(*c, 100 + i);
        }
    }
}
