//! ext-gateway: the QoE-aware serving gateway under load surges.
//!
//! Compares four front-door configurations — {none, admission-only,
//! pacing-only, full} — fronting a 2-replica Andes cluster, under
//! Poisson and Gamma-burst (cv = 3) arrivals at 1×/2×/4× of the
//! estimated aggregate capacity. Reports per-cell: served/rejected
//! counts, mean and p10 QoE over served requests, mean QoE counting
//! rejects as zero, and the fraction of tokens delivered ahead of the
//! digestion deadline before/after delivery shaping.
//!
//! The 24-cell grid fans out over [`super::shard::run_grid`]
//! (`--shards N`); every cell is a self-contained simulation, and the
//! CSV/report/telemetry are assembled from the merged results in cell
//! order, so the artifacts are byte-identical at any shard count.

use anyhow::Result;

use crate::cluster::{Cluster, RoutingPolicy};
use crate::config::SchedulerConfig;
use crate::coordinator::engine::EngineConfig;
use crate::coordinator::sched::andes::AndesConfig;
use crate::gateway::{Gateway, GatewayConfig};
use crate::model::gpu::a100_4x;
use crate::model::latency::LatencyModel;
use crate::model::llm::opt_66b;
use crate::telemetry::{Telemetry, TelemetryConfig};
use crate::util::csv::Csv;
use crate::util::stats::percentile;
use crate::workload::{ArrivalProcess, Dataset, QoeTrace, Workload};

use super::runner::estimate_capacity;
use super::{shard, ExpCtx};

#[derive(Clone, Copy)]
struct Variant {
    name: &'static str,
    admission: bool,
    pacing: bool,
}

/// One cell's outcome, kept for the shape checks.
struct Cell {
    arrivals: &'static str,
    load: f64,
    variant: &'static str,
    mean_served: f64,
    reject_frac: f64,
    early_raw: f64,
    early_shaped: f64,
}

/// One cell of the sharded grid: arrivals × load × variant.
struct GridCell {
    alabel: &'static str,
    cv: f64,
    load: f64,
    variant: Variant,
}

/// Everything a worker brings back from one cell; the CSV, report, and
/// telemetry artifacts are assembled from these post-merge so file
/// output order never depends on thread scheduling.
struct CellOut {
    cell: Cell,
    csv_row: Vec<String>,
    line: String,
    /// `(trace jsonl, snapshot csv, event count)` from the single
    /// instrumented stress cell, when `--trace-out` is set.
    telemetry: Option<(String, String, usize)>,
}

pub fn ext_gateway(ctx: &ExpCtx) -> Result<String> {
    let llm = opt_66b();
    let gpu = a100_4x();
    let latency = LatencyModel::for_deployment(&llm, &gpu);
    let replicas = 2usize;
    let capacity = estimate_capacity(&llm, &gpu, Dataset::ShareGpt) * replicas as f64;
    let n = if ctx.quick { 400 } else { 1000 };
    let engine_cfg = EngineConfig {
        kv_capacity_tokens: llm.kv_capacity_tokens(&gpu),
        swap_capacity_tokens: llm.swap_capacity_tokens(&gpu),
        ..EngineConfig::default()
    };
    let sched = SchedulerConfig::Andes(AndesConfig::default());
    let variants = [
        Variant { name: "none", admission: false, pacing: false },
        Variant { name: "admission", admission: true, pacing: false },
        Variant { name: "pacing", admission: false, pacing: true },
        Variant { name: "full", admission: true, pacing: true },
    ];
    let mut grid: Vec<GridCell> = Vec::new();
    for (alabel, cv) in [("poisson", 1.0), ("gamma-cv3", 3.0)] {
        for load in [1.0, 2.0, 4.0] {
            for variant in variants {
                grid.push(GridCell { alabel, cv, load, variant });
            }
        }
    }

    let outs = shard::run_grid(&grid, ctx.shards, |_, g| -> Result<CellOut> {
        let v = g.variant;
        let rate = capacity * g.load;
        // Each cell regenerates its (seeded) trace so cells stay fully
        // independent across worker threads.
        let trace = Workload {
            dataset: Dataset::ShareGpt,
            arrivals: if g.cv == 1.0 {
                ArrivalProcess::Poisson { rate }
            } else {
                ArrivalProcess::Gamma { rate, cv: g.cv }
            },
            qoe_trace: QoeTrace::TextReading,
            num_requests: n,
            seed: 42,
        }
        .generate();
        let mut cluster = Cluster::new(
            replicas,
            engine_cfg.clone(),
            latency.clone(),
            &sched,
            RoutingPolicy::QoeAware,
        );
        let mut gcfg = GatewayConfig::default();
        gcfg.admission_enabled = v.admission;
        gcfg.pacing_enabled = v.pacing;
        gcfg.surge.baseline_rate = capacity;
        // `--trace-out` instruments exactly the stress cell (4×
        // Gamma-burst, full gateway) — the cell the shape checks
        // interrogate; its artifacts are written post-merge.
        let instrument = ctx.trace_out.is_some()
            && g.alabel == "gamma-cv3"
            && g.load == 4.0
            && v.name == "full";
        let telemetry = if instrument {
            Telemetry::new(&TelemetryConfig {
                enabled: true,
                snapshot_interval: 1.0,
                ..TelemetryConfig::default()
            })
        } else {
            Telemetry::disabled()
        };
        telemetry.set_time_domain("sim");
        cluster.set_telemetry(telemetry.clone());
        let mut gw = Gateway::new(cluster, gcfg);
        gw.set_telemetry(telemetry.clone());
        let res = gw.run_trace(trace)?;
        let served: Vec<f64> = res.served.iter().map(|s| s.paced_qoe).collect();
        let (early_raw, early_shaped) = res.early_token_fractions();
        let cell = Cell {
            arrivals: g.alabel,
            load: g.load,
            variant: v.name,
            mean_served: res.mean_served_qoe(),
            reject_frac: res.rejected_fraction(),
            early_raw,
            early_shaped,
        };
        let csv_row = vec![
            g.alabel.to_string(),
            format!("{}", g.load),
            v.name.to_string(),
            format!("{}", served.len()),
            format!("{}", res.rejections.len()),
            format!("{:.4}", cell.reject_frac),
            format!("{:.4}", cell.mean_served),
            format!("{:.4}", percentile(&served, 10.0)),
            format!("{:.4}", res.mean_qoe_incl_rejects()),
            format!("{early_raw:.4}"),
            format!("{early_shaped:.4}"),
            format!("{}", res.stats.surge_transitions),
        ];
        let line = format!(
            "  {:<10} {:.0}x {:<10} served {:<4} rejected {:<4} \
             QoE {:.3} (p10 {:.3}, incl-rej {:.3}) early {:.2}→{:.2}\n",
            g.alabel,
            g.load,
            v.name,
            served.len(),
            res.rejections.len(),
            cell.mean_served,
            percentile(&served, 10.0),
            res.mean_qoe_incl_rejects(),
            early_raw,
            early_shaped,
        );
        let telemetry_out = instrument.then(|| {
            (
                telemetry.trace_jsonl(),
                telemetry.snapshot_csv(),
                telemetry.trace_stats().0,
            )
        });
        Ok(CellOut { cell, csv_row, line, telemetry: telemetry_out })
    });

    let mut csv = Csv::new(&[
        "arrivals",
        "load",
        "variant",
        "served",
        "rejected",
        "reject_frac",
        "mean_served_qoe",
        "p10_served_qoe",
        "mean_qoe_incl_rejects",
        "early_frac_unshaped",
        "early_frac_delivered",
        "surge_transitions",
    ]);
    let mut report = format!(
        "ext-gateway — {replicas}-replica Andes cluster, aggregate capacity ≈ {capacity:.1} req/s\n"
    );
    let mut cells: Vec<Cell> = Vec::new();
    for out in outs {
        let out = out?;
        if let (Some((jsonl, snapshots, events)), Some(path)) =
            (&out.telemetry, &ctx.trace_out)
        {
            std::fs::write(path, jsonl)?;
            let csv_path = path.with_extension("metrics.csv");
            std::fs::write(&csv_path, snapshots)?;
            report.push_str(&format!(
                "  trace: {} ({} events) + {}\n",
                path.display(),
                events,
                csv_path.display(),
            ));
        }
        csv.row(&out.csv_row);
        report.push_str(&out.line);
        cells.push(out.cell);
    }
    csv.write(&ctx.out_dir.join("ext_gateway.csv"))?;

    // Shape checks at the stress cell: 4× Gamma-burst load.
    let none4 = find(&cells, "none", "gamma-cv3", 4.0);
    let full4 = find(&cells, "full", "gamma-cv3", 4.0);
    let pace4 = find(&cells, "pacing", "gamma-cv3", 4.0);
    let none1 = find(&cells, "none", "poisson", 1.0);
    let full1 = find(&cells, "full", "poisson", 1.0);
    let c1 = full4.mean_served > none4.mean_served;
    let c2 = full4.reject_frac > 0.0 && full4.reject_frac <= 0.85;
    let c3 = pace4.early_shaped < pace4.early_raw
        && pace4.mean_served >= none4.mean_served - 0.02;
    let c4 = full1.reject_frac <= 0.1;
    report.push_str(&format!(
        "shape checks @4x gamma-burst:\n\
         \x20 full gateway beats no-gateway on served QoE ({:.3} vs {:.3}): {}\n\
         \x20 rejected fraction bounded (0 < {:.3} <= 0.85): {}\n\
         \x20 pacing alone cuts early tokens ({:.2} -> {:.2}) at no QoE cost: {}\n\
         \x20 @1x poisson the full gateway rejects <= 10% ({:.3}): {}\n\
         \x20 sanity: no-gateway served QoE at 1x poisson = {:.3}\n",
        full4.mean_served,
        none4.mean_served,
        verdict(c1),
        full4.reject_frac,
        verdict(c2),
        pace4.early_raw,
        pace4.early_shaped,
        verdict(c3),
        full1.reject_frac,
        verdict(c4),
        none1.mean_served,
    ));
    Ok(report)
}

fn verdict(ok: bool) -> &'static str {
    if ok {
        "HOLDS"
    } else {
        "VIOLATED"
    }
}

fn find<'a>(cells: &'a [Cell], variant: &str, arrivals: &str, load: f64) -> &'a Cell {
    cells
        .iter()
        .find(|c| c.variant == variant && c.arrivals == arrivals && c.load == load)
        .expect("cell missing")
}
