//! Microbenchmarks: Fig. 20 (swap vs recomputation overhead) and the
//! real-model end-to-end run (DESIGN.md `e2e`).

use anyhow::Result;

use crate::model::gpu::a100_4x;
use crate::model::latency::LatencyModel;
use crate::model::llm::{opt_13b, opt_30b, opt_66b};
use crate::util::csv::Csv;
use crate::util::plot::{line_plot, Series};

use super::ExpCtx;

/// Fig. 20 (Appendix D): swap vs recomputation latency as a function of
/// the preempted context size, across OPT models on the A100 node.
pub fn fig20(ctx: &ExpCtx) -> Result<String> {
    let gpu = a100_4x();
    let mut csv = Csv::new(&["model", "tokens", "swap_s", "recompute_s"]);
    let mut report = String::new();
    let mut all_hold = true;
    for llm in [opt_13b(), opt_30b(), opt_66b()] {
        let lat = LatencyModel::for_deployment(&llm, &gpu);
        let mut swap_pts = Vec::new();
        let mut rec_pts = Vec::new();
        for tokens in (128..=2048).step_by(128) {
            let s = lat.swap(tokens);
            let r = lat.recompute(tokens);
            csv.row(&[
                llm.name.to_string(),
                format!("{tokens}"),
                format!("{s:.4}"),
                format!("{r:.4}"),
            ]);
            swap_pts.push((tokens as f64, s));
            rec_pts.push((tokens as f64, r));
        }
        report.push_str(&line_plot(
            &format!("Fig. 20 — preemption overhead ({})", llm.name),
            "context tokens",
            "seconds",
            &[Series::new("swap", swap_pts.clone()), Series::new("recompute", rec_pts.clone())],
        ));
        // Paper (their node): swap consistently cheaper at realistic sizes.
        if swap_pts.last().unwrap().1 >= rec_pts.last().unwrap().1 {
            all_hold = false;
        }
    }
    csv.write(&ctx.out_dir.join("fig20_preemption_overhead.csv"))?;
    report.push_str(&format!(
        "shape check (swap cheaper than recompute at large contexts): {}\n",
        if all_hold { "HOLDS" } else { "VIOLATED" }
    ));
    Ok(report)
}

/// End-to-end real-model run: the tiny-OPT PJRT artifacts served by the
/// Andes engine — the proof that all three layers compose. Gated on
/// `make artifacts` having run.
pub fn e2e_real(ctx: &ExpCtx) -> Result<String> {
    use crate::backend::pjrt::PjrtBackend;
    use crate::backend::WallClock;
    use crate::coordinator::engine::{Engine, EngineConfig};
    use crate::coordinator::sched::andes::AndesScheduler;
    use crate::model::gpu::a100_1x;
    use crate::model::llm::tiny_opt;
    use crate::qoe::spec::QoeSpec;
    use crate::runtime::engine::ModelRuntime;
    use crate::runtime::tokenizer::ByteTokenizer;
    use crate::runtime::Sampling;
    use crate::workload::RequestSpec;

    let dir = ModelRuntime::default_dir();
    if !dir.join("meta.json").exists() {
        return Ok("e2e — SKIPPED (run `make artifacts` first)\n".into());
    }
    let runtime = ModelRuntime::load(&dir)?;
    let platform = runtime.platform();
    let tokenizer = ByteTokenizer::new();
    let backend = PjrtBackend::new(runtime, Sampling::TopK { k: 40, temperature: 1.0 }, 7);
    let cfg = EngineConfig {
        kv_capacity_tokens: 2048,
        swap_capacity_tokens: 8192,
        max_output_tokens: 64,
        ..EngineConfig::default()
    };
    let latency = LatencyModel::for_deployment(&tiny_opt(), &a100_1x());
    let mut engine = Engine::new(
        cfg,
        backend,
        WallClock::new(),
        Box::new(AndesScheduler::with_defaults()),
        latency,
    );
    let n = if ctx.quick { 6 } else { 12 };
    for i in 0..n {
        let text = format!("request {i}: explain quality of experience in text streaming");
        let prompt = tokenizer.encode(&text);
        engine.submit_with_prompt(
            RequestSpec {
                id: i,
                arrival: 0.0,
                prompt_tokens: prompt.len(),
                output_tokens: 32 + (i * 4) % 32,
                qoe: QoeSpec::new(0.5, 4.8),
                session: None,
            },
            prompt,
        )?;
    }
    while engine.has_work() {
        engine.tick()?;
    }
    let m = engine.metrics();
    let mut csv = Csv::new(&["request", "prompt_tokens", "output_tokens", "ttft_s", "qoe"]);
    for r in &m.requests {
        csv.row_f64(&[
            r.id as f64,
            r.prompt_tokens as f64,
            r.output_tokens as f64,
            r.ttft,
            r.final_qoe,
        ]);
    }
    csv.write(&ctx.out_dir.join("e2e_real_model.csv"))?;
    Ok(format!(
        "e2e — real tiny-OPT over PJRT ({platform})\n  {}\n  shape check (all requests served, QoE tracked): {}\n",
        m.summary(),
        if m.requests.len() == n { "HOLDS" } else { "VIOLATED" }
    ))
}
