//! Shared experiment runner: one simulated serving run = (model, GPU,
//! scheduler, workload) → Metrics.

use crate::backend::sim::SimBackend;
use crate::backend::VirtualClock;
use crate::coordinator::engine::{Engine, EngineConfig};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::sched::andes::{AndesConfig, AndesScheduler};
use crate::coordinator::sched::fcfs::FcfsScheduler;
use crate::coordinator::sched::round_robin::RoundRobinScheduler;
use crate::coordinator::sched::Scheduler;
use crate::model::gpu::GpuProfile;
use crate::model::latency::LatencyModel;
use crate::model::llm::LlmProfile;
use crate::workload::{ArrivalProcess, Dataset, QoeTrace, Workload};

/// Scheduler selector for experiments.
#[derive(Debug, Clone)]
pub enum SchedKind {
    Fcfs,
    RoundRobin { quantum: u64 },
    Andes(AndesConfig),
}

impl SchedKind {
    pub fn label(&self) -> &'static str {
        match self {
            SchedKind::Fcfs => "vLLM-FCFS",
            SchedKind::RoundRobin { .. } => "Round-Robin",
            SchedKind::Andes(_) => "Andes",
        }
    }

    pub fn build(&self) -> Box<dyn Scheduler> {
        match self {
            SchedKind::Fcfs => Box::new(FcfsScheduler::new()),
            SchedKind::RoundRobin { quantum } => Box::new(RoundRobinScheduler::new(*quantum)),
            SchedKind::Andes(cfg) => Box::new(AndesScheduler::new(cfg.clone())),
        }
    }

    pub fn andes_default() -> SchedKind {
        SchedKind::Andes(AndesConfig::default())
    }

    /// The paper's three contenders.
    pub fn paper_three() -> Vec<SchedKind> {
        vec![SchedKind::Fcfs, SchedKind::RoundRobin { quantum: 50 }, Self::andes_default()]
    }
}

/// Full description of one simulation run.
#[derive(Debug, Clone)]
pub struct SimRun {
    pub llm: LlmProfile,
    pub gpu: GpuProfile,
    pub sched: SchedKind,
    pub dataset: Dataset,
    pub arrivals: ArrivalProcess,
    pub qoe_trace: QoeTrace,
    pub num_requests: usize,
    pub seed: u64,
}

impl SimRun {
    pub fn execute(&self) -> Metrics {
        let latency = LatencyModel::for_deployment(&self.llm, &self.gpu);
        let cfg = EngineConfig {
            kv_capacity_tokens: self.llm.kv_capacity_tokens(&self.gpu),
            swap_capacity_tokens: self.llm.swap_capacity_tokens(&self.gpu),
            ..EngineConfig::default()
        };
        let mut engine = Engine::new(
            cfg,
            SimBackend::new(latency.clone()),
            VirtualClock::default(),
            self.sched.build(),
            latency,
        );
        let wl = Workload {
            dataset: self.dataset,
            arrivals: self.arrivals,
            qoe_trace: self.qoe_trace,
            num_requests: self.num_requests,
            seed: self.seed,
        };
        engine.load_trace(wl.generate());
        engine
            .run_to_completion()
            .expect("simulation must complete");
        std::mem::take(engine.metrics_mut())
    }
}

/// Analytic capacity estimate (req/s) for a (model, GPU, dataset)
/// deployment: saturated decode throughput divided by per-request token
/// demand including the prefill-equivalent cost. Used to place each
/// experiment's rate sweep around the interesting region, like the
/// paper's per-model x-axes in Figs. 10–11.
pub fn estimate_capacity(llm: &LlmProfile, gpu: &GpuProfile, dataset: Dataset) -> f64 {
    let latency = LatencyModel::for_deployment(llm, gpu);
    // Dataset means (see workload::dataset distributions).
    let (avg_prompt, avg_output) = match dataset {
        Dataset::ShareGpt => (200.0, 260.0),
        Dataset::MultiRoundShareGpt => (510.0, 260.0),
    };
    let avg_ctx = avg_prompt + avg_output / 2.0;
    let m = llm.kv_capacity_tokens(gpu) as f64;
    let b_max = (m / avg_ctx).max(1.0);
    let iter = latency.decode(b_max as usize, m as usize);
    let decode_tput = b_max / iter; // tokens/s at saturation
    // Each request needs avg_output decode tokens plus prefill time
    // expressed in decode-token equivalents.
    let prefill_equiv = latency.prefill(avg_prompt as usize) * decode_tput;
    decode_tput / (avg_output + prefill_equiv)
}

/// Standard rate grid spanning under- to over-saturation. The analytic
/// capacity estimate is conservative (prefill amortization and finite
/// traces push the empirical QoE knee ~1.5–1.7× higher), so the grid
/// extends to 1.9× to guarantee the collapse region is swept.
pub fn rate_grid(capacity: f64, quick: bool) -> Vec<f64> {
    let fracs: &[f64] = if quick {
        &[0.8, 1.3, 1.9]
    } else {
        &[0.6, 0.9, 1.1, 1.3, 1.45, 1.6, 1.75, 1.9]
    };
    fracs.iter().map(|f| (f * capacity * 100.0).round() / 100.0).collect()
}

/// The "just past the knee" evaluation rate used by the breakdown and
/// sensitivity experiments (paper: OPT-66B at 3.3 req/s where Andes
/// scored 0.92 while vLLM collapsed).
pub fn eval_rate(llm: &LlmProfile, gpu: &GpuProfile, dataset: Dataset) -> f64 {
    1.7 * estimate_capacity(llm, gpu, dataset)
}

/// Find the max rate (linear interpolation on a swept series) where QoE
/// stays above `threshold` — the paper's "system capacity" metric.
pub fn capacity_at_threshold(series: &[(f64, f64)], threshold: f64) -> f64 {
    let mut last_ok: Option<(f64, f64)> = None;
    for &(rate, qoe) in series {
        if qoe >= threshold {
            last_ok = Some((rate, qoe));
        } else if let Some((r0, q0)) = last_ok {
            // Interpolate crossing between (r0, q0) and (rate, qoe).
            if q0 > qoe {
                let t = (q0 - threshold) / (q0 - qoe);
                return r0 + t * (rate - r0);
            }
            return r0;
        }
    }
    last_ok.map(|(r, _)| r).unwrap_or(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gpu::a100_4x;
    use crate::model::llm::{opt_30b, opt_66b};

    #[test]
    fn capacity_estimates_are_ordered() {
        let c66 = estimate_capacity(&opt_66b(), &a100_4x(), Dataset::ShareGpt);
        let c30 = estimate_capacity(&opt_30b(), &a100_4x(), Dataset::ShareGpt);
        assert!(c30 > c66, "30B ({c30}) must out-serve 66B ({c66})");
        assert!((1.0..20.0).contains(&c66), "66B capacity {c66}");
        let c66mr = estimate_capacity(&opt_66b(), &a100_4x(), Dataset::MultiRoundShareGpt);
        assert!(c66mr < c66, "longer prompts reduce capacity");
    }

    #[test]
    fn threshold_interpolation() {
        let series = [(1.0, 1.0), (2.0, 0.95), (3.0, 0.5)];
        let c = capacity_at_threshold(&series, 0.9);
        assert!((2.0..3.0).contains(&c), "{c}");
        assert_eq!(capacity_at_threshold(&[(1.0, 0.2)], 0.9), 0.0);
        assert_eq!(capacity_at_threshold(&series, 0.4), 3.0);
    }

    #[test]
    fn small_run_executes() {
        let run = SimRun {
            llm: opt_66b(),
            gpu: a100_4x(),
            sched: SchedKind::Fcfs,
            dataset: Dataset::ShareGpt,
            arrivals: ArrivalProcess::Poisson { rate: 1.0 },
            qoe_trace: QoeTrace::TextReading,
            num_requests: 20,
            seed: 1,
        };
        let m = run.execute();
        assert_eq!(m.requests.len(), 20);
    }
}
