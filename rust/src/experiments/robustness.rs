//! Robustness experiments (Fig. 15): different hardware (A40), bursty
//! Gamma arrivals, and the voice-chat QoE trace.

use anyhow::Result;

use crate::model::gpu::{a100_4x, a40_1x, GpuProfile};
use crate::model::llm::{opt_13b, opt_66b, LlmProfile};
use crate::util::csv::Csv;
use crate::util::plot::{line_plot, Series};
use crate::workload::{ArrivalProcess, Dataset, QoeTrace};

use super::runner::{capacity_at_threshold, estimate_capacity, rate_grid, SchedKind, SimRun};
use super::ExpCtx;

#[allow(clippy::too_many_arguments)]
fn sweep(
    ctx: &ExpCtx,
    llm: &LlmProfile,
    gpu: &GpuProfile,
    qoe_trace: QoeTrace,
    arrivals: fn(f64) -> ArrivalProcess,
    csv: &mut Csv,
    tag: &str,
    rate_scale: f64,
) -> (String, f64, f64) {
    let capacity = estimate_capacity(llm, gpu, Dataset::ShareGpt) * rate_scale;
    let rates = rate_grid(capacity, ctx.quick);
    let n = if ctx.quick { 600 } else { 1500 };
    let mut all_series = Vec::new();
    for sched in SchedKind::paper_three() {
        let mut pts = Vec::new();
        for &rate in &rates {
            let m = SimRun {
                llm: llm.clone(),
                gpu: gpu.clone(),
                sched: sched.clone(),
                dataset: Dataset::ShareGpt,
                arrivals: arrivals(rate),
                qoe_trace,
                num_requests: n,
                seed: 42,
            }
            .execute();
            csv.row(&[
                tag.to_string(),
                sched.label().to_string(),
                format!("{rate}"),
                format!("{:.4}", m.avg_qoe()),
            ]);
            pts.push((rate, m.avg_qoe()));
        }
        all_series.push((sched.label().to_string(), pts));
    }
    let plot = line_plot(
        &format!("Fig. 15 ({tag}) — avg QoE vs rate"),
        "req/s",
        "avg QoE",
        &all_series.iter().map(|(n, p)| Series::new(n, p.clone())).collect::<Vec<_>>(),
    );
    let cap = |name: &str| {
        capacity_at_threshold(&all_series.iter().find(|(n, _)| n == name).unwrap().1, 0.9)
    };
    (plot, cap("vLLM-FCFS"), cap("Andes"))
}

/// Fig. 15a: A40 hardware (OPT-13B — 66B does not fit a 46 GB A40).
pub fn fig15a(ctx: &ExpCtx) -> Result<String> {
    let mut csv = Csv::new(&["config", "scheduler", "rate", "avg_qoe"]);
    let (plot, c_fcfs, c_andes) = sweep(
        ctx,
        &opt_13b(),
        &a40_1x(),
        QoeTrace::TextReading,
        |r| ArrivalProcess::Poisson { rate: r },
        &mut csv,
        "A40",
        1.0,
    );
    csv.write(&ctx.out_dir.join("fig15a_a40.csv"))?;
    let gain = if c_fcfs > 0.0 { c_andes / c_fcfs } else { f64::NAN };
    Ok(format!(
        "{plot}  capacity gain on A40: {gain:.2}× (paper: ~1.1×, smaller than A100 — less \
         actual-vs-expected TDS slack)\n  shape check (gain ≥ 1.0): {}\n",
        if c_andes >= c_fcfs * 0.98 { "HOLDS" } else { "VIOLATED" }
    ))
}

/// Fig. 15b: bursty Gamma(CV=3) arrivals on OPT-66B.
pub fn fig15b(ctx: &ExpCtx) -> Result<String> {
    let mut csv = Csv::new(&["config", "scheduler", "rate", "avg_qoe"]);
    let (plot_p, _, _) = sweep(
        ctx,
        &opt_66b(),
        &a100_4x(),
        QoeTrace::TextReading,
        |r| ArrivalProcess::Poisson { rate: r },
        &mut csv,
        "poisson",
        1.0,
    );
    let (plot_g, c_fcfs, c_andes) = sweep(
        ctx,
        &opt_66b(),
        &a100_4x(),
        QoeTrace::TextReading,
        |r| ArrivalProcess::Gamma { rate: r, cv: 3.0 },
        &mut csv,
        "gamma-cv3",
        1.0,
    );
    csv.write(&ctx.out_dir.join("fig15b_bursty.csv"))?;
    let _ = plot_p;
    let gain = if c_fcfs > 0.0 { c_andes / c_fcfs } else { f64::NAN };
    Ok(format!(
        "{plot_g}  bursty capacity: fcfs={c_fcfs:.2}, andes={c_andes:.2} (gain {gain:.2}×; paper: ~1.3×)\n  shape check (Andes ≥ FCFS under burst): {}\n",
        if c_andes >= c_fcfs * 0.98 { "HOLDS" } else { "VIOLATED" }
    ))
}

/// Fig. 15c: voice-chat QoE trace (slower expected TDS) on OPT-66B.
pub fn fig15c(ctx: &ExpCtx) -> Result<String> {
    let mut csv = Csv::new(&["config", "scheduler", "rate", "avg_qoe"]);
    // Voice tolerates higher rates: extend the sweep beyond text capacity.
    let (plot, c_fcfs, c_andes) = sweep(
        ctx,
        &opt_66b(),
        &a100_4x(),
        QoeTrace::VoiceSpeaking,
        |r| ArrivalProcess::Poisson { rate: r },
        &mut csv,
        "voice",
        1.5,
    );
    csv.write(&ctx.out_dir.join("fig15c_voice.csv"))?;
    let gain = if c_fcfs > 0.0 { c_andes / c_fcfs } else { f64::NAN };
    Ok(format!(
        "{plot}  voice capacity: fcfs={c_fcfs:.2}, andes={c_andes:.2} (gain {gain:.2}×; paper: ~2×, theoretical 6.6/3.3)\n  shape check (voice gain ≥ text gain trend): {}\n",
        if c_andes >= c_fcfs { "HOLDS" } else { "VIOLATED" }
    ))
}
