//! ext-sessions: multi-turn session serving with KV prefix retention
//! and session-affinity routing (DESIGN.md §10).
//!
//! Sweeps {no-park, park, park+affinity} × {poisson, gamma-cv3} session
//! openings on a 2-replica Andes cluster behind the gateway at mild
//! overload (~1.3× aggregate capacity in turns). Reported per cell:
//! served/rejected counts, **prefix-hit rate** over returning turns,
//! parked/evicted prefix counts, **per-turn mean TTFT** (opening vs.
//! returning), and mean QoE with rejects counted as zero.
//!
//! Shape checks assert the session story: no-park never hits (nothing
//! is parked), park+affinity hits strictly more often than blind park
//! (a hit requires landing on the replica that parked the prefix), and
//! prefix retention + affinity does not lose mean QoE vs. no-park —
//! returning turns skip most of their prefill, which is exactly the
//! capacity the mild overload is short of.

use anyhow::Result;

use crate::cluster::{Cluster, RoutingPolicy};
use crate::config::SchedulerConfig;
use crate::coordinator::engine::EngineConfig;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::sched::andes::AndesConfig;
use crate::gateway::{Gateway, GatewayConfig};
use crate::model::gpu::a100_4x;
use crate::model::latency::LatencyModel;
use crate::model::llm::opt_66b;
use crate::util::csv::Csv;
use crate::util::stats::mean;
use crate::workload::qoe_trace::QoeTrace;
use crate::workload::{ArrivalProcess, Dataset, RequestSpec, SessionWorkload};

use super::runner::estimate_capacity;
use super::ExpCtx;

struct Cell {
    arrivals: &'static str,
    mode: &'static str,
    hit_rate: f64,
    ttft_returning: f64,
    mean_qoe: f64,
}

struct CellStats {
    served: usize,
    rejected: usize,
    hits: u64,
    returning_served: usize,
    parked: u64,
    evictions: u64,
    ttft_opening: f64,
    ttft_returning: f64,
    qoe_served: f64,
}

fn aggregate(per_replica: &[Metrics], rejected: usize) -> CellStats {
    let mut opening: Vec<f64> = Vec::new();
    let mut returning: Vec<f64> = Vec::new();
    let mut qoes: Vec<f64> = Vec::new();
    let mut returning_served = 0usize;
    let mut hits = 0u64;
    let mut served = 0usize;
    for m in per_replica {
        for r in &m.requests {
            served += 1;
            qoes.push(r.final_qoe);
            if r.ttft.is_finite() {
                match r.session {
                    Some(s) if s.is_returning() => returning.push(r.ttft),
                    _ => opening.push(r.ttft),
                }
            }
            if r.session.is_some_and(|s| s.is_returning()) {
                returning_served += 1;
                if r.prefix_hit_tokens > 0 {
                    hits += 1;
                }
            }
        }
    }
    CellStats {
        served,
        rejected,
        hits,
        returning_served,
        parked: per_replica.iter().map(|m| m.prefixes_parked).sum(),
        evictions: per_replica.iter().map(|m| m.park_evictions).sum(),
        ttft_opening: mean(&opening),
        ttft_returning: mean(&returning),
        qoe_served: mean(&qoes),
    }
}

pub fn ext_sessions(ctx: &ExpCtx) -> Result<String> {
    let llm = opt_66b();
    let gpu = a100_4x();
    let latency = LatencyModel::for_deployment(&llm, &gpu);
    let replicas = 2usize;
    let capacity = estimate_capacity(&llm, &gpu, Dataset::ShareGpt) * replicas as f64;
    // Session turns (≈3 per session) arrive at ~1.3× aggregate capacity
    // in steady state: enough pressure that prefill savings matter,
    // not so much that everything sheds.
    let avg_turns = 3.0;
    let session_rate = capacity * 1.3 / avg_turns;
    let num_sessions = if ctx.quick { 60 } else { 150 };
    let engine_base = EngineConfig {
        kv_capacity_tokens: llm.kv_capacity_tokens(&gpu),
        swap_capacity_tokens: llm.swap_capacity_tokens(&gpu),
        ..EngineConfig::default()
    };
    let sched = SchedulerConfig::Andes(AndesConfig::default());

    let arrival_variants: [(&'static str, fn(f64) -> ArrivalProcess); 2] = [
        ("poisson", |rate| ArrivalProcess::Poisson { rate }),
        ("gamma-cv3", |rate| ArrivalProcess::Gamma { rate, cv: 3.0 }),
    ];
    // (label, park, affinity)
    let modes: [(&'static str, bool, bool); 3] = [
        ("no-park", false, false),
        ("park", true, false),
        ("park+affinity", true, true),
    ];

    let mut csv = Csv::new(&[
        "arrivals",
        "mode",
        "requests",
        "served",
        "rejected",
        "prefix_hit_rate",
        "prefixes_parked",
        "park_evictions",
        "mean_ttft_opening",
        "mean_ttft_returning",
        "mean_qoe_served",
        "mean_qoe_incl_rejects",
    ]);
    let mut report = format!(
        "ext-sessions — {replicas}-replica Andes cluster, ~1.3x capacity in turns \
         ({:.2} sessions/s x ~{avg_turns} turns), {num_sessions} sessions\n",
        session_rate
    );
    let mut cells: Vec<Cell> = Vec::new();

    for &(alabel, mk_arrivals) in &arrival_variants {
        let trace: Vec<RequestSpec> = SessionWorkload {
            num_sessions,
            arrivals: mk_arrivals(session_rate),
            qoe_trace: QoeTrace::TextReading,
            min_turns: 2,
            max_turns: 4,
            think_time_mean: 4.0,
            seed: 42,
        }
        .generate();
        let n = trace.len();

        for &(mlabel, park, affinity) in &modes {
            let mut ecfg = engine_base.clone();
            ecfg.park_prefixes = park;
            let mut cluster = Cluster::new(
                replicas,
                ecfg,
                latency.clone(),
                &sched,
                RoutingPolicy::QoeAware,
            );
            cluster.set_session_affinity(affinity);
            let mut gcfg = GatewayConfig::default();
            gcfg.pacing_enabled = false;
            gcfg.surge.baseline_rate = capacity;
            let mut gw = Gateway::new(cluster, gcfg);
            let res = gw.run_trace(trace.clone())?;
            anyhow::ensure!(
                res.served.len() + res.rejections.len() == n,
                "{alabel}/{mlabel}: lost requests"
            );
            let s = aggregate(&res.per_replica, res.rejections.len());
            let hit_rate = if s.returning_served == 0 {
                0.0
            } else {
                s.hits as f64 / s.returning_served as f64
            };
            let mean_qoe = res.mean_qoe_incl_rejects();
            csv.row(&[
                alabel.to_string(),
                mlabel.to_string(),
                format!("{n}"),
                format!("{}", s.served),
                format!("{}", s.rejected),
                format!("{hit_rate:.4}"),
                format!("{}", s.parked),
                format!("{}", s.evictions),
                format!("{:.4}", s.ttft_opening),
                format!("{:.4}", s.ttft_returning),
                format!("{:.4}", s.qoe_served),
                format!("{mean_qoe:.4}"),
            ]);
            report.push_str(&format!(
                "  {alabel:<9} {mlabel:<13} served {:<4} rejected {:<3} hit-rate {:.3} \
                 parked {:<4} ttft(open/return) {:.2}/{:.2}s QoE {:.3}\n",
                s.served,
                s.rejected,
                hit_rate,
                s.parked,
                s.ttft_opening,
                s.ttft_returning,
                mean_qoe,
            ));
            cells.push(Cell {
                arrivals: alabel,
                mode: mlabel,
                hit_rate,
                ttft_returning: s.ttft_returning,
                mean_qoe,
            });
        }
    }
    csv.write(&ctx.out_dir.join("ext_sessions.csv"))?;

    // Shape checks per arrival process.
    for &(alabel, _) in &arrival_variants {
        let find = |mode: &str| {
            cells
                .iter()
                .find(|c| c.arrivals == alabel && c.mode == mode)
                .expect("cell missing")
        };
        let (noop, park, full) = (find("no-park"), find("park"), find("park+affinity"));
        let c1 = noop.hit_rate == 0.0;
        let c2 = full.hit_rate > 0.0;
        let c3 = full.hit_rate >= park.hit_rate;
        let c4 = full.mean_qoe >= noop.mean_qoe;
        let c5 = full.ttft_returning <= noop.ttft_returning;
        report.push_str(&format!(
            "shape checks [{alabel}]:\n\
             \x20 no-park never hits ({:.3}): {}\n\
             \x20 park+affinity hits ({:.3} > 0): {}\n\
             \x20 affinity hits at least as often as blind park ({:.3} vs {:.3}): {}\n\
             \x20 park+affinity holds mean QoE ({:.3} vs {:.3}): {}\n\
             \x20 returning-turn TTFT no worse ({:.2}s vs {:.2}s): {}\n",
            noop.hit_rate,
            verdict(c1),
            full.hit_rate,
            verdict(c2),
            full.hit_rate,
            park.hit_rate,
            verdict(c3),
            full.mean_qoe,
            noop.mean_qoe,
            verdict(c4),
            full.ttft_returning,
            noop.ttft_returning,
            verdict(c5),
        ));
    }
    Ok(report)
}

fn verdict(ok: bool) -> &'static str {
    if ok {
        "HOLDS"
    } else {
        "VIOLATED"
    }
}
