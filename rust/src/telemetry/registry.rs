//! Labeled metric families — counters, gauges, fixed-bucket histograms —
//! with Prometheus text exposition (stable label ordering) and
//! percentile extraction shared with `util::stats`.
//!
//! The registry is dependency-light by design: label sets are
//! `BTreeMap`s so every render walks families and series in one
//! deterministic order, which is what lets the golden suite pin the
//! exposition text byte-for-byte.
//!
//! ```
//! use andes::telemetry::registry::{Registry, UNIT_BUCKETS};
//!
//! let mut r = Registry::new();
//! r.inc("andes_requests_total", &[("tier", "premium"), ("outcome", "admitted")], 1.0);
//! r.observe("andes_qoe", &[("tier", "premium")], 0.93, UNIT_BUCKETS);
//! let text = r.render();
//! assert!(text.contains("andes_requests_total{outcome=\"admitted\",tier=\"premium\"} 1"));
//! assert!(andes::telemetry::registry::validate_exposition(&text).is_ok());
//! assert!((r.histogram_percentile("andes_qoe", &[("tier", "premium")], 50.0) - 1.0).abs() < 1e-9);
//! ```

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, Result};

use crate::util::stats::percentile_of_buckets;

/// Upper bounds (seconds) for request-latency histograms (TTFT).
pub const LATENCY_BUCKETS: &[f64] =
    &[0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0, 120.0];

/// Upper bounds (seconds/token) for per-token latency histograms (TPOT).
pub const TPOT_BUCKETS: &[f64] = &[0.01, 0.02, 0.05, 0.1, 0.2, 0.35, 0.5, 1.0, 2.0];

/// Upper bounds for unit-interval scores (QoE ∈ [0, 1]).
pub const UNIT_BUCKETS: &[f64] = &[0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0];

/// Canonical sorted label set; ordering is what stabilises exposition.
pub type LabelSet = BTreeMap<String, String>;

fn label_set(labels: &[(&str, &str)]) -> LabelSet {
    labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect()
}

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Render a label set as `{k="v",...}` (empty string for no labels);
/// `extra` is appended last (used for the histogram `le` label).
fn render_labels(labels: &LabelSet, extra: Option<(&str, &str)>) -> String {
    let mut parts: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
        .collect();
    if let Some((k, v)) = extra {
        parts.push(format!("{k}=\"{v}\""));
    }
    if parts.is_empty() {
        String::new()
    } else {
        format!("{{{}}}", parts.join(","))
    }
}

/// One fixed-bucket histogram series: cumulative exposition, with
/// percentile extraction via the shared `util::stats` estimator.
#[derive(Debug, Clone)]
pub struct HistogramCell {
    /// Finite upper bounds, ascending; the `+Inf` bucket is implicit.
    bounds: Vec<f64>,
    /// One count per finite bound, plus the overflow count last.
    counts: Vec<u64>,
    sum: f64,
    count: u64,
}

impl HistogramCell {
    fn new(bounds: &[f64]) -> Self {
        debug_assert!(bounds.windows(2).all(|w| w[0] < w[1]), "bounds must ascend");
        HistogramCell {
            bounds: bounds.to_vec(),
            counts: vec![0; bounds.len() + 1],
            sum: 0.0,
            count: 0,
        }
    }

    /// Record one sample. Non-finite samples are dropped (a NaN TTFT —
    /// an unfinished request — must not poison the sum).
    pub fn observe(&mut self, v: f64) {
        if !v.is_finite() {
            return;
        }
        let idx = self.bounds.partition_point(|b| f64::total_cmp(b, &v).is_lt());
        self.counts[idx] += 1;
        self.sum += v;
        self.count += 1;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Percentile estimate via [`percentile_of_buckets`] — the single
    /// shared implementation; overflow samples are conservatively
    /// attributed to the last finite bucket.
    pub fn percentile(&self, p: f64) -> f64 {
        let n = self.bounds.len();
        let mut counts = self.counts[..n].to_vec();
        counts[n - 1] += self.counts[n];
        percentile_of_buckets(&self.bounds, &counts, p)
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kind {
    Counter,
    Gauge,
    Histogram,
}

impl Kind {
    fn name(self) -> &'static str {
        match self {
            Kind::Counter => "counter",
            Kind::Gauge => "gauge",
            Kind::Histogram => "histogram",
        }
    }
}

#[derive(Debug, Clone)]
enum Cell {
    Value(f64),
    Hist(HistogramCell),
}

#[derive(Debug, Clone)]
struct Family {
    kind: Kind,
    help: String,
    bounds: Vec<f64>,
    cells: BTreeMap<LabelSet, Cell>,
}

/// The metric registry: families keyed by name, series by label set.
#[derive(Debug, Clone, Default)]
pub struct Registry {
    families: BTreeMap<String, Family>,
}

impl Registry {
    pub fn new() -> Self {
        Registry::default()
    }

    /// Pre-declare a family so `/metrics` lists it (HELP/TYPE) before
    /// any traffic touches it.
    pub fn declare_counter(&mut self, name: &str, help: &str) {
        self.declare(name, Kind::Counter, help, &[]);
    }

    pub fn declare_gauge(&mut self, name: &str, help: &str) {
        self.declare(name, Kind::Gauge, help, &[]);
    }

    pub fn declare_histogram(&mut self, name: &str, help: &str, bounds: &[f64]) {
        self.declare(name, Kind::Histogram, help, bounds);
    }

    fn declare(&mut self, name: &str, kind: Kind, help: &str, bounds: &[f64]) {
        self.families.entry(name.to_string()).or_insert_with(|| Family {
            kind,
            help: help.to_string(),
            bounds: bounds.to_vec(),
            cells: BTreeMap::new(),
        });
    }

    /// Increment a counter series by `by` (auto-declared if new).
    pub fn inc(&mut self, name: &str, labels: &[(&str, &str)], by: f64) {
        let fam = self.family_mut(name, Kind::Counter, &[]);
        match fam.cells.entry(label_set(labels)).or_insert(Cell::Value(0.0)) {
            Cell::Value(v) => *v += by,
            Cell::Hist(_) => debug_assert!(false, "{name} is a histogram"),
        }
    }

    /// Set a gauge series to `v` (auto-declared if new).
    pub fn set(&mut self, name: &str, labels: &[(&str, &str)], v: f64) {
        let fam = self.family_mut(name, Kind::Gauge, &[]);
        match fam.cells.entry(label_set(labels)).or_insert(Cell::Value(0.0)) {
            Cell::Value(g) => *g = v,
            Cell::Hist(_) => debug_assert!(false, "{name} is a histogram"),
        }
    }

    /// Record one histogram observation; `bounds` applies when the
    /// family is auto-declared by this call.
    pub fn observe(&mut self, name: &str, labels: &[(&str, &str)], v: f64, bounds: &[f64]) {
        let fam = self.family_mut(name, Kind::Histogram, bounds);
        let fam_bounds = fam.bounds.clone();
        match fam
            .cells
            .entry(label_set(labels))
            .or_insert_with(|| Cell::Hist(HistogramCell::new(&fam_bounds)))
        {
            Cell::Hist(h) => h.observe(v),
            Cell::Value(_) => debug_assert!(false, "{name} is not a histogram"),
        }
    }

    fn family_mut(&mut self, name: &str, kind: Kind, bounds: &[f64]) -> &mut Family {
        self.declare(name, kind, "andes metric", bounds);
        self.families.get_mut(name).expect("just declared")
    }

    /// Current value of a counter/gauge series (0 when absent) — used by
    /// tests and the health endpoint.
    pub fn value(&self, name: &str, labels: &[(&str, &str)]) -> f64 {
        match self.families.get(name).and_then(|f| f.cells.get(&label_set(labels))) {
            Some(Cell::Value(v)) => *v,
            Some(Cell::Hist(h)) => h.count() as f64,
            None => 0.0,
        }
    }

    /// Percentile of a histogram series (NaN when absent/empty).
    pub fn histogram_percentile(&self, name: &str, labels: &[(&str, &str)], p: f64) -> f64 {
        match self.families.get(name).and_then(|f| f.cells.get(&label_set(labels))) {
            Some(Cell::Hist(h)) => h.percentile(p),
            _ => f64::NAN,
        }
    }

    /// Long-format rows `(metric, labels, value)` for the periodic
    /// snapshot CSV. Histograms export their `_count`, `_sum`, and
    /// p50/p90/p99 estimates.
    pub fn snapshot_rows(&self) -> Vec<(String, String, f64)> {
        let mut rows = Vec::new();
        for (name, fam) in &self.families {
            for (labels, cell) in &fam.cells {
                let l = render_labels(labels, None);
                match cell {
                    Cell::Value(v) => rows.push((name.clone(), l, *v)),
                    Cell::Hist(h) => {
                        rows.push((format!("{name}_count"), l.clone(), h.count() as f64));
                        rows.push((format!("{name}_sum"), l.clone(), h.sum()));
                        for (tag, p) in [("p50", 50.0), ("p90", 90.0), ("p99", 99.0)] {
                            let v = h.percentile(p);
                            if v.is_finite() {
                                rows.push((format!("{name}_{tag}"), l.clone(), v));
                            }
                        }
                    }
                }
            }
        }
        rows
    }

    /// Render the whole registry in Prometheus text exposition format.
    /// Families, series, and labels all iterate in `BTreeMap` order, so
    /// the output is deterministic for a deterministic run.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (name, fam) in &self.families {
            let _ = writeln!(out, "# HELP {name} {}", fam.help);
            let _ = writeln!(out, "# TYPE {name} {}", fam.kind.name());
            for (labels, cell) in &fam.cells {
                match cell {
                    Cell::Value(v) => {
                        let _ = writeln!(out, "{name}{} {v}", render_labels(labels, None));
                    }
                    Cell::Hist(h) => {
                        let mut cum = 0u64;
                        for (i, b) in h.bounds.iter().enumerate() {
                            cum += h.counts[i];
                            let _ = writeln!(
                                out,
                                "{name}_bucket{} {cum}",
                                render_labels(labels, Some(("le", &format!("{b}"))))
                            );
                        }
                        let _ = writeln!(
                            out,
                            "{name}_bucket{} {}",
                            render_labels(labels, Some(("le", "+Inf"))),
                            h.count()
                        );
                        let _ = writeln!(
                            out,
                            "{name}_sum{} {}",
                            render_labels(labels, None),
                            h.sum()
                        );
                        let _ = writeln!(
                            out,
                            "{name}_count{} {}",
                            render_labels(labels, None),
                            h.count()
                        );
                    }
                }
            }
        }
        out
    }
}

fn valid_metric_name(s: &str) -> bool {
    !s.is_empty()
        && s.chars().next().is_some_and(|c| c.is_ascii_alphabetic() || c == '_' || c == ':')
        && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

/// Strip a histogram series suffix to its family name.
fn histogram_base(name: &str) -> Option<&str> {
    for suffix in ["_bucket", "_sum", "_count"] {
        if let Some(base) = name.strip_suffix(suffix) {
            return Some(base);
        }
    }
    None
}

/// Parse one sample line into `(series_name, labels, value)`.
fn parse_sample(line: &str) -> Result<(String, Vec<(String, String)>, f64)> {
    let (name_part, rest) = match line.find('{') {
        Some(i) => (&line[..i], &line[i..]),
        None => match line.split_once(' ') {
            Some((n, v)) => (n, v),
            None => bail!("sample line without value: '{line}'"),
        },
    };
    if !valid_metric_name(name_part) {
        bail!("invalid metric name '{name_part}'");
    }
    let (labels, value_str) = if let Some(body) = rest.strip_prefix('{') {
        let close = body.find('}').ok_or_else(|| anyhow::anyhow!("unclosed labels: '{line}'"))?;
        let label_body = &body[..close];
        let value_str = body[close + 1..].trim();
        let mut labels = Vec::new();
        // Label values in our renderer never contain commas/braces, so a
        // comma split is a faithful parse of what `render` emits.
        for pair in label_body.split(',').filter(|p| !p.is_empty()) {
            let (k, v) = pair
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("bad label pair '{pair}'"))?;
            let v = v
                .strip_prefix('"')
                .and_then(|v| v.strip_suffix('"'))
                .ok_or_else(|| anyhow::anyhow!("unquoted label value '{pair}'"))?;
            if !valid_metric_name(k) {
                bail!("invalid label name '{k}'");
            }
            labels.push((k.to_string(), v.to_string()));
        }
        (labels, value_str)
    } else {
        (Vec::new(), rest.trim())
    };
    let value: f64 = value_str
        .parse()
        .map_err(|_| anyhow::anyhow!("unparseable sample value '{value_str}' in '{line}'"))?;
    Ok((name_part.to_string(), labels, value))
}

/// Validate Prometheus text exposition: HELP/TYPE lines well-formed,
/// every sample's family TYPE-declared before use, histogram bucket
/// counts cumulative with a `+Inf` bucket equal to `_count`. Returns the
/// number of sample lines checked.
pub fn validate_exposition(text: &str) -> Result<usize> {
    let mut types: BTreeMap<String, String> = BTreeMap::new();
    // (family, labels-sans-le) -> (last cumulative count, saw +Inf, inf value)
    let mut hist: BTreeMap<(String, String), (f64, bool, f64)> = BTreeMap::new();
    let mut counts: BTreeMap<(String, String), f64> = BTreeMap::new();
    let mut samples = 0usize;
    for line in text.lines() {
        let line = line.trim_end();
        if line.is_empty() {
            continue;
        }
        if let Some(meta) = line.strip_prefix("# ") {
            let mut it = meta.splitn(3, ' ');
            let (kw, name) = (it.next().unwrap_or(""), it.next().unwrap_or(""));
            match kw {
                "HELP" => {
                    if !valid_metric_name(name) {
                        bail!("HELP for invalid name '{name}'");
                    }
                }
                "TYPE" => {
                    let t = it.next().unwrap_or("");
                    if !matches!(t, "counter" | "gauge" | "histogram") {
                        bail!("unknown TYPE '{t}' for '{name}'");
                    }
                    types.insert(name.to_string(), t.to_string());
                }
                _ => bail!("unknown comment directive '{kw}'"),
            }
            continue;
        }
        if line.starts_with('#') {
            continue; // plain comment
        }
        let (series, labels, value) = parse_sample(line)?;
        samples += 1;
        let family = histogram_base(&series)
            .filter(|b| types.get(*b).is_some_and(|t| t == "histogram"))
            .unwrap_or(&series)
            .to_string();
        let declared = types
            .get(&family)
            .ok_or_else(|| anyhow::anyhow!("sample '{series}' precedes its TYPE line"))?;
        if declared == "counter" && value < 0.0 {
            bail!("negative counter sample '{line}'");
        }
        if declared == "histogram" {
            let base_labels: Vec<String> = labels
                .iter()
                .filter(|(k, _)| k != "le")
                .map(|(k, v)| format!("{k}={v}"))
                .collect();
            let key = (family.clone(), base_labels.join(","));
            if series.ends_with("_bucket") {
                let le = labels
                    .iter()
                    .find(|(k, _)| k == "le")
                    .map(|(_, v)| v.clone())
                    .ok_or_else(|| anyhow::anyhow!("bucket without le: '{line}'"))?;
                let entry = hist.entry(key).or_insert((0.0, false, 0.0));
                if value + 1e-9 < entry.0 {
                    bail!("non-cumulative bucket counts at '{line}'");
                }
                entry.0 = value;
                if le == "+Inf" {
                    entry.1 = true;
                    entry.2 = value;
                }
            } else if series.ends_with("_count") {
                counts.insert(key, value);
            }
        }
    }
    for (key, count) in &counts {
        match hist.get(key) {
            Some((_, true, inf)) if (inf - count).abs() < 1e-9 => {}
            Some((_, true, inf)) => {
                bail!("histogram {}: +Inf bucket {inf} != count {count}", key.0)
            }
            _ => bail!("histogram {} lacks a +Inf bucket", key.0),
        }
    }
    Ok(samples)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_render_sorted_labels() {
        let mut r = Registry::new();
        r.inc("reqs_total", &[("tier", "premium"), ("outcome", "admit")], 2.0);
        r.set("depth", &[], 7.0);
        let text = r.render();
        // Labels render alphabetically regardless of insertion order.
        assert!(text.contains("reqs_total{outcome=\"admit\",tier=\"premium\"} 2"));
        assert!(text.contains("depth 7"));
        assert!(validate_exposition(&text).is_ok());
    }

    #[test]
    fn histogram_renders_cumulative_buckets() {
        let mut r = Registry::new();
        for v in [0.05, 0.15, 0.15, 0.95, 5.0] {
            r.observe("ttft", &[("tier", "standard")], v, &[0.1, 0.5, 1.0]);
        }
        let text = r.render();
        assert!(text.contains("ttft_bucket{tier=\"standard\",le=\"0.1\"} 1"));
        assert!(text.contains("ttft_bucket{tier=\"standard\",le=\"0.5\"} 3"));
        assert!(text.contains("ttft_bucket{tier=\"standard\",le=\"1\"} 4"));
        assert!(text.contains("ttft_bucket{tier=\"standard\",le=\"+Inf\"} 5"));
        assert!(text.contains("ttft_count{tier=\"standard\"} 5"));
        assert!(validate_exposition(&text).is_ok());
    }

    #[test]
    fn histogram_percentiles_use_shared_estimator() {
        let mut h = HistogramCell::new(&[1.0, 2.0, 4.0]);
        for _ in 0..10 {
            h.observe(1.5);
        }
        // All samples in the (1, 2] bucket: p0 → lower edge, p100 → upper.
        assert_eq!(h.percentile(0.0), 1.0);
        assert_eq!(h.percentile(100.0), 2.0);
        // Empty → NaN; NaN observations are dropped.
        let mut e = HistogramCell::new(&[1.0]);
        assert!(e.percentile(50.0).is_nan());
        e.observe(f64::NAN);
        assert_eq!(e.count(), 0);
    }

    #[test]
    fn declared_families_render_before_traffic() {
        let mut r = Registry::new();
        r.declare_counter("andes_requests_total", "requests by outcome");
        r.declare_histogram("andes_ttft_seconds", "time to first token", LATENCY_BUCKETS);
        let text = r.render();
        assert!(text.contains("# TYPE andes_requests_total counter"));
        assert!(text.contains("# TYPE andes_ttft_seconds histogram"));
        assert!(validate_exposition(&text).is_ok());
    }

    #[test]
    fn validator_rejects_malformed_exposition() {
        assert!(validate_exposition("no_type_line 1\n").is_err());
        assert!(validate_exposition("# TYPE x counter\nx{a=b} 1\n").is_err());
        assert!(validate_exposition("# TYPE x counter\nx -1\n").is_err());
        assert!(validate_exposition("# TYPE x histogram\nx_bucket{le=\"1\"} 2\nx_count 2\n")
            .is_err(), "missing +Inf bucket must fail");
        let ok = "# HELP x h\n# TYPE x histogram\nx_bucket{le=\"1\"} 1\n\
                  x_bucket{le=\"+Inf\"} 2\nx_sum 3\nx_count 2\n";
        assert_eq!(validate_exposition(ok).unwrap(), 4);
    }

    #[test]
    fn label_values_escape() {
        let mut r = Registry::new();
        r.inc("m", &[("detail", "say \"hi\"\nnow")], 1.0);
        let text = r.render();
        assert!(text.contains(r#"m{detail="say \"hi\"\nnow"} 1"#));
    }
}
