//! Telemetry: metric registry, per-request event tracing, and the data
//! behind the live `/metrics` + `/health` surface (DESIGN.md §12).
//!
//! The stack-wide handle is [`Telemetry`]: a cloneable, thread-safe
//! wrapper that is either **enabled** (shared registry + tracer behind a
//! mutex) or **disabled** (every call a no-op). The disabled handle is
//! the default everywhere, so a run with `telemetry: off` executes the
//! exact pre-telemetry code path — parity-tested in
//! `rust/tests/telemetry.rs`.
//!
//! Time domains: the telemetry layer never reads a clock of its own.
//! Every event/snapshot timestamp is supplied by the caller from the
//! engine's [`crate::backend::Clock`] — virtual seconds in simulation,
//! wall seconds in live serving — and the domain is recorded once via
//! [`Telemetry::set_time_domain`] so exports are self-describing.
//!
//! ```
//! use andes::telemetry::{Telemetry, TelemetryConfig};
//!
//! let tel = Telemetry::new(&TelemetryConfig { enabled: true, ..TelemetryConfig::default() });
//! tel.inc("andes_requests_total", &[("tier", "standard"), ("outcome", "admitted")], 1.0);
//! tel.event(3, "arrival", 0.5, &[("tier", "standard".into())]);
//! assert!(tel.render_prometheus().contains("andes_requests_total"));
//!
//! // The disabled handle observes nothing and renders nothing.
//! let off = Telemetry::disabled();
//! off.inc("andes_requests_total", &[], 1.0);
//! assert_eq!(off.render_prometheus(), "");
//! ```

pub mod logging;
pub mod registry;
pub mod trace;

pub use logging::{init as init_logging, parse_level};
pub use registry::{validate_exposition, Registry};
pub use trace::{validate_jsonl, TraceEvent, Tracer, EVENT_KINDS};

use std::sync::{Arc, Mutex};

use crate::util::csv::{fmt_f64, Csv};
use crate::util::json::Json;

use registry::{LATENCY_BUCKETS, TPOT_BUCKETS, UNIT_BUCKETS};

/// The `"telemetry"` config section / CLI knobs.
#[derive(Debug, Clone)]
pub struct TelemetryConfig {
    /// Master switch. Off (the default in simulation) keeps every code
    /// path bit-identical to the pre-telemetry stack.
    pub enabled: bool,
    /// Tracer ring-buffer capacity in events (closed spans evicted
    /// oldest-first past this; open spans never dropped).
    pub trace_capacity: usize,
    /// Period of the metrics-snapshot CSV in engine-clock seconds;
    /// 0 disables periodic snapshots.
    pub snapshot_interval: f64,
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        TelemetryConfig { enabled: false, trace_capacity: 65_536, snapshot_interval: 0.0 }
    }
}

struct Inner {
    registry: Registry,
    tracer: Tracer,
    snapshot_interval: f64,
    next_snapshot: f64,
    snapshots: Csv,
}

/// Cloneable stack-wide telemetry handle (see module docs).
#[derive(Clone, Default)]
pub struct Telemetry {
    inner: Option<Arc<Mutex<Inner>>>,
}

impl std::fmt::Debug for Telemetry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Telemetry").field("enabled", &self.is_enabled()).finish()
    }
}

impl Telemetry {
    /// The no-op handle: every record call returns immediately.
    pub fn disabled() -> Self {
        Telemetry { inner: None }
    }

    /// Build from config; `cfg.enabled == false` yields [`Self::disabled`].
    pub fn new(cfg: &TelemetryConfig) -> Self {
        if !cfg.enabled {
            return Telemetry::disabled();
        }
        let mut registry = Registry::new();
        declare_base_families(&mut registry);
        Telemetry {
            inner: Some(Arc::new(Mutex::new(Inner {
                registry,
                tracer: Tracer::new(cfg.trace_capacity),
                snapshot_interval: cfg.snapshot_interval,
                next_snapshot: 0.0,
                snapshots: Csv::new(&["time", "metric", "labels", "value"]),
            }))),
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    fn with_inner<R>(&self, f: impl FnOnce(&mut Inner) -> R) -> Option<R> {
        self.inner.as_ref().map(|m| f(&mut m.lock().expect("telemetry lock")))
    }

    /// Record which clock domain timestamps come from ("sim" | "wall").
    pub fn set_time_domain(&self, domain: &str) {
        let wall = if domain == "wall" { 1.0 } else { 0.0 };
        self.with_inner(|i| i.registry.set("andes_time_domain_wall", &[], wall));
    }

    /// Increment a counter family.
    pub fn inc(&self, name: &str, labels: &[(&str, &str)], by: f64) {
        self.with_inner(|i| i.registry.inc(name, labels, by));
    }

    /// Set a gauge family.
    pub fn set_gauge(&self, name: &str, labels: &[(&str, &str)], v: f64) {
        self.with_inner(|i| i.registry.set(name, labels, v));
    }

    /// Observe into a latency histogram (TTFT-style buckets).
    pub fn observe_latency(&self, name: &str, labels: &[(&str, &str)], v: f64) {
        self.with_inner(|i| i.registry.observe(name, labels, v, LATENCY_BUCKETS));
    }

    /// Observe into a per-token latency histogram (TPOT-style buckets).
    pub fn observe_tpot(&self, name: &str, labels: &[(&str, &str)], v: f64) {
        self.with_inner(|i| i.registry.observe(name, labels, v, TPOT_BUCKETS));
    }

    /// Observe into a unit-interval histogram (QoE-style buckets).
    pub fn observe_unit(&self, name: &str, labels: &[(&str, &str)], v: f64) {
        self.with_inner(|i| i.registry.observe(name, labels, v, UNIT_BUCKETS));
    }

    /// Append a structured trace event to `request`'s span.
    pub fn event(&self, request: u64, kind: &'static str, time: f64, fields: &[(&str, Json)]) {
        self.with_inner(|i| i.tracer.record(request, kind, time, fields));
    }

    /// Take a periodic metrics snapshot if `now` crossed the interval
    /// boundary (no-op when snapshots are disabled). Call from the hot
    /// loop that owns the engine clock.
    pub fn maybe_snapshot(&self, now: f64) {
        self.with_inner(|i| {
            if i.snapshot_interval <= 0.0 || now < i.next_snapshot {
                return;
            }
            // One row per (metric, labels); skip ahead past gaps so an
            // idle stretch doesn't emit a burst of identical snapshots.
            i.next_snapshot = now + i.snapshot_interval;
            let rows = i.registry.snapshot_rows();
            for (metric, labels, value) in rows {
                i.snapshots.row(&[fmt_f64(now), metric, labels, fmt_f64(value)]);
            }
        });
    }

    /// Render the registry in Prometheus text exposition format (empty
    /// when disabled).
    pub fn render_prometheus(&self) -> String {
        self.with_inner(|i| i.registry.render()).unwrap_or_default()
    }

    /// Export the tracer ring buffer as JSONL (empty when disabled).
    pub fn trace_jsonl(&self) -> String {
        self.with_inner(|i| i.tracer.export_jsonl()).unwrap_or_default()
    }

    /// The accumulated metrics-snapshot CSV text (header-only when no
    /// snapshot fired).
    pub fn snapshot_csv(&self) -> String {
        self.with_inner(|i| i.snapshots.to_string()).unwrap_or_default()
    }

    /// Number of snapshot rows accumulated so far.
    pub fn snapshot_rows_len(&self) -> usize {
        self.with_inner(|i| i.snapshots.len()).unwrap_or(0)
    }

    /// Current value of a counter/gauge series (0 when disabled/absent).
    pub fn value(&self, name: &str, labels: &[(&str, &str)]) -> f64 {
        self.with_inner(|i| i.registry.value(name, labels)).unwrap_or(0.0)
    }

    /// Histogram percentile via the shared estimator (NaN when absent).
    pub fn histogram_percentile(&self, name: &str, labels: &[(&str, &str)], p: f64) -> f64 {
        self.with_inner(|i| i.registry.histogram_percentile(name, labels, p))
            .unwrap_or(f64::NAN)
    }

    /// Buffered trace events / open spans (diagnostics, tests).
    pub fn trace_stats(&self) -> (usize, usize, u64) {
        self.with_inner(|i| {
            (i.tracer.buffered_events(), i.tracer.open_spans(), i.tracer.dropped_spans())
        })
        .unwrap_or((0, 0, 0))
    }
}

/// Pre-declare the stack's metric taxonomy (DESIGN.md §12) so `/metrics`
/// advertises every family — HELP/TYPE lines — before traffic arrives.
fn declare_base_families(r: &mut Registry) {
    r.declare_gauge("andes_time_domain_wall", "1 when timestamps are wall-clock, 0 for sim time");
    r.declare_counter("andes_requests_total", "arrivals by tier and admission outcome");
    r.declare_counter("andes_rejects_total", "structured rejections by cause");
    r.declare_counter("andes_tokens_total", "output tokens delivered, by tier");
    r.declare_histogram("andes_ttft_seconds", "time to first token, by tier", LATENCY_BUCKETS);
    r.declare_histogram(
        "andes_tpot_seconds",
        "mean time per output token after the first, by tier",
        TPOT_BUCKETS,
    );
    r.declare_histogram("andes_qoe", "final per-request QoE in [0,1], by tier", UNIT_BUCKETS);
    r.declare_gauge("andes_defer_queue_depth", "requests parked in the gateway defer queue");
    r.declare_gauge("andes_surge_mode", "1 while the surge detector reports surge load");
    r.declare_gauge("andes_pacer_lead_tokens", "pacer lead of the most recent finished stream");
    r.declare_gauge("andes_batch_size", "requests in the current engine iteration, per replica");
    r.declare_gauge(
        "andes_kv_used_fraction",
        "device KV cache utilization in [0,1], per replica",
    );
    r.declare_counter("andes_iterations_total", "engine iterations by replica and phase");
    r.declare_counter("andes_preemptions_total", "preemptions by replica and kind");
    r.declare_counter("andes_prefix_hits_total", "parked-prefix claims, per replica");
    r.declare_gauge("andes_replicas", "routable serving replicas");
    r.declare_counter("andes_replica_events_total", "replica lifecycle events by action");
    r.declare_counter("andes_net_stalls_total", "client playback stalls, by tier");
    r.declare_counter("andes_net_stall_seconds_total", "client stall time, by tier");
    r.declare_counter("andes_net_retransmits_total", "network retransmissions, by tier");
    r.declare_counter("andes_net_disconnects_total", "tokens delayed by disconnects, by tier");
}

#[cfg(test)]
mod tests {
    use super::*;

    fn enabled() -> Telemetry {
        Telemetry::new(&TelemetryConfig { enabled: true, ..TelemetryConfig::default() })
    }

    #[test]
    fn disabled_handle_is_inert() {
        let t = Telemetry::disabled();
        t.inc("andes_requests_total", &[("tier", "premium")], 1.0);
        t.event(1, "arrival", 0.0, &[]);
        t.maybe_snapshot(10.0);
        assert!(!t.is_enabled());
        assert_eq!(t.render_prometheus(), "");
        assert_eq!(t.trace_jsonl(), "");
        assert_eq!(t.value("andes_requests_total", &[("tier", "premium")]), 0.0);
    }

    #[test]
    fn config_off_is_disabled() {
        assert!(!Telemetry::new(&TelemetryConfig::default()).is_enabled());
    }

    #[test]
    fn clones_share_state() {
        let a = enabled();
        let b = a.clone();
        b.inc("andes_tokens_total", &[("tier", "standard")], 42.0);
        assert_eq!(a.value("andes_tokens_total", &[("tier", "standard")]), 42.0);
    }

    #[test]
    fn base_families_render_and_validate_before_traffic() {
        let t = enabled();
        let text = t.render_prometheus();
        for family in [
            "andes_requests_total",
            "andes_ttft_seconds",
            "andes_tpot_seconds",
            "andes_qoe",
            "andes_tokens_total",
            "andes_rejects_total",
            "andes_defer_queue_depth",
            "andes_batch_size",
        ] {
            assert!(text.contains(&format!("# TYPE {family}")), "{family} missing");
        }
        assert!(validate_exposition(&text).is_ok());
    }

    #[test]
    fn snapshots_fire_on_interval() {
        let t = Telemetry::new(&TelemetryConfig {
            enabled: true,
            snapshot_interval: 1.0,
            ..TelemetryConfig::default()
        });
        t.set_gauge("andes_defer_queue_depth", &[], 3.0);
        t.maybe_snapshot(0.0); // fires (first boundary at 0)
        t.maybe_snapshot(0.5); // inside interval: no row
        let after_first = t.snapshot_rows_len();
        assert!(after_first > 0);
        t.maybe_snapshot(1.5); // next boundary crossed
        assert!(t.snapshot_rows_len() > after_first);
        let csv = t.snapshot_csv();
        assert!(csv.starts_with("time,metric,labels,value"));
        assert!(csv.contains("andes_defer_queue_depth"));
    }

    #[test]
    fn time_domain_gauge() {
        let t = enabled();
        t.set_time_domain("wall");
        assert_eq!(t.value("andes_time_domain_wall", &[]), 1.0);
        t.set_time_domain("sim");
        assert_eq!(t.value("andes_time_domain_wall", &[]), 0.0);
    }
}
