//! Leveled structured logging to stderr, replacing the old hardcoded
//! Info-only logger in `main.rs` so `--quiet` / `--log-level` behave
//! consistently across subcommands.
//!
//! Lines render as `[  12.345s LEVEL target] message` — elapsed process
//! time, level, and the emitting module — so advisory logs from the
//! serving stack (autoscale/spill/network/park) are grep-able and
//! filterable without a crates.io logging framework.

use std::sync::OnceLock;
use std::time::Instant;

use log::{Level, LevelFilter, Log, Metadata, Record};

static START: OnceLock<Instant> = OnceLock::new();
static LOGGER: StderrLogger = StderrLogger;

struct StderrLogger;

impl Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let elapsed = START.get_or_init(Instant::now).elapsed().as_secs_f64();
        let target = record.target().rsplit("::").next().unwrap_or("andes");
        eprintln!(
            "[{elapsed:>9.3}s {:<5} {target}] {}",
            record.level(),
            record.args()
        );
    }

    fn flush(&self) {}
}

/// Parse a CLI level name. `--quiet` maps to [`LevelFilter::Error`].
pub fn parse_level(s: &str) -> Option<LevelFilter> {
    match s.to_ascii_lowercase().as_str() {
        "off" => Some(LevelFilter::Off),
        "error" => Some(LevelFilter::Error),
        "warn" => Some(LevelFilter::Warn),
        "info" => Some(LevelFilter::Info),
        "debug" => Some(LevelFilter::Debug),
        "trace" => Some(LevelFilter::Trace),
        _ => None,
    }
}

/// Install the stderr logger at `level`. Safe to call repeatedly: later
/// calls only adjust the max level (the first logger installation wins,
/// which is the same logger).
pub fn init(level: LevelFilter) {
    START.get_or_init(Instant::now);
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(level);
}

/// Convenience: map a `Level` to the label used in log lines (tested
/// so the format stays stable for scrapers).
pub fn level_label(level: Level) -> &'static str {
    match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN",
        Level::Info => "INFO",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(parse_level("info"), Some(LevelFilter::Info));
        assert_eq!(parse_level("WARN"), Some(LevelFilter::Warn));
        assert_eq!(parse_level("off"), Some(LevelFilter::Off));
        assert_eq!(parse_level("loud"), None);
    }

    #[test]
    fn init_adjusts_max_level() {
        init(LevelFilter::Warn);
        assert_eq!(log::max_level(), LevelFilter::Warn);
        init(LevelFilter::Error);
        assert_eq!(log::max_level(), LevelFilter::Error);
    }

    #[test]
    fn level_labels() {
        assert_eq!(level_label(Level::Info), "INFO");
        assert_eq!(level_label(Level::Error), "ERROR");
    }
}
