//! Per-request structured event tracing with bounded-memory ring
//! buffering and JSONL export.
//!
//! Every request's life is a *span* of [`TraceEvent`]s — arrival →
//! admit/defer/reject → prefill start → first token → pacing releases →
//! preempt/restore → network stall/retransmit → finish. The tracer
//! bounds its memory by evicting whole **closed** spans, oldest first,
//! once the buffered event count exceeds the configured capacity; a
//! span still open (its request in flight) is never evicted, so a live
//! request's timeline survives any amount of churn around it
//! (property-tested in `rust/tests/telemetry.rs`).
//!
//! ```
//! use andes::telemetry::trace::{validate_jsonl, Tracer};
//!
//! let mut t = Tracer::new(1024);
//! t.record(7, "arrival", 0.5, &[("tier", "premium".into())]);
//! t.record(7, "admit", 0.5, &[("replica", 0u64.into())]);
//! t.record(7, "finish", 3.2, &[("tokens", 120u64.into())]);
//! let jsonl = t.export_jsonl();
//! assert_eq!(validate_jsonl(&jsonl).unwrap(), 3);
//! ```

use std::collections::{BTreeMap, VecDeque};

use anyhow::{bail, Result};

use crate::util::json::Json;

/// The closed vocabulary of trace event kinds — the JSONL schema the CI
/// smoke validates against (see [`validate_jsonl`]).
pub const EVENT_KINDS: &[&str] = &[
    "arrival",
    "admit",
    "defer",
    "reject",
    "spill",
    "prefill_start",
    "first_token",
    "pacer_release",
    "preempt",
    "restore",
    "net_stall",
    "retransmit",
    "disconnect",
    "finish",
];

/// Kinds that end a request's span (further events reopen nothing).
const CLOSING_KINDS: &[&str] = &["reject", "finish"];

/// One structured event inside a request's span.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    /// Global record order (export order).
    pub seq: u64,
    /// Engine-clock time (sim or wall seconds, per the run's clock).
    pub time: f64,
    /// Span key: the request this event belongs to.
    pub request: u64,
    pub kind: &'static str,
    /// Event-specific payload, flattened into the JSONL line.
    pub fields: Vec<(String, Json)>,
}

#[derive(Debug, Default)]
struct Span {
    events: Vec<TraceEvent>,
    open: bool,
}

/// Bounded per-request event buffer (see module docs for the eviction
/// contract).
#[derive(Debug)]
pub struct Tracer {
    capacity: usize,
    next_seq: u64,
    spans: BTreeMap<u64, Span>,
    /// Closed spans in closing order — the eviction queue.
    closed: VecDeque<u64>,
    buffered: usize,
    dropped_spans: u64,
    dropped_events: u64,
}

impl Tracer {
    /// `capacity` bounds the buffered event count (≥ 1).
    pub fn new(capacity: usize) -> Self {
        Tracer {
            capacity: capacity.max(1),
            next_seq: 0,
            spans: BTreeMap::new(),
            closed: VecDeque::new(),
            buffered: 0,
            dropped_spans: 0,
            dropped_events: 0,
        }
    }

    /// Append one event to `request`'s span, opening it if needed and
    /// closing it on a terminal kind, then evict closed spans (oldest
    /// first) while over capacity.
    pub fn record(&mut self, request: u64, kind: &'static str, time: f64, fields: &[(&str, Json)]) {
        debug_assert!(EVENT_KINDS.contains(&kind), "unknown event kind '{kind}'");
        let span = self.spans.entry(request).or_insert_with(|| Span {
            events: Vec::new(),
            open: true,
        });
        let was_open = span.open;
        span.events.push(TraceEvent {
            seq: self.next_seq,
            time,
            request,
            kind,
            fields: fields.iter().map(|(k, v)| (k.to_string(), v.clone())).collect(),
        });
        self.next_seq += 1;
        self.buffered += 1;
        if was_open && CLOSING_KINDS.contains(&kind) {
            span.open = false;
            self.closed.push_back(request);
        }
        while self.buffered > self.capacity {
            let Some(victim) = self.closed.pop_front() else {
                // Only open spans remain: never evict them. The buffer
                // overshoots until something closes (bounded in practice
                // by in-flight concurrency × span length).
                break;
            };
            if let Some(s) = self.spans.remove(&victim) {
                self.buffered -= s.events.len();
                self.dropped_spans += 1;
                self.dropped_events += s.events.len() as u64;
            }
        }
    }

    pub fn buffered_events(&self) -> usize {
        self.buffered
    }

    pub fn open_spans(&self) -> usize {
        self.spans.values().filter(|s| s.open).count()
    }

    pub fn dropped_spans(&self) -> u64 {
        self.dropped_spans
    }

    pub fn dropped_events(&self) -> u64 {
        self.dropped_events
    }

    /// The buffered events of one request's span, in record order.
    pub fn events_for(&self, request: u64) -> Option<&[TraceEvent]> {
        self.spans.get(&request).map(|s| s.events.as_slice())
    }

    /// Export every buffered event as JSON Lines, in global record
    /// order. Each line carries `time`, `request`, `event`, plus the
    /// event's flattened payload fields.
    pub fn export_jsonl(&self) -> String {
        let mut events: Vec<&TraceEvent> =
            self.spans.values().flat_map(|s| s.events.iter()).collect();
        events.sort_by_key(|e| e.seq);
        let mut out = String::new();
        for e in events {
            let mut pairs: Vec<(&str, Json)> = vec![
                ("time", Json::from(e.time)),
                ("request", Json::from(e.request)),
                ("event", Json::from(e.kind)),
            ];
            for (k, v) in &e.fields {
                pairs.push((k.as_str(), v.clone()));
            }
            out.push_str(&Json::obj(pairs).to_string());
            out.push('\n');
        }
        out
    }
}

/// Validate a JSONL trace export against the event schema: every line a
/// JSON object with a finite non-negative `time`, an integer `request`,
/// an `event` drawn from [`EVENT_KINDS`], and only scalar payload
/// fields. Returns the number of validated lines.
pub fn validate_jsonl(text: &str) -> Result<usize> {
    let mut n = 0usize;
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let lineno = i + 1;
        let v = Json::parse(line).map_err(|e| anyhow::anyhow!("line {lineno}: {e}"))?;
        let o = match &v {
            Json::Obj(o) => o,
            _ => bail!("line {lineno}: not a JSON object"),
        };
        let time = v
            .get("time")
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("line {lineno}: missing numeric 'time'"))?;
        if !time.is_finite() || time < 0.0 {
            bail!("line {lineno}: 'time' must be finite and non-negative, got {time}");
        }
        let req = v
            .get("request")
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("line {lineno}: missing numeric 'request'"))?;
        if req < 0.0 || req.fract() != 0.0 {
            bail!("line {lineno}: 'request' must be a non-negative integer");
        }
        let kind = v
            .get("event")
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("line {lineno}: missing string 'event'"))?;
        if !EVENT_KINDS.contains(&kind) {
            bail!("line {lineno}: unknown event kind '{kind}'");
        }
        for (k, field) in o {
            if matches!(field, Json::Arr(_) | Json::Obj(_)) {
                bail!("line {lineno}: field '{k}' must be scalar");
            }
        }
        n += 1;
    }
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(t: &mut Tracer, req: u64, kind: &'static str) {
        t.record(req, kind, req as f64, &[]);
    }

    #[test]
    fn span_records_in_order_and_closes() {
        let mut t = Tracer::new(100);
        ev(&mut t, 1, "arrival");
        ev(&mut t, 1, "admit");
        ev(&mut t, 1, "first_token");
        assert_eq!(t.open_spans(), 1);
        ev(&mut t, 1, "finish");
        assert_eq!(t.open_spans(), 0);
        let kinds: Vec<&str> = t.events_for(1).unwrap().iter().map(|e| e.kind).collect();
        assert_eq!(kinds, ["arrival", "admit", "first_token", "finish"]);
    }

    #[test]
    fn eviction_drops_oldest_closed_span_first() {
        let mut t = Tracer::new(4);
        ev(&mut t, 1, "arrival");
        ev(&mut t, 1, "finish"); // closed, 2 events
        ev(&mut t, 2, "arrival");
        ev(&mut t, 2, "finish"); // closed, 2 events — at capacity
        ev(&mut t, 3, "arrival"); // over capacity → span 1 evicted
        assert!(t.events_for(1).is_none());
        assert!(t.events_for(2).is_some());
        assert_eq!(t.dropped_spans(), 1);
        assert_eq!(t.dropped_events(), 2);
        assert!(t.buffered_events() <= 4);
    }

    #[test]
    fn open_spans_survive_overflow() {
        let mut t = Tracer::new(3);
        for i in 0..10 {
            ev(&mut t, 42, "pacer_release");
            // Closed churn around the open span.
            ev(&mut t, 100 + i, "arrival");
            ev(&mut t, 100 + i, "finish");
        }
        // Every event of the open span is still buffered.
        assert_eq!(t.events_for(42).unwrap().len(), 10);
        assert_eq!(t.open_spans(), 1);
    }

    #[test]
    fn rejected_span_is_closed() {
        let mut t = Tracer::new(10);
        ev(&mut t, 5, "arrival");
        ev(&mut t, 5, "reject");
        assert_eq!(t.open_spans(), 0);
    }

    #[test]
    fn jsonl_roundtrip_validates() {
        let mut t = Tracer::new(64);
        t.record(0, "arrival", 0.25, &[("tier", "economy".into())]);
        t.record(0, "reject", 0.25, &[("cause", "surge-shed".into())]);
        t.record(1, "arrival", 0.50, &[]);
        t.record(1, "admit", 0.50, &[("replica", 1u64.into())]);
        t.record(1, "finish", 2.0, &[("tokens", 64u64.into())]);
        let jsonl = t.export_jsonl();
        assert_eq!(validate_jsonl(&jsonl).unwrap(), 5);
        assert!(jsonl.lines().next().unwrap().contains("\"event\":\"arrival\""));
    }

    #[test]
    fn validator_rejects_bad_lines() {
        assert!(validate_jsonl("not json\n").is_err());
        assert!(validate_jsonl("{\"time\":1,\"request\":0}\n").is_err(), "missing event");
        assert!(
            validate_jsonl("{\"time\":1,\"request\":0,\"event\":\"warp\"}\n").is_err(),
            "unknown kind"
        );
        assert!(
            validate_jsonl("{\"time\":-1,\"request\":0,\"event\":\"arrival\"}\n").is_err(),
            "negative time"
        );
        assert!(
            validate_jsonl("{\"time\":1,\"request\":0.5,\"event\":\"arrival\"}\n").is_err(),
            "fractional request id"
        );
        assert_eq!(validate_jsonl("\n\n").unwrap(), 0);
    }
}
