//! Cluster-level serving: multiple engine replicas behind a router.
//!
//! The paper scopes Andes to a single vLLM instance and "assumes that
//! cluster-level load balancing ... [is] done separately" (§5). This
//! module builds that separate layer — the natural extension a
//! deployment needs — and lets the `ext-cluster` experiment quantify
//! how much the routing policy matters once per-replica scheduling is
//! QoE-aware:
//!
//! - [`RoutingPolicy::RoundRobin`] — classic stateless spraying;
//! - [`RoutingPolicy::LeastLoaded`] — join-the-shortest-queue on active
//!   request count;
//! - [`RoutingPolicy::QoeAware`] — route to the replica with the most
//!   KV-token headroom per active request (a proxy for the marginal QoE
//!   cost of placing one more request there).

use anyhow::Result;

use crate::backend::sim::SimBackend;
use crate::backend::VirtualClock;
use crate::config::SchedulerConfig;
use crate::coordinator::engine::{Engine, EngineConfig};
use crate::coordinator::metrics::Metrics;
use crate::model::latency::LatencyModel;
use crate::workload::RequestSpec;

/// Request routing policies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutingPolicy {
    RoundRobin,
    LeastLoaded,
    QoeAware,
}

impl RoutingPolicy {
    pub fn label(&self) -> &'static str {
        match self {
            RoutingPolicy::RoundRobin => "round-robin",
            RoutingPolicy::LeastLoaded => "least-loaded",
            RoutingPolicy::QoeAware => "qoe-aware",
        }
    }
}

/// A simulated serving cluster.
pub struct Cluster {
    replicas: Vec<Engine<SimBackend, VirtualClock>>,
    policy: RoutingPolicy,
    rr_next: usize,
}

impl Cluster {
    /// Build `n` identical replicas.
    pub fn new(
        n: usize,
        engine_cfg: EngineConfig,
        latency: LatencyModel,
        scheduler: &SchedulerConfig,
        policy: RoutingPolicy,
    ) -> Self {
        assert!(n > 0);
        let replicas = (0..n)
            .map(|_| {
                Engine::new(
                    engine_cfg.clone(),
                    SimBackend::new(latency.clone()),
                    VirtualClock::default(),
                    scheduler.build(),
                    latency.clone(),
                )
            })
            .collect();
        Cluster { replicas, policy, rr_next: 0 }
    }

    pub fn num_replicas(&self) -> usize {
        self.replicas.len()
    }

    /// Active (unfinished) request count per replica.
    fn loads(&self) -> Vec<usize> {
        self.replicas
            .iter()
            .map(|e| e.requests().iter().filter(|r| r.is_active()).count())
            .collect()
    }

    /// Pick a replica for a new request.
    fn route(&mut self) -> usize {
        match self.policy {
            RoutingPolicy::RoundRobin => {
                let idx = self.rr_next % self.replicas.len();
                self.rr_next += 1;
                idx
            }
            RoutingPolicy::LeastLoaded => {
                let loads = self.loads();
                (0..loads.len()).min_by_key(|&i| loads[i]).unwrap()
            }
            RoutingPolicy::QoeAware => {
                // Most free KV tokens per active request: replicas close
                // to memory saturation will degrade everyone's QoE when
                // given one more request.
                let loads = self.loads();
                (0..self.replicas.len())
                    .max_by(|&a, &b| {
                        let score = |i: usize| {
                            self.replicas[i].kv().device_free_tokens() as f64
                                / (loads[i] + 1) as f64
                        };
                        score(a).partial_cmp(&score(b)).unwrap()
                    })
                    .unwrap()
            }
        }
    }

    /// Advance every replica's virtual clock to at least `t`, running
    /// any pending work on the way.
    fn advance_all_to(&mut self, t: f64) -> Result<()> {
        for e in self.replicas.iter_mut() {
            while e.has_work() && e.now() < t {
                e.tick()?;
            }
            e.advance_clock_to(t);
        }
        Ok(())
    }

    /// Run a full trace through the cluster; returns per-replica metrics.
    pub fn run_trace(&mut self, mut trace: Vec<RequestSpec>) -> Result<Vec<Metrics>> {
        trace.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        for spec in trace {
            // Bring the cluster's clocks up to the arrival instant so
            // routing sees current loads.
            self.advance_all_to(spec.arrival)?;
            let idx = self.route();
            self.replicas[idx].submit(spec)?;
        }
        // Drain.
        for e in self.replicas.iter_mut() {
            while e.has_work() {
                e.tick()?;
            }
        }
        Ok(self
            .replicas
            .iter_mut()
            .map(|e| std::mem::take(e.metrics_mut()))
            .collect())
    }
}

/// Merge per-replica metrics into cluster-level aggregates.
pub fn merged_qoes(all: &[Metrics]) -> Vec<f64> {
    all.iter().flat_map(|m| m.qoes()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gpu::a100_4x;
    use crate::model::llm::opt_66b;
    use crate::workload::{ArrivalProcess, Dataset, QoeTrace, Workload};

    fn small_cluster(policy: RoutingPolicy, n: usize) -> Cluster {
        let latency = LatencyModel::for_deployment(&opt_66b(), &a100_4x());
        let cfg = EngineConfig {
            kv_capacity_tokens: 4000,
            swap_capacity_tokens: 8000,
            ..EngineConfig::default()
        };
        Cluster::new(n, cfg, latency, &SchedulerConfig::Fcfs, policy)
    }

    fn trace(n: usize, rate: f64, seed: u64) -> Vec<RequestSpec> {
        Workload {
            dataset: Dataset::ShareGpt,
            arrivals: ArrivalProcess::Poisson { rate },
            qoe_trace: QoeTrace::TextReading,
            num_requests: n,
            seed,
        }
        .generate()
    }

    #[test]
    fn all_requests_complete_across_replicas() {
        for policy in
            [RoutingPolicy::RoundRobin, RoutingPolicy::LeastLoaded, RoutingPolicy::QoeAware]
        {
            let mut c = small_cluster(policy, 3);
            let all = c.run_trace(trace(60, 3.0, 5)).unwrap();
            let total: usize = all.iter().map(|m| m.requests.len()).sum();
            assert_eq!(total, 60, "{}", policy.label());
        }
    }

    #[test]
    fn round_robin_spreads_evenly() {
        let mut c = small_cluster(RoutingPolicy::RoundRobin, 4);
        let all = c.run_trace(trace(80, 2.0, 6)).unwrap();
        for m in &all {
            assert_eq!(m.requests.len(), 20);
        }
    }

    #[test]
    fn least_loaded_balances_under_skew() {
        let mut c = small_cluster(RoutingPolicy::LeastLoaded, 2);
        let all = c.run_trace(trace(40, 4.0, 7)).unwrap();
        let counts: Vec<usize> = all.iter().map(|m| m.requests.len()).collect();
        let diff = counts[0].abs_diff(counts[1]);
        assert!(diff <= 8, "unbalanced: {counts:?}");
    }

    #[test]
    fn single_replica_cluster_matches_engine() {
        let mut c = small_cluster(RoutingPolicy::QoeAware, 1);
        let all = c.run_trace(trace(30, 2.0, 8)).unwrap();
        assert_eq!(all[0].requests.len(), 30);
        assert!(merged_qoes(&all).len() == 30);
    }
}
