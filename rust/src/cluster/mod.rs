//! Cluster-level serving: multiple engine replicas behind a router.
//!
//! The paper scopes Andes to a single vLLM instance and "assumes that
//! cluster-level load balancing ... [is] done separately" (§5). This
//! module builds that separate layer — the natural extension a
//! deployment needs — and lets the `ext-cluster` experiment quantify
//! how much the routing policy matters once per-replica scheduling is
//! QoE-aware:
//!
//! - [`RoutingPolicy::RoundRobin`] — classic stateless spraying;
//! - [`RoutingPolicy::LeastLoaded`] — join-the-shortest-queue on active
//!   request count;
//! - [`RoutingPolicy::QoeAware`] — route to the replica with the most
//!   KV-token headroom per active request (a proxy for the marginal QoE
//!   cost of placing one more request there).
//!
//! Per-replica active-request counts are maintained incrementally
//! (+1 on submit, −1 as finishes are observed) so routing is O(replicas)
//! per arrival instead of a scan over every request vector. The
//! [`crate::gateway`] front door drives a cluster through the public
//! `submit_with_policy`/`advance_all_to`/`drain` API.

use anyhow::Result;

use crate::backend::sim::SimBackend;
use crate::backend::VirtualClock;
use crate::config::SchedulerConfig;
use crate::coordinator::engine::{Engine, EngineConfig};
use crate::coordinator::metrics::Metrics;
use crate::model::latency::LatencyModel;
use crate::workload::RequestSpec;

/// Request routing policies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutingPolicy {
    RoundRobin,
    LeastLoaded,
    QoeAware,
}

impl RoutingPolicy {
    pub fn label(&self) -> &'static str {
        match self {
            RoutingPolicy::RoundRobin => "round-robin",
            RoutingPolicy::LeastLoaded => "least-loaded",
            RoutingPolicy::QoeAware => "qoe-aware",
        }
    }
}

/// A simulated serving cluster.
pub struct Cluster {
    replicas: Vec<Engine<SimBackend, VirtualClock>>,
    policy: RoutingPolicy,
    rr_next: usize,
    /// Incrementally maintained active (unfinished) count per replica.
    active: Vec<usize>,
    /// Finished-request count already subtracted from `active`.
    finished_seen: Vec<usize>,
}

impl Cluster {
    /// Build `n` identical replicas.
    pub fn new(
        n: usize,
        engine_cfg: EngineConfig,
        latency: LatencyModel,
        scheduler: &SchedulerConfig,
        policy: RoutingPolicy,
    ) -> Self {
        assert!(n > 0);
        let replicas = (0..n)
            .map(|_| {
                Engine::new(
                    engine_cfg.clone(),
                    SimBackend::new(latency.clone()),
                    VirtualClock::default(),
                    scheduler.build(),
                    latency.clone(),
                )
            })
            .collect();
        Cluster {
            replicas,
            policy,
            rr_next: 0,
            active: vec![0; n],
            finished_seen: vec![0; n],
        }
    }

    pub fn num_replicas(&self) -> usize {
        self.replicas.len()
    }

    /// Read-only view of the replicas (gateway state snapshots).
    pub fn replicas(&self) -> &[Engine<SimBackend, VirtualClock>] {
        &self.replicas
    }

    /// Incrementally maintained active-request count per replica.
    pub fn active_counts(&self) -> &[usize] {
        &self.active
    }

    /// Latest simulated time across replicas.
    pub fn now(&self) -> f64 {
        self.replicas.iter().map(|e| e.now()).fold(0.0, f64::max)
    }

    /// Fold replica `i`'s newly observed finishes into its active count.
    fn sync_finished(&mut self, i: usize) {
        let fin = self.replicas[i].metrics().requests.len();
        let newly = fin - self.finished_seen[i];
        if newly > 0 {
            self.active[i] -= newly;
            self.finished_seen[i] = fin;
        }
    }

    /// Pick a replica under `policy`.
    fn route(&mut self, policy: RoutingPolicy) -> usize {
        match policy {
            RoutingPolicy::RoundRobin => {
                let idx = self.rr_next % self.replicas.len();
                self.rr_next += 1;
                idx
            }
            RoutingPolicy::LeastLoaded => {
                (0..self.active.len()).min_by_key(|&i| self.active[i]).unwrap()
            }
            RoutingPolicy::QoeAware => {
                // Most free KV tokens per active request: replicas close
                // to memory saturation will degrade everyone's QoE when
                // given one more request.
                (0..self.replicas.len())
                    .max_by(|&a, &b| {
                        let score = |i: usize| {
                            self.replicas[i].kv().device_free_tokens() as f64
                                / (self.active[i] + 1) as f64
                        };
                        score(a).partial_cmp(&score(b)).unwrap()
                    })
                    .unwrap()
            }
        }
    }

    /// Route and submit one request; returns the chosen replica index.
    pub fn submit(&mut self, spec: RequestSpec) -> Result<usize> {
        self.submit_with_policy(spec, None)
    }

    /// Submit with an optional routing-policy override — the gateway's
    /// surge-aware routing hook.
    pub fn submit_with_policy(
        &mut self,
        spec: RequestSpec,
        policy: Option<RoutingPolicy>,
    ) -> Result<usize> {
        let idx = self.route(policy.unwrap_or(self.policy));
        self.replicas[idx].submit(spec)?;
        self.active[idx] += 1;
        Ok(idx)
    }

    /// Advance every replica's virtual clock to at least `t`, running
    /// any pending work on the way.
    pub fn advance_all_to(&mut self, t: f64) -> Result<()> {
        for i in 0..self.replicas.len() {
            {
                let e = &mut self.replicas[i];
                while e.has_work() && e.now() < t {
                    e.tick()?;
                }
                e.advance_clock_to(t);
            }
            self.sync_finished(i);
        }
        Ok(())
    }

    /// Finish all outstanding work and take the per-replica metrics.
    pub fn drain(&mut self) -> Result<Vec<Metrics>> {
        for i in 0..self.replicas.len() {
            {
                let e = &mut self.replicas[i];
                while e.has_work() {
                    e.tick()?;
                }
            }
            self.sync_finished(i);
        }
        // Taking the metrics resets each replica's finish history; keep
        // the incremental counters consistent with that.
        self.finished_seen.iter_mut().for_each(|f| *f = 0);
        Ok(self
            .replicas
            .iter_mut()
            .map(|e| std::mem::take(e.metrics_mut()))
            .collect())
    }

    /// Run a full trace through the cluster; returns per-replica metrics.
    pub fn run_trace(&mut self, mut trace: Vec<RequestSpec>) -> Result<Vec<Metrics>> {
        trace.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        for spec in trace {
            // Bring the cluster's clocks up to the arrival instant so
            // routing sees current loads.
            self.advance_all_to(spec.arrival)?;
            self.submit(spec)?;
        }
        self.drain()
    }
}

/// Merge per-replica metrics into cluster-level aggregates.
pub fn merged_qoes(all: &[Metrics]) -> Vec<f64> {
    all.iter().flat_map(|m| m.qoes()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gpu::a100_4x;
    use crate::model::llm::opt_66b;
    use crate::qoe::spec::QoeSpec;
    use crate::util::stats::mean;
    use crate::workload::{ArrivalProcess, Dataset, QoeTrace, Workload};

    fn small_cluster(policy: RoutingPolicy, n: usize) -> Cluster {
        let latency = LatencyModel::for_deployment(&opt_66b(), &a100_4x());
        let cfg = EngineConfig {
            kv_capacity_tokens: 4000,
            swap_capacity_tokens: 8000,
            ..EngineConfig::default()
        };
        Cluster::new(n, cfg, latency, &SchedulerConfig::Fcfs, policy)
    }

    fn trace(n: usize, rate: f64, seed: u64) -> Vec<RequestSpec> {
        Workload {
            dataset: Dataset::ShareGpt,
            arrivals: ArrivalProcess::Poisson { rate },
            qoe_trace: QoeTrace::TextReading,
            num_requests: n,
            seed,
        }
        .generate()
    }

    #[test]
    fn all_requests_complete_across_replicas() {
        for policy in
            [RoutingPolicy::RoundRobin, RoutingPolicy::LeastLoaded, RoutingPolicy::QoeAware]
        {
            let mut c = small_cluster(policy, 3);
            let all = c.run_trace(trace(60, 3.0, 5)).unwrap();
            let total: usize = all.iter().map(|m| m.requests.len()).sum();
            assert_eq!(total, 60, "{}", policy.label());
        }
    }

    #[test]
    fn round_robin_spreads_evenly() {
        let mut c = small_cluster(RoutingPolicy::RoundRobin, 4);
        let all = c.run_trace(trace(80, 2.0, 6)).unwrap();
        for m in &all {
            assert_eq!(m.requests.len(), 20);
        }
    }

    #[test]
    fn least_loaded_balances_under_skew() {
        let mut c = small_cluster(RoutingPolicy::LeastLoaded, 2);
        let all = c.run_trace(trace(40, 4.0, 7)).unwrap();
        let counts: Vec<usize> = all.iter().map(|m| m.requests.len()).collect();
        let diff = counts[0].abs_diff(counts[1]);
        assert!(diff <= 8, "unbalanced: {counts:?}");
    }

    #[test]
    fn single_replica_cluster_matches_engine() {
        let mut c = small_cluster(RoutingPolicy::QoeAware, 1);
        let all = c.run_trace(trace(30, 2.0, 8)).unwrap();
        assert_eq!(all[0].requests.len(), 30);
        assert!(merged_qoes(&all).len() == 30);
    }

    #[test]
    fn incremental_counts_match_recount() {
        // The maintained active counts must equal a fresh scan at every
        // arrival instant.
        let mut c = small_cluster(RoutingPolicy::LeastLoaded, 3);
        let mut reqs = trace(50, 5.0, 9);
        reqs.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        for spec in reqs {
            c.advance_all_to(spec.arrival).unwrap();
            c.submit(spec).unwrap();
            for (i, e) in c.replicas().iter().enumerate() {
                let scan = e.requests().iter().filter(|r| r.is_active()).count();
                assert_eq!(c.active_counts()[i], scan, "replica {i}");
            }
        }
        let all = c.drain().unwrap();
        assert_eq!(all.iter().map(|m| m.requests.len()).sum::<usize>(), 50);
        assert!(c.active_counts().iter().all(|&a| a == 0));
    }

    #[test]
    fn qoe_aware_beats_round_robin_under_kv_skew() {
        // Parity-correlated sizes: every even-id request is KV-heavy, so
        // round-robin over 2 replicas lands all of them on replica 0 (the
        // classic hash-routing pathology). QoE-aware routing sees the
        // vanishing headroom and spreads the heavy requests.
        let latency = LatencyModel::for_deployment(&opt_66b(), &a100_4x());
        let cfg = EngineConfig {
            kv_capacity_tokens: 2000,
            swap_capacity_tokens: 8000,
            ..EngineConfig::default()
        };
        let make_trace = || -> Vec<RequestSpec> {
            (0..60)
                .map(|i| RequestSpec {
                    id: i,
                    arrival: 0.15 * (i + 1) as f64,
                    prompt_tokens: if i % 2 == 0 { 950 } else { 60 },
                    output_tokens: 120,
                    qoe: QoeSpec::new(1.0, 4.8),
                })
                .collect()
        };
        let run = |policy: RoutingPolicy| {
            let mut c =
                Cluster::new(2, cfg.clone(), latency.clone(), &SchedulerConfig::Fcfs, policy);
            let all = c.run_trace(make_trace()).unwrap();
            assert_eq!(
                all.iter().map(|m| m.requests.len()).sum::<usize>(),
                60,
                "{} lost requests",
                policy.label()
            );
            mean(&merged_qoes(&all))
        };
        let rr = run(RoutingPolicy::RoundRobin);
        let qa = run(RoutingPolicy::QoeAware);
        assert!(qa > rr, "qoe-aware {qa:.3} must beat round-robin {rr:.3}");
    }
}
