//! Cluster-level serving: multiple engine replicas behind a router.
//!
//! The paper scopes Andes to a single vLLM instance and "assumes that
//! cluster-level load balancing ... [is] done separately" (§5). This
//! module builds that separate layer — the natural extension a
//! deployment needs — and lets the `ext-cluster` experiment quantify
//! how much the routing policy matters once per-replica scheduling is
//! QoE-aware:
//!
//! - [`RoutingPolicy::RoundRobin`] — classic stateless spraying;
//! - [`RoutingPolicy::LeastLoaded`] — join-the-shortest-queue on active
//!   request count;
//! - [`RoutingPolicy::QoeAware`] — route to the replica with the most
//!   KV-token headroom per active request (a proxy for the marginal QoE
//!   cost of placing one more request there).
//!
//! Per-replica active-request counts are maintained incrementally
//! (+1 on submit, −1 as finishes are observed) so routing is O(replicas)
//! per arrival instead of a scan over every request vector. The
//! [`crate::gateway`] front door drives a cluster through the public
//! `submit_with_policy`/`advance_all_to`/`drain` API.
//!
//! The cluster is **elastic**: [`Cluster::add_replica`] commissions a
//! fresh replica mid-run (the gateway's predictive autoscaler models
//! the cold-start delay before calling it) and
//! [`Cluster::retire_replica`] begins a graceful drain — the replica
//! receives no new routing and decommissions once its in-flight
//! requests finish. Each replica's in-service window (commission →
//! decommission) is tracked so runs can report **replica-seconds** as
//! their resource-cost metric, the currency of the paper's
//! "equal QoE at fewer GPUs" result.

use anyhow::Result;

use crate::backend::sim::SimBackend;
use crate::backend::VirtualClock;
use crate::config::SchedulerConfig;
use crate::coordinator::engine::{Engine, EngineConfig};
use crate::coordinator::metrics::Metrics;
use crate::model::latency::LatencyModel;
use crate::telemetry::Telemetry;
use crate::workload::RequestSpec;

/// Request routing policies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutingPolicy {
    RoundRobin,
    LeastLoaded,
    QoeAware,
}

impl RoutingPolicy {
    pub fn label(&self) -> &'static str {
        match self {
            RoutingPolicy::RoundRobin => "round-robin",
            RoutingPolicy::LeastLoaded => "least-loaded",
            RoutingPolicy::QoeAware => "qoe-aware",
        }
    }
}

/// A simulated serving cluster.
pub struct Cluster {
    replicas: Vec<Engine<SimBackend, VirtualClock>>,
    policy: RoutingPolicy,
    rr_next: usize,
    /// Incrementally maintained active (unfinished) count per replica.
    active: Vec<usize>,
    /// Finished-request count already subtracted from `active`.
    finished_seen: Vec<usize>,
    /// Replicas in graceful drain: no new routing, in-flight finishes.
    draining: Vec<bool>,
    /// When each replica entered service.
    commissioned_at: Vec<f64>,
    /// When each retired replica finished draining (None while serving).
    decommissioned_at: Vec<Option<f64>>,
    /// Kept so replicas can be commissioned mid-run.
    engine_cfg: EngineConfig,
    latency: LatencyModel,
    scheduler: SchedulerConfig,
    /// Replica-seconds consumed by retired replicas whose slot was
    /// reused by a later `add_replica`.
    retired_seconds: f64,
    /// Metrics of reused-slot replicas, surfaced by `drain`.
    retired_metrics: Vec<Metrics>,
    /// Route a returning session turn to the replica holding its parked
    /// KV prefix (DESIGN.md §10). Off by default: routing is
    /// bit-identical to pre-session behavior.
    session_affinity: bool,
    /// Observation handle, propagated to every replica (disabled by
    /// default).
    telemetry: Telemetry,
}

impl Cluster {
    /// Build `n` identical replicas.
    pub fn new(
        n: usize,
        engine_cfg: EngineConfig,
        latency: LatencyModel,
        scheduler: &SchedulerConfig,
        policy: RoutingPolicy,
    ) -> Self {
        assert!(n > 0);
        let replicas = (0..n)
            .map(|_| {
                Engine::new(
                    engine_cfg.clone(),
                    SimBackend::new(latency.clone()),
                    VirtualClock::default(),
                    scheduler.build(),
                    latency.clone(),
                )
            })
            .collect();
        Cluster {
            replicas,
            policy,
            rr_next: 0,
            active: vec![0; n],
            finished_seen: vec![0; n],
            draining: vec![false; n],
            commissioned_at: vec![0.0; n],
            decommissioned_at: vec![None; n],
            engine_cfg,
            latency,
            scheduler: scheduler.clone(),
            retired_seconds: 0.0,
            retired_metrics: Vec::new(),
            session_affinity: false,
            telemetry: Telemetry::disabled(),
        }
    }

    /// Attach a telemetry handle, propagated to every replica (current
    /// and future) with its slot index as the `replica` label. The
    /// cluster itself records replica lifecycle events and the live
    /// routable-replica gauge.
    pub fn set_telemetry(&mut self, tel: Telemetry) {
        self.telemetry = tel;
        for (i, e) in self.replicas.iter_mut().enumerate() {
            e.set_telemetry(self.telemetry.clone(), i);
        }
        self.telemetry.set_gauge("andes_replicas", &[], self.routable_count() as f64);
    }

    /// Enable or disable session-affinity routing (see
    /// [`Cluster::parked_replica`]).
    pub fn set_session_affinity(&mut self, on: bool) {
        self.session_affinity = on;
    }

    /// Whether session-affinity routing is enabled.
    pub fn session_affinity(&self) -> bool {
        self.session_affinity
    }

    pub fn num_replicas(&self) -> usize {
        self.replicas.len()
    }

    /// Read-only view of the replicas (gateway state snapshots).
    pub fn replicas(&self) -> &[Engine<SimBackend, VirtualClock>] {
        &self.replicas
    }

    /// Incrementally maintained active-request count per replica.
    pub fn active_counts(&self) -> &[usize] {
        &self.active
    }

    /// Whether replica `i` is draining (retired, finishing in-flight
    /// work).
    pub fn is_draining(&self, i: usize) -> bool {
        self.draining[i]
    }

    /// Replicas still accepting new routing.
    pub fn routable_count(&self) -> usize {
        self.draining.iter().filter(|&&d| !d).count()
    }

    /// When replica `i` finished draining (None while in service).
    pub fn decommissioned_time(&self, i: usize) -> Option<f64> {
        self.decommissioned_at[i]
    }

    /// Latest simulated time across replicas.
    pub fn now(&self) -> f64 {
        self.replicas.iter().map(|e| e.now()).fold(0.0, f64::max)
    }

    /// Commission a fresh replica at time `t`; returns its index. The
    /// caller (the gateway's autoscaler) models any cold-start delay —
    /// by the time this is called the replica is ready to serve.
    ///
    /// A fully drained slot is reused instead of growing the replica
    /// vector without bound under oscillating load; the retired
    /// replica's metrics and replica-seconds are preserved.
    pub fn add_replica(&mut self, t: f64) -> usize {
        let mut e = Engine::new(
            self.engine_cfg.clone(),
            SimBackend::new(self.latency.clone()),
            VirtualClock::default(),
            self.scheduler.build(),
            self.latency.clone(),
        );
        e.advance_clock_to(t);
        let reusable = (0..self.replicas.len()).find(|&i| {
            self.draining[i] && self.active[i] == 0 && self.decommissioned_at[i].is_some()
        });
        let slot = reusable.unwrap_or(self.replicas.len());
        e.set_telemetry(self.telemetry.clone(), slot);
        self.telemetry.inc("andes_replica_events_total", &[("action", "add")], 1.0);
        if let Some(i) = reusable {
            // lint:allow(D6, reusable slots are filtered on decommissioned_at.is_some())
            let retired = self.decommissioned_at[i].unwrap() - self.commissioned_at[i];
            self.retired_seconds += retired.max(0.0);
            self.retired_metrics.push(std::mem::take(self.replicas[i].metrics_mut()));
            self.replicas[i] = e;
            self.finished_seen[i] = 0;
            self.draining[i] = false;
            self.commissioned_at[i] = t;
            self.decommissioned_at[i] = None;
            self.telemetry.set_gauge("andes_replicas", &[], self.routable_count() as f64);
            return i;
        }
        self.replicas.push(e);
        self.active.push(0);
        self.finished_seen.push(0);
        self.draining.push(false);
        self.commissioned_at.push(t);
        self.decommissioned_at.push(None);
        self.telemetry.set_gauge("andes_replicas", &[], self.routable_count() as f64);
        self.replicas.len() - 1
    }

    /// Begin retiring replica `idx` at time `t`: it is removed from
    /// routing immediately and decommissions once its in-flight
    /// requests finish (graceful drain — nothing is dropped).
    pub fn retire_replica(&mut self, idx: usize, t: f64) {
        if self.draining[idx] {
            return;
        }
        self.draining[idx] = true;
        if self.active[idx] == 0 {
            self.decommissioned_at[idx] = Some(t.max(self.replicas[idx].now()));
        }
        self.telemetry.inc("andes_replica_events_total", &[("action", "retire")], 1.0);
        self.telemetry.set_gauge("andes_replicas", &[], self.routable_count() as f64);
    }

    /// Retire the least-loaded routable replica, keeping at least one
    /// routable. Returns the retired index.
    pub fn retire_least_loaded(&mut self, t: f64) -> Option<usize> {
        let routable: Vec<usize> =
            (0..self.replicas.len()).filter(|&i| !self.draining[i]).collect();
        if routable.len() <= 1 {
            return None;
        }
        let idx = routable.into_iter().min_by_key(|&i| self.active[i])?;
        self.retire_replica(idx, t);
        Some(idx)
    }

    /// Total replica-seconds consumed up to `t`: each replica is
    /// charged from commissioning until decommissioning (or `t` while
    /// still in service), plus the windows of retired replicas whose
    /// slots were reused — the run's resource-cost metric.
    pub fn replica_seconds(&self, t: f64) -> f64 {
        self.retired_seconds
            + (0..self.replicas.len())
                .map(|i| {
                    let end = self.decommissioned_at[i].unwrap_or(t).min(t);
                    (end - self.commissioned_at[i]).max(0.0)
                })
                .sum::<f64>()
    }

    /// Fold replica `i`'s newly observed finishes into its active count.
    fn sync_finished(&mut self, i: usize) {
        let fin = self.replicas[i].metrics().requests.len();
        let newly = fin - self.finished_seen[i];
        if newly > 0 {
            self.active[i] -= newly;
            self.finished_seen[i] = fin;
        }
        if self.draining[i] && self.active[i] == 0 && self.decommissioned_at[i].is_none()
        {
            self.decommissioned_at[i] = Some(self.replicas[i].now());
        }
    }

    /// Pick a replica under `policy` among routable (non-draining)
    /// replicas.
    fn route(&mut self, policy: RoutingPolicy) -> usize {
        let mut candidates: Vec<usize> =
            (0..self.replicas.len()).filter(|&i| !self.draining[i]).collect();
        if candidates.is_empty() {
            // Defensive: with everything draining, reactivate the
            // least-loaded replica rather than dropping the request —
            // and clear its decommission mark so the service it renders
            // from here on is charged to replica-seconds again (the
            // idle gap stays charged too; honest and conservative).
            // lint:allow(D6, a cluster always owns at least one replica)
            let idx = (0..self.replicas.len()).min_by_key(|&i| self.active[i]).unwrap();
            self.draining[idx] = false;
            self.decommissioned_at[idx] = None;
            candidates.push(idx);
        }
        match policy {
            RoutingPolicy::RoundRobin => {
                let idx = candidates[self.rr_next % candidates.len()];
                self.rr_next += 1;
                idx
            }
            RoutingPolicy::LeastLoaded => {
                // lint:allow(D6, candidates was made non-empty above)
                candidates.into_iter().min_by_key(|&i| self.active[i]).unwrap()
            }
            RoutingPolicy::QoeAware => {
                // Most free KV tokens per active request: replicas close
                // to memory saturation will degrade everyone's QoE when
                // given one more request.
                candidates
                    .into_iter()
                    .max_by(|&a, &b| {
                        let score = |i: usize| {
                            self.replicas[i].kv().device_free_tokens() as f64
                                / (self.active[i] + 1) as f64
                        };
                        score(a).total_cmp(&score(b))
                    })
                    // lint:allow(D6, candidates was made non-empty above)
                    .unwrap()
            }
        }
    }

    /// The replica with work whose clock lags furthest behind, ties
    /// broken toward the lower index. This is the cluster's next-event
    /// selection, but deliberately *not* on the event calendar
    /// (DESIGN.md §14): the lag is derived from live replica state that
    /// changes on every tick, so a registered wakeup would be stale the
    /// moment it was scheduled. A state scan each step is the
    /// deterministic choice here.
    fn next_lagging_replica(&self) -> Option<usize> {
        let mut best: Option<usize> = None;
        for i in 0..self.replicas.len() {
            if self.replicas[i].has_work() {
                best = match best {
                    Some(j) if self.replicas[j].now() <= self.replicas[i].now() => {
                        Some(j)
                    }
                    _ => Some(i),
                };
            }
        }
        best
    }

    /// Run the replica with work whose clock lags furthest behind
    /// through one engine iteration; returns its new time, or `None`
    /// when every replica is idle.
    pub fn step_once(&mut self) -> Result<Option<f64>> {
        match self.next_lagging_replica() {
            Some(i) => {
                self.replicas[i].tick()?;
                self.sync_finished(i);
                Ok(Some(self.replicas[i].now()))
            }
            None => Ok(None),
        }
    }

    /// The non-draining replica holding `session_id`'s parked KV
    /// prefix, if any. Usually that is unique (the replica that served
    /// the previous turn), but overlapping turns routed apart under
    /// overload can each park under the same key on different
    /// replicas; the longest prefix wins and the stale entry ages out
    /// of the other replica's pool via LRU eviction.
    pub fn parked_replica(&self, session_id: u64) -> Option<usize> {
        (0..self.replicas.len())
            .filter(|&i| !self.draining[i])
            .map(|i| (i, self.replicas[i].parked_prefix_tokens(session_id)))
            .filter(|&(_, tokens)| tokens > 0)
            .max_by_key(|&(i, tokens)| (tokens, std::cmp::Reverse(i)))
            .map(|(i, _)| i)
    }

    /// Route and submit one request; returns the chosen replica index.
    pub fn submit(&mut self, spec: RequestSpec) -> Result<usize> {
        self.submit_with_policy(spec, None)
    }

    /// Submit with an optional routing-policy override — the gateway's
    /// surge-aware routing hook. With session affinity enabled, a
    /// returning turn whose parked prefix survives on a routable
    /// replica is pinned there (a hit elsewhere is impossible: prefixes
    /// park where the previous turn ran); when that replica drained or
    /// the prefix was evicted, routing falls back to the policy as if
    /// the session were new.
    pub fn submit_with_policy(
        &mut self,
        spec: RequestSpec,
        policy: Option<RoutingPolicy>,
    ) -> Result<usize> {
        let affinity = if self.session_affinity {
            spec.session
                .filter(|s| s.is_returning())
                .and_then(|s| self.parked_replica(s.session_id))
        } else {
            None
        };
        let idx = match affinity {
            Some(i) => i,
            None => self.route(policy.unwrap_or(self.policy)),
        };
        self.replicas[idx].submit(spec)?;
        self.active[idx] += 1;
        Ok(idx)
    }

    /// Advance every replica's virtual clock to at least `t`, running
    /// any pending work on the way.
    pub fn advance_all_to(&mut self, t: f64) -> Result<()> {
        for i in 0..self.replicas.len() {
            {
                let e = &mut self.replicas[i];
                while e.has_work() && e.now() < t {
                    e.tick()?;
                }
                e.advance_clock_to(t);
            }
            self.sync_finished(i);
        }
        Ok(())
    }

    /// Finish all outstanding work and take the per-replica metrics.
    pub fn drain(&mut self) -> Result<Vec<Metrics>> {
        for i in 0..self.replicas.len() {
            {
                let e = &mut self.replicas[i];
                while e.has_work() {
                    e.tick()?;
                }
            }
            self.sync_finished(i);
        }
        // Taking the metrics resets each replica's finish history; keep
        // the incremental counters consistent with that.
        self.finished_seen.iter_mut().for_each(|f| *f = 0);
        let mut out: Vec<Metrics> = self
            .replicas
            .iter_mut()
            .map(|e| std::mem::take(e.metrics_mut()))
            .collect();
        // Requests served by retired replicas whose slots were reused.
        out.append(&mut self.retired_metrics);
        Ok(out)
    }

    /// Run a full trace through the cluster; returns per-replica metrics.
    pub fn run_trace(&mut self, mut trace: Vec<RequestSpec>) -> Result<Vec<Metrics>> {
        trace.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
        for spec in trace {
            // Bring the cluster's clocks up to the arrival instant so
            // routing sees current loads.
            self.advance_all_to(spec.arrival)?;
            self.submit(spec)?;
        }
        self.drain()
    }
}

/// Merge per-replica metrics into cluster-level aggregates.
pub fn merged_qoes(all: &[Metrics]) -> Vec<f64> {
    all.iter().flat_map(|m| m.qoes()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gpu::a100_4x;
    use crate::model::llm::opt_66b;
    use crate::qoe::spec::QoeSpec;
    use crate::util::stats::mean;
    use crate::workload::{ArrivalProcess, Dataset, QoeTrace, Workload};

    fn small_cluster(policy: RoutingPolicy, n: usize) -> Cluster {
        let latency = LatencyModel::for_deployment(&opt_66b(), &a100_4x());
        let cfg = EngineConfig {
            kv_capacity_tokens: 4000,
            swap_capacity_tokens: 8000,
            ..EngineConfig::default()
        };
        Cluster::new(n, cfg, latency, &SchedulerConfig::Fcfs, policy)
    }

    fn trace(n: usize, rate: f64, seed: u64) -> Vec<RequestSpec> {
        Workload {
            dataset: Dataset::ShareGpt,
            arrivals: ArrivalProcess::Poisson { rate },
            qoe_trace: QoeTrace::TextReading,
            num_requests: n,
            seed,
        }
        .generate()
    }

    #[test]
    fn all_requests_complete_across_replicas() {
        for policy in
            [RoutingPolicy::RoundRobin, RoutingPolicy::LeastLoaded, RoutingPolicy::QoeAware]
        {
            let mut c = small_cluster(policy, 3);
            let all = c.run_trace(trace(60, 3.0, 5)).unwrap();
            let total: usize = all.iter().map(|m| m.requests.len()).sum();
            assert_eq!(total, 60, "{}", policy.label());
        }
    }

    #[test]
    fn round_robin_spreads_evenly() {
        let mut c = small_cluster(RoutingPolicy::RoundRobin, 4);
        let all = c.run_trace(trace(80, 2.0, 6)).unwrap();
        for m in &all {
            assert_eq!(m.requests.len(), 20);
        }
    }

    #[test]
    fn least_loaded_balances_under_skew() {
        let mut c = small_cluster(RoutingPolicy::LeastLoaded, 2);
        let all = c.run_trace(trace(40, 4.0, 7)).unwrap();
        let counts: Vec<usize> = all.iter().map(|m| m.requests.len()).collect();
        let diff = counts[0].abs_diff(counts[1]);
        assert!(diff <= 8, "unbalanced: {counts:?}");
    }

    #[test]
    fn single_replica_cluster_matches_engine() {
        let mut c = small_cluster(RoutingPolicy::QoeAware, 1);
        let all = c.run_trace(trace(30, 2.0, 8)).unwrap();
        assert_eq!(all[0].requests.len(), 30);
        assert!(merged_qoes(&all).len() == 30);
    }

    #[test]
    fn incremental_counts_match_recount() {
        // The maintained active counts must equal a fresh scan at every
        // arrival instant.
        let mut c = small_cluster(RoutingPolicy::LeastLoaded, 3);
        let mut reqs = trace(50, 5.0, 9);
        reqs.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
        for spec in reqs {
            c.advance_all_to(spec.arrival).unwrap();
            c.submit(spec).unwrap();
            for (i, e) in c.replicas().iter().enumerate() {
                let scan = e.requests().iter().filter(|r| r.is_active()).count();
                assert_eq!(c.active_counts()[i], scan, "replica {i}");
            }
        }
        let all = c.drain().unwrap();
        assert_eq!(all.iter().map(|m| m.requests.len()).sum::<usize>(), 50);
        assert!(c.active_counts().iter().all(|&a| a == 0));
    }

    #[test]
    fn added_replica_receives_routing() {
        let mut c = small_cluster(RoutingPolicy::LeastLoaded, 1);
        // Load replica 0, then commission a second replica: the next
        // request must land on the fresh (empty) one.
        c.submit(RequestSpec {
            id: 0,
            arrival: 0.1,
            prompt_tokens: 200,
            output_tokens: 50,
            qoe: QoeSpec::new(1.0, 4.8),
            session: None,
        })
        .unwrap();
        let idx = c.add_replica(0.2);
        assert_eq!(idx, 1);
        assert_eq!(c.num_replicas(), 2);
        let routed = c
            .submit(RequestSpec {
                id: 1,
                arrival: 0.3,
                prompt_tokens: 200,
                output_tokens: 50,
                qoe: QoeSpec::new(1.0, 4.8),
                session: None,
            })
            .unwrap();
        assert_eq!(routed, 1, "new replica must take the next request");
        let all = c.drain().unwrap();
        assert_eq!(all.iter().map(|m| m.requests.len()).sum::<usize>(), 2);
    }

    #[test]
    fn retired_replica_drains_without_new_routing() {
        let mut c = small_cluster(RoutingPolicy::LeastLoaded, 2);
        let mk = |id: usize, arrival: f64| RequestSpec {
            id,
            arrival,
            prompt_tokens: 300,
            output_tokens: 60,
            qoe: QoeSpec::new(1.0, 4.8),
            session: None,
        };
        c.advance_all_to(0.1).unwrap();
        let first = c.submit(mk(0, 0.1)).unwrap();
        c.retire_replica(first, 0.2);
        assert!(c.is_draining(first));
        assert_eq!(c.routable_count(), 1);
        // Every subsequent request avoids the draining replica.
        for i in 1..6 {
            let r = c.submit(mk(i, 0.1 * (i + 1) as f64)).unwrap();
            assert_ne!(r, first, "routed onto a draining replica");
        }
        let all = c.drain().unwrap();
        // The in-flight request still finished (graceful drain).
        assert_eq!(all.iter().map(|m| m.requests.len()).sum::<usize>(), 6);
        assert_eq!(all[first].requests.len(), 1);
    }

    #[test]
    fn all_draining_fallback_reactivates_a_replica() {
        let mut c = small_cluster(RoutingPolicy::LeastLoaded, 1);
        c.retire_replica(0, 1.0);
        assert_eq!(c.routable_count(), 0);
        let idx = c
            .submit(RequestSpec {
                id: 0,
                arrival: 1.5,
                prompt_tokens: 100,
                output_tokens: 20,
                qoe: QoeSpec::new(1.0, 4.8),
                session: None,
            })
            .unwrap();
        assert_eq!(idx, 0);
        assert!(!c.is_draining(0), "fallback must un-retire the replica");
        // The cleared decommission mark means its service is charged to
        // replica-seconds again (idle gap included).
        assert!((c.replica_seconds(5.0) - 5.0).abs() < 1e-9);
        let all = c.drain().unwrap();
        assert_eq!(all[0].requests.len(), 1);
    }

    #[test]
    fn replica_seconds_charge_commission_to_decommission() {
        let mut c = small_cluster(RoutingPolicy::LeastLoaded, 1);
        // Static single replica: cost is 1 × elapsed.
        assert!((c.replica_seconds(10.0) - 10.0).abs() < 1e-9);
        // A replica commissioned at t=4 adds only its own in-service
        // window.
        c.add_replica(4.0);
        assert!((c.replica_seconds(10.0) - 16.0).abs() < 1e-9);
        // Retiring the idle second replica at t=6 caps its charge.
        c.retire_replica(1, 6.0);
        assert!((c.replica_seconds(10.0) - 12.0).abs() < 1e-9);
        // And the clamp: queries before decommission are unaffected.
        assert!((c.replica_seconds(5.0) - 6.0).abs() < 1e-9);
    }

    #[test]
    fn add_replica_reuses_drained_slots() {
        let mut c = small_cluster(RoutingPolicy::LeastLoaded, 2);
        let mk = |id: usize, arrival: f64| RequestSpec {
            id,
            arrival,
            prompt_tokens: 200,
            output_tokens: 30,
            qoe: QoeSpec::new(1.0, 4.8),
            session: None,
        };
        let first = c.submit(mk(0, 0.1)).unwrap();
        c.advance_all_to(30.0).unwrap(); // request finishes
        c.retire_replica(first, 30.0);
        assert!(c.decommissioned_time(first).is_some());
        // Commissioning again reuses the drained slot: the replica
        // vector stays bounded under oscillating load.
        let idx = c.add_replica(40.0);
        assert_eq!(idx, first);
        assert_eq!(c.num_replicas(), 2);
        assert!(!c.is_draining(first));
        // The retired window (0..30) is still charged, the reused slot
        // from 40, the untouched replica for the whole span.
        assert!((c.replica_seconds(50.0) - (30.0 + 10.0 + 50.0)).abs() < 1e-9);
        // And the retired replica's served request survives into drain.
        c.submit(mk(1, 40.0)).unwrap();
        let all = c.drain().unwrap();
        assert_eq!(all.len(), 3, "2 live slots + 1 retired metrics set");
        assert_eq!(all.iter().map(|m| m.requests.len()).sum::<usize>(), 2);
    }

    #[test]
    fn step_once_advances_lagging_replica() {
        let mut c = small_cluster(RoutingPolicy::RoundRobin, 2);
        assert!(c.step_once().unwrap().is_none(), "idle cluster has no events");
        c.advance_all_to(0.1).unwrap();
        c.submit(RequestSpec {
            id: 0,
            arrival: 0.1,
            prompt_tokens: 100,
            output_tokens: 30,
            qoe: QoeSpec::new(1.0, 4.8),
            session: None,
        })
        .unwrap();
        let t1 = c.step_once().unwrap().expect("busy replica must step");
        assert!(t1 > 0.1, "stepping must advance time");
        // Repeated stepping eventually drains the work.
        let mut guard = 0;
        while c.step_once().unwrap().is_some() {
            guard += 1;
            assert!(guard < 10_000, "step_once failed to make progress");
        }
        assert_eq!(c.active_counts(), &[0, 0]);
    }

    fn session_cluster(n: usize, policy: RoutingPolicy) -> Cluster {
        let latency = LatencyModel::for_deployment(&opt_66b(), &a100_4x());
        let cfg = EngineConfig {
            kv_capacity_tokens: 8000,
            swap_capacity_tokens: 16_000,
            park_prefixes: true,
            ..EngineConfig::default()
        };
        let mut c = Cluster::new(n, cfg, latency, &SchedulerConfig::Fcfs, policy);
        c.set_session_affinity(true);
        c
    }

    fn turn_spec(id: usize, arrival: f64, turn: usize, prefix: usize) -> RequestSpec {
        use crate::workload::SessionInfo;
        RequestSpec {
            id,
            arrival,
            prompt_tokens: prefix + 300,
            output_tokens: 40,
            qoe: QoeSpec::new(1.0, 4.8),
            session: Some(SessionInfo {
                session_id: 5,
                turn,
                turns_total: 3,
                prefix_tokens: prefix,
            }),
        }
    }

    #[test]
    fn session_affinity_routes_returning_turn_to_parked_replica() {
        let mut c = session_cluster(2, RoutingPolicy::RoundRobin);
        c.advance_all_to(0.1).unwrap();
        let first = c.submit(turn_spec(0, 0.1, 0, 0)).unwrap();
        // Let turn 0 finish and park its 340-token context.
        c.advance_all_to(60.0).unwrap();
        assert_eq!(c.parked_replica(5), Some(first));
        // Round-robin would pick the other replica next; affinity pins
        // the returning turn to the one holding the prefix.
        let routed = c.submit(turn_spec(1, 60.0, 1, 340)).unwrap();
        assert_eq!(routed, first, "returning turn must follow its parked prefix");
        let all = c.drain().unwrap();
        assert_eq!(all.iter().map(|m| m.requests.len()).sum::<usize>(), 2);
        assert_eq!(all[first].prefix_hits, 1, "the pinned replica served a hit");
    }

    #[test]
    fn session_affinity_falls_back_when_replica_drains() {
        let mut c = session_cluster(2, RoutingPolicy::LeastLoaded);
        c.advance_all_to(0.1).unwrap();
        let first = c.submit(turn_spec(0, 0.1, 0, 0)).unwrap();
        c.advance_all_to(60.0).unwrap();
        assert_eq!(c.parked_replica(5), Some(first));
        // The parking replica retires: its prefix is unreachable and
        // the returning turn must route elsewhere, served cold.
        c.retire_replica(first, 60.0);
        assert_eq!(c.parked_replica(5), None, "draining replica is not a target");
        let routed = c.submit(turn_spec(1, 60.0, 1, 340)).unwrap();
        assert_ne!(routed, first, "affinity must not route onto a draining replica");
        let all = c.drain().unwrap();
        assert_eq!(all.iter().map(|m| m.requests.len()).sum::<usize>(), 2);
        assert_eq!(all.iter().map(|m| m.prefix_hits).sum::<u64>(), 0, "cold fallback");
    }

    #[test]
    fn affinity_disabled_leaves_routing_untouched() {
        // Same scenario as the affinity test, affinity off: round-robin
        // sends the returning turn to the other replica (a miss).
        let mut c = session_cluster(2, RoutingPolicy::RoundRobin);
        c.set_session_affinity(false);
        c.advance_all_to(0.1).unwrap();
        let first = c.submit(turn_spec(0, 0.1, 0, 0)).unwrap();
        c.advance_all_to(60.0).unwrap();
        let routed = c.submit(turn_spec(1, 60.0, 1, 340)).unwrap();
        assert_ne!(routed, first, "round-robin must alternate with affinity off");
        let all = c.drain().unwrap();
        assert_eq!(all.iter().map(|m| m.prefix_hits).sum::<u64>(), 0);
    }

    #[test]
    fn qoe_aware_beats_round_robin_under_kv_skew() {
        // Parity-correlated sizes: every even-id request is KV-heavy, so
        // round-robin over 2 replicas lands all of them on replica 0 (the
        // classic hash-routing pathology). QoE-aware routing sees the
        // vanishing headroom and spreads the heavy requests.
        let latency = LatencyModel::for_deployment(&opt_66b(), &a100_4x());
        let cfg = EngineConfig {
            kv_capacity_tokens: 2000,
            swap_capacity_tokens: 8000,
            ..EngineConfig::default()
        };
        let make_trace = || -> Vec<RequestSpec> {
            (0..60)
                .map(|i| RequestSpec {
                    id: i,
                    arrival: 0.15 * (i + 1) as f64,
                    prompt_tokens: if i % 2 == 0 { 950 } else { 60 },
                    output_tokens: 120,
                    qoe: QoeSpec::new(1.0, 4.8),
                    session: None,
                })
                .collect()
        };
        let run = |policy: RoutingPolicy| {
            let mut c =
                Cluster::new(2, cfg.clone(), latency.clone(), &SchedulerConfig::Fcfs, policy);
            let all = c.run_trace(make_trace()).unwrap();
            assert_eq!(
                all.iter().map(|m| m.requests.len()).sum::<usize>(),
                60,
                "{} lost requests",
                policy.label()
            );
            mean(&merged_qoes(&all))
        };
        let rr = run(RoutingPolicy::RoundRobin);
        let qa = run(RoutingPolicy::QoeAware);
        assert!(qa > rr, "qoe-aware {qa:.3} must beat round-robin {rr:.3}");
    }
}
