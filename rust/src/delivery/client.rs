//! The client playback buffer: where tokens actually become QoE.
//!
//! Tokens arrive over the network in order; the client renders them at
//! the user's digestion speed. When the next token has not arrived by
//! the time playback wants it, the stream **stalls** — the visible
//! artifact jittery links inflict on text streaming. [`ClientBuffer`]
//! replays arrivals into a [`DigestState`] (so QoE is computed from
//! client-perceived times) and accounts stalls against the playback
//! cursor.
//!
//! Stall accounting: playback of token 0 starts at its arrival (TTFT
//! lateness is the QoE metric's domain, not a stall); token `i` is due
//! one digestion interval after token `i−1` started rendering. An
//! arrival past its due time is a stall of that length. Consequently,
//! stall time is exactly zero whenever the cumulative-arrival staircase
//! stays on or above the digestion ramp anchored at the first arrival —
//! the invariant the property tests pin.
//!
//! ```
//! use andes::delivery::ClientBuffer;
//! use andes::qoe::spec::QoeSpec;
//!
//! let spec = QoeSpec::new(1.0, 2.0); // digest at 2 tok/s
//! let mut buf = ClientBuffer::new(&spec);
//! for &t in &[1.0, 1.5, 2.0, 2.5] {
//!     buf.receive(t); // exactly on the digestion ramp
//! }
//! assert_eq!(buf.stall_time(), 0.0);
//! let mut late = ClientBuffer::new(&spec);
//! late.receive(1.0);
//! late.receive(3.0); // due at 1.5 → 1.5 s stall
//! assert_eq!(late.stall_count(), 1);
//! assert!((late.stall_time() - 1.5).abs() < 1e-12);
//! ```

use crate::qoe::metric::{qoe_finished, DigestState};
use crate::qoe::spec::QoeSpec;

/// Client-side receive buffer + playback cursor for one request.
#[derive(Debug, Clone)]
pub struct ClientBuffer {
    spec: QoeSpec,
    digest: DigestState,
    received: usize,
    /// Time the most recent token started rendering.
    last_render: f64,
    stall_count: usize,
    stall_time: f64,
    last_arrival: f64,
}

impl ClientBuffer {
    pub fn new(spec: &QoeSpec) -> Self {
        ClientBuffer {
            spec: *spec,
            digest: DigestState::new(spec),
            received: 0,
            last_render: f64::NEG_INFINITY,
            stall_count: 0,
            stall_time: 0.0,
            last_arrival: f64::NEG_INFINITY,
        }
    }

    /// Receive the next token at request-relative time `t`. Arrivals
    /// must be in order (the network model guarantees it); each token is
    /// replayed into the digestion state exactly once.
    pub fn receive(&mut self, t: f64) {
        debug_assert!(t >= self.last_arrival, "arrivals must be non-decreasing");
        self.last_arrival = t;
        if self.received == 0 {
            // First token: playback starts at arrival.
            self.last_render = t;
        } else {
            let due = self.last_render + 1.0 / self.spec.tds;
            if t > due + 1e-12 {
                self.stall_count += 1;
                self.stall_time += t - due;
                self.last_render = t;
            } else {
                self.last_render = due;
            }
        }
        self.digest.deliver(t);
        self.received += 1;
    }

    /// Tokens received so far.
    pub fn received(&self) -> usize {
        self.received
    }

    /// Number of playback stalls (distinct late arrivals).
    pub fn stall_count(&self) -> usize {
        self.stall_count
    }

    /// Total seconds playback spent waiting on late tokens.
    pub fn stall_time(&self) -> f64 {
        self.stall_time
    }

    /// The digestion state fed from client arrivals (read-only).
    pub fn digest(&self) -> &DigestState {
        &self.digest
    }

    /// Final client-perceived QoE once the stream is complete.
    /// `response_len` must equal the number of received tokens.
    pub fn final_qoe(&self, response_len: usize) -> f64 {
        qoe_finished(&self.spec, &self.digest, response_len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::assert_close;

    fn spec() -> QoeSpec {
        QoeSpec::new(1.0, 2.0)
    }

    #[test]
    fn on_time_stream_never_stalls() {
        let mut buf = ClientBuffer::new(&spec());
        for i in 0..20 {
            buf.receive(0.5 + i as f64 * 0.5);
        }
        assert_eq!(buf.stall_count(), 0);
        assert_eq!(buf.stall_time(), 0.0);
        assert_eq!(buf.received(), 20);
    }

    #[test]
    fn burst_then_gap_stalls_once() {
        let mut buf = ClientBuffer::new(&spec());
        // 4 tokens at t=1: playback covered until 1 + 3*0.5 = 2.5.
        for _ in 0..4 {
            buf.receive(1.0);
        }
        // Token 4 due at 3.0; arriving at 5.0 stalls for 2 s.
        buf.receive(5.0);
        assert_eq!(buf.stall_count(), 1);
        assert_close(buf.stall_time(), 2.0, 1e-12);
        // The next token rides the new cursor: due 5.5.
        buf.receive(5.4);
        assert_eq!(buf.stall_count(), 1);
    }

    #[test]
    fn late_first_token_is_not_a_stall() {
        // TTFT lateness is the QoE metric's business, not the stall
        // counter's.
        let mut buf = ClientBuffer::new(&spec());
        buf.receive(30.0);
        assert_eq!(buf.stall_count(), 0);
        assert!(buf.final_qoe(1) < 1.0, "late TTFT still costs QoE");
    }

    #[test]
    fn digestion_never_precedes_arrival() {
        let mut buf = ClientBuffer::new(&spec());
        for &t in &[1.0, 1.2, 4.0, 4.0, 9.0] {
            buf.receive(t);
            assert!(buf.digest().digested() <= buf.digest().delivered() + 1e-12);
            assert_eq!(buf.digest().delivered(), buf.received() as f64);
        }
    }

    #[test]
    fn perfect_delivery_perfect_qoe() {
        let sp = spec();
        let mut buf = ClientBuffer::new(&sp);
        for i in 0..10 {
            buf.receive(sp.ttft + i as f64 / sp.tds);
        }
        assert!(buf.final_qoe(10) > 0.99);
    }
}
