//! The last-mile network between the gateway pacer and the user's
//! device.
//!
//! The serving stack so far counts a token as *digested* the instant the
//! server releases it — an implicit perfect-network assumption. Real
//! delivery paths (wifi, cellular) add base latency, jitter, burst loss
//! with retransmission, and outright disconnect/reconnect episodes; all
//! of them move the client-perceived arrival curve that QoE is actually
//! defined on (Eloquent; DiSCo). [`NetworkModel`] simulates that path
//! per request, deterministically from a seed.
//!
//! The model is TCP-like: tokens arrive **in order** (a delayed token
//! head-of-line-blocks everything behind it), a lost token is
//! retransmitted after a timeout, and tokens released during a
//! disconnect episode are flushed at reconnect.
//!
//! ```
//! use andes::delivery::{NetworkModel, NetworkProfile};
//! use andes::util::rng::Rng;
//!
//! // An ideal link is the identity: arrival == release, no losses.
//! let mut net = NetworkModel::new(NetworkProfile::ideal(), Rng::new(7));
//! let t = net.send(1.0);
//! assert_eq!(t.arrived_at, 1.0);
//! assert_eq!(t.retransmits, 0);
//!
//! // A lossy link can only delay, never reorder or drop for good.
//! let mut net = NetworkModel::new(NetworkProfile::lte(), Rng::new(7));
//! let mut last = f64::NEG_INFINITY;
//! for i in 0..50 {
//!     let t = net.send(i as f64 * 0.2);
//!     assert!(t.arrived_at >= i as f64 * 0.2);
//!     assert!(t.arrived_at >= last, "in-order delivery");
//!     last = t.arrived_at;
//! }
//! assert_eq!(net.sent(), 50);
//! ```

use crate::util::rng::Rng;

/// Parameters of one last-mile link class. All times in seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetworkProfile {
    /// Profile name (as accepted by [`NetworkProfile::by_name`]).
    pub name: &'static str,
    /// Deterministic one-way propagation delay.
    pub base_latency: f64,
    /// Mean of the exponential per-token extra delay (0 = no jitter).
    pub jitter_mean: f64,
    /// Per-transmission loss probability (each retransmission re-rolls,
    /// so burst losses emerge geometrically).
    pub loss_prob: f64,
    /// Timeout before a lost transmission is retried.
    pub retransmit_delay: f64,
    /// Disconnect episodes per second of stream time (0 = never).
    pub disconnect_rate: f64,
    /// Mean duration of a disconnect episode (exponential).
    pub disconnect_mean: f64,
}

impl NetworkProfile {
    /// Zero-cost link: arrival == release. The parity anchor — the whole
    /// delivery layer must be bit-identical to no delivery layer at all
    /// under this profile.
    pub fn ideal() -> Self {
        NetworkProfile {
            name: "ideal",
            base_latency: 0.0,
            jitter_mean: 0.0,
            loss_prob: 0.0,
            retransmit_delay: 0.0,
            disconnect_rate: 0.0,
            disconnect_mean: 0.0,
        }
    }

    /// Wired broadband: a few milliseconds, effectively jitter-free.
    pub fn fiber() -> Self {
        NetworkProfile {
            name: "fiber",
            base_latency: 0.005,
            jitter_mean: 0.002,
            loss_prob: 0.0,
            retransmit_delay: 0.05,
            disconnect_rate: 0.0,
            disconnect_mean: 0.0,
        }
    }

    /// Home/office WLAN: moderate jitter, rare losses and dropouts.
    pub fn wifi() -> Self {
        NetworkProfile {
            name: "wifi",
            base_latency: 0.015,
            jitter_mean: 0.03,
            loss_prob: 0.005,
            retransmit_delay: 0.08,
            disconnect_rate: 1.0 / 300.0,
            disconnect_mean: 0.5,
        }
    }

    /// Mobile cellular: heavy jitter, burst loss, and disconnect
    /// episodes — the profile where the client buffer earns its keep.
    pub fn lte() -> Self {
        NetworkProfile {
            name: "lte",
            base_latency: 0.06,
            jitter_mean: 0.25,
            loss_prob: 0.02,
            retransmit_delay: 0.2,
            disconnect_rate: 1.0 / 45.0,
            disconnect_mean: 1.5,
        }
    }

    /// Look up a built-in profile by its name.
    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "ideal" => Some(Self::ideal()),
            "fiber" => Some(Self::fiber()),
            "wifi" => Some(Self::wifi()),
            "lte" => Some(Self::lte()),
            _ => None,
        }
    }

    /// True when the profile is exactly the identity link (every knob
    /// zero): the delivery layer adds nothing under it.
    pub fn is_identity(&self) -> bool {
        self.base_latency == 0.0
            && self.jitter_mean == 0.0
            && self.loss_prob == 0.0
            && self.disconnect_rate == 0.0
    }
}

/// Fate of one token on the wire (request-relative times).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TokenTransit {
    /// Server release time.
    pub sent_at: f64,
    /// End of the loss phase: `sent_at + retransmits × retransmit_delay`.
    /// Before this instant the token (if it was ever lost) is waiting on
    /// a retransmission, not in flight.
    pub lost_until: f64,
    /// Client arrival time (after in-order head-of-line blocking).
    pub arrived_at: f64,
    /// Failed transmission attempts before the one that got through.
    pub retransmits: usize,
    /// Seconds the token spent parked behind a disconnect episode.
    pub disconnect_wait: f64,
}

impl TokenTransit {
    /// Where this token is at time `t`: `None` = not yet sent,
    /// `Some(TokenState)` otherwise. The three live states partition
    /// `[sent_at, ∞)`, which is what the conservation property tests.
    pub fn state_at(&self, t: f64) -> Option<TokenState> {
        if t < self.sent_at {
            None
        } else if t >= self.arrived_at {
            Some(TokenState::Delivered)
        } else if t < self.lost_until {
            Some(TokenState::LostPendingRetransmit)
        } else {
            Some(TokenState::InFlight)
        }
    }
}

/// Mutually exclusive states of a sent token (see
/// [`TokenTransit::state_at`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokenState {
    InFlight,
    LostPendingRetransmit,
    Delivered,
}

/// Per-request simulated last-mile link. Deterministic given the profile
/// and the seed of its [`Rng`]; sends must use non-decreasing times.
#[derive(Debug, Clone)]
pub struct NetworkModel {
    profile: NetworkProfile,
    rng: Rng,
    /// In-order floor: no token may arrive before its predecessor.
    last_arrival: f64,
    /// Current/next disconnect episode window, drawn lazily.
    episode_start: f64,
    episode_end: f64,
    transits: Vec<TokenTransit>,
    retransmits_total: usize,
    disconnects_hit: usize,
}

/// Retransmission attempts are capped so a pathological RNG stream
/// cannot stall a request forever (the cap is far beyond anything the
/// built-in loss probabilities reach in practice).
const MAX_RETRANSMITS: usize = 16;

impl NetworkModel {
    pub fn new(profile: NetworkProfile, mut rng: Rng) -> Self {
        let (episode_start, episode_end) = if profile.disconnect_rate > 0.0 {
            let start = rng.exponential(profile.disconnect_rate);
            let dur = rng.exponential(1.0 / profile.disconnect_mean.max(1e-9));
            (start, start + dur)
        } else {
            (f64::INFINITY, f64::INFINITY)
        };
        NetworkModel {
            profile,
            rng,
            last_arrival: f64::NEG_INFINITY,
            episode_start,
            episode_end,
            transits: Vec::new(),
            retransmits_total: 0,
            disconnects_hit: 0,
        }
    }

    pub fn profile(&self) -> &NetworkProfile {
        &self.profile
    }

    /// Transmit a token released by the server at time `t` (must be
    /// ≥ every earlier send) and return its fate.
    pub fn send(&mut self, t: f64) -> TokenTransit {
        if let Some(prev) = self.transits.last() {
            debug_assert!(t >= prev.sent_at, "sends must be in release order");
        }
        // Loss phase: each attempt re-rolls; a loss costs one timeout.
        let mut retransmits = 0usize;
        while self.profile.loss_prob > 0.0
            && retransmits < MAX_RETRANSMITS
            && self.rng.chance(self.profile.loss_prob)
        {
            retransmits += 1;
        }
        let lost_until = t + retransmits as f64 * self.profile.retransmit_delay;
        // Wire phase: propagation plus exponential jitter.
        let jitter = if self.profile.jitter_mean > 0.0 {
            self.rng.exponential(1.0 / self.profile.jitter_mean)
        } else {
            0.0
        };
        let raw = lost_until + self.profile.base_latency + jitter;
        // Disconnect phase: an arrival falling inside an episode waits
        // for the reconnect and flushes then.
        let after_disc = self.hold_for_disconnect(raw);
        let disconnect_wait = after_disc - raw;
        if disconnect_wait > 0.0 {
            self.disconnects_hit += 1;
        }
        // In-order floor (head-of-line blocking).
        let arrived_at = after_disc.max(self.last_arrival).max(t);
        self.last_arrival = arrived_at;
        self.retransmits_total += retransmits;
        let transit =
            TokenTransit { sent_at: t, lost_until, arrived_at, retransmits, disconnect_wait };
        self.transits.push(transit);
        transit
    }

    /// Push `t` past any disconnect episode it falls into, advancing the
    /// lazily drawn episode timeline. Callers present non-decreasing
    /// probe times (guaranteed by the in-order send contract plus the
    /// monotone floor).
    fn hold_for_disconnect(&mut self, t: f64) -> f64 {
        if self.profile.disconnect_rate <= 0.0 {
            return t;
        }
        let mut t = t;
        while t >= self.episode_start {
            if t < self.episode_end {
                t = self.episode_end;
            }
            // Past this episode: draw the next one.
            let gap = self.rng.exponential(self.profile.disconnect_rate);
            let dur = self.rng.exponential(1.0 / self.profile.disconnect_mean.max(1e-9));
            self.episode_start = self.episode_end + gap;
            self.episode_end = self.episode_start + dur;
        }
        t
    }

    /// Every token's recorded fate, in send order.
    pub fn transits(&self) -> &[TokenTransit] {
        &self.transits
    }

    pub fn sent(&self) -> usize {
        self.transits.len()
    }

    pub fn retransmits(&self) -> usize {
        self.retransmits_total
    }

    /// Tokens that waited out at least one disconnect episode.
    pub fn disconnects_hit(&self) -> usize {
        self.disconnects_hit
    }

    /// (delivered, in_flight, lost_pending) token counts at time `t` —
    /// the conservation partition: the three always sum to the number
    /// of tokens sent by `t`.
    pub fn census_at(&self, t: f64) -> (usize, usize, usize) {
        let mut delivered = 0;
        let mut in_flight = 0;
        let mut lost = 0;
        for tr in &self.transits {
            match tr.state_at(t) {
                Some(TokenState::Delivered) => delivered += 1,
                Some(TokenState::InFlight) => in_flight += 1,
                Some(TokenState::LostPendingRetransmit) => lost += 1,
                None => {}
            }
        }
        (delivered, in_flight, lost)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_link_is_identity() {
        let mut net = NetworkModel::new(NetworkProfile::ideal(), Rng::new(1));
        for i in 0..20 {
            let t = i as f64 * 0.1;
            let tr = net.send(t);
            assert_eq!(tr.arrived_at, t);
            assert_eq!(tr.retransmits, 0);
            assert_eq!(tr.disconnect_wait, 0.0);
        }
        assert_eq!(net.retransmits(), 0);
        assert_eq!(net.disconnects_hit(), 0);
    }

    #[test]
    fn deterministic_from_seed() {
        let run = |seed| {
            let mut net = NetworkModel::new(NetworkProfile::lte(), Rng::new(seed));
            (0..200).map(|i| net.send(i as f64 * 0.2).arrived_at).collect::<Vec<_>>()
        };
        assert_eq!(run(9), run(9));
        assert_ne!(run(9), run(10));
    }

    #[test]
    fn in_order_and_never_early() {
        let mut net = NetworkModel::new(NetworkProfile::lte(), Rng::new(3));
        let mut last = f64::NEG_INFINITY;
        for i in 0..300 {
            let t = i as f64 * 0.15;
            let tr = net.send(t);
            assert!(tr.arrived_at >= t, "token arrived before release");
            assert!(tr.arrived_at >= last, "reordered delivery");
            assert!(tr.lost_until >= tr.sent_at);
            assert!(tr.arrived_at >= tr.lost_until);
            last = tr.arrived_at;
        }
    }

    #[test]
    fn lossy_link_retransmits() {
        let profile = NetworkProfile { loss_prob: 0.4, ..NetworkProfile::lte() };
        let mut net = NetworkModel::new(profile, Rng::new(5));
        for i in 0..200 {
            net.send(i as f64 * 0.1);
        }
        assert!(net.retransmits() > 10, "40% loss must retransmit often");
        // A retransmitted token pays at least one timeout.
        for tr in net.transits() {
            if tr.retransmits > 0 {
                assert!(tr.arrived_at - tr.sent_at >= profile.retransmit_delay - 1e-12);
            }
        }
    }

    #[test]
    fn disconnect_episode_flushes_at_reconnect() {
        // Very frequent, long episodes: most tokens flush together at
        // reconnect boundaries with zero inter-arrival gap.
        let profile = NetworkProfile {
            disconnect_rate: 1.0,
            disconnect_mean: 2.0,
            jitter_mean: 0.0,
            loss_prob: 0.0,
            base_latency: 0.0,
            ..NetworkProfile::lte()
        };
        let mut net = NetworkModel::new(profile, Rng::new(11));
        let arrivals: Vec<f64> = (0..100).map(|i| net.send(i as f64 * 0.1).arrived_at).collect();
        assert!(net.disconnects_hit() > 0, "episodes must be hit");
        let flushes = arrivals.windows(2).filter(|w| w[1] == w[0]).count();
        assert!(flushes > 0, "reconnect must flush a burst");
    }

    #[test]
    fn census_partitions_sent_tokens() {
        let mut net = NetworkModel::new(NetworkProfile::lte(), Rng::new(17));
        for i in 0..100 {
            net.send(i as f64 * 0.2);
        }
        for probe in [0.0, 1.0, 5.0, 10.0, 19.9, 25.0, 1000.0] {
            let sent_by_probe =
                net.transits().iter().filter(|tr| tr.sent_at <= probe).count();
            let (d, f, l) = net.census_at(probe);
            assert_eq!(d + f + l, sent_by_probe, "partition at t={probe}");
        }
        let (d, f, l) = net.census_at(f64::INFINITY);
        assert_eq!((d, f, l), (100, 0, 0), "everything eventually delivers");
    }

    #[test]
    fn profiles_by_name() {
        for name in ["ideal", "fiber", "wifi", "lte"] {
            assert_eq!(NetworkProfile::by_name(name).unwrap().name, name);
        }
        assert!(NetworkProfile::by_name("carrier-pigeon").is_none());
        assert!(NetworkProfile::ideal().is_identity());
        assert!(!NetworkProfile::wifi().is_identity());
    }
}
