//! Jitter-adaptive lead control for the gateway pacer.
//!
//! The static pacer lets a fixed `lead_tokens` through unpaced so the
//! client holds a small reserve against network jitter. A fixed lead is
//! wrong in both directions: wasteful on fiber, hopeless on cellular.
//! Eloquent's insight is to size the reserve from *observed* delivery
//! jitter: the server watches per-token acknowledgement times, keeps an
//! RFC 6298-style EWMA of the transit-time mean and deviation, and grows
//! the lead so the client buffers roughly `headroom × deviation` seconds
//! of playback.
//!
//! Control law (DESIGN.md §11):
//!
//! ```text
//! dev  ← (1−β)·dev + β·|x − mean|      (β = dev_alpha)
//! mean ← (1−α)·mean + α·x              (α = mean_alpha)
//! lead  = base_lead + ⌈dev × headroom × TDS⌉, clamped to max_lead
//! ```
//!
//! The first sample initializes `mean = x`, `dev = x/2` (as RFC 6298
//! seeds RTTVAR), so the controller reacts within a handful of tokens.
//! With zero observed jitter the lead equals the static `base_lead`
//! exactly — the adaptive mode is a strict generalization.
//!
//! ```
//! use andes::delivery::{AdaptiveLead, AdaptiveLeadConfig};
//!
//! let mut ctl = AdaptiveLead::new(AdaptiveLeadConfig::default(), 4, 4.8);
//! assert_eq!(ctl.lead(), 4); // nothing observed yet: static behavior
//! for _ in 0..8 {
//!     ctl.observe(0.05); // steady transit → deviation decays toward 0
//! }
//! assert!(ctl.lead() <= 5); // at most one token of residual slack
//! for x in [0.05, 0.9, 0.1, 1.2] {
//!     ctl.observe(x); // jittery link
//! }
//! assert!(ctl.lead() > 4, "observed jitter must grow the lead");
//! ```

/// Tuning knobs of the adaptive-lead controller.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdaptiveLeadConfig {
    /// EWMA gain for the transit-time mean (RFC 6298 SRTT gain).
    pub mean_alpha: f64,
    /// EWMA gain for the transit-time deviation (RFC 6298 RTTVAR gain).
    pub dev_alpha: f64,
    /// Seconds of playback the lead should cover per second of observed
    /// deviation (the safety multiplier).
    pub headroom: f64,
    /// Hard cap on the adaptive lead, bounding how much of the paced
    /// surplus the controller may hand back to the wire.
    pub max_lead: usize,
}

impl Default for AdaptiveLeadConfig {
    fn default() -> Self {
        AdaptiveLeadConfig { mean_alpha: 0.125, dev_alpha: 0.25, headroom: 4.0, max_lead: 64 }
    }
}

/// EWMA state of the controller for one request.
#[derive(Debug, Clone)]
pub struct AdaptiveLead {
    cfg: AdaptiveLeadConfig,
    base_lead: usize,
    tds: f64,
    mean: Option<f64>,
    dev: f64,
}

impl AdaptiveLead {
    /// `base_lead` is the static `lead_tokens` floor; `tds` the
    /// request's digestion speed (tokens/s).
    pub fn new(cfg: AdaptiveLeadConfig, base_lead: usize, tds: f64) -> Self {
        assert!(tds > 0.0, "tds must be positive");
        AdaptiveLead { cfg, base_lead, tds, mean: None, dev: 0.0 }
    }

    /// Feed one acknowledged token's transit time (seconds from release
    /// to client arrival, as observed via its ack).
    pub fn observe(&mut self, transit: f64) {
        match self.mean {
            None => {
                self.mean = Some(transit);
                self.dev = transit / 2.0;
            }
            Some(m) => {
                let (a, b) = (self.cfg.mean_alpha, self.cfg.dev_alpha);
                self.dev = (1.0 - b) * self.dev + b * (transit - m).abs();
                self.mean = Some((1.0 - a) * m + a * transit);
            }
        }
    }

    /// EWMA of the transit-time deviation (seconds).
    pub fn deviation(&self) -> f64 {
        self.dev
    }

    /// Current lead-token target.
    pub fn lead(&self) -> usize {
        let extra = (self.dev * self.cfg.headroom * self.tds).ceil() as usize;
        (self.base_lead + extra).min(self.cfg.max_lead.max(self.base_lead))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_jitter_keeps_static_lead() {
        let mut ctl = AdaptiveLead::new(AdaptiveLeadConfig::default(), 4, 4.8);
        for _ in 0..100 {
            ctl.observe(0.02);
        }
        // After the first-sample seed decays, steady transit → base lead.
        assert!(ctl.lead() <= 5, "steady link grew the lead to {}", ctl.lead());
        // An exactly-zero transit stream never leaves the base.
        let mut zero = AdaptiveLead::new(AdaptiveLeadConfig::default(), 4, 4.8);
        for _ in 0..10 {
            zero.observe(0.0);
        }
        assert_eq!(zero.lead(), 4);
    }

    #[test]
    fn jitter_grows_lead_and_cap_binds() {
        let cfg = AdaptiveLeadConfig { max_lead: 10, ..AdaptiveLeadConfig::default() };
        let mut ctl = AdaptiveLead::new(cfg, 4, 4.8);
        for i in 0..50 {
            ctl.observe(if i % 2 == 0 { 0.05 } else { 2.0 });
        }
        assert_eq!(ctl.lead(), 10, "heavy jitter must saturate the cap");
        // The cap can never undercut the static base.
        let tight = AdaptiveLeadConfig { max_lead: 2, ..AdaptiveLeadConfig::default() };
        let ctl = AdaptiveLead::new(tight, 4, 4.8);
        assert_eq!(ctl.lead(), 4);
    }

    #[test]
    fn adapts_within_a_few_samples() {
        let mut ctl = AdaptiveLead::new(AdaptiveLeadConfig::default(), 4, 4.8);
        ctl.observe(0.5); // one jittery sample seeds dev = 0.25
        assert!(ctl.lead() > 4, "first-sample seed must already react");
    }
}
