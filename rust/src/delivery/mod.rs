//! Client-side delivery: the network and playback layer between the
//! gateway pacer and the QoE metric (DESIGN.md §11).
//!
//! Andes defines QoE on the *user's* perceived timeline, but the rest of
//! the stack stops at the server: a paced token counts as digested the
//! instant it is released. This module closes the gap with three pieces:
//!
//! - [`network`] — a per-request, seeded last-mile link model (latency,
//!   jitter, burst loss with retransmission, disconnect/reconnect
//!   episodes), TCP-like in-order delivery;
//! - [`client`] — the client playback buffer, replaying arrivals into
//!   the digestion state so QoE is computed from client-perceived
//!   times, and accounting playback stalls;
//! - [`adaptive`] — an Eloquent-style jitter-adaptive mode of the
//!   gateway pacer that grows its lead buffer from an EWMA of observed
//!   ack jitter instead of a static `lead_tokens`.
//!
//! [`deliver_request`] runs all three jointly for one finished request:
//! the pacer releases tokens (its lead possibly adapting to acks the
//! server has seen so far), the network carries them, the client buffer
//! replays them. With the layer disabled — or under the explicit
//! [`NetworkProfile::ideal`] link — the result is bit-identical to the
//! pacer-only path (property-tested in `rust/tests/delivery.rs`).
//!
//! ```
//! use andes::delivery::{deliver_request, NetworkConfig, NetworkProfile};
//! use andes::gateway::PacingConfig;
//! use andes::qoe::spec::QoeSpec;
//!
//! let spec = QoeSpec::new(1.0, 4.0);
//! let pacing = PacingConfig { rate_factor: 1.0, lead_tokens: 2 };
//! let gen: Vec<f64> = vec![1.0; 12]; // a 12-token burst at t=1
//!
//! // The ideal link adds nothing: arrivals == paced releases, no stalls.
//! let ideal = NetworkConfig { enabled: true, ..NetworkConfig::default() }
//!     .with_mix(vec![(NetworkProfile::ideal(), 1.0)]);
//! let out = deliver_request(&spec, true, &pacing, &ideal, 0, &gen);
//! assert_eq!(out.client_arrivals, out.release_times);
//! assert_eq!(out.stall_count, 0);
//!
//! // A cellular link delays and may stall; QoE can only drop.
//! let lte = ideal.clone().with_mix(vec![(NetworkProfile::lte(), 1.0)]);
//! let rough = deliver_request(&spec, true, &pacing, &lte, 0, &gen);
//! assert!(rough.client_qoe <= out.client_qoe + 1e-12);
//! ```

pub mod adaptive;
pub mod client;
pub mod network;

pub use adaptive::{AdaptiveLead, AdaptiveLeadConfig};
pub use client::ClientBuffer;
pub use network::{NetworkModel, NetworkProfile, TokenState, TokenTransit};

use std::collections::VecDeque;

use anyhow::{bail, Context, Result};

use crate::coordinator::calendar::{EventCalendar, EventKind};
use crate::gateway::pacing::{PacingConfig, TokenPacer};
use crate::qoe::spec::QoeSpec;
use crate::util::rng::{splitmix64, Rng};

/// The gateway's `"network"` section: which last-mile links requests
/// ride, and whether the pacer lead adapts to observed jitter.
#[derive(Debug, Clone)]
pub struct NetworkConfig {
    /// Master switch. Off (the default) keeps every downstream number
    /// bit-identical to the pacer-only path.
    pub enabled: bool,
    /// Link-class mix: each request draws one profile, weighted.
    pub mix: Vec<(NetworkProfile, f64)>,
    /// Grow the pacer lead from observed ack jitter (Eloquent-style)
    /// instead of keeping the static `lead_tokens`.
    pub adaptive_lead: bool,
    pub adaptive: AdaptiveLeadConfig,
    /// Root seed for per-request link draws; combined with the request
    /// id so each "user" gets an independent, reproducible link.
    pub seed: u64,
    /// Drain acks from the legacy in-order scan instead of the event
    /// calendar (DESIGN.md §14). Both paths are bit-identical; the
    /// toggle exists for the step-vs-calendar parity suite.
    pub legacy_stepping: bool,
}

impl Default for NetworkConfig {
    fn default() -> Self {
        NetworkConfig {
            enabled: false,
            mix: vec![(NetworkProfile::fiber(), 1.0)],
            adaptive_lead: false,
            adaptive: AdaptiveLeadConfig::default(),
            seed: 0xA11D_E500,
            legacy_stepping: false,
        }
    }
}

impl NetworkConfig {
    /// Builder-style mix override (used by tests and experiments).
    pub fn with_mix(mut self, mix: Vec<(NetworkProfile, f64)>) -> Self {
        self.mix = mix;
        self
    }

    /// Parse a CLI mix spec: either one profile name (`"lte"`) or a
    /// weighted list (`"fiber:0.6,wifi:0.3,lte:0.1"`).
    ///
    /// ```
    /// use andes::delivery::NetworkConfig;
    /// let mix = NetworkConfig::parse_mix("fiber:0.6,lte:0.4").unwrap();
    /// assert_eq!(mix.len(), 2);
    /// assert_eq!(mix[0].0.name, "fiber");
    /// assert!(NetworkConfig::parse_mix("warp-drive").is_err());
    /// ```
    pub fn parse_mix(s: &str) -> Result<Vec<(NetworkProfile, f64)>> {
        let mut mix = Vec::new();
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (name, weight) = match part.split_once(':') {
                Some((n, w)) => {
                    let w: f64 = w
                        .trim()
                        .parse()
                        .with_context(|| format!("bad mix weight in '{part}'"))?;
                    (n.trim(), w)
                }
                None => (part, 1.0),
            };
            let profile = NetworkProfile::by_name(name).with_context(|| {
                format!("unknown network profile '{name}' (ideal|fiber|wifi|lte)")
            })?;
            if !weight.is_finite() || weight <= 0.0 {
                bail!("network mix weight for '{name}' must be positive and finite");
            }
            mix.push((profile, weight));
        }
        if mix.is_empty() {
            bail!("empty network mix");
        }
        Ok(mix)
    }

    /// Expected one-way transit of the configured mix: the mix-weighted
    /// mean base latency when the layer is on, 0.0 when it is off. This
    /// is the slack estimator's transit term (DESIGN.md §15) — a cheap
    /// first moment, deliberately ignoring jitter/loss tails.
    pub fn expected_transit(&self) -> f64 {
        if !self.enabled {
            return 0.0;
        }
        let total: f64 = self.mix.iter().map(|(_, w)| *w).sum();
        if total <= 0.0 {
            return 0.0;
        }
        let weighted: f64 =
            self.mix.iter().map(|(p, w)| p.base_latency * w).sum();
        weighted / total
    }

    /// Deterministically draw the link for one request: profile chosen
    /// from the mix, plus the RNG that will drive its jitter/loss/
    /// disconnect streams. Depends only on `(seed, request_id)`, so a
    /// request keeps its "user's" link across replays (e.g. a spill).
    pub fn draw_for(&self, request_id: usize) -> (NetworkProfile, Rng) {
        let mut state = self.seed ^ (request_id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(splitmix64(&mut state));
        let weights: Vec<f64> = self.mix.iter().map(|(_, w)| *w).collect();
        let idx = rng.categorical(&weights);
        (self.mix[idx].0, rng)
    }
}

/// One request's delivery-layer outcome (all times request-relative).
#[derive(Debug, Clone)]
pub struct DeliveryOutcome {
    /// Server-side release times (post-pacing; the adaptive lead may
    /// burst extra unpaced tokens after jitter is observed).
    pub release_times: Vec<f64>,
    /// Client-side arrival times (in order, one per token).
    pub client_arrivals: Vec<f64>,
    /// Final QoE computed from the client arrivals.
    pub client_qoe: f64,
    pub stall_count: usize,
    pub stall_time: f64,
    pub retransmits: usize,
    /// Tokens that waited out a disconnect episode.
    pub disconnects: usize,
    /// The pacer's lead at end of stream (== `lead_tokens` when the
    /// adaptive mode is off or nothing jittered; 0 with pacing
    /// disabled).
    pub final_lead: usize,
}

/// Jointly simulate pacer → network → client buffer for one finished
/// request.
///
/// * `gen_times` — request-relative token generation times, as recorded
///   by the engine (non-decreasing).
/// * `pacing_enabled: false` sends tokens as generated (the network
///   still applies).
///
/// Adaptive-lead causality: before releasing token *i*, the controller
/// only sees acks that reached the server by the earliest instant token
/// *i* could release (`max(generated, last_release)`) — the server
/// never peeks at the future.
pub fn deliver_request(
    spec: &QoeSpec,
    pacing_enabled: bool,
    pacing: &PacingConfig,
    cfg: &NetworkConfig,
    request_id: usize,
    gen_times: &[f64],
) -> DeliveryOutcome {
    let (profile, rng) = cfg.draw_for(request_id);
    let mut pacer = if pacing_enabled {
        TokenPacer::new(spec, pacing)
    } else {
        TokenPacer::passthrough()
    };
    let mut controller = (pacing_enabled && cfg.adaptive_lead)
        .then(|| AdaptiveLead::new(cfg.adaptive, pacing.lead_tokens, spec.tds));
    let mut net = NetworkModel::new(profile, rng);
    let mut client = ClientBuffer::new(spec);
    // (ack arrival at server, observed transit) for sent tokens; acks
    // ride the deterministic return path, so they stay in send order.
    // `acks` serves the legacy path; the calendar mirrors it
    // event-for-event (the observed transit travels in the payload
    // bits), so draining either structure observes identical values.
    let mut acks: VecDeque<(f64, f64)> = VecDeque::new();
    let mut ack_calendar = EventCalendar::new();
    let mut releases = Vec::with_capacity(gen_times.len());
    let mut arrivals = Vec::with_capacity(gen_times.len());
    for &g in gen_times {
        if let Some(ctl) = controller.as_mut() {
            let horizon = g.max(pacer.last_release());
            if cfg.legacy_stepping {
                while let Some(&(ack_at, transit)) = acks.front() {
                    if ack_at > horizon {
                        break;
                    }
                    ctl.observe(transit);
                    acks.pop_front();
                }
            } else {
                while ack_calendar.peek().is_some_and(|w| w.time <= horizon) {
                    // lint:allow(D6, peek() just returned a due wakeup)
                    let w = ack_calendar.pop().unwrap();
                    ctl.observe(f64::from_bits(w.payload));
                }
            }
            pacer.set_lead(ctl.lead());
        }
        pacer.push(g);
        // lint:allow(D6, push() one line up makes the pacer non-empty)
        let due = pacer.next_due().expect("token just pushed");
        let released = pacer.release_due(due);
        debug_assert_eq!(released, 1, "exactly the pushed token releases at its due time");
        let transit = net.send(due);
        client.receive(transit.arrived_at);
        let ack_at = transit.arrived_at + profile.base_latency;
        let observed = transit.arrived_at - due;
        if cfg.legacy_stepping {
            acks.push_back((ack_at, observed));
        } else {
            ack_calendar.register(ack_at, EventKind::DeliveryAck, observed.to_bits());
        }
        releases.push(due);
        arrivals.push(transit.arrived_at);
    }
    DeliveryOutcome {
        client_qoe: client.final_qoe(arrivals.len()),
        release_times: releases,
        client_arrivals: arrivals,
        stall_count: client.stall_count(),
        stall_time: client.stall_time(),
        retransmits: net.retransmits(),
        disconnects: net.disconnects_hit(),
        // The passthrough pacer's "lead" is a sentinel ∞ — report 0.
        final_lead: if pacing_enabled { pacer.lead() } else { 0 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gateway::pacing::pace_times;

    fn spec() -> QoeSpec {
        QoeSpec::new(1.0, 4.0)
    }

    fn cfg_with(profile: NetworkProfile) -> NetworkConfig {
        NetworkConfig { enabled: true, ..NetworkConfig::default() }
            .with_mix(vec![(profile, 1.0)])
    }

    #[test]
    fn ideal_static_matches_batch_pacer_exactly() {
        // Under the identity link with the adaptive mode off, releases
        // must equal `pace_times` and arrivals must equal releases.
        let sp = spec();
        let pacing = PacingConfig { rate_factor: 1.0, lead_tokens: 3 };
        let gen: Vec<f64> = vec![0.5, 0.5, 0.5, 0.5, 0.9, 2.0, 2.0, 5.0];
        let out =
            deliver_request(&sp, true, &pacing, &cfg_with(NetworkProfile::ideal()), 7, &gen);
        assert_eq!(out.release_times, pace_times(&sp, &pacing, &gen));
        assert_eq!(out.client_arrivals, out.release_times);
        assert_eq!(out.stall_count, 0);
        assert_eq!(out.retransmits, 0);
        assert_eq!(out.final_lead, 3);
    }

    #[test]
    fn adaptive_on_ideal_link_stays_static() {
        // Zero observed jitter ⇒ the controller never leaves the base
        // lead, so adaptive and static schedules coincide.
        let sp = spec();
        let pacing = PacingConfig::default();
        let gen: Vec<f64> = (0..30).map(|i| 0.3 + 0.05 * i as f64).collect();
        let mut cfg = cfg_with(NetworkProfile::ideal());
        let static_out = deliver_request(&sp, true, &pacing, &cfg, 3, &gen);
        cfg.adaptive_lead = true;
        let adaptive_out = deliver_request(&sp, true, &pacing, &cfg, 3, &gen);
        assert_eq!(static_out.release_times, adaptive_out.release_times);
        assert_eq!(static_out.client_arrivals, adaptive_out.client_arrivals);
        assert_eq!(adaptive_out.final_lead, pacing.lead_tokens);
    }

    #[test]
    fn adaptive_lead_grows_under_jitter() {
        let sp = spec();
        let pacing = PacingConfig { rate_factor: 1.0, lead_tokens: 4 };
        let mut cfg = cfg_with(NetworkProfile::lte());
        cfg.adaptive_lead = true;
        let gen: Vec<f64> = vec![0.5; 120];
        let out = deliver_request(&sp, true, &pacing, &cfg, 11, &gen);
        assert!(out.final_lead > pacing.lead_tokens, "lte jitter must grow the lead");
    }

    #[test]
    fn mix_draw_is_deterministic_per_request() {
        let cfg = NetworkConfig { enabled: true, ..NetworkConfig::default() }.with_mix(
            vec![
                (NetworkProfile::fiber(), 0.5),
                (NetworkProfile::wifi(), 0.3),
                (NetworkProfile::lte(), 0.2),
            ],
        );
        let mut seen_lte = false;
        for id in 0..200 {
            let (a, _) = cfg.draw_for(id);
            let (b, _) = cfg.draw_for(id);
            assert_eq!(a, b, "request {id} must redraw the same link");
            seen_lte |= a.name == "lte";
        }
        assert!(seen_lte, "a 20% share must appear in 200 draws");
        // A different root seed reshuffles the assignment.
        let reseeded = NetworkConfig { seed: 99, ..cfg.clone() };
        let moved = (0..200)
            .filter(|&id| cfg.draw_for(id).0 != reseeded.draw_for(id).0)
            .count();
        assert!(moved > 0);
    }

    #[test]
    fn pacing_disabled_sends_as_generated() {
        let sp = spec();
        let gen: Vec<f64> = vec![0.2, 0.4, 0.6, 0.8];
        let out = deliver_request(
            &sp,
            false,
            &PacingConfig::default(),
            &cfg_with(NetworkProfile::ideal()),
            0,
            &gen,
        );
        assert_eq!(out.release_times, gen);
        assert_eq!(out.client_arrivals, gen);
    }

    #[test]
    fn legacy_and_calendar_ack_paths_agree() {
        // The calendar drain must observe exactly the acks the legacy
        // scan does, at the same horizons, so the adaptive schedule is
        // bit-identical either way.
        let sp = spec();
        let pacing = PacingConfig { rate_factor: 1.0, lead_tokens: 4 };
        let mut cfg = cfg_with(NetworkProfile::lte());
        cfg.adaptive_lead = true;
        let gen: Vec<f64> = (0..150).map(|i| 0.4 + 0.03 * i as f64).collect();
        let calendar_out = deliver_request(&sp, true, &pacing, &cfg, 23, &gen);
        cfg.legacy_stepping = true;
        let legacy_out = deliver_request(&sp, true, &pacing, &cfg, 23, &gen);
        assert_eq!(legacy_out.release_times, calendar_out.release_times);
        assert_eq!(legacy_out.client_arrivals, calendar_out.client_arrivals);
        assert_eq!(legacy_out.final_lead, calendar_out.final_lead);
        assert_eq!(legacy_out.client_qoe.to_bits(), calendar_out.client_qoe.to_bits());
    }

    #[test]
    fn expected_transit_is_the_weighted_mean_base_latency() {
        assert_eq!(NetworkConfig::default().expected_transit(), 0.0, "off ⇒ 0");
        let fiber = cfg_with(NetworkProfile::fiber());
        assert!((fiber.expected_transit() - NetworkProfile::fiber().base_latency).abs() < 1e-12);
        let mixed = NetworkConfig { enabled: true, ..NetworkConfig::default() }.with_mix(vec![
            (NetworkProfile::fiber(), 1.0),
            (NetworkProfile::lte(), 1.0),
        ]);
        let want =
            (NetworkProfile::fiber().base_latency + NetworkProfile::lte().base_latency) / 2.0;
        assert!((mixed.expected_transit() - want).abs() < 1e-12);
    }

    #[test]
    fn empty_stream_is_well_defined() {
        let out = deliver_request(
            &spec(),
            true,
            &PacingConfig::default(),
            &cfg_with(NetworkProfile::lte()),
            0,
            &[],
        );
        assert!(out.release_times.is_empty());
        assert_eq!(out.client_qoe, 1.0, "zero-length responses are perfect");
        assert_eq!(out.stall_count, 0);
    }
}
