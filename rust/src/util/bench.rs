//! Criterion-style micro-benchmark harness (criterion is unavailable
//! offline). Provides warmup, adaptive iteration count targeting a fixed
//! measurement window, and mean/p50/p99 reporting.

use std::time::{Duration, Instant};

use super::stats;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub p50: Duration,
    pub p99: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12} {:>12} {:>12} {:>12}  ({} iters)",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.p50),
            fmt_dur(self.p99),
            fmt_dur(self.min),
            self.iters,
        )
    }
}

pub fn header() -> String {
    format!(
        "{:<44} {:>12} {:>12} {:>12} {:>12}",
        "benchmark", "mean", "p50", "p99", "min"
    )
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

/// Benchmark runner with a fixed time budget per case.
pub struct Bencher {
    /// Target total measurement time per case.
    pub measure_time: Duration,
    /// Warmup time before measuring.
    pub warmup_time: Duration,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            measure_time: Duration::from_millis(700),
            warmup_time: Duration::from_millis(150),
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn quick() -> Self {
        Bencher {
            measure_time: Duration::from_millis(150),
            warmup_time: Duration::from_millis(30),
            results: Vec::new(),
        }
    }

    /// Run `f` repeatedly, measuring per-call latency. The closure's return
    /// value is passed through `std::hint::black_box` to defeat DCE.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup and calibration: figure out per-call cost.
        let warm_start = Instant::now();
        let mut calib_iters = 0u64;
        while warm_start.elapsed() < self.warmup_time || calib_iters == 0 {
            std::hint::black_box(f());
            calib_iters += 1;
            if calib_iters > 1_000_000 {
                break;
            }
        }
        let per_call = warm_start.elapsed().as_secs_f64() / calib_iters as f64;

        // Choose batch size so each sample costs ~ measure_time/100, with
        // at least 30 samples.
        let target_samples = 100u64;
        let budget = self.measure_time.as_secs_f64();
        let batch = ((budget / target_samples as f64 / per_call.max(1e-9)).floor() as u64).max(1);
        let mut samples: Vec<f64> = Vec::new();
        let start = Instant::now();
        while start.elapsed().as_secs_f64() < budget || samples.len() < 30 {
            let t0 = Instant::now();
            for _ in 0..batch {
                std::hint::black_box(f());
            }
            samples.push(t0.elapsed().as_secs_f64() / batch as f64);
            if samples.len() > 100_000 {
                break;
            }
        }
        let mut sorted = samples.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let result = BenchResult {
            name: name.to_string(),
            iters: batch * samples.len() as u64,
            mean: Duration::from_secs_f64(stats::mean(&samples)),
            p50: Duration::from_secs_f64(stats::percentile_sorted(&sorted, 50.0)),
            p99: Duration::from_secs_f64(stats::percentile_sorted(&sorted, 99.0)),
            min: Duration::from_secs_f64(sorted[0]),
        };
        // lint:allow(D5, live per-case progress line is the bench harness contract)
        println!("{}", result.report());
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Time a single invocation of `f` and record it as a
    /// one-iteration case (mean == p50 == p99 == min == the one
    /// measurement). For whole-simulation benchmarks that run for
    /// seconds — far past the adaptive sampling loop's budget — where
    /// one run is the measurement.
    pub fn bench_once<T, F: FnOnce() -> T>(&mut self, name: &str, f: F) -> &BenchResult {
        let t0 = Instant::now();
        std::hint::black_box(f());
        let elapsed = t0.elapsed();
        let result = BenchResult {
            name: name.to_string(),
            iters: 1,
            mean: elapsed,
            p50: elapsed,
            p99: elapsed,
            min: elapsed,
        };
        // lint:allow(D5, live per-case progress line is the bench harness contract)
        println!("{}", result.report());
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Serialize every recorded result as pretty-printed JSON — the
    /// `BENCH_*.json` perf-baseline format (mean/p50/p99/min in
    /// nanoseconds, plus iteration counts).
    pub fn results_json(&self) -> String {
        use super::json::{pretty, Json};
        let cases = self.results.iter().map(|r| {
            Json::obj(vec![
                ("name", Json::from(r.name.as_str())),
                ("iters", Json::from(r.iters)),
                ("mean_ns", Json::from(r.mean.as_nanos() as f64)),
                ("p50_ns", Json::from(r.p50.as_nanos() as f64)),
                ("p99_ns", Json::from(r.p99.as_nanos() as f64)),
                ("min_ns", Json::from(r.min.as_nanos() as f64)),
            ])
        });
        pretty(&Json::obj(vec![("benchmarks", Json::arr(cases))]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bencher {
            measure_time: Duration::from_millis(20),
            warmup_time: Duration::from_millis(2),
            results: Vec::new(),
        };
        let r = b.bench("noop-ish", || {
            // black_box on the bound keeps release builds from
            // const-folding the whole loop away.
            let n = std::hint::black_box(100u64);
            let mut acc = 0u64;
            for i in 0..n {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(r.iters > 0);
        assert!(r.mean.as_nanos() > 0);
        assert!(r.p99 >= r.p50 || r.p99.as_nanos() + 50 >= r.p50.as_nanos());
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn bench_once_records_a_single_sample() {
        let mut b = Bencher::quick();
        let r = b.bench_once("one-shot", || {
            let n = std::hint::black_box(1000u64);
            let mut acc = 0u64;
            for i in 0..n {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert_eq!(r.iters, 1);
        assert_eq!(r.mean, r.p99);
        assert_eq!(r.mean, r.min);
        assert!(r.mean.as_nanos() > 0);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn results_serialize_to_json() {
        let mut b = Bencher {
            measure_time: Duration::from_millis(5),
            warmup_time: Duration::from_millis(1),
            results: Vec::new(),
        };
        b.bench("case-a", || std::hint::black_box(3u64).wrapping_mul(7));
        let json = b.results_json();
        let parsed = super::super::json::Json::parse(&json).unwrap();
        let cases = parsed.get("benchmarks").as_arr().unwrap();
        assert_eq!(cases.len(), 1);
        assert_eq!(cases[0].get("name").as_str(), Some("case-a"));
        assert!(cases[0].get("mean_ns").as_f64().unwrap() > 0.0);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500 ns");
        assert_eq!(fmt_dur(Duration::from_micros(1500)), "1.50 ms");
        assert!(fmt_dur(Duration::from_secs(2)).contains('s'));
    }
}
