//! In-tree substrates: the offline build environment provides no crates
//! beyond the `xla` closure, so PRNG/distributions, JSON, CLI parsing,
//! CSV, plotting, micro-benchmarking, property testing, and golden-snapshot
//! comparison are implemented here (see DESIGN.md §1, §3).

pub mod bench;
pub mod cli;
pub mod csv;
pub mod golden;
pub mod json;
pub mod plot;
pub mod rng;
pub mod stats;
pub mod testing;
