//! Descriptive statistics used by the metrics pipeline and experiment
//! harness: percentiles, running moments, histograms, Pearson correlation.

/// Percentile of a sample by linear interpolation (like numpy's default).
/// `p` in [0, 100]. Returns NaN on an empty slice (a per-tier report row
/// with zero requests is a legitimate input, not a panic); NaN samples
/// are sorted to the end (`total_cmp`) rather than poisoning the sort.
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    let mut xs: Vec<f64> = samples.to_vec();
    xs.sort_by(f64::total_cmp);
    percentile_sorted(&xs, p)
}

/// Percentile of an already-sorted sample. Returns NaN on an empty
/// slice, the sole element on a singleton.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let rank = percentile_rank(n, p);
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Fractional 0-based rank of percentile `p` among `n` ordered samples —
/// the one interpolation rule shared by [`percentile_sorted`] and the
/// telemetry bucket histograms ([`percentile_of_buckets`]), so the two
/// estimators cannot drift apart again.
pub fn percentile_rank(n: usize, p: f64) -> f64 {
    (p.clamp(0.0, 100.0) / 100.0) * n.saturating_sub(1) as f64
}

/// Percentile extracted from a fixed-bucket histogram: `bounds[i]` is the
/// inclusive upper edge of bucket `i` (ascending), `counts[i]` its count.
/// Samples are assumed uniformly spread inside their bucket, so the
/// estimate interpolates linearly between the bucket's edges using the
/// same fractional rank as [`percentile_sorted`]. Returns NaN on an
/// empty histogram; a bucket holding a single sample reports its upper
/// edge (mirroring the singleton rule above, to bucket resolution).
pub fn percentile_of_buckets(bounds: &[f64], counts: &[u64], p: f64) -> f64 {
    assert_eq!(bounds.len(), counts.len(), "bucket arity mismatch");
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return f64::NAN;
    }
    let rank = percentile_rank(total as usize, p);
    let mut cum = 0u64;
    for (i, &c) in counts.iter().enumerate() {
        if c == 0 {
            continue;
        }
        let first = cum as f64;
        let last = (cum + c - 1) as f64;
        if rank <= last {
            let lo = if i == 0 { bounds[0].min(0.0) } else { bounds[i - 1] };
            let hi = bounds[i];
            if last == first {
                return hi;
            }
            let frac = ((rank - first) / (last - first)).clamp(0.0, 1.0);
            return lo + (hi - lo) * frac;
        }
        cum += c;
    }
    bounds[bounds.len() - 1]
}

/// Arithmetic mean; NaN on empty input.
pub fn mean(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    samples.iter().sum::<f64>() / samples.len() as f64
}

/// Population standard deviation; NaN on empty input.
pub fn std_dev(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    let m = mean(samples);
    (samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / samples.len() as f64).sqrt()
}

/// Pearson correlation coefficient of two equal-length series.
/// Used to reproduce Fig. 19 (batch size vs total context length, r≈0.997).
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    if xs.len() < 2 {
        return f64::NAN;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        cov += (x - mx) * (y - my);
        vx += (x - mx) * (x - mx);
        vy += (y - my) * (y - my);
    }
    if vx == 0.0 || vy == 0.0 {
        return f64::NAN;
    }
    cov / (vx.sqrt() * vy.sqrt())
}

/// Running summary accumulator (no sample storage): count/mean/min/max/std
/// via Welford's algorithm.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.mean }
    }
    pub fn std(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { (self.m2 / self.n as f64).sqrt() }
    }
    pub fn min(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.min }
    }
    pub fn max(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.max }
    }

    pub fn merge(&mut self, other: &Summary) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 = self.m2 + other.m2 + d * d * self.n as f64 * other.n as f64 / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Fixed-bin histogram over [lo, hi); out-of-range values clamp to the
/// first/last bin. Used for dataset-distribution experiments (Fig. 9).
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    bins: Vec<u64>,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram { lo, hi, bins: vec![0; nbins] }
    }

    pub fn add(&mut self, x: f64) {
        let n = self.bins.len();
        let idx = ((x - self.lo) / (self.hi - self.lo) * n as f64).floor();
        let idx = (idx.max(0.0) as usize).min(n - 1);
        self.bins[idx] += 1;
    }

    pub fn bins(&self) -> &[u64] {
        &self.bins
    }

    pub fn total(&self) -> u64 {
        self.bins.iter().sum()
    }

    /// (bin_center, normalized density) pairs.
    pub fn density(&self) -> Vec<(f64, f64)> {
        let total = self.total().max(1) as f64;
        let w = (self.hi - self.lo) / self.bins.len() as f64;
        self.bins
            .iter()
            .enumerate()
            .map(|(i, &c)| (self.lo + (i as f64 + 0.5) * w, c as f64 / total / w))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert!((percentile(&xs, 25.0) - 2.0).abs() < 1e-12);
        assert!((percentile(&xs, 90.0) - 4.6).abs() < 1e-12);
    }

    #[test]
    fn percentile_unsorted_input() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 50.0), 3.0);
    }

    #[test]
    fn percentile_edge_cases() {
        // Empty and singleton inputs (a per-tier CSV row with zero or
        // one request) must not panic.
        assert!(percentile(&[], 50.0).is_nan());
        assert!(percentile_sorted(&[], 10.0).is_nan());
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
        assert_eq!(percentile_sorted(&[7.0], 0.0), 7.0);
    }

    #[test]
    fn percentile_tolerates_nan_samples() {
        // A NaN sample (e.g. an unfinished request's TTFT) used to
        // panic the `partial_cmp().unwrap()` sort; total_cmp orders it
        // after every finite value instead.
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert!(percentile(&xs, 100.0).is_nan());
    }

    #[test]
    fn percentile_all_equal() {
        // A constant sample must report that constant at every p (the
        // interpolation between equal neighbours is exact).
        let xs = [4.2; 9];
        for p in [0.0, 10.0, 50.0, 90.0, 100.0] {
            assert_eq!(percentile(&xs, p), 4.2, "p={p}");
        }
    }

    #[test]
    fn bucket_percentile_edge_cases() {
        let bounds = [1.0, 2.0, 4.0, 8.0];
        // Empty histogram → NaN, like the sample estimator.
        assert!(percentile_of_buckets(&bounds, &[0, 0, 0, 0], 50.0).is_nan());
        // Singleton → the sample's bucket upper edge, independent of p.
        for p in [0.0, 50.0, 100.0] {
            assert_eq!(percentile_of_buckets(&bounds, &[0, 1, 0, 0], p), 2.0);
        }
        // All-equal (everything in one bucket) → constant estimate to
        // bucket resolution: p0 pins the lower edge, p100 the upper.
        assert_eq!(percentile_of_buckets(&bounds, &[0, 0, 7, 0], 0.0), 2.0);
        assert_eq!(percentile_of_buckets(&bounds, &[0, 0, 7, 0], 100.0), 4.0);
    }

    #[test]
    fn bucket_percentile_tracks_sample_percentile() {
        // Samples placed exactly on bucket edges: the bucket estimator
        // must agree with the sample estimator to bucket resolution.
        let bounds: Vec<f64> = (1..=10).map(|i| i as f64).collect();
        let samples: Vec<f64> = (1..=10).map(|i| i as f64).collect();
        let counts = vec![1u64; 10];
        for p in [0.0, 25.0, 50.0, 75.0, 90.0, 100.0] {
            let exact = percentile(&samples, p);
            let approx = percentile_of_buckets(&bounds, &counts, p);
            assert!(
                (exact - approx).abs() <= 1.0 + 1e-12,
                "p={p}: sample {exact} vs bucket {approx}"
            );
        }
        // The shared rank rule: median of 10 one-per-bucket samples.
        assert!((percentile_of_buckets(&bounds, &counts, 100.0) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert_eq!(mean(&xs), 5.0);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let yneg = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&xs, &yneg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_uncorrelated_constant() {
        assert!(pearson(&[1.0, 2.0, 3.0], &[5.0, 5.0, 5.0]).is_nan());
    }

    #[test]
    fn summary_matches_batch() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut s = Summary::new();
        for &x in &xs {
            s.add(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - 2.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn summary_merge() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut whole = Summary::new();
        for &x in &xs {
            whole.add(x);
        }
        let mut a = Summary::new();
        let mut b = Summary::new();
        for &x in &xs[..37] {
            a.add(x);
        }
        for &x in &xs[37..] {
            b.add(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert!((a.mean() - whole.mean()).abs() < 1e-9);
        assert!((a.std() - whole.std()).abs() < 1e-9);
    }

    #[test]
    fn histogram_binning() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        assert!(h.bins().iter().all(|&c| c == 1));
        h.add(-5.0); // clamps to first bin
        h.add(99.0); // clamps to last bin
        assert_eq!(h.bins()[0], 2);
        assert_eq!(h.bins()[9], 2);
        assert_eq!(h.total(), 12);
    }
}
