//! Property-based testing mini-framework (proptest is unavailable offline).
//!
//! A property is a closure over a seeded [`crate::util::rng::Rng`]; the
//! harness runs it across many seeds and, on failure, reruns with a fixed
//! set of "small" seeds first to give a stable, reportable reproduction.
//!
//! ```ignore
//! check_prop("kv never leaks", 256, |rng| {
//!     let ops = gen_ops(rng);
//!     run(ops); // assert! inside
//! });
//! ```

use super::rng::Rng;

/// Run `prop` across `cases` deterministic seeds. Panics (with the seed)
/// on the first failing case so failures are reproducible.
pub fn check_prop<F: FnMut(&mut Rng)>(name: &str, cases: u64, mut prop: F) {
    for case in 0..cases {
        let seed = 0xA11D_E500_0000_0000u64 ^ case.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        // AssertUnwindSafe: the closure is only reused after a failure to
        // report the seed, never to continue shared-state mutation.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::new(seed);
            prop(&mut rng);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Generate a vector whose length is sampled in `[0, max_len]` via `gen`.
pub fn gen_vec<T>(rng: &mut Rng, max_len: usize, mut gen: impl FnMut(&mut Rng) -> T) -> Vec<T> {
    let n = rng.below(max_len as u64 + 1) as usize;
    (0..n).map(|_| gen(rng)).collect()
}

/// Assert two floats are close (absolute + relative tolerance).
#[track_caller]
pub fn assert_close(a: f64, b: f64, tol: f64) {
    let scale = a.abs().max(b.abs()).max(1.0);
    assert!(
        (a - b).abs() <= tol * scale,
        "assert_close failed: {a} vs {b} (tol {tol}, scaled {})",
        tol * scale
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0u64;
        check_prop("always true", 50, |rng| {
            let _ = rng.f64();
            count += 1;
        });
        assert_eq!(count, 50);
    }

    #[test]
    fn failing_property_reports_seed() {
        let result = std::panic::catch_unwind(|| {
            check_prop("fails eventually", 50, |rng| {
                assert!(rng.f64() < 0.9, "value too large");
            });
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("seed"), "message was: {msg}");
        assert!(msg.contains("value too large"), "message was: {msg}");
    }

    #[test]
    fn gen_vec_respects_bounds() {
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let v = gen_vec(&mut rng, 10, |r| r.below(5));
            assert!(v.len() <= 10);
            assert!(v.iter().all(|&x| x < 5));
        }
    }

    #[test]
    fn assert_close_behaves() {
        assert_close(1.0, 1.0 + 1e-12, 1e-9);
        assert!(std::panic::catch_unwind(|| assert_close(1.0, 2.0, 1e-9)).is_err());
    }
}
