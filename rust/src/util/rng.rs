//! Pseudo-random number generation and distribution sampling.
//!
//! The offline build environment has no `rand`/`rand_distr`, so this module
//! implements the PRNG substrate the workload generators need:
//! xoshiro256++ seeded via splitmix64, plus samplers for the distributions
//! the paper's evaluation uses (Poisson/Gamma arrival processes, lognormal
//! length distributions, categorical QoE-trace mixtures).
//!
//! All simulation randomness flows through [`Rng`] so experiments are
//! reproducible from a single `u64` seed.

/// xoshiro256++ PRNG (Blackman & Vigna). Fast, 2^256-1 period, passes BigCrush.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

/// splitmix64 step, used for seeding and as a cheap standalone mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child generator (for per-component streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 top bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in (0, 1] — safe as a log() argument.
    #[inline]
    pub fn f64_open(&mut self) -> f64 {
        1.0 - self.f64()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (polar-free variant; simple and exact).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64_open();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean `mu`, std `sigma`.
    pub fn normal_with(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// LogNormal: exp(N(mu, sigma)). Used to model ShareGPT length
    /// distributions (heavy right tail as in paper Fig. 9).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal_with(mu, sigma).exp()
    }

    /// Exponential with rate `lambda` (mean 1/lambda). Poisson-process
    /// inter-arrival times.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        -self.f64_open().ln() / lambda
    }

    /// Gamma(shape k, scale theta) via Marsaglia & Tsang (2000).
    /// Used for the bursty arrival process (paper §6.4: Gamma with CV=3).
    pub fn gamma(&mut self, shape: f64, scale: f64) -> f64 {
        debug_assert!(shape > 0.0 && scale > 0.0);
        if shape < 1.0 {
            // Boost: Gamma(k) = Gamma(k+1) * U^(1/k)
            let g = self.gamma(shape + 1.0, 1.0);
            let u = self.f64_open();
            return g * u.powf(1.0 / shape) * scale;
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v = v * v * v;
            let u = self.f64_open();
            let x2 = x * x;
            if u < 1.0 - 0.0331 * x2 * x2 {
                return d * v * scale;
            }
            if u.ln() < 0.5 * x2 + d * (1.0 - v + v.ln()) {
                return d * v * scale;
            }
        }
    }

    /// Poisson-distributed count with mean `lambda` (Knuth for small
    /// lambda, normal approximation above 30).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        debug_assert!(lambda >= 0.0);
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 30.0 {
            let x = self.normal_with(lambda, lambda.sqrt());
            return x.max(0.0).round() as u64;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Sample an index from unnormalized categorical weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn moments(samples: &[f64]) -> (f64, f64) {
        let n = samples.len() as f64;
        let mean = samples.iter().sum::<f64>() / n;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        (mean, var)
    }

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            let k = r.range(3, 7);
            assert!((3..=7).contains(&k));
        }
    }

    #[test]
    fn below_unbiased_small() {
        let mut r = Rng::new(7);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let xs: Vec<f64> = (0..100_000).map(|_| r.normal()).collect();
        let (mean, var) = moments(&xs);
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(3);
        let xs: Vec<f64> = (0..100_000).map(|_| r.exponential(2.0)).collect();
        let (mean, _) = moments(&xs);
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn gamma_moments() {
        // Gamma(k, theta): mean k*theta, var k*theta^2.
        let mut r = Rng::new(4);
        for &(k, theta) in &[(0.5, 2.0), (1.0, 1.0), (4.0, 0.5), (1.0 / 9.0, 9.0)] {
            let xs: Vec<f64> = (0..200_000).map(|_| r.gamma(k, theta)).collect();
            let (mean, var) = moments(&xs);
            assert!((mean - k * theta).abs() < 0.05 * (k * theta).max(0.2), "k={k} mean {mean}");
            assert!(
                (var - k * theta * theta).abs() < 0.1 * (k * theta * theta).max(0.3),
                "k={k} var {var}"
            );
        }
    }

    #[test]
    fn poisson_mean() {
        let mut r = Rng::new(5);
        for &lam in &[0.5, 3.0, 50.0] {
            let xs: Vec<f64> = (0..50_000).map(|_| r.poisson(lam) as f64).collect();
            let (mean, _) = moments(&xs);
            assert!((mean - lam).abs() < 0.05 * lam.max(1.0), "lam={lam} mean {mean}");
        }
    }

    #[test]
    fn lognormal_positive() {
        let mut r = Rng::new(6);
        for _ in 0..10_000 {
            assert!(r.lognormal(5.0, 1.0) > 0.0);
        }
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(8);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.categorical(&[1.0, 2.0, 1.0])] += 1;
        }
        assert!(counts[1] > counts[0] && counts[1] > counts[2]);
        let frac = counts[1] as f64 / 30_000.0;
        assert!((frac - 0.5).abs() < 0.03);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_diverge() {
        let mut a = Rng::new(10);
        let mut b = a.fork();
        let mut c = a.fork();
        assert_ne!(b.next_u64(), c.next_u64());
    }
}
