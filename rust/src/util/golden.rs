//! Golden-snapshot comparison for seeded regression runs.
//!
//! A golden test runs a fixed-seed scenario, reduces it to a flat set of
//! named metrics, and compares them against a JSON snapshot committed
//! under `rust/tests/golden/`. Each metric carries its own **relative
//! tolerance**: counts pin exactly (`rel_tol = 0`), floats absorb
//! platform-libm noise (`rel_tol ≈ 1e-6`) while still catching any real
//! behavior change.
//!
//! Lifecycle:
//!
//! - **Missing snapshot** → the run *blesses* it (writes the file) and
//!   passes with a notice. This bootstraps a fresh scenario: run the
//!   suite once, review the generated JSON, and commit it.
//! - **Intentional behavior change** → regenerate with
//!   `GOLDEN_BLESS=1 cargo test --test golden` and commit the diff.
//! - **Unintentional drift** → the comparison fails, naming every
//!   metric outside its tolerance.
//!
//! ```
//! use andes::util::golden::{metric, check_or_bless};
//! let dir = std::env::temp_dir().join("andes-golden-doc");
//! let path = dir.join("demo.json");
//! let _ = std::fs::remove_file(&path);
//! let metrics = [metric("served", 42.0, 0.0), metric("mean_qoe", 0.87, 1e-6)];
//! // First run blesses, second run verifies.
//! check_or_bless(&path, &metrics).unwrap();
//! check_or_bless(&path, &metrics).unwrap();
//! // Out-of-tolerance drift is caught.
//! let drifted = [metric("served", 41.0, 0.0), metric("mean_qoe", 0.87, 1e-6)];
//! assert!(check_or_bless(&path, &drifted).is_err());
//! ```

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::json::{pretty, Json};

/// One pinned metric: name, observed value, relative tolerance.
#[derive(Debug, Clone, Copy)]
pub struct GoldenMetric {
    pub name: &'static str,
    pub value: f64,
    /// Allowed relative drift: `|observed − golden| ≤ rel_tol ×
    /// max(|golden|, 1)`. 0 pins the value exactly (use for counts).
    pub rel_tol: f64,
}

/// Shorthand constructor.
pub fn metric(name: &'static str, value: f64, rel_tol: f64) -> GoldenMetric {
    GoldenMetric { name, value, rel_tol }
}

/// Compare `metrics` against the snapshot at `path`, blessing it when
/// missing or when `GOLDEN_BLESS=1` is set (see the module docs).
pub fn check_or_bless(path: &Path, metrics: &[GoldenMetric]) -> Result<()> {
    // A non-finite metric would serialize as invalid JSON and poison
    // every later run with an opaque parse error — refuse it by name.
    if let Some(bad) = metrics.iter().find(|m| !m.value.is_finite()) {
        bail!(
            "golden metric '{}' is non-finite ({}) — fix the scenario before pinning",
            bad.name,
            bad.value
        );
    }
    let bless = std::env::var("GOLDEN_BLESS").map(|v| v == "1").unwrap_or(false);
    if bless || !path.exists() {
        write_snapshot(path, metrics)?;
        // lint:allow(D5, bless mode talks to the operator who just set GOLDEN_BLESS=1)
        eprintln!(
            "golden: blessed {} ({} metrics) — review and commit it",
            path.display(),
            metrics.len()
        );
        return Ok(());
    }
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading golden snapshot {}", path.display()))?;
    let j = Json::parse(&text)
        .with_context(|| format!("parsing golden snapshot {}", path.display()))?;
    let obj = match j.as_obj() {
        Some(m) => m,
        None => bail!("golden snapshot {} is not a JSON object", path.display()),
    };
    let mut failures: Vec<String> = Vec::new();
    for m in metrics {
        match obj.get(m.name).and_then(|v| v.as_f64()) {
            None => failures.push(format!(
                "  {}: missing from the snapshot (new metric? re-bless)",
                m.name
            )),
            Some(golden) => {
                let tol = m.rel_tol * golden.abs().max(1.0);
                // NaN-safe: a NaN on either side fails the comparison.
                let within = (m.value - golden).abs() <= tol;
                if !within {
                    failures.push(format!(
                        "  {}: observed {} vs golden {} (tol {})",
                        m.name, m.value, golden, tol
                    ));
                }
            }
        }
    }
    for name in obj.keys() {
        if !metrics.iter().any(|m| m.name == name.as_str()) {
            failures.push(format!(
                "  {name}: present in the snapshot but no longer reported"
            ));
        }
    }
    if !failures.is_empty() {
        bail!(
            "golden snapshot {} drifted:\n{}\n\
             (intentional change? regenerate with GOLDEN_BLESS=1)",
            path.display(),
            failures.join("\n")
        );
    }
    Ok(())
}

/// Compare verbatim text against the snapshot at `path`, blessing it
/// when missing or when `GOLDEN_BLESS=1` is set. Used for exact textual
/// surfaces (e.g. the Prometheus exposition of a seeded run) where the
/// whole byte sequence — family order, label order, bucket layout — is
/// the contract. On mismatch the error names the first differing line.
pub fn check_or_bless_text(path: &Path, observed: &str) -> Result<()> {
    let bless = std::env::var("GOLDEN_BLESS").map(|v| v == "1").unwrap_or(false);
    if bless || !path.exists() {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating {}", parent.display()))?;
        }
        std::fs::write(path, observed)
            .with_context(|| format!("writing golden text {}", path.display()))?;
        // lint:allow(D5, bless mode talks to the operator who just set GOLDEN_BLESS=1)
        eprintln!(
            "golden: blessed {} ({} lines) — review and commit it",
            path.display(),
            observed.lines().count()
        );
        return Ok(());
    }
    let golden = std::fs::read_to_string(path)
        .with_context(|| format!("reading golden text {}", path.display()))?;
    if golden == observed {
        return Ok(());
    }
    let mut gl = golden.lines();
    let mut ol = observed.lines();
    let mut lineno = 0usize;
    loop {
        lineno += 1;
        match (gl.next(), ol.next()) {
            (Some(g), Some(o)) if g == o => continue,
            (Some(g), Some(o)) => bail!(
                "golden text {} drifted at line {lineno}:\n  golden:   {g}\n  observed: {o}\n\
                 (intentional change? regenerate with GOLDEN_BLESS=1)",
                path.display()
            ),
            (Some(g), None) => bail!(
                "golden text {} drifted: observed output ends at line {lineno}, \
                 golden continues with: {g}",
                path.display()
            ),
            (None, Some(o)) => bail!(
                "golden text {} drifted: golden ends at line {lineno}, \
                 observed continues with: {o}",
                path.display()
            ),
            (None, None) => bail!(
                "golden text {} drifted in trailing whitespace only",
                path.display()
            ),
        }
    }
}

fn write_snapshot(path: &Path, metrics: &[GoldenMetric]) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)
            .with_context(|| format!("creating {}", parent.display()))?;
    }
    let obj = Json::Obj(
        metrics.iter().map(|m| (m.name.to_string(), Json::Num(m.value))).collect(),
    );
    let mut text = pretty(&obj);
    text.push('\n');
    std::fs::write(path, text)
        .with_context(|| format!("writing golden snapshot {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("andes-golden-tests");
        let _ = std::fs::create_dir_all(&dir);
        dir.join(name)
    }

    #[test]
    fn bless_then_verify_roundtrip() {
        let path = tmp("roundtrip.json");
        let _ = std::fs::remove_file(&path);
        let ms = [metric("count", 12.0, 0.0), metric("qoe", 0.923456, 1e-6)];
        check_or_bless(&path, &ms).unwrap();
        assert!(path.exists());
        check_or_bless(&path, &ms).unwrap();
    }

    #[test]
    fn drift_beyond_tolerance_fails() {
        let path = tmp("drift.json");
        let _ = std::fs::remove_file(&path);
        check_or_bless(&path, &[metric("qoe", 0.9, 1e-6)]).unwrap();
        // Inside tolerance: passes.
        check_or_bless(&path, &[metric("qoe", 0.9 + 5e-7, 1e-6)]).unwrap();
        // Outside: fails and names the metric.
        let err = check_or_bless(&path, &[metric("qoe", 0.91, 1e-6)]).unwrap_err();
        assert!(err.to_string().contains("qoe"), "{err:#}");
    }

    #[test]
    fn non_finite_metrics_are_rejected_before_blessing() {
        let path = tmp("nan.json");
        let _ = std::fs::remove_file(&path);
        let err =
            check_or_bless(&path, &[metric("bad", f64::NAN, 0.0)]).unwrap_err();
        assert!(err.to_string().contains("bad"), "{err:#}");
        assert!(!path.exists(), "a poisoned snapshot must never be written");
    }

    #[test]
    fn text_snapshot_roundtrip_and_drift() {
        let path = tmp("text.golden");
        let _ = std::fs::remove_file(&path);
        let text = "# HELP x y\n# TYPE x counter\nx 1\n";
        check_or_bless_text(&path, text).unwrap();
        assert!(path.exists());
        check_or_bless_text(&path, text).unwrap();
        let err = check_or_bless_text(&path, "# HELP x y\n# TYPE x counter\nx 2\n")
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("line 3"), "{msg}");
        assert!(msg.contains("x 1") && msg.contains("x 2"), "{msg}");
        // Truncated output is drift too.
        assert!(check_or_bless_text(&path, "# HELP x y\n").is_err());
    }

    #[test]
    fn exact_pins_and_key_set_changes() {
        let path = tmp("keys.json");
        let _ = std::fs::remove_file(&path);
        check_or_bless(&path, &[metric("served", 40.0, 0.0)]).unwrap();
        // rel_tol 0 pins exactly.
        assert!(check_or_bless(&path, &[metric("served", 41.0, 0.0)]).is_err());
        // A metric vanishing from the report is drift too.
        assert!(check_or_bless(&path, &[metric("other", 40.0, 0.0)]).is_err());
        // As is a brand-new metric the snapshot has never seen.
        assert!(check_or_bless(
            &path,
            &[metric("served", 40.0, 0.0), metric("new", 1.0, 0.0)]
        )
        .is_err());
    }
}
