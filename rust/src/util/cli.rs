//! Tiny declarative CLI argument parser (clap is unavailable offline).
//!
//! Supports `program <subcommand> [--flag] [--key value] [--key=value]
//! [positional...]`. Unknown flags are errors; `--help` renders generated
//! usage text.

use std::collections::BTreeMap;

/// Parsed arguments: flag/option map plus positionals.
#[derive(Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

/// Declarative option spec used for validation and --help output.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
    pub help: &'static str,
}

impl OptSpec {
    pub fn value(name: &'static str, default: Option<&'static str>, help: &'static str) -> Self {
        OptSpec { name, takes_value: true, default, help }
    }
    pub fn flag(name: &'static str, help: &'static str) -> Self {
        OptSpec { name, takes_value: false, default: None, help }
    }
}

#[derive(Debug, thiserror::Error)]
pub enum CliError {
    #[error("unknown option --{0}")]
    Unknown(String),
    #[error("option --{0} requires a value")]
    MissingValue(String),
    #[error("invalid value for --{0}: {1}")]
    Invalid(String, String),
    #[error("help requested")]
    Help,
}

impl Args {
    /// Parse raw argv (without the program/subcommand names) against specs.
    pub fn parse(raw: &[String], specs: &[OptSpec]) -> Result<Args, CliError> {
        let mut args = Args::default();
        for s in specs.iter().filter(|s| s.takes_value) {
            if let Some(d) = s.default {
                args.opts.insert(s.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if a == "--help" || a == "-h" {
                return Err(CliError::Help);
            }
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| CliError::Unknown(name.clone()))?;
                if spec.takes_value {
                    let v = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            raw.get(i).cloned().ok_or_else(|| CliError::MissingValue(name.clone()))?
                        }
                    };
                    args.opts.insert(name, v);
                } else {
                    if inline_val.is_some() {
                        return Err(CliError::Invalid(name, "flag takes no value".into()));
                    }
                    args.flags.push(name);
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>, CliError> {
        self.opts
            .get(name)
            .map(|v| v.parse::<f64>().map_err(|_| CliError::Invalid(name.into(), v.clone())))
            .transpose()
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>, CliError> {
        self.opts
            .get(name)
            .map(|v| v.parse::<usize>().map_err(|_| CliError::Invalid(name.into(), v.clone())))
            .transpose()
    }

    pub fn get_u64(&self, name: &str) -> Result<Option<u64>, CliError> {
        self.opts
            .get(name)
            .map(|v| v.parse::<u64>().map_err(|_| CliError::Invalid(name.into(), v.clone())))
            .transpose()
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// Render a usage block for a subcommand.
pub fn usage(cmd: &str, about: &str, specs: &[OptSpec]) -> String {
    let mut s = format!("{about}\n\nUsage: andes {cmd} [options]\n\nOptions:\n");
    for spec in specs {
        let lhs = if spec.takes_value {
            format!("--{} <v>", spec.name)
        } else {
            format!("--{}", spec.name)
        };
        let default = spec.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
        s.push_str(&format!("  {lhs:<26} {}{}\n", spec.help, default));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    fn specs() -> Vec<OptSpec> {
        vec![
            OptSpec::value("rate", Some("2.0"), "request rate"),
            OptSpec::value("model", None, "model profile"),
            OptSpec::flag("verbose", "chatty output"),
        ]
    }

    #[test]
    fn defaults_and_override() {
        let a = Args::parse(&sv(&[]), &specs()).unwrap();
        assert_eq!(a.get("rate"), Some("2.0"));
        assert_eq!(a.get("model"), None);
        let a = Args::parse(&sv(&["--rate", "3.3"]), &specs()).unwrap();
        assert_eq!(a.get_f64("rate").unwrap(), Some(3.3));
    }

    #[test]
    fn equals_form_and_flags() {
        let a = Args::parse(&sv(&["--rate=4.5", "--verbose", "pos1"]), &specs()).unwrap();
        assert_eq!(a.get("rate"), Some("4.5"));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn errors() {
        assert!(matches!(
            Args::parse(&sv(&["--nope"]), &specs()),
            Err(CliError::Unknown(_))
        ));
        assert!(matches!(
            Args::parse(&sv(&["--model"]), &specs()),
            Err(CliError::MissingValue(_))
        ));
        assert!(matches!(Args::parse(&sv(&["--help"]), &specs()), Err(CliError::Help)));
        let a = Args::parse(&sv(&["--rate", "abc"]), &specs()).unwrap();
        assert!(a.get_f64("rate").is_err());
    }

    #[test]
    fn usage_renders() {
        let u = usage("serve", "Run the server", &specs());
        assert!(u.contains("--rate"));
        assert!(u.contains("[default: 2.0]"));
    }
}
