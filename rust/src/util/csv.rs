//! CSV writer for experiment outputs (one file per paper figure/table).

use std::fmt::Write as _;
use std::path::Path;

/// In-memory CSV table with a fixed header row.
#[derive(Debug, Clone)]
pub struct Csv {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

fn escape(field: &str) -> String {
    if field.contains(',') || field.contains('"') || field.contains('\n') {
        format!("\"{}\"", field.replace('"', "\"\""))
    } else {
        field.to_string()
    }
}

/// Format a float compactly: integers render without decimals, otherwise
/// up to 6 significant decimals with trailing zeros trimmed.
pub fn fmt_f64(x: f64) -> String {
    if x.is_nan() {
        return "nan".into();
    }
    if x.fract() == 0.0 && x.abs() < 9e15 {
        return format!("{}", x as i64);
    }
    let s = format!("{x:.6}");
    let s = s.trim_end_matches('0').trim_end_matches('.');
    s.to_string()
}

impl Csv {
    pub fn new(header: &[&str]) -> Self {
        Csv { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append a row of pre-formatted fields. Panics if arity mismatches.
    pub fn row(&mut self, fields: &[String]) {
        assert_eq!(fields.len(), self.header.len(), "csv row arity mismatch");
        self.rows.push(fields.to_vec());
    }

    /// Append a row of f64s.
    pub fn row_f64(&mut self, fields: &[f64]) {
        let v: Vec<String> = fields.iter().map(|x| fmt_f64(*x)).collect();
        self.row(&v);
    }

    /// Append a row with a leading label then f64s.
    pub fn row_labeled(&mut self, label: &str, fields: &[f64]) {
        let mut v = vec![label.to_string()];
        v.extend(fields.iter().map(|x| fmt_f64(*x)));
        self.row(&v);
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{}", self.header.iter().map(|h| escape(h)).collect::<Vec<_>>().join(","));
        for row in &self.rows {
            let _ = writeln!(s, "{}", row.iter().map(|f| escape(f)).collect::<Vec<_>>().join(","));
        }
        s
    }

    pub fn write(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_rows() {
        let mut c = Csv::new(&["a", "b"]);
        c.row_f64(&[1.0, 2.5]);
        c.row_labeled("x", &[3.0]);
        let s = c.to_string();
        assert_eq!(s, "a,b\n1,2.5\nx,3\n");
    }

    #[test]
    fn escaping() {
        let mut c = Csv::new(&["name", "v"]);
        c.row(&["has,comma".to_string(), "has\"quote".to_string()]);
        assert_eq!(c.to_string(), "name,v\n\"has,comma\",\"has\"\"quote\"\n");
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut c = Csv::new(&["a", "b"]);
        c.row(&["only-one".to_string()]);
    }

    #[test]
    fn fmt_compact() {
        assert_eq!(fmt_f64(3.0), "3");
        assert_eq!(fmt_f64(0.125), "0.125");
        assert_eq!(fmt_f64(1.0 / 3.0), "0.333333");
        assert_eq!(fmt_f64(f64::NAN), "nan");
    }

    #[test]
    fn writes_file() {
        let dir = std::env::temp_dir().join("andes_csv_test");
        let path = dir.join("t.csv");
        let mut c = Csv::new(&["x"]);
        c.row_f64(&[1.0]);
        c.write(&path).unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "x\n1\n");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
