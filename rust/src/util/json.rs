//! Minimal JSON implementation (value model, recursive-descent parser,
//! writer). Substrate for the config system, the TCP streaming protocol,
//! and experiment result files — `serde_json` is unavailable offline.
//!
//! Supports the full JSON grammar except unicode surrogate pairs beyond
//! the BMP escape handling noted below. Numbers are f64 (like JavaScript).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a BTreeMap so output is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|x| *x >= 0.0 && x.fract() == 0.0).map(|x| x as u64)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// Object field lookup; returns `Json::Null` for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // -- builders ----------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle surrogate pairs.
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            s.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                        } else if (0xDC00..0xE000).contains(&cp) {
                            return Err(self.err("lone low surrogate"));
                        } else {
                            s.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("raw control char in string")),
                Some(c) => {
                    // Re-decode UTF-8 multibyte sequences from the raw bytes.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.b.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..start + len])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        txt.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

fn escape_into(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    /// Compact single-line serialization.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_compact(&mut s, self);
        f.write_str(&s)
    }
}

fn write_compact(out: &mut String, v: &Json) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(x) => {
            if x.fract() == 0.0 && x.abs() < 9e15 {
                out.push_str(&format!("{}", *x as i64));
            } else {
                out.push_str(&format!("{x}"));
            }
        }
        Json::Str(s) => escape_into(out, s),
        Json::Arr(xs) => {
            out.push('[');
            for (i, x) in xs.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_compact(out, x);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape_into(out, k);
                out.push(':');
                write_compact(out, x);
            }
            out.push('}');
        }
    }
}

/// Pretty-printed serialization with 2-space indentation.
pub fn pretty(v: &Json) -> String {
    let mut s = String::new();
    write_pretty(&mut s, v, 0);
    s
}

fn write_pretty(out: &mut String, v: &Json, depth: usize) {
    let pad = "  ".repeat(depth + 1);
    let pad_close = "  ".repeat(depth);
    match v {
        Json::Arr(xs) if !xs.is_empty() => {
            out.push_str("[\n");
            for (i, x) in xs.iter().enumerate() {
                if i > 0 {
                    out.push_str(",\n");
                }
                out.push_str(&pad);
                write_pretty(out, x, depth + 1);
            }
            out.push('\n');
            out.push_str(&pad_close);
            out.push(']');
        }
        Json::Obj(m) if !m.is_empty() => {
            out.push_str("{\n");
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push_str(",\n");
                }
                out.push_str(&pad);
                escape_into(out, k);
                out.push_str(": ");
                write_pretty(out, x, depth + 1);
            }
            out.push('\n');
            out.push_str(&pad_close);
            out.push('}');
        }
        other => write_compact(out, other),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""hi""#).unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert!(v.get("a").as_arr().unwrap()[2].get("b").is_null());
        assert_eq!(v.get("c").as_str(), Some("x"));
        assert!(v.get("missing").is_null());
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\Aé"));
    }

    #[test]
    fn parse_surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn parse_utf8_passthrough() {
        let v = Json::parse(r#""héllo 世界""#).unwrap();
        assert_eq!(v.as_str(), Some("héllo 世界"));
    }

    #[test]
    fn parse_errors() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse(r#"{"a":1} extra"#).is_err());
        assert!(Json::parse(r#""\ud800""#).is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"b":true,"n":null,"nested":{"x":-1}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
        assert_eq!(out, src);
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.25).to_string(), "3.25");
    }

    #[test]
    fn pretty_roundtrip() {
        let v = Json::obj(vec![
            ("a", Json::arr(vec![1.0.into(), 2.0.into()])),
            ("b", Json::obj(vec![("c", "d".into())])),
        ]);
        let p = pretty(&v);
        assert!(p.contains('\n'));
        assert_eq!(Json::parse(&p).unwrap(), v);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"u": 7, "f": 7.5, "neg": -1}"#).unwrap();
        assert_eq!(v.get("u").as_u64(), Some(7));
        assert_eq!(v.get("f").as_u64(), None);
        assert_eq!(v.get("neg").as_u64(), None);
        assert_eq!(v.get("f").as_f64(), Some(7.5));
    }
}
