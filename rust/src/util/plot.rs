//! ASCII plotting for terminal rendering of the paper's figures.
//!
//! Not a substitute for the CSVs (which external tooling can plot), but
//! lets `andes exp <id>` show the *shape* of each figure inline.

/// A named series of (x, y) points.
#[derive(Debug, Clone)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(name: &str, points: Vec<(f64, f64)>) -> Self {
        Series { name: name.to_string(), points }
    }
}

const MARKS: &[char] = &['*', 'o', '+', 'x', '#', '@', '%', '&'];

/// Render series on a fixed-size character grid with axes and a legend.
pub fn line_plot(title: &str, xlabel: &str, ylabel: &str, series: &[Series]) -> String {
    render(title, xlabel, ylabel, series, 64, 20)
}

/// Render with explicit grid dimensions.
pub fn render(
    title: &str,
    xlabel: &str,
    ylabel: &str,
    series: &[Series],
    width: usize,
    height: usize,
) -> String {
    let all: Vec<(f64, f64)> = series
        .iter()
        .flat_map(|s| s.points.iter().copied())
        .filter(|(x, y)| x.is_finite() && y.is_finite())
        .collect();
    if all.is_empty() {
        return format!("{title}\n  (no data)\n");
    }
    let (mut xmin, mut xmax) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut ymin, mut ymax) = (f64::INFINITY, f64::NEG_INFINITY);
    for (x, y) in &all {
        xmin = xmin.min(*x);
        xmax = xmax.max(*x);
        ymin = ymin.min(*y);
        ymax = ymax.max(*y);
    }
    if (xmax - xmin).abs() < 1e-12 {
        xmax = xmin + 1.0;
    }
    if (ymax - ymin).abs() < 1e-12 {
        ymax = ymin + 1.0;
    }
    // Pad y range slightly so extremes are visible.
    let ypad = (ymax - ymin) * 0.05;
    let (ymin, ymax) = (ymin - ypad, ymax + ypad);

    let mut grid = vec![vec![' '; width]; height];
    for (si, s) in series.iter().enumerate() {
        let mark = MARKS[si % MARKS.len()];
        // Plot points, then connect consecutive points with interpolation.
        let to_cell = |x: f64, y: f64| -> (usize, usize) {
            let cx = ((x - xmin) / (xmax - xmin) * (width - 1) as f64).round() as usize;
            let cy = ((y - ymin) / (ymax - ymin) * (height - 1) as f64).round() as usize;
            (cx.min(width - 1), height - 1 - cy.min(height - 1))
        };
        let pts: Vec<(f64, f64)> =
            s.points.iter().copied().filter(|(x, y)| x.is_finite() && y.is_finite()).collect();
        for w in pts.windows(2) {
            let (x0, y0) = w[0];
            let (x1, y1) = w[1];
            let steps = (width * 2).max(2);
            for k in 0..=steps {
                let t = k as f64 / steps as f64;
                let (cx, cy) = to_cell(x0 + (x1 - x0) * t, y0 + (y1 - y0) * t);
                if grid[cy][cx] == ' ' {
                    grid[cy][cx] = '.';
                }
            }
        }
        for &(x, y) in &pts {
            let (cx, cy) = to_cell(x, y);
            grid[cy][cx] = mark;
        }
    }

    let mut out = String::new();
    out.push_str(&format!("  {title}\n"));
    let ylab_top = format!("{ymax:.3}");
    let ylab_bot = format!("{ymin:.3}");
    let lw = ylab_top.len().max(ylab_bot.len());
    for (r, row) in grid.iter().enumerate() {
        let label = if r == 0 {
            format!("{ylab_top:>lw$}")
        } else if r == height - 1 {
            format!("{ylab_bot:>lw$}")
        } else if r == height / 2 {
            let mid = format!("{:.3}", (ymin + ymax) / 2.0);
            format!("{mid:>lw$}")
        } else {
            " ".repeat(lw)
        };
        out.push_str(&format!("{label} |{}\n", row.iter().collect::<String>()));
    }
    out.push_str(&format!("{} +{}\n", " ".repeat(lw), "-".repeat(width)));
    out.push_str(&format!(
        "{}  {:<w2$}{:>w3$}\n",
        " ".repeat(lw),
        format!("{xmin:.2}"),
        format!("{xmax:.2}  ({xlabel})"),
        w2 = width / 2,
        w3 = width / 2,
    ));
    out.push_str(&format!("  y: {ylabel}   legend: "));
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!("{}={} ", MARKS[si % MARKS.len()], s.name));
    }
    out.push('\n');
    out
}

/// Simple horizontal bar chart for categorical comparisons.
pub fn bar_chart(title: &str, items: &[(String, f64)]) -> String {
    let mut out = format!("  {title}\n");
    let max = items.iter().map(|(_, v)| *v).fold(f64::NEG_INFINITY, f64::max).max(1e-12);
    let lw = items.iter().map(|(k, _)| k.len()).max().unwrap_or(0);
    for (k, v) in items {
        let n = ((v / max) * 40.0).round().max(0.0) as usize;
        out.push_str(&format!("  {k:>lw$} | {}{} {v:.4}\n", "█".repeat(n), if n == 0 { "·" } else { "" }));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nonempty() {
        let s = line_plot(
            "test",
            "x",
            "y",
            &[Series::new("a", vec![(0.0, 0.0), (1.0, 1.0), (2.0, 4.0)])],
        );
        assert!(s.contains("test"));
        assert!(s.contains('*'));
        assert!(s.contains("legend"));
    }

    #[test]
    fn empty_series_ok() {
        let s = line_plot("empty", "x", "y", &[Series::new("a", vec![])]);
        assert!(s.contains("no data"));
    }

    #[test]
    fn constant_series_ok() {
        let s = line_plot("const", "x", "y", &[Series::new("a", vec![(1.0, 5.0), (2.0, 5.0)])]);
        assert!(s.contains('*'));
    }

    #[test]
    fn nan_points_skipped() {
        let s = line_plot(
            "nan",
            "x",
            "y",
            &[Series::new("a", vec![(0.0, f64::NAN), (1.0, 2.0), (2.0, 3.0)])],
        );
        assert!(s.contains('*'));
    }

    #[test]
    fn bars() {
        let s = bar_chart("b", &[("one".into(), 1.0), ("two".into(), 2.0)]);
        assert!(s.contains("one"));
        assert!(s.contains('█'));
    }
}
