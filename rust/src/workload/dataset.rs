//! Synthetic ShareGPT-like datasets (paper §6.1, Fig. 9).
//!
//! The real ShareGPT dump is not available offline; we generate prompt /
//! response length pairs from lognormal fits matching the distributions
//! in Fig. 9:
//!
//! - **ShareGPT**: input median ≈ 90 tokens with a heavy tail (mean ≈
//!   170), output median ≈ 150 (mean ≈ 210), both truncated to 1k.
//! - **Multi-Round ShareGPT**: several conversation rounds concatenated,
//!   giving ≈3× longer inputs (mean ≈ 510, capped at 1k); output lengths
//!   match ShareGPT (the final response).
//!
//! The scheduler observes only (prompt_len, output_len), so matching the
//! marginals is what preserves the paper's behaviour (DESIGN.md §1).

use crate::util::rng::Rng;

/// Maximum context length of the OPT family (paper truncates to fit).
pub const MAX_CONTEXT: usize = 1024;

/// A single request's length profile.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LengthSample {
    pub prompt_tokens: usize,
    pub output_tokens: usize,
}

impl LengthSample {
    pub fn total(&self) -> usize {
        self.prompt_tokens + self.output_tokens
    }
}

/// Dataset families from the paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dataset {
    ShareGpt,
    MultiRoundShareGpt,
}

impl Dataset {
    pub fn name(&self) -> &'static str {
        match self {
            Dataset::ShareGpt => "ShareGPT",
            Dataset::MultiRoundShareGpt => "MultiRound-ShareGPT",
        }
    }

    pub fn by_name(name: &str) -> Option<Dataset> {
        match name {
            "sharegpt" | "ShareGPT" => Some(Dataset::ShareGpt),
            "multiround" | "multi-round" | "MultiRound-ShareGPT" => {
                Some(Dataset::MultiRoundShareGpt)
            }
            _ => None,
        }
    }

    /// Sample one request's prompt/output lengths.
    pub fn sample(&self, rng: &mut Rng) -> LengthSample {
        match self {
            Dataset::ShareGpt => {
                // lognormal(4.8, 1.0): median 122, mean ≈ 200.
                let prompt = rng.lognormal(4.8, 1.0).round() as usize;
                // lognormal(5.2, 0.85): median 181, mean ≈ 260.
                let output = rng.lognormal(5.2, 0.85).round() as usize;
                LengthSample {
                    prompt_tokens: prompt.clamp(4, MAX_CONTEXT / 2),
                    output_tokens: output.clamp(4, MAX_CONTEXT / 2),
                }
            }
            Dataset::MultiRoundShareGpt => {
                // Concatenate 2–5 rounds of ShareGPT-sized prompts +
                // responses (history), capped to fit the context window.
                let rounds = rng.range(2, 5);
                let mut prompt = 0usize;
                for _ in 0..rounds {
                    prompt += rng.lognormal(4.8, 1.0).round().max(4.0) as usize;
                    prompt += rng.lognormal(5.2, 0.85).round().max(4.0) as usize;
                }
                let output = rng.lognormal(5.2, 0.85).round() as usize;
                LengthSample {
                    prompt_tokens: prompt.clamp(16, MAX_CONTEXT / 2),
                    output_tokens: output.clamp(4, MAX_CONTEXT / 2),
                }
            }
        }
    }

    /// Sample a batch of length profiles.
    pub fn sample_many(&self, rng: &mut Rng, n: usize) -> Vec<LengthSample> {
        (0..n).map(|_| self.sample(rng)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::mean;

    fn means(ds: Dataset, n: usize) -> (f64, f64) {
        let mut rng = Rng::new(7);
        let samples = ds.sample_many(&mut rng, n);
        let p: Vec<f64> = samples.iter().map(|s| s.prompt_tokens as f64).collect();
        let o: Vec<f64> = samples.iter().map(|s| s.output_tokens as f64).collect();
        (mean(&p), mean(&o))
    }

    #[test]
    fn sharegpt_scale_matches_fig9() {
        let (p, o) = means(Dataset::ShareGpt, 20_000);
        assert!((120.0..260.0).contains(&p), "prompt mean {p}");
        assert!((180.0..330.0).contains(&o), "output mean {o}");
    }

    #[test]
    fn multiround_inputs_are_about_3x() {
        let (p1, o1) = means(Dataset::ShareGpt, 20_000);
        let (p3, o3) = means(Dataset::MultiRoundShareGpt, 20_000);
        let ratio = p3 / p1;
        assert!((2.0..4.5).contains(&ratio), "input ratio {ratio}");
        // Output distributions similar (within 25%).
        assert!((o3 / o1 - 1.0).abs() < 0.25, "output ratio {}", o3 / o1);
    }

    #[test]
    fn lengths_bounded_by_context() {
        let mut rng = Rng::new(3);
        for ds in [Dataset::ShareGpt, Dataset::MultiRoundShareGpt] {
            for s in ds.sample_many(&mut rng, 5000) {
                assert!(s.total() <= MAX_CONTEXT, "{:?}", s);
                assert!(s.prompt_tokens >= 4 && s.output_tokens >= 4);
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(11);
        let mut b = Rng::new(11);
        assert_eq!(
            Dataset::ShareGpt.sample_many(&mut a, 100),
            Dataset::ShareGpt.sample_many(&mut b, 100)
        );
    }

    #[test]
    fn by_name() {
        assert_eq!(Dataset::by_name("sharegpt"), Some(Dataset::ShareGpt));
        assert_eq!(Dataset::by_name("multiround"), Some(Dataset::MultiRoundShareGpt));
        assert_eq!(Dataset::by_name("x"), None);
    }
}
