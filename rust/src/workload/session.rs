//! Multi-turn conversational sessions (DESIGN.md §10).
//!
//! The Multi-Round ShareGPT dataset already *shapes* prompts as
//! concatenated conversation rounds, but every request still enters the
//! system as an isolated one-shot. This module generates explicit
//! `Session`s of `Turn`s: a user opens a session, sends a prompt, reads
//! the response, thinks, and sends the next prompt whose context is the
//! whole history so far (the **growing shared prefix**). The serving
//! side exploits that structure via KV prefix parking
//! ([`crate::coordinator::kv::KvCacheManager::park`]) and
//! session-affinity routing ([`crate::cluster::Cluster`]).
//!
//! Turn timing is open-loop but user-shaped: turn *k+1* arrives at
//! `arrival_k + expected_ttft + output_k / tds + think gap`, i.e. after
//! the user is expected to have read the previous response plus an
//! exponential think time. Under overload the previous turn may still
//! be running (or parked KV may have been evicted) when the next turn
//! arrives — the serving side must degrade gracefully to a cold
//! prefill, never depend on a hit.
//!
//! ```
//! use andes::workload::{ArrivalProcess, QoeTrace, SessionWorkload};
//!
//! let trace = SessionWorkload {
//!     num_sessions: 10,
//!     arrivals: ArrivalProcess::Poisson { rate: 0.5 },
//!     qoe_trace: QoeTrace::TextReading,
//!     min_turns: 2,
//!     max_turns: 4,
//!     think_time_mean: 5.0,
//!     seed: 7,
//! }
//! .generate();
//! assert!(trace.len() >= 20);
//! // Returning turns carry their shared prefix with the previous turn.
//! let returning = trace.iter().find(|r| r.session.unwrap().turn > 0).unwrap();
//! assert!(returning.session.unwrap().prefix_tokens > 0);
//! ```

use crate::qoe::spec::QoeSpec;
use crate::util::rng::Rng;

use super::dataset::MAX_CONTEXT;
use super::{ArrivalProcess, QoeTrace, RequestSpec};

/// A request's membership in a conversational session.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SessionInfo {
    /// Stable session key (the KV park / affinity key).
    pub session_id: u64,
    /// 0-based turn index within the session.
    pub turn: usize,
    /// Total turns the session will make; `usize::MAX` when unknown
    /// (live serving), in which case every turn may be followed by
    /// another and parking stays worthwhile.
    pub turns_total: usize,
    /// Leading prompt tokens shared with the previous turn's full
    /// context (its prompt + response) — the parkable prefix. 0 on the
    /// opening turn.
    pub prefix_tokens: usize,
}

impl SessionInfo {
    /// Whether this is a returning (non-opening) turn.
    pub fn is_returning(&self) -> bool {
        self.turn > 0
    }

    /// Whether another turn is expected after this one (parking pays
    /// off only then).
    pub fn expects_return(&self) -> bool {
        self.turn + 1 < self.turns_total
    }

    /// Portion of `parked_tokens` this turn can actually reuse: capped
    /// at the declared shared prefix; opening turns reuse nothing. The
    /// single definition keeps the simulated gateway, the live server,
    /// and the engine's claim agreeing on what a prefix is worth.
    pub fn usable_prefix(&self, parked_tokens: usize) -> usize {
        if self.is_returning() {
            parked_tokens.min(self.prefix_tokens)
        } else {
            0
        }
    }
}

/// Generator for multi-turn conversational workloads.
#[derive(Debug, Clone)]
pub struct SessionWorkload {
    pub num_sessions: usize,
    /// Arrival process of session *openings* (turn 0 of each session).
    pub arrivals: ArrivalProcess,
    /// One QoE spec per session (the same user reads every turn).
    pub qoe_trace: QoeTrace,
    /// Turns per session, drawn uniformly from `min_turns..=max_turns`.
    pub min_turns: usize,
    pub max_turns: usize,
    /// Mean think time between reading a response and sending the next
    /// prompt (exponential), seconds.
    pub think_time_mean: f64,
    pub seed: u64,
}

impl SessionWorkload {
    /// Generate the full trace: every turn of every session, merged and
    /// sorted by arrival, with dense ids in arrival order (the same
    /// contract as [`super::Workload::generate`]).
    pub fn generate(&self) -> Vec<RequestSpec> {
        assert!(self.min_turns >= 1 && self.min_turns <= self.max_turns);
        let mut rng = Rng::new(self.seed);
        let mut arr_rng = rng.fork();
        let mut len_rng = rng.fork();
        let mut qoe_rng = rng.fork();
        let mut think_rng = rng.fork();
        let starts = self.arrivals.generate(&mut arr_rng, self.num_sessions);
        let mut out: Vec<RequestSpec> = Vec::new();
        for (sid, start) in starts.into_iter().enumerate() {
            let qoe = self.qoe_trace.sample(&mut qoe_rng);
            let turns = len_rng.range(self.min_turns, self.max_turns);
            let mut arrival = start;
            // Full context of the previous turn (prompt + response) —
            // the prefix the next turn shares.
            let mut prefix = 0usize;
            for turn in 0..turns {
                let (new_prompt, output) = sample_turn_lengths(&mut len_rng);
                // The whole history rides along as the prompt; cap to
                // the model context, trimming the *oldest* history first
                // (a sliding window), so prefix + new + output fits.
                let budget = MAX_CONTEXT.saturating_sub(new_prompt + output);
                let kept_prefix = prefix.min(budget);
                let spec = RequestSpec {
                    id: 0, // assigned after the global sort
                    arrival,
                    prompt_tokens: kept_prefix + new_prompt,
                    output_tokens: output,
                    qoe,
                    session: Some(SessionInfo {
                        session_id: sid as u64,
                        turn,
                        turns_total: turns,
                        prefix_tokens: kept_prefix,
                    }),
                };
                prefix = spec.prompt_tokens + output;
                // Reading + thinking before the next turn.
                arrival += qoe.ttft
                    + output as f64 / qoe.tds
                    + think_rng.exponential(1.0 / self.think_time_mean.max(1e-9));
                out.push(spec);
            }
        }
        out.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
        for (id, spec) in out.iter_mut().enumerate() {
            spec.id = id;
        }
        out
    }
}

/// One turn's fresh user prompt and response lengths (ShareGPT-shaped
/// lognormals, the per-round marginals behind Multi-Round ShareGPT).
fn sample_turn_lengths(rng: &mut Rng) -> (usize, usize) {
    let prompt = (rng.lognormal(4.8, 1.0).round() as usize).clamp(4, MAX_CONTEXT / 4);
    let output = (rng.lognormal(5.2, 0.85).round() as usize).clamp(4, MAX_CONTEXT / 4);
    (prompt, output)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    fn wl(seed: u64) -> SessionWorkload {
        SessionWorkload {
            num_sessions: 50,
            arrivals: ArrivalProcess::Poisson { rate: 1.0 },
            qoe_trace: QoeTrace::TextReading,
            min_turns: 2,
            max_turns: 5,
            think_time_mean: 4.0,
            seed,
        }
    }

    #[test]
    fn turns_ordered_with_growing_prefix() {
        let trace = wl(1).generate();
        assert!(trace.windows(2).all(|w| w[1].arrival >= w[0].arrival));
        assert!(trace.iter().enumerate().all(|(i, r)| r.id == i));
        // Group by session and check per-session structure.
        let mut by_session: HashMap<u64, Vec<&RequestSpec>> = HashMap::new();
        for r in &trace {
            by_session.entry(r.session.unwrap().session_id).or_default().push(r);
        }
        assert_eq!(by_session.len(), 50);
        for turns in by_session.values() {
            let mut turns = turns.clone();
            turns.sort_by_key(|r| r.session.unwrap().turn);
            let total = turns[0].session.unwrap().turns_total;
            assert!((2..=5).contains(&total));
            assert_eq!(turns.len(), total);
            for (k, r) in turns.iter().enumerate() {
                let s = r.session.unwrap();
                assert_eq!(s.turn, k);
                assert_eq!(s.turns_total, total);
                assert!(s.prefix_tokens <= r.prompt_tokens);
                assert!(r.prompt_tokens + r.output_tokens <= MAX_CONTEXT);
                if k == 0 {
                    assert_eq!(s.prefix_tokens, 0);
                    assert!(!s.is_returning());
                } else {
                    assert!(s.is_returning());
                    // The prefix is the previous turn's full context,
                    // possibly trimmed by the sliding window.
                    let prev = &turns[k - 1];
                    assert!(
                        s.prefix_tokens
                            <= prev.prompt_tokens + prev.output_tokens,
                        "prefix larger than the history it claims to share"
                    );
                    assert!(s.prefix_tokens > 0, "returning turn must share history");
                    // Turns arrive strictly after the previous one.
                    assert!(r.arrival > prev.arrival);
                }
                assert_eq!(s.expects_return(), k + 1 < total);
            }
            // The same user: one QoE spec across the session.
            assert!(turns.iter().all(|r| r.qoe == turns[0].qoe));
        }
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        assert_eq!(wl(3).generate(), wl(3).generate());
        assert_ne!(wl(3).generate(), wl(4).generate());
    }

    #[test]
    fn think_time_spaces_turns() {
        let trace = wl(5).generate();
        for r in &trace {
            let s = r.session.unwrap();
            if s.turn == 0 {
                continue;
            }
            // Each returning turn waited at least the reading time of
            // *some* response; spot-check a loose lower bound > 0.
            assert!(r.arrival > 0.0);
        }
        // Sessions overlap: the trace is not one session at a time.
        let first = trace.iter().position(|r| r.session.unwrap().turn > 0).unwrap();
        assert!(
            trace[first + 1..].iter().any(|r| r.session.unwrap().turn == 0),
            "session openings must interleave with returning turns"
        );
    }
}
