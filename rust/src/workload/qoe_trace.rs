//! QoE requirement traces (paper §6.1, Tables 1–2).
//!
//! Expected TTFT is 1 second for all requests; expected TDS is drawn from
//! the paper's demographic tables, converted from words-per-minute to
//! tokens/second with ChatGPT's average ratio of ~0.75 words/token:
//!
//! `tokens/s = WPM / 60 / 0.75`
//!
//! Table 1 (reading, by age group) drives the text-chat trace; Table 2
//! (speaking, by language) drives the voice-chat trace.

use crate::qoe::spec::QoeSpec;
use crate::util::rng::Rng;

/// Average words per token for ChatGPT-style BPE (paper cites [38]).
pub const WORDS_PER_TOKEN: f64 = 0.75;

/// Convert words-per-minute to tokens-per-second.
pub fn wpm_to_tps(wpm: f64) -> f64 {
    wpm / 60.0 / WORDS_PER_TOKEN
}

/// Paper Table 1: reading speed (WPM) and population share by age group.
pub const READING_SPEED_TABLE: &[(&str, f64, f64)] = &[
    ("18-24", 0.280, 236.0),
    ("25-44", 0.519, 200.0),
    ("45-54", 0.112, 192.0),
    ("55-64", 0.056, 185.0),
    ("65+", 0.033, 175.0),
];

/// Paper Table 2: speaking speed (WPM) and usage share by language.
pub const SPEAKING_SPEED_TABLE: &[(&str, f64, f64)] = &[
    ("English", 0.793, 150.0),
    ("Chinese", 0.070, 158.0),
    ("Korean", 0.069, 150.0),
    ("French", 0.036, 195.0),
    ("Spanish", 0.032, 218.0),
];

/// QoE requirement trace kinds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum QoeTrace {
    /// Text chat: expected TDS from the reading-speed table.
    TextReading,
    /// Voice chat: expected TDS from the speaking-speed table (Fig. 15c).
    VoiceSpeaking,
    /// Fixed TDS for controlled experiments.
    Fixed { ttft: f64, tds: f64 },
    /// API price tiers (paper §6.1: "a higher per-token price provides
    /// faster TDS"): premium 20% (TDS 6.5, TTFT 0.5; just under the
    /// saturated per-stream speed so the contract is feasible), standard 50%
    /// (reading speed), economy 30% (TDS 2.5, relaxed TTFT 2s).
    Tiered,
}

impl QoeTrace {
    pub fn by_name(name: &str) -> Option<QoeTrace> {
        match name {
            "text" | "reading" => Some(QoeTrace::TextReading),
            "voice" | "speaking" => Some(QoeTrace::VoiceSpeaking),
            "tiered" | "tiers" => Some(QoeTrace::Tiered),
            _ => None,
        }
    }

    /// Sample one request's QoE spec.
    pub fn sample(&self, rng: &mut Rng) -> QoeSpec {
        match self {
            QoeTrace::TextReading => {
                let weights: Vec<f64> = READING_SPEED_TABLE.iter().map(|r| r.1).collect();
                let idx = rng.categorical(&weights);
                QoeSpec::new(1.0, wpm_to_tps(READING_SPEED_TABLE[idx].2))
            }
            QoeTrace::VoiceSpeaking => {
                let weights: Vec<f64> = SPEAKING_SPEED_TABLE.iter().map(|r| r.1).collect();
                let idx = rng.categorical(&weights);
                QoeSpec::new(1.0, wpm_to_tps(SPEAKING_SPEED_TABLE[idx].2))
            }
            QoeTrace::Fixed { ttft, tds } => QoeSpec::new(*ttft, *tds),
            QoeTrace::Tiered => match rng.categorical(&[0.2, 0.5, 0.3]) {
                0 => QoeSpec::new(0.5, 6.5), // premium
                1 => QoeSpec::new(1.0, wpm_to_tps(200.0)), // standard
                _ => QoeSpec::new(2.0, 2.5),  // economy
            },
        }
    }

    /// Population-average expected TDS of this trace (tokens/s).
    pub fn mean_tds(&self) -> f64 {
        match self {
            QoeTrace::TextReading => {
                let total: f64 = READING_SPEED_TABLE.iter().map(|r| r.1).sum();
                READING_SPEED_TABLE.iter().map(|r| r.1 * wpm_to_tps(r.2)).sum::<f64>() / total
            }
            QoeTrace::VoiceSpeaking => {
                let total: f64 = SPEAKING_SPEED_TABLE.iter().map(|r| r.1).sum();
                SPEAKING_SPEED_TABLE.iter().map(|r| r.1 * wpm_to_tps(r.2)).sum::<f64>() / total
            }
            QoeTrace::Fixed { tds, .. } => *tds,
            QoeTrace::Tiered => 0.2 * 6.5 + 0.5 * wpm_to_tps(200.0) + 0.3 * 2.5,
        }
    }

    /// Tier label for a sampled spec (Tiered trace only).
    pub fn tier_of(spec: &QoeSpec) -> &'static str {
        if spec.tds >= 6.5 {
            "premium"
        } else if spec.tds <= 2.5 {
            "economy"
        } else {
            "standard"
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::mean;

    #[test]
    fn wpm_conversion_matches_paper() {
        // Paper §2.2: ~200 WPM reading ≈ 4.8 tok/s was derived with a
        // slightly different ratio; ours lands in the same band.
        let reading = QoeTrace::TextReading.mean_tds();
        assert!((4.0..5.2).contains(&reading), "reading tds {reading}");
        let speaking = QoeTrace::VoiceSpeaking.mean_tds();
        assert!((3.0..3.9).contains(&speaking), "speaking tds {speaking}");
        assert!(speaking < reading);
    }

    #[test]
    fn shares_sum_to_one() {
        let r: f64 = READING_SPEED_TABLE.iter().map(|x| x.1).sum();
        let s: f64 = SPEAKING_SPEED_TABLE.iter().map(|x| x.1).sum();
        assert!((r - 1.0).abs() < 0.01, "reading shares {r}");
        assert!((s - 1.0).abs() < 0.01, "speaking shares {s}");
    }

    #[test]
    fn samples_follow_mixture() {
        let mut rng = Rng::new(5);
        let t = QoeTrace::TextReading;
        let samples: Vec<f64> = (0..50_000).map(|_| t.sample(&mut rng).tds).collect();
        assert!((mean(&samples) - t.mean_tds()).abs() < 0.05);
        // All values come from the table.
        let valid: Vec<f64> =
            READING_SPEED_TABLE.iter().map(|r| wpm_to_tps(r.2)).collect();
        for s in &samples[..100] {
            assert!(valid.iter().any(|v| (v - s).abs() < 1e-9));
        }
    }

    #[test]
    fn fixed_trace() {
        let mut rng = Rng::new(6);
        let t = QoeTrace::Fixed { ttft: 0.5, tds: 7.0 };
        let s = t.sample(&mut rng);
        assert_eq!(s.ttft, 0.5);
        assert_eq!(s.tds, 7.0);
        assert_eq!(t.mean_tds(), 7.0);
    }

    #[test]
    fn ttft_is_one_second() {
        let mut rng = Rng::new(7);
        assert_eq!(QoeTrace::TextReading.sample(&mut rng).ttft, 1.0);
        assert_eq!(QoeTrace::VoiceSpeaking.sample(&mut rng).ttft, 1.0);
    }
}
