//! Workload generation: datasets (lengths), arrival processes, and QoE
//! requirement traces, combined into full request traces for the engine.
//! Multi-turn conversational sessions live in [`session`].

pub mod arrivals;
pub mod dataset;
pub mod qoe_trace;
pub mod session;

pub use arrivals::ArrivalProcess;
pub use dataset::{Dataset, LengthSample};
pub use qoe_trace::QoeTrace;
pub use session::{SessionInfo, SessionWorkload};

use crate::qoe::spec::QoeSpec;
use crate::util::rng::Rng;

/// Parse a workload trace back from the CSV produced by
/// `andes workload --out` (columns: id, arrival, prompt_tokens,
/// output_tokens, ttft_expected, tds_expected). Enables record/replay:
/// generate once, replay identically across schedulers or code versions.
pub fn parse_trace_csv(text: &str) -> anyhow::Result<Vec<RequestSpec>> {
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || (lineno == 0 && line.starts_with("id,")) {
            continue;
        }
        let f: Vec<&str> = line.split(',').collect();
        anyhow::ensure!(f.len() == 6, "line {}: expected 6 fields, got {}", lineno + 1, f.len());
        let parse_f = |i: usize| -> anyhow::Result<f64> {
            f[i].parse::<f64>()
                .map_err(|_| anyhow::anyhow!("line {}: bad number '{}'", lineno + 1, f[i]))
        };
        let arrival = parse_f(1)?;
        // A non-finite arrival is never ingested by the engine
        // (`NaN <= now` is false) — reject it loudly here rather than
        // rely on the engine's defensive clamp-to-origin.
        anyhow::ensure!(
            arrival.is_finite(),
            "line {}: non-finite arrival '{}'",
            lineno + 1,
            f[1]
        );
        out.push(RequestSpec {
            id: parse_f(0)? as usize,
            arrival,
            prompt_tokens: parse_f(2)? as usize,
            output_tokens: parse_f(3)? as usize,
            qoe: QoeSpec::new(parse_f(4)?, parse_f(5)?),
            session: None,
        });
    }
    out.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
    Ok(out)
}

/// One request as described by a workload trace, before it enters the
/// serving system.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestSpec {
    /// Trace-assigned id (dense, in arrival order).
    pub id: usize,
    /// Absolute arrival time, seconds from trace start.
    pub arrival: f64,
    pub prompt_tokens: usize,
    /// Ground-truth response length (the engine "discovers" it token by
    /// token; schedulers must not read it — mirrors the paper's unknown
    /// output length).
    pub output_tokens: usize,
    pub qoe: QoeSpec,
    /// Conversational-session membership (DESIGN.md §10); `None` for
    /// one-shot requests, which behave exactly as before.
    pub session: Option<SessionInfo>,
}

/// A complete workload description.
#[derive(Debug, Clone)]
pub struct Workload {
    pub dataset: Dataset,
    pub arrivals: ArrivalProcess,
    pub qoe_trace: QoeTrace,
    pub num_requests: usize,
    pub seed: u64,
}

impl Workload {
    /// Generate the full request trace.
    pub fn generate(&self) -> Vec<RequestSpec> {
        let mut rng = Rng::new(self.seed);
        let mut arr_rng = rng.fork();
        let mut len_rng = rng.fork();
        let mut qoe_rng = rng.fork();
        let times = self.arrivals.generate(&mut arr_rng, self.num_requests);
        times
            .into_iter()
            .enumerate()
            .map(|(id, arrival)| {
                let len = self.dataset.sample(&mut len_rng);
                RequestSpec {
                    id,
                    arrival,
                    prompt_tokens: len.prompt_tokens,
                    output_tokens: len.output_tokens,
                    qoe: self.qoe_trace.sample(&mut qoe_rng),
                    session: None,
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wl(seed: u64) -> Workload {
        Workload {
            dataset: Dataset::ShareGpt,
            arrivals: ArrivalProcess::Poisson { rate: 2.0 },
            qoe_trace: QoeTrace::TextReading,
            num_requests: 500,
            seed,
        }
    }

    #[test]
    fn generates_requested_count_in_order() {
        let reqs = wl(1).generate();
        assert_eq!(reqs.len(), 500);
        assert!(reqs.windows(2).all(|w| w[1].arrival >= w[0].arrival));
        assert!(reqs.iter().enumerate().all(|(i, r)| r.id == i));
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        assert_eq!(wl(1).generate(), wl(1).generate());
        assert_ne!(wl(1).generate(), wl(2).generate());
    }

    #[test]
    fn trace_csv_roundtrip() {
        let reqs = wl(5).generate();
        let mut csv = String::from(
            "id,arrival,prompt_tokens,output_tokens,ttft_expected,tds_expected\n",
        );
        for r in &reqs {
            csv.push_str(&format!(
                "{},{},{},{},{},{}\n",
                r.id, r.arrival, r.prompt_tokens, r.output_tokens, r.qoe.ttft, r.qoe.tds
            ));
        }
        let back = parse_trace_csv(&csv).unwrap();
        assert_eq!(back.len(), reqs.len());
        for (a, b) in reqs.iter().zip(&back) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.prompt_tokens, b.prompt_tokens);
            assert_eq!(a.output_tokens, b.output_tokens);
            assert!((a.arrival - b.arrival).abs() < 1e-9);
            assert!((a.qoe.tds - b.qoe.tds).abs() < 1e-9);
        }
    }

    #[test]
    fn trace_csv_rejects_malformed() {
        assert!(parse_trace_csv("1,2,3").is_err());
        assert!(parse_trace_csv("a,b,c,d,e,f").is_err());
        assert!(parse_trace_csv("").unwrap().is_empty());
        // Non-finite arrivals would hang the engine's ingest loop.
        assert!(parse_trace_csv("0,NaN,100,50,1.0,4.8").is_err());
        assert!(parse_trace_csv("0,inf,100,50,1.0,4.8").is_err());
    }

    #[test]
    fn component_streams_independent() {
        // Changing the arrival process must not change sampled lengths.
        let a = wl(3).generate();
        let mut w = wl(3);
        w.arrivals = ArrivalProcess::Gamma { rate: 2.0, cv: 3.0 };
        let b = w.generate();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt_tokens, y.prompt_tokens);
            assert_eq!(x.output_tokens, y.output_tokens);
            assert_eq!(x.qoe, y.qoe);
        }
    }
}
