//! Request arrival processes (paper §6.1, §6.4).
//!
//! - Poisson arrivals at a configurable rate (the main evaluation).
//! - Gamma-renewal arrivals with coefficient of variation 3 (the bursty
//!   robustness workload of Fig. 15b): inter-arrival ~ Gamma(k=1/CV²,
//!   θ chosen so the mean is 1/rate).

use crate::util::rng::Rng;

/// An arrival process generating monotone timestamps (seconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// Poisson process with rate `req/s` (exponential inter-arrivals).
    Poisson { rate: f64 },
    /// Gamma renewal process with rate `req/s` and coefficient of
    /// variation `cv` (cv = 1 degenerates to Poisson).
    Gamma { rate: f64, cv: f64 },
}

impl ArrivalProcess {
    pub fn rate(&self) -> f64 {
        match self {
            ArrivalProcess::Poisson { rate } => *rate,
            ArrivalProcess::Gamma { rate, .. } => *rate,
        }
    }

    /// Sample the next inter-arrival gap.
    pub fn next_gap(&self, rng: &mut Rng) -> f64 {
        match self {
            ArrivalProcess::Poisson { rate } => rng.exponential(*rate),
            ArrivalProcess::Gamma { rate, cv } => {
                // Gamma(k, θ): mean kθ = 1/rate, CV = 1/√k ⇒ k = 1/cv².
                let k = 1.0 / (cv * cv);
                let theta = 1.0 / (rate * k);
                rng.gamma(k, theta)
            }
        }
    }

    /// Generate `n` absolute arrival timestamps starting at 0.
    pub fn generate(&self, rng: &mut Rng, n: usize) -> Vec<f64> {
        let mut t = 0.0;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            t += self.next_gap(rng);
            out.push(t);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::{mean, std_dev};

    #[test]
    fn poisson_rate_holds() {
        let mut rng = Rng::new(1);
        let p = ArrivalProcess::Poisson { rate: 3.3 };
        let ts = p.generate(&mut rng, 50_000);
        let duration = *ts.last().unwrap();
        let measured = ts.len() as f64 / duration;
        assert!((measured - 3.3).abs() < 0.1, "measured rate {measured}");
    }

    #[test]
    fn gamma_cv_holds() {
        let mut rng = Rng::new(2);
        let p = ArrivalProcess::Gamma { rate: 2.0, cv: 3.0 };
        let gaps: Vec<f64> = (0..200_000).map(|_| p.next_gap(&mut rng)).collect();
        let m = mean(&gaps);
        let cv = std_dev(&gaps) / m;
        assert!((m - 0.5).abs() < 0.02, "mean gap {m}");
        assert!((cv - 3.0).abs() < 0.15, "cv {cv}");
    }

    #[test]
    fn gamma_cv1_is_poisson_like() {
        let mut rng = Rng::new(3);
        let p = ArrivalProcess::Gamma { rate: 2.0, cv: 1.0 };
        let gaps: Vec<f64> = (0..100_000).map(|_| p.next_gap(&mut rng)).collect();
        let cv = std_dev(&gaps) / mean(&gaps);
        assert!((cv - 1.0).abs() < 0.05, "cv {cv}");
    }

    #[test]
    fn timestamps_monotone() {
        let mut rng = Rng::new(4);
        for p in [
            ArrivalProcess::Poisson { rate: 5.0 },
            ArrivalProcess::Gamma { rate: 5.0, cv: 3.0 },
        ] {
            let ts = p.generate(&mut rng, 1000);
            assert!(ts.windows(2).all(|w| w[1] >= w[0]));
            assert!(ts[0] > 0.0);
        }
    }
}
