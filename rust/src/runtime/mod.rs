//! Rust-side model runtime: PJRT artifact loading/execution, the byte
//! tokenizer, and sampling. Python never runs on this path — the
//! artifacts are self-contained HLO with baked weights.

pub mod engine;
pub mod sampler;
pub mod tokenizer;

pub use engine::{ModelMeta, ModelRuntime, PrefillResult};
pub use sampler::{sample, Sampling};
pub use tokenizer::ByteTokenizer;
