//! PJRT model runtime: load the AOT HLO artifacts and execute them.
//!
//! Wraps the `xla` crate (PJRT C API): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`. One
//! compiled executable per (phase, batch size); the engine rounds a
//! logical batch up to the nearest compiled size and pads.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Model dimensions parsed from artifacts/meta.json.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub max_seq: usize,
    pub pad_token: u32,
    pub eos_token: u32,
    pub prefill_batches: Vec<usize>,
    pub decode_batches: Vec<usize>,
}

impl ModelMeta {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).context("parsing meta.json")?;
        let usize_field = |k: &str| -> Result<usize> {
            j.get(k)
                .as_u64()
                .map(|x| x as usize)
                .with_context(|| format!("meta.json missing '{k}'"))
        };
        let batches = |k: &str| -> Result<Vec<usize>> {
            Ok(j.get(k)
                .as_arr()
                .with_context(|| format!("meta.json missing '{k}'"))?
                .iter()
                .filter_map(|v| v.as_u64().map(|x| x as usize))
                .collect())
        };
        Ok(ModelMeta {
            vocab: usize_field("vocab")?,
            d_model: usize_field("d_model")?,
            n_layers: usize_field("n_layers")?,
            n_heads: usize_field("n_heads")?,
            d_head: usize_field("d_head")?,
            max_seq: usize_field("max_seq")?,
            pad_token: usize_field("pad_token")? as u32,
            eos_token: usize_field("eos_token")? as u32,
            prefill_batches: batches("prefill_batches")?,
            decode_batches: batches("decode_batches")?,
        })
    }

    /// Elements in one sequence's KV cache (per K or V): L·H·S·d.
    pub fn kv_elems_per_seq(&self) -> usize {
        self.n_layers * self.n_heads * self.max_seq * self.d_head
    }
}

/// Result of a prefill call for one sequence.
pub struct PrefillResult {
    /// Logits at the last prompt position, [vocab].
    pub logits: Vec<f32>,
    /// K cache [L, H, S, d] flattened, this sequence only.
    pub k_cache: Vec<f32>,
    /// V cache likewise.
    pub v_cache: Vec<f32>,
}

/// The loaded model: PJRT client + compiled executables.
pub struct ModelRuntime {
    pub meta: ModelMeta,
    client: xla::PjRtClient,
    prefill_exes: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    decode_exes: BTreeMap<usize, xla::PjRtLoadedExecutable>,
}

impl ModelRuntime {
    /// Load every artifact in `dir` and compile.
    pub fn load(dir: &Path) -> Result<Self> {
        let meta = ModelMeta::load(&dir.join("meta.json"))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut prefill_exes = BTreeMap::new();
        let mut decode_exes = BTreeMap::new();
        for &b in &meta.prefill_batches {
            let path = dir.join(format!("prefill_b{b}.hlo.txt"));
            prefill_exes.insert(b, Self::compile(&client, &path)?);
        }
        for &b in &meta.decode_batches {
            let path = dir.join(format!("decode_b{b}.hlo.txt"));
            decode_exes.insert(b, Self::compile(&client, &path)?);
        }
        if prefill_exes.is_empty() || decode_exes.is_empty() {
            bail!("no artifacts found in {}", dir.display());
        }
        Ok(ModelRuntime { meta, client, prefill_exes, decode_exes })
    }

    /// Default artifacts directory: $ANDES_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("ANDES_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    fn compile(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e}", path.display()))
    }

    /// Smallest compiled batch size ≥ n (or the largest available).
    fn pick_batch(sizes: &BTreeMap<usize, xla::PjRtLoadedExecutable>, n: usize) -> usize {
        for (&b, _) in sizes.iter() {
            if b >= n {
                return b;
            }
        }
        *sizes.keys().last().unwrap()
    }

    /// Largest compiled decode batch (the engine chunks bigger batches).
    pub fn max_decode_batch(&self) -> usize {
        *self.decode_exes.keys().last().unwrap()
    }

    /// Prefill a set of prompts (each padded to max_seq internally).
    /// Returns one PrefillResult per prompt, in order.
    pub fn prefill(&self, prompts: &[Vec<u32>]) -> Result<Vec<PrefillResult>> {
        let m = &self.meta;
        let mut results = Vec::with_capacity(prompts.len());
        let mut i = 0;
        while i < prompts.len() {
            let remaining = prompts.len() - i;
            let b = Self::pick_batch(&self.prefill_exes, remaining);
            let n = remaining.min(b);
            let chunk = &prompts[i..i + n];
            // Assemble padded token matrix [b, S] and lengths [b].
            let mut tokens = vec![m.pad_token as i32; b * m.max_seq];
            let mut lengths = vec![1i32; b];
            for (row, p) in chunk.iter().enumerate() {
                anyhow::ensure!(
                    p.len() <= m.max_seq,
                    "prompt of {} tokens exceeds max_seq {}",
                    p.len(),
                    m.max_seq
                );
                for (col, &t) in p.iter().enumerate() {
                    tokens[row * m.max_seq + col] = t as i32;
                }
                lengths[row] = p.len().max(1) as i32;
            }
            let tokens_lit =
                xla::Literal::vec1(&tokens).reshape(&[b as i64, m.max_seq as i64])?;
            let lengths_lit = xla::Literal::vec1(&lengths);
            let exe = &self.prefill_exes[&b];
            let out = exe.execute::<xla::Literal>(&[tokens_lit, lengths_lit])?[0][0]
                .to_literal_sync()?;
            let parts = out.to_tuple()?;
            anyhow::ensure!(parts.len() == 3, "prefill output arity {}", parts.len());
            let logits: Vec<f32> = parts[0].to_vec()?;
            let k_all: Vec<f32> = parts[1].to_vec()?;
            let v_all: Vec<f32> = parts[2].to_vec()?;
            for (row, _) in chunk.iter().enumerate() {
                results.push(PrefillResult {
                    logits: logits[row * m.vocab..(row + 1) * m.vocab].to_vec(),
                    k_cache: extract_seq(&k_all, row, b, m),
                    v_cache: extract_seq(&v_all, row, b, m),
                });
            }
            i += n;
        }
        Ok(results)
    }

    /// Low-level decode step on pre-assembled batch literals.
    ///
    /// `tokens`/`positions` are padded to the executable batch size `b`
    /// (which must be one of the compiled sizes); `k`/`v` have shape
    /// [L, b, H, S, d]. Returns (flat logits [b·vocab], new k, new v) —
    /// the returned KV literals can be fed straight back into the next
    /// call, which is what lets the serving hot path skip the
    /// host-side extract/insert copies entirely when batch membership
    /// is stable (see EXPERIMENTS.md §Perf).
    pub fn decode_literals(
        &self,
        tokens: &[i32],
        positions: &[i32],
        k: xla::Literal,
        v: xla::Literal,
        b: usize,
    ) -> Result<(Vec<f32>, xla::Literal, xla::Literal)> {
        anyhow::ensure!(tokens.len() == b && positions.len() == b, "padded batch mismatch");
        let exe = self
            .decode_exes
            .get(&b)
            .with_context(|| format!("no decode executable for batch {b}"))?;
        let tokens_lit = xla::Literal::vec1(tokens);
        let positions_lit = xla::Literal::vec1(positions);
        let out = exe
            .execute::<xla::Literal>(&[tokens_lit, positions_lit, k, v])?[0][0]
            .to_literal_sync()?;
        let mut parts = out.to_tuple()?;
        anyhow::ensure!(parts.len() == 3, "decode output arity {}", parts.len());
        let v_new = parts.pop().unwrap();
        let k_new = parts.pop().unwrap();
        let logits: Vec<f32> = parts[0].to_vec()?;
        Ok((logits, k_new, v_new))
    }

    /// Compiled decode batch size for a logical batch of `n` (rounds up).
    pub fn decode_exec_batch(&self, n: usize) -> usize {
        Self::pick_batch(&self.decode_exes, n)
    }

    /// One decode step for a batch of sequences.
    ///
    /// `entries`: per sequence (last_token, position, &k_cache, &v_cache)
    /// where the caches are per-sequence [L, H, S, d] flats.
    /// Returns (logits[vocab], new_k, new_v) per sequence.
    #[allow(clippy::type_complexity)]
    pub fn decode(
        &self,
        entries: &[(u32, usize, &[f32], &[f32])],
    ) -> Result<Vec<(Vec<f32>, Vec<f32>, Vec<f32>)>> {
        let m = &self.meta;
        let mut results = Vec::with_capacity(entries.len());
        let mut i = 0;
        while i < entries.len() {
            let remaining = entries.len() - i;
            let b = Self::pick_batch(&self.decode_exes, remaining);
            let n = remaining.min(b);
            let chunk = &entries[i..i + n];

            let mut tokens = vec![m.pad_token as i32; b];
            let mut positions = vec![0i32; b];
            let per_seq = m.kv_elems_per_seq();
            let mut k_batch = vec![0f32; b * per_seq];
            let mut v_batch = vec![0f32; b * per_seq];
            for (row, (tok, pos, k, v)) in chunk.iter().enumerate() {
                tokens[row] = *tok as i32;
                positions[row] = *pos as i32;
                insert_seq(&mut k_batch, k, row, b, m);
                insert_seq(&mut v_batch, v, row, b, m);
            }
            let kv_dims = [
                m.n_layers as i64,
                b as i64,
                m.n_heads as i64,
                m.max_seq as i64,
                m.d_head as i64,
            ];
            let tokens_lit = xla::Literal::vec1(&tokens);
            let positions_lit = xla::Literal::vec1(&positions);
            let k_lit = xla::Literal::vec1(&k_batch).reshape(&kv_dims)?;
            let v_lit = xla::Literal::vec1(&v_batch).reshape(&kv_dims)?;
            let exe = &self.decode_exes[&b];
            let out = exe
                .execute::<xla::Literal>(&[tokens_lit, positions_lit, k_lit, v_lit])?[0][0]
                .to_literal_sync()?;
            let parts = out.to_tuple()?;
            anyhow::ensure!(parts.len() == 3, "decode output arity {}", parts.len());
            let logits: Vec<f32> = parts[0].to_vec()?;
            let k_all: Vec<f32> = parts[1].to_vec()?;
            let v_all: Vec<f32> = parts[2].to_vec()?;
            for row in 0..n {
                results.push((
                    logits[row * m.vocab..(row + 1) * m.vocab].to_vec(),
                    extract_seq(&k_all, row, b, m),
                    extract_seq(&v_all, row, b, m),
                ));
            }
            i += n;
        }
        Ok(results)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

/// Extract sequence `row`'s [L, H, S, d] slice from a batched
/// [L, B, H, S, d] flat buffer.
pub fn extract_seq(batched: &[f32], row: usize, b: usize, m: &ModelMeta) -> Vec<f32> {
    let inner = m.n_heads * m.max_seq * m.d_head; // per (layer, seq)
    let mut out = Vec::with_capacity(m.n_layers * inner);
    for layer in 0..m.n_layers {
        let start = (layer * b + row) * inner;
        out.extend_from_slice(&batched[start..start + inner]);
    }
    out
}

/// Inverse of `extract_seq`.
pub fn insert_seq(batched: &mut [f32], seq: &[f32], row: usize, b: usize, m: &ModelMeta) {
    let inner = m.n_heads * m.max_seq * m.d_head;
    for layer in 0..m.n_layers {
        let dst = (layer * b + row) * inner;
        let src = layer * inner;
        batched[dst..dst + inner].copy_from_slice(&seq[src..src + inner]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> ModelMeta {
        ModelMeta {
            vocab: 8,
            d_model: 4,
            n_layers: 2,
            n_heads: 2,
            d_head: 2,
            max_seq: 4,
            pad_token: 0,
            eos_token: 1,
            prefill_batches: vec![1, 2],
            decode_batches: vec![1, 2, 4],
        }
    }

    #[test]
    fn seq_roundtrip() {
        let m = meta();
        let b = 3;
        let per_seq = m.kv_elems_per_seq();
        let mut batched = vec![0f32; b * per_seq];
        let seq: Vec<f32> = (0..per_seq).map(|x| x as f32).collect();
        insert_seq(&mut batched, &seq, 1, b, &m);
        let back = extract_seq(&batched, 1, b, &m);
        assert_eq!(back, seq);
        // Other rows untouched.
        assert_eq!(extract_seq(&batched, 0, b, &m), vec![0f32; per_seq]);
    }

    #[test]
    fn meta_parses_json() {
        let dir = std::env::temp_dir().join("andes_meta_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("meta.json");
        std::fs::write(
            &path,
            r#"{"vocab":512,"d_model":128,"n_layers":4,"n_heads":8,"d_head":16,
               "max_seq":256,"pad_token":0,"eos_token":1,
               "prefill_batches":[1,2,4],"decode_batches":[1,2,4,8,16]}"#,
        )
        .unwrap();
        let m = ModelMeta::load(&path).unwrap();
        assert_eq!(m.vocab, 512);
        assert_eq!(m.decode_batches, vec![1, 2, 4, 8, 16]);
        assert_eq!(m.kv_elems_per_seq(), 4 * 8 * 256 * 16);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
