//! Byte-level tokenizer for the tiny-OPT model.
//!
//! Token ids: 0 = PAD, 1 = EOS, 2..=257 = raw bytes, the rest of the
//! 512-entry vocabulary is unused headroom. Trivially reversible, no
//! merges — the model is a random-weight demo; the serving stack around
//! it is what's under test.

/// Reserved ids (must match python/compile/model.py ModelConfig).
pub const PAD_TOKEN: u32 = 0;
pub const EOS_TOKEN: u32 = 1;
const BYTE_BASE: u32 = 2;

/// Byte-level tokenizer.
#[derive(Debug, Clone, Copy, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub fn new() -> Self {
        ByteTokenizer
    }

    /// Encode text to token ids.
    pub fn encode(&self, text: &str) -> Vec<u32> {
        text.bytes().map(|b| b as u32 + BYTE_BASE).collect()
    }

    /// Decode token ids back to text; PAD/EOS and out-of-range ids are
    /// skipped, invalid UTF-8 is replaced.
    pub fn decode(&self, tokens: &[u32]) -> String {
        let bytes: Vec<u8> = tokens
            .iter()
            .filter_map(|&t| {
                if (BYTE_BASE..BYTE_BASE + 256).contains(&t) {
                    Some((t - BYTE_BASE) as u8)
                } else {
                    None
                }
            })
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }

    /// Decode a single token (streaming); empty for specials.
    pub fn decode_one(&self, token: u32) -> String {
        self.decode(&[token])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = ByteTokenizer::new();
        let ids = t.encode("hello, world!");
        assert_eq!(ids.len(), 13);
        assert_eq!(t.decode(&ids), "hello, world!");
    }

    #[test]
    fn roundtrip_utf8() {
        let t = ByteTokenizer::new();
        let s = "héllo 世界 😀";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn specials_are_skipped() {
        let t = ByteTokenizer::new();
        let mut ids = t.encode("ab");
        ids.push(EOS_TOKEN);
        ids.insert(0, PAD_TOKEN);
        assert_eq!(t.decode(&ids), "ab");
    }

    #[test]
    fn ids_in_vocab_range() {
        let t = ByteTokenizer::new();
        for id in t.encode("\u{0}\u{7f}ÿ") {
            assert!(id >= 2 && id < 512);
        }
    }
}
