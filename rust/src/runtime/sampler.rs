//! Token sampling from model logits.

use crate::util::rng::Rng;

/// Sampling strategy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Sampling {
    /// Argmax.
    Greedy,
    /// Softmax sampling at the given temperature (> 0).
    Temperature(f64),
    /// Top-k truncation then temperature sampling.
    TopK { k: usize, temperature: f64 },
}

/// Sample one token id from a logits row.
pub fn sample(logits: &[f32], strategy: Sampling, rng: &mut Rng) -> u32 {
    debug_assert!(!logits.is_empty());
    match strategy {
        Sampling::Greedy => argmax(logits) as u32,
        Sampling::Temperature(t) => {
            let probs = softmax_scaled(logits, t);
            pick(&probs, rng) as u32
        }
        Sampling::TopK { k, temperature } => {
            let k = k.max(1).min(logits.len());
            // Indices of the top-k logits.
            let mut idx: Vec<usize> = (0..logits.len()).collect();
            idx.sort_unstable_by(|&a, &b| logits[b].total_cmp(&logits[a]));
            idx.truncate(k);
            let top: Vec<f32> = idx.iter().map(|&i| logits[i]).collect();
            let probs = softmax_scaled(&top, temperature);
            idx[pick(&probs, rng)] as u32
        }
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

fn softmax_scaled(logits: &[f32], temperature: f64) -> Vec<f64> {
    let t = temperature.max(1e-6);
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let exps: Vec<f64> = logits.iter().map(|&x| ((x as f64 - m) / t).exp()).collect();
    let z: f64 = exps.iter().sum();
    exps.into_iter().map(|e| e / z).collect()
}

fn pick(probs: &[f64], rng: &mut Rng) -> usize {
    let mut x = rng.f64();
    for (i, &p) in probs.iter().enumerate() {
        x -= p;
        if x < 0.0 {
            return i;
        }
    }
    probs.len() - 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let mut rng = Rng::new(1);
        let logits = [0.1, 5.0, -2.0, 3.0];
        assert_eq!(sample(&logits, Sampling::Greedy, &mut rng), 1);
    }

    #[test]
    fn temperature_respects_distribution() {
        let mut rng = Rng::new(2);
        // One dominant logit: low temperature should almost always pick it.
        let logits = [0.0, 10.0, 0.0];
        let hits = (0..200)
            .filter(|_| sample(&logits, Sampling::Temperature(0.5), &mut rng) == 1)
            .count();
        assert!(hits > 195, "hits {hits}");
        // High temperature spreads out.
        let spread = (0..2000)
            .filter(|_| sample(&logits, Sampling::Temperature(50.0), &mut rng) != 1)
            .count();
        assert!(spread > 400, "spread {spread}");
    }

    #[test]
    fn top_k_restricts_support() {
        let mut rng = Rng::new(3);
        let logits = [1.0, 2.0, 3.0, 4.0, 5.0];
        for _ in 0..200 {
            let t = sample(&logits, Sampling::TopK { k: 2, temperature: 1.0 }, &mut rng);
            assert!(t == 4 || t == 3, "token {t}");
        }
    }

    #[test]
    fn deterministic_greedy() {
        let mut a = Rng::new(4);
        let mut b = Rng::new(5);
        let logits = [0.5, 0.7, 0.3];
        assert_eq!(
            sample(&logits, Sampling::Greedy, &mut a),
            sample(&logits, Sampling::Greedy, &mut b)
        );
    }
}
