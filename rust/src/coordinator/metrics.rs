//! Metrics collection: per-request records, per-iteration samples, and
//! the aggregates every experiment reports (avg QoE, TTFT/TDS
//! percentiles, throughput, normalized latency, preemption frequency).

use crate::util::stats::{mean, pearson, percentile};
use crate::workload::SessionInfo;

use super::request::Request;

/// Final record of one served request.
#[derive(Debug, Clone)]
pub struct RequestRecord {
    pub id: usize,
    /// Trace-level id of the submitting spec (see [`Request::spec_id`]).
    pub spec_id: usize,
    pub arrival: f64,
    pub prompt_tokens: usize,
    pub output_tokens: usize,
    pub ttft: f64,
    pub final_qoe: f64,
    /// The request's expected TTFT/TDS (its QoE spec) — lets delivery-layer
    /// post-processing (gateway pacing) re-evaluate QoE from `token_times`.
    pub expected_ttft: f64,
    pub expected_tds: f64,
    /// Average TDS excluding TTFT; NaN when fewer than 2 tokens.
    pub avg_tds: f64,
    pub normalized_latency: f64,
    pub preemptions: usize,
    pub finished_at: f64,
    /// Absolute delivery timestamps (the TDT, for Fig. 22).
    pub token_times: Vec<f64>,
    /// Conversational-session membership (None = one-shot request).
    pub session: Option<SessionInfo>,
    /// Context tokens restored from a parked session prefix (0 = cold
    /// prefill) — the per-request prefix-hit record (`ext-sessions`).
    pub prefix_hit_tokens: usize,
}

impl RequestRecord {
    pub fn from_request(r: &Request) -> Self {
        RequestRecord {
            id: r.id,
            spec_id: r.spec_id,
            arrival: r.arrival,
            prompt_tokens: r.prompt_tokens,
            output_tokens: r.generated,
            ttft: r.ttft().unwrap_or(f64::NAN),
            final_qoe: r.final_qoe(),
            expected_ttft: r.qoe_spec.ttft,
            expected_tds: r.qoe_spec.tds,
            avg_tds: r.avg_tds().unwrap_or(f64::NAN),
            normalized_latency: r.normalized_latency().unwrap_or(f64::NAN),
            preemptions: r.preemptions,
            finished_at: r.finished_at.unwrap_or(f64::NAN),
            token_times: r.token_times.clone(),
            session: r.session,
            prefix_hit_tokens: r.prefix_hit_tokens,
        }
    }

    pub fn total_len(&self) -> usize {
        self.prompt_tokens + self.output_tokens
    }
}

/// One engine iteration's sample (Fig. 19's substrate).
#[derive(Debug, Clone, Copy)]
pub struct IterationSample {
    pub time: f64,
    pub batch_size: usize,
    pub total_ctx: usize,
    pub latency: f64,
    pub is_prefill: bool,
}

/// Collector owned by the engine.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: Vec<RequestRecord>,
    pub iterations: Vec<IterationSample>,
    pub total_tokens: u64,
    pub total_preemptions: u64,
    pub swap_preemptions: u64,
    pub recompute_preemptions: u64,
    /// Preemptions initiated by the engine's OOM safety net (a running
    /// request could not grow), as opposed to scheduler decisions.
    pub oom_preemptions: u64,
    /// Preemptions of runners whose server-side digest showed a client
    /// buffer deep enough to cover a swap round trip (ext-slack's
    /// instrumentation; counted whether or not the estimator is on).
    pub deep_buffer_preemptions: u64,
    /// Finished turns whose context was parked for the session's next
    /// turn (KV prefix retention, DESIGN.md §10).
    pub prefixes_parked: u64,
    /// Returning turns admitted with a parked-prefix hit.
    pub prefix_hits: u64,
    /// Context tokens restored from parked prefixes (prefill skipped).
    pub prefix_hit_tokens: u64,
    /// Parked prefixes evicted under host-pool pressure.
    pub park_evictions: u64,
    pub scheduler_time: f64,
    pub started_at: f64,
    pub ended_at: f64,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    pub fn record_finish(&mut self, r: &Request) {
        self.requests.push(RequestRecord::from_request(r));
    }

    pub fn record_iteration(&mut self, s: IterationSample) {
        self.total_tokens += s.batch_size as u64;
        self.iterations.push(s);
    }

    pub fn elapsed(&self) -> f64 {
        (self.ended_at - self.started_at).max(1e-9)
    }

    /// Server-side token generation throughput, tokens/s.
    pub fn throughput(&self) -> f64 {
        self.total_tokens as f64 / self.elapsed()
    }

    /// Average final QoE over finished requests.
    pub fn avg_qoe(&self) -> f64 {
        mean(&self.qoes())
    }

    pub fn qoes(&self) -> Vec<f64> {
        self.requests.iter().map(|r| r.final_qoe).collect()
    }

    pub fn ttfts(&self) -> Vec<f64> {
        self.requests.iter().map(|r| r.ttft).filter(|x| x.is_finite()).collect()
    }

    pub fn tds_values(&self) -> Vec<f64> {
        self.requests.iter().map(|r| r.avg_tds).filter(|x| x.is_finite()).collect()
    }

    pub fn normalized_latencies(&self) -> Vec<f64> {
        self.requests
            .iter()
            .map(|r| r.normalized_latency)
            .filter(|x| x.is_finite())
            .collect()
    }

    /// Average preemptions per finished request (Fig. 13).
    pub fn preemption_frequency(&self) -> f64 {
        if self.requests.is_empty() {
            return 0.0;
        }
        self.total_preemptions as f64 / self.requests.len() as f64
    }

    /// Fraction of served *returning* turns (session turn > 0) admitted
    /// with a parked-prefix hit; NaN when the run had no returning
    /// turns.
    pub fn prefix_hit_rate(&self) -> f64 {
        let returning = self
            .requests
            .iter()
            .filter(|r| r.session.is_some_and(|s| s.is_returning()))
            .count();
        if returning == 0 {
            return f64::NAN;
        }
        let hits = self.requests.iter().filter(|r| r.prefix_hit_tokens > 0).count();
        hits as f64 / returning as f64
    }

    /// Pearson correlation between batch size and total context length
    /// over decode iterations (Fig. 19 / Appendix B).
    pub fn batch_ctx_correlation(&self) -> f64 {
        let decode: Vec<&IterationSample> =
            self.iterations.iter().filter(|s| !s.is_prefill).collect();
        let xs: Vec<f64> = decode.iter().map(|s| s.batch_size as f64).collect();
        let ys: Vec<f64> = decode.iter().map(|s| s.total_ctx as f64).collect();
        pearson(&xs, &ys)
    }

    /// Summary table rendered by experiments/CLI.
    pub fn summary(&self) -> String {
        let q = self.qoes();
        let t = self.ttfts();
        let d = self.tds_values();
        format!(
            "requests={} avg_qoe={:.3} p10_qoe={:.3} p50_qoe={:.3} \
             p50_ttft={:.2}s p90_ttft={:.2}s p50_tds={:.2} \
             throughput={:.1} tok/s preempt/req={:.3}",
            self.requests.len(),
            self.avg_qoe(),
            percentile(&q, 10.0),
            percentile(&q, 50.0),
            percentile(&t, 50.0),
            percentile(&t, 90.0),
            percentile(&d, 50.0),
            self.throughput(),
            self.preemption_frequency(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Phase;
    use crate::qoe::spec::QoeSpec;

    fn finished_request(id: usize) -> Request {
        let mut r = Request::new(id, 0.0, 50, QoeSpec::new(1.0, 2.0));
        for i in 0..4 {
            r.deliver_token(1.0 + i as f64 * 0.5);
        }
        r.phase = Phase::Finished;
        r.finished_at = Some(2.5);
        r
    }

    #[test]
    fn record_captures_request() {
        let mut m = Metrics::new();
        m.record_finish(&finished_request(0));
        let rec = &m.requests[0];
        assert_eq!(rec.output_tokens, 4);
        assert!((rec.ttft - 1.0).abs() < 1e-9);
        assert!(rec.final_qoe > 0.99);
        assert!((rec.avg_tds - 2.0).abs() < 1e-9);
        assert_eq!(rec.total_len(), 54);
    }

    #[test]
    fn throughput_and_preemption_freq() {
        let mut m = Metrics::new();
        m.started_at = 0.0;
        m.ended_at = 10.0;
        for i in 0..5 {
            m.record_finish(&finished_request(i));
        }
        m.total_tokens = 200;
        m.total_preemptions = 2;
        assert!((m.throughput() - 20.0).abs() < 1e-9);
        assert!((m.preemption_frequency() - 0.4).abs() < 1e-9);
    }

    #[test]
    fn correlation_over_decode_iterations() {
        let mut m = Metrics::new();
        for b in 1..50usize {
            m.record_iteration(IterationSample {
                time: b as f64,
                batch_size: b,
                total_ctx: b * 400 + (b % 3) * 10,
                latency: 0.1,
                is_prefill: false,
            });
        }
        assert!(m.batch_ctx_correlation() > 0.99);
    }

    #[test]
    fn empty_metrics_are_safe() {
        let m = Metrics::new();
        assert_eq!(m.preemption_frequency(), 0.0);
        assert!(m.avg_qoe().is_nan());
        let _ = m.summary();
    }
}
