//! The Andes coordinator: request lifecycle, KV-cache accounting, the
//! scheduling policies (FCFS / Round-Robin / Andes), and the continuous
//! batching engine that ties them to an execution backend.

pub mod calendar;
pub mod engine;
pub mod kv;
pub mod metrics;
pub mod request;
pub mod sched;
pub mod slack;

pub use calendar::{EventCalendar, EventKind, Wakeup, WakeupToken};
pub use kv::{KvCacheManager, KvResidence};
pub use request::{Phase, Request, RequestId};
pub use slack::{SlackConfig, SlackEstimator};
