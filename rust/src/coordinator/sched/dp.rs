//! Exact K-item knapsack via 3D dynamic programming (paper Algorithm 2,
//! Appendix C).
//!
//! `dp[i][b][m]` = best total value using a subset of the first `i`
//! items with exactly `b` chosen and total weight exactly `m`. The
//! paper's analysis: O(M·N²) pseudo-polynomial time — too slow online,
//! which is why Andes ships the greedy Algorithm 1; this solver exists
//! for the Fig. 18 comparison and as a test oracle for the greedy.
//!
//! Weights here are KV *blocks* (not tokens), which keeps `M` in the
//! hundreds. When `M` is still too large we coarsen by a constant factor
//! (conservative rounding up of weights, so capacity is never violated).

/// Maximum capacity units the DP table will use before coarsening.
const MAX_CAPACITY_UNITS: usize = 512;

/// Solve: maximize Σ value[i]·x[i] s.t. Σx = B(exactly ≤), Σ weight·x ≤ capacity.
///
/// Returns (chosen item indices, total value). Mirrors Algorithm 2 but
/// allows "at most B" by taking the best over b ≤ B (the paper scans all
/// B anyway, so this is equivalent at the outer loop level).
pub fn solve_exact_knapsack(
    weights: &[usize],
    values: &[f64],
    b_target: usize,
    capacity: usize,
) -> (Vec<usize>, f64) {
    let n = weights.len();
    assert_eq!(n, values.len());
    if n == 0 || b_target == 0 || capacity == 0 {
        return (Vec::new(), 0.0);
    }
    let b_max = b_target.min(n);

    // Coarsen weights if capacity is too fine-grained for the table.
    let scale = capacity.div_ceil(MAX_CAPACITY_UNITS).max(1);
    let cap_u = capacity / scale;
    let w: Vec<usize> = weights.iter().map(|&x| x.div_ceil(scale)).collect();

    const NEG: f64 = f64::NEG_INFINITY;
    let stride_m = cap_u + 1;
    let stride_b = (b_max + 1) * stride_m;
    // dp[i][b][m], flattened; two layers rolled over i. choice bits kept
    // for all i for reconstruction.
    let mut prev = vec![NEG; stride_b];
    let mut cur = vec![NEG; stride_b];
    prev[0] = 0.0;
    let mut choice = vec![false; n * stride_b];

    for i in 0..n {
        cur.copy_from_slice(&prev);
        let wi = w[i];
        let vi = values[i];
        if wi <= cap_u {
            for b in 1..=b_max.min(i + 1) {
                let base_b = b * stride_m;
                let base_pb = (b - 1) * stride_m;
                for m in wi..=cap_u {
                    let from = prev[base_pb + m - wi];
                    if from == NEG {
                        continue;
                    }
                    let cand = from + vi;
                    if cand > cur[base_b + m] {
                        cur[base_b + m] = cand;
                        choice[i * stride_b + base_b + m] = true;
                    }
                }
            }
        }
        std::mem::swap(&mut prev, &mut cur);
    }

    // Best over b ≤ b_max, m ≤ cap_u.
    let mut best = (0usize, 0usize, 0.0f64); // (b, m, value)
    for b in 0..=b_max {
        for m in 0..=cap_u {
            let v = prev[b * stride_m + m];
            if v > best.2 {
                best = (b, m, v);
            }
        }
    }
    let (mut b, mut m, value) = best;
    if value <= 0.0 {
        return (Vec::new(), 0.0);
    }

    // Reconstruct by walking choices backwards. `prev` holds layer n.
    let mut chosen = Vec::new();
    for i in (0..n).rev() {
        if b == 0 {
            break;
        }
        if choice[i * stride_b + b * stride_m + m] {
            chosen.push(i);
            m -= w[i];
            b -= 1;
        }
    }
    chosen.reverse();
    (chosen, value)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trivial_cases() {
        assert_eq!(solve_exact_knapsack(&[], &[], 3, 10).0.len(), 0);
        assert_eq!(solve_exact_knapsack(&[1], &[1.0], 0, 10).0.len(), 0);
        assert_eq!(solve_exact_knapsack(&[1], &[1.0], 1, 0).0.len(), 0);
    }

    #[test]
    fn picks_best_subset_under_both_constraints() {
        // capacity 10, B≤2: subsets fitting in 10: {3}=13, {1,2}=12,
        // {0}=10 … best is the single item 3.
        let w = [6, 5, 5, 9];
        let v = [10.0, 6.0, 6.0, 13.0];
        let (chosen, value) = solve_exact_knapsack(&w, &v, 2, 10);
        assert_eq!(chosen, vec![3]);
        assert!((value - 13.0).abs() < 1e-9);
        // Drop item 3: now the pair {1,2} wins over {0} alone.
        let (chosen, value) = solve_exact_knapsack(&w[..3], &v[..3], 2, 10);
        assert_eq!(chosen, vec![1, 2]);
        assert!((value - 12.0).abs() < 1e-9);
        // With B=1, best single item that fits: item 3 (w 9, v 13).
        let (chosen, value) = solve_exact_knapsack(&w, &v, 1, 10);
        assert_eq!(chosen, vec![3]);
        assert!((value - 13.0).abs() < 1e-9);
    }

    #[test]
    fn respects_capacity_exactly() {
        let w = [4, 4, 4];
        let v = [1.0, 1.0, 1.0];
        let (chosen, _) = solve_exact_knapsack(&w, &v, 3, 8);
        assert_eq!(chosen.len(), 2);
        let total: usize = chosen.iter().map(|&i| w[i]).sum();
        assert!(total <= 8);
    }

    #[test]
    fn beats_or_matches_greedy_on_adversarial_instance() {
        // Greedy by value/weight picks item 0 (ratio 3) then can't fit
        // the two ratio-2.5 items; DP finds the better pair.
        let w = [2, 3, 3];
        let v = [6.0, 7.5, 7.5];
        let (chosen, value) = solve_exact_knapsack(&w, &v, 2, 6);
        assert_eq!(chosen, vec![1, 2]);
        assert!((value - 15.0).abs() < 1e-9);
    }

    #[test]
    fn coarsening_stays_feasible() {
        // capacity far above MAX_CAPACITY_UNITS forces coarsening; the
        // solution must still satisfy the true capacity.
        let n = 40;
        let w: Vec<usize> = (0..n).map(|i| 50 + (i * 37) % 300).collect();
        let v: Vec<f64> = (0..n).map(|i| 1.0 + (i % 7) as f64).collect();
        let cap = 2000usize;
        let (chosen, _) = solve_exact_knapsack(&w, &v, 10, cap);
        let total: usize = chosen.iter().map(|&i| w[i]).sum();
        assert!(total <= cap, "capacity violated: {total} > {cap}");
        assert!(chosen.len() <= 10);
    }

    #[test]
    fn exhaustive_agreement_small() {
        // Brute-force oracle over all subsets for small instances.
        let w = [3usize, 1, 4, 2, 3];
        let v = [4.0, 2.0, 5.0, 3.0, 4.0];
        for b in 1..=4usize {
            for cap in 3..=9usize {
                let (_, got) = solve_exact_knapsack(&w, &v, b, cap);
                let mut best = 0.0f64;
                for mask in 0u32..(1 << w.len()) {
                    let cnt = mask.count_ones() as usize;
                    if cnt > b {
                        continue;
                    }
                    let tw: usize =
                        (0..w.len()).filter(|&i| mask >> i & 1 == 1).map(|i| w[i]).sum();
                    if tw > cap {
                        continue;
                    }
                    let tv: f64 =
                        (0..w.len()).filter(|&i| mask >> i & 1 == 1).map(|i| v[i]).sum();
                    best = best.max(tv);
                }
                assert!(
                    (got - best).abs() < 1e-9,
                    "b={b} cap={cap}: dp {got} vs brute {best}"
                );
            }
        }
    }
}
