//! Round-Robin scheduling (the paper's second baseline, §6.1).
//!
//! Guarantees equal service through cyclic preemption: every `quantum`
//! iterations the running set yields to the next cohort in cyclic order.
//! The paper sets the service interval to 50 inference iterations
//! ("maximizing its QoE performance").

use std::collections::VecDeque;

use super::{SchedView, Scheduler};
use crate::coordinator::request::{Phase, RequestId};

#[derive(Debug)]
pub struct RoundRobinScheduler {
    /// Service interval in iterations (paper: 50).
    pub quantum: u64,
    /// Cyclic order of active requests.
    ring: VecDeque<RequestId>,
    /// Iterations since the last rotation.
    since_rotate: u64,
    /// Memory watermark (same semantics as FCFS).
    pub watermark: f64,
}

impl RoundRobinScheduler {
    pub fn new(quantum: u64) -> Self {
        RoundRobinScheduler { quantum, ring: VecDeque::new(), since_rotate: 0, watermark: 0.01 }
    }

    /// Sync the ring with the view: enqueue newcomers, drop finished.
    fn sync(&mut self, view: &SchedView<'_>) {
        let active: std::collections::HashSet<RequestId> = view.active.iter().copied().collect();
        self.ring.retain(|id| active.contains(id));
        let known: std::collections::HashSet<RequestId> = self.ring.iter().copied().collect();
        let mut newcomers: Vec<RequestId> =
            view.active.iter().copied().filter(|id| !known.contains(id)).collect();
        newcomers.sort_by(|&a, &b| {
            view.req(a).arrival.total_cmp(&view.req(b).arrival).then(a.cmp(&b))
        });
        self.ring.extend(newcomers);
    }
}

impl Scheduler for RoundRobinScheduler {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn schedule(&mut self, view: &SchedView<'_>) -> Vec<RequestId> {
        self.sync(view);
        if self.ring.is_empty() {
            return Vec::new();
        }

        // Rotate the ring every `quantum` iterations *if* someone is
        // waiting (no point preempting when everyone already runs).
        let anyone_waiting = view
            .active
            .iter()
            .any(|&id| matches!(view.req(id).phase, Phase::Waiting | Phase::SwappedOut));
        self.since_rotate += 1;
        if self.since_rotate >= self.quantum && anyone_waiting {
            self.since_rotate = 0;
            // Move the currently-running prefix to the back of the ring.
            let running: std::collections::HashSet<RequestId> =
                view.running().into_iter().collect();
            let mut yielded = Vec::new();
            while let Some(&front) = self.ring.front() {
                if running.contains(&front) {
                    // lint:allow(D6, front() just returned Some for this element)
                    yielded.push(self.ring.pop_front().unwrap());
                } else {
                    break;
                }
            }
            self.ring.extend(yielded);
        }

        // Fill from the ring front while memory fits.
        let total_blocks = view.total_blocks();
        let reserve = (total_blocks as f64 * self.watermark).ceil() as usize;
        let mut desired = Vec::new();
        let mut used = 0usize;
        for &id in self.ring.iter() {
            let need = view.block_cost(id);
            if used + need + reserve <= total_blocks {
                used += need;
                desired.push(id);
            } else {
                break; // keep cyclic order strict
            }
        }
        desired
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sched::testutil::Fixture;

    #[test]
    fn serves_in_ring_order_and_rotates() {
        // 3 equal requests, capacity for 2 (each 4 blocks of the 10 − 1
        // reserve).
        let mut f = Fixture::new(&[(60, 10, 0.0), (60, 10, 1.0), (60, 10, 2.0)], 160);
        static ACTIVE: &[RequestId] = &[0, 1, 2];
        let mut s = RoundRobinScheduler::new(3);
        // Iterations 1..2: front of ring = [0,1].
        let d1 = s.schedule(&f.view(ACTIVE));
        assert_eq!(d1, vec![0, 1]);
        f.run(0);
        f.run(1);
        let d2 = s.schedule(&f.view(ACTIVE));
        assert_eq!(d2, vec![0, 1]);
        // Third call hits the quantum → ring rotates, request 2 now front.
        let d3 = s.schedule(&f.view(ACTIVE));
        assert_eq!(d3[0], 2, "rotation must bring the starved request forward: {d3:?}");
    }

    #[test]
    fn no_rotation_when_nobody_waits() {
        let mut f = Fixture::new(&[(60, 10, 0.0), (60, 10, 1.0)], 1600);
        f.run(0);
        f.run(1);
        static ACTIVE: &[RequestId] = &[0, 1];
        let mut s = RoundRobinScheduler::new(2);
        for _ in 0..5 {
            let d = s.schedule(&f.view(ACTIVE));
            assert_eq!(d, vec![0, 1]);
        }
    }

    #[test]
    fn finished_requests_leave_the_ring() {
        let mut f = Fixture::new(&[(60, 10, 0.0), (60, 10, 1.0)], 1600);
        f.run(0);
        static A2: &[RequestId] = &[0, 1];
        let mut s = RoundRobinScheduler::new(50);
        let _ = s.schedule(&f.view(A2));
        // Request 0 finishes.
        f.requests[0].phase = Phase::Finished;
        f.kv.free(0).unwrap();
        static A1: &[RequestId] = &[1];
        let d = s.schedule(&f.view(A1));
        assert_eq!(d, vec![1]);
    }

    #[test]
    fn empty() {
        let f = Fixture::new(&[], 160);
        static ACTIVE: &[RequestId] = &[];
        let mut s = RoundRobinScheduler::new(50);
        assert!(s.schedule(&f.view(ACTIVE)).is_empty());
    }
}
