//! First-come-first-serve scheduling — vLLM 0.2.7's policy (the paper's
//! main baseline, §6.1).
//!
//! Semantics reproduced from vLLM's scheduler:
//! 1. the running batch keeps generating (continuous batching);
//! 2. swapped-out requests are swapped back in (in arrival order) before
//!    any new admissions;
//! 3. waiting requests are admitted in arrival order while their prompt
//!    KV fits under the admission watermark;
//! 4. on memory pressure (a running request cannot grow), the engine
//!    preempts the *latest-arrived* running request — FCFS never preempts
//!    proactively here.

use super::{SchedView, Scheduler};
use crate::coordinator::request::RequestId;

/// vLLM-style FCFS.
#[derive(Debug, Default)]
pub struct FcfsScheduler {
    /// Fraction of device blocks kept free as an admission watermark
    /// (vLLM's `watermark=0.01`).
    pub watermark: f64,
}

impl FcfsScheduler {
    pub fn new() -> Self {
        FcfsScheduler { watermark: 0.01 }
    }
}

impl Scheduler for FcfsScheduler {
    fn name(&self) -> &'static str {
        "fcfs"
    }

    fn schedule(&mut self, view: &SchedView<'_>) -> Vec<RequestId> {
        let total_blocks = view.total_blocks();
        let reserve = (total_blocks as f64 * self.watermark).ceil() as usize;

        // Running requests stay, in arrival order.
        let mut desired = view.running();
        desired.sort_by(|&a, &b| {
            view.req(a).arrival.total_cmp(&view.req(b).arrival).then(a.cmp(&b))
        });
        let mut used_blocks: usize = desired.iter().map(|&id| view.block_cost(id)).sum();

        // Swapped-out first, then waiting — each in arrival order.
        let mut candidates = view.not_running();
        candidates.sort_by(|&a, &b| {
            use crate::coordinator::request::Phase;
            let pa = view.req(a).phase == Phase::SwappedOut;
            let pb = view.req(b).phase == Phase::SwappedOut;
            pb.cmp(&pa)
                .then(view.req(a).arrival.total_cmp(&view.req(b).arrival))
                .then(a.cmp(&b))
        });
        for id in candidates {
            let need = view.block_cost(id);
            if used_blocks + need + reserve <= total_blocks {
                used_blocks += need;
                desired.push(id);
            } else {
                // Strict FCFS: head-of-line blocking — don't skip ahead.
                break;
            }
        }
        desired
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sched::testutil::Fixture;

    #[test]
    fn admits_in_arrival_order_until_full() {
        // Capacity 160 tokens = 10 blocks of 16; watermark reserves 1.
        let mut f = Fixture::new(
            &[(60, 10, 0.0), (60, 10, 1.0), (60, 10, 2.0)],
            160,
        );
        static ACTIVE: &[RequestId] = &[0, 1, 2];
        let mut s = FcfsScheduler::new();
        let got = s.schedule(&f.view(ACTIVE));
        // Each request costs ceil(61/16) = 4 blocks; 2 fit under 10-1.
        assert_eq!(got, vec![0, 1]);
        // Run those two; the third still blocked next round.
        f.run(0);
        f.run(1);
        let got = s.schedule(&f.view(ACTIVE));
        assert_eq!(got, vec![0, 1]);
    }

    #[test]
    fn head_of_line_blocking() {
        // A huge request at the queue head blocks a small one behind it —
        // the pathology Fig. 4 illustrates.
        let mut f = Fixture::new(&[(100, 10, 0.0), (150, 10, 1.0), (10, 10, 2.0)], 160);
        f.run(0); // 0 occupies 7 blocks (101 tokens).
        static ACTIVE: &[RequestId] = &[0, 1, 2];
        let mut s = FcfsScheduler::new();
        let got = s.schedule(&f.view(ACTIVE));
        // Request 1 needs 10 blocks, only 3 free → blocked; FCFS must NOT
        // admit request 2 ahead of it.
        assert_eq!(got, vec![0]);
    }

    #[test]
    fn swapped_requests_have_priority_over_waiting() {
        use crate::coordinator::request::Phase;
        let mut f = Fixture::new(&[(60, 10, 0.0), (30, 10, 1.0)], 160);
        // Request 0 swapped out, request 1 new in queue.
        f.requests[0].phase = Phase::SwappedOut;
        f.kv.allocate(0, 60).unwrap();
        f.kv.swap_out(0).unwrap();
        static ACTIVE: &[RequestId] = &[0, 1];
        let mut s = FcfsScheduler::new();
        let got = s.schedule(&f.view(ACTIVE));
        assert_eq!(got[0], 0, "swapped request must come back first");
    }

    #[test]
    fn empty_system() {
        let f = Fixture::new(&[], 160);
        static ACTIVE: &[RequestId] = &[];
        let mut s = FcfsScheduler::new();
        assert!(s.schedule(&f.view(ACTIVE)).is_empty());
    }
}
