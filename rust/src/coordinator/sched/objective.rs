//! Scheduling objectives (paper §4.1 Eq. 2 and Appendix A).
//!
//! The knapsack item value for request *i* is a *QoE gain*: how much
//! better off the objective is if the request is served for the next Δt
//! versus left waiting. Three objectives from the paper:
//!
//! - **AvgQoe** (Eq. 2): `Q_serve,i(B) − Q_wait,i` — maximize the sum
//!   (equivalently the average) of QoE.
//! - **MaxMin** (Eq. 6): `max(Q_min − Q_wait,i, 0)` — lift the QoE floor
//!   by prioritizing requests that would drag the minimum down.
//! - **PerfectCount** (Eq. 7): `[1(Q_serve=1) − 1(Q_wait=1)]·1(Q_cur=1)`
//!   — maximize the number of requests finishing with perfect QoE.

/// Inputs to the gain computation for one request.
#[derive(Debug, Clone, Copy)]
pub struct QoeOutlook {
    /// Predicted QoE after Δt if served at the candidate batch size.
    pub q_serve: f64,
    /// Predicted QoE after Δt if left waiting.
    pub q_wait: f64,
    /// QoE right now.
    pub q_current: f64,
}

/// Scheduling objective selector.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Objective {
    AvgQoe,
    /// `q_min_global` must be supplied per scheduling round.
    MaxMin,
    PerfectCount,
}

/// Tolerance for "perfect QoE" indicator functions.
const PERFECT_EPS: f64 = 1e-6;

impl Objective {
    pub fn by_name(name: &str) -> Option<Objective> {
        match name {
            "avg" | "avg-qoe" => Some(Objective::AvgQoe),
            "maxmin" | "max-min" => Some(Objective::MaxMin),
            "perfect" | "perfect-count" => Some(Objective::PerfectCount),
            _ => None,
        }
    }

    /// QoE gain (knapsack item value) for one request.
    /// `q_min_global` is the minimum current QoE across all active
    /// requests (used by MaxMin only).
    pub fn gain(&self, o: &QoeOutlook, q_min_global: f64) -> f64 {
        match self {
            Objective::AvgQoe => o.q_serve - o.q_wait,
            // Eq. 6 as written zeroes the gain of every request whose
            // waiting QoE stays above the floor, which degenerates the
            // knapsack into arbitrary tie-breaking for the bulk of the
            // batch. Add an ε-scaled average-QoE term as a lexicographic
            // tie-breaker: floor-lifting dominates, everyone else is
            // still scheduled sensibly.
            Objective::MaxMin => {
                (q_min_global - o.q_wait).max(0.0) + 0.01 * (o.q_serve - o.q_wait)
            }
            Objective::PerfectCount => {
                let perfect = |q: f64| q >= 1.0 - PERFECT_EPS;
                if !perfect(o.q_current) {
                    return 0.0;
                }
                (perfect(o.q_serve) as i32 - perfect(o.q_wait) as i32) as f64
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outlook(q_serve: f64, q_wait: f64, q_current: f64) -> QoeOutlook {
        QoeOutlook { q_serve, q_wait, q_current }
    }

    #[test]
    fn avg_qoe_is_difference() {
        let o = outlook(0.9, 0.4, 0.7);
        assert!((Objective::AvgQoe.gain(&o, 0.0) - 0.5).abs() < 1e-12);
        // Serving can't help an already-perfect request.
        let o2 = outlook(1.0, 1.0, 1.0);
        assert_eq!(Objective::AvgQoe.gain(&o2, 0.0), 0.0);
    }

    #[test]
    fn maxmin_prioritizes_requests_near_floor() {
        // Request whose waiting QoE would fall below the current floor.
        let urgent = outlook(0.9, 0.2, 0.6);
        let safe = outlook(1.0, 0.8, 1.0);
        let q_min = 0.5;
        assert!(Objective::MaxMin.gain(&urgent, q_min) > Objective::MaxMin.gain(&safe, q_min));
        // Requests already above the floor even when waiting keep only
        // the ε-scaled tie-breaker term.
        let safe_gain = Objective::MaxMin.gain(&safe, q_min);
        assert!(safe_gain < 0.01, "tie-breaker only: {safe_gain}");
        assert!((safe_gain - 0.01 * (1.0 - 0.8)).abs() < 1e-12);
    }

    #[test]
    fn perfect_count_indicator_logic() {
        // Currently perfect, would degrade if not served, stays perfect
        // if served → gain 1.
        let save = outlook(1.0, 0.95, 1.0);
        assert_eq!(Objective::PerfectCount.gain(&save, 0.0), 1.0);
        // Currently imperfect → no point (gain 0).
        let lost = outlook(1.0, 0.5, 0.8);
        assert_eq!(Objective::PerfectCount.gain(&lost, 0.0), 0.0);
        // Perfect either way → gain 0.
        let safe = outlook(1.0, 1.0, 1.0);
        assert_eq!(Objective::PerfectCount.gain(&safe, 0.0), 0.0);
        // Serving wouldn't even keep it perfect → 0 (1-1=0 case is above;
        // here serve imperfect, wait imperfect → 0-0).
        let doomed = outlook(0.9, 0.8, 1.0);
        assert_eq!(Objective::PerfectCount.gain(&doomed, 0.0), 0.0);
    }

    #[test]
    fn lookup() {
        assert_eq!(Objective::by_name("avg"), Some(Objective::AvgQoe));
        assert_eq!(Objective::by_name("maxmin"), Some(Objective::MaxMin));
        assert_eq!(Objective::by_name("perfect"), Some(Objective::PerfectCount));
        assert_eq!(Objective::by_name("x"), None);
    }
}
