//! Scheduling framework (paper §4).
//!
//! At the beginning of every engine iteration (continuous batching), the
//! scheduler inspects all active requests and returns the **desired
//! running set** for the next iteration. The engine diffs that against
//! the current running set: departures are preempted (swap, falling back
//! to recomputation), newcomers are admitted (swap-in or prefill).
//!
//! Implementations:
//! - [`fcfs`]: vLLM 0.2.7's first-come-first-serve (the paper's baseline);
//! - [`round_robin`]: cyclic fair-sharing with a service quantum;
//! - [`andes`]: the paper's QoE-aware knapsack scheduler (Algorithm 1);
//! - [`dp`]: the exact 3D dynamic-programming solver (Algorithm 2),
//!   used by the Fig. 18 comparison.

pub mod andes;
pub mod dp;
pub mod fcfs;
pub mod objective;
pub mod round_robin;

use super::kv::KvCacheManager;
use super::request::{Phase, Request, RequestId};
use super::slack::SlackEstimator;
use crate::model::latency::LatencyModel;

/// Preemption mechanisms (paper §4.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PreemptMechanism {
    /// Move KV cache to host memory and back.
    Swap,
    /// Drop KV cache; replay prefill on re-admission.
    Recompute,
}

/// Read-only view of the system handed to schedulers each iteration.
pub struct SchedView<'a> {
    /// Current absolute time (s).
    pub now: f64,
    /// Prediction horizon Δt (s) — engine-estimated average request
    /// completion time unless overridden.
    pub horizon: f64,
    /// All requests ever admitted, indexed by id.
    pub requests: &'a [Request],
    /// Ids of non-finished requests (waiting + running + swapped).
    pub active: &'a [RequestId],
    pub kv: &'a KvCacheManager,
    pub latency: &'a LatencyModel,
    /// Lifetime counters for the preemption cap (Optimization #4).
    pub total_requests_seen: usize,
    pub total_preemptions: usize,
    /// Server-side client-buffer slack estimate (DESIGN.md §15).
    /// `None` reproduces slack-blind scheduling bit-identically.
    pub slack: Option<&'a SlackEstimator>,
}

impl<'a> SchedView<'a> {
    pub fn req(&self, id: RequestId) -> &Request {
        &self.requests[id]
    }

    /// Ids currently in the running batch.
    pub fn running(&self) -> Vec<RequestId> {
        self.active
            .iter()
            .copied()
            .filter(|&id| self.requests[id].phase == Phase::Running)
            .collect()
    }

    /// Ids waiting or swapped out.
    pub fn not_running(&self) -> Vec<RequestId> {
        self.active
            .iter()
            .copied()
            .filter(|&id| {
                matches!(self.requests[id].phase, Phase::Waiting | Phase::SwappedOut)
            })
            .collect()
    }

    /// Device blocks a request needs to run *and* grow by one token
    /// (conservative admission cost).
    pub fn block_cost(&self, id: RequestId) -> usize {
        (self.requests[id].context_len() + 1).div_ceil(self.kv.block_size())
    }

    /// Total device blocks available to the scheduler.
    pub fn total_blocks(&self) -> usize {
        self.kv.device_capacity_tokens() / self.kv.block_size()
    }

    /// Mean context length over active requests (Appendix B's proxy that
    /// lets latency be modeled as a function of batch size alone).
    pub fn avg_context_len(&self) -> usize {
        if self.active.is_empty() {
            return 0;
        }
        let total: usize = self.active.iter().map(|&id| self.requests[id].context_len()).sum();
        (total / self.active.len()).max(1)
    }
}

/// A scheduling policy.
pub trait Scheduler: Send {
    fn name(&self) -> &'static str;

    /// Return the desired running set for the next iteration. The engine
    /// trusts but verifies: sets that exceed KV capacity are truncated.
    fn schedule(&mut self, view: &SchedView<'_>) -> Vec<RequestId>;

    /// Notification hooks so stateful schedulers (e.g. RR) can track
    /// request lifecycle. Default: no-op.
    fn on_finish(&mut self, _id: RequestId) {}
}

#[cfg(test)]
pub(crate) mod testutil {
    //! Shared helpers for scheduler unit tests.
    use super::*;
    use crate::model::gpu::a100_4x;
    use crate::model::llm::opt_66b;
    use crate::qoe::spec::QoeSpec;

    pub struct Fixture {
        pub requests: Vec<Request>,
        pub kv: KvCacheManager,
        pub latency: LatencyModel,
        pub now: f64,
        /// Optional slack estimator exposed through the view (slack-aware
        /// scheduler tests); `None` keeps the classic slack-blind view.
        pub slack: Option<SlackEstimator>,
    }

    impl Fixture {
        /// Build a fixture with the given (prompt, output, arrival) specs
        /// and a device capacity in tokens.
        pub fn new(specs: &[(usize, usize, f64)], capacity_tokens: usize) -> Fixture {
            let requests: Vec<Request> = specs
                .iter()
                .enumerate()
                .map(|(i, &(p, _o, a))| Request::new(i, a, p, QoeSpec::new(1.0, 4.8)))
                .collect();
            Fixture {
                requests,
                kv: KvCacheManager::new(capacity_tokens, capacity_tokens, 16),
                latency: LatencyModel::for_deployment(&opt_66b(), &a100_4x()),
                now: 0.0,
                slack: None,
            }
        }

        /// Mark a request as running and allocate its KV.
        pub fn run(&mut self, id: RequestId) {
            self.requests[id].phase = Phase::Running;
            self.kv.allocate(id, self.requests[id].context_len()).unwrap();
        }

        pub fn view(&self, active: &'static [RequestId]) -> SchedView<'_> {
            SchedView {
                now: self.now,
                horizon: 30.0,
                requests: &self.requests,
                active,
                kv: &self.kv,
                latency: &self.latency,
                total_requests_seen: self.requests.len(),
                total_preemptions: 0,
                slack: self.slack.as_ref(),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::Fixture;
    use super::*;

    #[test]
    fn view_accessors() {
        let mut f = Fixture::new(&[(100, 50, 0.0), (200, 50, 1.0), (300, 50, 2.0)], 10_000);
        f.run(0);
        static ACTIVE: &[RequestId] = &[0, 1, 2];
        let v = f.view(ACTIVE);
        assert_eq!(v.running(), vec![0]);
        assert_eq!(v.not_running(), vec![1, 2]);
        assert_eq!(v.avg_context_len(), 200);
        // 100+1 tokens over 16-token blocks → 7 blocks
        assert_eq!(v.block_cost(0), 7);
        assert_eq!(v.total_blocks(), 625);
    }
}
