//! The Andes QoE-aware scheduler (paper §4).
//!
//! Each iteration, solve (approximately) the exact-K-item knapsack of
//! Eq. 4: choose the batch (set of requests) maximizing total QoE gain
//! `Σ (Q_serve,i(B) − Q_wait,i)` subject to the KV-memory capacity and a
//! target batch size `B`, scanning `B` over a pruned range.
//!
//! Optimizations from the paper, all implemented here:
//! 1. **Selective triggering** — skip the solver entirely while memory
//!    and compute are unconstrained, and just serve everyone.
//! 2. **Batch-size search-space pruning** — scan `B ∈ [B_min, B_max]`
//!    where `B_max` packs shortest-context requests into `M` and `B_min`
//!    is the largest batch still faster than the most stringent TDS.
//! 3. **Greedy packing** (Algorithm 1) — sort by priority
//!    `(Q_serve(B) − Q_wait)/l_i` and fill; `O(N log N)`.
//! 4. **Preemption cap** — bound lifetime-average preemptions per
//!    request by `P` (default 1.0).

use super::dp::solve_exact_knapsack;
use super::objective::{Objective, QoeOutlook};
use super::{SchedView, Scheduler};
use crate::coordinator::request::{Phase, RequestId};
use crate::qoe::metric::{project, projected_area, qoe_at, DigestState};

/// Knapsack solver choice (Fig. 18 compares these).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KnapsackSolver {
    /// Algorithm 1: greedy by priority, O(N log N).
    Greedy,
    /// Algorithm 2: exact 3D dynamic programming (pseudo-polynomial,
    /// evaluated at coarsened capacity granularity to stay tractable).
    Dp,
}

/// Configuration of the Andes scheduler.
#[derive(Debug, Clone)]
pub struct AndesConfig {
    pub objective: Objective,
    /// Preemption cap P: max average preemptions per request (Opt. #4).
    pub preemption_cap: f64,
    /// Override for the prediction horizon Δt; `None` = engine estimate.
    pub delta_t_override: Option<f64>,
    /// Number of candidate batch sizes evaluated in [B_min, B_max].
    pub b_grid: usize,
    pub solver: KnapsackSolver,
    /// High-memory watermark that *triggers* the solver (Opt. #1).
    /// Packing itself uses the full capacity M minus a 1% growth
    /// reserve, like the FCFS baseline — Eq. 3's M is full memory.
    pub watermark: f64,
    /// Preemption hysteresis: a newcomer only displaces a running
    /// request if its QoE gain exceeds the runner's by this margin.
    /// Pausing a runner forfeits exactly its own gain, and the swap
    /// itself costs real iteration time, so marginal displacements are
    /// net-negative (§4.2: balance QoE gains vs slowdowns). The margin
    /// naturally selects "coasting" runners (deep client buffer ⇒ gain
    /// near 0) as preemption victims — the paper's §2.3 mechanism.
    pub preempt_margin: f64,
}

impl Default for AndesConfig {
    fn default() -> Self {
        AndesConfig {
            objective: Objective::AvgQoe,
            preemption_cap: 1.0,
            delta_t_override: None,
            b_grid: 8,
            solver: KnapsackSolver::Greedy,
            watermark: 0.9,
            preempt_margin: 0.2,
        }
    }
}

/// The Andes scheduler.
#[derive(Debug)]
pub struct AndesScheduler {
    pub cfg: AndesConfig,
    /// Scratch buffers reused across iterations (hot-path allocation
    /// avoidance; see EXPERIMENTS.md §Perf).
    scratch: Scratch,
}

#[derive(Debug, Default)]
struct Scratch {
    candidates: Vec<Candidate>,
    order: Vec<usize>,
    /// Precomputed priorities (gain / l_i), refreshed per candidate B —
    /// sorting with cached keys instead of recomputing two divisions per
    /// comparison (see EXPERIMENTS.md §Perf).
    priorities: Vec<f64>,
}

#[derive(Debug, Clone, Copy)]
struct Candidate {
    id: RequestId,
    /// Context length l_i (knapsack weight, in tokens).
    ctx: usize,
    /// Admission cost in blocks.
    blocks: usize,
    q_wait: f64,
    q_current: f64,
    /// Serving start delay (prefill / swap-in) in seconds.
    start_delay: f64,
    running: bool,
    /// Filled per candidate B.
    gain: f64,
    /// Hot-loop caches (B-independent; see EXPERIMENTS.md §Perf):
    /// digestion state snapshot, request-relative horizon, and the
    /// expected-area denominator of Eq. 1 at that horizon.
    digest: DigestState,
    rel_horizon: f64,
    expected_area_h: f64,
    /// Estimated client-buffer slack window in seconds (DESIGN.md §15),
    /// `None` when the view carries no slack estimate for this request
    /// — the scheduler then behaves exactly as the slack-blind build.
    slack_window: Option<f64>,
}

impl AndesScheduler {
    pub fn new(cfg: AndesConfig) -> Self {
        AndesScheduler { cfg, scratch: Scratch::default() }
    }

    pub fn with_defaults() -> Self {
        Self::new(AndesConfig::default())
    }

    /// Predicted QoE of a request after Δt if served at token rate
    /// `rate`, starting after `start_delay`. Uses the candidate's cached
    /// digest snapshot and expected-area denominator (hot loop: runs
    /// N × |B-grid| times per scheduling iteration).
    #[inline]
    fn q_serve(c: &Candidate, rate: f64) -> f64 {
        if c.expected_area_h <= 0.0 {
            return 1.0;
        }
        let actual = projected_area(&c.digest, rate, c.start_delay, c.rel_horizon);
        (actual / c.expected_area_h).clamp(0.0, 1.0)
    }

    /// Build per-request candidate records (everything B-independent).
    fn build_candidates(&mut self, view: &SchedView<'_>, horizon: f64) {
        self.scratch.candidates.clear();
        for &id in view.active {
            let req = view.req(id);
            let ctx = req.context_len();
            let rel_now = view.now - req.arrival;
            let rel_horizon = rel_now + horizon;
            // Slack-aware mode (DESIGN.md §15): project QoE from the
            // *estimated client-side* digest instead of the server-side
            // one — the server's counts tokens at generation time, which
            // overestimates what a paced client actually holds.
            let slack_est = view.slack.and_then(|s| s.estimate(id, rel_now));
            let (digest, slack_window) = match slack_est {
                Some(est) => {
                    let window = est.buffered() / req.qoe_spec.tds.max(1e-9);
                    (est, Some(window))
                }
                None => (req.digest, None),
            };
            let waited = project(&digest, 0.0, 0.0, rel_horizon);
            let q_wait = qoe_at(&req.qoe_spec, &waited, rel_horizon, None);
            let q_current = match slack_est {
                Some(ref est) => qoe_at(&req.qoe_spec, est, rel_now, None),
                None => req.qoe_at(view.now),
            };
            let start_delay = match req.phase {
                Phase::Running => 0.0,
                Phase::SwappedOut => view.latency.swap(ctx),
                Phase::Waiting => view.latency.recompute(ctx),
                Phase::Finished => continue,
            };
            self.scratch.candidates.push(Candidate {
                id,
                ctx,
                blocks: view.block_cost(id),
                q_wait,
                q_current,
                start_delay,
                running: req.phase == Phase::Running,
                gain: 0.0,
                digest,
                rel_horizon,
                expected_area_h: req.qoe_spec.expected_area(rel_horizon, None),
                slack_window,
            });
        }
    }

    /// Pruned candidate batch sizes [B_min, B_max] (Optimization #2).
    fn batch_size_range(&self, view: &SchedView<'_>) -> (usize, usize) {
        let n = self.scratch.candidates.len();
        // B_max: pack shortest contexts into the block budget.
        let budget = self.block_budget(view);
        let mut blocks: Vec<usize> = self.scratch.candidates.iter().map(|c| c.blocks).collect();
        blocks.sort_unstable();
        let mut used = 0usize;
        let mut b_max = 0usize;
        for b in blocks {
            if used + b > budget {
                break;
            }
            used += b;
            b_max += 1;
        }
        let b_max = b_max.max(1).min(n);
        // B_min: largest batch still faster than the most stringent TDS.
        let stringent = self
            .scratch
            .candidates
            .iter()
            .map(|c| view.req(c.id).qoe_spec.tds)
            .fold(0.0f64, f64::max)
            .max(1e-6);
        let b_min = view
            .latency
            .max_batch_for_tds(stringent, view.avg_context_len())
            .clamp(1, b_max);
        (b_min, b_max)
    }

    /// Device block budget for packing: full capacity minus a 1% growth
    /// reserve (same headroom as the FCFS baseline).
    fn block_budget(&self, view: &SchedView<'_>) -> usize {
        (view.total_blocks() as f64 * 0.99).floor() as usize
    }

    /// Selective triggering (Optimization #1): true if the solver can be
    /// skipped and everyone served.
    fn unconstrained(&self, view: &SchedView<'_>) -> bool {
        let total_blocks: usize = self.scratch.candidates.iter().map(|c| c.blocks).sum();
        let trigger_blocks =
            (view.total_blocks() as f64 * self.cfg.watermark).floor() as usize;
        if total_blocks > trigger_blocks {
            return false;
        }
        let n = self.scratch.candidates.len();
        let total_ctx: usize = self.scratch.candidates.iter().map(|c| c.ctx).sum();
        let iter_latency = view.latency.decode(n, total_ctx);
        let stringent = self
            .scratch
            .candidates
            .iter()
            .map(|c| view.req(c.id).qoe_spec.tds)
            .fold(0.0f64, f64::max);
        stringent <= 0.0 || iter_latency <= 1.0 / stringent
    }

    /// Greedy packing (Algorithm 1) for a target batch size B. Returns
    /// (chosen candidate indices, objective value).
    fn pack_greedy(&mut self, b: usize, budget: usize) -> (Vec<usize>, f64) {
        let cands = &self.scratch.candidates;
        // Priority: gain / l_i (Eq. 5), precomputed once per B.
        let prios = &mut self.scratch.priorities;
        prios.clear();
        prios.extend(cands.iter().map(|c| c.gain / c.ctx.max(1) as f64));
        let order = &mut self.scratch.order;
        order.clear();
        order.extend(0..cands.len());
        order.sort_unstable_by(|&i, &j| {
            prios[j].total_cmp(&prios[i]).then(cands[i].id.cmp(&cands[j].id))
        });
        let mut chosen = Vec::with_capacity(b);
        let mut used_blocks = 0usize;
        let mut value = 0.0;
        for &i in order.iter() {
            if chosen.len() >= b {
                break;
            }
            let c = &cands[i];
            if used_blocks + c.blocks <= budget {
                used_blocks += c.blocks;
                value += c.gain;
                chosen.push(i);
            }
        }
        (chosen, value)
    }

    /// Exact DP packing (Algorithm 2) for a target batch size B.
    fn pack_dp(&self, b: usize, budget: usize) -> (Vec<usize>, f64) {
        let weights: Vec<usize> = self.scratch.candidates.iter().map(|c| c.blocks).collect();
        let values: Vec<f64> = self.scratch.candidates.iter().map(|c| c.gain).collect();
        solve_exact_knapsack(&weights, &values, b, budget)
    }

    /// Preemption hysteresis: undo displacements whose *gain
    /// differential* is marginal. A running request stays unless the
    /// newcomers taking its place each promise more QoE gain than it
    /// forfeits by pausing, by a margin covering the *system-wide* cost
    /// of the displacement: the two swap transfers stall the entire
    /// batch, costing every running request ≈ stall/Δt of its QoE-gain
    /// scale — so the margin grows with batch size.
    fn apply_hysteresis(
        &self,
        view: &SchedView<'_>,
        desired: Vec<usize>,
        horizon: f64,
    ) -> Vec<usize> {
        let cands = &self.scratch.candidates;
        let b_running = cands.iter().filter(|c| c.running).count();
        let stall = 2.0 * view.latency.swap(view.avg_context_len());
        let margin =
            self.cfg.preempt_margin.max(2.5 * b_running as f64 * stall / horizon.max(1e-9));
        let chosen: std::collections::HashSet<usize> = desired.iter().copied().collect();
        // Running requests the solution would preempt, highest-gain first
        // (they have the strongest case to stay).
        let mut preempted: Vec<usize> = (0..cands.len())
            .filter(|&i| cands[i].running && !chosen.contains(&i))
            .collect();
        if preempted.is_empty() {
            return desired;
        }
        preempted.sort_by(|&i, &j| cands[j].gain.total_cmp(&cands[i].gain));
        // Newcomers the solution admits, lowest-gain first.
        let mut newcomers: Vec<usize> =
            desired.iter().copied().filter(|&i| !cands[i].running).collect();
        newcomers.sort_by(|&i, &j| cands[i].gain.total_cmp(&cands[j].gain));

        let mut result = desired;
        for &r in &preempted {
            // Slack-aware margin (DESIGN.md §15): charge the KV swap
            // stall against the runner's estimated client-buffer window.
            // A buffer that cannot cover the swap-out + swap-in stall
            // makes the runner effectively un-preemptable (infinite
            // margin); a deep buffer absorbs the stall for free, so the
            // margin shrinks proportionally. `None` (slack off) keeps
            // the classic batch-wide margin bit-identically.
            let margin_r = match cands[r].slack_window {
                None => margin,
                Some(w) => {
                    let stall_r = 2.0 * view.latency.swap(cands[r].ctx);
                    if w < stall_r {
                        f64::INFINITY
                    } else {
                        margin * (stall_r / w).min(1.0)
                    }
                }
            };
            // Displacing runner r is justified only if even the weakest
            // admitted newcomer clears the gain margin. Otherwise evict
            // weak newcomers until the runner fits back in — and if the
            // freed blocks never cover the runner, restore the evicted
            // newcomers rather than silently shrinking the batch.
            let mut evicted: Vec<usize> = Vec::new();
            let mut reinstated = false;
            while let Some(&w) = newcomers.first() {
                if !(cands[w].gain < cands[r].gain + margin_r) {
                    break; // displacement justified
                }
                // Marginal displacement: evict the weak newcomer.
                newcomers.remove(0);
                result.retain(|&x| x != w);
                evicted.push(w);
                // Does the runner fit now?
                let used: usize = result.iter().map(|&x| cands[x].blocks).sum();
                if used + cands[r].blocks <= self.block_budget(view) {
                    result.push(r);
                    reinstated = true;
                    break;
                }
            }
            if !reinstated && !evicted.is_empty() {
                // The runner never fit: undo the evictions so capacity
                // is not wasted (batch block-usage must not shrink
                // across hysteresis — pinned by regression test).
                newcomers.splice(0..0, evicted.iter().copied());
                result.extend(evicted);
            }
        }
        result
    }

    /// Candidate batch sizes: the full `[b_min, b_max]` range when it is
    /// small, otherwise an even subsample of `b_grid` points. `b_grid`
    /// is clamped to ≥ 2 — a 1-point (or 0-point) grid would divide by
    /// `b_grid - 1 = 0`, yielding `NaN → 0` and silently collapsing the
    /// scan to `b_min` (regression-tested).
    fn candidate_grid(&self, b_min: usize, b_max: usize) -> Vec<usize> {
        let g = self.cfg.b_grid.max(2);
        if b_max - b_min + 1 <= g {
            (b_min..=b_max).collect()
        } else {
            (0..g)
                .map(|k| {
                    b_min
                        + ((b_max - b_min) as f64 * k as f64 / (g - 1) as f64).round()
                            as usize
                })
                .collect()
        }
    }

    /// Enforce the preemption cap (Optimization #4) on a desired set.
    fn apply_preemption_cap(
        &mut self,
        view: &SchedView<'_>,
        desired: Vec<usize>,
    ) -> Vec<usize> {
        let cands = &self.scratch.candidates;
        let chosen: std::collections::HashSet<usize> = desired.iter().copied().collect();
        let preempted: Vec<usize> = (0..cands.len())
            .filter(|&i| cands[i].running && !chosen.contains(&i))
            .collect();
        let allowed = (self.cfg.preemption_cap * view.total_requests_seen as f64
            - view.total_preemptions as f64)
            .floor()
            .max(0.0) as usize;
        // Gate on the logger instead of reading the environment: an
        // env read on the deterministic sim hot path is a wall-domain
        // leak (lint rule D2's env-var case, added with this fix).
        if log::log_enabled!(log::Level::Debug) && !preempted.is_empty() {
            log::debug!(
                "cap: seen={} preempts={} allowed={} this_round={}",
                view.total_requests_seen,
                view.total_preemptions,
                allowed,
                preempted.len()
            );
        }
        if preempted.len() <= allowed {
            return desired;
        }
        // Over budget: only the `allowed` lowest-priority runners may be
        // displaced. Every other currently-running request is kept
        // (keeping a resident request costs nothing), and the remaining
        // memory is filled with the desired non-running requests by
        // priority.
        let prio = |i: usize| cands[i].gain / cands[i].ctx.max(1) as f64;
        let mut victims = preempted;
        victims.sort_by(|&i, &j| prio(i).total_cmp(&prio(j)));
        victims.truncate(allowed);
        let victim_set: std::collections::HashSet<usize> = victims.iter().copied().collect();
        // Keep all runners except the allowed victims.
        let mut result: Vec<usize> = (0..cands.len())
            .filter(|&i| cands[i].running && !victim_set.contains(&i))
            .collect();
        let budget = self.block_budget(view);
        let mut used: usize = result.iter().map(|&i| cands[i].blocks).sum();
        // Fill with desired non-running requests, best priority first.
        let mut rest: Vec<usize> =
            desired.into_iter().filter(|&i| !cands[i].running).collect();
        rest.sort_by(|&i, &j| prio(j).total_cmp(&prio(i)));
        for i in rest {
            if used + cands[i].blocks <= budget {
                used += cands[i].blocks;
                result.push(i);
            }
        }
        result
    }
}

impl Scheduler for AndesScheduler {
    fn name(&self) -> &'static str {
        "andes"
    }

    fn schedule(&mut self, view: &SchedView<'_>) -> Vec<RequestId> {
        if view.active.is_empty() {
            return Vec::new();
        }
        let horizon = self.cfg.delta_t_override.unwrap_or(view.horizon);
        self.build_candidates(view, horizon);

        // Optimization #1: serve everyone while unconstrained.
        if self.unconstrained(view) {
            return self.scratch.candidates.iter().map(|c| c.id).collect();
        }

        // Optimization #2: pruned batch-size range, subsampled to a grid.
        let (b_min, b_max) = self.batch_size_range(view);
        let grid = self.candidate_grid(b_min, b_max);

        let avg_ctx = view.avg_context_len();
        let budget = self.block_budget(view);
        // Global current QoE floor (MaxMin objective input).
        let q_min = self
            .scratch
            .candidates
            .iter()
            .map(|c| c.q_current)
            .fold(f64::INFINITY, f64::min);

        let mut best: Option<(f64, Vec<usize>)> = None;
        for &b in &grid {
            // Token generation rate per request at batch size B
            // (Appendix B: context length ≈ perfectly correlated with B).
            let rate = 1.0 / view.latency.decode(b, b * avg_ctx);
            // Fill gains for this B.
            for k in 0..self.scratch.candidates.len() {
                let c = self.scratch.candidates[k];
                let q_serve = Self::q_serve(&c, rate);
                let outlook =
                    QoeOutlook { q_serve, q_wait: c.q_wait, q_current: c.q_current };
                self.scratch.candidates[k].gain =
                    self.cfg.objective.gain(&outlook, q_min).max(0.0);
            }
            let (chosen, value) = match self.cfg.solver {
                KnapsackSolver::Greedy => self.pack_greedy(b, budget),
                KnapsackSolver::Dp => self.pack_dp(b, budget),
            };
            // Prefer larger B on ties (more concurrent progress).
            if best.as_ref().map_or(true, |(v, _)| value >= *v) {
                best = Some((value, chosen));
            }
        }
        // lint:allow(D6, grid is non-empty so the loop always sets best)
        let (_, desired) = best.unwrap();

        // Anti-thrash hysteresis, then the hard preemption cap
        // (Optimization #4). Gains from the last grid B are fine for
        // ordering purposes.
        let desired = self.apply_hysteresis(view, desired, horizon);
        let desired = self.apply_preemption_cap(view, desired);

        desired.into_iter().map(|i| self.scratch.candidates[i].id).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sched::testutil::Fixture;
    use crate::qoe::spec::QoeSpec;

    #[test]
    fn unconstrained_serves_everyone() {
        let mut f = Fixture::new(&[(50, 10, 0.0), (50, 10, 0.5)], 100_000);
        f.now = 1.0;
        static ACTIVE: &[RequestId] = &[0, 1];
        let mut s = AndesScheduler::with_defaults();
        let got = s.schedule(&f.view(ACTIVE));
        assert_eq!(got.len(), 2);
    }

    #[test]
    fn memory_pressure_triggers_knapsack_and_respects_capacity() {
        // 10 blocks (160 tokens); three requests of 4 blocks each → only
        // 2 fit under the 0.9 watermark (9 blocks).
        let mut f = Fixture::new(
            &[(60, 50, 0.0), (60, 50, 0.1), (60, 50, 0.2)],
            160,
        );
        f.now = 5.0;
        static ACTIVE: &[RequestId] = &[0, 1, 2];
        let mut s = AndesScheduler::with_defaults();
        let got = s.schedule(&f.view(ACTIVE));
        assert!(got.len() <= 2, "must respect memory: {got:?}");
        assert!(!got.is_empty());
    }

    #[test]
    fn prioritizes_urgent_waiting_request_over_satisfied_running() {
        // Request 0 has been running and is far ahead of its expected
        // timeline (deep client buffer). Request 1 is waiting, past its
        // expected TTFT, QoE collapsing. With room for only one, Andes
        // must serve request 1.
        let mut f = Fixture::new(&[(60, 200, 0.0), (60, 200, 0.0)], 160);
        // Give request 0 a large head start: 40 tokens in the first second.
        f.run(0);
        for i in 0..40 {
            f.requests[0].deliver_token(0.5 + i as f64 * 0.01);
        }
        f.now = 2.0; // request 1 now 1.0s past its expected TTFT
        static ACTIVE: &[RequestId] = &[0, 1];
        let mut s = AndesScheduler::with_defaults();
        let got = s.schedule(&f.view(ACTIVE));
        assert!(got.contains(&1), "urgent waiting request must be served: {got:?}");
    }

    #[test]
    fn priority_discounts_by_context_length() {
        // Two equally-urgent waiting requests, one with a much longer
        // context: the short one packs first and when only one fits,
        // it is the short one.
        let mut f = Fixture::new(&[(120, 50, 0.0), (16, 50, 0.0)], 160);
        f.now = 3.0;
        static ACTIVE: &[RequestId] = &[0, 1];
        let mut s = AndesScheduler::with_defaults();
        let got = s.schedule(&f.view(ACTIVE));
        assert!(got.contains(&1), "short request should win: {got:?}");
    }

    #[test]
    fn preemption_cap_blocks_excess_preemptions() {
        let mut f = Fixture::new(&[(60, 200, 0.0), (60, 200, 0.0), (60, 200, 0.0)], 160);
        f.run(0);
        f.run(1);
        // Both running are ahead; request 2 waiting and urgent.
        for i in 0..30 {
            f.requests[0].deliver_token(0.2 + i as f64 * 0.01);
            f.requests[1].deliver_token(0.2 + i as f64 * 0.01);
        }
        f.now = 3.0;
        static ACTIVE: &[RequestId] = &[0, 1, 2];
        // Cap = 0: no preemption allowed at all.
        let mut s = AndesScheduler::new(AndesConfig {
            preemption_cap: 0.0,
            ..AndesConfig::default()
        });
        let mut view = f.view(ACTIVE);
        view.total_preemptions = 0;
        let got = s.schedule(&view);
        assert!(
            got.contains(&0) && got.contains(&1),
            "cap=0 must keep running requests resident: {got:?}"
        );
    }

    #[test]
    fn starved_request_priority_rises_over_time() {
        // The same waiting request gains priority as time passes
        // (starvation prevention, §4.2 goal b).
        let mut f = Fixture::new(&[(60, 100, 0.0), (60, 100, 0.0)], 160);
        f.run(0);
        for i in 0..40 {
            f.requests[0].deliver_token(0.3 + i as f64 * 0.01);
        }
        static ACTIVE: &[RequestId] = &[0, 1];

        // Shortly after arrival (before expected TTFT) Andes may keep 0.
        f.now = 0.5;
        let mut s = AndesScheduler::with_defaults();
        let _early = s.schedule(&f.view(ACTIVE));

        // Long past TTFT the waiting request must be in the batch.
        f.now = 10.0;
        let late = s.schedule(&f.view(ACTIVE));
        assert!(late.contains(&1), "{late:?}");
    }

    #[test]
    fn dp_solver_agrees_with_greedy_on_easy_instance() {
        let mut f = Fixture::new(
            &[(60, 50, 0.0), (60, 50, 0.1), (60, 50, 0.2)],
            160,
        );
        f.now = 5.0;
        static ACTIVE: &[RequestId] = &[0, 1, 2];
        let mut greedy = AndesScheduler::with_defaults();
        let mut dp = AndesScheduler::new(AndesConfig {
            solver: KnapsackSolver::Dp,
            ..AndesConfig::default()
        });
        let a = greedy.schedule(&f.view(ACTIVE));
        let b = dp.schedule(&f.view(ACTIVE));
        assert_eq!(a.len(), b.len());
    }

    #[test]
    fn respects_explicit_delta_t() {
        let mut f = Fixture::new(&[(60, 50, 0.0)], 100_000);
        f.now = 1.0;
        static ACTIVE: &[RequestId] = &[0];
        let mut s = AndesScheduler::new(AndesConfig {
            delta_t_override: Some(5.0),
            ..AndesConfig::default()
        });
        assert_eq!(s.schedule(&f.view(ACTIVE)), vec![0]);
    }

    /// Regression guard for the partial_cmp → total_cmp migration: on
    /// finite keys (the only values the scheduler produces) the two
    /// comparators induce the same stable sort, so victim/newcomer
    /// ordering is unchanged by the switch.
    #[test]
    fn total_cmp_preserves_finite_sort_order() {
        let gains = [
            3.5, -1.25, 0.0, 3.5, 7.0, -1.25, 0.5, 100.0, -64.0, 0.0, 2.5, 3.5,
        ];
        let mut by_total: Vec<usize> = (0..gains.len()).collect();
        by_total.sort_by(|&a, &b| gains[a].total_cmp(&gains[b]));
        let mut by_partial: Vec<usize> = (0..gains.len()).collect();
        // lint:allow(D3, equivalence oracle: the old comparator, on finite keys only)
        by_partial.sort_by(|&a, &b| gains[a].partial_cmp(&gains[b]).unwrap());
        assert_eq!(by_total, by_partial, "ordering changed under total_cmp");
    }

    /// Pin the scheduler's decision on a seeded contended fixture: same
    /// inputs → same desired set, in the same order, across instances.
    #[test]
    fn contended_schedule_ordering_is_pinned() {
        let mut f = Fixture::new(
            &[(60, 200, 0.0), (60, 200, 0.1), (60, 200, 0.2), (16, 50, 0.3)],
            160,
        );
        f.run(0);
        f.run(1);
        // Runner 0 coasts far ahead; runner 1 barely started.
        for i in 0..40 {
            f.requests[0].deliver_token(0.5 + i as f64 * 0.01);
        }
        f.requests[1].deliver_token(1.9);
        f.now = 2.0;
        static ACTIVE: &[RequestId] = &[0, 1, 2, 3];
        let first = AndesScheduler::with_defaults().schedule(&f.view(ACTIVE));
        let second = AndesScheduler::with_defaults().schedule(&f.view(ACTIVE));
        assert_eq!(first, second, "schedule must be deterministic");
        // The exact ordering is part of the pinned contract: the short
        // urgent newcomer (3) packs ahead of the coasting runner (0).
        assert!(first.contains(&3), "short urgent newcomer must be served: {first:?}");
        assert!(!first.is_empty(), "contended schedule must serve someone");
    }

    /// Bug regression: hysteresis used to evict weak newcomers one by
    /// one and, when the freed blocks never covered the runner, leave
    /// both the runner *and* the evicted newcomers out — silently
    /// shrinking the batch. Block usage must be non-decreasing across
    /// hysteresis.
    #[test]
    fn hysteresis_restores_evicted_newcomers_when_runner_never_fits() {
        // 10 blocks, budget 9. Runner 0 needs 10 blocks (ctx 150) so it
        // can never fit back; newcomers 1 (4 blocks), 2 and 3 (2 each).
        let mut f = Fixture::new(
            &[(150, 200, 0.0), (60, 200, 0.0), (16, 50, 0.0), (16, 50, 0.0)],
            160,
        );
        f.run(0);
        f.now = 5.0;
        static ACTIVE: &[RequestId] = &[0, 1, 2, 3];
        // Infinite margin: every displacement counts as marginal, so the
        // pre-fix code evicts all newcomers chasing a runner that can
        // never fit, emptying the batch.
        let mut s = AndesScheduler::new(AndesConfig {
            preempt_margin: 1e9,
            ..AndesConfig::default()
        });
        let view = f.view(ACTIVE);
        s.build_candidates(&view, 30.0);
        let desired = vec![1usize, 2, 3];
        let used_before: usize =
            desired.iter().map(|&i| s.scratch.candidates[i].blocks).sum();
        let result = s.apply_hysteresis(&view, desired, 30.0);
        let used_after: usize =
            result.iter().map(|&i| s.scratch.candidates[i].blocks).sum();
        assert!(
            used_after >= used_before,
            "batch block-usage shrank across hysteresis: {used_after} < {used_before}"
        );
        for w in [1usize, 2, 3] {
            assert!(result.contains(&w), "evicted newcomer {w} not restored: {result:?}");
        }
    }

    /// Bug regression: with `b_grid: 1` the grid subsample divided by
    /// `b_grid - 1 = 0`, producing `NaN → 0` and collapsing the whole
    /// scan to `b_min`. The grid must still span [b_min, b_max].
    #[test]
    fn degenerate_b_grid_still_spans_full_range() {
        let s = AndesScheduler::new(AndesConfig { b_grid: 1, ..AndesConfig::default() });
        let grid = s.candidate_grid(1, 40);
        assert_eq!(grid.first(), Some(&1));
        assert_eq!(grid.last(), Some(&40), "b_grid=1 collapsed the scan: {grid:?}");
        assert!(grid.len() >= 2);
        // b_grid: 0 used to produce an *empty* grid and panic on
        // `best.unwrap()` in schedule().
        let s0 = AndesScheduler::new(AndesConfig { b_grid: 0, ..AndesConfig::default() });
        assert!(!s0.candidate_grid(3, 50).is_empty());
        let mut f = Fixture::new(&[(60, 50, 0.0), (60, 50, 0.1), (60, 50, 0.2)], 160);
        f.now = 5.0;
        static ACTIVE: &[RequestId] = &[0, 1, 2];
        let mut sched =
            AndesScheduler::new(AndesConfig { b_grid: 0, ..AndesConfig::default() });
        let got = sched.schedule(&f.view(ACTIVE));
        assert!(!got.is_empty(), "b_grid=0 must still schedule someone");
    }

    /// Slack mechanism (DESIGN.md §15): a runner whose *estimated
    /// client* buffer is empty cannot absorb the swap stall — the
    /// slack-aware scheduler must keep it resident even though the
    /// server-side digest makes it look like a coasting deep-buffer
    /// runner (the slack-blind arm preempts it).
    #[test]
    fn slack_protects_buffer_starved_runner_from_preemption() {
        use crate::coordinator::slack::{SlackConfig, SlackEstimator};
        let mut f = Fixture::new(&[(60, 200, 0.0), (60, 200, 0.0)], 160);
        f.run(0);
        for i in 0..40 {
            f.requests[0].deliver_token(0.5 + i as f64 * 0.01);
        }
        f.now = 2.0;
        static ACTIVE: &[RequestId] = &[0, 1];
        let blind = AndesScheduler::with_defaults().schedule(&f.view(ACTIVE));
        assert!(
            blind.contains(&1) && !blind.contains(&0),
            "slack-blind arm should preempt the coasting runner: {blind:?}"
        );
        // The modeled pacer released one token long ago and the client
        // digested it: window ≈ 0 < swap stall → runner is pinned.
        let mut est = SlackEstimator::new(SlackConfig::default());
        est.on_token(0, &f.requests[0].qoe_spec, 0.5);
        f.slack = Some(est);
        let aware = AndesScheduler::with_defaults().schedule(&f.view(ACTIVE));
        assert!(
            aware.contains(&0),
            "slack-aware arm must keep the buffer-starved runner: {aware:?}"
        );
    }

    /// Slack mechanism (DESIGN.md §15): a genuinely deep client buffer
    /// shrinks the hysteresis margin, making the runner near-free to
    /// pause — the same gain differential that hysteresis would veto in
    /// slack-blind mode displaces the runner in slack-aware mode.
    #[test]
    fn deep_slack_window_makes_runner_near_free_to_pause() {
        use crate::coordinator::slack::{SlackConfig, SlackEstimator};
        let mut f = Fixture::new(&[(60, 200, 0.0), (60, 200, 0.0)], 160);
        f.run(0);
        for i in 0..40 {
            f.requests[0].deliver_token(0.5 + i as f64 * 0.01);
        }
        f.now = 2.0;
        static ACTIVE: &[RequestId] = &[0, 1];

        // Blind arm: margin 0.2 vetoes a 0.1-gain displacement and the
        // runner (7 blocks ≤ budget 9) is reinstated.
        let mut blind = AndesScheduler::with_defaults();
        let view = f.view(ACTIVE);
        blind.build_candidates(&view, 30.0);
        blind.scratch.candidates[0].gain = 0.0;
        blind.scratch.candidates[1].gain = 0.1;
        let kept = blind.apply_hysteresis(&view, vec![1], 30.0);
        assert!(kept.contains(&0), "blind hysteresis must reinstate the runner: {kept:?}");

        // Aware arm: the pacer replay leaves several tokens buffered
        // (window ≫ swap stall), so the margin collapses and the same
        // 0.1 differential justifies the displacement.
        let mut est = SlackEstimator::new(SlackConfig::default());
        for i in 0..40 {
            est.on_token(0, &f.requests[0].qoe_spec, 0.5 + i as f64 * 0.01);
        }
        f.slack = Some(est);
        let view = f.view(ACTIVE);
        let mut aware = AndesScheduler::with_defaults();
        aware.build_candidates(&view, 30.0);
        assert!(
            aware.scratch.candidates[0].slack_window.unwrap_or(0.0) > 0.5,
            "estimated window should be deep: {:?}",
            aware.scratch.candidates[0].slack_window
        );
        aware.scratch.candidates[0].gain = 0.0;
        aware.scratch.candidates[1].gain = 0.1;
        let displaced = aware.apply_hysteresis(&view, vec![1], 30.0);
        assert!(
            displaced.contains(&1) && !displaced.contains(&0),
            "deep-buffer runner must be near-free to pause: {displaced:?}"
        );
    }

    #[test]
    fn voice_spec_tolerates_larger_batches() {
        // With slower expected TDS (voice), B_min grows — more requests
        // can run concurrently with no QoE penalty.
        let mut f = Fixture::new(&[(60, 50, 0.0); 4], 100_000);
        for r in f.requests.iter_mut() {
            r.qoe_spec = QoeSpec::new(1.0, 3.3);
        }
        f.now = 0.5;
        static ACTIVE: &[RequestId] = &[0, 1, 2, 3];
        let mut s = AndesScheduler::with_defaults();
        let got = s.schedule(&f.view(ACTIVE));
        assert_eq!(got.len(), 4);
    }
}

#[cfg(test)]
mod cap_tests {
    use super::*;
    use crate::coordinator::sched::testutil::Fixture;
    use crate::coordinator::request::{Phase, RequestId};

    #[test]
    fn cap_zero_budget_freezes_preemptions() {
        // Tight memory; 2 coasting runners + 2 urgent waiters; budget
        // exhausted (total_preemptions >= P * seen) → runners must stay.
        let mut f = Fixture::new(
            &[(60, 200, 0.0), (60, 200, 0.0), (60, 200, 0.0), (60, 200, 0.0)],
            160,
        );
        f.run(0);
        f.run(1);
        for i in 0..40 {
            f.requests[0].deliver_token(0.2 + i as f64 * 0.01);
            f.requests[1].deliver_token(0.2 + i as f64 * 0.01);
        }
        f.now = 5.0;
        static ACTIVE: &[RequestId] = &[0, 1, 2, 3];
        let mut view = f.view(ACTIVE);
        view.total_preemptions = 100; // ≫ P * 4
        let mut s = AndesScheduler::with_defaults();
        let got = s.schedule(&view);
        assert!(
            got.contains(&0) && got.contains(&1),
            "exhausted budget must keep runners: {got:?}"
        );
        let _ = Phase::Running;
    }
}
