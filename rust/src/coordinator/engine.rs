//! The continuous-batching serving engine (paper Fig. 6).
//!
//! One iteration = ① ingest arrivals, ② ask the scheduler for the
//! desired running set, ③ apply the diff (preempt via swap with
//! recompute fallback; admit via swap-in or prefill), ④ run one model
//! step (a prefill pass if anyone was just admitted from Waiting, else a
//! decode pass), ⑤ deliver tokens and retire finished requests.
//!
//! The engine is generic over [`ExecutionBackend`] and [`Clock`], so the
//! same coordinator code drives both the calibrated simulator and the
//! real PJRT-compiled model (DESIGN.md §2).

use crate::backend::{BackendRequest, Clock, ExecutionBackend, PrefillJob};
use crate::model::latency::LatencyModel;
use crate::telemetry::Telemetry;
use crate::workload::RequestSpec;

use super::calendar::{EventCalendar, EventKind};
use super::kv::KvCacheManager;
use super::metrics::{IterationSample, Metrics};
use super::request::{Phase, Request, RequestId};
use super::sched::{SchedView, Scheduler};
use super::slack::{SlackConfig, SlackEstimator};

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// KV block size in tokens (vLLM default 16).
    pub block_size: usize,
    /// Device KV capacity in tokens (`M` of Eq. 3).
    pub kv_capacity_tokens: usize,
    /// Host swap pool capacity in tokens.
    pub swap_capacity_tokens: usize,
    /// Hard cap on generated tokens per request (safety net).
    pub max_output_tokens: usize,
    /// Prefer swap (true) or recompute (false) for preemption.
    pub prefer_swap: bool,
    /// Initial Δt estimate before any request completes (s).
    pub initial_horizon: f64,
    /// Park a finished session turn's KV in the host pool for the
    /// session's next turn (prefix retention, DESIGN.md §10). Disabled
    /// by default: off, the engine is bit-identical to pre-session
    /// behavior even on session-annotated traces.
    pub park_prefixes: bool,
    /// Drive trace arrivals from the legacy reverse-sorted pending
    /// vector instead of the event calendar. Both paths are proven
    /// bit-identical by `tests/calendar.rs`; the toggle exists so the
    /// parity suite can keep exercising the pre-calendar stepping until
    /// the legacy path is deleted.
    pub legacy_stepping: bool,
    /// Estimate per-request client-buffer slack and expose it to the
    /// scheduler (DESIGN.md §15). Disabled by default: `None` keeps the
    /// `SchedView` slack-blind and the engine bit-identical to
    /// pre-slack behavior.
    pub slack: Option<SlackConfig>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            block_size: 16,
            kv_capacity_tokens: 16 * 4096,
            swap_capacity_tokens: 16 * 8192,
            max_output_tokens: 2048,
            prefer_swap: true,
            initial_horizon: 60.0,
            park_prefixes: false,
            legacy_stepping: false,
            slack: None,
        }
    }
}

/// The serving engine.
pub struct Engine<B: ExecutionBackend, C: Clock> {
    cfg: EngineConfig,
    backend: B,
    clock: C,
    scheduler: Box<dyn Scheduler>,
    latency: LatencyModel,
    kv: KvCacheManager,
    requests: Vec<Request>,
    /// Non-finished request ids.
    active: Vec<RequestId>,
    /// Pending trace arrivals, reverse-sorted so pop() yields earliest.
    pending: Vec<RequestSpec>,
    /// Event timeline mirroring `pending` (one Arrival/SessionReturn
    /// wakeup per spec, in pop order) — the calendar stepping path.
    calendar: EventCalendar,
    metrics: Metrics,
    /// Client-buffer slack estimator, present iff `cfg.slack` is set.
    slack: Option<SlackEstimator>,
    /// Running average of request completion time (the Δt estimate).
    completion_avg: f64,
    completions: u64,
    started: bool,
    /// Observation handle (disabled by default — zero-cost no-ops).
    telemetry: Telemetry,
    /// Replica label for metric series ("r0", "r1", …).
    replica_label: String,
}

impl<B: ExecutionBackend, C: Clock> Engine<B, C> {
    pub fn new(
        cfg: EngineConfig,
        backend: B,
        clock: C,
        scheduler: Box<dyn Scheduler>,
        latency: LatencyModel,
    ) -> Self {
        let kv = KvCacheManager::new(
            cfg.kv_capacity_tokens,
            cfg.swap_capacity_tokens,
            cfg.block_size,
        );
        let slack = cfg.slack.map(SlackEstimator::new);
        Engine {
            cfg,
            backend,
            clock,
            scheduler,
            latency,
            kv,
            requests: Vec::new(),
            active: Vec::new(),
            pending: Vec::new(),
            calendar: EventCalendar::new(),
            metrics: Metrics::new(),
            slack,
            completion_avg: 0.0,
            completions: 0,
            started: false,
            telemetry: Telemetry::disabled(),
            replica_label: "r0".to_string(),
        }
    }

    /// Attach a telemetry handle, labeling this engine's series as
    /// replica `replica`. The engine records batch occupancy and KV
    /// watermark gauges per iteration, iteration/preemption/prefix-hit
    /// counters, and per-request prefill/first-token/preempt/restore
    /// trace events keyed by the submitting spec's trace id.
    pub fn set_telemetry(&mut self, tel: Telemetry, replica: usize) {
        self.telemetry = tel;
        self.replica_label = format!("r{replica}");
    }

    pub fn telemetry(&self) -> &Telemetry {
        &self.telemetry
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn metrics_mut(&mut self) -> &mut Metrics {
        &mut self.metrics
    }

    pub fn kv(&self) -> &KvCacheManager {
        &self.kv
    }

    pub fn requests(&self) -> &[Request] {
        &self.requests
    }

    pub fn clock(&self) -> &C {
        &self.clock
    }

    pub fn backend(&self) -> &B {
        &self.backend
    }

    pub fn backend_mut(&mut self) -> &mut B {
        &mut self.backend
    }

    pub fn latency(&self) -> &LatencyModel {
        &self.latency
    }

    /// The engine's slack estimator, when `cfg.slack` is set (test and
    /// gateway observability).
    pub fn slack_estimator(&self) -> Option<&SlackEstimator> {
        self.slack.as_ref()
    }

    pub fn now(&self) -> f64 {
        self.clock.now()
    }

    /// Number of active (unfinished) requests: waiting + running + swapped.
    pub fn active_count(&self) -> usize {
        self.active.len()
    }

    /// Tokens parked for `session_id` on this engine's host pool (0
    /// when absent) — the gateway's affinity/admission probe.
    pub fn parked_prefix_tokens(&self, session_id: u64) -> usize {
        self.kv.parked_tokens(session_id).unwrap_or(0)
    }

    /// Mean context length across active requests (0 when idle).
    pub fn avg_active_context(&self) -> usize {
        if self.active.is_empty() {
            return 0;
        }
        let total: usize =
            self.active.iter().map(|&id| self.requests[id].context_len()).sum();
        (total / self.active.len()).max(1)
    }

    /// Queue a whole workload trace (sim mode). Non-finite arrivals are
    /// clamped to the trace origin: a NaN would neither sort stably
    /// (the old `partial_cmp().unwrap()` panicked here) nor ever be
    /// ingested (`NaN <= now` is false — `run_to_completion` would hang
    /// with the request pending forever).
    pub fn load_trace(&mut self, mut specs: Vec<RequestSpec>) {
        for s in &mut specs {
            if !s.arrival.is_finite() {
                s.arrival = 0.0;
            }
        }
        specs.sort_by(|a, b| b.arrival.total_cmp(&a.arrival));
        self.pending = specs;
        if !self.cfg.legacy_stepping {
            // Mirror the pending vector onto the calendar in pop order
            // (earliest first; ties keep the pop order of the stable
            // descending sort), so `(time, seq)` firing order equals
            // the legacy `pending.pop()` order exactly.
            self.calendar.clear();
            for s in self.pending.iter().rev() {
                let kind = if s.session.is_some_and(|sess| sess.is_returning()) {
                    EventKind::SessionReturn
                } else {
                    EventKind::Arrival
                };
                self.calendar.register(s.arrival, kind, s.id as u64);
            }
        }
    }

    /// Submit one request immediately (live serving mode). Returns its id.
    pub fn submit(&mut self, spec: RequestSpec) -> anyhow::Result<RequestId> {
        self.submit_with_prompt(spec, Vec::new())
    }

    /// Submit with concrete prompt token ids (real-model serving; the
    /// simulator only needs the length). `spec.prompt_tokens` is
    /// overridden by the actual token count when a prompt is given.
    pub fn submit_with_prompt(
        &mut self,
        mut spec: RequestSpec,
        prompt: Vec<u32>,
    ) -> anyhow::Result<RequestId> {
        if !prompt.is_empty() {
            spec.prompt_tokens = prompt.len();
        }
        let id = self.requests.len();
        // Preserve a past arrival timestamp so queueing delay outside the
        // engine (e.g. a gateway defer queue) is charged to the request's
        // QoE; an unset arrival (0.0, live serving) is stamped with now.
        let now = self.clock.now();
        let arrival = if spec.arrival > 0.0 { spec.arrival } else { now };
        self.backend.register(BackendRequest {
            id,
            prompt,
            prompt_tokens: spec.prompt_tokens,
            output_tokens: spec.output_tokens,
        })?;
        let mut req = Request::new(id, arrival, spec.prompt_tokens, spec.qoe);
        req.spec_id = spec.id;
        req.session = spec.session;
        self.requests.push(req);
        self.active.push(id);
        Ok(id)
    }

    fn ingest_arrivals(&mut self) -> anyhow::Result<()> {
        let now = self.clock.now();
        if self.cfg.legacy_stepping {
            while self.pending.last().is_some_and(|s| s.arrival <= now) {
                // lint:allow(D6, last() just returned Some in the loop condition)
                let spec = self.pending.pop().unwrap();
                self.submit(spec)?;
            }
        } else {
            // The calendar fires in the same order the legacy path
            // pops, so draining both in lockstep keeps `pending` and
            // the timeline consistent.
            while self.calendar.peek().is_some_and(|w| w.time <= now) {
                self.calendar.pop();
                // lint:allow(D6, the calendar holds one wakeup per pending spec)
                let spec = self.pending.pop().unwrap();
                self.submit(spec)?;
            }
        }
        Ok(())
    }

    /// Earliest pending trace arrival — the legacy vector peek or the
    /// calendar's next live wakeup, depending on the stepping mode.
    fn next_arrival_time(&mut self) -> Option<f64> {
        if self.cfg.legacy_stepping {
            self.pending.last().map(|s| s.arrival)
        } else {
            self.calendar.next_time()
        }
    }

    /// Preempt `id` out of the running batch: swap if preferred and
    /// possible, else drop + mark for recompute.
    fn preempt(&mut self, id: RequestId) {
        debug_assert_eq!(self.requests[id].phase, Phase::Running);
        // Instrumentation (ext-slack): count preemptions of runners whose
        // *server-side* digest shows a buffer deep enough to cover a full
        // swap-out + swap-in round trip. Measured identically whether the
        // slack estimator is on or off (it reads only the request's own
        // digest), so it never perturbs scheduling.
        {
            let req = &self.requests[id];
            let rel_now = self.clock.now() - req.arrival;
            let mut d = req.digest;
            d.advance_to(rel_now);
            let window = d.buffered() / req.qoe_spec.tds.max(1e-9);
            if window >= 2.0 * self.latency.swap(req.context_len()) {
                self.metrics.deep_buffer_preemptions += 1;
            }
        }
        let mut swapped = false;
        if self.cfg.prefer_swap {
            if let Ok(tokens) = self.kv.swap_out(id) {
                let cost = self.backend.swap_cost(tokens);
                self.clock.advance(cost);
                self.requests[id].phase = Phase::SwappedOut;
                self.metrics.swap_preemptions += 1;
                swapped = true;
            }
        }
        if !swapped {
            // Recompute: drop KV entirely; prefill replays on readmission.
            let _ = self.kv.free(id);
            self.backend.drop_kv(id);
            self.requests[id].phase = Phase::Waiting;
            self.metrics.recompute_preemptions += 1;
        }
        self.requests[id].preemptions += 1;
        self.metrics.total_preemptions += 1;
        // A swap-out may have evicted parked prefixes for room.
        self.metrics.park_evictions = self.kv.park_evictions();
        if self.telemetry.is_enabled() {
            let kind = if swapped { "swap" } else { "recompute" };
            self.telemetry.inc(
                "andes_preemptions_total",
                &[("kind", kind), ("replica", &self.replica_label)],
                1.0,
            );
            self.telemetry.event(
                self.requests[id].spec_id as u64,
                "preempt",
                self.clock.now(),
                &[("kind", kind.into())],
            );
        }
    }

    /// Claim a parked session prefix for a first admission, if one
    /// exists. Returns the token count whose prefill is skipped — 0 on
    /// a cold start, a one-shot request, or a recompute readmission
    /// (the claimed prefix was dropped with the rest of the KV, so the
    /// replay pays full prefill).
    fn claim_prefix(&mut self, id: RequestId, ctx: usize) -> usize {
        let r = &self.requests[id];
        if r.generated > 0 || r.preemptions > 0 || r.prefix_hit_tokens > 0 {
            return 0;
        }
        let Some(s) = r.session else { return 0 };
        if !s.is_returning() {
            return 0;
        }
        if self.kv.parked_tokens(s.session_id).is_none() {
            return 0; // evicted, never parked, or parked on another replica
        }
        // The entry belongs to this session's previous turn; claim it
        // whether or not it is usable — the turn now being served
        // supersedes it either way.
        // lint:allow(D6, parked_tokens() returned Some for this session just above)
        let parked = self.kv.claim_parked(s.session_id).expect("checked above");
        // The hit covers at most the declared shared prefix, and leaves
        // at least one fresh token to prefill (producing the next
        // token).
        let hit = s.usable_prefix(parked).min(ctx.saturating_sub(1));
        if hit == 0 {
            return 0;
        }
        // The cheap (transfer-instead-of-compute) prefill runs in the
        // same tick as this claim — preemption is decided before
        // admissions and the OOM net skips prefilling requests — so a
        // later recompute preemption cannot retroactively void the
        // TTFT benefit these counters record.
        self.requests[id].prefix_hit_tokens = hit;
        self.metrics.prefix_hits += 1;
        self.metrics.prefix_hit_tokens += hit as u64;
        self.telemetry.inc(
            "andes_prefix_hits_total",
            &[("replica", &self.replica_label)],
            1.0,
        );
        hit
    }

    /// Retire a finished request. With prefix parking enabled, a
    /// session turn that expects a follow-up parks its KV in the host
    /// pool (keyed by session id) instead of freeing it; the next turn
    /// claims it and skips the shared-prefix prefill. Parking falls
    /// back to a plain free when the host pool cannot hold the context
    /// even after LRU eviction.
    fn finish(&mut self, id: RequestId, now: f64) {
        let r = &mut self.requests[id];
        r.phase = Phase::Finished;
        r.finished_at = Some(now);
        let completion = now - r.arrival;
        self.completions += 1;
        self.completion_avg +=
            (completion - self.completion_avg) / self.completions as f64;
        let park_key = match self.requests[id].session {
            Some(s) if self.cfg.park_prefixes && s.expects_return() => Some(s.session_id),
            _ => None,
        };
        let parked = match park_key {
            Some(key) => self.kv.park(key, id).is_ok(),
            None => false,
        };
        if parked {
            self.metrics.prefixes_parked += 1;
        } else {
            let _ = self.kv.free(id);
        }
        self.metrics.park_evictions = self.kv.park_evictions();
        self.backend.release(id);
        self.metrics.record_finish(&self.requests[id]);
        self.scheduler.on_finish(id);
        if let Some(sl) = self.slack.as_mut() {
            sl.on_finish(id);
        }
        self.active.retain(|&a| a != id);
    }

    /// Whether any work remains (active requests or pending arrivals).
    pub fn has_work(&self) -> bool {
        !self.active.is_empty() || !self.pending.is_empty()
    }

    /// Advance the clock to `t` if it lags (cluster-level coordination of
    /// idle replicas; a no-op for wall clocks already past `t`).
    pub fn advance_clock_to(&mut self, t: f64) {
        self.clock.advance_to(t);
    }

    /// Run one engine iteration. Returns false when idle with nothing
    /// pending.
    pub fn tick(&mut self) -> anyhow::Result<bool> {
        if !self.started {
            self.metrics.started_at = self.clock.now();
            self.started = true;
        }
        self.ingest_arrivals()?;

        if self.active.is_empty() {
            match self.next_arrival_time() {
                Some(t) => {
                    self.clock.advance_to(t);
                    self.metrics.ended_at = self.clock.now();
                    return Ok(true);
                }
                None => {
                    self.metrics.ended_at = self.clock.now();
                    return Ok(false);
                }
            }
        }

        // ② Scheduling decision. (Split borrows: the scheduler is &mut
        // while the view borrows the rest of the engine immutably.)
        // lint:allow(D2, wall-clock profiling of scheduler overhead, reported outside sim results)
        let sched_t0 = std::time::Instant::now();
        let view = SchedView {
            now: self.clock.now(),
            horizon: if self.completions == 0 {
                self.cfg.initial_horizon
            } else {
                self.completion_avg
            },
            requests: &self.requests,
            active: &self.active,
            kv: &self.kv,
            latency: &self.latency,
            total_requests_seen: self.requests.len(),
            total_preemptions: self.metrics.total_preemptions as usize,
            slack: self.slack.as_ref(),
        };
        let desired = self.scheduler.schedule(&view);
        self.metrics.scheduler_time += sched_t0.elapsed().as_secs_f64();

        // Sanitize: active, non-finished, deduped.
        let mut desired: Vec<RequestId> = desired
            .into_iter()
            .filter(|&id| id < self.requests.len() && self.requests[id].is_active())
            .collect();
        desired.dedup();

        let desired_set: std::collections::HashSet<RequestId> =
            desired.iter().copied().collect();

        // ③a Preempt departures first (frees blocks for admissions).
        let departures: Vec<RequestId> = self
            .active
            .iter()
            .copied()
            .filter(|&id| self.requests[id].phase == Phase::Running && !desired_set.contains(&id))
            .collect();
        for id in departures {
            self.preempt(id);
        }

        // ③b Admit newcomers: swap-in or schedule a prefill.
        let mut prefills: Vec<PrefillJob> = Vec::new();
        for &id in &desired {
            match self.requests[id].phase {
                Phase::Running => {}
                Phase::SwappedOut => {
                    if self.kv.swap_in(id).is_ok() {
                        let cost = self.backend.swap_cost(self.requests[id].context_len());
                        self.clock.advance(cost);
                        self.requests[id].phase = Phase::Running;
                        self.telemetry.event(
                            self.requests[id].spec_id as u64,
                            "restore",
                            self.clock.now(),
                            &[("kind", "swap_in".into())],
                        );
                    }
                    // else: no room this round; stays swapped.
                }
                Phase::Waiting => {
                    let ctx = self.requests[id].context_len();
                    if self.kv.allocate(id, ctx).is_ok() {
                        self.requests[id].phase = Phase::Running;
                        // A returning turn may restore its shared prefix
                        // from the session's parked KV (host→device
                        // transfer instead of prefill compute).
                        let cached = self.claim_prefix(id, ctx);
                        if self.telemetry.is_enabled() {
                            // A recompute readmission replays prefill;
                            // only the first pass is the span's
                            // prefill_start.
                            if self.requests[id].generated == 0
                                && self.requests[id].preemptions == 0
                            {
                                self.telemetry.event(
                                    self.requests[id].spec_id as u64,
                                    "prefill_start",
                                    self.clock.now(),
                                    &[
                                        ("context_tokens", (ctx as u64).into()),
                                        ("cached_tokens", (cached as u64).into()),
                                    ],
                                );
                            }
                        }
                        prefills.push(PrefillJob {
                            id,
                            context_tokens: ctx,
                            cached_tokens: cached,
                        });
                    }
                    // else: scheduler overcommitted; skip this round.
                }
                Phase::Finished => unreachable!(),
            }
        }

        // OOM safety net (vLLM behaviour): every running request must be
        // able to grow by one token this iteration; preempt the
        // latest-arrived runners until that holds.
        loop {
            let running: Vec<RequestId> = self
                .active
                .iter()
                .copied()
                .filter(|&id| self.requests[id].phase == Phase::Running)
                .collect();
            let needed: usize = running
                .iter()
                .filter(|&&id| {
                    !prefills.iter().any(|p| p.id == id)
                        && self.requests[id].context_len() % self.cfg.block_size == 0
                })
                .count();
            if needed <= self.kv.device_free_blocks() {
                break;
            }
            // Preempt the latest-arrived running request (vLLM policy).
            let victim = running
                .into_iter()
                .filter(|id| !prefills.iter().any(|p| p.id == *id))
                .max_by(|&a, &b| {
                    self.requests[a]
                        .arrival
                        .total_cmp(&self.requests[b].arrival)
                        .then(a.cmp(&b))
                });
            match victim {
                Some(v) => {
                    self.preempt(v);
                    self.metrics.oom_preemptions += 1;
                }
                None => break,
            }
        }

        // ④ Execute: a prefill pass if any admissions, else decode.
        let now_before = self.clock.now();
        if !prefills.is_empty() {
            let outcome = self.backend.prefill(&prefills)?;
            self.clock.advance(outcome.latency);
            let now = self.clock.now();
            let total_ctx: usize = prefills.iter().map(|p| p.context_tokens).sum();
            self.metrics.record_iteration(IterationSample {
                time: now_before,
                batch_size: prefills.len(),
                total_ctx,
                latency: outcome.latency,
                is_prefill: true,
            });
            self.note_iteration(prefills.len(), "prefill");
            for ev in outcome.tokens {
                // The prefill pass produces each request's next token.
                self.kv.extend(ev.id, 1).ok();
                self.deliver(ev.id, ev.finished, now);
            }
        } else {
            let running: Vec<RequestId> = self
                .active
                .iter()
                .copied()
                .filter(|&id| self.requests[id].phase == Phase::Running)
                .collect();
            if running.is_empty() {
                // Everything waiting couldn't be admitted (e.g. one giant
                // request larger than memory) — drop the smallest-context
                // blocked request to avoid livelock, or jump time.
                match self.next_arrival_time() {
                    Some(t) => self.clock.advance_to(t),
                    None => anyhow::bail!(
                        "livelock: {} active requests, none runnable",
                        self.active.len()
                    ),
                }
                self.metrics.ended_at = self.clock.now();
                return Ok(true);
            }
            let total_ctx: usize =
                running.iter().map(|&id| self.requests[id].context_len()).sum();
            let outcome = self.backend.decode(&running, total_ctx)?;
            self.clock.advance(outcome.latency);
            let now = self.clock.now();
            self.metrics.record_iteration(IterationSample {
                time: now_before,
                batch_size: running.len(),
                total_ctx,
                latency: outcome.latency,
                is_prefill: false,
            });
            self.note_iteration(running.len(), "decode");
            for ev in outcome.tokens {
                self.kv.extend(ev.id, 1).ok();
                self.deliver(ev.id, ev.finished, now);
            }
            for &id in &running {
                self.requests[id].service_iterations += 1;
            }
        }

        self.metrics.ended_at = self.clock.now();
        Ok(true)
    }

    /// Batch-occupancy and KV-watermark gauges plus the iteration
    /// counter, per replica (no-op on a disabled handle).
    fn note_iteration(&self, batch: usize, phase: &'static str) {
        if !self.telemetry.is_enabled() {
            return;
        }
        let labels = [("replica", self.replica_label.as_str())];
        self.telemetry.set_gauge("andes_batch_size", &labels, batch as f64);
        let cap = self.kv.device_capacity_tokens().max(1);
        let used = cap.saturating_sub(self.kv.device_free_tokens());
        self.telemetry.set_gauge(
            "andes_kv_used_fraction",
            &labels,
            used as f64 / cap as f64,
        );
        self.telemetry.inc(
            "andes_iterations_total",
            &[("phase", phase), ("replica", &self.replica_label)],
            1.0,
        );
    }

    fn deliver(&mut self, id: RequestId, finished: bool, now: f64) {
        self.requests[id].deliver_token(now);
        if let Some(sl) = self.slack.as_mut() {
            let req = &self.requests[id];
            sl.on_token(id, &req.qoe_spec, now - req.arrival);
        }
        let done = finished || self.requests[id].generated >= self.cfg.max_output_tokens;
        if done {
            self.finish(id, now);
        }
    }

    /// Drive the engine until the trace is exhausted and all requests
    /// finished. Returns the metrics.
    pub fn run_to_completion(&mut self) -> anyhow::Result<&Metrics> {
        while self.has_work() {
            self.tick()?;
        }
        Ok(&self.metrics)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::sim::SimBackend;
    use crate::backend::VirtualClock;
    use crate::coordinator::sched::andes::AndesScheduler;
    use crate::coordinator::sched::fcfs::FcfsScheduler;
    use crate::coordinator::sched::round_robin::RoundRobinScheduler;
    use crate::model::gpu::a100_4x;
    use crate::model::llm::opt_66b;
    use crate::qoe::spec::QoeSpec;

    fn sim_engine(
        scheduler: Box<dyn Scheduler>,
        kv_tokens: usize,
    ) -> Engine<SimBackend, VirtualClock> {
        let latency = LatencyModel::for_deployment(&opt_66b(), &a100_4x());
        let cfg = EngineConfig {
            kv_capacity_tokens: kv_tokens,
            swap_capacity_tokens: kv_tokens * 2,
            ..EngineConfig::default()
        };
        Engine::new(
            cfg,
            SimBackend::new(latency.clone()),
            VirtualClock::default(),
            scheduler,
            latency,
        )
    }

    fn spec(id: usize, arrival: f64, prompt: usize, output: usize) -> RequestSpec {
        RequestSpec {
            id,
            arrival,
            prompt_tokens: prompt,
            output_tokens: output,
            qoe: QoeSpec::new(1.0, 4.8),
            session: None,
        }
    }

    fn trace(n: usize, gap: f64) -> Vec<RequestSpec> {
        (0..n).map(|i| spec(i, i as f64 * gap, 100, 50)).collect()
    }

    #[test]
    fn fcfs_completes_all_requests() {
        let mut e = sim_engine(Box::new(FcfsScheduler::new()), 100_000);
        e.load_trace(trace(20, 0.5));
        let m = e.run_to_completion().unwrap();
        assert_eq!(m.requests.len(), 20);
        // Every request delivered exactly its ground-truth output.
        for r in &m.requests {
            assert_eq!(r.output_tokens, 50);
            assert_eq!(r.token_times.len(), 50);
        }
        assert!(m.throughput() > 0.0);
    }

    #[test]
    fn all_schedulers_complete_under_pressure() {
        // Tight memory: 2500 tokens ≈ 16 concurrent requests of ~150 ctx.
        for sched in [
            Box::new(FcfsScheduler::new()) as Box<dyn Scheduler>,
            Box::new(RoundRobinScheduler::new(50)),
            Box::new(AndesScheduler::with_defaults()),
        ] {
            let name = sched.name();
            let mut e = sim_engine(sched, 2500);
            e.load_trace(trace(40, 0.2));
            let m = e.run_to_completion().unwrap();
            assert_eq!(m.requests.len(), 40, "{name} lost requests");
            for r in &m.requests {
                assert_eq!(r.token_times.len(), 50, "{name} token conservation");
                assert!(
                    r.token_times.windows(2).all(|w| w[1] >= w[0] - 1e-12),
                    "{name} token times must be monotone"
                );
            }
        }
    }

    #[test]
    fn token_times_strictly_positive_latency() {
        let mut e = sim_engine(Box::new(FcfsScheduler::new()), 100_000);
        e.load_trace(trace(3, 0.1));
        let m = e.run_to_completion().unwrap();
        for r in &m.requests {
            assert!(r.ttft > 0.0, "TTFT must include prefill cost");
            assert!(r.finished_at > r.arrival);
        }
    }

    #[test]
    fn kv_is_fully_released_at_end() {
        let mut e = sim_engine(Box::new(AndesScheduler::with_defaults()), 3000);
        e.load_trace(trace(30, 0.15));
        e.run_to_completion().unwrap();
        assert_eq!(e.kv().num_allocations(), 0);
        assert_eq!(e.kv().device_free_tokens(), e.kv().device_capacity_tokens());
    }

    #[test]
    fn idle_engine_jumps_to_next_arrival() {
        let mut e = sim_engine(Box::new(FcfsScheduler::new()), 100_000);
        e.load_trace(vec![spec(0, 100.0, 50, 5)]);
        assert!(e.tick().unwrap());
        assert!(e.now() >= 100.0, "virtual clock must jump to arrival");
        e.run_to_completion().unwrap();
        assert_eq!(e.metrics().requests.len(), 1);
    }

    #[test]
    fn deterministic_same_seed_same_result() {
        let run = || {
            let mut e = sim_engine(Box::new(AndesScheduler::with_defaults()), 2500);
            e.load_trace(trace(30, 0.2));
            e.run_to_completion().unwrap().avg_qoe()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn preemptions_are_counted_consistently() {
        let mut e = sim_engine(Box::new(RoundRobinScheduler::new(5)), 2000);
        e.load_trace(trace(30, 0.1));
        let m = e.run_to_completion().unwrap();
        let per_req: usize = m.requests.iter().map(|r| r.preemptions).sum();
        assert_eq!(per_req as u64, m.total_preemptions);
        assert_eq!(
            m.total_preemptions,
            m.swap_preemptions + m.recompute_preemptions
        );
        assert!(m.total_preemptions > 0, "RR with quantum 5 must preempt");
    }

    #[test]
    fn live_submit_and_tick() {
        let mut e = sim_engine(Box::new(FcfsScheduler::new()), 100_000);
        e.submit(spec(0, 0.0, 64, 8)).unwrap();
        while e.has_work() {
            e.tick().unwrap();
        }
        assert_eq!(e.metrics().requests.len(), 1);
        let r = &e.metrics().requests[0];
        assert_eq!(r.output_tokens, 8);
    }

    #[test]
    fn load_trace_tolerates_nan_arrival() {
        // `partial_cmp().unwrap()` panicked here, and a raw total_cmp
        // sort would hang run_to_completion (a NaN arrival is never
        // ingested). The clamp must make the run complete with every
        // request served.
        let mut e = sim_engine(Box::new(FcfsScheduler::new()), 100_000);
        let mut bad = spec(1, 0.0, 50, 5);
        bad.arrival = f64::NAN;
        e.load_trace(vec![spec(0, 1.0, 50, 5), bad]);
        let m = e.run_to_completion().unwrap();
        assert_eq!(m.requests.len(), 2);
    }

    fn sspec(
        id: usize,
        arrival: f64,
        sid: u64,
        turn: usize,
        total: usize,
        prefix: usize,
        new_prompt: usize,
        output: usize,
    ) -> RequestSpec {
        use crate::workload::SessionInfo;
        RequestSpec {
            id,
            arrival,
            prompt_tokens: prefix + new_prompt,
            output_tokens: output,
            qoe: QoeSpec::new(1.0, 4.8),
            session: Some(SessionInfo {
                session_id: sid,
                turn,
                turns_total: total,
                prefix_tokens: prefix,
            }),
        }
    }

    fn session_engine(park: bool) -> Engine<SimBackend, VirtualClock> {
        let latency = LatencyModel::for_deployment(&opt_66b(), &a100_4x());
        let cfg = EngineConfig {
            kv_capacity_tokens: 100_000,
            swap_capacity_tokens: 200_000,
            park_prefixes: park,
            ..EngineConfig::default()
        };
        Engine::new(
            cfg,
            SimBackend::new(latency.clone()),
            VirtualClock::default(),
            Box::new(FcfsScheduler::new()),
            latency,
        )
    }

    fn two_turn_trace() -> Vec<RequestSpec> {
        vec![
            sspec(0, 0.0, 9, 0, 2, 0, 400, 100), // turn 0: ctx 400 → 500 parked
            sspec(1, 60.0, 9, 1, 2, 500, 300, 50), // turn 1 shares those 500
        ]
    }

    #[test]
    fn parked_prefix_shortens_returning_turn_ttft() {
        let run = |park: bool| {
            let mut e = session_engine(park);
            e.load_trace(two_turn_trace());
            let m = e.run_to_completion().unwrap();
            assert_eq!(m.requests.len(), 2);
            let t1 = m.requests.iter().find(|r| (r.arrival - 60.0).abs() < 1e-9).unwrap();
            (m.prefix_hits, m.prefixes_parked, t1.prefix_hit_tokens, t1.ttft)
        };
        let (hits, parked, hit_tokens, cold_ttft) = run(false);
        assert_eq!((hits, parked, hit_tokens), (0, 0, 0), "parking off must be inert");
        let (hits, parked, hit_tokens, warm_ttft) = run(true);
        assert_eq!(hits, 1);
        assert_eq!(parked, 1);
        assert_eq!(hit_tokens, 500, "the whole shared prefix is restored");
        assert!(
            warm_ttft < cold_ttft,
            "prefix hit must shorten TTFT: {warm_ttft} !< {cold_ttft}"
        );
    }

    #[test]
    fn parked_prefix_drains_with_the_session() {
        // The final turn claims the prefix and does not re-park
        // (expects_return is false), so a completed session leaves both
        // pools clean.
        let mut e = session_engine(true);
        e.load_trace(two_turn_trace());
        e.run_to_completion().unwrap();
        assert_eq!(e.kv().parked_count(), 0, "final turn must not park");
        assert_eq!(e.kv().num_allocations(), 0);
        assert_eq!(e.kv().device_free_tokens(), e.kv().device_capacity_tokens());
        assert_eq!(e.parked_prefix_tokens(9), 0);
    }

    #[test]
    fn parking_disabled_is_bit_identical_to_stripped_sessions() {
        // Flag-off parity: with park_prefixes = false, session metadata
        // must have zero effect — the run is bit-identical to the same
        // trace with the session annotations removed.
        let trace = crate::workload::SessionWorkload {
            num_sessions: 12,
            arrivals: crate::workload::ArrivalProcess::Poisson { rate: 0.8 },
            qoe_trace: crate::workload::QoeTrace::TextReading,
            min_turns: 2,
            max_turns: 4,
            think_time_mean: 3.0,
            seed: 21,
        }
        .generate();
        let mut with = session_engine(false);
        with.load_trace(trace.clone());
        let m1 = with.run_to_completion().unwrap();

        let stripped: Vec<RequestSpec> =
            trace.iter().cloned().map(|mut s| {
                s.session = None;
                s
            }).collect();
        let mut without = session_engine(false);
        without.load_trace(stripped);
        let m2 = without.run_to_completion().unwrap();

        assert_eq!(m1.requests.len(), m2.requests.len());
        for (a, b) in m1.requests.iter().zip(&m2.requests) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.token_times, b.token_times, "request {}", a.id);
            assert_eq!(a.final_qoe, b.final_qoe);
        }
        assert_eq!(m1.total_tokens, m2.total_tokens);
        assert_eq!(m1.total_preemptions, m2.total_preemptions);
        assert_eq!(m1.prefix_hits, 0);
        assert_eq!(m1.prefixes_parked, 0);
    }

    #[test]
    fn evicted_prefix_falls_back_to_cold_prefill() {
        // Host pool too small to hold the parked context → the park
        // falls back to a plain free and the returning turn pays full
        // prefill, with nothing lost or leaked.
        let latency = LatencyModel::for_deployment(&opt_66b(), &a100_4x());
        let cfg = EngineConfig {
            kv_capacity_tokens: 100_000,
            swap_capacity_tokens: 256, // 16 blocks of 16 — too small for 500 tokens
            park_prefixes: true,
            ..EngineConfig::default()
        };
        let mut e = Engine::new(
            cfg,
            SimBackend::new(latency.clone()),
            VirtualClock::default(),
            Box::new(FcfsScheduler::new()),
            latency,
        );
        e.load_trace(two_turn_trace());
        let m = e.run_to_completion().unwrap();
        assert_eq!(m.requests.len(), 2, "both turns served despite the failed park");
        assert_eq!(m.prefixes_parked, 0);
        assert_eq!(m.prefix_hits, 0);
        assert_eq!(e.kv().parked_count(), 0);
        assert_eq!(e.kv().num_allocations(), 0);
    }

    #[test]
    fn max_output_cap_enforced() {
        let mut e = sim_engine(Box::new(FcfsScheduler::new()), 100_000);
        let mut s = spec(0, 0.0, 10, 5000);
        s.output_tokens = 5000;
        e.load_trace(vec![s]);
        let m = e.run_to_completion().unwrap();
        assert_eq!(m.requests[0].output_tokens, EngineConfig::default().max_output_tokens);
    }
}
