//! Server-side estimate of each client's playback-buffer slack
//! (DESIGN.md §15; TokenFlow × Andes).
//!
//! The engine delivers tokens at *generation* time, but the gateway
//! pacer releases them to the client at the paced schedule and the
//! network adds transit on top — so the server-side [`DigestState`]
//! systematically *overestimates* what the client holds. A runner that
//! raced ahead looks deep-buffered ("coasting", QoE gain ≈ 0) to the
//! scheduler while the real client sits at `lead_tokens` of slack and
//! will stall the moment the runner is preempted.
//!
//! [`SlackEstimator`] closes that gap: per request it replays the
//! pacer's release rule online (burst `lead_tokens`, then one token per
//! `1/(tds·rate_factor)` seconds), adds the expected network transit
//! (mix-weighted mean one-way latency when `delivery` is on, 0 when it
//! is off — the client then digests at the QoE-spec rate from release
//! time, the documented fallback), and feeds the resulting *arrival*
//! times into a client-side [`DigestState`]. The scheduler queries the
//! estimate through [`crate::coordinator::sched::SchedView::slack`].
//!
//! Estimated occupancy is structurally bounded: `0 ≤ buffered ≤
//! delivered ≤ released` (only released tokens are ever delivered into
//! the digest, and digestion never exceeds delivery). The property
//! tests in `rust/tests/slack.rs` pin both bounds and agreement with
//! the ground-truth client buffer on seeded traces.

use std::collections::{BTreeMap, VecDeque};

use super::request::RequestId;
use crate::qoe::metric::DigestState;
use crate::qoe::spec::QoeSpec;

/// Configuration of the slack estimator — a mirror of the gateway's
/// pacing parameters plus the expected network transit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SlackConfig {
    /// Model the gateway pacer's release schedule. When false (pacing
    /// disabled at the gateway), tokens are assumed released at
    /// generation time.
    pub paced: bool,
    /// Pacer release rate as a multiple of the request's expected TDS
    /// (mirrors `gateway::pacing::PacingConfig::rate_factor`).
    pub rate_factor: f64,
    /// Tokens released immediately at the start of the stream (mirrors
    /// `gateway::pacing::PacingConfig::lead_tokens`).
    pub lead_tokens: usize,
    /// Expected one-way transit (s) between a pacer release and the
    /// client holding the token: the delivery layer's mix-weighted mean
    /// base latency when the network model is on, 0.0 when it is off
    /// (the QoE-spec digestion-rate fallback).
    pub transit: f64,
}

impl Default for SlackConfig {
    fn default() -> Self {
        SlackConfig { paced: true, rate_factor: 1.25, lead_tokens: 4, transit: 0.0 }
    }
}

/// Per-request pacer replay + estimated client digest.
#[derive(Debug, Clone)]
struct ReqSlack {
    /// Digestion speed (the QoE spec's expected TDS).
    tds: f64,
    /// Pacer release interval `1/(tds·rate_factor)` seconds.
    interval: f64,
    /// Tokens released by the (modeled) pacer so far.
    released: usize,
    /// Request-relative time of the last modeled release.
    last_release: f64,
    /// Estimated client-side digestion state, fed by arrivals that are
    /// already in the observable past.
    digest: DigestState,
    /// Estimated arrival times not yet folded into `digest` (the pacer
    /// schedules releases into the future once the lead is spent).
    /// Non-decreasing by construction.
    pending: VecDeque<f64>,
}

/// Tracks, per in-flight request, how many tokens the client plausibly
/// holds undigested. See the module docs for the model.
#[derive(Debug, Clone)]
pub struct SlackEstimator {
    cfg: SlackConfig,
    requests: BTreeMap<RequestId, ReqSlack>,
}

impl SlackEstimator {
    pub fn new(cfg: SlackConfig) -> Self {
        SlackEstimator { cfg, requests: BTreeMap::new() }
    }

    pub fn config(&self) -> &SlackConfig {
        &self.cfg
    }

    /// Record a token generated for `id` at request-relative time
    /// `gen_rel`. Models the pacer release + transit and queues the
    /// estimated client arrival.
    pub fn on_token(&mut self, id: RequestId, spec: &QoeSpec, gen_rel: f64) {
        let cfg = self.cfg;
        let st = self.requests.entry(id).or_insert_with(|| ReqSlack {
            tds: spec.tds,
            interval: 1.0 / (spec.tds * cfg.rate_factor).max(1e-9),
            released: 0,
            last_release: 0.0,
            digest: DigestState::new(spec),
            pending: VecDeque::new(),
        });
        // The pacer's release rule (gateway::pacing::pace_times):
        // burst the lead, then hold each token to the paced interval.
        let release = if !cfg.paced {
            gen_rel.max(st.last_release)
        } else if st.released < cfg.lead_tokens {
            gen_rel.max(st.last_release)
        } else {
            gen_rel.max(st.last_release + st.interval)
        };
        st.last_release = release;
        st.released += 1;
        st.pending.push_back(release + cfg.transit);
        // Fold arrivals already in the observable past into the digest
        // permanently — every future query is at a time ≥ `gen_rel`.
        while let Some(&a) = st.pending.front() {
            if a <= gen_rel {
                st.digest.deliver(a);
                st.pending.pop_front();
            } else {
                break;
            }
        }
    }

    /// Drop per-request state once the request finishes.
    pub fn on_finish(&mut self, id: RequestId) {
        self.requests.remove(&id);
    }

    /// Tokens released by the modeled pacer so far (test observability).
    pub fn released(&self, id: RequestId) -> Option<usize> {
        self.requests.get(&id).map(|s| s.released)
    }

    /// Estimated client-side digestion state at request-relative time
    /// `rel_now`, advanced to `rel_now`. `None` if no token has been
    /// generated for `id` yet.
    pub fn estimate(&self, id: RequestId, rel_now: f64) -> Option<DigestState> {
        let st = self.requests.get(&id)?;
        let mut d = st.digest;
        for &a in st.pending.iter() {
            if a <= rel_now {
                d.deliver(a);
            } else {
                break;
            }
        }
        d.advance_to(rel_now);
        Some(d)
    }

    /// Estimated client-buffer occupancy (tokens delivered to the
    /// client but not yet digested) at request-relative `rel_now`.
    pub fn occupancy(&self, id: RequestId, rel_now: f64) -> Option<f64> {
        self.estimate(id, rel_now).map(|d| d.buffered())
    }

    /// Slack window in *seconds*: how long the client can keep digesting
    /// from its buffer alone. This is what preemption stalls are charged
    /// against — a runner is only cheap to pause when its window covers
    /// the swap-out + swap-in stall.
    pub fn window(&self, id: RequestId, rel_now: f64) -> Option<f64> {
        let st = self.requests.get(&id)?;
        self.occupancy(id, rel_now).map(|occ| occ / st.tds.max(1e-9))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> QoeSpec {
        QoeSpec::new(1.0, 2.0) // tds = 2 tok/s
    }

    #[test]
    fn no_state_before_first_token() {
        let est = SlackEstimator::new(SlackConfig::default());
        assert!(est.estimate(0, 1.0).is_none());
        assert!(est.window(0, 1.0).is_none());
    }

    #[test]
    fn burst_generation_is_paced_not_instant() {
        // 20 tokens generated in a burst at t=0.1; pacer releases 4
        // immediately, then one per 1/(2*1.25) = 0.4s.
        let sp = spec();
        let mut est = SlackEstimator::new(SlackConfig::default());
        for _ in 0..20 {
            est.on_token(0, &sp, 0.1);
        }
        assert_eq!(est.released(0), Some(20));
        // Right after the burst the client plausibly holds only the lead.
        let occ = est.occupancy(0, 0.1).unwrap();
        assert!(occ <= 4.0 + 1e-9, "occupancy {occ} must not exceed the lead");
        // Much later everything has arrived and been digested.
        let occ_late = est.occupancy(0, 100.0).unwrap();
        assert!(occ_late < 1e-9, "late occupancy {occ_late} should be ~0");
    }

    #[test]
    fn occupancy_bounded_by_released_and_nonnegative() {
        let sp = spec();
        let mut est = SlackEstimator::new(SlackConfig { transit: 0.015, ..Default::default() });
        let gen_times = [0.05, 0.1, 0.1, 0.4, 0.9, 0.9, 0.9, 2.0];
        for (i, &t) in gen_times.iter().enumerate() {
            est.on_token(7, &sp, t);
            let released = est.released(7).unwrap();
            assert_eq!(released, i + 1);
            for probe in [t, t + 0.3, t + 5.0] {
                let occ = est.occupancy(7, probe).unwrap();
                assert!(occ >= -1e-12, "occupancy {occ} negative at {probe}");
                assert!(
                    occ <= released as f64 + 1e-9,
                    "occupancy {occ} exceeds released {released}"
                );
            }
        }
    }

    #[test]
    fn unpaced_config_delivers_at_generation_plus_transit() {
        let sp = spec();
        let mut est =
            SlackEstimator::new(SlackConfig { paced: false, ..Default::default() });
        for i in 0..6 {
            est.on_token(1, &sp, 0.2 * i as f64);
        }
        // At t=1.0 (last gen time), 6 tokens arrived; digestion at tds=2
        // for 1s leaves ~4 buffered (first token arrives at 0.0 but
        // digestion only starts once delivered).
        let occ = est.occupancy(1, 1.0).unwrap();
        assert!(occ > 3.0 && occ <= 6.0, "occ = {occ}");
    }

    #[test]
    fn window_scales_occupancy_by_tds() {
        let sp = spec();
        let mut est = SlackEstimator::new(SlackConfig::default());
        for _ in 0..4 {
            est.on_token(3, &sp, 0.0); // lead burst: all 4 arrive at 0.
        }
        let occ = est.occupancy(3, 0.0).unwrap();
        let win = est.window(3, 0.0).unwrap();
        assert!((win - occ / sp.tds).abs() < 1e-12);
    }

    #[test]
    fn on_finish_drops_state() {
        let sp = spec();
        let mut est = SlackEstimator::new(SlackConfig::default());
        est.on_token(0, &sp, 0.0);
        assert!(est.estimate(0, 0.0).is_some());
        est.on_finish(0);
        assert!(est.estimate(0, 0.0).is_none());
    }
}
