//! The event calendar: one monotone timeline every simulated subsystem
//! schedules against (DESIGN.md §14).
//!
//! Before this module, each subsystem hand-rolled its own "next event"
//! special case — the gateway scanned its defer queue for the earliest
//! deadline, the autoscaler exposed `next_event()`, federation kept a
//! `last_sync + interval` counter, the engine peeked the pending-arrival
//! vector, and the delivery layer drained an ack `VecDeque`. The
//! calendar replaces those scans with a single binary-heap timeline:
//! subsystems **register** wakeups, hold a [`WakeupToken`] to cancel
//! them, and either **pop** fired events in order (consumers like the
//! engine's arrival stream) or **query** the earliest pending instant
//! (index users like the gateway's sweep loop).
//!
//! The ordering rule is the determinism contract: wakeups fire by
//! `(time, seq)` where `time` compares via `f64::total_cmp` and `seq`
//! is the registration counter. Two wakeups at the same instant always
//! fire in registration order — heap layout, event kind, and payload
//! never influence the schedule, so a calendar-driven run is
//! reproducible bit for bit.
//!
//! Cancellation is lazy: `cancel` marks the seq and the heap entry is
//! dropped when it surfaces, so cancel is O(log n) and never reorders
//! the heap. `len`/`is_empty` count only live wakeups.

use std::cmp::Ordering;
use std::collections::{BTreeSet, BinaryHeap};

/// What a wakeup means to the subsystem that registered it. The kind
/// never participates in ordering — two wakeups at the same time fire
/// in registration (`seq`) order regardless of kind — it only lets an
/// index user ask "when is the next X?" via
/// [`EventCalendar::next_time_of`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// A workload request reaches the front door.
    Arrival,
    /// A session turn returns after its think-time gap.
    SessionReturn,
    /// A deferred request's admission deadline expires.
    DeferDeadline,
    /// The predictive autoscaler's next evaluation instant.
    AutoscaleTick,
    /// A federation snapshot exchange comes due.
    FederationSync,
    /// A delivery-layer ack becomes observable to the pacer.
    DeliveryAck,
}

/// Handle for cancelling a registered wakeup. Tokens stay inert after
/// their wakeup fires, after cancellation, and across [`EventCalendar::
/// clear`] (seqs are never reused), so holding a stale token is safe.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WakeupToken(u64);

/// One registered wakeup, as returned by [`EventCalendar::pop`] /
/// [`EventCalendar::peek`].
#[derive(Debug, Clone, Copy)]
pub struct Wakeup {
    /// Simulation instant the wakeup fires at.
    pub time: f64,
    /// Registration sequence number — the deterministic tie-break.
    pub seq: u64,
    /// What the wakeup means to its registrant.
    pub kind: EventKind,
    /// Registrant-defined correlation value (request id, node index,
    /// ack index — whatever the subsystem needs to route the event).
    pub payload: u64,
}

/// Heap entry with the `(time, seq)` ordering reversed so the std
/// max-heap yields the earliest wakeup first.
struct Entry(Wakeup);

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Entry {}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .0
            .time
            .total_cmp(&self.0.time)
            .then(other.0.seq.cmp(&self.0.seq))
    }
}

/// Binary-heap event timeline with deterministic `(time, seq)` ordering
/// and token-based lazy cancellation. See the module docs for the
/// ordering contract.
///
/// ```
/// use andes::coordinator::calendar::{EventCalendar, EventKind};
/// let mut cal = EventCalendar::new();
/// let late = cal.register(2.0, EventKind::DeferDeadline, 7);
/// cal.register(1.0, EventKind::Arrival, 0);
/// cal.register(1.0, EventKind::Arrival, 1); // same instant: fires second
/// assert_eq!(cal.next_time(), Some(1.0));
/// assert!(cal.cancel(late));
/// let first = cal.pop().unwrap();
/// let second = cal.pop().unwrap();
/// assert_eq!((first.payload, second.payload), (0, 1));
/// assert!(cal.pop().is_none(), "cancelled wakeups never fire");
/// ```
#[derive(Default)]
pub struct EventCalendar {
    heap: BinaryHeap<Entry>,
    /// Seqs registered but not yet fired or cancelled.
    live: BTreeSet<u64>,
    /// Cancelled seqs whose heap entries have not surfaced yet.
    cancelled: BTreeSet<u64>,
    next_seq: u64,
    fired: u64,
    last_fired: Option<f64>,
}

impl EventCalendar {
    pub fn new() -> Self {
        EventCalendar::default()
    }

    /// Register a wakeup at `time`. Returns the cancellation token.
    pub fn register(&mut self, time: f64, kind: EventKind, payload: u64) -> WakeupToken {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Entry(Wakeup { time, seq, kind, payload }));
        self.live.insert(seq);
        WakeupToken(seq)
    }

    /// Cancel a pending wakeup. Returns whether the token was live
    /// (false for already-fired, already-cancelled, or pre-`clear`
    /// tokens — all inert).
    pub fn cancel(&mut self, token: WakeupToken) -> bool {
        if self.live.remove(&token.0) {
            self.cancelled.insert(token.0);
            true
        } else {
            false
        }
    }

    /// Drop cancelled entries off the top of the heap.
    fn purge(&mut self) {
        while let Some(top) = self.heap.peek() {
            if self.cancelled.remove(&top.0.seq) {
                self.heap.pop();
            } else {
                break;
            }
        }
    }

    /// The earliest live wakeup, without firing it. O(log n) amortized.
    pub fn peek(&mut self) -> Option<Wakeup> {
        self.purge();
        self.heap.peek().map(|e| e.0)
    }

    /// The earliest live fire time. O(log n) amortized; for the
    /// borrow-friendly `&self` variant restricted to one kind see
    /// [`Self::next_time_of`].
    pub fn next_time(&mut self) -> Option<f64> {
        self.peek().map(|w| w.time)
    }

    /// The earliest live fire time among wakeups of `kind`. O(n) scan
    /// over the heap — fine for the small index-style calendars (defer
    /// queues, sync timers) this serves, and deterministic regardless
    /// of heap layout because an unordered min is order-independent.
    pub fn next_time_of(&self, kind: EventKind) -> Option<f64> {
        let mut best: Option<(f64, u64)> = None;
        for e in self.heap.iter() {
            let w = &e.0;
            if w.kind != kind || !self.live.contains(&w.seq) {
                continue;
            }
            let better = match best {
                None => true,
                Some((t, s)) => match w.time.total_cmp(&t) {
                    Ordering::Less => true,
                    Ordering::Equal => w.seq < s,
                    Ordering::Greater => false,
                },
            };
            if better {
                best = Some((w.time, w.seq));
            }
        }
        best.map(|(t, _)| t)
    }

    /// Fire the earliest live wakeup. Fire times are monotone
    /// non-decreasing over the calendar's lifetime (debug-asserted);
    /// registering a wakeup earlier than the last fired instant is a
    /// scheduling bug in the registrant.
    pub fn pop(&mut self) -> Option<Wakeup> {
        self.purge();
        let w = self.heap.pop()?.0;
        self.live.remove(&w.seq);
        debug_assert!(
            self.last_fired.is_none_or(|last| !(w.time < last)),
            "calendar fired backwards: {} after {:?}",
            w.time,
            self.last_fired
        );
        self.last_fired = Some(w.time);
        self.fired += 1;
        Some(w)
    }

    /// Number of live (pending) wakeups.
    pub fn len(&self) -> usize {
        self.live.len()
    }

    pub fn is_empty(&self) -> bool {
        self.live.is_empty()
    }

    /// Total wakeups fired over the calendar's lifetime.
    pub fn fired(&self) -> u64 {
        self.fired
    }

    /// The instant of the most recent fire, if any.
    pub fn last_fired(&self) -> Option<f64> {
        self.last_fired
    }

    /// Drop every pending wakeup and re-anchor the monotonicity check
    /// (a fresh schedule may start earlier than the old one ended).
    /// Seqs keep counting up so tokens issued before the clear stay
    /// inert rather than aliasing new wakeups.
    pub fn clear(&mut self) {
        self.heap.clear();
        self.live.clear();
        self.cancelled.clear();
        self.last_fired = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fires_in_time_then_registration_order() {
        let mut cal = EventCalendar::new();
        cal.register(3.0, EventKind::Arrival, 30);
        cal.register(1.0, EventKind::Arrival, 10);
        cal.register(2.0, EventKind::Arrival, 20);
        cal.register(1.0, EventKind::SessionReturn, 11); // tie: after 10
        let order: Vec<u64> = std::iter::from_fn(|| cal.pop()).map(|w| w.payload).collect();
        assert_eq!(order, vec![10, 11, 20, 30]);
        assert_eq!(cal.fired(), 4);
        assert!(cal.is_empty());
    }

    #[test]
    fn cancellation_is_lazy_and_exact() {
        let mut cal = EventCalendar::new();
        let a = cal.register(1.0, EventKind::DeferDeadline, 1);
        let b = cal.register(2.0, EventKind::DeferDeadline, 2);
        assert_eq!(cal.len(), 2);
        assert!(cal.cancel(a));
        assert!(!cal.cancel(a), "double-cancel is inert");
        assert_eq!(cal.len(), 1);
        assert_eq!(cal.next_time(), Some(2.0), "cancelled top is skipped");
        let w = cal.pop().unwrap();
        assert_eq!(w.payload, 2);
        assert!(!cal.cancel(b), "fired tokens are inert");
        assert!(cal.pop().is_none());
    }

    #[test]
    fn kind_filtered_queries_ignore_other_kinds_and_cancelled() {
        let mut cal = EventCalendar::new();
        cal.register(5.0, EventKind::AutoscaleTick, 0);
        let d = cal.register(3.0, EventKind::DeferDeadline, 0);
        cal.register(4.0, EventKind::DeferDeadline, 1);
        assert_eq!(cal.next_time_of(EventKind::DeferDeadline), Some(3.0));
        assert_eq!(cal.next_time_of(EventKind::AutoscaleTick), Some(5.0));
        assert_eq!(cal.next_time_of(EventKind::FederationSync), None);
        cal.cancel(d);
        assert_eq!(cal.next_time_of(EventKind::DeferDeadline), Some(4.0));
    }

    #[test]
    fn clear_re_anchors_and_keeps_old_tokens_inert() {
        let mut cal = EventCalendar::new();
        let stale = cal.register(10.0, EventKind::Arrival, 0);
        cal.pop().unwrap();
        cal.clear();
        // A fresh schedule may start before the old one ended.
        cal.register(1.0, EventKind::Arrival, 7);
        assert!(!cal.cancel(stale), "pre-clear tokens must not alias new wakeups");
        assert_eq!(cal.pop().unwrap().payload, 7);
    }

    #[test]
    fn peek_matches_pop_without_consuming() {
        let mut cal = EventCalendar::new();
        cal.register(2.5, EventKind::DeliveryAck, 9);
        let p = cal.peek().unwrap();
        assert_eq!((p.time, p.payload), (2.5, 9));
        assert_eq!(cal.len(), 1, "peek must not consume");
        let w = cal.pop().unwrap();
        assert_eq!(w.seq, p.seq);
    }
}
