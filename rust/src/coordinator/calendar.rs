//! The event calendar: one monotone timeline every simulated subsystem
//! schedules against (DESIGN.md §14).
//!
//! Before this module, each subsystem hand-rolled its own "next event"
//! special case — the gateway scanned its defer queue for the earliest
//! deadline, the autoscaler exposed `next_event()`, federation kept a
//! `last_sync + interval` counter, the engine peeked the pending-arrival
//! vector, and the delivery layer drained an ack `VecDeque`. The
//! calendar replaces those scans with a single binary-heap timeline:
//! subsystems **register** wakeups, hold a [`WakeupToken`] to cancel
//! them, and either **pop** fired events in order (consumers like the
//! engine's arrival stream) or **query** the earliest pending instant
//! (index users like the gateway's sweep loop).
//!
//! The ordering rule is the determinism contract: wakeups fire by
//! `(time, seq)` where `time` compares via `f64::total_cmp` and `seq`
//! is the registration counter. Two wakeups at the same instant always
//! fire in registration order — heap layout, event kind, and payload
//! never influence the schedule, so a calendar-driven run is
//! reproducible bit for bit.
//!
//! Cancellation is lazy: `cancel` marks the seq and the heap entry is
//! dropped when it surfaces, so cancel is O(log n) and never reorders
//! the heap. `len`/`is_empty` count only live wakeups. A per-kind index
//! (`by_kind`) is maintained eagerly on register/cancel/pop, so
//! [`EventCalendar::next_time_of`] answers in O(log n) instead of
//! scanning the heap.

use std::cmp::Ordering;
use std::collections::{BTreeMap, BTreeSet, BinaryHeap};

/// What a wakeup means to the subsystem that registered it. The kind
/// never participates in ordering — two wakeups at the same time fire
/// in registration (`seq`) order regardless of kind — it only lets an
/// index user ask "when is the next X?" via
/// [`EventCalendar::next_time_of`]. (`Ord` exists solely to key the
/// per-kind index; it has no scheduling meaning.)
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum EventKind {
    /// A workload request reaches the front door.
    Arrival,
    /// A session turn returns after its think-time gap.
    SessionReturn,
    /// A deferred request's admission deadline expires.
    DeferDeadline,
    /// The predictive autoscaler's next evaluation instant.
    AutoscaleTick,
    /// A federation snapshot exchange comes due.
    FederationSync,
    /// A delivery-layer ack becomes observable to the pacer.
    DeliveryAck,
}

/// Handle for cancelling a registered wakeup. Tokens stay inert after
/// their wakeup fires, after cancellation, and across [`EventCalendar::
/// clear`] (seqs are never reused), so holding a stale token is safe.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WakeupToken(u64);

/// One registered wakeup, as returned by [`EventCalendar::pop`] /
/// [`EventCalendar::peek`].
#[derive(Debug, Clone, Copy)]
pub struct Wakeup {
    /// Simulation instant the wakeup fires at.
    pub time: f64,
    /// Registration sequence number — the deterministic tie-break.
    pub seq: u64,
    /// What the wakeup means to its registrant.
    pub kind: EventKind,
    /// Registrant-defined correlation value (request id, node index,
    /// ack index — whatever the subsystem needs to route the event).
    pub payload: u64,
}

/// Heap entry with the `(time, seq)` ordering reversed so the std
/// max-heap yields the earliest wakeup first.
struct Entry(Wakeup);

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Entry {}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .0
            .time
            .total_cmp(&self.0.time)
            .then(other.0.seq.cmp(&self.0.seq))
    }
}

/// Binary-heap event timeline with deterministic `(time, seq)` ordering
/// and token-based lazy cancellation. See the module docs for the
/// ordering contract.
///
/// ```
/// use andes::coordinator::calendar::{EventCalendar, EventKind};
/// let mut cal = EventCalendar::new();
/// let late = cal.register(2.0, EventKind::DeferDeadline, 7);
/// cal.register(1.0, EventKind::Arrival, 0);
/// cal.register(1.0, EventKind::Arrival, 1); // same instant: fires second
/// assert_eq!(cal.next_time(), Some(1.0));
/// assert!(cal.cancel(late));
/// let first = cal.pop().unwrap();
/// let second = cal.pop().unwrap();
/// assert_eq!((first.payload, second.payload), (0, 1));
/// assert!(cal.pop().is_none(), "cancelled wakeups never fire");
/// ```
#[derive(Default)]
pub struct EventCalendar {
    heap: BinaryHeap<Entry>,
    /// Live wakeups: seq → (order-preserving time key, kind). Updated
    /// eagerly on register/cancel/pop so it always mirrors exactly the
    /// pending set (unlike the lazily-purged heap).
    live: BTreeMap<u64, (u64, EventKind)>,
    /// Per-kind index of live wakeups as (time key, seq), so the
    /// earliest pending instant of one kind is the set's first element.
    by_kind: BTreeMap<EventKind, BTreeSet<(u64, u64)>>,
    /// Cancelled seqs whose heap entries have not surfaced yet.
    cancelled: BTreeSet<u64>,
    next_seq: u64,
    fired: u64,
    last_fired: Option<f64>,
}

/// Map an `f64` to a `u64` whose unsigned order equals `total_cmp`
/// order: flip all bits of negatives, flip only the sign bit of
/// non-negatives. Bijective, so [`key_time`] recovers the exact bits.
fn time_key(t: f64) -> u64 {
    let b = t.to_bits();
    if b >> 63 == 1 {
        !b
    } else {
        b | (1 << 63)
    }
}

/// Inverse of [`time_key`].
fn key_time(k: u64) -> f64 {
    if k >> 63 == 1 {
        f64::from_bits(k & !(1 << 63))
    } else {
        f64::from_bits(!k)
    }
}

impl EventCalendar {
    pub fn new() -> Self {
        EventCalendar::default()
    }

    /// Register a wakeup at `time`. Returns the cancellation token.
    pub fn register(&mut self, time: f64, kind: EventKind, payload: u64) -> WakeupToken {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Entry(Wakeup { time, seq, kind, payload }));
        let key = time_key(time);
        self.live.insert(seq, (key, kind));
        self.by_kind.entry(kind).or_default().insert((key, seq));
        WakeupToken(seq)
    }

    /// Cancel a pending wakeup. Returns whether the token was live
    /// (false for already-fired, already-cancelled, or pre-`clear`
    /// tokens — all inert). The per-kind index drops the entry
    /// immediately; the heap entry is dropped lazily when it surfaces.
    pub fn cancel(&mut self, token: WakeupToken) -> bool {
        if let Some((key, kind)) = self.live.remove(&token.0) {
            self.drop_from_index(key, kind, token.0);
            self.cancelled.insert(token.0);
            true
        } else {
            false
        }
    }

    /// Remove one wakeup from the per-kind index, pruning empty sets so
    /// `by_kind` never accumulates dead kinds across a long run.
    fn drop_from_index(&mut self, key: u64, kind: EventKind, seq: u64) {
        if let Some(set) = self.by_kind.get_mut(&kind) {
            set.remove(&(key, seq));
            if set.is_empty() {
                self.by_kind.remove(&kind);
            }
        }
    }

    /// Drop cancelled entries off the top of the heap.
    fn purge(&mut self) {
        while let Some(top) = self.heap.peek() {
            if self.cancelled.remove(&top.0.seq) {
                self.heap.pop();
            } else {
                break;
            }
        }
    }

    /// The earliest live wakeup, without firing it. O(log n) amortized.
    pub fn peek(&mut self) -> Option<Wakeup> {
        self.purge();
        self.heap.peek().map(|e| e.0)
    }

    /// The earliest live fire time. O(log n) amortized; for the
    /// borrow-friendly `&self` variant restricted to one kind see
    /// [`Self::next_time_of`].
    pub fn next_time(&mut self) -> Option<f64> {
        self.peek().map(|w| w.time)
    }

    /// The earliest live fire time among wakeups of `kind`. O(log n):
    /// reads the first element of the eagerly-maintained per-kind index
    /// (a BTreeSet of `(time key, seq)`, where the key preserves
    /// `total_cmp` order). [`Self::next_time_of_scan`] is the brute
    /// force this is property-tested against.
    pub fn next_time_of(&self, kind: EventKind) -> Option<f64> {
        self.by_kind
            .get(&kind)
            .and_then(|set| set.first())
            .map(|&(key, _)| key_time(key))
    }

    /// Reference implementation of [`Self::next_time_of`]: an O(n) scan
    /// over the heap. Deterministic regardless of heap layout because an
    /// unordered min is order-independent. Kept as the oracle for the
    /// index-equivalence property test (and for debugging the index).
    pub fn next_time_of_scan(&self, kind: EventKind) -> Option<f64> {
        let mut best: Option<(f64, u64)> = None;
        for e in self.heap.iter() {
            let w = &e.0;
            if w.kind != kind || !self.live.contains_key(&w.seq) {
                continue;
            }
            let better = match best {
                None => true,
                Some((t, s)) => match w.time.total_cmp(&t) {
                    Ordering::Less => true,
                    Ordering::Equal => w.seq < s,
                    Ordering::Greater => false,
                },
            };
            if better {
                best = Some((w.time, w.seq));
            }
        }
        best.map(|(t, _)| t)
    }

    /// Fire the earliest live wakeup. Fire times are monotone
    /// non-decreasing over the calendar's lifetime (debug-asserted);
    /// registering a wakeup earlier than the last fired instant is a
    /// scheduling bug in the registrant.
    pub fn pop(&mut self) -> Option<Wakeup> {
        self.purge();
        let w = self.heap.pop()?.0;
        if let Some((key, kind)) = self.live.remove(&w.seq) {
            self.drop_from_index(key, kind, w.seq);
        }
        debug_assert!(
            self.last_fired.is_none_or(|last| !(w.time < last)),
            "calendar fired backwards: {} after {:?}",
            w.time,
            self.last_fired
        );
        self.last_fired = Some(w.time);
        self.fired += 1;
        Some(w)
    }

    /// Number of live (pending) wakeups.
    pub fn len(&self) -> usize {
        self.live.len()
    }

    pub fn is_empty(&self) -> bool {
        self.live.is_empty()
    }

    /// Total wakeups fired over the calendar's lifetime.
    pub fn fired(&self) -> u64 {
        self.fired
    }

    /// The instant of the most recent fire, if any.
    pub fn last_fired(&self) -> Option<f64> {
        self.last_fired
    }

    /// Drop every pending wakeup and re-anchor the monotonicity check
    /// (a fresh schedule may start earlier than the old one ended).
    /// Seqs keep counting up so tokens issued before the clear stay
    /// inert rather than aliasing new wakeups.
    pub fn clear(&mut self) {
        self.heap.clear();
        self.live.clear();
        self.by_kind.clear();
        self.cancelled.clear();
        self.last_fired = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fires_in_time_then_registration_order() {
        let mut cal = EventCalendar::new();
        cal.register(3.0, EventKind::Arrival, 30);
        cal.register(1.0, EventKind::Arrival, 10);
        cal.register(2.0, EventKind::Arrival, 20);
        cal.register(1.0, EventKind::SessionReturn, 11); // tie: after 10
        let order: Vec<u64> = std::iter::from_fn(|| cal.pop()).map(|w| w.payload).collect();
        assert_eq!(order, vec![10, 11, 20, 30]);
        assert_eq!(cal.fired(), 4);
        assert!(cal.is_empty());
    }

    #[test]
    fn cancellation_is_lazy_and_exact() {
        let mut cal = EventCalendar::new();
        let a = cal.register(1.0, EventKind::DeferDeadline, 1);
        let b = cal.register(2.0, EventKind::DeferDeadline, 2);
        assert_eq!(cal.len(), 2);
        assert!(cal.cancel(a));
        assert!(!cal.cancel(a), "double-cancel is inert");
        assert_eq!(cal.len(), 1);
        assert_eq!(cal.next_time(), Some(2.0), "cancelled top is skipped");
        let w = cal.pop().unwrap();
        assert_eq!(w.payload, 2);
        assert!(!cal.cancel(b), "fired tokens are inert");
        assert!(cal.pop().is_none());
    }

    #[test]
    fn kind_filtered_queries_ignore_other_kinds_and_cancelled() {
        let mut cal = EventCalendar::new();
        cal.register(5.0, EventKind::AutoscaleTick, 0);
        let d = cal.register(3.0, EventKind::DeferDeadline, 0);
        cal.register(4.0, EventKind::DeferDeadline, 1);
        assert_eq!(cal.next_time_of(EventKind::DeferDeadline), Some(3.0));
        assert_eq!(cal.next_time_of(EventKind::AutoscaleTick), Some(5.0));
        assert_eq!(cal.next_time_of(EventKind::FederationSync), None);
        cal.cancel(d);
        assert_eq!(cal.next_time_of(EventKind::DeferDeadline), Some(4.0));
    }

    #[test]
    fn clear_re_anchors_and_keeps_old_tokens_inert() {
        let mut cal = EventCalendar::new();
        let stale = cal.register(10.0, EventKind::Arrival, 0);
        cal.pop().unwrap();
        cal.clear();
        // A fresh schedule may start before the old one ended.
        cal.register(1.0, EventKind::Arrival, 7);
        assert!(!cal.cancel(stale), "pre-clear tokens must not alias new wakeups");
        assert_eq!(cal.pop().unwrap().payload, 7);
    }

    #[test]
    fn time_key_preserves_total_cmp_order_and_round_trips() {
        let times = [
            f64::NEG_INFINITY,
            -2.5,
            -0.0,
            0.0,
            1.0e-300,
            0.25,
            3.0,
            f64::INFINITY,
        ];
        for &a in &times {
            assert_eq!(key_time(time_key(a)).to_bits(), a.to_bits(), "round trip of {a}");
            for &b in &times {
                assert_eq!(
                    time_key(a).cmp(&time_key(b)),
                    a.total_cmp(&b),
                    "key order of ({a}, {b})"
                );
            }
        }
    }

    #[test]
    fn next_time_of_index_matches_brute_force_scan() {
        use crate::util::testing::check_prop;
        let kinds = [
            EventKind::Arrival,
            EventKind::SessionReturn,
            EventKind::DeferDeadline,
            EventKind::AutoscaleTick,
            EventKind::FederationSync,
            EventKind::DeliveryAck,
        ];
        // Random interleavings of register/cancel/pop (times are
        // quantized to force exact ties and never precede the last
        // fired instant, honoring the monotonicity contract); after
        // every op the per-kind index must agree bit-for-bit with the
        // brute-force heap scan for every kind.
        check_prop("next_time_of index == scan", 48, |rng| {
            let mut cal = EventCalendar::new();
            let mut tokens: Vec<WakeupToken> = Vec::new();
            let mut floor = -4.0f64;
            for _ in 0..60 {
                match rng.below(10) {
                    0..=4 => {
                        let t = floor + rng.below(12) as f64 * 0.25;
                        let kind = kinds[rng.below(6) as usize];
                        tokens.push(cal.register(t, kind, rng.below(100)));
                    }
                    5..=6 => {
                        if !tokens.is_empty() {
                            let i = rng.below(tokens.len() as u64) as usize;
                            cal.cancel(tokens.swap_remove(i));
                        }
                    }
                    _ => {
                        if let Some(w) = cal.pop() {
                            floor = w.time;
                        }
                    }
                }
                for &kind in &kinds {
                    let idx = cal.next_time_of(kind);
                    let scan = cal.next_time_of_scan(kind);
                    assert_eq!(
                        idx.map(f64::to_bits),
                        scan.map(f64::to_bits),
                        "kind {kind:?}: index {idx:?} vs scan {scan:?}"
                    );
                }
            }
        });
    }

    #[test]
    fn peek_matches_pop_without_consuming() {
        let mut cal = EventCalendar::new();
        cal.register(2.5, EventKind::DeliveryAck, 9);
        let p = cal.peek().unwrap();
        assert_eq!((p.time, p.payload), (2.5, 9));
        assert_eq!(cal.len(), 1, "peek must not consume");
        let w = cal.pop().unwrap();
        assert_eq!(w.seq, p.seq);
    }
}
