//! Block-based KV-cache manager (the vLLM PagedAttention accounting
//! substrate, paper §2.1/§4.1 Eq. 3 and the swap mechanism of §4.2).
//!
//! GPU memory holds `M` tokens of KV cache, quantized into fixed-size
//! blocks. Preempted requests either move their blocks to a bounded host
//! pool (swap) or drop them (recompute later). The manager only does
//! *accounting* — actual tensor movement lives in the execution backend —
//! but its invariants are load-bearing for the scheduler:
//!
//! 1. device blocks in use never exceed the device pool;
//! 2. host blocks in use never exceed the host pool;
//! 3. blocks never leak: freeing everything returns both pools to zero.

use std::collections::HashMap;

use super::request::RequestId;

/// Where a request's KV cache currently lives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvResidence {
    Device,
    Host,
}

#[derive(Debug, Clone)]
struct Allocation {
    blocks: usize,
    tokens: usize,
    residence: KvResidence,
}

#[derive(Debug, thiserror::Error, PartialEq, Eq)]
pub enum KvError {
    #[error("device pool exhausted: need {need} blocks, {free} free")]
    DeviceFull { need: usize, free: usize },
    #[error("host swap pool exhausted: need {need} blocks, {free} free")]
    HostFull { need: usize, free: usize },
    #[error("request {0} has no allocation")]
    NotAllocated(RequestId),
    #[error("request {0} already allocated")]
    AlreadyAllocated(RequestId),
    #[error("request {0} KV not resident on {1:?}")]
    WrongResidence(RequestId, KvResidence),
}

/// KV cache pool accounting.
#[derive(Debug, Clone)]
pub struct KvCacheManager {
    block_size: usize,
    device_blocks_total: usize,
    host_blocks_total: usize,
    device_blocks_used: usize,
    host_blocks_used: usize,
    allocs: HashMap<RequestId, Allocation>,
}

impl KvCacheManager {
    /// Create a manager with capacities given in *tokens* (rounded down
    /// to whole blocks).
    pub fn new(device_capacity_tokens: usize, host_capacity_tokens: usize, block_size: usize) -> Self {
        assert!(block_size > 0);
        KvCacheManager {
            block_size,
            device_blocks_total: device_capacity_tokens / block_size,
            host_blocks_total: host_capacity_tokens / block_size,
            device_blocks_used: 0,
            host_blocks_used: 0,
            allocs: HashMap::new(),
        }
    }

    fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// Device capacity in tokens (`M` in Eq. 3).
    pub fn device_capacity_tokens(&self) -> usize {
        self.device_blocks_total * self.block_size
    }

    pub fn device_free_blocks(&self) -> usize {
        self.device_blocks_total - self.device_blocks_used
    }

    pub fn device_free_tokens(&self) -> usize {
        self.device_free_blocks() * self.block_size
    }

    pub fn host_free_blocks(&self) -> usize {
        self.host_blocks_total - self.host_blocks_used
    }

    /// Fraction of the device pool in use ∈ [0, 1].
    pub fn device_utilization(&self) -> f64 {
        if self.device_blocks_total == 0 {
            return 1.0;
        }
        self.device_blocks_used as f64 / self.device_blocks_total as f64
    }

    /// Tokens currently resident on device for `id` (0 if none).
    pub fn device_tokens_of(&self, id: RequestId) -> usize {
        match self.allocs.get(&id) {
            Some(a) if a.residence == KvResidence::Device => a.tokens,
            _ => 0,
        }
    }

    pub fn residence_of(&self, id: RequestId) -> Option<KvResidence> {
        self.allocs.get(&id).map(|a| a.residence)
    }

    /// Whether a fresh allocation of `tokens` would fit on device.
    pub fn can_allocate(&self, tokens: usize) -> bool {
        self.blocks_for(tokens) <= self.device_free_blocks()
    }

    /// Allocate device blocks for a request entering the running batch
    /// (covers its whole current context: prompt + generated so far).
    pub fn allocate(&mut self, id: RequestId, tokens: usize) -> Result<(), KvError> {
        if self.allocs.contains_key(&id) {
            return Err(KvError::AlreadyAllocated(id));
        }
        let need = self.blocks_for(tokens);
        let free = self.device_free_blocks();
        if need > free {
            return Err(KvError::DeviceFull { need, free });
        }
        self.device_blocks_used += need;
        self.allocs.insert(id, Allocation { blocks: need, tokens, residence: KvResidence::Device });
        Ok(())
    }

    /// Grow a running request's context by `n` tokens (one per decode
    /// iteration); may claim a new block at block boundaries.
    pub fn extend(&mut self, id: RequestId, n: usize) -> Result<(), KvError> {
        let a = self.allocs.get_mut(&id).ok_or(KvError::NotAllocated(id))?;
        if a.residence != KvResidence::Device {
            return Err(KvError::WrongResidence(id, KvResidence::Device));
        }
        let new_tokens = a.tokens + n;
        let new_blocks = new_tokens.div_ceil(self.block_size);
        let extra = new_blocks.saturating_sub(a.blocks);
        if extra > self.device_blocks_total - self.device_blocks_used {
            return Err(KvError::DeviceFull {
                need: extra,
                free: self.device_blocks_total - self.device_blocks_used,
            });
        }
        self.device_blocks_used += extra;
        a.blocks = new_blocks;
        a.tokens = new_tokens;
        Ok(())
    }

    /// Swap a request's KV cache device → host. Fails (leaving state
    /// unchanged) if the host pool cannot hold it — callers then fall
    /// back to recomputation, as the paper specifies.
    pub fn swap_out(&mut self, id: RequestId) -> Result<usize, KvError> {
        let a = self.allocs.get_mut(&id).ok_or(KvError::NotAllocated(id))?;
        if a.residence != KvResidence::Device {
            return Err(KvError::WrongResidence(id, KvResidence::Device));
        }
        let need = a.blocks;
        let free = self.host_blocks_total - self.host_blocks_used;
        if need > free {
            return Err(KvError::HostFull { need, free });
        }
        a.residence = KvResidence::Host;
        self.device_blocks_used -= need;
        self.host_blocks_used += need;
        Ok(a.tokens)
    }

    /// Swap a request's KV cache host → device.
    pub fn swap_in(&mut self, id: RequestId) -> Result<usize, KvError> {
        let a = self.allocs.get_mut(&id).ok_or(KvError::NotAllocated(id))?;
        if a.residence != KvResidence::Host {
            return Err(KvError::WrongResidence(id, KvResidence::Host));
        }
        let need = a.blocks;
        let free = self.device_blocks_total - self.device_blocks_used;
        if need > free {
            return Err(KvError::DeviceFull { need, free });
        }
        a.residence = KvResidence::Device;
        self.host_blocks_used -= need;
        self.device_blocks_used += need;
        Ok(a.tokens)
    }

    /// Release a request's KV wherever it lives (finish or recompute-
    /// preemption drop). Returns the freed token count.
    pub fn free(&mut self, id: RequestId) -> Result<usize, KvError> {
        let a = self.allocs.remove(&id).ok_or(KvError::NotAllocated(id))?;
        match a.residence {
            KvResidence::Device => self.device_blocks_used -= a.blocks,
            KvResidence::Host => self.host_blocks_used -= a.blocks,
        }
        Ok(a.tokens)
    }

    /// Total tokens resident on device across all requests.
    pub fn device_tokens_used(&self) -> usize {
        self.allocs
            .values()
            .filter(|a| a.residence == KvResidence::Device)
            .map(|a| a.tokens)
            .sum()
    }

    /// Number of live allocations (diagnostics).
    pub fn num_allocations(&self) -> usize {
        self.allocs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr() -> KvCacheManager {
        // 10 device blocks of 16 tokens (160), 5 host blocks (80).
        KvCacheManager::new(160, 80, 16)
    }

    #[test]
    fn allocate_and_free_roundtrip() {
        let mut m = mgr();
        m.allocate(1, 40).unwrap(); // 3 blocks
        assert_eq!(m.device_free_blocks(), 7);
        assert_eq!(m.device_tokens_of(1), 40);
        assert_eq!(m.free(1).unwrap(), 40);
        assert_eq!(m.device_free_blocks(), 10);
        assert_eq!(m.num_allocations(), 0);
    }

    #[test]
    fn rejects_oversized_and_double_alloc() {
        let mut m = mgr();
        assert!(matches!(m.allocate(1, 161), Err(KvError::DeviceFull { .. })));
        m.allocate(1, 16).unwrap();
        assert_eq!(m.allocate(1, 16), Err(KvError::AlreadyAllocated(1)));
    }

    #[test]
    fn extend_claims_blocks_lazily() {
        let mut m = mgr();
        m.allocate(1, 16).unwrap(); // exactly 1 block
        assert_eq!(m.device_free_blocks(), 9);
        m.extend(1, 1).unwrap(); // 17 tokens → 2 blocks
        assert_eq!(m.device_free_blocks(), 8);
        for _ in 0..15 {
            m.extend(1, 1).unwrap(); // up to 32 tokens, still 2 blocks
        }
        assert_eq!(m.device_free_blocks(), 8);
        m.extend(1, 1).unwrap(); // 33 → 3 blocks
        assert_eq!(m.device_free_blocks(), 7);
    }

    #[test]
    fn extend_fails_when_full_but_state_intact() {
        let mut m = KvCacheManager::new(32, 0, 16);
        m.allocate(1, 32).unwrap();
        assert!(matches!(m.extend(1, 1), Err(KvError::DeviceFull { .. })));
        assert_eq!(m.device_tokens_of(1), 32);
    }

    #[test]
    fn swap_out_in_roundtrip() {
        let mut m = mgr();
        m.allocate(1, 48).unwrap(); // 3 blocks
        let moved = m.swap_out(1).unwrap();
        assert_eq!(moved, 48);
        assert_eq!(m.device_free_blocks(), 10);
        assert_eq!(m.host_free_blocks(), 2);
        assert_eq!(m.residence_of(1), Some(KvResidence::Host));
        assert_eq!(m.device_tokens_of(1), 0);
        let back = m.swap_in(1).unwrap();
        assert_eq!(back, 48);
        assert_eq!(m.residence_of(1), Some(KvResidence::Device));
        assert_eq!(m.host_free_blocks(), 5);
    }

    #[test]
    fn swap_out_fails_when_host_full() {
        let mut m = KvCacheManager::new(160, 32, 16);
        m.allocate(1, 48).unwrap();
        m.allocate(2, 32).unwrap();
        m.swap_out(2).unwrap(); // host now full
        let err = m.swap_out(1);
        assert!(matches!(err, Err(KvError::HostFull { .. })));
        // State unchanged: request 1 still on device.
        assert_eq!(m.residence_of(1), Some(KvResidence::Device));
        assert_eq!(m.device_tokens_of(1), 48);
    }

    #[test]
    fn cannot_extend_swapped_request() {
        let mut m = mgr();
        m.allocate(1, 16).unwrap();
        m.swap_out(1).unwrap();
        assert!(matches!(m.extend(1, 1), Err(KvError::WrongResidence(..))));
        // free() works from host residence.
        assert_eq!(m.free(1).unwrap(), 16);
        assert_eq!(m.host_free_blocks(), 5);
    }

    #[test]
    fn utilization_tracks() {
        let mut m = mgr();
        assert_eq!(m.device_utilization(), 0.0);
        m.allocate(1, 80).unwrap();
        assert!((m.device_utilization() - 0.5).abs() < 1e-12);
        assert_eq!(m.device_tokens_used(), 80);
    }

    #[test]
    fn capacity_rounds_down_to_blocks() {
        let m = KvCacheManager::new(100, 50, 16);
        assert_eq!(m.device_capacity_tokens(), 96);
    }
}
