//! Block-based KV-cache manager (the vLLM PagedAttention accounting
//! substrate, paper §2.1/§4.1 Eq. 3 and the swap mechanism of §4.2).
//!
//! GPU memory holds `M` tokens of KV cache, quantized into fixed-size
//! blocks. Preempted requests either move their blocks to a bounded host
//! pool (swap) or drop them (recompute later). The manager only does
//! *accounting* — actual tensor movement lives in the execution backend —
//! but its invariants are load-bearing for the scheduler:
//!
//! 1. device blocks in use never exceed the device pool;
//! 2. host blocks in use never exceed the host pool;
//! 3. blocks never leak: freeing everything returns both pools to zero.
//!
//! The host pool doubles as a **prefix park** for multi-turn sessions
//! (DESIGN.md §10): a finished turn may move its context blocks to the
//! host keyed by session id ([`KvCacheManager::park`]) instead of
//! freeing them; the session's next turn claims them back
//! ([`KvCacheManager::claim_parked`]) and skips the shared-prefix
//! portion of prefill. Parked prefixes are opportunistic cache, not
//! live state: under host pressure — a swap-out or a newer park needing
//! room — the least-recently-used parked prefix is evicted first, and
//! invariant 3 extends to them (freeing every allocation and dropping
//! every parked prefix returns both pools to zero).

use std::collections::BTreeMap;

use super::request::RequestId;

/// Where a request's KV cache currently lives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvResidence {
    Device,
    Host,
}

#[derive(Debug, Clone)]
struct Allocation {
    blocks: usize,
    tokens: usize,
    residence: KvResidence,
}

/// A session's parked prefix: host blocks retained after a turn
/// finished, waiting for the session's next turn.
#[derive(Debug, Clone)]
struct ParkedPrefix {
    blocks: usize,
    tokens: usize,
    /// LRU stamp (monotone counter; smaller = older).
    stamp: u64,
}

#[derive(Debug, thiserror::Error, PartialEq, Eq)]
pub enum KvError {
    #[error("device pool exhausted: need {need} blocks, {free} free")]
    DeviceFull { need: usize, free: usize },
    #[error("host swap pool exhausted: need {need} blocks, {free} free")]
    HostFull { need: usize, free: usize },
    #[error("request {0} has no allocation")]
    NotAllocated(RequestId),
    #[error("request {0} already allocated")]
    AlreadyAllocated(RequestId),
    #[error("request {0} KV not resident on {1:?}")]
    WrongResidence(RequestId, KvResidence),
}

/// KV cache pool accounting.
#[derive(Debug, Clone)]
pub struct KvCacheManager {
    block_size: usize,
    device_blocks_total: usize,
    host_blocks_total: usize,
    device_blocks_used: usize,
    /// Host blocks in use by swapped requests *and* parked prefixes.
    host_blocks_used: usize,
    // BTreeMap: both maps are iterated (usage sums, LRU scan) and the
    // LRU scan breaks stamp ties by iteration order — keep it keyed.
    allocs: BTreeMap<RequestId, Allocation>,
    /// Parked session prefixes, keyed by session id.
    parked: BTreeMap<u64, ParkedPrefix>,
    /// Monotone stamp source for parked-prefix LRU order.
    park_stamp: u64,
    /// Parked prefixes dropped to relieve host pressure (lifetime).
    park_evictions: u64,
}

impl KvCacheManager {
    /// Create a manager with capacities given in *tokens* (rounded down
    /// to whole blocks).
    pub fn new(device_capacity_tokens: usize, host_capacity_tokens: usize, block_size: usize) -> Self {
        assert!(block_size > 0);
        KvCacheManager {
            block_size,
            device_blocks_total: device_capacity_tokens / block_size,
            host_blocks_total: host_capacity_tokens / block_size,
            device_blocks_used: 0,
            host_blocks_used: 0,
            allocs: BTreeMap::new(),
            parked: BTreeMap::new(),
            park_stamp: 0,
            park_evictions: 0,
        }
    }

    fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// Device capacity in tokens (`M` in Eq. 3).
    pub fn device_capacity_tokens(&self) -> usize {
        self.device_blocks_total * self.block_size
    }

    pub fn device_free_blocks(&self) -> usize {
        self.device_blocks_total - self.device_blocks_used
    }

    pub fn device_free_tokens(&self) -> usize {
        self.device_free_blocks() * self.block_size
    }

    pub fn host_free_blocks(&self) -> usize {
        self.host_blocks_total - self.host_blocks_used
    }

    /// Fraction of the device pool in use ∈ [0, 1].
    pub fn device_utilization(&self) -> f64 {
        if self.device_blocks_total == 0 {
            return 1.0;
        }
        self.device_blocks_used as f64 / self.device_blocks_total as f64
    }

    /// Tokens currently resident on device for `id` (0 if none).
    pub fn device_tokens_of(&self, id: RequestId) -> usize {
        match self.allocs.get(&id) {
            Some(a) if a.residence == KvResidence::Device => a.tokens,
            _ => 0,
        }
    }

    pub fn residence_of(&self, id: RequestId) -> Option<KvResidence> {
        self.allocs.get(&id).map(|a| a.residence)
    }

    /// Whether a fresh allocation of `tokens` would fit on device.
    pub fn can_allocate(&self, tokens: usize) -> bool {
        self.blocks_for(tokens) <= self.device_free_blocks()
    }

    /// Allocate device blocks for a request entering the running batch
    /// (covers its whole current context: prompt + generated so far).
    pub fn allocate(&mut self, id: RequestId, tokens: usize) -> Result<(), KvError> {
        if self.allocs.contains_key(&id) {
            return Err(KvError::AlreadyAllocated(id));
        }
        let need = self.blocks_for(tokens);
        let free = self.device_free_blocks();
        if need > free {
            return Err(KvError::DeviceFull { need, free });
        }
        self.device_blocks_used += need;
        self.allocs.insert(id, Allocation { blocks: need, tokens, residence: KvResidence::Device });
        Ok(())
    }

    /// Grow a running request's context by `n` tokens (one per decode
    /// iteration); may claim a new block at block boundaries.
    pub fn extend(&mut self, id: RequestId, n: usize) -> Result<(), KvError> {
        let a = self.allocs.get_mut(&id).ok_or(KvError::NotAllocated(id))?;
        if a.residence != KvResidence::Device {
            return Err(KvError::WrongResidence(id, KvResidence::Device));
        }
        let new_tokens = a.tokens + n;
        let new_blocks = new_tokens.div_ceil(self.block_size);
        let extra = new_blocks.saturating_sub(a.blocks);
        if extra > self.device_blocks_total - self.device_blocks_used {
            return Err(KvError::DeviceFull {
                need: extra,
                free: self.device_blocks_total - self.device_blocks_used,
            });
        }
        self.device_blocks_used += extra;
        a.blocks = new_blocks;
        a.tokens = new_tokens;
        Ok(())
    }

    /// Swap a request's KV cache device → host. Live swap state outranks
    /// opportunistically parked prefixes: LRU parked entries are evicted
    /// to make room first. Fails (leaving allocations unchanged) if the
    /// host pool still cannot hold it — callers then fall back to
    /// recomputation, as the paper specifies.
    pub fn swap_out(&mut self, id: RequestId) -> Result<usize, KvError> {
        let need = match self.allocs.get(&id) {
            None => return Err(KvError::NotAllocated(id)),
            Some(a) if a.residence != KvResidence::Device => {
                return Err(KvError::WrongResidence(id, KvResidence::Device));
            }
            Some(a) => a.blocks,
        };
        // Feasibility before eviction: an infeasible swap must not
        // destroy the prefix cache on its way to failing anyway.
        if self.host_free_blocks() + self.parked_blocks() < need {
            return Err(KvError::HostFull { need, free: self.host_free_blocks() });
        }
        let fits = self.make_host_room(need);
        debug_assert!(fits, "feasibility was checked above");
        // lint:allow(D6, entry existence was verified at the top of this fn)
        let a = self.allocs.get_mut(&id).expect("checked above");
        a.residence = KvResidence::Host;
        self.device_blocks_used -= need;
        self.host_blocks_used += need;
        Ok(a.tokens)
    }

    /// Swap a request's KV cache host → device.
    pub fn swap_in(&mut self, id: RequestId) -> Result<usize, KvError> {
        let a = self.allocs.get_mut(&id).ok_or(KvError::NotAllocated(id))?;
        if a.residence != KvResidence::Host {
            return Err(KvError::WrongResidence(id, KvResidence::Host));
        }
        let need = a.blocks;
        let free = self.device_blocks_total - self.device_blocks_used;
        if need > free {
            return Err(KvError::DeviceFull { need, free });
        }
        a.residence = KvResidence::Device;
        self.host_blocks_used -= need;
        self.device_blocks_used += need;
        Ok(a.tokens)
    }

    /// Release a request's KV wherever it lives (finish or recompute-
    /// preemption drop). Returns the freed token count.
    pub fn free(&mut self, id: RequestId) -> Result<usize, KvError> {
        let a = self.allocs.remove(&id).ok_or(KvError::NotAllocated(id))?;
        match a.residence {
            KvResidence::Device => self.device_blocks_used -= a.blocks,
            KvResidence::Host => self.host_blocks_used -= a.blocks,
        }
        Ok(a.tokens)
    }

    /// Evict least-recently-parked prefixes until at least `need` host
    /// blocks are free or no parked prefix remains; reports whether
    /// `need` now fits. Eviction order is park time (a claim re-parks on
    /// the next finish, refreshing the stamp).
    fn make_host_room(&mut self, need: usize) -> bool {
        while self.host_blocks_total - self.host_blocks_used < need {
            let lru = self.parked.iter().min_by_key(|(_, p)| p.stamp).map(|(&k, _)| k);
            match lru {
                Some(k) => {
                    // lint:allow(D6, the key came out of the same map one line up)
                    let p = self.parked.remove(&k).expect("lru key present");
                    self.host_blocks_used -= p.blocks;
                    self.park_evictions += 1;
                }
                None => break,
            }
        }
        self.host_blocks_total - self.host_blocks_used >= need
    }

    /// Park a finished turn's device KV in the host pool under session
    /// `key` instead of freeing it, evicting LRU parked prefixes to make
    /// room. Any previous prefix parked under `key` is replaced (it
    /// described a stale, shorter context). On `HostFull` *nothing*
    /// changes — the request's allocation and any previously parked
    /// entry under `key` both survive — and the caller falls back to a
    /// plain [`Self::free`]. Returns the parked token count.
    pub fn park(&mut self, key: u64, id: RequestId) -> Result<usize, KvError> {
        let (blocks, tokens) = match self.allocs.get(&id) {
            None => return Err(KvError::NotAllocated(id)),
            Some(a) if a.residence != KvResidence::Device => {
                return Err(KvError::WrongResidence(id, KvResidence::Device));
            }
            Some(a) => (a.blocks, a.tokens),
        };
        // Feasibility first: every parked entry (including the one this
        // park replaces) is evictable, so the new prefix fits iff it
        // fits in free + parked. Checking before mutating keeps a
        // failed re-park from losing the old (still-usable) entry.
        if self.host_free_blocks() + self.parked_blocks() < blocks {
            return Err(KvError::HostFull { need: blocks, free: self.host_free_blocks() });
        }
        if let Some(old) = self.parked.remove(&key) {
            self.host_blocks_used -= old.blocks;
        }
        let fits = self.make_host_room(blocks);
        debug_assert!(fits, "feasibility was checked above");
        self.allocs.remove(&id);
        self.device_blocks_used -= blocks;
        self.host_blocks_used += blocks;
        self.park_stamp += 1;
        self.parked.insert(key, ParkedPrefix { blocks, tokens, stamp: self.park_stamp });
        Ok(tokens)
    }

    /// Tokens parked under session `key`, if any (routing/admission
    /// probe; does not touch LRU order).
    pub fn parked_tokens(&self, key: u64) -> Option<usize> {
        self.parked.get(&key).map(|p| p.tokens)
    }

    /// Claim (and release) the prefix parked under `key`: the session's
    /// returning turn takes ownership, the host blocks are freed, and
    /// the caller re-allocates the full context on device — charging a
    /// host→device transfer for the claimed tokens instead of prefill
    /// compute. Returns the claimed token count.
    pub fn claim_parked(&mut self, key: u64) -> Option<usize> {
        let p = self.parked.remove(&key)?;
        self.host_blocks_used -= p.blocks;
        Some(p.tokens)
    }

    /// Drop the prefix parked under `key` (session ended or expired)
    /// without claiming it. Returns whether an entry existed.
    pub fn drop_parked(&mut self, key: u64) -> bool {
        match self.parked.remove(&key) {
            Some(p) => {
                self.host_blocks_used -= p.blocks;
                true
            }
            None => false,
        }
    }

    /// Number of parked session prefixes.
    pub fn parked_count(&self) -> usize {
        self.parked.len()
    }

    /// Host blocks held by parked prefixes.
    pub fn parked_blocks(&self) -> usize {
        self.parked.values().map(|p| p.blocks).sum()
    }

    /// Lifetime count of parked prefixes evicted under host pressure.
    pub fn park_evictions(&self) -> u64 {
        self.park_evictions
    }

    /// Total tokens resident on device across all requests.
    pub fn device_tokens_used(&self) -> usize {
        self.allocs
            .values()
            .filter(|a| a.residence == KvResidence::Device)
            .map(|a| a.tokens)
            .sum()
    }

    /// Number of live allocations (diagnostics).
    pub fn num_allocations(&self) -> usize {
        self.allocs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr() -> KvCacheManager {
        // 10 device blocks of 16 tokens (160), 5 host blocks (80).
        KvCacheManager::new(160, 80, 16)
    }

    #[test]
    fn allocate_and_free_roundtrip() {
        let mut m = mgr();
        m.allocate(1, 40).unwrap(); // 3 blocks
        assert_eq!(m.device_free_blocks(), 7);
        assert_eq!(m.device_tokens_of(1), 40);
        assert_eq!(m.free(1).unwrap(), 40);
        assert_eq!(m.device_free_blocks(), 10);
        assert_eq!(m.num_allocations(), 0);
    }

    #[test]
    fn rejects_oversized_and_double_alloc() {
        let mut m = mgr();
        assert!(matches!(m.allocate(1, 161), Err(KvError::DeviceFull { .. })));
        m.allocate(1, 16).unwrap();
        assert_eq!(m.allocate(1, 16), Err(KvError::AlreadyAllocated(1)));
    }

    #[test]
    fn extend_claims_blocks_lazily() {
        let mut m = mgr();
        m.allocate(1, 16).unwrap(); // exactly 1 block
        assert_eq!(m.device_free_blocks(), 9);
        m.extend(1, 1).unwrap(); // 17 tokens → 2 blocks
        assert_eq!(m.device_free_blocks(), 8);
        for _ in 0..15 {
            m.extend(1, 1).unwrap(); // up to 32 tokens, still 2 blocks
        }
        assert_eq!(m.device_free_blocks(), 8);
        m.extend(1, 1).unwrap(); // 33 → 3 blocks
        assert_eq!(m.device_free_blocks(), 7);
    }

    #[test]
    fn extend_fails_when_full_but_state_intact() {
        let mut m = KvCacheManager::new(32, 0, 16);
        m.allocate(1, 32).unwrap();
        assert!(matches!(m.extend(1, 1), Err(KvError::DeviceFull { .. })));
        assert_eq!(m.device_tokens_of(1), 32);
    }

    #[test]
    fn swap_out_in_roundtrip() {
        let mut m = mgr();
        m.allocate(1, 48).unwrap(); // 3 blocks
        let moved = m.swap_out(1).unwrap();
        assert_eq!(moved, 48);
        assert_eq!(m.device_free_blocks(), 10);
        assert_eq!(m.host_free_blocks(), 2);
        assert_eq!(m.residence_of(1), Some(KvResidence::Host));
        assert_eq!(m.device_tokens_of(1), 0);
        let back = m.swap_in(1).unwrap();
        assert_eq!(back, 48);
        assert_eq!(m.residence_of(1), Some(KvResidence::Device));
        assert_eq!(m.host_free_blocks(), 5);
    }

    #[test]
    fn swap_out_fails_when_host_full() {
        let mut m = KvCacheManager::new(160, 32, 16);
        m.allocate(1, 48).unwrap();
        m.allocate(2, 32).unwrap();
        m.swap_out(2).unwrap(); // host now full
        let err = m.swap_out(1);
        assert!(matches!(err, Err(KvError::HostFull { .. })));
        // State unchanged: request 1 still on device.
        assert_eq!(m.residence_of(1), Some(KvResidence::Device));
        assert_eq!(m.device_tokens_of(1), 48);
    }

    #[test]
    fn cannot_extend_swapped_request() {
        let mut m = mgr();
        m.allocate(1, 16).unwrap();
        m.swap_out(1).unwrap();
        assert!(matches!(m.extend(1, 1), Err(KvError::WrongResidence(..))));
        // free() works from host residence.
        assert_eq!(m.free(1).unwrap(), 16);
        assert_eq!(m.host_free_blocks(), 5);
    }

    #[test]
    fn utilization_tracks() {
        let mut m = mgr();
        assert_eq!(m.device_utilization(), 0.0);
        m.allocate(1, 80).unwrap();
        assert!((m.device_utilization() - 0.5).abs() < 1e-12);
        assert_eq!(m.device_tokens_used(), 80);
    }

    #[test]
    fn capacity_rounds_down_to_blocks() {
        let m = KvCacheManager::new(100, 50, 16);
        assert_eq!(m.device_capacity_tokens(), 96);
    }

    #[test]
    fn park_claim_roundtrip_conserves_blocks() {
        let mut m = mgr();
        m.allocate(1, 40).unwrap(); // 3 device blocks
        assert_eq!(m.park(7, 1).unwrap(), 40);
        // Device freed, host holds the parked prefix, allocation gone.
        assert_eq!(m.device_free_blocks(), 10);
        assert_eq!(m.host_free_blocks(), 2);
        assert_eq!(m.num_allocations(), 0);
        assert_eq!(m.parked_count(), 1);
        assert_eq!(m.parked_tokens(7), Some(40));
        assert_eq!(m.parked_tokens(8), None);
        // Claim returns the tokens and both pools go back to zero use.
        assert_eq!(m.claim_parked(7), Some(40));
        assert_eq!(m.claim_parked(7), None, "claim is one-shot");
        assert_eq!(m.host_free_blocks(), 5);
        assert_eq!(m.parked_count(), 0);
    }

    #[test]
    fn park_replaces_same_key_and_evicts_lru_under_pressure() {
        // Host pool: 5 blocks. Park 3 sessions of 2 blocks each — the
        // third park must evict the least-recently-parked entry.
        let mut m = mgr();
        m.allocate(1, 32).unwrap(); // 2 blocks
        m.allocate(2, 32).unwrap();
        m.allocate(3, 32).unwrap();
        m.park(100, 1).unwrap();
        m.park(200, 2).unwrap();
        assert_eq!(m.host_free_blocks(), 1);
        m.park(300, 3).unwrap(); // needs 2 > 1 free → evicts key 100
        assert_eq!(m.park_evictions(), 1);
        assert_eq!(m.parked_tokens(100), None, "LRU entry evicted");
        assert_eq!(m.parked_tokens(200), Some(32));
        assert_eq!(m.parked_tokens(300), Some(32));
        // Re-parking a key replaces (not duplicates) its entry: the old
        // 2 blocks free up, so the larger prefix fits without eviction.
        m.allocate(4, 48).unwrap(); // 3 blocks
        m.park(200, 4).unwrap();
        assert_eq!(m.parked_tokens(200), Some(48));
        assert_eq!(m.parked_tokens(300), Some(32));
        assert_eq!(m.parked_count(), 2);
        assert_eq!(m.park_evictions(), 1, "replacement is not an eviction");
        assert_eq!(m.host_free_blocks(), 0);
        // Cleanup: drop everything → both pools fully free.
        assert!(m.drop_parked(200));
        assert!(m.drop_parked(300));
        assert!(!m.drop_parked(200));
        assert_eq!(m.host_free_blocks(), 5);
        assert_eq!(m.device_free_blocks(), 10);
    }

    #[test]
    fn park_fails_oversized_leaving_allocation_intact() {
        // Host pool (5 blocks) cannot hold a 6-block context even after
        // evicting every parked prefix; the allocation must survive so
        // the caller can fall back to a plain free.
        let mut m = mgr();
        m.allocate(1, 96).unwrap(); // 6 blocks
        assert!(matches!(m.park(9, 1), Err(KvError::HostFull { .. })));
        assert_eq!(m.device_tokens_of(1), 96);
        assert_eq!(m.num_allocations(), 1);
        assert_eq!(m.free(1).unwrap(), 96);
    }

    #[test]
    fn failed_repark_keeps_the_previous_entry() {
        // A same-key re-park that cannot fit must leave the old (still
        // usable) parked prefix in place, not drop it on the way out.
        let mut m = mgr();
        m.allocate(1, 32).unwrap(); // 2 blocks
        m.park(9, 1).unwrap();
        m.allocate(2, 96).unwrap(); // 6 blocks — never fits in 5
        assert!(matches!(m.park(9, 2), Err(KvError::HostFull { .. })));
        assert_eq!(m.parked_tokens(9), Some(32), "old prefix must survive");
        assert_eq!(m.park_evictions(), 0);
        assert_eq!(m.device_tokens_of(2), 96);
        // Cleanup drains both pools.
        m.free(2).unwrap();
        assert!(m.drop_parked(9));
        assert_eq!(m.host_free_blocks(), 5);
        assert_eq!(m.device_free_blocks(), 10);
    }

    #[test]
    fn infeasible_swap_out_leaves_parked_prefixes_alone() {
        // Host: 5 blocks = 4 swapped + 1 parked. A 2-block swap_out can
        // never fit even after evicting the parked prefix, so it must
        // fail *without* destroying the cache on the way.
        let mut m = mgr();
        m.allocate(1, 64).unwrap(); // 4 blocks
        m.swap_out(1).unwrap();
        m.allocate(2, 16).unwrap(); // 1 block
        m.park(5, 2).unwrap();
        assert_eq!(m.host_free_blocks(), 0);
        m.allocate(3, 32).unwrap(); // 2 blocks
        assert!(matches!(m.swap_out(3), Err(KvError::HostFull { .. })));
        assert_eq!(m.parked_tokens(5), Some(16), "cache must survive a doomed swap");
        assert_eq!(m.park_evictions(), 0);
    }

    #[test]
    fn swap_out_evicts_parked_prefixes_first() {
        // Host: 5 blocks. A 4-block parked prefix blocks a 2-block swap
        // until the swap path evicts it (live state outranks cache).
        let mut m = mgr();
        m.allocate(1, 64).unwrap(); // 4 blocks
        m.park(50, 1).unwrap();
        assert_eq!(m.host_free_blocks(), 1);
        m.allocate(2, 32).unwrap(); // 2 blocks
        assert_eq!(m.swap_out(2).unwrap(), 32);
        assert_eq!(m.park_evictions(), 1);
        assert_eq!(m.parked_tokens(50), None);
        assert_eq!(m.residence_of(2), Some(KvResidence::Host));
        // Cleanup returns both pools to zero use.
        m.free(2).unwrap();
        assert_eq!(m.host_free_blocks(), 5);
        assert_eq!(m.device_free_blocks(), 10);
    }
}
