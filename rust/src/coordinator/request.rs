//! Request lifecycle and per-request state (paper Fig. 6).
//!
//! A request moves through:
//!
//! ```text
//! Waiting ──admit──▶ Running ──last token──▶ Finished
//!    ▲                  │
//!    │   preempt(swap)  ├──▶ SwappedOut ──swap-in──▶ Running
//!    └── preempt(drop) ─┘        (KV on host)
//! ```
//!
//! `Waiting` covers both brand-new requests and recompute-preempted ones
//! (their KV was dropped; re-admission replays prefill over prompt +
//! generated tokens). Times are absolute engine times in seconds; the
//! QoE digest state internally uses request-relative time.

use crate::qoe::metric::{qoe_at, qoe_finished, DigestState};
use crate::qoe::spec::QoeSpec;
use crate::workload::SessionInfo;

pub type RequestId = usize;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// In the waiting queue (no KV on device). `generated > 0` means the
    /// request was preempted via recomputation.
    Waiting,
    /// In the running batch; generates one token per iteration.
    Running,
    /// Preempted with KV cache moved to host memory.
    SwappedOut,
    /// All tokens generated and delivered.
    Finished,
}

/// Serving-time state of one request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    /// The submitting spec's trace-level id. Engine ids follow
    /// submission order per engine, so once a gateway defer queue or a
    /// cluster router reorders admissions only `spec_id` ties the
    /// record back to the trace (and to its telemetry span).
    pub spec_id: usize,
    /// Absolute arrival time (s).
    pub arrival: f64,
    pub prompt_tokens: usize,
    pub qoe_spec: QoeSpec,
    pub phase: Phase,
    /// Tokens generated so far.
    pub generated: usize,
    /// Incremental QoE digestion state (request-relative time).
    pub digest: DigestState,
    /// Absolute delivery timestamps of every generated token (the TDT).
    pub token_times: Vec<f64>,
    pub first_token_at: Option<f64>,
    pub finished_at: Option<f64>,
    /// Number of times this request has been preempted.
    pub preemptions: usize,
    /// Iterations spent in the running batch (for RR quanta).
    pub service_iterations: u64,
    /// Conversational-session membership (None = one-shot request).
    pub session: Option<SessionInfo>,
    /// Leading context tokens restored from a parked session prefix at
    /// admission (0 = cold prefill). See DESIGN.md §10.
    pub prefix_hit_tokens: usize,
}

impl Request {
    pub fn new(
        id: RequestId,
        arrival: f64,
        prompt_tokens: usize,
        qoe_spec: QoeSpec,
    ) -> Self {
        Request {
            id,
            spec_id: id,
            arrival,
            prompt_tokens,
            qoe_spec,
            phase: Phase::Waiting,
            generated: 0,
            digest: DigestState::new(&qoe_spec),
            token_times: Vec::new(),
            first_token_at: None,
            finished_at: None,
            preemptions: 0,
            service_iterations: 0,
            session: None,
            prefix_hit_tokens: 0,
        }
    }

    /// Context length `l_i` (Eq. 3): prompt plus generated tokens — the
    /// number of KV-cache entries the request occupies when running.
    pub fn context_len(&self) -> usize {
        self.prompt_tokens + self.generated
    }

    /// Record delivery of one generated token at absolute time `t`.
    pub fn deliver_token(&mut self, t: f64) {
        debug_assert!(t >= self.arrival);
        self.generated += 1;
        self.digest.deliver(t - self.arrival);
        self.token_times.push(t);
        if self.first_token_at.is_none() {
            self.first_token_at = Some(t);
        }
    }

    /// Actual TTFT if the first token has been delivered.
    pub fn ttft(&self) -> Option<f64> {
        self.first_token_at.map(|t| t - self.arrival)
    }

    /// Average observed TDS excluding TTFT (Table 4's definition):
    /// (tokens − 1) / (t_last − t_first).
    pub fn avg_tds(&self) -> Option<f64> {
        if self.token_times.len() < 2 {
            return None;
        }
        // lint:allow(D6, len >= 2 was checked above)
        let span = self.token_times.last().unwrap() - self.token_times[0];
        if span <= 0.0 {
            return None;
        }
        Some((self.token_times.len() - 1) as f64 / span)
    }

    /// Current QoE evaluated at absolute time `t` (mid-flight).
    pub fn qoe_at(&self, t: f64) -> f64 {
        let cap = if self.phase == Phase::Finished { Some(self.generated as f64) } else { None };
        qoe_at(&self.qoe_spec, &self.digest, t - self.arrival, cap)
    }

    /// Final QoE (Eq. 1). Panics if not finished.
    pub fn final_qoe(&self) -> f64 {
        assert_eq!(self.phase, Phase::Finished, "request {} not finished", self.id);
        qoe_finished(&self.qoe_spec, &self.digest, self.generated)
    }

    /// Normalized latency (vLLM/Orca metric, Appendix E): end-to-end
    /// latency divided by output length.
    pub fn normalized_latency(&self) -> Option<f64> {
        let end = self.finished_at?;
        if self.generated == 0 {
            return None;
        }
        Some((end - self.arrival) / self.generated as f64)
    }

    pub fn is_active(&self) -> bool {
        !matches!(self.phase, Phase::Finished)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req() -> Request {
        Request::new(0, 10.0, 50, QoeSpec::new(1.0, 2.0))
    }

    #[test]
    fn lifecycle_and_ttft() {
        let mut r = req();
        assert_eq!(r.phase, Phase::Waiting);
        assert_eq!(r.ttft(), None);
        r.deliver_token(11.5);
        assert_eq!(r.ttft(), Some(1.5));
        assert_eq!(r.generated, 1);
        assert_eq!(r.context_len(), 51);
        r.deliver_token(12.0);
        assert_eq!(r.context_len(), 52);
        assert_eq!(r.first_token_at, Some(11.5));
    }

    #[test]
    fn avg_tds_excludes_ttft() {
        let mut r = req();
        r.deliver_token(15.0); // slow TTFT
        r.deliver_token(15.5);
        r.deliver_token(16.0);
        // 2 tokens over 1 second after the first.
        assert!((r.avg_tds().unwrap() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn final_qoe_perfect_for_on_time() {
        let mut r = req();
        for i in 0..8 {
            r.deliver_token(10.0 + 1.0 + i as f64 / 2.0);
        }
        r.phase = Phase::Finished;
        r.finished_at = Some(*r.token_times.last().unwrap());
        assert!(r.final_qoe() > 0.99);
        assert!((r.normalized_latency().unwrap() - 4.5 / 8.0).abs() < 1e-9);
    }

    #[test]
    fn qoe_mid_flight_degrades_while_waiting() {
        let r = req();
        assert_eq!(r.qoe_at(10.5), 1.0); // before expected TTFT
        assert_eq!(r.qoe_at(13.0), 0.0); // nothing delivered, past TTFT
    }

    #[test]
    #[should_panic]
    fn final_qoe_requires_finished() {
        let r = req();
        r.final_qoe();
    }
}
