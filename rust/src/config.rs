//! Deployment configuration: a JSON config file describing the model,
//! hardware, scheduler, and engine knobs, overridable from the CLI.
//!
//! ```json
//! {
//!   "model": "opt-66b",
//!   "gpu": "a100-4x",
//!   "scheduler": {
//!     "kind": "andes",
//!     "objective": "avg",
//!     "preemption_cap": 1.0,
//!     "delta_t": null,
//!     "b_grid": 8,
//!     "solver": "greedy"
//!   },
//!   "engine": {
//!     "block_size": 16,
//!     "max_output_tokens": 2048,
//!     "prefer_swap": true
//!   },
//!   "gateway": {
//!     "admission": true,
//!     "pacing": true,
//!     "lead_tokens": 4,
//!     "pace_rate_factor": 1.25,
//!     "min_predicted_qoe": 0.35,
//!     "baseline_rate": 3.0,
//!     "surge_enter": 1.5,
//!     "surge_exit": 1.1
//!   },
//!   "autoscale": {
//!     "enabled": true,
//!     "min_replicas": 1,
//!     "max_replicas": 4,
//!     "replica_capacity": 1.2,
//!     "target_utilization": 0.8,
//!     "cold_start_secs": 15,
//!     "scale_in_hold_secs": 30,
//!     "kv_high_watermark": 0.9,
//!     "eval_interval_secs": 1.0
//!   },
//!   "spill": {
//!     "enabled": true,
//!     "replicas": 1,
//!     "kv_fraction": 0.5
//!   },
//!   "federation": {
//!     "gateways": 2,
//!     "sync_interval_secs": 0.25,
//!     "staleness_bound_secs": 2.0
//!   },
//!   "tiers": {
//!     "premium": 2.0,
//!     "standard": 1.0,
//!     "economy": 0.5
//!   },
//!   "sessions": {
//!     "park": true,
//!     "affinity": true
//!   },
//!   "telemetry": {
//!     "enabled": true,
//!     "trace_capacity": 65536,
//!     "snapshot_interval": 1.0
//!   },
//!   "network": {
//!     "enabled": true,
//!     "mix": {"fiber": 0.6, "wifi": 0.3, "lte": 0.1},
//!     "adaptive_lead": true,
//!     "jitter_headroom": 4.0,
//!     "max_lead": 64,
//!     "seed": 7
//!   },
//!   "slack": {
//!     "enabled": true
//!   }
//! }
//! ```

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::coordinator::engine::EngineConfig;
use crate::coordinator::sched::andes::{AndesConfig, AndesScheduler, KnapsackSolver};
use crate::gateway::{FederationConfig, GatewayConfig, SpillConfig};
use crate::coordinator::sched::fcfs::FcfsScheduler;
use crate::coordinator::sched::objective::Objective;
use crate::coordinator::sched::round_robin::RoundRobinScheduler;
use crate::coordinator::sched::Scheduler;
use crate::model::gpu::{gpu_by_name, GpuProfile};
use crate::model::llm::{llm_by_name, LlmProfile};
use crate::telemetry::TelemetryConfig;
use crate::util::json::Json;

/// Parsed deployment configuration.
#[derive(Debug, Clone)]
pub struct AndesDeployment {
    pub llm: LlmProfile,
    pub gpu: GpuProfile,
    pub scheduler: SchedulerConfig,
    pub engine: EngineConfig,
    pub gateway: GatewayConfig,
    /// Overflow tier replaying primary rejections (disabled by default).
    pub spill: SpillConfig,
    /// Multi-gateway federation (1 gateway — i.e. disabled — by
    /// default). Per-tier admission weights live in
    /// `gateway.admission.tier_weights` (the `"tiers"` section).
    /// Note: the `andes` CLI currently drives federation through
    /// `simulate --gateways/--sync-interval` flags rather than a config
    /// file, and the live server fronts a single engine (it prints a
    /// note when `gateways > 1`); this section is parsed and validated
    /// so deployment descriptors can carry the topology for embedders
    /// building a [`crate::gateway::FederatedGateway`] themselves.
    pub federation: FederationConfig,
    /// Multi-turn session serving (DESIGN.md §10): `park` mirrors into
    /// `engine.park_prefixes`; `affinity` is applied to the cluster by
    /// whichever frontend builds one (`simulate`, embedders).
    pub sessions: SessionsConfig,
    /// `"telemetry"` section (DESIGN.md §12): metric registry + event
    /// tracer. `None` when the config carries no section, so each
    /// frontend keeps its own default (live server: on; simulation
    /// paths: off for bit-identical parity).
    pub telemetry: Option<TelemetryConfig>,
}

/// `"sessions"` section: KV prefix parking + session-affinity routing.
/// Both default to off, which reproduces pre-session behavior
/// bit-identically.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SessionsConfig {
    /// Park a finished turn's KV for the session's next turn.
    pub park: bool,
    /// Route returning turns to the replica holding their parked prefix
    /// (requires `park`).
    pub affinity: bool,
}

/// Scheduler section.
#[derive(Debug, Clone)]
pub enum SchedulerConfig {
    Fcfs,
    RoundRobin { quantum: u64 },
    Andes(AndesConfig),
}

impl SchedulerConfig {
    pub fn build(&self) -> Box<dyn Scheduler> {
        match self {
            SchedulerConfig::Fcfs => Box::new(FcfsScheduler::new()),
            SchedulerConfig::RoundRobin { quantum } => {
                Box::new(RoundRobinScheduler::new(*quantum))
            }
            SchedulerConfig::Andes(cfg) => Box::new(AndesScheduler::new(cfg.clone())),
        }
    }
}

impl Default for AndesDeployment {
    fn default() -> Self {
        let llm = crate::model::llm::opt_66b();
        let gpu = crate::model::gpu::a100_4x();
        let engine = EngineConfig {
            kv_capacity_tokens: llm.kv_capacity_tokens(&gpu),
            swap_capacity_tokens: llm.swap_capacity_tokens(&gpu),
            ..EngineConfig::default()
        };
        AndesDeployment {
            llm,
            gpu,
            scheduler: SchedulerConfig::Andes(AndesConfig::default()),
            engine,
            gateway: GatewayConfig::default(),
            spill: SpillConfig::default(),
            federation: FederationConfig::default(),
            sessions: SessionsConfig::default(),
            telemetry: None,
        }
    }
}

impl AndesDeployment {
    /// Load from a JSON file.
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_json_str(&text)
    }

    /// Parse from a JSON string.
    pub fn from_json_str(text: &str) -> Result<Self> {
        let j = Json::parse(text).context("parsing config json")?;
        let mut d = AndesDeployment::default();

        if let Some(name) = j.get("model").as_str() {
            d.llm = llm_by_name(name)
                .with_context(|| format!("unknown model '{name}'"))?;
        }
        if let Some(name) = j.get("gpu").as_str() {
            d.gpu =
                gpu_by_name(name).with_context(|| format!("unknown gpu '{name}'"))?;
        }
        // Re-derive capacity from the (possibly new) model/GPU pair.
        d.engine.kv_capacity_tokens = d.llm.kv_capacity_tokens(&d.gpu);
        d.engine.swap_capacity_tokens = d.llm.swap_capacity_tokens(&d.gpu);

        let s = j.get("scheduler");
        if !s.is_null() {
            let kind = s.get("kind").as_str().unwrap_or("andes");
            d.scheduler = match kind {
                "fcfs" => SchedulerConfig::Fcfs,
                "rr" | "round-robin" => SchedulerConfig::RoundRobin {
                    quantum: s.get("quantum").as_u64().unwrap_or(50),
                },
                "andes" => {
                    let mut cfg = AndesConfig::default();
                    if let Some(o) = s.get("objective").as_str() {
                        cfg.objective = Objective::by_name(o)
                            .with_context(|| format!("unknown objective '{o}'"))?;
                    }
                    if let Some(p) = s.get("preemption_cap").as_f64() {
                        if p < 0.0 {
                            bail!("preemption_cap must be ≥ 0");
                        }
                        cfg.preemption_cap = p;
                    }
                    if let Some(dt) = s.get("delta_t").as_f64() {
                        cfg.delta_t_override = Some(dt);
                    }
                    if let Some(g) = s.get("b_grid").as_u64() {
                        if g == 0 {
                            bail!("b_grid must be >= 1");
                        }
                        cfg.b_grid = g as usize;
                    }
                    if let Some(sv) = s.get("solver").as_str() {
                        cfg.solver = match sv {
                            "greedy" => KnapsackSolver::Greedy,
                            "dp" => KnapsackSolver::Dp,
                            other => bail!("unknown solver '{other}'"),
                        };
                    }
                    if let Some(w) = s.get("watermark").as_f64() {
                        if !(0.0..=1.0).contains(&w) {
                            bail!("watermark must be in [0,1]");
                        }
                        cfg.watermark = w;
                    }
                    if let Some(m) = s.get("preempt_margin").as_f64() {
                        cfg.preempt_margin = m.max(0.0);
                    }
                    SchedulerConfig::Andes(cfg)
                }
                other => bail!("unknown scheduler kind '{other}'"),
            };
        }

        let e = j.get("engine");
        if !e.is_null() {
            if let Some(b) = e.get("block_size").as_u64() {
                if b == 0 {
                    bail!("block_size must be > 0");
                }
                d.engine.block_size = b as usize;
            }
            if let Some(m) = e.get("max_output_tokens").as_u64() {
                d.engine.max_output_tokens = m as usize;
            }
            if let Some(p) = e.get("prefer_swap").as_bool() {
                d.engine.prefer_swap = p;
            }
            if let Some(k) = e.get("kv_capacity_tokens").as_u64() {
                d.engine.kv_capacity_tokens = k as usize;
            }
            if let Some(k) = e.get("swap_capacity_tokens").as_u64() {
                d.engine.swap_capacity_tokens = k as usize;
            }
        }

        let g = j.get("gateway");
        if !g.is_null() {
            if let Some(b) = g.get("admission").as_bool() {
                d.gateway.admission_enabled = b;
            }
            if let Some(b) = g.get("pacing").as_bool() {
                d.gateway.pacing_enabled = b;
            }
            if let Some(n) = g.get("lead_tokens").as_u64() {
                // 0 is a valid setting: it disables the lead buffer.
                d.gateway.pacing.lead_tokens = n as usize;
            }
            if let Some(f) = g.get("pace_rate_factor").as_f64() {
                if f <= 0.0 {
                    bail!("pace_rate_factor must be > 0");
                }
                d.gateway.pacing.rate_factor = f;
            }
            if let Some(q) = g.get("min_predicted_qoe").as_f64() {
                if !(0.0..=1.0).contains(&q) {
                    bail!("min_predicted_qoe must be in [0,1]");
                }
                d.gateway.admission.min_predicted_qoe = q;
            }
            if let Some(h) = g.get("admission_hysteresis").as_f64() {
                if h < 0.0 {
                    bail!("admission_hysteresis must be ≥ 0");
                }
                d.gateway.admission.hysteresis = h;
            }
            if let Some(n) = g.get("max_deferred").as_u64() {
                d.gateway.admission.max_deferred = n as usize;
            }
            if let Some(w) = g.get("max_defer_wait").as_f64() {
                if w < 0.0 {
                    bail!("max_defer_wait must be ≥ 0");
                }
                d.gateway.admission.max_defer_wait = w;
            }
            if let Some(n) = g.get("expected_output_tokens").as_u64() {
                d.gateway.admission.expected_output_tokens = n as usize;
            }
            if let Some(w) = g.get("surge_window").as_f64() {
                if w <= 0.0 {
                    bail!("surge_window must be > 0");
                }
                d.gateway.surge.window_secs = w;
            }
            if let Some(r) = g.get("baseline_rate").as_f64() {
                if r <= 0.0 {
                    bail!("baseline_rate must be > 0");
                }
                d.gateway.surge.baseline_rate = r;
            }
            if let Some(f) = g.get("surge_enter").as_f64() {
                d.gateway.surge.enter_factor = f;
            }
            if let Some(f) = g.get("surge_exit").as_f64() {
                d.gateway.surge.exit_factor = f;
            }
            if d.gateway.surge.enter_factor <= d.gateway.surge.exit_factor {
                bail!(
                    "surge_enter ({}) must exceed surge_exit ({})",
                    d.gateway.surge.enter_factor,
                    d.gateway.surge.exit_factor
                );
            }
        }

        let a = j.get("autoscale");
        if !a.is_null() {
            let asc = &mut d.gateway.autoscale;
            if let Some(b) = a.get("enabled").as_bool() {
                asc.enabled = b;
            }
            if let Some(n) = a.get("min_replicas").as_u64() {
                if n == 0 {
                    bail!("min_replicas must be >= 1");
                }
                asc.min_replicas = n as usize;
            }
            if let Some(n) = a.get("max_replicas").as_u64() {
                asc.max_replicas = n as usize;
            }
            if let Some(v) = a.get("replica_capacity").as_f64() {
                if v <= 0.0 {
                    bail!("replica_capacity must be > 0");
                }
                asc.replica_capacity = v;
            }
            if let Some(v) = a.get("target_utilization").as_f64() {
                if v <= 0.0 || v > 1.5 {
                    bail!("target_utilization must be in (0, 1.5]");
                }
                asc.target_utilization = v;
            }
            if let Some(v) = a.get("cold_start_secs").as_f64() {
                if v < 0.0 {
                    bail!("cold_start_secs must be >= 0");
                }
                asc.cold_start_secs = v;
            }
            if let Some(v) = a.get("scale_in_hold_secs").as_f64() {
                if v < 0.0 {
                    bail!("scale_in_hold_secs must be >= 0");
                }
                asc.scale_in_hold_secs = v;
            }
            if let Some(v) = a.get("kv_high_watermark").as_f64() {
                if !(0.0..=1.0).contains(&v) {
                    bail!("kv_high_watermark must be in [0, 1]");
                }
                asc.kv_high_watermark = v;
            }
            if let Some(v) = a.get("eval_interval_secs").as_f64() {
                if v < 0.0 {
                    bail!("eval_interval_secs must be >= 0");
                }
                asc.eval_interval_secs = v;
            }
            if asc.min_replicas > asc.max_replicas {
                bail!(
                    "min_replicas ({}) must not exceed max_replicas ({})",
                    asc.min_replicas,
                    asc.max_replicas
                );
            }
        }

        let sp = j.get("spill");
        if !sp.is_null() {
            if let Some(b) = sp.get("enabled").as_bool() {
                d.spill.enabled = b;
            }
            if let Some(n) = sp.get("replicas").as_u64() {
                if n == 0 {
                    bail!("spill replicas must be >= 1");
                }
                d.spill.replicas = n as usize;
            }
            if let Some(v) = sp.get("kv_fraction").as_f64() {
                if v <= 0.0 || v > 1.0 {
                    bail!("spill kv_fraction must be in (0, 1]");
                }
                d.spill.kv_fraction = v;
            }
        }

        let f = j.get("federation");
        if !f.is_null() {
            if let Some(n) = f.get("gateways").as_u64() {
                if n == 0 {
                    bail!("federation gateways must be >= 1");
                }
                d.federation.gateways = n as usize;
            }
            if let Some(v) = f.get("sync_interval_secs").as_f64() {
                if v <= 0.0 {
                    bail!("sync_interval_secs must be > 0");
                }
                d.federation.sync_interval_secs = v;
            }
            if let Some(v) = f.get("staleness_bound_secs").as_f64() {
                if v < 0.0 {
                    bail!("staleness_bound_secs must be >= 0");
                }
                d.federation.staleness_bound_secs = v;
            }
        }

        let se = j.get("sessions");
        if !se.is_null() {
            if let Some(b) = se.get("park").as_bool() {
                d.sessions.park = b;
            }
            if let Some(b) = se.get("affinity").as_bool() {
                d.sessions.affinity = b;
            }
            if d.sessions.affinity && !d.sessions.park {
                bail!("sessions.affinity requires sessions.park");
            }
            d.engine.park_prefixes = d.sessions.park;
        }

        let net = j.get("network");
        if !net.is_null() {
            let n = &mut d.gateway.network;
            if let Some(b) = net.get("enabled").as_bool() {
                n.enabled = b;
            }
            let mix = net.get("mix");
            if let Some(m) = mix.as_obj() {
                let mut parsed = Vec::new();
                for (name, w) in m {
                    let profile = crate::delivery::NetworkProfile::by_name(name)
                        .with_context(|| {
                            format!(
                                "unknown network profile '{name}' \
                                 (ideal|fiber|wifi|lte)"
                            )
                        })?;
                    let w = w.as_f64().unwrap_or(f64::NAN);
                    if !w.is_finite() || w <= 0.0 {
                        bail!("network mix weight '{name}' must be positive and finite");
                    }
                    parsed.push((profile, w));
                }
                if parsed.is_empty() {
                    bail!("network mix must name at least one profile");
                }
                n.mix = parsed;
            } else if !mix.is_null() {
                bail!("network mix must be an object of profile: weight pairs");
            }
            if let Some(b) = net.get("adaptive_lead").as_bool() {
                n.adaptive_lead = b;
            }
            if let Some(h) = net.get("jitter_headroom").as_f64() {
                if !h.is_finite() || h <= 0.0 {
                    bail!("jitter_headroom must be positive and finite");
                }
                n.adaptive.headroom = h;
            }
            if let Some(m) = net.get("max_lead").as_u64() {
                if m == 0 {
                    bail!("network max_lead must be >= 1");
                }
                n.adaptive.max_lead = m as usize;
            }
            if let Some(s) = net.get("seed").as_u64() {
                n.seed = s;
            }
        }

        // Parsed after "gateway" and "network": the estimator mirrors
        // their final pacing/transit values (DESIGN.md §15).
        let sl = j.get("slack");
        if !sl.is_null() && sl.get("enabled").as_bool() == Some(true) {
            d.engine.slack = Some(d.gateway.slack_config());
        }

        let t = j.get("telemetry");
        if !t.is_null() {
            let mut tc = TelemetryConfig::default();
            if let Some(b) = t.get("enabled").as_bool() {
                tc.enabled = b;
            }
            if let Some(n) = t.get("trace_capacity").as_u64() {
                if n == 0 {
                    bail!("telemetry trace_capacity must be >= 1");
                }
                tc.trace_capacity = n as usize;
            }
            if let Some(v) = t.get("snapshot_interval").as_f64() {
                if !v.is_finite() || v < 0.0 {
                    bail!("telemetry snapshot_interval must be >= 0 (0 disables)");
                }
                tc.snapshot_interval = v;
            }
            d.telemetry = Some(tc);
        }

        let tiers = j.get("tiers");
        if !tiers.is_null() {
            let w = &mut d.gateway.admission.tier_weights;
            for (name, slot) in [
                ("premium", &mut w.premium),
                ("standard", &mut w.standard),
                ("economy", &mut w.economy),
            ] {
                if let Some(v) = tiers.get(name).as_f64() {
                    if !v.is_finite() || v <= 0.0 {
                        bail!("tier weight '{name}' must be positive and finite");
                    }
                    *slot = v;
                }
            }
        }
        Ok(d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_66b_andes() {
        let d = AndesDeployment::default();
        assert_eq!(d.llm.name, "OPT-66B");
        assert!(matches!(d.scheduler, SchedulerConfig::Andes(_)));
        assert!(d.engine.kv_capacity_tokens > 10_000);
    }

    #[test]
    fn full_config_parses() {
        let d = AndesDeployment::from_json_str(
            r#"{
              "model": "opt-13b",
              "gpu": "a100-1x",
              "scheduler": {"kind": "andes", "objective": "maxmin",
                            "preemption_cap": 0.4, "delta_t": 60,
                            "b_grid": 4, "solver": "dp", "watermark": 0.8},
              "engine": {"block_size": 32, "max_output_tokens": 512,
                         "prefer_swap": false}
            }"#,
        )
        .unwrap();
        assert_eq!(d.llm.name, "OPT-13B");
        assert_eq!(d.gpu.name, "1xA100-80G");
        match &d.scheduler {
            SchedulerConfig::Andes(c) => {
                assert_eq!(c.objective, Objective::MaxMin);
                assert_eq!(c.preemption_cap, 0.4);
                assert_eq!(c.delta_t_override, Some(60.0));
                assert_eq!(c.b_grid, 4);
                assert_eq!(c.solver, KnapsackSolver::Dp);
                assert_eq!(c.watermark, 0.8);
            }
            other => panic!("wrong scheduler {other:?}"),
        }
        assert_eq!(d.engine.block_size, 32);
        assert!(!d.engine.prefer_swap);
        // Capacity derived from 13B on 1×A100.
        assert!(d.engine.kv_capacity_tokens > 40_000);
    }

    #[test]
    fn partial_config_keeps_defaults() {
        let d = AndesDeployment::from_json_str(r#"{"scheduler": {"kind": "fcfs"}}"#).unwrap();
        assert!(matches!(d.scheduler, SchedulerConfig::Fcfs));
        assert_eq!(d.llm.name, "OPT-66B");
    }

    #[test]
    fn rr_quantum() {
        let d = AndesDeployment::from_json_str(
            r#"{"scheduler": {"kind": "rr", "quantum": 25}}"#,
        )
        .unwrap();
        assert!(matches!(d.scheduler, SchedulerConfig::RoundRobin { quantum: 25 }));
    }

    #[test]
    fn rejects_bad_values() {
        assert!(AndesDeployment::from_json_str(r#"{"model": "gpt-99"}"#).is_err());
        assert!(AndesDeployment::from_json_str(
            r#"{"scheduler": {"kind": "magic"}}"#
        )
        .is_err());
        assert!(AndesDeployment::from_json_str(
            r#"{"scheduler": {"kind": "andes", "solver": "quantum"}}"#
        )
        .is_err());
        assert!(AndesDeployment::from_json_str(
            r#"{"scheduler": {"kind": "andes", "watermark": 1.5}}"#
        )
        .is_err());
        assert!(AndesDeployment::from_json_str(r#"{"engine": {"block_size": 0}}"#).is_err());
        // Regression: b_grid 0 used to parse and later collapse the
        // batch-size scan (NaN spacing → every grid point = b_min).
        assert!(AndesDeployment::from_json_str(
            r#"{"scheduler": {"kind": "andes", "b_grid": 0}}"#
        )
        .is_err());
        assert!(AndesDeployment::from_json_str("not json").is_err());
    }

    #[test]
    fn gateway_config_parses() {
        let d = AndesDeployment::from_json_str(
            r#"{"gateway": {"admission": false, "pacing": true,
                 "lead_tokens": 8, "pace_rate_factor": 1.5,
                 "min_predicted_qoe": 0.5, "max_deferred": 16,
                 "max_defer_wait": 5.0, "baseline_rate": 4.0,
                 "surge_window": 20, "surge_enter": 2.0, "surge_exit": 1.2}}"#,
        )
        .unwrap();
        assert!(!d.gateway.admission_enabled);
        assert!(d.gateway.pacing_enabled);
        assert_eq!(d.gateway.pacing.lead_tokens, 8);
        assert_eq!(d.gateway.pacing.rate_factor, 1.5);
        assert_eq!(d.gateway.admission.min_predicted_qoe, 0.5);
        assert_eq!(d.gateway.admission.max_deferred, 16);
        assert_eq!(d.gateway.admission.max_defer_wait, 5.0);
        assert_eq!(d.gateway.surge.baseline_rate, 4.0);
        assert_eq!(d.gateway.surge.window_secs, 20.0);
        assert_eq!(d.gateway.surge.enter_factor, 2.0);
        assert_eq!(d.gateway.surge.exit_factor, 1.2);
    }

    #[test]
    fn lead_tokens_zero_disables_lead() {
        // Regression: the parser used to promote 0 → 1, so a config
        // could never actually disable the pacer's lead buffer.
        let d =
            AndesDeployment::from_json_str(r#"{"gateway": {"lead_tokens": 0}}"#).unwrap();
        assert_eq!(d.gateway.pacing.lead_tokens, 0);
    }

    #[test]
    fn autoscale_and_spill_sections_parse() {
        let d = AndesDeployment::from_json_str(
            r#"{"autoscale": {"enabled": true, "min_replicas": 2,
                 "max_replicas": 6, "replica_capacity": 1.5,
                 "target_utilization": 0.7, "cold_start_secs": 8,
                 "scale_in_hold_secs": 25, "kv_high_watermark": 0.85,
                 "eval_interval_secs": 0.5},
                "spill": {"enabled": true, "replicas": 2,
                          "kv_fraction": 0.4}}"#,
        )
        .unwrap();
        let a = &d.gateway.autoscale;
        assert!(a.enabled);
        assert_eq!(a.min_replicas, 2);
        assert_eq!(a.max_replicas, 6);
        assert_eq!(a.replica_capacity, 1.5);
        assert_eq!(a.target_utilization, 0.7);
        assert_eq!(a.cold_start_secs, 8.0);
        assert_eq!(a.scale_in_hold_secs, 25.0);
        assert_eq!(a.kv_high_watermark, 0.85);
        assert_eq!(a.eval_interval_secs, 0.5);
        assert!(d.spill.enabled);
        assert_eq!(d.spill.replicas, 2);
        assert_eq!(d.spill.kv_fraction, 0.4);
        // Defaults leave both disabled.
        let plain = AndesDeployment::from_json_str("{}").unwrap();
        assert!(!plain.gateway.autoscale.enabled);
        assert!(!plain.spill.enabled);
    }

    #[test]
    fn autoscale_and_spill_reject_bad_values() {
        for bad in [
            r#"{"autoscale": {"min_replicas": 0}}"#,
            r#"{"autoscale": {"min_replicas": 5, "max_replicas": 2}}"#,
            r#"{"autoscale": {"replica_capacity": -1}}"#,
            r#"{"autoscale": {"target_utilization": 0}}"#,
            r#"{"autoscale": {"kv_high_watermark": 1.5}}"#,
            r#"{"autoscale": {"cold_start_secs": -1}}"#,
            r#"{"spill": {"replicas": 0}}"#,
            r#"{"spill": {"kv_fraction": 0}}"#,
            r#"{"spill": {"kv_fraction": 1.2}}"#,
        ] {
            assert!(AndesDeployment::from_json_str(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn federation_and_tiers_sections_parse() {
        let d = AndesDeployment::from_json_str(
            r#"{"federation": {"gateways": 4, "sync_interval_secs": 0.5,
                               "staleness_bound_secs": 5.0},
                "tiers": {"premium": 2.0, "economy": 0.5}}"#,
        )
        .unwrap();
        assert_eq!(d.federation.gateways, 4);
        assert_eq!(d.federation.sync_interval_secs, 0.5);
        assert_eq!(d.federation.staleness_bound_secs, 5.0);
        let w = &d.gateway.admission.tier_weights;
        assert_eq!(w.premium, 2.0);
        assert_eq!(w.standard, 1.0, "unset tier keeps its default");
        assert_eq!(w.economy, 0.5);
        // Defaults: single gateway, tier-blind.
        let plain = AndesDeployment::from_json_str("{}").unwrap();
        assert_eq!(plain.federation.gateways, 1);
        assert!(plain.gateway.admission.tier_weights.is_uniform());
    }

    #[test]
    fn sessions_section_parses_and_mirrors_into_engine() {
        let d = AndesDeployment::from_json_str(
            r#"{"sessions": {"park": true, "affinity": true}}"#,
        )
        .unwrap();
        assert!(d.sessions.park);
        assert!(d.sessions.affinity);
        assert!(d.engine.park_prefixes, "park must mirror into the engine config");
        // Defaults: everything off, engine untouched.
        let plain = AndesDeployment::from_json_str("{}").unwrap();
        assert_eq!(plain.sessions, SessionsConfig::default());
        assert!(!plain.engine.park_prefixes);
        // Affinity without parking is a configuration error.
        assert!(AndesDeployment::from_json_str(r#"{"sessions": {"affinity": true}}"#)
            .is_err());
    }

    #[test]
    fn network_section_parses() {
        let d = AndesDeployment::from_json_str(
            r#"{"network": {"enabled": true,
                 "mix": {"fiber": 0.6, "wifi": 0.3, "lte": 0.1},
                 "adaptive_lead": true, "jitter_headroom": 6.0,
                 "max_lead": 32, "seed": 7}}"#,
        )
        .unwrap();
        let n = &d.gateway.network;
        assert!(n.enabled);
        assert!(n.adaptive_lead);
        assert_eq!(n.mix.len(), 3);
        assert_eq!(n.adaptive.headroom, 6.0);
        assert_eq!(n.adaptive.max_lead, 32);
        assert_eq!(n.seed, 7);
        // Defaults leave the delivery layer off entirely.
        let plain = AndesDeployment::from_json_str("{}").unwrap();
        assert!(!plain.gateway.network.enabled);
        assert!(!plain.gateway.network.adaptive_lead);
    }

    #[test]
    fn network_section_rejects_bad_values() {
        for bad in [
            r#"{"network": {"mix": {"warp-drive": 1.0}}}"#,
            r#"{"network": {"mix": {"lte": 0}}}"#,
            r#"{"network": {"mix": {"lte": -1}}}"#,
            r#"{"network": {"mix": {}}}"#,
            r#"{"network": {"mix": ["lte"]}}"#,
            r#"{"network": {"jitter_headroom": 0}}"#,
            r#"{"network": {"max_lead": 0}}"#,
        ] {
            assert!(AndesDeployment::from_json_str(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn federation_and_tiers_reject_bad_values() {
        for bad in [
            r#"{"federation": {"gateways": 0}}"#,
            r#"{"federation": {"sync_interval_secs": 0}}"#,
            r#"{"federation": {"staleness_bound_secs": -1}}"#,
            r#"{"tiers": {"premium": 0}}"#,
            r#"{"tiers": {"economy": -2}}"#,
        ] {
            assert!(AndesDeployment::from_json_str(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn gateway_config_rejects_bad_values() {
        for bad in [
            r#"{"gateway": {"surge_enter": 1.0, "surge_exit": 1.5}}"#,
            r#"{"gateway": {"min_predicted_qoe": 1.5}}"#,
            r#"{"gateway": {"pace_rate_factor": 0}}"#,
            r#"{"gateway": {"baseline_rate": -2}}"#,
        ] {
            assert!(AndesDeployment::from_json_str(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn slack_section_mirrors_gateway_settings_into_engine() {
        // Defaults / absent section / enabled:false → estimator off.
        let plain = AndesDeployment::from_json_str("{}").unwrap();
        assert!(plain.engine.slack.is_none());
        let off =
            AndesDeployment::from_json_str(r#"{"slack": {"enabled": false}}"#).unwrap();
        assert!(off.engine.slack.is_none());
        // Enabled: the estimator mirrors the final pacing + network
        // settings, wherever the sections appear in the document.
        let d = AndesDeployment::from_json_str(
            r#"{"slack": {"enabled": true},
                "gateway": {"pacing": true, "lead_tokens": 8,
                            "pace_rate_factor": 1.5},
                "network": {"enabled": true, "mix": {"lte": 1.0}}}"#,
        )
        .unwrap();
        let sc = d.engine.slack.expect("slack enabled");
        assert!(sc.paced);
        assert_eq!(sc.lead_tokens, 8);
        assert_eq!(sc.rate_factor, 1.5);
        assert!((sc.transit - d.gateway.network.expected_transit()).abs() < 1e-12);
        assert!(sc.transit > 0.0, "lte mix must contribute transit");
        // Pacing off → the estimator models release-at-generation.
        let unpaced = AndesDeployment::from_json_str(
            r#"{"slack": {"enabled": true}, "gateway": {"pacing": false}}"#,
        )
        .unwrap();
        let sc = unpaced.engine.slack.expect("slack enabled");
        assert!(!sc.paced);
        assert_eq!(sc.transit, 0.0, "network off ⇒ no transit term");
    }

    #[test]
    fn telemetry_section_parses() {
        let d = AndesDeployment::from_json_str(
            r#"{"telemetry": {"enabled": true, "trace_capacity": 1024,
                              "snapshot_interval": 0.5}}"#,
        )
        .unwrap();
        let t = d.telemetry.expect("section present");
        assert!(t.enabled);
        assert_eq!(t.trace_capacity, 1024);
        assert_eq!(t.snapshot_interval, 0.5);
        // No section → None, so frontends keep their own defaults.
        let plain = AndesDeployment::from_json_str("{}").unwrap();
        assert!(plain.telemetry.is_none());
        // Partial section fills from TelemetryConfig defaults.
        let partial =
            AndesDeployment::from_json_str(r#"{"telemetry": {"enabled": false}}"#).unwrap();
        let t = partial.telemetry.expect("section present");
        assert!(!t.enabled);
        assert_eq!(t.trace_capacity, TelemetryConfig::default().trace_capacity);
    }

    #[test]
    fn telemetry_section_rejects_bad_values() {
        for bad in [
            r#"{"telemetry": {"trace_capacity": 0}}"#,
            r#"{"telemetry": {"snapshot_interval": -1}}"#,
        ] {
            assert!(AndesDeployment::from_json_str(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn scheduler_builds() {
        for cfg in [
            r#"{"scheduler": {"kind": "fcfs"}}"#,
            r#"{"scheduler": {"kind": "rr"}}"#,
            r#"{"scheduler": {"kind": "andes"}}"#,
        ] {
            let d = AndesDeployment::from_json_str(cfg).unwrap();
            let s = d.scheduler.build();
            assert!(!s.name().is_empty());
        }
    }
}
