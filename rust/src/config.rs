//! Deployment configuration: a JSON config file describing the model,
//! hardware, scheduler, and engine knobs, overridable from the CLI.
//!
//! ```json
//! {
//!   "model": "opt-66b",
//!   "gpu": "a100-4x",
//!   "scheduler": {
//!     "kind": "andes",
//!     "objective": "avg",
//!     "preemption_cap": 1.0,
//!     "delta_t": null,
//!     "b_grid": 8,
//!     "solver": "greedy"
//!   },
//!   "engine": {
//!     "block_size": 16,
//!     "max_output_tokens": 2048,
//!     "prefer_swap": true
//!   }
//! }
//! ```

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::coordinator::engine::EngineConfig;
use crate::coordinator::sched::andes::{AndesConfig, AndesScheduler, KnapsackSolver};
use crate::coordinator::sched::fcfs::FcfsScheduler;
use crate::coordinator::sched::objective::Objective;
use crate::coordinator::sched::round_robin::RoundRobinScheduler;
use crate::coordinator::sched::Scheduler;
use crate::model::gpu::{gpu_by_name, GpuProfile};
use crate::model::llm::{llm_by_name, LlmProfile};
use crate::util::json::Json;

/// Parsed deployment configuration.
#[derive(Debug, Clone)]
pub struct AndesDeployment {
    pub llm: LlmProfile,
    pub gpu: GpuProfile,
    pub scheduler: SchedulerConfig,
    pub engine: EngineConfig,
}

/// Scheduler section.
#[derive(Debug, Clone)]
pub enum SchedulerConfig {
    Fcfs,
    RoundRobin { quantum: u64 },
    Andes(AndesConfig),
}

impl SchedulerConfig {
    pub fn build(&self) -> Box<dyn Scheduler> {
        match self {
            SchedulerConfig::Fcfs => Box::new(FcfsScheduler::new()),
            SchedulerConfig::RoundRobin { quantum } => {
                Box::new(RoundRobinScheduler::new(*quantum))
            }
            SchedulerConfig::Andes(cfg) => Box::new(AndesScheduler::new(cfg.clone())),
        }
    }
}

impl Default for AndesDeployment {
    fn default() -> Self {
        let llm = crate::model::llm::opt_66b();
        let gpu = crate::model::gpu::a100_4x();
        let engine = EngineConfig {
            kv_capacity_tokens: llm.kv_capacity_tokens(&gpu),
            swap_capacity_tokens: llm.swap_capacity_tokens(&gpu),
            ..EngineConfig::default()
        };
        AndesDeployment {
            llm,
            gpu,
            scheduler: SchedulerConfig::Andes(AndesConfig::default()),
            engine,
        }
    }
}

impl AndesDeployment {
    /// Load from a JSON file.
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_json_str(&text)
    }

    /// Parse from a JSON string.
    pub fn from_json_str(text: &str) -> Result<Self> {
        let j = Json::parse(text).context("parsing config json")?;
        let mut d = AndesDeployment::default();

        if let Some(name) = j.get("model").as_str() {
            d.llm = llm_by_name(name)
                .with_context(|| format!("unknown model '{name}'"))?;
        }
        if let Some(name) = j.get("gpu").as_str() {
            d.gpu =
                gpu_by_name(name).with_context(|| format!("unknown gpu '{name}'"))?;
        }
        // Re-derive capacity from the (possibly new) model/GPU pair.
        d.engine.kv_capacity_tokens = d.llm.kv_capacity_tokens(&d.gpu);
        d.engine.swap_capacity_tokens = d.llm.swap_capacity_tokens(&d.gpu);

        let s = j.get("scheduler");
        if !s.is_null() {
            let kind = s.get("kind").as_str().unwrap_or("andes");
            d.scheduler = match kind {
                "fcfs" => SchedulerConfig::Fcfs,
                "rr" | "round-robin" => SchedulerConfig::RoundRobin {
                    quantum: s.get("quantum").as_u64().unwrap_or(50),
                },
                "andes" => {
                    let mut cfg = AndesConfig::default();
                    if let Some(o) = s.get("objective").as_str() {
                        cfg.objective = Objective::by_name(o)
                            .with_context(|| format!("unknown objective '{o}'"))?;
                    }
                    if let Some(p) = s.get("preemption_cap").as_f64() {
                        if p < 0.0 {
                            bail!("preemption_cap must be ≥ 0");
                        }
                        cfg.preemption_cap = p;
                    }
                    if let Some(dt) = s.get("delta_t").as_f64() {
                        cfg.delta_t_override = Some(dt);
                    }
                    if let Some(g) = s.get("b_grid").as_u64() {
                        cfg.b_grid = (g as usize).max(1);
                    }
                    if let Some(sv) = s.get("solver").as_str() {
                        cfg.solver = match sv {
                            "greedy" => KnapsackSolver::Greedy,
                            "dp" => KnapsackSolver::Dp,
                            other => bail!("unknown solver '{other}'"),
                        };
                    }
                    if let Some(w) = s.get("watermark").as_f64() {
                        if !(0.0..=1.0).contains(&w) {
                            bail!("watermark must be in [0,1]");
                        }
                        cfg.watermark = w;
                    }
                    if let Some(m) = s.get("preempt_margin").as_f64() {
                        cfg.preempt_margin = m.max(0.0);
                    }
                    SchedulerConfig::Andes(cfg)
                }
                other => bail!("unknown scheduler kind '{other}'"),
            };
        }

        let e = j.get("engine");
        if !e.is_null() {
            if let Some(b) = e.get("block_size").as_u64() {
                if b == 0 {
                    bail!("block_size must be > 0");
                }
                d.engine.block_size = b as usize;
            }
            if let Some(m) = e.get("max_output_tokens").as_u64() {
                d.engine.max_output_tokens = m as usize;
            }
            if let Some(p) = e.get("prefer_swap").as_bool() {
                d.engine.prefer_swap = p;
            }
            if let Some(k) = e.get("kv_capacity_tokens").as_u64() {
                d.engine.kv_capacity_tokens = k as usize;
            }
            if let Some(k) = e.get("swap_capacity_tokens").as_u64() {
                d.engine.swap_capacity_tokens = k as usize;
            }
        }
        Ok(d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_66b_andes() {
        let d = AndesDeployment::default();
        assert_eq!(d.llm.name, "OPT-66B");
        assert!(matches!(d.scheduler, SchedulerConfig::Andes(_)));
        assert!(d.engine.kv_capacity_tokens > 10_000);
    }

    #[test]
    fn full_config_parses() {
        let d = AndesDeployment::from_json_str(
            r#"{
              "model": "opt-13b",
              "gpu": "a100-1x",
              "scheduler": {"kind": "andes", "objective": "maxmin",
                            "preemption_cap": 0.4, "delta_t": 60,
                            "b_grid": 4, "solver": "dp", "watermark": 0.8},
              "engine": {"block_size": 32, "max_output_tokens": 512,
                         "prefer_swap": false}
            }"#,
        )
        .unwrap();
        assert_eq!(d.llm.name, "OPT-13B");
        assert_eq!(d.gpu.name, "1xA100-80G");
        match &d.scheduler {
            SchedulerConfig::Andes(c) => {
                assert_eq!(c.objective, Objective::MaxMin);
                assert_eq!(c.preemption_cap, 0.4);
                assert_eq!(c.delta_t_override, Some(60.0));
                assert_eq!(c.b_grid, 4);
                assert_eq!(c.solver, KnapsackSolver::Dp);
                assert_eq!(c.watermark, 0.8);
            }
            other => panic!("wrong scheduler {other:?}"),
        }
        assert_eq!(d.engine.block_size, 32);
        assert!(!d.engine.prefer_swap);
        // Capacity derived from 13B on 1×A100.
        assert!(d.engine.kv_capacity_tokens > 40_000);
    }

    #[test]
    fn partial_config_keeps_defaults() {
        let d = AndesDeployment::from_json_str(r#"{"scheduler": {"kind": "fcfs"}}"#).unwrap();
        assert!(matches!(d.scheduler, SchedulerConfig::Fcfs));
        assert_eq!(d.llm.name, "OPT-66B");
    }

    #[test]
    fn rr_quantum() {
        let d = AndesDeployment::from_json_str(
            r#"{"scheduler": {"kind": "rr", "quantum": 25}}"#,
        )
        .unwrap();
        assert!(matches!(d.scheduler, SchedulerConfig::RoundRobin { quantum: 25 }));
    }

    #[test]
    fn rejects_bad_values() {
        assert!(AndesDeployment::from_json_str(r#"{"model": "gpt-99"}"#).is_err());
        assert!(AndesDeployment::from_json_str(
            r#"{"scheduler": {"kind": "magic"}}"#
        )
        .is_err());
        assert!(AndesDeployment::from_json_str(
            r#"{"scheduler": {"kind": "andes", "solver": "quantum"}}"#
        )
        .is_err());
        assert!(AndesDeployment::from_json_str(
            r#"{"scheduler": {"kind": "andes", "watermark": 1.5}}"#
        )
        .is_err());
        assert!(AndesDeployment::from_json_str(r#"{"engine": {"block_size": 0}}"#).is_err());
        assert!(AndesDeployment::from_json_str("not json").is_err());
    }

    #[test]
    fn scheduler_builds() {
        for cfg in [
            r#"{"scheduler": {"kind": "fcfs"}}"#,
            r#"{"scheduler": {"kind": "rr"}}"#,
            r#"{"scheduler": {"kind": "andes"}}"#,
        ] {
            let d = AndesDeployment::from_json_str(cfg).unwrap();
            let s = d.scheduler.build();
            assert!(!s.name().is_empty());
        }
    }
}
