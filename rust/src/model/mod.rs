//! Deployment profiles: GPU hardware, LLM architectures, and the
//! calibrated latency model that stands in for real A100 nodes
//! (DESIGN.md §1).

pub mod gpu;
pub mod latency;
pub mod llm;

pub use gpu::GpuProfile;
pub use latency::LatencyModel;
pub use llm::LlmProfile;
