//! GPU hardware profiles (paper Table 3 testbeds).
//!
//! We do not have A100s; these profiles parameterize the calibrated
//! latency model in [`super::latency`] so the simulator reproduces the
//! paper's *relative* behaviour (see DESIGN.md §1 substitution table).

/// A GPU server configuration (possibly multi-GPU tensor-parallel).
#[derive(Debug, Clone, PartialEq)]
pub struct GpuProfile {
    pub name: &'static str,
    /// Number of GPUs (tensor parallel degree).
    pub num_gpus: usize,
    /// Total GPU memory in GiB across the node.
    pub total_mem_gib: f64,
    /// Relative compute capability (A100 = 1.0). Scales iteration latency.
    pub compute_scale: f64,
    /// Host↔device bandwidth in GiB/s (PCIe; bounds swap overhead).
    pub pcie_gib_s: f64,
    /// CPU swap space for evicted KV caches, GiB (paper §6.1: 240 GB).
    pub swap_space_gib: f64,
}

/// 4×A100-80GB node (paper's main testbed for 30B/66B/175B).
pub fn a100_4x() -> GpuProfile {
    GpuProfile {
        name: "4xA100-80G",
        num_gpus: 4,
        total_mem_gib: 320.0,
        compute_scale: 1.0,
        pcie_gib_s: 25.0,
        swap_space_gib: 240.0,
    }
}

/// Single A100-80GB (paper's 13B testbed).
pub fn a100_1x() -> GpuProfile {
    GpuProfile {
        name: "1xA100-80G",
        num_gpus: 1,
        total_mem_gib: 80.0,
        compute_scale: 1.0,
        pcie_gib_s: 25.0,
        swap_space_gib: 240.0,
    }
}

/// NVIDIA A40 46GB (paper §6.4 robustness hardware).
/// ~2.7× slower than A100 for transformer decode (FP16 tensor-core
/// throughput 150 vs 312 TFLOPS, and lower memory bandwidth).
pub fn a40_1x() -> GpuProfile {
    GpuProfile {
        name: "1xA40-46G",
        num_gpus: 1,
        total_mem_gib: 46.0,
        compute_scale: 2.7,
        pcie_gib_s: 25.0,
        swap_space_gib: 240.0,
    }
}

/// Look up a profile by name (CLI / config).
pub fn gpu_by_name(name: &str) -> Option<GpuProfile> {
    match name {
        "a100-4x" | "4xA100-80G" => Some(a100_4x()),
        "a100-1x" | "1xA100-80G" => Some(a100_1x()),
        "a40" | "a40-1x" | "1xA40-46G" => Some(a40_1x()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup() {
        assert_eq!(gpu_by_name("a100-4x").unwrap().num_gpus, 4);
        assert_eq!(gpu_by_name("a40").unwrap().name, "1xA40-46G");
        assert!(gpu_by_name("h100").is_none());
    }

    #[test]
    fn a40_slower_than_a100() {
        assert!(a40_1x().compute_scale > a100_1x().compute_scale);
    }
}
