//! LLM model profiles: the OPT family used in the paper's evaluation
//! (Table 3), plus the tiny OPT-style model served for real by the PJRT
//! backend.
//!
//! The profile captures exactly what the scheduler and simulator consume:
//! memory footprints (⇒ the KV token capacity `M` of Eq. 3) and the
//! architectural scale factors behind the latency model.

use super::gpu::GpuProfile;

/// Bytes in one GiB.
pub const GIB: f64 = 1024.0 * 1024.0 * 1024.0;

#[derive(Debug, Clone, PartialEq)]
pub struct LlmProfile {
    pub name: &'static str,
    pub num_layers: usize,
    pub d_model: usize,
    pub num_heads: usize,
    /// Parameter count in billions (for flops estimates).
    pub params_b: f64,
    /// Weight memory in GiB as deployed (Table 3; 175B is INT8).
    pub model_mem_gib: f64,
    /// Bytes per KV-cache element (2 = fp16).
    pub kv_bytes_per_el: f64,
}

impl LlmProfile {
    /// KV-cache bytes consumed by one token of context:
    /// 2 (K and V) × layers × d_model × element size.
    pub fn kv_bytes_per_token(&self) -> f64 {
        2.0 * self.num_layers as f64 * self.d_model as f64 * self.kv_bytes_per_el
    }

    /// Token capacity `M` (Eq. 3): KV entries that fit in GPU memory.
    ///
    /// vLLM-style accounting: 90% of device memory is usable (the rest is
    /// activations/workspace); weights are subtracted first.
    pub fn kv_capacity_tokens(&self, gpu: &GpuProfile) -> usize {
        let usable = gpu.total_mem_gib * 0.9 - self.model_mem_gib;
        assert!(
            usable > 0.0,
            "{} does not fit on {} ({} GiB weights)",
            self.name,
            gpu.name,
            self.model_mem_gib
        );
        (usable * GIB / self.kv_bytes_per_token()) as usize
    }

    /// CPU swap capacity in tokens (paper §6.1: 240 GB swap space).
    pub fn swap_capacity_tokens(&self, gpu: &GpuProfile) -> usize {
        (gpu.swap_space_gib * GIB / self.kv_bytes_per_token()) as usize
    }
}

/// OPT-13B (40 layers, d=5120). Paper pairs it with 1×A100.
pub fn opt_13b() -> LlmProfile {
    LlmProfile {
        name: "OPT-13B",
        num_layers: 40,
        d_model: 5120,
        num_heads: 40,
        params_b: 13.0,
        model_mem_gib: 26.0,
        kv_bytes_per_el: 2.0,
    }
}

/// OPT-30B (48 layers, d=7168). 4×A100.
pub fn opt_30b() -> LlmProfile {
    LlmProfile {
        name: "OPT-30B",
        num_layers: 48,
        d_model: 7168,
        num_heads: 56,
        params_b: 30.0,
        model_mem_gib: 60.0,
        kv_bytes_per_el: 2.0,
    }
}

/// OPT-66B (64 layers, d=9216). 4×A100 — the paper's workhorse.
pub fn opt_66b() -> LlmProfile {
    LlmProfile {
        name: "OPT-66B",
        num_layers: 64,
        d_model: 9216,
        num_heads: 72,
        params_b: 66.0,
        model_mem_gib: 132.0,
        kv_bytes_per_el: 2.0,
    }
}

/// OPT-175B with INT8 weights (96 layers, d=12288). 4×A100.
/// KV cache stays fp16.
pub fn opt_175b() -> LlmProfile {
    LlmProfile {
        name: "OPT-175B",
        num_layers: 96,
        d_model: 12288,
        num_heads: 96,
        params_b: 175.0,
        model_mem_gib: 180.0,
        kv_bytes_per_el: 2.0,
    }
}

/// The tiny OPT-style model actually compiled and served by the PJRT
/// backend (python/compile/model.py). Memory numbers are real but small;
/// `model_mem_gib` is approximate (fp32 weights).
pub fn tiny_opt() -> LlmProfile {
    LlmProfile {
        name: "tiny-opt",
        num_layers: 4,
        d_model: 128,
        num_heads: 8,
        params_b: 0.003,
        model_mem_gib: 0.05,
        kv_bytes_per_el: 4.0, // fp32 on CPU
    }
}

pub fn llm_by_name(name: &str) -> Option<LlmProfile> {
    match name {
        "opt-13b" | "OPT-13B" | "13b" => Some(opt_13b()),
        "opt-30b" | "OPT-30B" | "30b" => Some(opt_30b()),
        "opt-66b" | "OPT-66B" | "66b" => Some(opt_66b()),
        "opt-175b" | "OPT-175B" | "175b" => Some(opt_175b()),
        "tiny" | "tiny-opt" => Some(tiny_opt()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gpu::{a100_1x, a100_4x, a40_1x};

    #[test]
    fn kv_bytes_match_hand_calc() {
        // OPT-66B: 2 * 64 * 9216 * 2 bytes = 2,359,296
        assert_eq!(opt_66b().kv_bytes_per_token(), 2_359_296.0);
        // OPT-13B: 2 * 40 * 5120 * 2 = 819,200
        assert_eq!(opt_13b().kv_bytes_per_token(), 819_200.0);
    }

    #[test]
    fn capacity_orders_match_paper() {
        // 66B on 4×A100: ~70k tokens (Fig. 19 saturates near 60k ctx).
        let m66 = opt_66b().kv_capacity_tokens(&a100_4x());
        assert!((50_000..100_000).contains(&m66), "M66 = {m66}");
        // 30B is far less memory-constrained (paper §6.2.1).
        let m30 = opt_30b().kv_capacity_tokens(&a100_4x());
        assert!(m30 > 2 * m66, "M30 = {m30}");
        // 175B is the most constrained on the same node.
        let m175 = opt_175b().kv_capacity_tokens(&a100_4x());
        assert!(m175 < m66 / 2, "M175 = {m175}");
        // 13B on one A100 ~ 60k.
        let m13 = opt_13b().kv_capacity_tokens(&a100_1x());
        assert!((40_000..90_000).contains(&m13), "M13 = {m13}");
    }

    #[test]
    fn a40_is_tight_for_13b() {
        let m = opt_13b().kv_capacity_tokens(&a40_1x());
        assert!(m < 25_000, "M = {m}");
    }

    #[test]
    #[should_panic]
    fn oversized_model_panics() {
        opt_66b().kv_capacity_tokens(&a40_1x());
    }

    #[test]
    fn lookup() {
        assert_eq!(llm_by_name("66b").unwrap().name, "OPT-66B");
        assert!(llm_by_name("gpt5").is_none());
    }

    #[test]
    fn swap_capacity_positive() {
        let s = opt_66b().swap_capacity_tokens(&a100_4x());
        assert!(s > 100_000, "swap = {s}"); // 240 GiB / 2.25 MiB
    }
}
