//! Calibrated token-generation latency model (paper Appendix B).
//!
//! The paper models one decode iteration's latency as a function of batch
//! size `B` (total context length is nearly perfectly correlated with B —
//! Pearson r = 0.997 — so it can be dropped). We keep a small explicit
//! context term so the Fig. 19 correlation experiment has a substrate to
//! measure, and model:
//!
//! ```text
//! decode(B, ctx)   = (base + per_seq·B + per_ctx·ctx) · compute_scale
//! prefill(tokens)  = (pre_base + per_tok·tokens)      · compute_scale
//! swap(tokens)     = kv_bytes(tokens) / pcie_bw + fixed launch cost
//! recompute(tokens)= prefill(tokens)
//! ```
//!
//! Decode is memory-bandwidth dominated (`base` = streaming the weights),
//! with small per-sequence and per-context-token terms; prefill is
//! compute-bound and linear in prompt tokens. Constants are calibrated so
//! OPT-66B on 4×A100 reproduces the paper's observed per-request
//! generation speed (≥6.6 tokens/s under load, Fig. 3b) and swap overhead
//! ≈ one decode iteration (Appendix D). Absolute numbers are estimates;
//! every experiment reports *relative* behaviour (DESIGN.md §1).

use super::gpu::GpuProfile;
use super::llm::{LlmProfile, GIB};

/// Latency model for one (model, GPU) deployment.
#[derive(Debug, Clone)]
pub struct LatencyModel {
    /// Decode iteration fixed cost, seconds (weight streaming + kernel
    /// launches + TP collectives).
    pub decode_base: f64,
    /// Additional decode cost per sequence in the batch, seconds.
    pub decode_per_seq: f64,
    /// Additional decode cost per token of total batch context, seconds.
    pub decode_per_ctx_token: f64,
    /// Prefill fixed cost, seconds.
    pub prefill_base: f64,
    /// Prefill cost per prompt token, seconds.
    pub prefill_per_token: f64,
    /// Fixed cost of a swap operation (launch/synchronization), seconds.
    pub swap_fixed: f64,
    /// Host↔device bandwidth, bytes/second.
    pub pcie_bytes_s: f64,
    /// KV bytes per token (from the LLM profile).
    pub kv_bytes_per_token: f64,
}

impl LatencyModel {
    /// Build the calibrated model for a (model, GPU) pair.
    pub fn for_deployment(llm: &LlmProfile, gpu: &GpuProfile) -> LatencyModel {
        let s = gpu.compute_scale;
        // Per-GPU weight bytes dominate the decode base (streamed from HBM
        // each iteration at ~2 TB/s on A100), plus a TP-collective tax per
        // extra GPU.
        let weight_gib_per_gpu = llm.model_mem_gib / gpu.num_gpus as f64;
        let hbm_gib_s = 1300.0; // effective A100 HBM bandwidth (decode MFU)
        let tp_tax = 1.0 + 0.25 * (gpu.num_gpus as f64 - 1.0);
        let decode_base = weight_gib_per_gpu / hbm_gib_s * tp_tax * s;
        // Per-sequence decode cost: activation + sampling overhead.
        // Calibrated so OPT-66B at its memory-saturated batch (~150 seqs,
        // ~70k ctx tokens) decodes in ~150 ms/iter → ≥6.6 tok/s per
        // request, the slack over user speeds that the paper's
        // preemptive time-multiplexing exploits (Fig. 3b, §2.3).
        let decode_per_seq = 0.18e-3 * (llm.params_b / 13.0).sqrt() * s;
        // Per-context-token: KV streaming + attention at a lower
        // effective bandwidth than dense weight streaming (gather-heavy
        // paged access patterns).
        let kv_hbm_gib_s = 1550.0;
        let kv_per_gpu = llm.kv_bytes_per_token() / gpu.num_gpus as f64;
        let decode_per_ctx_token = kv_per_gpu / (kv_hbm_gib_s * GIB) * tp_tax * s;
        // Prefill: 2·P flops per token at ~45% MFU of 312 TFLOPS/GPU.
        let flops_per_token = 2.0 * llm.params_b * 1e9;
        let cluster_flops = 312e12 * 0.45 * gpu.num_gpus as f64;
        let prefill_per_token = flops_per_token / cluster_flops * s;
        LatencyModel {
            decode_base,
            decode_per_seq,
            decode_per_ctx_token,
            prefill_base: decode_base, // one pass over the weights too
            prefill_per_token,
            swap_fixed: 3e-3,
            pcie_bytes_s: gpu.pcie_gib_s * GIB,
            kv_bytes_per_token: llm.kv_bytes_per_token(),
        }
    }

    /// Latency of one decode iteration for a batch of `batch_size`
    /// sequences holding `total_ctx_tokens` tokens of context in total.
    pub fn decode(&self, batch_size: usize, total_ctx_tokens: usize) -> f64 {
        if batch_size == 0 {
            return 0.0;
        }
        self.decode_base
            + self.decode_per_seq * batch_size as f64
            + self.decode_per_ctx_token * total_ctx_tokens as f64
    }

    /// Latency of prefilling `prompt_tokens` tokens (possibly several
    /// requests batched into one prefill pass).
    pub fn prefill(&self, prompt_tokens: usize) -> f64 {
        if prompt_tokens == 0 {
            return 0.0;
        }
        self.prefill_base + self.prefill_per_token * prompt_tokens as f64
    }

    /// Latency of swapping `tokens` of KV cache between GPU and host
    /// (either direction — PCIe is symmetric).
    pub fn swap(&self, tokens: usize) -> f64 {
        if tokens == 0 {
            return 0.0;
        }
        self.swap_fixed + tokens as f64 * self.kv_bytes_per_token / self.pcie_bytes_s
    }

    /// Latency of recomputing `tokens` of KV cache (= a prefill pass).
    pub fn recompute(&self, tokens: usize) -> f64 {
        self.prefill(tokens)
    }

    /// Steady-state per-request token generation speed at a given batch
    /// size and average per-request context length.
    pub fn tokens_per_sec(&self, batch_size: usize, avg_ctx: usize) -> f64 {
        if batch_size == 0 {
            return 0.0;
        }
        1.0 / self.decode(batch_size, batch_size * avg_ctx)
    }

    /// Largest batch size whose decode iteration is still faster than
    /// `1/tds` — the `B_min` bound of the paper's Optimization #2
    /// (a smaller batch would overserve and waste capacity). Uses the
    /// given average context length per sequence. Returns at least 1.
    pub fn max_batch_for_tds(&self, tds: f64, avg_ctx: usize) -> usize {
        let budget = 1.0 / tds;
        let per_seq = self.decode_per_seq + self.decode_per_ctx_token * avg_ctx as f64;
        if self.decode_base >= budget {
            return 1;
        }
        (((budget - self.decode_base) / per_seq).floor() as usize).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gpu::{a100_4x, a40_1x};
    use crate::model::llm::{opt_13b, opt_66b};

    fn m66() -> LatencyModel {
        LatencyModel::for_deployment(&opt_66b(), &a100_4x())
    }

    #[test]
    fn calibration_66b_matches_paper_speed() {
        // Paper Fig. 3b: per-request generation speed 6.6–10 tok/s on
        // OPT-66B / 4×A100 under realistic batches (avg ctx ≈ 500).
        let m = m66();
        let fast = m.tokens_per_sec(10, 500);
        let loaded = m.tokens_per_sec(120, 500);
        assert!(fast > 10.0, "lightly-loaded speed {fast}");
        assert!((4.0..9.0).contains(&loaded), "loaded speed {loaded}");
    }

    #[test]
    fn decode_monotone_in_batch_and_ctx() {
        let m = m66();
        assert!(m.decode(2, 100) > m.decode(1, 100));
        assert!(m.decode(10, 5000) > m.decode(10, 100));
        assert_eq!(m.decode(0, 0), 0.0);
    }

    #[test]
    fn swap_close_to_one_iteration() {
        // Appendix D: swapping one request's KV ≈ one decode iteration.
        let m = m66();
        let iter = m.decode(100, 50_000);
        let swap = m.swap(500); // one avg request's context
        assert!(swap < 3.0 * iter && swap > 0.05 * iter, "swap {swap}, iter {iter}");
    }

    #[test]
    fn recompute_more_expensive_than_swap_for_long_ctx() {
        let m = m66();
        // Paper Fig. 20: recomputation overhead exceeds swap on this
        // node configuration for substantial contexts.
        assert!(m.recompute(1000) > m.swap(1000));
    }

    #[test]
    fn prefill_linear() {
        let m = m66();
        let a = m.prefill(100);
        let b = m.prefill(1100);
        assert!((b - a - 1000.0 * m.prefill_per_token).abs() < 1e-12);
    }

    #[test]
    fn a40_slower() {
        let m13_a100 =
            LatencyModel::for_deployment(&opt_13b(), &crate::model::gpu::a100_1x());
        let m13_a40 = LatencyModel::for_deployment(&opt_13b(), &a40_1x());
        assert!(m13_a40.decode(10, 1000) > 2.0 * m13_a100.decode(10, 1000));
    }

    #[test]
    fn max_batch_for_tds_bounds() {
        let m = m66();
        // For reading speed 4.8 tok/s the serving budget is ~208ms/iter.
        let b = m.max_batch_for_tds(4.8, 500);
        assert!(b >= 1);
        // The found B indeed meets the budget and B+1 does not.
        assert!(m.decode(b, b * 500) <= 1.0 / 4.8 + 1e-9);
        assert!(m.decode(b + 1, (b + 1) * 500) > 1.0 / 4.8 - 1e-3);
        // A stricter TDS allows a smaller batch.
        assert!(m.max_batch_for_tds(20.0, 500) <= b);
    }

    #[test]
    fn max_batch_handles_impossible_tds() {
        let m = m66();
        // TDS faster than even batch-1 decode → returns 1.
        assert_eq!(m.max_batch_for_tds(1000.0, 500), 1);
    }
}
