//! Token-stream + brace-tree parser: the substrate all lint rules run on.
//!
//! [`lex`] is a total, dependency-light Rust lexer: any byte sequence in,
//! a contiguous spanned token stream out. Tokens carry exact byte spans
//! (`lo..hi`) plus the (0-based) line and char-based column where they
//! start, and whitespace/comments are tokens too — so concatenating the
//! spans of every token reconstructs the source byte-for-byte, which the
//! span-fidelity property test in `rust/tests/lint.rs` pins. [`ParsedFile`]
//! adds the brace/paren/bracket tree on top: a map from every opening
//! delimiter token to its matching close, total over unbalanced input.
//!
//! The per-line blanking pass in [`super::lexer`] is kept as an oracle:
//! [`to_stripped`] projects the token stream back into the legacy
//! [`Stripped`] view (same blanking, same captured comments and string
//! literals), and an agreement sweep over every file in `rust/src/`
//! asserts the two front ends never disagree on comment/string extents.
//! Line-oriented rules (D1, D3, X1) still run on that projection; the
//! token-native rules (D2, D4–D7, C1, C2) walk the stream directly.
//!
//! ```
//! let p = andes::analysis::parse::ParsedFile::parse("fn f() { g(1); }");
//! let idents: Vec<&str> = p
//!     .tokens
//!     .iter()
//!     .filter(|t| t.kind == andes::analysis::parse::TokKind::Ident)
//!     .map(|t| t.text(p.src.as_str()))
//!     .collect();
//! assert_eq!(idents, ["fn", "f", "g"]);
//! ```

use std::collections::BTreeMap;

use super::lexer::{StrLit, Stripped};

/// Token classification. String-like kinds remember whether their closer
/// was ever seen (`closed`), so unterminated literals stay representable
/// without panicking and the stripped projection can mirror the legacy
/// lexer's discard-at-EOF behavior.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (including raw identifiers like `r#fn`).
    Ident,
    /// Lifetime marker (`'a`, `'static`).
    Lifetime,
    /// Numeric literal (`42`, `0.5`, `1e-3`, `0xFF`).
    Num,
    /// Single punctuation character.
    Punct,
    /// Char literal (`'x'`, `'\n'`, `'\u{1F600}'`), always single-line.
    Char,
    /// Plain string literal (`"…"`).
    Str { closed: bool },
    /// Byte string literal (`b"…"`).
    ByteStr { closed: bool },
    /// Raw (byte) string; `prefix` is the char count before the hashes
    /// (1 for `r`, 2 for `br`), `hashes` the opener's `#` count.
    RawStr { closed: bool, hashes: usize, prefix: usize },
    /// Line comment, `//` to end of line (newline excluded).
    LineComment,
    /// Block comment, nesting-aware.
    BlockComment { closed: bool },
    /// Run of whitespace (may span lines).
    Whitespace,
}

/// One spanned token. `lo..hi` are byte offsets into the source; `line`
/// and `col` are the 0-based line and char-based column of the first
/// character (multi-byte chars count as one column, matching the legacy
/// strip pass).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Token {
    pub kind: TokKind,
    pub lo: usize,
    pub hi: usize,
    pub line: usize,
    pub col: usize,
}

impl Token {
    /// The source text this token covers.
    pub fn text<'a>(&self, src: &'a str) -> &'a str {
        &src[self.lo..self.hi]
    }

    /// Whitespace or comment — skipped by the significant-token view.
    pub fn is_trivia(&self) -> bool {
        matches!(
            self.kind,
            TokKind::Whitespace | TokKind::LineComment | TokKind::BlockComment { .. }
        )
    }

    /// Is this token the single punctuation character `c`?
    pub fn is_punct(&self, src: &str, c: char) -> bool {
        self.kind == TokKind::Punct && self.text(src).chars().next() == Some(c)
    }

    /// Is this token the identifier `name`?
    pub fn is_ident(&self, src: &str, name: &str) -> bool {
        self.kind == TokKind::Ident && self.text(src) == name
    }
}

/// A lexed file with the significant-token view and the delimiter tree.
#[derive(Debug, Clone)]
pub struct ParsedFile {
    pub src: String,
    pub tokens: Vec<Token>,
    /// Indices into `tokens` of every non-trivia token, in order.
    pub sig: Vec<usize>,
    /// Matching-delimiter map over *token indices*: every `(`/`[`/`{`
    /// token with a matching closer maps to that closer's index.
    pub pairs: BTreeMap<usize, usize>,
}

impl ParsedFile {
    pub fn parse(src: &str) -> ParsedFile {
        let tokens = lex(src);
        let sig: Vec<usize> = tokens
            .iter()
            .enumerate()
            .filter(|(_, t)| !t.is_trivia())
            .map(|(i, _)| i)
            .collect();
        let mut pairs = BTreeMap::new();
        let mut stack: Vec<(char, usize)> = Vec::new();
        for &ti in &sig {
            let t = &tokens[ti];
            if t.kind != TokKind::Punct {
                continue;
            }
            match t.text(src).chars().next() {
                Some(c @ ('(' | '[' | '{')) => stack.push((c, ti)),
                Some(c @ (')' | ']' | '}')) => {
                    let open = match c {
                        ')' => '(',
                        ']' => '[',
                        _ => '{',
                    };
                    // Total on unbalanced input: a stray closer that does
                    // not match the innermost open delimiter is ignored.
                    if stack.last().map(|&(o, _)| o) == Some(open) {
                        let (_, oi) = stack.pop().expect("non-empty stack");
                        pairs.insert(oi, ti);
                    }
                }
                _ => {}
            }
        }
        ParsedFile {
            src: src.to_string(),
            tokens,
            sig,
            pairs,
        }
    }
}

/// Tokenize `src`. Total: never panics, every byte lands in exactly one
/// token, and token spans are contiguous (`tokens[i].hi == tokens[i+1].lo`).
pub fn lex(src: &str) -> Vec<Token> {
    let chars: Vec<(usize, char)> = src.char_indices().collect();
    let n = chars.len();
    let mut toks = Vec::new();
    let mut i = 0usize;
    let mut line = 0usize;
    let mut col = 0usize;
    let at = |j: usize| chars.get(j).map(|p| p.1);
    while i < n {
        let start = i;
        let (lo, c) = chars[i];
        let (tline, tcol) = (line, col);
        let kind = if c.is_whitespace() {
            while i < n && chars[i].1.is_whitespace() {
                i += 1;
            }
            TokKind::Whitespace
        } else if c == '/' && at(i + 1) == Some('/') {
            while i < n && chars[i].1 != '\n' {
                i += 1;
            }
            TokKind::LineComment
        } else if c == '/' && at(i + 1) == Some('*') {
            i += 2;
            let mut depth = 1u32;
            while i < n && depth > 0 {
                if chars[i].1 == '/' && at(i + 1) == Some('*') {
                    depth += 1;
                    i += 2;
                } else if chars[i].1 == '*' && at(i + 1) == Some('/') {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            TokKind::BlockComment { closed: depth == 0 }
        } else if c == '"' {
            i += 1;
            let closed = scan_plain_str(&chars, &mut i);
            TokKind::Str { closed }
        } else if c == '\'' {
            match char_lit_len(&chars, i) {
                Some(len) => {
                    i += len;
                    TokKind::Char
                }
                None => {
                    if at(i + 1).is_some_and(|ch| ch.is_alphabetic() || ch == '_') {
                        i += 1;
                        while i < n && (chars[i].1.is_alphanumeric() || chars[i].1 == '_') {
                            i += 1;
                        }
                        TokKind::Lifetime
                    } else {
                        i += 1;
                        TokKind::Punct
                    }
                }
            }
        } else if c.is_ascii_digit() {
            scan_num(&chars, &mut i);
            TokKind::Num
        } else if c.is_alphanumeric() || c == '_' {
            while i < n && (chars[i].1.is_alphanumeric() || chars[i].1 == '_') {
                i += 1;
            }
            let word: String = chars[start..i].iter().map(|p| p.1).collect();
            // Prefix reinterpretations, mirroring the legacy lexer's
            // ident_before guard (a preceding ident char would have been
            // absorbed into a longer word, so `word` is standalone here).
            let raw_prefix = match word.as_str() {
                "r" => Some(1usize),
                "br" => Some(2usize),
                _ => None,
            };
            let mut kind = TokKind::Ident;
            if let Some(prefix) = raw_prefix {
                let mut hashes = 0usize;
                while at(i + hashes) == Some('#') {
                    hashes += 1;
                }
                if at(i + hashes) == Some('"') {
                    i += hashes + 1;
                    let closed = scan_raw_str(&chars, &mut i, hashes);
                    kind = TokKind::RawStr {
                        closed,
                        hashes,
                        prefix,
                    };
                } else if prefix == 1
                    && hashes == 1
                    && at(i + 1).is_some_and(|ch| ch.is_alphabetic() || ch == '_')
                {
                    // Raw identifier `r#name`.
                    i += 2;
                    while i < n && (chars[i].1.is_alphanumeric() || chars[i].1 == '_') {
                        i += 1;
                    }
                }
            } else if word == "b" && at(i) == Some('"') {
                i += 1;
                let closed = scan_plain_str(&chars, &mut i);
                kind = TokKind::ByteStr { closed };
            }
            kind
        } else {
            i += 1;
            TokKind::Punct
        };
        let hi = if i < n { chars[i].0 } else { src.len() };
        toks.push(Token {
            kind,
            lo,
            hi,
            line: tline,
            col: tcol,
        });
        for k in start..i {
            if chars[k].1 == '\n' {
                line += 1;
                col = 0;
            } else {
                col += 1;
            }
        }
    }
    toks
}

/// Consume a plain (or byte) string body; `i` sits just past the opening
/// quote. Escapes never cross a line break, matching the legacy pass.
fn scan_plain_str(chars: &[(usize, char)], i: &mut usize) -> bool {
    while *i < chars.len() {
        let c = chars[*i].1;
        if c == '\\' && *i + 1 < chars.len() && chars[*i + 1].1 != '\n' {
            *i += 2;
        } else if c == '"' {
            *i += 1;
            return true;
        } else {
            *i += 1;
        }
    }
    false
}

/// Consume a raw string body; `i` sits just past the opening quote. The
/// closer is a quote followed by at least `hashes` hash marks, of which
/// exactly `hashes` belong to the literal.
fn scan_raw_str(chars: &[(usize, char)], i: &mut usize, hashes: usize) -> bool {
    while *i < chars.len() {
        if chars[*i].1 == '"' {
            let mut h = 0usize;
            while *i + 1 + h < chars.len() && chars[*i + 1 + h].1 == '#' {
                h += 1;
            }
            if h >= hashes {
                *i += 1 + hashes;
                return true;
            }
        }
        *i += 1;
    }
    false
}

/// Consume a numeric literal starting at a digit: integer/float bodies
/// with `_` separators, a fractional part only when a digit follows the
/// dot (so `0.5.total_cmp` stops after `0.5` and `1..4` after `1`), and
/// signed exponents.
fn scan_num(chars: &[(usize, char)], i: &mut usize) {
    let body = |c: char| c.is_alphanumeric() || c == '_';
    while *i < chars.len() && body(chars[*i].1) {
        *i += 1;
    }
    if *i + 1 < chars.len() && chars[*i].1 == '.' && chars[*i + 1].1.is_ascii_digit() {
        *i += 1;
        while *i < chars.len() && body(chars[*i].1) {
            *i += 1;
        }
    }
    if *i < chars.len()
        && matches!(chars[*i].1, '+' | '-')
        && chars
            .get(i.wrapping_sub(1))
            .is_some_and(|p| matches!(p.1, 'e' | 'E'))
        && chars.get(*i + 1).is_some_and(|p| p.1.is_ascii_digit())
    {
        *i += 1;
        while *i < chars.len() && body(chars[*i].1) {
            *i += 1;
        }
    }
}

/// Length in chars of the char literal opening at `chars[i] == '\''`, or
/// `None` when this quote starts a lifetime (or is stray). Mirrors the
/// legacy `char_literal_len` exactly, including its same-line restriction.
fn char_lit_len(chars: &[(usize, char)], i: usize) -> Option<usize> {
    let line_len = chars[i..]
        .iter()
        .position(|p| p.1 == '\n')
        .unwrap_or(chars.len() - i);
    let n = i + line_len;
    let get = |j: usize| if j < n { Some(chars[j].1) } else { None };
    if i + 1 >= n {
        return None;
    }
    if get(i + 1) == Some('\\') {
        if get(i + 2) == Some('u') {
            for j in i + 3..n {
                if chars[j].1 == '\'' {
                    return Some(j - i + 1);
                }
            }
            return None;
        }
        if get(i + 3) == Some('\'') {
            return Some(4);
        }
        return None;
    }
    if get(i + 2) == Some('\'') && get(i + 1) != Some('\'') {
        return Some(3);
    }
    None
}

/// Project the token stream back into the legacy [`Stripped`] view:
/// comments and literal contents blanked to spaces column-for-column,
/// comment text captured per line, and every *closed* string literal
/// recorded with its opening line/column. Byte-identical to
/// `lexer::strip_source` on every input (pinned by the agreement sweep
/// in `rust/tests/lint.rs`).
pub fn to_stripped(src: &str, tokens: &[Token]) -> Stripped {
    let mut out = Stripped {
        code: vec![String::new()],
        comments: vec![String::new()],
        strings: Vec::new(),
    };
    let newline = |out: &mut Stripped| {
        out.code.push(String::new());
        out.comments.push(String::new());
    };
    for t in tokens {
        let text = t.text(src);
        match t.kind {
            TokKind::Whitespace => {
                for c in text.chars() {
                    if c == '\n' {
                        newline(&mut out);
                    } else {
                        out.code.last_mut().expect("non-empty").push(c);
                    }
                }
            }
            TokKind::Ident | TokKind::Lifetime | TokKind::Num | TokKind::Punct => {
                out.code.last_mut().expect("non-empty").push_str(text);
            }
            TokKind::LineComment => {
                for c in text.chars() {
                    out.comments.last_mut().expect("non-empty").push(c);
                    out.code.last_mut().expect("non-empty").push(' ');
                }
            }
            TokKind::BlockComment { .. } => {
                for c in text.chars() {
                    if c == '\n' {
                        newline(&mut out);
                    } else {
                        out.comments.last_mut().expect("non-empty").push(c);
                        out.code.last_mut().expect("non-empty").push(' ');
                    }
                }
            }
            TokKind::Char => {
                let m = text.chars().count();
                let code = out.code.last_mut().expect("non-empty");
                code.push('\'');
                for _ in 0..m.saturating_sub(2) {
                    code.push(' ');
                }
                code.push('\'');
            }
            TokKind::Str { closed } => {
                blank_literal(&mut out, text, 0);
                if closed {
                    record_lit(&mut out, t, lit_slice(text, 1, 1));
                }
            }
            TokKind::ByteStr { closed } => {
                blank_literal(&mut out, text, 1);
                if closed {
                    record_lit(&mut out, t, lit_slice(text, 2, 1));
                }
            }
            TokKind::RawStr {
                closed,
                hashes,
                prefix,
            } => {
                blank_literal(&mut out, text, 0);
                if closed {
                    record_lit(&mut out, t, lit_slice(text, prefix + hashes + 1, 1 + hashes));
                }
            }
        }
    }
    out
}

/// Blank a string-like token into the code view: the first `keep` chars
/// pass through (the `b` of a byte string survives blanking in the
/// legacy pass), everything else becomes a space, newlines split lines.
fn blank_literal(out: &mut Stripped, text: &str, keep: usize) {
    for (k, c) in text.chars().enumerate() {
        if c == '\n' {
            out.code.push(String::new());
            out.comments.push(String::new());
        } else if k < keep {
            out.code.last_mut().expect("non-empty").push(c);
        } else {
            out.code.last_mut().expect("non-empty").push(' ');
        }
    }
}

/// The literal body: `text` minus `head` leading and `tail` trailing
/// *chars* (ASCII here, but counted as chars for safety).
fn lit_slice(text: &str, head: usize, tail: usize) -> String {
    let total = text.chars().count();
    text.chars()
        .skip(head)
        .take(total.saturating_sub(head + tail))
        .collect()
}

fn record_lit(out: &mut Stripped, t: &Token, content: String) {
    out.strings.push(StrLit {
        line: t.line,
        col: t.col,
        content,
    });
}

#[cfg(test)]
mod tests {
    use super::super::lexer::strip_source;
    use super::*;

    fn kinds(src: &str) -> Vec<TokKind> {
        lex(src).iter().map(|t| t.kind).collect()
    }

    /// Spans must tile the source exactly; concatenation reconstructs it.
    fn assert_tiling(src: &str) {
        let toks = lex(src);
        let mut at = 0usize;
        let mut rebuilt = String::new();
        for t in &toks {
            assert_eq!(t.lo, at, "gap before token at byte {at} in {src:?}");
            rebuilt.push_str(t.text(src));
            at = t.hi;
        }
        assert_eq!(at, src.len(), "tokens stop early in {src:?}");
        assert_eq!(rebuilt, src);
    }

    /// The token projection must agree with the legacy strip pass.
    fn assert_agrees(src: &str) {
        let legacy = strip_source(src);
        let toks = lex(src);
        let ours = to_stripped(src, &toks);
        assert_eq!(ours.code, legacy.code, "code view drifted for {src:?}");
        assert_eq!(ours.comments, legacy.comments, "comments drifted for {src:?}");
        assert_eq!(ours.strings, legacy.strings, "strings drifted for {src:?}");
    }

    #[test]
    fn basic_token_stream() {
        let src = "fn f(x: u32) -> f64 { x as f64 * 0.5 }";
        assert_tiling(src);
        let idents: Vec<&str> = lex(src)
            .iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text(src))
            .collect();
        assert_eq!(idents, ["fn", "f", "x", "u32", "f64", "x", "as", "f64"]);
    }

    #[test]
    fn string_kinds_and_contents() {
        let src = "let a = \"s\"; let b = b\"y\"; let c = r#\"raw \" q\"#; let d = br\"z\";";
        assert_tiling(src);
        assert_agrees(src);
        let toks = lex(src);
        let strs: Vec<TokKind> = toks
            .iter()
            .filter(|t| {
                matches!(
                    t.kind,
                    TokKind::Str { .. } | TokKind::ByteStr { .. } | TokKind::RawStr { .. }
                )
            })
            .map(|t| t.kind)
            .collect();
        assert_eq!(
            strs,
            [
                TokKind::Str { closed: true },
                TokKind::ByteStr { closed: true },
                TokKind::RawStr {
                    closed: true,
                    hashes: 1,
                    prefix: 1
                },
                TokKind::RawStr {
                    closed: true,
                    hashes: 0,
                    prefix: 2
                },
            ]
        );
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        let src = "let q = '\"'; fn f<'a>(x: &'a str) -> char { '\\n' }";
        assert_tiling(src);
        assert_agrees(src);
        let toks = lex(src);
        assert_eq!(
            toks.iter().filter(|t| t.kind == TokKind::Char).count(),
            2,
            "{toks:?}"
        );
        assert_eq!(toks.iter().filter(|t| t.kind == TokKind::Lifetime).count(), 2);
    }

    #[test]
    fn comments_nest_and_span_lines() {
        let src = "a /* one /* two */ still */ b\nc // end\nd /* open\nmid\n*/ e";
        assert_tiling(src);
        assert_agrees(src);
    }

    #[test]
    fn unterminated_constructs_are_total() {
        for src in ["\"open", "/* open", "r#\"open", "b\"open", "let a = 'x", "fn f() {"] {
            assert_tiling(src);
            assert_agrees(src);
        }
    }

    #[test]
    fn line_and_col_are_char_based() {
        let src = "let s = \"héllo\";\nlet 'x = 0;";
        let toks = lex(src);
        let lit = toks
            .iter()
            .find(|t| matches!(t.kind, TokKind::Str { .. }))
            .expect("literal");
        assert_eq!((lit.line, lit.col), (0, 8));
        // The second line starts at col 0 despite the multi-byte char above.
        let second = toks.iter().find(|t| t.line == 1).expect("line 1 token");
        assert_eq!(second.col, 0);
        assert_agrees(src);
    }

    #[test]
    fn numbers_do_not_swallow_methods_or_ranges() {
        let src = "a(0.5.total_cmp(&b), 1..4, 1e-3, 0xFF_u32)";
        assert_tiling(src);
        let nums: Vec<&str> = lex(src)
            .iter()
            .filter(|t| t.kind == TokKind::Num)
            .map(|t| t.text(src))
            .collect();
        assert_eq!(nums, ["0.5", "1", "4", "1e-3", "0xFF_u32"]);
    }

    #[test]
    fn delimiter_tree_matches_pairs() {
        let src = "fn f(a: [u8; 4]) { g(h(1), [2]); }";
        let p = ParsedFile::parse(src);
        for (&open, &close) in &p.pairs {
            let o = p.tokens[open].text(src);
            let c = p.tokens[close].text(src);
            let expect = match o {
                "(" => ")",
                "[" => "]",
                "{" => "}",
                other => panic!("non-delimiter open {other:?}"),
            };
            assert_eq!(c, expect);
            assert!(open < close);
        }
        assert_eq!(p.pairs.len(), 6, "{:?}", p.pairs);
    }

    #[test]
    fn unbalanced_input_keeps_partial_pairs() {
        let p = ParsedFile::parse("fn f() { g(1); ]");
        // `(`..`)` inside matches; the stray `]` and unclosed `{` do not.
        assert_eq!(p.pairs.len(), 2, "{:?}", p.pairs);
    }

    #[test]
    fn raw_identifiers_lex_as_idents() {
        let src = "let r#fn = r#type;";
        assert_tiling(src);
        let toks = lex(src);
        let raws: Vec<&str> = toks
            .iter()
            .filter(|t| t.kind == TokKind::Ident && t.text(src).starts_with("r#"))
            .map(|t| t.text(src))
            .collect();
        assert_eq!(raws, ["r#fn", "r#type"]);
    }

    #[test]
    fn byte_string_keeps_its_prefix_in_code_view() {
        assert_agrees("let b = b\"bytes\"; let n = xb\"not a byte string\";");
    }

    #[test]
    fn trivia_kind_mix() {
        let src = "x\t y\n\n z";
        assert_eq!(
            kinds(src),
            [
                TokKind::Ident,
                TokKind::Whitespace,
                TokKind::Ident,
                TokKind::Whitespace,
                TokKind::Ident
            ]
        );
    }
}
