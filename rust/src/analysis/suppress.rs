//! Inline suppression directives and `#[cfg(test)]` range detection.
//!
//! A finding can be waived at the site with a comment directive:
//!
//! ```text
//! // lint:allow(D6, pop() follows a non-empty check on the same branch)
//! ```
//!
//! The rule id is required; the reason is free text and strongly
//! encouraged (DESIGN.md §13 treats a missing reason as a review smell,
//! though the scanner accepts it). A directive suppresses matching
//! findings on its own line; when the directive sits on a comment-only
//! line it also covers the line immediately below, so it can be placed
//! above the offending statement without fighting rustfmt's line width.

use std::collections::{BTreeMap, BTreeSet};

use super::lexer::Stripped;

/// Parsed `lint:allow` directives for one file, keyed by 0-based line.
#[derive(Debug, Default)]
pub struct Suppressions {
    by_line: BTreeMap<usize, BTreeSet<String>>,
    /// Lines whose directive was consulted at least once (for
    /// unused-suppression accounting in the report).
    used: usize,
}

impl Suppressions {
    /// Extract directives from the comment text of a stripped file.
    pub fn parse(stripped: &Stripped) -> Self {
        let mut by_line: BTreeMap<usize, BTreeSet<String>> = BTreeMap::new();
        for (li, com) in stripped.comments.iter().enumerate() {
            for rule in directives(com) {
                by_line.entry(li).or_default().insert(rule.clone());
                // Comment-only line: the directive covers the next line.
                let code_only_ws = stripped
                    .code
                    .get(li)
                    .map(|c| c.trim().is_empty())
                    .unwrap_or(true);
                if code_only_ws {
                    by_line.entry(li + 1).or_default().insert(rule);
                }
            }
        }
        Suppressions { by_line, used: 0 }
    }

    /// Does a directive on `line` (0-based) waive `rule`? Counts a hit.
    pub fn allows(&mut self, line: usize, rule: &str) -> bool {
        let hit = self
            .by_line
            .get(&line)
            .map(|set| set.contains(rule))
            .unwrap_or(false);
        if hit {
            self.used += 1;
        }
        hit
    }

    /// Number of findings waived through this file's directives.
    pub fn hits(&self) -> usize {
        self.used
    }
}

/// Pull every `lint:allow(<rule>[, reason])` rule id out of a comment.
fn directives(comment: &str) -> Vec<String> {
    const NEEDLE: &str = "lint:allow(";
    let mut out = Vec::new();
    let mut rest = comment;
    while let Some(pos) = rest.find(NEEDLE) {
        let after = &rest[pos + NEEDLE.len()..];
        let body: String = after.chars().take_while(|&c| c != ')').collect();
        let rule = body.split(',').next().unwrap_or("").trim();
        if is_rule_id(rule) {
            out.push(rule.to_string());
        }
        rest = &rest[pos + NEEDLE.len()..];
    }
    out
}

/// Rule ids look like `D1`..`D9` or `X1`..`X9`.
fn is_rule_id(s: &str) -> bool {
    let b = s.as_bytes();
    b.len() == 2 && (b[0] == b'D' || b[0] == b'X') && b[1].is_ascii_digit()
}

/// Inclusive 0-based line ranges covered by `#[cfg(test)]` blocks, found
/// by brace-depth tracking from each attribute to its matching close.
/// Rules that only govern shipping code (D1, D5, D6, X1) skip these
/// ranges; tests are free to iterate hash maps or unwrap.
pub fn test_ranges(code: &[String]) -> Vec<(usize, usize)> {
    let mut ranges = Vec::new();
    let mut pending = false;
    let mut depth: i64 = 0;
    let mut start = 0usize;
    for (li, line) in code.iter().enumerate() {
        if line.contains("#[cfg(test)]") {
            pending = true;
        }
        if pending {
            for c in line.chars() {
                if c == '{' {
                    if depth == 0 {
                        start = li;
                    }
                    depth += 1;
                } else if c == '}' && depth > 0 {
                    depth -= 1;
                    if depth == 0 {
                        ranges.push((start, li));
                        pending = false;
                    }
                }
            }
        }
    }
    if pending && depth > 0 {
        ranges.push((start, code.len().saturating_sub(1)));
    }
    ranges
}

/// Is 0-based line `li` inside any of `ranges`?
pub fn in_ranges(ranges: &[(usize, usize)], li: usize) -> bool {
    ranges.iter().any(|&(a, b)| a <= li && li <= b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::strip_source;

    #[test]
    fn directive_on_own_line_covers_next_line() {
        let s = strip_source(
            "// lint:allow(D6, checked above)\nx.unwrap();\ny.unwrap(); // lint:allow(D6)\nz();",
        );
        let mut sup = Suppressions::parse(&s);
        assert!(sup.allows(0, "D6"));
        assert!(sup.allows(1, "D6"));
        assert!(sup.allows(2, "D6"));
        assert!(!sup.allows(3, "D6"));
        assert!(!sup.allows(1, "D2"));
        assert_eq!(sup.hits(), 3);
    }

    #[test]
    fn malformed_directives_are_ignored() {
        let s = strip_source("// lint:allow(banana)\n// lint:allow(D66)\nx.unwrap();");
        let mut sup = Suppressions::parse(&s);
        assert!(!sup.allows(2, "D6"));
    }

    #[test]
    fn cfg_test_ranges_track_braces() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n  fn t() {\n  }\n}\nfn b() {}";
        let s = strip_source(src);
        let ranges = test_ranges(&s.code);
        assert_eq!(ranges, vec![(2, 5)]);
        assert!(!in_ranges(&ranges, 0));
        assert!(in_ranges(&ranges, 4));
        assert!(!in_ranges(&ranges, 6));
    }
}
