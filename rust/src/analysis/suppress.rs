//! Inline suppression directives and `#[cfg(test)]` range detection.
//!
//! A finding can be waived at the site with a comment directive:
//!
//! ```text
//! // lint:allow(D6, pop() follows a non-empty check on the same branch)
//! ```
//!
//! The rule id is required; the reason is free text and strongly
//! encouraged (DESIGN.md §13 treats a missing reason as a review smell,
//! though the scanner accepts it). A directive suppresses matching
//! findings on its own line; when the directive sits on a comment-only
//! line it also covers the line immediately below, so it can be placed
//! above the offending statement without fighting rustfmt's line width.
//!
//! Directives inside doc comments (`///`, `//!`, `/**`, `/*!`) are
//! ignored: a documentation example that *shows* a directive must not
//! waive anything in the file that documents it.
//!
//! Every directive carries identity: one that waives no finding is
//! itself reported as a W1 finding (unused suppression), so stale
//! waivers can't silently linger after the code they excused is fixed.

use std::collections::BTreeMap;

use super::lexer::Stripped;

/// One parsed `lint:allow` directive with its consumption state.
#[derive(Debug, Clone)]
struct Directive {
    /// 0-based line the directive's comment sits on.
    line: usize,
    rule: String,
    used: bool,
}

/// Parsed `lint:allow` directives for one file.
#[derive(Debug, Default)]
pub struct Suppressions {
    directives: Vec<Directive>,
    /// Covered line (0-based) → indices into `directives`.
    by_line: BTreeMap<usize, Vec<usize>>,
    /// Findings waived so far.
    waived: usize,
}

impl Suppressions {
    /// Extract directives from the comment text of a stripped file.
    pub fn parse(stripped: &Stripped) -> Self {
        let mut sup = Suppressions::default();
        for (li, com) in stripped.comments.iter().enumerate() {
            if is_doc_comment(com) {
                continue;
            }
            for rule in directives(com) {
                let idx = sup.directives.len();
                sup.directives.push(Directive {
                    line: li,
                    rule,
                    used: false,
                });
                sup.by_line.entry(li).or_default().push(idx);
                // Comment-only line: the directive covers the next line.
                let code_only_ws = stripped
                    .code
                    .get(li)
                    .map(|c| c.trim().is_empty())
                    .unwrap_or(true);
                if code_only_ws {
                    sup.by_line.entry(li + 1).or_default().push(idx);
                }
            }
        }
        sup
    }

    /// Does a directive on `line` (0-based) waive `rule`? Counts a hit
    /// and marks the matching directive(s) as used.
    pub fn allows(&mut self, line: usize, rule: &str) -> bool {
        let mut hit = false;
        if let Some(idxs) = self.by_line.get(&line) {
            for &i in idxs {
                if self.directives[i].rule == rule {
                    self.directives[i].used = true;
                    hit = true;
                }
            }
        }
        if hit {
            self.waived += 1;
        }
        hit
    }

    /// Number of findings waived through this file's directives.
    pub fn hits(&self) -> usize {
        self.waived
    }

    /// Directives that waived nothing, as (0-based line, rule id) —
    /// deduplicated, in source order. Reported as W1 findings.
    pub fn unused(&self) -> Vec<(usize, String)> {
        let mut out: Vec<(usize, String)> = Vec::new();
        for d in &self.directives {
            if !d.used && !out.iter().any(|(l, r)| *l == d.line && *r == d.rule) {
                out.push((d.line, d.rule.clone()));
            }
        }
        out
    }
}

/// Pull every `lint:allow(<rule>[, reason])` rule id out of a comment.
fn directives(comment: &str) -> Vec<String> {
    const NEEDLE: &str = "lint:allow(";
    let mut out = Vec::new();
    let mut rest = comment;
    while let Some(pos) = rest.find(NEEDLE) {
        let after = &rest[pos + NEEDLE.len()..];
        let body: String = after.chars().take_while(|&c| c != ')').collect();
        let rule = body.split(',').next().unwrap_or("").trim();
        if is_rule_id(rule) {
            out.push(rule.to_string());
        }
        rest = &rest[pos + NEEDLE.len()..];
    }
    out
}

/// Does this line's captured comment text open with a doc comment?
/// (`////` is rustdoc's way of writing a *plain* comment, so it stays
/// eligible for directives.)
fn is_doc_comment(comment: &str) -> bool {
    let t = comment.trim_start();
    (t.starts_with("///") && !t.starts_with("////"))
        || t.starts_with("//!")
        || t.starts_with("/**")
        || t.starts_with("/*!")
}

/// Rule ids look like `D1`..`D9`, `C1`..`C9`, `W1`..`W9`, or `X1`..`X9`.
fn is_rule_id(s: &str) -> bool {
    let b = s.as_bytes();
    b.len() == 2 && matches!(b[0], b'D' | b'C' | b'W' | b'X') && b[1].is_ascii_digit()
}

/// Inclusive 0-based line ranges covered by `#[cfg(test)]` blocks, found
/// by brace-depth tracking from each attribute to its matching close.
/// Rules that only govern shipping code (D1, D5, D6, C1, C2, X1) skip
/// these ranges; tests are free to iterate hash maps or unwrap.
pub fn test_ranges(code: &[String]) -> Vec<(usize, usize)> {
    let mut ranges = Vec::new();
    let mut pending = false;
    let mut depth: i64 = 0;
    let mut start = 0usize;
    for (li, line) in code.iter().enumerate() {
        if line.contains("#[cfg(test)]") {
            pending = true;
        }
        if pending {
            for c in line.chars() {
                if c == '{' {
                    if depth == 0 {
                        start = li;
                    }
                    depth += 1;
                } else if c == '}' && depth > 0 {
                    depth -= 1;
                    if depth == 0 {
                        ranges.push((start, li));
                        pending = false;
                    }
                }
            }
        }
    }
    if pending && depth > 0 {
        ranges.push((start, code.len().saturating_sub(1)));
    }
    ranges
}

/// Is 0-based line `li` inside any of `ranges`?
pub fn in_ranges(ranges: &[(usize, usize)], li: usize) -> bool {
    ranges.iter().any(|&(a, b)| a <= li && li <= b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::strip_source;

    #[test]
    fn directive_on_own_line_covers_next_line() {
        let s = strip_source(
            "// lint:allow(D6, checked above)\nx.unwrap();\ny.unwrap(); // lint:allow(D6)\nz();",
        );
        let mut sup = Suppressions::parse(&s);
        assert!(sup.allows(0, "D6"));
        assert!(sup.allows(1, "D6"));
        assert!(sup.allows(2, "D6"));
        assert!(!sup.allows(3, "D6"));
        assert!(!sup.allows(1, "D2"));
        assert_eq!(sup.hits(), 3);
        assert!(sup.unused().is_empty(), "{:?}", sup.unused());
    }

    #[test]
    fn malformed_directives_are_ignored() {
        let s = strip_source("// lint:allow(banana)\n// lint:allow(D66)\nx.unwrap();");
        let mut sup = Suppressions::parse(&s);
        assert!(!sup.allows(2, "D6"));
        assert!(sup.unused().is_empty());
    }

    #[test]
    fn unconsumed_directives_surface_as_unused() {
        let s = strip_source("// lint:allow(D2, stale)\nclean();\nx(); // lint:allow(C1)\n");
        let mut sup = Suppressions::parse(&s);
        assert!(sup.allows(2, "C1"));
        assert_eq!(sup.unused(), vec![(0, "D2".to_string())]);
    }

    #[test]
    fn doc_comment_directives_are_inert() {
        let s = strip_source(
            "//! // lint:allow(D6, doc example, not a waiver)\n/// lint:allow(D2, same)\nf();",
        );
        let sup = Suppressions::parse(&s);
        assert!(sup.unused().is_empty(), "{:?}", sup.unused());
        let mut sup = sup;
        assert!(!sup.allows(0, "D6"));
        assert!(!sup.allows(2, "D2"));
    }

    #[test]
    fn extended_rule_prefixes_parse() {
        let s = strip_source("// lint:allow(C2, sanctioned) lint:allow(W1, meta)\nx();");
        let mut sup = Suppressions::parse(&s);
        assert!(sup.allows(1, "C2"));
        assert!(sup.allows(1, "W1"));
    }

    #[test]
    fn cfg_test_ranges_track_braces() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n  fn t() {\n  }\n}\nfn b() {}";
        let s = strip_source(src);
        let ranges = test_ranges(&s.code);
        assert_eq!(ranges, vec![(2, 5)]);
        assert!(!in_ranges(&ranges, 0));
        assert!(in_ranges(&ranges, 4));
        assert!(!in_ranges(&ranges, 6));
    }
}
